//! # ULEEN — Ultra Low-Energy Edge Neural Networks (full-system reproduction)
//!
//! This crate is the Layer-3 (runtime) half of a three-layer reproduction of
//! *"ULEEN: A Novel Architecture for Ultra Low-Energy Edge Neural Networks"*
//! (Susskind et al., cs.AR 2023):
//!
//! * **L1/L2** live in `python/compile/`: Pallas kernels for the
//!   hash-and-lookup hot-spot and the JAX ensemble model (multi-shot STE
//!   training), AOT-lowered once to `artifacts/*.hlo.txt`.
//! * **L3 (this crate)** owns everything at runtime: a native bit-packed
//!   weightless-neural-network inference engine, one-shot training with
//!   bleaching, the serving coordinator (router / dynamic batcher / worker
//!   pool), a PJRT runtime that loads the AOT artifacts, and the hardware
//!   co-design models (cycle-level accelerator simulator, FPGA & 45 nm ASIC
//!   cost models, FINN and Bit Fusion baselines) used to regenerate every
//!   table and figure in the paper's evaluation.
//!
//! The public API is organised bottom-up: [`util`] and the substrates
//! ([`encoding`], [`hash`], [`bloom`], [`data`]) → the model core
//! ([`model`], [`train`]) → the runtime ([`runtime`], [`coordinator`]) →
//! hardware co-design ([`hw`]) and the bench harness ([`bench`]).

// Style lints that fight deliberate idioms in this crate — the §Perf
// hot-path style of explicit index loops over parallel flat arrays, the
// hand-rolled offline substrates (Json's inherent `to_string`), and
// config structs built by field init. CI denies every other clippy
// warning on the library and binary targets (`cargo clippy -- -D
// warnings`); tests/benches/examples are compiled by the build job but
// not lint-gated.
#![allow(
    clippy::needless_range_loop,
    clippy::too_many_arguments,
    clippy::type_complexity,
    clippy::inherent_to_string,
    clippy::new_without_default,
    clippy::manual_memcpy,
    clippy::comparison_chain,
    clippy::collapsible_else_if
)]

pub mod bench;
pub mod bloom;
pub mod coordinator;
pub mod data;
pub mod encoding;
pub mod hash;
pub mod hw;
pub mod model;
pub mod runtime;
pub mod train;
pub mod util;

/// Crate-wide result alias.
pub type Result<T> = anyhow::Result<T>;

// The lib's own test harness runs under the counting allocator so the
// steady-state zero-allocation witness tests (runtime::, coordinator::)
// can count per-thread heap traffic; overhead is one relaxed atomic
// increment per allocation. Production builds never see this — the
// module itself is gated on `cfg(test)` / the `alloc-witness` feature.
#[cfg(test)]
#[global_allocator]
static ALLOC_WITNESS: util::alloc_witness::CountingAlloc = util::alloc_witness::CountingAlloc;
