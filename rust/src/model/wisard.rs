//! Classic WiSARD (Aleksander et al., 1981) — the paper's Fig 10 starting
//! point: direct 2^n-entry RAM nodes, one-shot set-on-seen training, no
//! hashing, no bleaching, no thermometer (callers choose the encoding).

use crate::encoding::thermometer::ThermometerEncoder;
use crate::model::submodel::SubmodelConfig;
use crate::util::bitvec::BitVec;
use crate::util::rng::Rng;
use crate::util::stats::Confusion;

/// A classic WiSARD model: per class, `num_filters` RAM nodes of 2^n bits.
#[derive(Clone, Debug)]
pub struct Wisard {
    pub inputs_per_filter: usize,
    pub num_classes: usize,
    pub total_input_bits: usize,
    pub input_order: Vec<u32>,
    /// rams[class][filter] — direct-mapped 2^n-bit table.
    pub rams: Vec<Vec<BitVec>>,
    pub encoder: ThermometerEncoder,
}

impl Wisard {
    pub fn num_filters(&self) -> usize {
        self.total_input_bits.div_ceil(self.inputs_per_filter)
    }

    pub fn new(rng: &mut Rng, encoder: ThermometerEncoder, inputs_per_filter: usize, num_classes: usize) -> Self {
        assert!(inputs_per_filter <= 28, "2^n RAM nodes get huge; use Bloom variants");
        let total_input_bits = encoder.encoded_bits();
        let cfg = SubmodelConfig {
            inputs_per_filter,
            entries_per_filter: 1 << inputs_per_filter,
            k_hashes: 1,
            num_classes,
            total_input_bits,
        };
        let input_order = crate::model::submodel::Submodel::make_input_order(rng, &cfg);
        let nf = total_input_bits.div_ceil(inputs_per_filter);
        let rams = (0..num_classes)
            .map(|_| (0..nf).map(|_| BitVec::zeros(1 << inputs_per_filter)).collect())
            .collect();
        Self { inputs_per_filter, num_classes, total_input_bits, input_order, rams, encoder }
    }

    fn keys(&self, encoded: &BitVec, keys: &mut Vec<u64>) {
        let n = self.inputs_per_filter;
        keys.clear();
        for f in 0..self.num_filters() {
            let mut key = 0u64;
            for i in 0..n {
                let src = self.input_order[f * n + i] as usize;
                key |= (encoded.get(src) as u64) << i;
            }
            keys.push(key);
        }
    }

    /// One-shot training: set the addressed bit in each RAM of the true
    /// class's discriminator.
    pub fn train_sample(&mut self, sample: &[f32], label: usize) {
        let encoded = self.encoder.encode(sample);
        let mut keys = Vec::new();
        self.keys(&encoded, &mut keys);
        for (f, &key) in keys.iter().enumerate() {
            self.rams[label][f].set(key as usize);
        }
    }

    pub fn train(&mut self, xs: &[f32], ys: &[u16], num_features: usize) {
        for (i, &y) in ys.iter().enumerate() {
            self.train_sample(&xs[i * num_features..(i + 1) * num_features], y as usize);
        }
    }

    pub fn predict(&self, sample: &[f32]) -> usize {
        let encoded = self.encoder.encode(sample);
        let mut keys = Vec::new();
        self.keys(&encoded, &mut keys);
        let resp: Vec<i32> = (0..self.num_classes)
            .map(|c| {
                keys.iter()
                    .enumerate()
                    .map(|(f, &key)| self.rams[c][f].get(key as usize) as i32)
                    .sum()
            })
            .collect();
        crate::util::argmax_tie_low(&resp)
    }

    pub fn evaluate(&self, xs: &[f32], ys: &[u16], num_features: usize) -> Confusion {
        let mut conf = Confusion::new(self.num_classes);
        for (i, &y) in ys.iter().enumerate() {
            let p = self.predict(&xs[i * num_features..(i + 1) * num_features]);
            conf.record(y as usize, p);
        }
        conf
    }

    /// Table storage in KiB: classes × filters × 2^n bits.
    pub fn size_kib(&self) -> f64 {
        (self.num_classes * self.num_filters() * (1usize << self.inputs_per_filter)) as f64
            / 8.0
            / 1024.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::encoding::thermometer::ThermometerKind;

    fn encoder() -> ThermometerEncoder {
        let data: Vec<f32> = (0..600).map(|i| (i % 100) as f32).collect();
        ThermometerEncoder::fit(ThermometerKind::Linear, &data, 6, 4)
    }

    #[test]
    fn memorizes_training_samples() {
        let mut rng = Rng::new(1);
        let mut w = Wisard::new(&mut rng, encoder(), 6, 3);
        let samples: Vec<Vec<f32>> = vec![
            vec![5.0, 10.0, 90.0, 20.0, 30.0, 70.0],
            vec![90.0, 80.0, 10.0, 60.0, 5.0, 15.0],
            vec![50.0, 50.0, 50.0, 50.0, 50.0, 50.0],
        ];
        for (c, s) in samples.iter().enumerate() {
            w.train_sample(s, c);
        }
        for (c, s) in samples.iter().enumerate() {
            assert_eq!(w.predict(s), c, "exact training sample must be recalled");
        }
    }

    #[test]
    fn size_formula() {
        let mut rng = Rng::new(2);
        let w = Wisard::new(&mut rng, encoder(), 6, 3);
        // 24 encoded bits / 6 = 4 filters; 3 * 4 * 64 bits = 768 bits
        assert_eq!(w.num_filters(), 4);
        assert!((w.size_kib() - 768.0 / 8192.0).abs() < 1e-12);
    }

    #[test]
    fn saturation_hurts_discrimination() {
        // With no bleaching, training *everything* into one class makes that
        // class win everywhere — the saturation failure ULEEN fixes.
        let mut rng = Rng::new(3);
        let mut w = Wisard::new(&mut rng, encoder(), 6, 2);
        let mut r = Rng::new(4);
        for _ in 0..500 {
            let s: Vec<f32> = (0..6).map(|_| (r.below(100)) as f32).collect();
            w.train_sample(&s, 0);
        }
        // class 1 sees only one pattern
        w.train_sample(&[1.0, 2.0, 3.0, 4.0, 5.0, 6.0], 1);
        let mut wins0 = 0;
        for _ in 0..100 {
            let s: Vec<f32> = (0..6).map(|_| (r.below(100)) as f32).collect();
            if w.predict(&s) == 0 {
                wins0 += 1;
            }
        }
        assert!(wins0 > 90, "saturated class should dominate, won {wins0}");
    }
}
