//! Runtime-dispatched SIMD kernels for the bit-sliced tile engine
//! (§Perf v6 — the software analogue of ULEEN's always-resident FPGA
//! datapath, chasing the paper's 14.3M inf/s).
//!
//! [`FlatModel::responses_tile_slices`](crate::model::flat::FlatModel::responses_tile_slices)
//! delegates its three hot phases here, one call per submodel per tile:
//!
//! 1. **CSR hash-slice XOR accumulation** — for every set slice word,
//!    XOR it into the `out_bits` hash bit-planes its H3 parameters
//!    select. Vector form: broadcast the slice word, test 4 (AVX2) / 2
//!    (NEON) parameter bits at once and XOR under the resulting lane
//!    masks. The CSR is AoS-interleaved (stride `k + 1`: filter index
//!    followed by its `k` params), so each scatter entry is one
//!    contiguous read run; with prefetch enabled the records just past
//!    the current span are requested ahead of the stream.
//! 2. **Per-filter index reassembly** — rebuild each sample's table
//!    index from the hash bit-planes. Vector form: 8 (AVX2) / 4 (NEON)
//!    samples per op, one shift-and-OR per plane, then a gathered
//!    (AVX2 `vpgatherdd`, u32 planes only) or staged-scalar class-mask
//!    load. Staged probes prefetch the mask line a few samples ahead;
//!    the scalar tier pipelines whole filter/hash pairs one step ahead
//!    through a second index buffer.
//! 3. **Class-mask fold + response scatter** — unpack the folded mask's
//!    class bits into the response rows, 8 (AVX2) / 4 (NEON) classes
//!    per op.
//!
//! The kernels are generic over the class-mask element width
//! ([`MaskWord`]: `u8`/`u16`/`u32`, chosen per model by [`MaskWidth`]).
//! Folding stays in `u32` scratch — narrow masks zero-extend, so a
//! width never changes a response bit, only the bytes the probe phase
//! touches.
//!
//! Offline constraint: `core::arch` intrinsics only, no external
//! crates. AVX-512 is deliberately not a tier — its intrinsics are not
//! stable on this crate's MSRV (1.73).
//!
//! **Dispatch is resolved ONCE, at `FlatModel` compile time** — never
//! per call — via [`KernelPath::resolve`]: the `ULEEN_KERNEL` env var
//! (`scalar` / `avx2` / `neon` / `auto`) wins when it names a path the
//! host supports, otherwise runtime feature detection picks AVX2 on
//! capable x86-64, NEON on aarch64 (baseline there), scalar everywhere
//! else. The scalar path IS the pre-SIMD code, moved here verbatim, and
//! every vector path is held bit-exact against it by unit tests below
//! plus the cross-engine conformance proptests.
//!
//! Alignment: the kernels demand nothing beyond `Vec`'s natural
//! alignment — every vector access is an explicitly unaligned
//! load/store (`loadu`/`storeu`, `vld1q`/`vst1q`), so scratch buffers
//! need no over-alignment and resizes can never introduce UB. (The
//! model tables themselves live in `FlatModel`'s 64-byte-aligned arena,
//! but the kernels only require natural element alignment of them.)

/// Which instruction set the compiled tile kernel runs on. Carried by
/// every `FlatModel` (chosen at compile time, see
/// [`KernelPath::resolve`]) and surfaced through engine labels,
/// `/metrics` (`kernel_path`) and bench JSON so a silently-degraded
/// dispatch is visible.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KernelPath {
    /// Portable reference path — always available, on every arch.
    Scalar,
    /// 256-bit AVX2 path (`x86_64`, runtime-detected).
    Avx2,
    /// 128-bit NEON path (`aarch64`, where NEON is ABI-baseline).
    Neon,
}

impl KernelPath {
    /// Env var that forces a dispatch tier: `scalar`, `avx2`, `neon`,
    /// or `auto` (= detect). A value the host cannot run falls back to
    /// detection — forcing can downgrade but never fault.
    pub const ENV: &'static str = "ULEEN_KERNEL";

    /// Stable lowercase name, used in labels / metrics / bench JSON.
    pub fn label(self) -> &'static str {
        match self {
            Self::Scalar => "scalar",
            Self::Avx2 => "avx2",
            Self::Neon => "neon",
        }
    }

    /// Parse a `ULEEN_KERNEL` value. `auto` and unknown strings map to
    /// `None` (= use detection).
    pub fn parse(s: &str) -> Option<Self> {
        match s.trim().to_ascii_lowercase().as_str() {
            "scalar" => Some(Self::Scalar),
            "avx2" => Some(Self::Avx2),
            "neon" => Some(Self::Neon),
            _ => None,
        }
    }

    /// Can the host actually execute this path?
    pub fn is_supported(self) -> bool {
        match self {
            Self::Scalar => true,
            Self::Neon => cfg!(target_arch = "aarch64"),
            Self::Avx2 => {
                #[cfg(target_arch = "x86_64")]
                let ok = std::arch::is_x86_feature_detected!("avx2");
                #[cfg(not(target_arch = "x86_64"))]
                let ok = false;
                ok
            }
        }
    }

    /// This path if the host supports it, else the scalar fallback.
    /// The only constructor-facing sanitizer: a `FlatModel` never
    /// carries a path its host cannot run.
    pub fn or_scalar(self) -> Self {
        if self.is_supported() {
            self
        } else {
            Self::Scalar
        }
    }

    /// Runtime feature detection: AVX2 on capable x86-64, NEON on
    /// aarch64, scalar everywhere else.
    pub fn detect() -> Self {
        if cfg!(target_arch = "aarch64") {
            return Self::Neon;
        }
        #[cfg(target_arch = "x86_64")]
        {
            if std::arch::is_x86_feature_detected!("avx2") {
                return Self::Avx2;
            }
        }
        Self::Scalar
    }

    /// The dispatch decision `FlatModel::compile` bakes in: an env
    /// override that names a supported path wins, otherwise
    /// [`KernelPath::detect`].
    pub fn resolve() -> Self {
        match std::env::var(Self::ENV) {
            Ok(v) => match Self::parse(&v) {
                Some(p) if p.is_supported() => p,
                _ => Self::detect(),
            },
            Err(_) => Self::detect(),
        }
    }

    /// Every path the host can run (scalar always included) — the
    /// conformance tests' iteration set.
    pub fn all_supported() -> Vec<Self> {
        [Self::Scalar, Self::Avx2, Self::Neon]
            .into_iter()
            .filter(|p| p.is_supported())
            .collect()
    }
}

/// Element width of the compiled class-mask planes — one bit per class,
/// so a model's class count picks the narrowest word that holds it
/// (≤ 8 classes → `u8`, ≤ 16 → `u16`, else `u32`). Chosen once at
/// `FlatModel` compile time (see [`MaskWidth::resolve`]), carried by the
/// model, and surfaced through `model_bytes` accounting and bench JSON.
/// Narrower planes cut the random-access bytes the probe phase touches
/// 2–4× without changing a single response bit (masks zero-extend into
/// the `u32` fold scratch).
///
/// `Ord` follows capacity: `U8 < U16 < U32`, so clamping a forced width
/// up to what a class count requires is `max`.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum MaskWidth {
    /// 1-byte planes — up to 8 classes.
    U8,
    /// 2-byte planes — up to 16 classes (MNIST's 10 lands here).
    U16,
    /// 4-byte planes — up to 32 classes, the flat engine's capacity.
    U32,
}

impl MaskWidth {
    /// Env var that forces a plane width: `8`/`u8`, `16`/`u16`,
    /// `32`/`u32`, or `auto` (= narrowest that holds the class count).
    /// A forced width too narrow for the model is widened, never
    /// honored unsoundly — forcing can waste bytes but not break
    /// capacity. Mirrors [`KernelPath::ENV`].
    pub const ENV: &'static str = "ULEEN_MASK_WIDTH";

    /// Stable lowercase name, used in accounting / bench JSON.
    pub fn label(self) -> &'static str {
        match self {
            Self::U8 => "u8",
            Self::U16 => "u16",
            Self::U32 => "u32",
        }
    }

    /// Plane element size in bytes.
    pub fn bytes(self) -> usize {
        match self {
            Self::U8 => 1,
            Self::U16 => 2,
            Self::U32 => 4,
        }
    }

    /// Classes one plane element can hold (one bit per class).
    pub fn bits(self) -> usize {
        self.bytes() * 8
    }

    /// All widths, narrowest first — the conformance tests' iteration
    /// set (skip those too narrow for the model under test).
    pub fn all() -> [Self; 3] {
        [Self::U8, Self::U16, Self::U32]
    }

    /// Parse a `ULEEN_MASK_WIDTH` value. `auto` and unknown strings map
    /// to `None` (= derive from the class count).
    pub fn parse(s: &str) -> Option<Self> {
        match s.trim().to_ascii_lowercase().as_str() {
            "8" | "u8" => Some(Self::U8),
            "16" | "u16" => Some(Self::U16),
            "32" | "u32" => Some(Self::U32),
            _ => None,
        }
    }

    /// The narrowest width whose element holds `classes` bits. Callers
    /// have already rejected `classes > 32` (the flat engine's
    /// capacity check).
    pub fn required_for(classes: usize) -> Self {
        if classes <= 8 {
            Self::U8
        } else if classes <= 16 {
            Self::U16
        } else {
            Self::U32
        }
    }

    /// This width, widened if it cannot hold `classes` — the
    /// constructor-facing sanitizer (the width analogue of
    /// [`KernelPath::or_scalar`]): a `FlatModel` never carries planes
    /// narrower than its class count.
    pub fn widen_to_hold(self, classes: usize) -> Self {
        self.max(Self::required_for(classes))
    }

    /// The width decision `FlatModel::compile` bakes in: an env
    /// override (widened to what `classes` requires) wins, otherwise
    /// the narrowest sufficient width.
    pub fn resolve(classes: usize) -> Self {
        match std::env::var(Self::ENV) {
            Ok(v) => match Self::parse(&v) {
                Some(w) => w.widen_to_hold(classes),
                None => Self::required_for(classes),
            },
            Err(_) => Self::required_for(classes),
        }
    }
}

/// A class-mask plane element — the type-level side of [`MaskWidth`].
/// Kernels fold masks in `u32` scratch regardless of storage width;
/// `to_u32` zero-extends on load, `from_u32` truncates on compile-time
/// store (sound: compilation only ever sets bits `< num_classes ≤`
/// the chosen width).
pub(crate) trait MaskWord: Copy + Send + Sync + 'static {
    /// The [`MaskWidth`] this element implements.
    const WIDTH: MaskWidth;
    fn to_u32(self) -> u32;
    fn from_u32(v: u32) -> Self;
}

impl MaskWord for u8 {
    const WIDTH: MaskWidth = MaskWidth::U8;
    #[inline(always)]
    fn to_u32(self) -> u32 {
        self as u32
    }
    #[inline(always)]
    fn from_u32(v: u32) -> Self {
        v as u8
    }
}

impl MaskWord for u16 {
    const WIDTH: MaskWidth = MaskWidth::U16;
    #[inline(always)]
    fn to_u32(self) -> u32 {
        self as u32
    }
    #[inline(always)]
    fn from_u32(v: u32) -> Self {
        v as u16
    }
}

impl MaskWord for u32 {
    const WIDTH: MaskWidth = MaskWidth::U32;
    #[inline(always)]
    fn to_u32(self) -> u32 {
        self
    }
    #[inline(always)]
    fn from_u32(v: u32) -> Self {
        v
    }
}

/// Best-effort software prefetch of the cache line holding `*p` into
/// L1 for reading. Same hand-declared-intrinsics discipline as the
/// kernels: `_mm_prefetch` on x86-64 (SSE is ABI-baseline there),
/// `prfm pldl1keep` via inline asm on aarch64, a no-op elsewhere.
/// Prefetch never faults architecturally; callers still keep `p`
/// inside (or one past) its allocation so constructing it is sound.
#[inline(always)]
pub(crate) fn prefetch_read<T>(p: *const T) {
    #[cfg(target_arch = "x86_64")]
    // SAFETY: prefetch is a hint — it cannot fault and touches no
    // Rust-visible state; SSE is baseline on x86_64.
    unsafe {
        core::arch::x86_64::_mm_prefetch::<{ core::arch::x86_64::_MM_HINT_T0 }>(p as *const i8);
    }
    #[cfg(target_arch = "aarch64")]
    // SAFETY: PRFM is a hint — it cannot fault and touches no
    // Rust-visible state.
    unsafe {
        core::arch::asm!(
            "prfm pldl1keep, [{ptr}]",
            ptr = in(reg) p,
            options(nostack, preserves_flags, readonly)
        );
    }
    #[cfg(not(any(target_arch = "x86_64", target_arch = "aarch64")))]
    let _ = p;
}

/// Borrowed view of everything one submodel's tile pass needs — the
/// kernel ABI shared by all dispatch tiers, generic over the class-mask
/// plane width `W`. `hash_slices` must arrive zeroed (length
/// `nf * k * ob`); `idx`/`idx2`/`masks` are uninitialized sample-width
/// scratch (length `nt`); `out` is the `nt × m` response plane the
/// kernel ACCUMULATES into (bias is added by the caller — it is
/// path-independent).
pub(crate) struct SubmodelTileArgs<'a, W: MaskWord> {
    /// one word per encoded input bit; bit `s` = that bit of sample `s`
    pub slices: &'a [u64],
    /// samples in the tile (1..=64)
    pub nt: usize,
    /// classes
    pub m: usize,
    /// table entries per filter (= `1 << ob`)
    pub e: usize,
    /// filters
    pub nf: usize,
    /// hash functions per filter
    pub k: usize,
    /// bits per table index (≤ 32)
    pub ob: usize,
    /// CSR span offsets: entries for source bit `src` live at record
    /// indices `csr_off[src]..csr_off[src + 1]`
    pub csr_off: &'a [u32],
    /// AoS-interleaved CSR records, stride `k + 1` u64 words per entry:
    /// `[filter, p_0, .., p_{k-1}]`, params masked to `ob` bits
    pub csr: &'a [u64],
    /// class-mask planes, layout `[filter][entry]`, element width `W`
    pub class_masks: &'a [W],
    /// software-prefetch upcoming CSR spans / class-mask lines
    /// (resolved once at model compile; `ULEEN_NO_PREFETCH` opt-out)
    pub prefetch: bool,
    /// bit-sliced H3 accumulators `[(f*k + j) * ob + b]`, pre-zeroed
    pub hash_slices: &'a mut [u64],
    /// per-sample table-index scratch (staging + pipeline "current")
    pub idx: &'a mut [u32],
    /// second index buffer — the scalar tier's one-pair-ahead pipeline
    pub idx2: &'a mut [u32],
    /// per-sample folded class mask for one filter (always u32 — narrow
    /// plane words zero-extend into it)
    pub masks: &'a mut [u32],
    /// `nt × m` row-major response accumulation plane
    pub out: &'a mut [i32],
}

/// Run one submodel's tile pass on the given dispatch tier. `path`
/// must be host-supported (guaranteed by [`KernelPath::or_scalar`] at
/// `FlatModel` construction); a non-compiled variant (e.g. `Neon` on
/// x86) falls through to scalar rather than faulting.
pub(crate) fn submodel_tile_kernel<W: MaskWord>(path: KernelPath, args: SubmodelTileArgs<'_, W>) {
    debug_assert_eq!(args.hash_slices.len(), args.nf * args.k * args.ob);
    debug_assert!(
        args.idx.len() >= args.nt && args.idx2.len() >= args.nt && args.masks.len() >= args.nt
    );
    debug_assert_eq!(args.out.len(), args.nt * args.m);
    debug_assert_eq!(args.class_masks.len(), args.nf * args.e);
    debug_assert_eq!(
        args.csr.len(),
        args.csr_off.last().map_or(0, |&t| t as usize) * (args.k + 1)
    );
    match path {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: `path == Avx2` only ever reaches a FlatModel via
        // `or_scalar`, which checked `is_x86_feature_detected!("avx2")`.
        KernelPath::Avx2 => unsafe { avx2::run(args) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: NEON is baseline on aarch64.
        KernelPath::Neon => unsafe { neon::run(args) },
        _ => scalar::run(args),
    }
}

/// The portable reference kernel — the pre-SIMD
/// `responses_tile_slices` inner loop with the phase-2 index rebuild
/// software-pipelined one filter/hash pair ahead (so the next pair's
/// class-mask lines can be prefetched while the current pair probes).
/// Every vector tier is asserted bit-exact against this.
mod scalar {
    use super::{prefetch_read, MaskWord, SubmodelTileArgs};

    /// Rebuild each sample's table index from one pair's `ob` hash
    /// bit-planes into `idx[..nt]`.
    #[inline]
    fn rebuild_indices(planes: &[u64], nt: usize, idx: &mut [u32]) {
        let idx = &mut idx[..nt];
        idx.fill(0);
        for (b, &w) in planes.iter().enumerate() {
            let mut w = w;
            while w != 0 {
                let s = w.trailing_zeros() as usize;
                w &= w - 1;
                debug_assert!(s < nt);
                idx[s] |= 1 << b;
            }
        }
    }

    pub(super) fn run<W: MaskWord>(a: SubmodelTileArgs<'_, W>) {
        let SubmodelTileArgs {
            slices,
            nt,
            m,
            e,
            nf,
            k,
            ob,
            csr_off,
            csr,
            class_masks,
            prefetch,
            hash_slices,
            idx,
            idx2,
            masks,
            out,
        } = a;
        let stride = k + 1;
        // Phase 1 — bit-sliced hashing: hash_slices[(f*k + j)*ob + b]
        // bit s = bit b of sample s's j-th hash for filter f. Records
        // are interleaved, so one CSR entry is one contiguous read run.
        for (src, &w) in slices.iter().enumerate() {
            if w == 0 {
                continue;
            }
            let lo = csr_off[src] as usize;
            let hi = csr_off[src + 1] as usize;
            if prefetch {
                // The records just past this span head the next span a
                // later set bit will stream — spans are adjacent in the
                // arena, so this warms the stream's continuation.
                // SAFETY: hi ≤ total entries, so hi*stride ≤ csr.len()
                // (at most one past the end, which `add` permits).
                prefetch_read(unsafe { csr.as_ptr().add(hi * stride) });
            }
            for t in lo..hi {
                let rb = t * stride;
                let f = unsafe { *csr.get_unchecked(rb) } as usize;
                let base = f * k * ob;
                for j in 0..k {
                    let mut p = unsafe { *csr.get_unchecked(rb + 1 + j) };
                    let hb = base + j * ob;
                    while p != 0 {
                        let b = p.trailing_zeros() as usize;
                        p &= p - 1;
                        unsafe {
                            *hash_slices.get_unchecked_mut(hb + b) ^= w;
                        }
                    }
                }
            }
        }
        // Phases 2+3 — per filter: reassemble each sample's table index
        // from the hash bit-planes, fold the k class-mask loads, then
        // scatter the mask's class bits into the response rows. The
        // rebuild runs one (filter, hash) pair ahead through a second
        // buffer so the NEXT pair's mask lines prefetch while the
        // current pair probes — same probe order and arithmetic as the
        // unpipelined loop, bit-exact by construction.
        let pairs = nf * k;
        if pairs == 0 {
            return;
        }
        let (mut cur, mut nxt) = (idx, idx2);
        rebuild_indices(&hash_slices[..ob], nt, cur);
        for f in 0..nf {
            masks[..nt].fill(u32::MAX);
            for j in 0..k {
                let t = f * k + j;
                if t + 1 < pairs {
                    rebuild_indices(&hash_slices[(t + 1) * ob..(t + 2) * ob], nt, nxt);
                    if prefetch {
                        let fnext = (t + 1) / k;
                        let tbase = fnext * e;
                        for &i in &nxt[..nt] {
                            // SAFETY: indices are < e (params masked to
                            // ob bits), so tbase + i < nf * e.
                            prefetch_read(unsafe {
                                class_masks.as_ptr().add(tbase + i as usize)
                            });
                        }
                    }
                }
                for (s, mask) in masks[..nt].iter_mut().enumerate() {
                    *mask &= unsafe {
                        class_masks.get_unchecked(f * e + cur[s] as usize)
                    }
                    .to_u32();
                }
                std::mem::swap(&mut cur, &mut nxt);
            }
            for (s, &mask) in masks[..nt].iter().enumerate() {
                let row = &mut out[s * m..(s + 1) * m];
                for (c, o) in row.iter_mut().enumerate() {
                    *o += ((mask >> c) & 1) as i32;
                }
            }
        }
    }
}

/// 256-bit AVX2 tier. All loads/stores unaligned; on u32 planes the
/// class-mask probe uses `vpgatherdd` (in-bounds because every hash
/// param is masked to `ob` bits at both `.uln` load and H3
/// construction, so indices are `< e`); narrower planes stage the
/// vector-built indices through `idx` and probe scalar-wise with the
/// mask line prefetched a few samples ahead (a 1/2-byte gather would
/// read past the element — there is no sub-dword gather).
#[cfg(target_arch = "x86_64")]
mod avx2 {
    use super::{prefetch_read, MaskWidth, MaskWord, SubmodelTileArgs};
    use core::arch::x86_64::*;

    /// How many samples ahead the staged probe prefetches.
    const PROBE_AHEAD: usize = 8;

    /// # Safety
    /// Caller must have verified AVX2 support
    /// (`is_x86_feature_detected!("avx2")`).
    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn run<W: MaskWord>(a: SubmodelTileArgs<'_, W>) {
        let SubmodelTileArgs {
            slices,
            nt,
            m,
            e,
            nf,
            k,
            ob,
            csr_off,
            csr,
            class_masks,
            prefetch,
            hash_slices,
            idx,
            idx2: _,
            masks,
            out,
        } = a;
        // gather offsets are signed 32-bit; anything close to 2^31
        // entries per filter could never have been compiled anyway
        debug_assert!(e <= 1 << 30);
        let stride = k + 1;
        let ones64 = _mm256_set1_epi64x(1);
        let ones32 = _mm256_set1_epi32(1);
        let lane = _mm256_setr_epi32(0, 1, 2, 3, 4, 5, 6, 7);
        // Phase 1 — broadcast the slice word, test 4 param bits per op
        // and XOR under the compare masks; scalar tail for ob % 4.
        for (src, &w) in slices.iter().enumerate() {
            if w == 0 {
                continue;
            }
            let wv = _mm256_set1_epi64x(w as i64);
            let lo = *csr_off.get_unchecked(src) as usize;
            let hi = *csr_off.get_unchecked(src + 1) as usize;
            if prefetch {
                // warm the next span's records (hi*stride ≤ csr.len())
                prefetch_read(csr.as_ptr().add(hi * stride));
            }
            for t in lo..hi {
                let rb = t * stride;
                let f = *csr.get_unchecked(rb) as usize;
                let base = f * k * ob;
                for j in 0..k {
                    let p = *csr.get_unchecked(rb + 1 + j);
                    if p == 0 {
                        continue;
                    }
                    let pv = _mm256_set1_epi64x(p as i64);
                    let hb = base + j * ob;
                    let mut b = 0usize;
                    while b + 4 <= ob {
                        let sh = _mm256_setr_epi64x(
                            b as i64,
                            b as i64 + 1,
                            b as i64 + 2,
                            b as i64 + 3,
                        );
                        let bits = _mm256_and_si256(_mm256_srlv_epi64(pv, sh), ones64);
                        let sel = _mm256_cmpeq_epi64(bits, ones64);
                        let ptr = hash_slices.as_mut_ptr().add(hb + b) as *mut __m256i;
                        let cur = _mm256_loadu_si256(ptr);
                        _mm256_storeu_si256(
                            ptr,
                            _mm256_xor_si256(cur, _mm256_and_si256(wv, sel)),
                        );
                        b += 4;
                    }
                    let mut pt = p >> b;
                    while pt != 0 {
                        let bb = pt.trailing_zeros() as usize;
                        pt &= pt - 1;
                        *hash_slices.get_unchecked_mut(hb + b + bb) ^= w;
                    }
                }
            }
        }
        // Phases 2+3 — 8 samples per op: rebuild indices plane-by-plane
        // (broadcast the plane's relevant byte window, per-lane shift,
        // mask, OR into position), then either gather the class masks
        // (u32 planes) or stage the indices and probe with prefetch
        // ahead (narrow planes); finally scatter each sample's mask 8
        // classes per op.
        for f in 0..nf {
            masks[..nt].fill(u32::MAX);
            let tbase = class_masks.as_ptr().add(f * e);
            if prefetch && W::WIDTH == MaskWidth::U32 && f + 1 < nf {
                // gather gives no per-index hook, so at least warm the
                // next filter's table head while this one folds
                prefetch_read(class_masks.as_ptr().add((f + 1) * e));
            }
            for j in 0..k {
                let hb = (f * k + j) * ob;
                if W::WIDTH == MaskWidth::U32 {
                    let table = tbase as *const i32;
                    let mut s0 = 0usize;
                    while s0 + 8 <= nt {
                        let mut iv = _mm256_setzero_si256();
                        for b in 0..ob {
                            let pw = *hash_slices.get_unchecked(hb + b);
                            // lanes 0..7 ← bits s0..s0+7 of the plane word
                            let lo32 = _mm256_set1_epi32((pw >> s0) as u32 as i32);
                            let bits = _mm256_and_si256(_mm256_srlv_epi32(lo32, lane), ones32);
                            iv = _mm256_or_si256(
                                iv,
                                _mm256_sll_epi32(bits, _mm_cvtsi32_si128(b as i32)),
                            );
                        }
                        let gathered = _mm256_i32gather_epi32::<4>(table, iv);
                        let mptr = masks.as_mut_ptr().add(s0) as *mut __m256i;
                        _mm256_storeu_si256(
                            mptr,
                            _mm256_and_si256(_mm256_loadu_si256(mptr), gathered),
                        );
                        s0 += 8;
                    }
                    for s in s0..nt {
                        let mut iw = 0usize;
                        for b in 0..ob {
                            iw |=
                                (((*hash_slices.get_unchecked(hb + b) >> s) & 1) as usize) << b;
                        }
                        *masks.get_unchecked_mut(s) &=
                            (*class_masks.get_unchecked(f * e + iw)).to_u32();
                    }
                } else {
                    // narrow planes: same vector index build, staged
                    // through `idx`, then a prefetch-ahead scalar probe
                    let mut s0 = 0usize;
                    while s0 + 8 <= nt {
                        let mut iv = _mm256_setzero_si256();
                        for b in 0..ob {
                            let pw = *hash_slices.get_unchecked(hb + b);
                            let lo32 = _mm256_set1_epi32((pw >> s0) as u32 as i32);
                            let bits = _mm256_and_si256(_mm256_srlv_epi32(lo32, lane), ones32);
                            iv = _mm256_or_si256(
                                iv,
                                _mm256_sll_epi32(bits, _mm_cvtsi32_si128(b as i32)),
                            );
                        }
                        _mm256_storeu_si256(idx.as_mut_ptr().add(s0) as *mut __m256i, iv);
                        s0 += 8;
                    }
                    for s in s0..nt {
                        let mut iw = 0u32;
                        for b in 0..ob {
                            iw |= (((*hash_slices.get_unchecked(hb + b) >> s) & 1) as u32) << b;
                        }
                        *idx.get_unchecked_mut(s) = iw;
                    }
                    for s in 0..nt {
                        if prefetch && s + PROBE_AHEAD < nt {
                            prefetch_read(
                                tbase.add(*idx.get_unchecked(s + PROBE_AHEAD) as usize),
                            );
                        }
                        *masks.get_unchecked_mut(s) &=
                            (*tbase.add(*idx.get_unchecked(s) as usize)).to_u32();
                    }
                }
            }
            for s in 0..nt {
                let mask = *masks.get_unchecked(s);
                let mv = _mm256_set1_epi32(mask as i32);
                let row = out.as_mut_ptr().add(s * m);
                let mut c = 0usize;
                while c + 8 <= m {
                    let sh = _mm256_add_epi32(lane, _mm256_set1_epi32(c as i32));
                    let bits = _mm256_and_si256(_mm256_srlv_epi32(mv, sh), ones32);
                    let ptr = row.add(c) as *mut __m256i;
                    _mm256_storeu_si256(
                        ptr,
                        _mm256_add_epi32(_mm256_loadu_si256(ptr), bits),
                    );
                    c += 8;
                }
                while c < m {
                    *row.add(c) += ((mask >> c) & 1) as i32;
                    c += 1;
                }
            }
        }
    }
}

/// 128-bit NEON tier (aarch64). No vector gather exists, so phase 2
/// stages reassembled indices through the `idx` scratch and probes the
/// class masks scalar-wise (with the mask line prefetched a few samples
/// ahead); phases 1 and 3 are fully vectorized.
#[cfg(target_arch = "aarch64")]
mod neon {
    use super::{prefetch_read, MaskWord, SubmodelTileArgs};
    use core::arch::aarch64::*;

    /// How many samples ahead the staged probe prefetches.
    const PROBE_AHEAD: usize = 8;

    /// # Safety
    /// NEON must be available (it is ABI-baseline on aarch64).
    #[target_feature(enable = "neon")]
    pub(super) unsafe fn run<W: MaskWord>(a: SubmodelTileArgs<'_, W>) {
        let SubmodelTileArgs {
            slices,
            nt,
            m,
            e,
            nf,
            k,
            ob,
            csr_off,
            csr,
            class_masks,
            prefetch,
            hash_slices,
            idx,
            idx2: _,
            masks,
            out,
        } = a;
        let stride = k + 1;
        let one32 = vdupq_n_u32(1);
        // negative vector shifts = right shifts for vshlq
        let rsh = vld1q_s32([0i32, -1, -2, -3].as_ptr());
        // Phase 1 — 2 bit-planes per op under all-ones/all-zeros lane
        // masks built from the param bits.
        for (src, &w) in slices.iter().enumerate() {
            if w == 0 {
                continue;
            }
            let wv = vdupq_n_u64(w);
            let lo = *csr_off.get_unchecked(src) as usize;
            let hi = *csr_off.get_unchecked(src + 1) as usize;
            if prefetch {
                // warm the next span's records (hi*stride ≤ csr.len())
                prefetch_read(csr.as_ptr().add(hi * stride));
            }
            for t in lo..hi {
                let rb = t * stride;
                let f = *csr.get_unchecked(rb) as usize;
                let base = f * k * ob;
                for j in 0..k {
                    let p = *csr.get_unchecked(rb + 1 + j);
                    if p == 0 {
                        continue;
                    }
                    let hb = base + j * ob;
                    let mut b = 0usize;
                    while b + 2 <= ob {
                        let sel = vcombine_u64(
                            vcreate_u64(0u64.wrapping_sub((p >> b) & 1)),
                            vcreate_u64(0u64.wrapping_sub((p >> (b + 1)) & 1)),
                        );
                        let ptr = hash_slices.as_mut_ptr().add(hb + b);
                        let cur = vld1q_u64(ptr);
                        vst1q_u64(ptr, veorq_u64(cur, vandq_u64(wv, sel)));
                        b += 2;
                    }
                    if b < ob && (p >> b) & 1 == 1 {
                        *hash_slices.get_unchecked_mut(hb + b) ^= w;
                    }
                }
            }
        }
        // Phases 2+3 — 4 samples per op into the `idx` staging buffer,
        // prefetch-ahead scalar class-mask probe, then a
        // 4-classes-per-op scatter.
        for f in 0..nf {
            masks[..nt].fill(u32::MAX);
            let tbase = class_masks.as_ptr().add(f * e);
            for j in 0..k {
                let hb = (f * k + j) * ob;
                let mut s0 = 0usize;
                while s0 + 4 <= nt {
                    let mut iv = vdupq_n_u32(0);
                    for b in 0..ob {
                        let pw = *hash_slices.get_unchecked(hb + b);
                        let lo32 = vdupq_n_u32((pw >> s0) as u32);
                        let bits = vandq_u32(vshlq_u32(lo32, rsh), one32);
                        iv = vorrq_u32(iv, vshlq_u32(bits, vdupq_n_s32(b as i32)));
                    }
                    vst1q_u32(idx.as_mut_ptr().add(s0), iv);
                    s0 += 4;
                }
                for s in s0..nt {
                    let mut iw = 0u32;
                    for b in 0..ob {
                        iw |= (((*hash_slices.get_unchecked(hb + b) >> s) & 1) as u32) << b;
                    }
                    *idx.get_unchecked_mut(s) = iw;
                }
                for s in 0..nt {
                    if prefetch && s + PROBE_AHEAD < nt {
                        prefetch_read(tbase.add(*idx.get_unchecked(s + PROBE_AHEAD) as usize));
                    }
                    *masks.get_unchecked_mut(s) &=
                        (*tbase.add(*idx.get_unchecked(s) as usize)).to_u32();
                }
            }
            for s in 0..nt {
                let mask = *masks.get_unchecked(s);
                let mv = vdupq_n_u32(mask);
                let row = out.as_mut_ptr().add(s * m);
                let mut c = 0usize;
                while c + 4 <= m {
                    let sh = vld1q_s32(
                        [-(c as i32), -(c as i32 + 1), -(c as i32 + 2), -(c as i32 + 3)]
                            .as_ptr(),
                    );
                    let bits = vandq_u32(vshlq_u32(mv, sh), one32);
                    let cur = vld1q_s32(row.add(c));
                    vst1q_s32(row.add(c), vaddq_s32(cur, vreinterpretq_s32_u32(bits)));
                    c += 4;
                }
                while c < m {
                    *row.add(c) += ((mask >> c) & 1) as i32;
                    c += 1;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_and_label_roundtrip() {
        for p in [KernelPath::Scalar, KernelPath::Avx2, KernelPath::Neon] {
            assert_eq!(KernelPath::parse(p.label()), Some(p));
        }
        assert_eq!(KernelPath::parse(" AVX2 "), Some(KernelPath::Avx2));
        assert_eq!(KernelPath::parse("auto"), None);
        assert_eq!(KernelPath::parse("sse9"), None);
    }

    #[test]
    fn detection_yields_a_supported_path_and_or_scalar_never_lies() {
        assert!(KernelPath::detect().is_supported());
        assert!(KernelPath::resolve().is_supported());
        for p in [KernelPath::Scalar, KernelPath::Avx2, KernelPath::Neon] {
            assert!(p.or_scalar().is_supported());
            if p.is_supported() {
                assert_eq!(p.or_scalar(), p);
            } else {
                assert_eq!(p.or_scalar(), KernelPath::Scalar);
            }
        }
        let all = KernelPath::all_supported();
        assert!(all.contains(&KernelPath::Scalar));
        assert!(all.contains(&KernelPath::detect()));
    }

    #[test]
    fn mask_width_parse_label_selection_and_clamp() {
        for w in MaskWidth::all() {
            assert_eq!(MaskWidth::parse(w.label()), Some(w));
            assert_eq!(w.bits(), w.bytes() * 8);
        }
        assert_eq!(MaskWidth::parse(" U16 "), Some(MaskWidth::U16));
        assert_eq!(MaskWidth::parse("8"), Some(MaskWidth::U8));
        assert_eq!(MaskWidth::parse("32"), Some(MaskWidth::U32));
        assert_eq!(MaskWidth::parse("auto"), None);
        assert_eq!(MaskWidth::parse("64"), None);

        assert_eq!(MaskWidth::required_for(1), MaskWidth::U8);
        assert_eq!(MaskWidth::required_for(8), MaskWidth::U8);
        assert_eq!(MaskWidth::required_for(9), MaskWidth::U16);
        assert_eq!(MaskWidth::required_for(10), MaskWidth::U16);
        assert_eq!(MaskWidth::required_for(16), MaskWidth::U16);
        assert_eq!(MaskWidth::required_for(17), MaskWidth::U32);
        assert_eq!(MaskWidth::required_for(32), MaskWidth::U32);

        // forcing can widen but never drop below what the class count
        // needs — the width analogue of or_scalar's "never fault"
        assert_eq!(MaskWidth::U8.widen_to_hold(12), MaskWidth::U16);
        assert_eq!(MaskWidth::U8.widen_to_hold(20), MaskWidth::U32);
        assert_eq!(MaskWidth::U32.widen_to_hold(3), MaskWidth::U32);
        assert_eq!(MaskWidth::U16.widen_to_hold(10), MaskWidth::U16);
    }

    /// Tiny deterministic LCG so the synthetic-kernel conformance cases
    /// below don't depend on any dataset or trainer.
    struct Lcg(u64);
    impl Lcg {
        fn next(&mut self) -> u64 {
            self.0 = self.0.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            self.0
        }
    }

    /// Drive one synthetic shape through the kernel ABI on one
    /// (path, width, prefetch) combination. The u32-valued masks are
    /// truncated into `W` storage exactly like compilation does — the
    /// caller guarantees `m ≤ W::WIDTH.bits()` so no set bit is lost.
    #[allow(clippy::too_many_arguments)]
    fn run_case<W: MaskWord>(
        path: KernelPath,
        prefetch: bool,
        (nf, ob, k, nt, m): (usize, usize, usize, usize, usize),
        csr_off: &[u32],
        csr: &[u64],
        masks_u32: &[u32],
        slices: &[u64],
    ) -> Vec<i32> {
        let e = 1usize << ob;
        let class_masks: Vec<W> = masks_u32.iter().map(|&v| W::from_u32(v)).collect();
        let mut hash_slices = vec![0u64; nf * k * ob];
        let mut idx = vec![0u32; nt];
        let mut idx2 = vec![0u32; nt];
        let mut masks = vec![0u32; nt];
        let mut out = vec![0i32; nt * m];
        submodel_tile_kernel(
            path,
            SubmodelTileArgs {
                slices,
                nt,
                m,
                e,
                nf,
                k,
                ob,
                csr_off,
                csr,
                class_masks: &class_masks,
                prefetch,
                hash_slices: &mut hash_slices,
                idx: &mut idx,
                idx2: &mut idx2,
                masks: &mut masks,
                out: &mut out,
            },
        );
        out
    }

    /// Build a random-but-valid synthetic submodel shape and assert
    /// every host-supported path × plane width × prefetch setting
    /// produces responses bit-identical to the u32 scalar reference —
    /// directly at the kernel ABI, no model required. Shapes chosen to
    /// hit every vector width's main loop AND its tail (ob % 4, nt % 8,
    /// m % 8 all nonzero in at least one case), and class counts that
    /// exercise every MaskWidth (m = 3 → all three, m = 32 → u32 only).
    #[test]
    fn every_supported_path_and_width_matches_scalar_on_synthetic_kernels() {
        for (seed, nf, ob, k, nt, m, total_bits) in [
            (1u64, 3usize, 4usize, 2usize, 64usize, 8usize, 24usize),
            (2, 2, 5, 3, 64, 10, 16),
            (3, 4, 7, 1, 37, 32, 40),
            (4, 1, 3, 2, 5, 3, 8),
            (5, 5, 6, 2, 63, 11, 33),
        ] {
            let e = 1usize << ob;
            let mut rng = Lcg(seed);
            // CSR: every (filter, slot) pair reads a rotating source bit
            let slots_per_filter = 3usize;
            let mut per_src: Vec<Vec<usize>> = vec![Vec::new(); total_bits];
            for f in 0..nf {
                for i in 0..slots_per_filter {
                    per_src[(f * slots_per_filter + i * 7) % total_bits].push(f);
                }
            }
            // interleaved records: [filter, p_0 .. p_{k-1}], stride k+1
            let mut csr_off = vec![0u32];
            let mut csr = Vec::new();
            let mut entries = 0u32;
            for fs in &per_src {
                for &f in fs {
                    csr.push(f as u64);
                    for _ in 0..k {
                        // params masked to ob bits, like real H3 params
                        csr.push(rng.next() & ((1u64 << ob) - 1));
                    }
                    entries += 1;
                }
                csr_off.push(entries);
            }
            // mask values restricted to the m class bits compilation
            // would ever set, so every sufficient width stores them
            // exactly
            let mbits = if m == 32 { u32::MAX } else { (1u32 << m) - 1 };
            let class_masks: Vec<u32> =
                (0..nf * e).map(|_| rng.next() as u32 & mbits).collect();
            let slices: Vec<u64> = (0..total_bits)
                .map(|_| {
                    let w = rng.next();
                    if nt == 64 { w } else { w & ((1u64 << nt) - 1) }
                })
                .collect();

            let shape = (nf, ob, k, nt, m);
            let want = run_case::<u32>(
                KernelPath::Scalar,
                false,
                shape,
                &csr_off,
                &csr,
                &class_masks,
                &slices,
            );
            for width in MaskWidth::all() {
                if m > width.bits() {
                    continue;
                }
                for path in KernelPath::all_supported() {
                    for prefetch in [false, true] {
                        let got = match width {
                            MaskWidth::U8 => run_case::<u8>(
                                path, prefetch, shape, &csr_off, &csr, &class_masks, &slices,
                            ),
                            MaskWidth::U16 => run_case::<u16>(
                                path, prefetch, shape, &csr_off, &csr, &class_masks, &slices,
                            ),
                            MaskWidth::U32 => run_case::<u32>(
                                path, prefetch, shape, &csr_off, &csr, &class_masks, &slices,
                            ),
                        };
                        assert_eq!(
                            got,
                            want,
                            "seed {seed}: {}/{}/prefetch={prefetch} diverges from the u32 \
                             scalar reference",
                            path.label(),
                            width.label()
                        );
                    }
                }
            }
        }
    }
}
