//! Runtime-dispatched SIMD kernels for the bit-sliced tile engine
//! (§Perf v6 — the software analogue of ULEEN's always-resident FPGA
//! datapath, chasing the paper's 14.3M inf/s).
//!
//! [`FlatModel::responses_tile_slices`](crate::model::flat::FlatModel::responses_tile_slices)
//! delegates its three hot phases here, one call per submodel per tile:
//!
//! 1. **CSR hash-slice XOR accumulation** — for every set slice word,
//!    XOR it into the `out_bits` hash bit-planes its H3 parameters
//!    select. Vector form: broadcast the slice word, test 4 (AVX2) / 2
//!    (NEON) parameter bits at once and XOR under the resulting lane
//!    masks.
//! 2. **Per-filter index reassembly** — rebuild each sample's table
//!    index from the hash bit-planes. Vector form: 8 (AVX2) / 4 (NEON)
//!    samples per op, one shift-and-OR per plane, then a gathered
//!    (AVX2 `vpgatherdd`) or staged-scalar (NEON) class-mask load.
//! 3. **Class-mask fold + response scatter** — unpack the folded mask's
//!    class bits into the response rows, 8 (AVX2) / 4 (NEON) classes
//!    per op.
//!
//! Offline constraint: `core::arch` intrinsics only, no external
//! crates. AVX-512 is deliberately not a tier — its intrinsics are not
//! stable on this crate's MSRV (1.73).
//!
//! **Dispatch is resolved ONCE, at `FlatModel` compile time** — never
//! per call — via [`KernelPath::resolve`]: the `ULEEN_KERNEL` env var
//! (`scalar` / `avx2` / `neon` / `auto`) wins when it names a path the
//! host supports, otherwise runtime feature detection picks AVX2 on
//! capable x86-64, NEON on aarch64 (baseline there), scalar everywhere
//! else. The scalar path IS the pre-SIMD code, moved here verbatim, and
//! every vector path is held bit-exact against it by unit tests below
//! plus the cross-engine conformance proptests.
//!
//! Alignment: the kernels demand nothing beyond `Vec`'s natural
//! alignment — every vector access is an explicitly unaligned
//! load/store (`loadu`/`storeu`, `vld1q`/`vst1q`), so scratch buffers
//! need no over-alignment and resizes can never introduce UB.

/// Which instruction set the compiled tile kernel runs on. Carried by
/// every `FlatModel` (chosen at compile time, see
/// [`KernelPath::resolve`]) and surfaced through engine labels,
/// `/metrics` (`kernel_path`) and bench JSON so a silently-degraded
/// dispatch is visible.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KernelPath {
    /// Portable reference path — always available, on every arch.
    Scalar,
    /// 256-bit AVX2 path (`x86_64`, runtime-detected).
    Avx2,
    /// 128-bit NEON path (`aarch64`, where NEON is ABI-baseline).
    Neon,
}

impl KernelPath {
    /// Env var that forces a dispatch tier: `scalar`, `avx2`, `neon`,
    /// or `auto` (= detect). A value the host cannot run falls back to
    /// detection — forcing can downgrade but never fault.
    pub const ENV: &'static str = "ULEEN_KERNEL";

    /// Stable lowercase name, used in labels / metrics / bench JSON.
    pub fn label(self) -> &'static str {
        match self {
            Self::Scalar => "scalar",
            Self::Avx2 => "avx2",
            Self::Neon => "neon",
        }
    }

    /// Parse a `ULEEN_KERNEL` value. `auto` and unknown strings map to
    /// `None` (= use detection).
    pub fn parse(s: &str) -> Option<Self> {
        match s.trim().to_ascii_lowercase().as_str() {
            "scalar" => Some(Self::Scalar),
            "avx2" => Some(Self::Avx2),
            "neon" => Some(Self::Neon),
            _ => None,
        }
    }

    /// Can the host actually execute this path?
    pub fn is_supported(self) -> bool {
        match self {
            Self::Scalar => true,
            Self::Neon => cfg!(target_arch = "aarch64"),
            Self::Avx2 => {
                #[cfg(target_arch = "x86_64")]
                let ok = std::arch::is_x86_feature_detected!("avx2");
                #[cfg(not(target_arch = "x86_64"))]
                let ok = false;
                ok
            }
        }
    }

    /// This path if the host supports it, else the scalar fallback.
    /// The only constructor-facing sanitizer: a `FlatModel` never
    /// carries a path its host cannot run.
    pub fn or_scalar(self) -> Self {
        if self.is_supported() {
            self
        } else {
            Self::Scalar
        }
    }

    /// Runtime feature detection: AVX2 on capable x86-64, NEON on
    /// aarch64, scalar everywhere else.
    pub fn detect() -> Self {
        if cfg!(target_arch = "aarch64") {
            return Self::Neon;
        }
        #[cfg(target_arch = "x86_64")]
        {
            if std::arch::is_x86_feature_detected!("avx2") {
                return Self::Avx2;
            }
        }
        Self::Scalar
    }

    /// The dispatch decision `FlatModel::compile` bakes in: an env
    /// override that names a supported path wins, otherwise
    /// [`KernelPath::detect`].
    pub fn resolve() -> Self {
        match std::env::var(Self::ENV) {
            Ok(v) => match Self::parse(&v) {
                Some(p) if p.is_supported() => p,
                _ => Self::detect(),
            },
            Err(_) => Self::detect(),
        }
    }

    /// Every path the host can run (scalar always included) — the
    /// conformance tests' iteration set.
    pub fn all_supported() -> Vec<Self> {
        [Self::Scalar, Self::Avx2, Self::Neon]
            .into_iter()
            .filter(|p| p.is_supported())
            .collect()
    }
}

/// Borrowed view of everything one submodel's tile pass needs — the
/// kernel ABI shared by all dispatch tiers. `hash_slices` must arrive
/// zeroed (length `nf * k * ob`); `idx`/`masks` are uninitialized
/// sample-width scratch (length `nt`); `out` is the `nt × m` response
/// plane the kernel ACCUMULATES into (bias is added by the caller —
/// it is path-independent).
pub(crate) struct SubmodelTileArgs<'a> {
    /// one word per encoded input bit; bit `s` = that bit of sample `s`
    pub slices: &'a [u64],
    /// samples in the tile (1..=64)
    pub nt: usize,
    /// classes
    pub m: usize,
    /// table entries per filter (= `1 << ob`)
    pub e: usize,
    /// filters
    pub nf: usize,
    /// hash functions per filter
    pub k: usize,
    /// bits per table index (≤ 32)
    pub ob: usize,
    pub csr_off: &'a [u32],
    pub csr_filter: &'a [u32],
    /// k hash-param words per CSR entry, each masked to `ob` bits
    pub csr_params: &'a [u64],
    /// class-mask bitplanes, layout `[filter][entry]`
    pub class_masks: &'a [u32],
    /// bit-sliced H3 accumulators `[(f*k + j) * ob + b]`, pre-zeroed
    pub hash_slices: &'a mut [u64],
    /// per-sample table-index scratch (scalar + NEON staging)
    pub idx: &'a mut [u32],
    /// per-sample folded class mask for one filter
    pub masks: &'a mut [u32],
    /// `nt × m` row-major response accumulation plane
    pub out: &'a mut [i32],
}

/// Run one submodel's tile pass on the given dispatch tier. `path`
/// must be host-supported (guaranteed by [`KernelPath::or_scalar`] at
/// `FlatModel` construction); a non-compiled variant (e.g. `Neon` on
/// x86) falls through to scalar rather than faulting.
pub(crate) fn submodel_tile_kernel(path: KernelPath, args: SubmodelTileArgs<'_>) {
    debug_assert_eq!(args.hash_slices.len(), args.nf * args.k * args.ob);
    debug_assert!(args.idx.len() >= args.nt && args.masks.len() >= args.nt);
    debug_assert_eq!(args.out.len(), args.nt * args.m);
    match path {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: `path == Avx2` only ever reaches a FlatModel via
        // `or_scalar`, which checked `is_x86_feature_detected!("avx2")`.
        KernelPath::Avx2 => unsafe { avx2::run(args) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: NEON is baseline on aarch64.
        KernelPath::Neon => unsafe { neon::run(args) },
        _ => scalar::run(args),
    }
}

/// The portable reference kernel — the pre-SIMD
/// `responses_tile_slices` inner loop, moved verbatim. Every vector
/// tier is asserted bit-exact against this.
mod scalar {
    use super::SubmodelTileArgs;

    pub(super) fn run(a: SubmodelTileArgs<'_>) {
        let SubmodelTileArgs {
            slices,
            nt,
            m,
            e,
            nf,
            k,
            ob,
            csr_off,
            csr_filter,
            csr_params,
            class_masks,
            hash_slices,
            idx,
            masks,
            out,
        } = a;
        // Phase 1 — bit-sliced hashing: hash_slices[(f*k + j)*ob + b]
        // bit s = bit b of sample s's j-th hash for filter f.
        for (src, &w) in slices.iter().enumerate() {
            if w == 0 {
                continue;
            }
            let lo = csr_off[src] as usize;
            let hi = csr_off[src + 1] as usize;
            for t in lo..hi {
                let f = unsafe { *csr_filter.get_unchecked(t) } as usize;
                let base = f * k * ob;
                let pbase = t * k;
                for j in 0..k {
                    let mut p = unsafe { *csr_params.get_unchecked(pbase + j) };
                    let hb = base + j * ob;
                    while p != 0 {
                        let b = p.trailing_zeros() as usize;
                        p &= p - 1;
                        unsafe {
                            *hash_slices.get_unchecked_mut(hb + b) ^= w;
                        }
                    }
                }
            }
        }
        // Phases 2+3 — per filter: reassemble each sample's table index
        // from the hash bit-planes, fold the k class-mask loads, then
        // scatter the mask's class bits into the response rows.
        for f in 0..nf {
            masks[..nt].fill(u32::MAX);
            for j in 0..k {
                let idx = &mut idx[..nt];
                idx.fill(0);
                let hb = (f * k + j) * ob;
                for (b, &w) in hash_slices[hb..hb + ob].iter().enumerate() {
                    let mut w = w;
                    while w != 0 {
                        let s = w.trailing_zeros() as usize;
                        w &= w - 1;
                        debug_assert!(s < nt);
                        idx[s] |= 1 << b;
                    }
                }
                for (s, mask) in masks[..nt].iter_mut().enumerate() {
                    *mask &= unsafe { *class_masks.get_unchecked(f * e + idx[s] as usize) };
                }
            }
            for (s, &mask) in masks[..nt].iter().enumerate() {
                let row = &mut out[s * m..(s + 1) * m];
                for (c, o) in row.iter_mut().enumerate() {
                    *o += ((mask >> c) & 1) as i32;
                }
            }
        }
    }
}

/// 256-bit AVX2 tier. All loads/stores unaligned; the class-mask probe
/// uses `vpgatherdd` (in-bounds because every hash param is masked to
/// `ob` bits at both `.uln` load and H3 construction, so indices are
/// `< e`).
#[cfg(target_arch = "x86_64")]
mod avx2 {
    use super::SubmodelTileArgs;
    use core::arch::x86_64::*;

    /// # Safety
    /// Caller must have verified AVX2 support
    /// (`is_x86_feature_detected!("avx2")`).
    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn run(a: SubmodelTileArgs<'_>) {
        let SubmodelTileArgs {
            slices,
            nt,
            m,
            e,
            nf,
            k,
            ob,
            csr_off,
            csr_filter,
            csr_params,
            class_masks,
            hash_slices,
            idx: _,
            masks,
            out,
        } = a;
        // gather offsets are signed 32-bit; anything close to 2^31
        // entries per filter could never have been compiled anyway
        debug_assert!(e <= 1 << 30);
        let ones64 = _mm256_set1_epi64x(1);
        let ones32 = _mm256_set1_epi32(1);
        let lane = _mm256_setr_epi32(0, 1, 2, 3, 4, 5, 6, 7);
        // Phase 1 — broadcast the slice word, test 4 param bits per op
        // and XOR under the compare masks; scalar tail for ob % 4.
        for (src, &w) in slices.iter().enumerate() {
            if w == 0 {
                continue;
            }
            let wv = _mm256_set1_epi64x(w as i64);
            let lo = *csr_off.get_unchecked(src) as usize;
            let hi = *csr_off.get_unchecked(src + 1) as usize;
            for t in lo..hi {
                let f = *csr_filter.get_unchecked(t) as usize;
                let base = f * k * ob;
                let pbase = t * k;
                for j in 0..k {
                    let p = *csr_params.get_unchecked(pbase + j);
                    if p == 0 {
                        continue;
                    }
                    let pv = _mm256_set1_epi64x(p as i64);
                    let hb = base + j * ob;
                    let mut b = 0usize;
                    while b + 4 <= ob {
                        let sh = _mm256_setr_epi64x(
                            b as i64,
                            b as i64 + 1,
                            b as i64 + 2,
                            b as i64 + 3,
                        );
                        let bits = _mm256_and_si256(_mm256_srlv_epi64(pv, sh), ones64);
                        let sel = _mm256_cmpeq_epi64(bits, ones64);
                        let ptr = hash_slices.as_mut_ptr().add(hb + b) as *mut __m256i;
                        let cur = _mm256_loadu_si256(ptr);
                        _mm256_storeu_si256(
                            ptr,
                            _mm256_xor_si256(cur, _mm256_and_si256(wv, sel)),
                        );
                        b += 4;
                    }
                    let mut pt = p >> b;
                    while pt != 0 {
                        let bb = pt.trailing_zeros() as usize;
                        pt &= pt - 1;
                        *hash_slices.get_unchecked_mut(hb + b + bb) ^= w;
                    }
                }
            }
        }
        // Phases 2+3 — 8 samples per op: rebuild indices plane-by-plane
        // (broadcast the plane's relevant byte window, per-lane shift,
        // mask, OR into position), gather the class masks, fold; then
        // scatter each sample's mask 8 classes per op.
        for f in 0..nf {
            masks[..nt].fill(u32::MAX);
            let table = class_masks.as_ptr().add(f * e) as *const i32;
            for j in 0..k {
                let hb = (f * k + j) * ob;
                let mut s0 = 0usize;
                while s0 + 8 <= nt {
                    let mut iv = _mm256_setzero_si256();
                    for b in 0..ob {
                        let pw = *hash_slices.get_unchecked(hb + b);
                        // lanes 0..7 ← bits s0..s0+7 of the plane word
                        let lo32 = _mm256_set1_epi32((pw >> s0) as u32 as i32);
                        let bits = _mm256_and_si256(_mm256_srlv_epi32(lo32, lane), ones32);
                        iv = _mm256_or_si256(
                            iv,
                            _mm256_sll_epi32(bits, _mm_cvtsi32_si128(b as i32)),
                        );
                    }
                    let gathered = _mm256_i32gather_epi32::<4>(table, iv);
                    let mptr = masks.as_mut_ptr().add(s0) as *mut __m256i;
                    _mm256_storeu_si256(
                        mptr,
                        _mm256_and_si256(_mm256_loadu_si256(mptr), gathered),
                    );
                    s0 += 8;
                }
                for s in s0..nt {
                    let mut iw = 0usize;
                    for b in 0..ob {
                        iw |= (((*hash_slices.get_unchecked(hb + b) >> s) & 1) as usize) << b;
                    }
                    *masks.get_unchecked_mut(s) &= *class_masks.get_unchecked(f * e + iw);
                }
            }
            for s in 0..nt {
                let mask = *masks.get_unchecked(s);
                let mv = _mm256_set1_epi32(mask as i32);
                let row = out.as_mut_ptr().add(s * m);
                let mut c = 0usize;
                while c + 8 <= m {
                    let sh = _mm256_add_epi32(lane, _mm256_set1_epi32(c as i32));
                    let bits = _mm256_and_si256(_mm256_srlv_epi32(mv, sh), ones32);
                    let ptr = row.add(c) as *mut __m256i;
                    _mm256_storeu_si256(
                        ptr,
                        _mm256_add_epi32(_mm256_loadu_si256(ptr), bits),
                    );
                    c += 8;
                }
                while c < m {
                    *row.add(c) += ((mask >> c) & 1) as i32;
                    c += 1;
                }
            }
        }
    }
}

/// 128-bit NEON tier (aarch64). No vector gather exists, so phase 2
/// stages reassembled indices through the `idx` scratch and probes the
/// class masks scalar-wise; phases 1 and 3 are fully vectorized.
#[cfg(target_arch = "aarch64")]
mod neon {
    use super::SubmodelTileArgs;
    use core::arch::aarch64::*;

    /// # Safety
    /// NEON must be available (it is ABI-baseline on aarch64).
    #[target_feature(enable = "neon")]
    pub(super) unsafe fn run(a: SubmodelTileArgs<'_>) {
        let SubmodelTileArgs {
            slices,
            nt,
            m,
            e,
            nf,
            k,
            ob,
            csr_off,
            csr_filter,
            csr_params,
            class_masks,
            hash_slices,
            idx,
            masks,
            out,
        } = a;
        let one32 = vdupq_n_u32(1);
        // negative vector shifts = right shifts for vshlq
        let rsh = vld1q_s32([0i32, -1, -2, -3].as_ptr());
        // Phase 1 — 2 bit-planes per op under all-ones/all-zeros lane
        // masks built from the param bits.
        for (src, &w) in slices.iter().enumerate() {
            if w == 0 {
                continue;
            }
            let wv = vdupq_n_u64(w);
            let lo = *csr_off.get_unchecked(src) as usize;
            let hi = *csr_off.get_unchecked(src + 1) as usize;
            for t in lo..hi {
                let f = *csr_filter.get_unchecked(t) as usize;
                let base = f * k * ob;
                let pbase = t * k;
                for j in 0..k {
                    let p = *csr_params.get_unchecked(pbase + j);
                    if p == 0 {
                        continue;
                    }
                    let hb = base + j * ob;
                    let mut b = 0usize;
                    while b + 2 <= ob {
                        let sel = vcombine_u64(
                            vcreate_u64(0u64.wrapping_sub((p >> b) & 1)),
                            vcreate_u64(0u64.wrapping_sub((p >> (b + 1)) & 1)),
                        );
                        let ptr = hash_slices.as_mut_ptr().add(hb + b);
                        let cur = vld1q_u64(ptr);
                        vst1q_u64(ptr, veorq_u64(cur, vandq_u64(wv, sel)));
                        b += 2;
                    }
                    if b < ob && (p >> b) & 1 == 1 {
                        *hash_slices.get_unchecked_mut(hb + b) ^= w;
                    }
                }
            }
        }
        // Phases 2+3 — 4 samples per op into the `idx` staging buffer,
        // scalar class-mask probe, then a 4-classes-per-op scatter.
        for f in 0..nf {
            masks[..nt].fill(u32::MAX);
            for j in 0..k {
                let hb = (f * k + j) * ob;
                let mut s0 = 0usize;
                while s0 + 4 <= nt {
                    let mut iv = vdupq_n_u32(0);
                    for b in 0..ob {
                        let pw = *hash_slices.get_unchecked(hb + b);
                        let lo32 = vdupq_n_u32((pw >> s0) as u32);
                        let bits = vandq_u32(vshlq_u32(lo32, rsh), one32);
                        iv = vorrq_u32(iv, vshlq_u32(bits, vdupq_n_s32(b as i32)));
                    }
                    vst1q_u32(idx.as_mut_ptr().add(s0), iv);
                    s0 += 4;
                }
                for s in s0..nt {
                    let mut iw = 0u32;
                    for b in 0..ob {
                        iw |= (((*hash_slices.get_unchecked(hb + b) >> s) & 1) as u32) << b;
                    }
                    *idx.get_unchecked_mut(s) = iw;
                }
                for s in 0..nt {
                    *masks.get_unchecked_mut(s) &= *class_masks
                        .get_unchecked(f * e + *idx.get_unchecked(s) as usize);
                }
            }
            for s in 0..nt {
                let mask = *masks.get_unchecked(s);
                let mv = vdupq_n_u32(mask);
                let row = out.as_mut_ptr().add(s * m);
                let mut c = 0usize;
                while c + 4 <= m {
                    let sh = vld1q_s32(
                        [-(c as i32), -(c as i32 + 1), -(c as i32 + 2), -(c as i32 + 3)]
                            .as_ptr(),
                    );
                    let bits = vandq_u32(vshlq_u32(mv, sh), one32);
                    let cur = vld1q_s32(row.add(c));
                    vst1q_s32(row.add(c), vaddq_s32(cur, vreinterpretq_s32_u32(bits)));
                    c += 4;
                }
                while c < m {
                    *row.add(c) += ((mask >> c) & 1) as i32;
                    c += 1;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_and_label_roundtrip() {
        for p in [KernelPath::Scalar, KernelPath::Avx2, KernelPath::Neon] {
            assert_eq!(KernelPath::parse(p.label()), Some(p));
        }
        assert_eq!(KernelPath::parse(" AVX2 "), Some(KernelPath::Avx2));
        assert_eq!(KernelPath::parse("auto"), None);
        assert_eq!(KernelPath::parse("sse9"), None);
    }

    #[test]
    fn detection_yields_a_supported_path_and_or_scalar_never_lies() {
        assert!(KernelPath::detect().is_supported());
        assert!(KernelPath::resolve().is_supported());
        for p in [KernelPath::Scalar, KernelPath::Avx2, KernelPath::Neon] {
            assert!(p.or_scalar().is_supported());
            if p.is_supported() {
                assert_eq!(p.or_scalar(), p);
            } else {
                assert_eq!(p.or_scalar(), KernelPath::Scalar);
            }
        }
        let all = KernelPath::all_supported();
        assert!(all.contains(&KernelPath::Scalar));
        assert!(all.contains(&KernelPath::detect()));
    }

    /// Tiny deterministic LCG so the synthetic-kernel conformance cases
    /// below don't depend on any dataset or trainer.
    struct Lcg(u64);
    impl Lcg {
        fn next(&mut self) -> u64 {
            self.0 = self.0.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            self.0
        }
    }

    /// Build a random-but-valid synthetic submodel shape and assert
    /// every host-supported path produces responses bit-identical to
    /// scalar — directly at the kernel ABI, no model required. Shapes
    /// chosen to hit every vector width's main loop AND its tail
    /// (ob % 4, nt % 8, m % 8 all nonzero in at least one case).
    #[test]
    fn every_supported_path_matches_scalar_on_synthetic_kernels() {
        for (seed, nf, ob, k, nt, m, total_bits) in [
            (1u64, 3usize, 4usize, 2usize, 64usize, 8usize, 24usize),
            (2, 2, 5, 3, 64, 10, 16),
            (3, 4, 7, 1, 37, 32, 40),
            (4, 1, 3, 2, 5, 3, 8),
            (5, 5, 6, 2, 63, 11, 33),
        ] {
            let e = 1usize << ob;
            let mut rng = Lcg(seed);
            // CSR: every (filter, slot) pair reads a rotating source bit
            let slots_per_filter = 3usize;
            let mut per_src: Vec<Vec<usize>> = vec![Vec::new(); total_bits];
            for f in 0..nf {
                for i in 0..slots_per_filter {
                    per_src[(f * slots_per_filter + i * 7) % total_bits].push(f);
                }
            }
            let mut csr_off = vec![0u32];
            let mut csr_filter = Vec::new();
            let mut csr_params = Vec::new();
            for fs in &per_src {
                for &f in fs {
                    csr_filter.push(f as u32);
                    for _ in 0..k {
                        // params masked to ob bits, like real H3 params
                        csr_params.push(rng.next() & ((1u64 << ob) - 1));
                    }
                }
            }
            csr_off.extend((1..=total_bits).map(|s| {
                per_src[..s].iter().map(|v| v.len() as u32).sum::<u32>()
            }));
            let class_masks: Vec<u32> =
                (0..nf * e).map(|_| rng.next() as u32).collect();
            let slices: Vec<u64> = (0..total_bits)
                .map(|_| {
                    let w = rng.next();
                    if nt == 64 { w } else { w & ((1u64 << nt) - 1) }
                })
                .collect();

            let run_path = |path: KernelPath| -> Vec<i32> {
                let mut hash_slices = vec![0u64; nf * k * ob];
                let mut idx = vec![0u32; nt];
                let mut masks = vec![0u32; nt];
                let mut out = vec![0i32; nt * m];
                submodel_tile_kernel(
                    path,
                    SubmodelTileArgs {
                        slices: &slices,
                        nt,
                        m,
                        e,
                        nf,
                        k,
                        ob,
                        csr_off: &csr_off,
                        csr_filter: &csr_filter,
                        csr_params: &csr_params,
                        class_masks: &class_masks,
                        hash_slices: &mut hash_slices,
                        idx: &mut idx,
                        masks: &mut masks,
                        out: &mut out,
                    },
                );
                out
            };

            let want = run_path(KernelPath::Scalar);
            for path in KernelPath::all_supported() {
                assert_eq!(
                    run_path(path),
                    want,
                    "seed {seed}: {} diverges from scalar",
                    path.label()
                );
            }
        }
    }
}
