//! Flat compiled model — the §Perf-optimized inference representation.
//!
//! `UleenModel` keeps each filter's table as its own heap allocation
//! (ergonomic for training/pruning, terrible for the inference cache):
//! profiling showed the lookup stage dominating the hot path (~70% of
//! per-sample time) with pointer-chasing through `Vec<Option<BinaryBloom>>`.
//!
//! [`FlatModel::compile`] re-lays every submodel into one cache-conscious
//! **memory plane** (§Perf v10): a single 64-byte-aligned arena holding,
//! per submodel, (1) width-adaptive class-mask planes in **filter-major,
//! class-minor** order — all classes' table bits for a filter adjacent,
//! stored as `u8`/`u16`/`u32` elements picked from the class count
//! ([`MaskWidth`]) so a 10-class model touches half the random-access
//! bytes of the old always-`u32` layout; (2) the scatter-hash CSR
//! **AoS-interleaved** (stride `k + 1`: filter index then its `k` H3
//! params) so each set input bit reads one contiguous record run instead
//! of two parallel arrays. Pruned filters become all-zero table slots
//! plus a keep-bit, so the inner loop is branchless on structure.
//! Compile-only buffers (`input_order`, the flattened `hash_params`) are
//! folded into the CSR and NOT retained — [`FlatModel::model_bytes`]
//! counts exactly what inference keeps resident. Semantics are identical
//! to the reference path (asserted by tests and the cross-engine
//! integration suite).
//!
//! Batch inference is built around one tile kernel,
//! [`FlatModel::responses_tile_slices`], that consumes a borrowed
//! [`TileSlices`] view (one `u64` per encoded input bit, one sample per
//! bit-lane). Two producers feed it: the **fused path**
//! ([`FlatModel::responses_batch_fused`]) thermometer-encodes raw float
//! rows straight into the slice layout, and the **BitVec adapter**
//! ([`FlatModel::responses_batch`]) transposes pre-encoded inputs — kept
//! so conformance tests can drive the kernel from the same encoded bits
//! as the scalar path.
//!
//! The kernels software-prefetch the next CSR span while streaming set
//! bits and the upcoming class-mask lines while probing (resolved once
//! at compile; `ULEEN_NO_PREFETCH=1` opts out — prefetch is a pure hint
//! and never changes a response bit).

use crate::encoding::thermometer::ThermometerEncoder;
use crate::model::ensemble::UleenModel;
use crate::model::simd::{self, prefetch_read, KernelPath, MaskWidth, MaskWord};
use crate::model::submodel::SubmodelConfig;
use crate::util::bitvec::BitVec;

/// A borrowed sample-sliced view of one ≤64-sample tile — the batch
/// kernel's native input layout. Word `slices[src]` holds encoded bit
/// `src` of every sample in the tile: bit `s` of that word is bit `src`
/// of sample `s`.
///
/// Producers: [`ThermometerEncoder::encode_tile_slices`] (the fused
/// encode, zero intermediate materialization) or the BitVec transpose
/// adapter inside [`FlatModel::responses_batch`] (kept for conformance
/// testing against pre-encoded inputs).
#[derive(Clone, Copy)]
pub struct TileSlices<'a> {
    slices: &'a [u64],
    nt: usize,
}

impl<'a> TileSlices<'a> {
    /// Wrap `slices` (one word per encoded input bit) holding `nt`
    /// samples. Bits `nt..64` of every word must be zero.
    pub fn new(slices: &'a [u64], nt: usize) -> Self {
        assert!(nt <= FlatModel::TILE, "a tile holds at most 64 samples");
        Self { slices, nt }
    }

    /// Samples in the tile (≤ 64).
    pub fn num_samples(&self) -> usize {
        self.nt
    }

    /// One word per encoded input bit.
    pub fn slices(&self) -> &'a [u64] {
        self.slices
    }
}

/// One 64-byte cache line — the arena's allocation unit. `repr(C)` over
/// a byte array with 64-byte alignment makes a `Vec<Line>` a single
/// contiguous cache-line-aligned byte buffer.
#[derive(Clone, Copy)]
#[repr(C, align(64))]
struct Line([u8; 64]);

/// One table's location inside the arena: a byte offset (always
/// 64-byte-aligned, see [`Span::reserve`]) and an element count.
#[derive(Clone, Copy, Debug, Default)]
struct Span {
    off: usize,
    len: usize,
}

impl Span {
    /// Reserve `len` elements of `elem_bytes` each, starting at the
    /// next cache-line boundary past `*cursor`; advances the cursor.
    /// Line-aligning every section start means no table ever shares a
    /// line with its neighbor and element alignment (≤ 8) is free.
    fn reserve(cursor: &mut usize, elem_bytes: usize, len: usize) -> Self {
        let off = (*cursor + 63) & !63;
        *cursor = off + elem_bytes * len;
        Self { off, len }
    }
}

/// The single 64-byte-aligned allocation holding every submodel's
/// compiled tables (class-mask planes, CSR offsets, interleaved CSR
/// records). One allocation per model means shard workers sharing a
/// `SharedModel` touch one compact footprint instead of a
/// heap-scattered `Vec` per table — and makes resident-byte accounting
/// ([`FlatModel::model_bytes`]) exact. Never serialized: `.uln`
/// artifacts store the source model and re-compile on load.
struct Arena {
    lines: Vec<Line>,
    /// bytes actually laid out (≤ `lines.len() * 64`)
    len: usize,
}

impl Arena {
    fn with_byte_len(len: usize) -> Self {
        Self { lines: vec![Line([0u8; 64]); len.div_ceil(64)], len }
    }

    /// Bytes this arena keeps resident (whole cache lines).
    fn allocated_bytes(&self) -> usize {
        self.lines.len() * 64
    }

    fn base(&self) -> *const u8 {
        self.lines.as_ptr() as *const u8
    }

    /// Typed read view of a reserved span. Private, and only ever
    /// instantiated with primitive integer elements (u8/u16/u32/u64)
    /// for spans reserved with that exact element size.
    fn typed<T>(&self, s: Span) -> &[T] {
        debug_assert_eq!(s.off % 64, 0);
        debug_assert!(s.off + s.len * std::mem::size_of::<T>() <= self.len);
        // SAFETY: the span lies inside this arena's initialized
        // (zero-filled at construction) allocation; its offset is
        // 64-byte-aligned, which satisfies any primitive integer
        // alignment; and every bit pattern is a valid value for the
        // integer types this is instantiated with.
        unsafe { std::slice::from_raw_parts(self.base().add(s.off) as *const T, s.len) }
    }

    /// Typed write view of a reserved span — the compile step's fill
    /// hook. Same instantiation contract as [`Arena::typed`].
    fn typed_mut<T>(&mut self, s: Span) -> &mut [T] {
        debug_assert_eq!(s.off % 64, 0);
        debug_assert!(s.off + s.len * std::mem::size_of::<T>() <= self.len);
        let base = self.lines.as_mut_ptr() as *mut u8;
        // SAFETY: as `typed`, and the `&mut self` borrow makes the view
        // exclusive.
        unsafe { std::slice::from_raw_parts_mut(base.add(s.off) as *mut T, s.len) }
    }
}

/// One submodel compiled into the model's arena.
///
/// The table storage is TRANSPOSED relative to the hardware's per-
/// discriminator view: plane entry `[f * E + e]` is a bitmask over
/// classes — bit `c` set iff discriminator `c`'s filter `f` is kept AND
/// its table entry `e` is 1. One probe then costs ONE mask-word load for
/// all classes (instead of `classes` separate random loads), and the
/// AND-over-k probes is a single word AND. Pruning folds into the masks
/// for free. The mask element width is the model's [`MaskWidth`].
pub struct FlatSubmodel {
    pub cfg: SubmodelConfig,
    pub k: usize,
    pub bias: Vec<i32>,
    /// class-mask planes, layout `[filter][entry]`, element width =
    /// the owning model's [`MaskWidth`]
    masks: Span,
    /// Scatter-hash CSR (§Perf v3): instead of gathering every key bit,
    /// iterate the SET bits of the encoded input once and XOR their hash
    /// contributions into per-filter accumulators. `csr_off[src]..
    /// csr_off[src+1]` indexes records for input bit `src` — H3
    /// linearity makes the order irrelevant. u32, `total_input_bits + 1`
    /// entries.
    csr_off: Span,
    /// AoS-interleaved CSR records (§Perf v10), stride `k + 1` u64
    /// words per scatter entry: `[filter, p_0, .., p_{k-1}]` — one
    /// contiguous read run per entry instead of parallel
    /// filter/params arrays.
    csr: Span,
}

impl FlatSubmodel {
    fn csr_off<'a>(&self, arena: &'a Arena) -> &'a [u32] {
        arena.typed(self.csr_off)
    }

    fn csr<'a>(&self, arena: &'a Arena) -> &'a [u64] {
        arena.typed(self.csr)
    }

    fn masks<'a, W: MaskWord>(&self, arena: &'a Arena) -> &'a [W] {
        arena.typed(self.masks)
    }
}

/// Compile-time knobs for [`FlatModel::compile_with`]. `None` fields
/// take the default decision (env override, else detection/derivation)
/// — `Default::default()` is exactly [`FlatModel::compile`]. Explicit
/// forcing exists so tests and benches can pin a configuration without
/// mutating process-global env vars.
#[derive(Clone, Copy, Debug, Default)]
pub struct CompileOptions {
    /// Forced SIMD dispatch tier (clamped to host support); `None` =
    /// [`KernelPath::resolve`] (`ULEEN_KERNEL`, else detection).
    pub kernel: Option<KernelPath>,
    /// Forced class-mask plane width (widened if too narrow for the
    /// class count); `None` = [`MaskWidth::resolve`]
    /// (`ULEEN_MASK_WIDTH`, else narrowest sufficient).
    pub mask_width: Option<MaskWidth>,
    /// Force software prefetch on/off; `None` = on unless
    /// `ULEEN_NO_PREFETCH` is set. A pure hint — never changes results.
    pub prefetch: Option<bool>,
}

/// A compiled inference-only model: all tables in one 64-byte-aligned
/// arena, plus the per-submodel shape/bias metadata describing it.
pub struct FlatModel {
    arena: Arena,
    pub submodels: Vec<FlatSubmodel>,
    pub num_classes: usize,
    /// SIMD dispatch tier for the tile kernel, resolved ONCE here at
    /// compile time (§Perf v6) — invariant: always host-supported
    /// (sanitized through [`KernelPath::or_scalar`]).
    kernel: KernelPath,
    /// Class-mask plane element width, resolved ONCE at compile time
    /// (§Perf v10) — invariant: always holds `num_classes` (sanitized
    /// through [`MaskWidth::widen_to_hold`]).
    width: MaskWidth,
    /// Software-prefetch upcoming CSR spans / mask lines in the hot
    /// loops (`ULEEN_NO_PREFETCH` opt-out; pure hint, bit-exact either
    /// way).
    prefetch: bool,
}

/// Per-submodel compile staging — everything pass 1 derives from the
/// source model before the arena exists. `input_order` and the
/// flattened H3 params live only here: both are folded into the
/// interleaved CSR and never become resident.
struct SubBuild {
    cfg: SubmodelConfig,
    k: usize,
    bias: Vec<i32>,
    masks_u32: Vec<u32>,
    csr_off_v: Vec<u32>,
    csr_v: Vec<u64>,
}

impl FlatModel {
    /// Env var that disables software prefetch in the compiled hot
    /// loops (any value). Prefetch is a pure hint: responses are
    /// bit-exact with it on or off (asserted by the kernel conformance
    /// tests), so this knob exists for benchmarking and for hosts whose
    /// prefetchers dislike hints.
    pub const NO_PREFETCH_ENV: &'static str = "ULEEN_NO_PREFETCH";

    /// Compile with the default decisions ([`KernelPath::resolve`],
    /// [`MaskWidth::resolve`], prefetch on unless `ULEEN_NO_PREFETCH`).
    /// Panics on a model the flat layout cannot represent — use
    /// [`FlatModel::try_compile`] to surface that as an error instead.
    pub fn compile(model: &UleenModel) -> Self {
        Self::compile_with(model, CompileOptions::default())
    }

    /// [`FlatModel::compile`] with a forced dispatch tier — the testing
    /// override the SIMD conformance suite is built on. An unsupported
    /// `kernel` is clamped to scalar, never trusted.
    pub fn compile_with_kernel(model: &UleenModel, kernel: KernelPath) -> Self {
        Self::compile_with(model, CompileOptions { kernel: Some(kernel), ..Default::default() })
    }

    /// [`FlatModel::compile`] with explicit [`CompileOptions`] — force
    /// any of kernel tier, mask width, prefetch; leave the rest `None`
    /// for the default decisions.
    pub fn compile_with(model: &UleenModel, opts: CompileOptions) -> Self {
        Self::try_compile_with(model, opts)
            .expect("FlatModel::compile: model incompatible with the flat engine")
    }

    /// Fallible compile — the class-capacity check every serving path
    /// funnels through (the `.uln` loader re-checks at parse time so
    /// hostile artifacts fail before any allocation).
    pub fn try_compile(model: &UleenModel) -> crate::Result<Self> {
        Self::try_compile_with(model, CompileOptions::default())
    }

    /// Fallible [`FlatModel::compile_with`].
    pub fn try_compile_with(model: &UleenModel, opts: CompileOptions) -> crate::Result<Self> {
        let m = model.num_classes();
        anyhow::ensure!(
            (1..=32).contains(&m),
            "flat engine: {m} classes exceed the 32-class capacity of the class-mask \
             planes (one bit per class, u32 at the widest; split the label space to \
             serve this model)"
        );
        let kernel = opts.kernel.unwrap_or_else(KernelPath::resolve).or_scalar();
        let width = match opts.mask_width {
            Some(w) => w.widen_to_hold(m),
            None => MaskWidth::resolve(m),
        };
        let prefetch = opts
            .prefetch
            .unwrap_or_else(|| std::env::var_os(Self::NO_PREFETCH_ENV).is_none());

        // Pass 1 — derive every table from the source model into
        // ordinary Vecs (compile-time only; dropped once copied).
        let builds: Vec<SubBuild> = model
            .submodels
            .iter()
            .map(|sm| {
                let nf = sm.cfg.num_filters();
                let e = sm.cfg.entries_per_filter;
                let mut masks_u32 = vec![0u32; nf * e];
                for (c, disc) in sm.discriminators.iter().enumerate() {
                    for (f, filt) in disc.filters.iter().enumerate() {
                        if let Some(filt) = filt {
                            for entry in 0..e {
                                if filt.table.get(entry) {
                                    masks_u32[f * e + entry] |= 1 << c;
                                }
                            }
                        }
                    }
                }
                let k = sm.cfg.k_hashes;
                let n = sm.cfg.inputs_per_filter;
                // H3 params flattened [k][n] row-major — compile
                // staging only, folded into the CSR records below.
                let mut hash_params = vec![0u64; k * n];
                for (j, h) in sm.hash.fns.iter().enumerate() {
                    hash_params[j * n..(j + 1) * n].copy_from_slice(&h.params);
                }
                // Build the scatter CSR: slot s = f*n + i reads input
                // bit input_order[s] and contributes params_j[i] to
                // filter f's j-th hash. Records are interleaved:
                // [filter, p_0 .. p_{k-1}], stride k+1.
                let total_bits = sm.cfg.total_input_bits;
                let mut per_src: Vec<Vec<(u32, Vec<u64>)>> = vec![Vec::new(); total_bits];
                for f in 0..nf {
                    for i in 0..n {
                        let src = sm.input_order[f * n + i] as usize;
                        let ps: Vec<u64> =
                            (0..k).map(|j| hash_params[j * n + i]).collect();
                        per_src[src].push((f as u32, ps));
                    }
                }
                let mut csr_off_v = Vec::with_capacity(total_bits + 1);
                let mut csr_v = Vec::new();
                csr_off_v.push(0u32);
                let mut entries = 0u32;
                for src in 0..total_bits {
                    for (f, ps) in &per_src[src] {
                        csr_v.push(*f as u64);
                        csr_v.extend_from_slice(ps);
                        entries += 1;
                    }
                    csr_off_v.push(entries);
                }
                SubBuild { cfg: sm.cfg, k, bias: sm.bias.clone(), masks_u32, csr_off_v, csr_v }
            })
            .collect();

        // Pass 2 — lay every table out in one arena (each section
        // starting on its own cache line) and copy the staging in.
        let mut cursor = 0usize;
        let spans: Vec<(Span, Span, Span)> = builds
            .iter()
            .map(|b| {
                let masks = Span::reserve(&mut cursor, width.bytes(), b.masks_u32.len());
                let csr_off = Span::reserve(&mut cursor, 4, b.csr_off_v.len());
                let csr = Span::reserve(&mut cursor, 8, b.csr_v.len());
                (masks, csr_off, csr)
            })
            .collect();
        let mut arena = Arena::with_byte_len(cursor);
        let mut submodels = Vec::with_capacity(builds.len());
        for (b, (masks, csr_off, csr)) in builds.into_iter().zip(spans) {
            match width {
                MaskWidth::U8 => fill_masks::<u8>(&mut arena, masks, &b.masks_u32),
                MaskWidth::U16 => fill_masks::<u16>(&mut arena, masks, &b.masks_u32),
                MaskWidth::U32 => fill_masks::<u32>(&mut arena, masks, &b.masks_u32),
            }
            arena.typed_mut::<u32>(csr_off).copy_from_slice(&b.csr_off_v);
            arena.typed_mut::<u64>(csr).copy_from_slice(&b.csr_v);
            submodels.push(FlatSubmodel {
                cfg: b.cfg,
                k: b.k,
                bias: b.bias,
                masks,
                csr_off,
                csr,
            });
        }
        Ok(Self { arena, submodels, num_classes: m, kernel, width, prefetch })
    }

    /// The SIMD dispatch tier this model's tile kernel runs on —
    /// resolved at compile time, surfaced through engine `/metrics`
    /// (`kernel_path`) and bench JSON.
    pub fn kernel_path(&self) -> KernelPath {
        self.kernel
    }

    /// Force a dispatch tier after compilation (clamped to scalar if
    /// the host can't run it). Testing/diagnostics hook; normal code
    /// lets [`FlatModel::compile`] decide once.
    pub fn set_kernel_path(&mut self, kernel: KernelPath) {
        self.kernel = kernel.or_scalar();
    }

    /// The class-mask plane element width baked in at compile time.
    pub fn mask_width(&self) -> MaskWidth {
        self.width
    }

    /// Whether the compiled hot loops software-prefetch ahead.
    pub fn prefetch_enabled(&self) -> bool {
        self.prefetch
    }

    /// Bytes this compiled model keeps resident for inference: the
    /// arena (class-mask planes + CSR, whole cache lines) plus the
    /// per-submodel bias rows. Surfaced through
    /// `InferenceEngine::model_bytes`, `/metrics` and the serve
    /// shutdown report — the accounting the multi-tenant registry
    /// (ROADMAP item 5) builds on.
    pub fn model_bytes(&self) -> u64 {
        let bias: usize = self.submodels.iter().map(|sm| sm.bias.len() * 4).sum();
        (self.arena.allocated_bytes() + bias) as u64
    }

    /// Bytes of the class-mask planes alone — the random-access tables
    /// the probe phase hits, `mask_width × filters × entries` summed
    /// over submodels. The width-adaptive win in one number: a
    /// ≤16-class model's planes are exactly half their u32-forced size.
    pub fn mask_plane_bytes(&self) -> u64 {
        self.submodels
            .iter()
            .map(|sm| (sm.masks.len * self.width.bytes()) as u64)
            .sum()
    }

    /// What this model would keep resident in the pre-v10 layout
    /// (always-u32 masks, split `csr_filter`/`csr_params` arrays,
    /// resident `input_order` + flattened H3 params, per-table `Vec`s
    /// with no line padding) — the baseline `model_bytes` savings are
    /// reported against in the mem-plane bench.
    pub fn baseline_u32_bytes(&self) -> u64 {
        self.submodels
            .iter()
            .map(|sm| {
                let nf = sm.cfg.num_filters();
                let e = sm.cfg.entries_per_filter;
                let n = sm.cfg.inputs_per_filter;
                let k = sm.k;
                let entries = sm.csr.len / (k + 1);
                (nf * e * 4                         // u32 class masks
                    + (sm.cfg.total_input_bits + 1) * 4 // csr_off
                    + entries * 4                   // csr_filter
                    + entries * k * 8               // csr_params (stride k)
                    + nf * n * 4                    // resident input_order
                    + k * n * 8                     // resident hash_params
                    + sm.bias.len() * 4) as u64
            })
            .sum()
    }

    /// Per-class responses for an encoded input, accumulated into `out`
    /// (caller zeroes). `scratch` holds the per-filter hash accumulators
    /// (no allocation after warmup).
    ///
    /// §Perf v3 scatter-hash: H3 is linear, so instead of gathering `n`
    /// bits per filter we stream the encoded input's SET bits once and XOR
    /// each bit's precomputed contribution into its filter's `k` hash
    /// accumulators (sequential interleaved-CSR reads, work ∝ set bits ≈
    /// I/2; the next span is prefetched while the current one streams).
    /// The class-mask probe then collapses the per-class Bloom AND into
    /// one mask-word AND per hash, prefetching the NEXT filter's `k`
    /// probe lines (their indices are already known) while the current
    /// filter folds.
    pub fn responses_encoded(
        &self,
        encoded: &BitVec,
        scratch: &mut FlatScratch,
        out: &mut [i32],
    ) {
        debug_assert_eq!(out.len(), self.num_classes);
        let m = self.num_classes;
        let enc_words = encoded.words();
        for sm in &self.submodels {
            let e = sm.cfg.entries_per_filter;
            let nf = sm.cfg.num_filters();
            let k = sm.k;
            let stride = k + 1;
            let csr_off = sm.csr_off(&self.arena);
            let csr = sm.csr(&self.arena);
            scratch.h.clear();
            scratch.h.resize(nf * k, 0);
            let h = &mut scratch.h[..];
            // stream set bits of the encoded input
            for (w_idx, &w) in enc_words.iter().enumerate() {
                let mut w = w;
                while w != 0 {
                    let bit = w.trailing_zeros() as usize;
                    w &= w - 1;
                    let src = (w_idx << 6) | bit;
                    let lo = unsafe { *csr_off.get_unchecked(src) } as usize;
                    let hi = unsafe { *csr_off.get_unchecked(src + 1) } as usize;
                    if self.prefetch {
                        // SAFETY: hi ≤ total entries ⇒ hi*stride ≤
                        // csr.len() (at most one past the end).
                        prefetch_read(unsafe { csr.as_ptr().add(hi * stride) });
                    }
                    for t in lo..hi {
                        let rb = t * stride;
                        let f = unsafe { *csr.get_unchecked(rb) } as usize;
                        for j in 0..k {
                            unsafe {
                                *h.get_unchecked_mut(f * k + j) ^=
                                    *csr.get_unchecked(rb + 1 + j);
                            }
                        }
                    }
                }
            }
            // probe class masks per filter, at the compiled plane width
            match self.width {
                MaskWidth::U8 => {
                    probe_filters::<u8>(sm.masks(&self.arena), e, nf, k, h, self.prefetch, m, out)
                }
                MaskWidth::U16 => {
                    probe_filters::<u16>(sm.masks(&self.arena), e, nf, k, h, self.prefetch, m, out)
                }
                MaskWidth::U32 => {
                    probe_filters::<u32>(sm.masks(&self.arena), e, nf, k, h, self.prefetch, m, out)
                }
            }
            for c in 0..m {
                out[c] += sm.bias[c];
            }
        }
    }

    /// Argmax prediction from an encoded input (ties break low).
    pub fn predict_encoded(&self, encoded: &BitVec, scratch: &mut FlatScratch) -> usize {
        scratch.resp.clear();
        scratch.resp.resize(self.num_classes, 0);
        let mut resp = std::mem::take(&mut scratch.resp);
        self.responses_encoded(encoded, scratch, &mut resp);
        let best = crate::util::argmax_tie_low(&resp);
        scratch.resp = resp;
        best
    }

    /// Samples per bit-sliced tile: one per bit of the slice word.
    pub const TILE: usize = 64;

    /// Per-class responses for a batch of encoded inputs (§Perf v4
    /// bit-sliced batch kernel). `out` is row-major `encoded.len() ×
    /// num_classes` and is zeroed here. Bit-exact with per-sample
    /// [`FlatModel::responses_encoded`] — asserted by the cross-engine
    /// conformance proptests.
    ///
    /// Samples are processed in tiles of up to [`FlatModel::TILE`] = 64.
    /// Within a tile everything is *sample-sliced*: word `slices[src]`
    /// holds bit `src` of all 64 samples, and the H3 accumulators become
    /// `out_bits` bit-planes per (filter, hash). H3 linearity turns the
    /// per-sample XOR of parameters into whole-word XORs of sample slices
    /// (bit `b` of a parameter set → XOR the slice into hash plane `b`),
    /// so one CSR traversal — the memory-bound stage that dominates the
    /// scalar path — serves all 64 samples.
    pub fn responses_batch(
        &self,
        encoded: &[BitVec],
        scratch: &mut FlatBatchScratch,
        out: &mut [i32],
    ) {
        let m = self.num_classes;
        assert_eq!(out.len(), encoded.len() * m);
        out.iter_mut().for_each(|o| *o = 0);
        let mut start = 0usize;
        while start < encoded.len() {
            let nt = (encoded.len() - start).min(Self::TILE);
            self.responses_tile(
                &encoded[start..start + nt],
                scratch,
                &mut out[start * m..(start + nt) * m],
            );
            start += nt;
        }
    }

    /// One ≤64-sample tile of [`FlatModel::responses_batch`], fed
    /// pre-encoded `BitVec`s. Thin adapter over
    /// [`FlatModel::responses_tile_slices`]: transposes the tile into the
    /// sample-slice layout (streaming set bits keeps this at O(set bits))
    /// and delegates. The fused path skips this transpose entirely by
    /// encoding straight into slices.
    fn responses_tile(&self, tile: &[BitVec], scratch: &mut FlatBatchScratch, out: &mut [i32]) {
        let nt = tile.len();
        debug_assert!(nt >= 1 && nt <= Self::TILE);
        let total_bits = self.submodels[0].cfg.total_input_bits;
        let mut slices = std::mem::take(&mut scratch.slices);
        slices.clear();
        slices.resize(total_bits, 0);
        for (s, enc) in tile.iter().enumerate() {
            debug_assert_eq!(enc.len(), total_bits);
            let sbit = 1u64 << s;
            for (w_idx, &w) in enc.words().iter().enumerate() {
                let mut w = w;
                while w != 0 {
                    let bit = w.trailing_zeros() as usize;
                    w &= w - 1;
                    slices[(w_idx << 6) | bit] |= sbit;
                }
            }
        }
        self.responses_tile_slices(TileSlices::new(&slices, nt), scratch, out);
        scratch.slices = slices;
    }

    /// Per-class responses for raw float rows (§Perf v5 **fused batch
    /// path**): thermometer-encodes each ≤64-sample tile directly into the
    /// kernel's sample-slice layout
    /// ([`ThermometerEncoder::encode_tile_slices`]) and runs
    /// [`FlatModel::responses_tile_slices`] on the borrowed view — no
    /// per-sample `BitVec`, no transpose, no intermediate allocation after
    /// warmup. `x` is row-major `n × encoder.num_inputs`; `out` is
    /// row-major `n × num_classes` and is zeroed here. Bit-exact with
    /// encode-then-[`FlatModel::responses_batch`] (conformance proptests).
    pub fn responses_batch_fused(
        &self,
        encoder: &ThermometerEncoder,
        x: &[f32],
        n: usize,
        scratch: &mut FlatBatchScratch,
        out: &mut [i32],
    ) {
        let f = encoder.num_inputs;
        assert_eq!(x.len(), n * f);
        let m = self.num_classes;
        assert_eq!(out.len(), n * m);
        debug_assert_eq!(
            encoder.encoded_bits(),
            self.submodels[0].cfg.total_input_bits,
            "encoder/model width mismatch"
        );
        out.iter_mut().for_each(|o| *o = 0);
        let mut slices = std::mem::take(&mut scratch.slices);
        let mut start = 0usize;
        while start < n {
            let nt = (n - start).min(Self::TILE);
            encoder.encode_tile_slices(&x[start * f..(start + nt) * f], nt, &mut slices);
            self.responses_tile_slices(
                TileSlices::new(&slices, nt),
                scratch,
                &mut out[start * m..(start + nt) * m],
            );
            start += nt;
        }
        scratch.slices = slices;
    }

    /// [`FlatModel::responses_batch_fused`] writing **f32** responses into
    /// a caller-owned plane — the write-into primitive every engine's
    /// `responses_into` bottoms out in. Only the `n * num_classes` prefix
    /// of `out` is written (oversized planes are fine, and a dirty prefix
    /// is fully overwritten); the integer tile staging lives in
    /// `scratch.resp`, so the i32 → f32 conversion costs one tile-sized
    /// pass and the whole call allocates nothing after warmup.
    pub fn responses_batch_fused_into(
        &self,
        encoder: &ThermometerEncoder,
        x: &[f32],
        n: usize,
        scratch: &mut FlatBatchScratch,
        out: &mut [f32],
    ) {
        let f = encoder.num_inputs;
        assert_eq!(x.len(), n * f);
        let m = self.num_classes;
        assert!(out.len() >= n * m, "output plane too short: {} < {}", out.len(), n * m);
        if n == 0 {
            return;
        }
        debug_assert_eq!(
            encoder.encoded_bits(),
            self.submodels[0].cfg.total_input_bits,
            "encoder/model width mismatch"
        );
        let mut slices = std::mem::take(&mut scratch.slices);
        let mut resp = std::mem::take(&mut scratch.resp);
        let mut start = 0usize;
        while start < n {
            let nt = (n - start).min(Self::TILE);
            encoder.encode_tile_slices(&x[start * f..(start + nt) * f], nt, &mut slices);
            resp.clear();
            resp.resize(nt * m, 0); // the tile kernel wants a zeroed plane
            self.responses_tile_slices(TileSlices::new(&slices, nt), scratch, &mut resp);
            for (o, &r) in out[start * m..(start + nt) * m].iter_mut().zip(resp.iter()) {
                *o = r as f32;
            }
            start += nt;
        }
        scratch.resp = resp;
        scratch.slices = slices;
    }

    /// The bit-sliced tile kernel proper, operating on a borrowed
    /// [`TileSlices`] view (`out` row-major `nt × num_classes`,
    /// pre-zeroed). Per submodel it prepares the shared scratch and
    /// delegates the three hot phases — CSR hash-slice XOR
    /// accumulation, per-filter index reassembly, class-mask fold +
    /// response scatter — to [`simd::submodel_tile_kernel`] on the
    /// dispatch tier AND plane width baked in at compile time
    /// ([`KernelPath::resolve`] / [`MaskWidth::resolve`]; the u32
    /// scalar kernel is the bit-exact reference, every path × width
    /// asserted against it). Both the BitVec adapter and the fused
    /// encode feed it. The bias add stays here: it is path-independent.
    pub fn responses_tile_slices(
        &self,
        tile: TileSlices<'_>,
        scratch: &mut FlatBatchScratch,
        out: &mut [i32],
    ) {
        let nt = tile.num_samples();
        let slices = tile.slices();
        debug_assert!(nt >= 1);
        let m = self.num_classes;
        debug_assert_eq!(out.len(), nt * m);
        let total_bits = self.submodels[0].cfg.total_input_bits;
        assert_eq!(slices.len(), total_bits, "slice view/model width mismatch");
        for sm in &self.submodels {
            let nf = sm.cfg.num_filters();
            let k = sm.k;
            let ob = sm.cfg.out_bits() as usize;
            // the probe reassembles indices into u32 (4 Gi-entry filters
            // are far beyond anything compile() could even allocate)
            debug_assert!(ob <= 32, "batch kernel supports out_bits <= 32");
            scratch.hash_slices.clear();
            scratch.hash_slices.resize(nf * k * ob, 0);
            scratch.idx.clear();
            scratch.idx.resize(nt, 0);
            scratch.idx2.clear();
            scratch.idx2.resize(nt, 0);
            scratch.masks.clear();
            scratch.masks.resize(nt, 0);
            match self.width {
                MaskWidth::U8 => self.run_tile::<u8>(sm, slices, nt, scratch, out),
                MaskWidth::U16 => self.run_tile::<u16>(sm, slices, nt, scratch, out),
                MaskWidth::U32 => self.run_tile::<u32>(sm, slices, nt, scratch, out),
            }
            for s in 0..nt {
                for c in 0..m {
                    out[s * m + c] += sm.bias[c];
                }
            }
        }
    }

    /// Monomorphized tile dispatch for one submodel at plane width `W`
    /// — builds the kernel ABI view over the arena spans and scratch.
    fn run_tile<W: MaskWord>(
        &self,
        sm: &FlatSubmodel,
        slices: &[u64],
        nt: usize,
        scratch: &mut FlatBatchScratch,
        out: &mut [i32],
    ) {
        simd::submodel_tile_kernel(
            self.kernel,
            simd::SubmodelTileArgs {
                slices,
                nt,
                m: self.num_classes,
                e: sm.cfg.entries_per_filter,
                nf: sm.cfg.num_filters(),
                k: sm.k,
                ob: sm.cfg.out_bits() as usize,
                csr_off: sm.csr_off(&self.arena),
                csr: sm.csr(&self.arena),
                class_masks: sm.masks::<W>(&self.arena),
                prefetch: self.prefetch,
                hash_slices: &mut scratch.hash_slices,
                idx: &mut scratch.idx,
                idx2: &mut scratch.idx2,
                masks: &mut scratch.masks,
                out: &mut *out,
            },
        );
    }
}

/// Copy the compile-staging u32 masks into the arena at width `W`
/// (truncation is lossless: only bits `< num_classes ≤ W` are set).
fn fill_masks<W: MaskWord>(arena: &mut Arena, span: Span, vals: &[u32]) {
    for (d, &v) in arena.typed_mut::<W>(span).iter_mut().zip(vals) {
        *d = W::from_u32(v);
    }
}

/// The single-sample probe loop at plane width `W`: fold each filter's
/// `k` mask loads, scatter the class bits, and prefetch the NEXT
/// filter's probe lines one step ahead (every index is already sitting
/// in the hash accumulators).
#[allow(clippy::too_many_arguments)]
fn probe_filters<W: MaskWord>(
    table: &[W],
    e: usize,
    nf: usize,
    k: usize,
    h: &[u64],
    prefetch: bool,
    m: usize,
    out: &mut [i32],
) {
    for f in 0..nf {
        if prefetch && f + 1 < nf {
            let base = (f + 1) * e;
            for j in 0..k {
                let idx = unsafe { *h.get_unchecked((f + 1) * k + j) } as usize;
                // SAFETY: H3 outputs are masked to out_bits ⇒ idx < e,
                // so base + idx < nf * e = table.len().
                prefetch_read(unsafe { table.as_ptr().add(base + idx) });
            }
        }
        let mut mask = u32::MAX;
        for j in 0..k {
            let idx = unsafe { *h.get_unchecked(f * k + j) } as usize;
            mask &= unsafe { table.get_unchecked(f * e + idx) }.to_u32();
        }
        for (c, o) in out.iter_mut().enumerate().take(m) {
            *o += ((mask >> c) & 1) as i32;
        }
    }
}

/// Reusable scratch for [`FlatModel`] inference.
#[derive(Default)]
pub struct FlatScratch {
    /// per-filter hash accumulators (nf × k)
    pub h: Vec<u64>,
    pub resp: Vec<i32>,
}

/// Reusable scratch for the bit-sliced batch kernel
/// ([`FlatModel::responses_batch`]). All buffers grow to the model's shape
/// on first use and are reused afterwards (no allocation after warmup).
#[derive(Default)]
pub struct FlatBatchScratch {
    /// backing store for the tile's sample slices (`slices[src]` bit `s`
    /// = bit `src` of tile sample `s`, length `total_input_bits`), lent
    /// out as a [`TileSlices`] view. Written by the fused encode or the
    /// BitVec transpose adapter; every (re)use resizes it to the exact
    /// model width, so swapping models of a different encoded width
    /// through one scratch is safe.
    slices: Vec<u64>,
    /// bit-sliced H3 accumulators: `[(f*k + j) * out_bits + b]`
    hash_slices: Vec<u64>,
    /// per-sample table index for one (filter, hash) during the probe
    idx: Vec<u32>,
    /// second per-sample index buffer — the scalar tier pipelines the
    /// rebuild one (filter, hash) pair ahead through it so the next
    /// pair's mask lines can be prefetched
    idx2: Vec<u32>,
    /// per-sample accumulated class mask for one filter
    masks: Vec<u32>,
    /// tile-sized i32 response staging for the f32 write-into path
    /// ([`FlatModel::responses_batch_fused_into`]) — ≤ 64 × classes
    resp: Vec<i32>,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth_mnist::synth_mnist;
    use crate::data::synth_uci::{synth_uci, uci_spec};
    use crate::model::ensemble::EnsembleScratch;
    use crate::train::oneshot::{train_oneshot, OneShotConfig};
    use crate::train::prune::prune_model;

    #[test]
    fn flat_matches_reference_responses_exactly() {
        let ds = synth_uci(3, uci_spec("vowel").unwrap());
        let (mut model, _) = train_oneshot(
            &ds,
            &OneShotConfig { inputs_per_filter: 10, entries_per_filter: 128, therm_bits: 6, ..Default::default() },
        );
        // include pruning + bias to exercise the keep/bias paths
        prune_model(&mut model, &ds, 0.3);
        let flat = FlatModel::compile(&model);
        let mut s = EnsembleScratch::default();
        let mut fs = FlatScratch::default();
        let mut out = vec![0i32; model.num_classes()];
        for i in 0..ds.n_test() {
            let enc = model.encoder.encode(ds.test_row(i));
            let want = model.responses_encoded(&enc, &mut s).to_vec();
            out.iter_mut().for_each(|x| *x = 0);
            flat.responses_encoded(&enc, &mut fs, &mut out);
            assert_eq!(out, want, "sample {i}");
        }
    }

    #[test]
    fn batch_kernel_matches_scalar_path_bit_exactly() {
        let ds = synth_uci(11, uci_spec("vowel").unwrap());
        let (mut model, _) = train_oneshot(
            &ds,
            &OneShotConfig { inputs_per_filter: 10, entries_per_filter: 128, therm_bits: 6, ..Default::default() },
        );
        prune_model(&mut model, &ds, 0.25); // exercise pruned slots + bias
        let flat = FlatModel::compile(&model);
        let m = model.num_classes();
        let mut fs = FlatScratch::default();
        let mut bs = FlatBatchScratch::default();
        // batch sizes straddling the 64-sample tile boundary, plus empty
        for n in [0usize, 1, 63, 64, 65, 130] {
            let n = n.min(ds.n_test());
            let encoded: Vec<_> =
                (0..n).map(|i| model.encoder.encode(ds.test_row(i))).collect();
            let mut got = vec![0i32; n * m];
            flat.responses_batch(&encoded, &mut bs, &mut got);
            for (i, enc) in encoded.iter().enumerate() {
                let mut want = vec![0i32; m];
                flat.responses_encoded(enc, &mut fs, &mut want);
                assert_eq!(&got[i * m..(i + 1) * m], &want[..], "n={n} sample {i}");
            }
        }
    }

    #[test]
    fn fused_batch_path_matches_encode_then_batch_kernel() {
        let ds = synth_uci(19, uci_spec("vowel").unwrap());
        let (mut model, _) = train_oneshot(
            &ds,
            &OneShotConfig { inputs_per_filter: 10, entries_per_filter: 128, therm_bits: 6, ..Default::default() },
        );
        prune_model(&mut model, &ds, 0.2);
        let flat = FlatModel::compile(&model);
        let m = model.num_classes();
        let mut bs_bv = FlatBatchScratch::default();
        let mut bs_fused = FlatBatchScratch::default();
        for n in [0usize, 1, 63, 64, 65, 130] {
            let n = n.min(ds.n_test());
            let x = &ds.test_x[..n * ds.num_features];
            let encoded: Vec<_> =
                (0..n).map(|i| model.encoder.encode(ds.test_row(i))).collect();
            let mut want = vec![0i32; n * m];
            flat.responses_batch(&encoded, &mut bs_bv, &mut want);
            let mut got = vec![0i32; n * m];
            flat.responses_batch_fused(&model.encoder, x, n, &mut bs_fused, &mut got);
            assert_eq!(got, want, "n={n}");
        }
    }

    #[test]
    fn fused_into_matches_i32_kernel_and_respects_the_prefix_contract() {
        let ds = synth_uci(23, uci_spec("vowel").unwrap());
        let (mut model, _) = train_oneshot(
            &ds,
            &OneShotConfig { inputs_per_filter: 10, entries_per_filter: 128, therm_bits: 5, ..Default::default() },
        );
        prune_model(&mut model, &ds, 0.2);
        let flat = FlatModel::compile(&model);
        let m = model.num_classes();
        let mut bs_i32 = FlatBatchScratch::default();
        let mut bs_f32 = FlatBatchScratch::default();
        const PAD: usize = 17;
        const SENTINEL: f32 = -4242.5;
        for n in [0usize, 1, 63, 64, 65, 130] {
            let n = n.min(ds.n_test());
            let x = &ds.test_x[..n * ds.num_features];
            let mut want = vec![0i32; n * m];
            flat.responses_batch_fused(&model.encoder, x, n, &mut bs_i32, &mut want);
            // dirty, oversized plane: the n*m prefix must be fully
            // overwritten, the suffix untouched
            let mut got = vec![SENTINEL; n * m + PAD];
            flat.responses_batch_fused_into(&model.encoder, x, n, &mut bs_f32, &mut got);
            for (i, (&g, &w)) in got.iter().zip(want.iter()).enumerate() {
                assert_eq!(g, w as f32, "n={n} slot {i}");
            }
            assert!(
                got[n * m..].iter().all(|&v| v == SENTINEL),
                "n={n}: the suffix beyond n*m must stay untouched"
            );
        }
    }

    #[test]
    fn forced_kernel_paths_match_scalar_bit_exactly_end_to_end() {
        let ds = synth_uci(29, uci_spec("vowel").unwrap());
        let (mut model, _) = train_oneshot(
            &ds,
            &OneShotConfig { inputs_per_filter: 10, entries_per_filter: 128, therm_bits: 6, ..Default::default() },
        );
        prune_model(&mut model, &ds, 0.2);
        let scalar = FlatModel::compile_with_kernel(&model, KernelPath::Scalar);
        assert_eq!(scalar.kernel_path(), KernelPath::Scalar);
        let m = model.num_classes();
        let mut bs_a = FlatBatchScratch::default();
        let mut bs_b = FlatBatchScratch::default();
        for path in KernelPath::all_supported() {
            let forced = FlatModel::compile_with_kernel(&model, path);
            assert_eq!(forced.kernel_path(), path, "supported paths must stick");
            for n in [1usize, 63, 64, 65, 130] {
                let n = n.min(ds.n_test());
                let x = &ds.test_x[..n * ds.num_features];
                let mut want = vec![0i32; n * m];
                scalar.responses_batch_fused(&model.encoder, x, n, &mut bs_a, &mut want);
                let mut got = vec![0i32; n * m];
                forced.responses_batch_fused(&model.encoder, x, n, &mut bs_b, &mut got);
                assert_eq!(got, want, "{} vs scalar at n={n}", path.label());
            }
        }
        // an unsupported forced path clamps to scalar instead of faulting
        let mut clamped = FlatModel::compile_with_kernel(&model, KernelPath::Scalar);
        for p in [KernelPath::Avx2, KernelPath::Neon] {
            clamped.set_kernel_path(p);
            assert!(clamped.kernel_path().is_supported());
        }
    }

    #[test]
    fn forced_mask_widths_and_prefetch_settings_stay_bit_exact() {
        let ds = synth_uci(31, uci_spec("vowel").unwrap());
        let (mut model, _) = train_oneshot(
            &ds,
            &OneShotConfig { inputs_per_filter: 10, entries_per_filter: 128, therm_bits: 6, ..Default::default() },
        );
        prune_model(&mut model, &ds, 0.25);
        let m = model.num_classes(); // vowel: 11 classes → u16 required
        let baseline = FlatModel::compile_with(
            &model,
            CompileOptions {
                kernel: Some(KernelPath::Scalar),
                mask_width: Some(MaskWidth::U32),
                prefetch: Some(false),
            },
        );
        let mut fs_a = FlatScratch::default();
        let mut fs_b = FlatScratch::default();
        let mut bs_a = FlatBatchScratch::default();
        let mut bs_b = FlatBatchScratch::default();
        for width in MaskWidth::all() {
            for prefetch in [false, true] {
                let forced = FlatModel::compile_with(
                    &model,
                    CompileOptions {
                        kernel: None, // dispatched tier, like production
                        mask_width: Some(width),
                        prefetch: Some(prefetch),
                    },
                );
                // too-narrow forcing widens instead of breaking capacity
                assert_eq!(forced.mask_width(), width.widen_to_hold(m));
                assert_eq!(forced.prefetch_enabled(), prefetch);
                for n in [1usize, 64, 130] {
                    let n = n.min(ds.n_test());
                    let x = &ds.test_x[..n * ds.num_features];
                    let mut want = vec![0i32; n * m];
                    baseline.responses_batch_fused(&model.encoder, x, n, &mut bs_a, &mut want);
                    let mut got = vec![0i32; n * m];
                    forced.responses_batch_fused(&model.encoder, x, n, &mut bs_b, &mut got);
                    assert_eq!(
                        got,
                        want,
                        "{}/prefetch={prefetch} vs u32 baseline at n={n}",
                        width.label()
                    );
                }
                // the single-sample scatter path too
                for i in 0..8.min(ds.n_test()) {
                    let enc = model.encoder.encode(ds.test_row(i));
                    let mut want = vec![0i32; m];
                    baseline.responses_encoded(&enc, &mut fs_a, &mut want);
                    let mut got = vec![0i32; m];
                    forced.responses_encoded(&enc, &mut fs_b, &mut got);
                    assert_eq!(got, want, "{} sample {i}", width.label());
                }
            }
        }
        // the default decisions match the documented resolution rules
        let flat = FlatModel::compile(&model);
        assert_eq!(flat.mask_width(), MaskWidth::resolve(m));
        assert_eq!(
            flat.prefetch_enabled(),
            std::env::var_os(FlatModel::NO_PREFETCH_ENV).is_none()
        );
    }

    /// The ISSUE-10 acceptance assert: the MNIST ULN-S shape (784
    /// features × 4 therm bits, 16 inputs/filter, 256 entries, 10
    /// classes → u16 planes) keeps FEWER resident bytes than the pre-v10
    /// layout, with `model_bytes` reproduced exactly from the arena
    /// arithmetic, and the 10-class mask plane exactly HALF its
    /// u32-forced size.
    #[test]
    fn model_bytes_shrinks_vs_the_pr9_layout_on_the_mnist_shape() {
        let ds = synth_mnist(7, 48, 8);
        let (model, _) = train_oneshot(
            &ds,
            &OneShotConfig {
                inputs_per_filter: 16,
                entries_per_filter: 256,
                therm_bits: 4,
                ..Default::default()
            },
        );
        assert_eq!(model.num_classes(), 10);
        // pin the width so the assert is immune to ULEEN_MASK_WIDTH in
        // the environment (the fallback CI job forces u32 globally)
        let flat = FlatModel::compile_with(
            &model,
            CompileOptions { mask_width: Some(MaskWidth::U16), ..Default::default() },
        );
        let forced_u32 = FlatModel::compile_with(
            &model,
            CompileOptions { mask_width: Some(MaskWidth::U32), ..Default::default() },
        );
        assert_eq!(flat.mask_width(), MaskWidth::U16);
        assert_eq!(MaskWidth::required_for(model.num_classes()), MaskWidth::U16);

        // exact reproduction of the arena layout arithmetic
        let align = |x: usize| (x + 63) & !63;
        let mut cursor = 0usize;
        let mut bias_bytes = 0usize;
        for sm in &flat.submodels {
            let nf = sm.cfg.num_filters();
            let e = sm.cfg.entries_per_filter;
            let n = sm.cfg.inputs_per_filter;
            let tb = sm.cfg.total_input_bits;
            let k = sm.k;
            cursor = align(cursor) + nf * e * 2; // u16 mask plane
            cursor = align(cursor) + (tb + 1) * 4; // csr_off
            cursor = align(cursor) + nf * n * (k + 1) * 8; // interleaved CSR
            bias_bytes += sm.bias.len() * 4;
        }
        let expect = (align(cursor) + bias_bytes) as u64;
        assert_eq!(flat.model_bytes(), expect, "model_bytes must be exact");

        // the tentpole shrink: fewer resident bytes than the PR-9
        // layout — even the u32-forced arena wins (dropped input_order
        // exactly pays for the interleave; dropped hash_params covers
        // the line padding), and the u16 plane halves on top
        assert!(flat.model_bytes() < flat.baseline_u32_bytes());
        assert!(forced_u32.model_bytes() < forced_u32.baseline_u32_bytes());
        assert!(flat.model_bytes() < forced_u32.model_bytes());
        assert_eq!(flat.mask_plane_bytes() * 2, forced_u32.mask_plane_bytes());
    }

    #[test]
    fn compile_rejects_more_than_32_classes_with_a_clear_error() {
        use crate::encoding::thermometer::ThermometerKind;
        use crate::model::submodel::Submodel;
        use crate::util::rng::Rng;
        let data: Vec<f32> = (0..400).map(|i| (i % 97) as f32).collect();
        let encoder = ThermometerEncoder::fit(ThermometerKind::Gaussian, &data, 8, 8);
        let cfg = SubmodelConfig {
            inputs_per_filter: 8,
            entries_per_filter: 64,
            k_hashes: 2,
            num_classes: 33, // one past the widest class-mask capacity
            total_input_bits: 64,
        };
        let mut rng = Rng::new(5);
        let sm = Submodel::new_random(&mut rng, cfg);
        let model = UleenModel { name: "too-wide".into(), encoder, submodels: vec![sm] };
        let err = FlatModel::try_compile(&model).unwrap_err().to_string();
        assert!(err.contains("32-class capacity"), "got: {err}");
    }

    #[test]
    fn batch_kernel_handles_multi_submodel_ensembles() {
        let ds = synth_uci(13, uci_spec("wine").unwrap());
        let (a, _) = train_oneshot(
            &ds,
            &OneShotConfig { inputs_per_filter: 8, entries_per_filter: 64, therm_bits: 4, seed: 7, ..Default::default() },
        );
        let (b, _) = train_oneshot(
            &ds,
            &OneShotConfig { inputs_per_filter: 12, entries_per_filter: 256, therm_bits: 4, seed: 8, ..Default::default() },
        );
        let mut ens = a.clone();
        ens.submodels.extend(b.submodels.iter().cloned());
        let flat = FlatModel::compile(&ens);
        let m = ens.num_classes();
        let n = ds.n_test();
        let encoded: Vec<_> = (0..n).map(|i| ens.encoder.encode(ds.test_row(i))).collect();
        let mut bs = FlatBatchScratch::default();
        let mut got = vec![0i32; n * m];
        flat.responses_batch(&encoded, &mut bs, &mut got);
        let mut es = EnsembleScratch::default();
        for (i, enc) in encoded.iter().enumerate() {
            let want = ens.responses_encoded(enc, &mut es);
            assert_eq!(&got[i * m..(i + 1) * m], want, "sample {i}");
        }
    }

    #[test]
    fn flat_predictions_match_for_multi_submodel_models() {
        let ds = synth_uci(9, uci_spec("wine").unwrap());
        let (a, _) = train_oneshot(
            &ds,
            &OneShotConfig { inputs_per_filter: 8, entries_per_filter: 64, therm_bits: 4, seed: 1, ..Default::default() },
        );
        let (b, _) = train_oneshot(
            &ds,
            &OneShotConfig { inputs_per_filter: 12, entries_per_filter: 128, therm_bits: 4, seed: 2, ..Default::default() },
        );
        let mut ens = a.clone();
        ens.submodels.extend(b.submodels.iter().cloned());
        let flat = FlatModel::compile(&ens);
        let mut s = EnsembleScratch::default();
        let mut scratch = FlatScratch::default();
        for i in 0..ds.n_test() {
            let enc = ens.encoder.encode(ds.test_row(i));
            assert_eq!(
                flat.predict_encoded(&enc, &mut scratch),
                ens.predict_encoded(&enc, &mut s)
            );
        }
    }
}
