//! Flat compiled model — the §Perf-optimized inference representation.
//!
//! `UleenModel` keeps each filter's table as its own heap allocation
//! (ergonomic for training/pruning, terrible for the inference cache):
//! profiling showed the lookup stage dominating the hot path (~70% of
//! per-sample time) with pointer-chasing through `Vec<Option<BinaryBloom>>`.
//!
//! [`FlatModel::compile`] re-lays every submodel into single contiguous
//! buffers with **filter-major, class-minor** order — all classes' table
//! words for a filter are adjacent, matching the traversal order of the
//! response loop (hash filter once → probe every class). Pruned filters
//! become all-zero table slots plus a keep-bit, so the inner loop is
//! branchless on structure. Semantics are identical to the reference path
//! (asserted by tests and the cross-engine integration suite).
//!
//! Batch inference is built around one tile kernel,
//! [`FlatModel::responses_tile_slices`], that consumes a borrowed
//! [`TileSlices`] view (one `u64` per encoded input bit, one sample per
//! bit-lane). Two producers feed it: the **fused path**
//! ([`FlatModel::responses_batch_fused`]) thermometer-encodes raw float
//! rows straight into the slice layout, and the **BitVec adapter**
//! ([`FlatModel::responses_batch`]) transposes pre-encoded inputs — kept
//! so conformance tests can drive the kernel from the same encoded bits
//! as the scalar path.

use crate::encoding::thermometer::ThermometerEncoder;
use crate::model::ensemble::UleenModel;
use crate::model::simd::{self, KernelPath};
use crate::model::submodel::SubmodelConfig;
use crate::util::bitvec::BitVec;

/// A borrowed sample-sliced view of one ≤64-sample tile — the batch
/// kernel's native input layout. Word `slices[src]` holds encoded bit
/// `src` of every sample in the tile: bit `s` of that word is bit `src`
/// of sample `s`.
///
/// Producers: [`ThermometerEncoder::encode_tile_slices`] (the fused
/// encode, zero intermediate materialization) or the BitVec transpose
/// adapter inside [`FlatModel::responses_batch`] (kept for conformance
/// testing against pre-encoded inputs).
#[derive(Clone, Copy)]
pub struct TileSlices<'a> {
    slices: &'a [u64],
    nt: usize,
}

impl<'a> TileSlices<'a> {
    /// Wrap `slices` (one word per encoded input bit) holding `nt`
    /// samples. Bits `nt..64` of every word must be zero.
    pub fn new(slices: &'a [u64], nt: usize) -> Self {
        assert!(nt <= FlatModel::TILE, "a tile holds at most 64 samples");
        Self { slices, nt }
    }

    /// Samples in the tile (≤ 64).
    pub fn num_samples(&self) -> usize {
        self.nt
    }

    /// One word per encoded input bit.
    pub fn slices(&self) -> &'a [u64] {
        self.slices
    }
}

/// One submodel compiled to flat arrays.
///
/// The table storage is TRANSPOSED relative to the hardware's per-
/// discriminator view: `class_masks[f * E + e]` is a bitmask over classes
/// — bit `c` set iff discriminator `c`'s filter `f` is kept AND its table
/// entry `e` is 1. One probe then costs ONE u32 load for all classes
/// (instead of `classes` separate random loads), and the AND-over-k probes
/// is a single word AND. Pruning folds into the masks for free.
pub struct FlatSubmodel {
    pub cfg: SubmodelConfig,
    pub input_order: Vec<u32>,
    /// H3 params flattened: [k][n] row-major (k rows of n params).
    pub hash_params: Vec<u64>,
    pub k: usize,
    /// class-mask bitplanes, layout [filter][entry] (supports ≤32 classes)
    pub class_masks: Vec<u32>,
    pub bias: Vec<i32>,
    /// Scatter-hash CSR (§Perf v3): instead of gathering every key bit,
    /// iterate the SET bits of the encoded input once and XOR their hash
    /// contributions into per-filter accumulators. `csr_off[src]..csr_off
    /// [src+1]` indexes entries of `(filter, k params)` for input bit `src`
    /// — H3 linearity makes the order irrelevant.
    pub csr_off: Vec<u32>,
    /// filter index per entry
    pub csr_filter: Vec<u32>,
    /// k hash-param words per entry (stride k, aligned with csr_filter)
    pub csr_params: Vec<u64>,
}

/// A compiled inference-only model.
pub struct FlatModel {
    pub submodels: Vec<FlatSubmodel>,
    pub num_classes: usize,
    /// SIMD dispatch tier for the tile kernel, resolved ONCE here at
    /// compile time (§Perf v6) — invariant: always host-supported
    /// (sanitized through [`KernelPath::or_scalar`]).
    kernel: KernelPath,
}

impl FlatModel {
    /// Compile with the default dispatch decision
    /// ([`KernelPath::resolve`]: `ULEEN_KERNEL` env override, else
    /// runtime feature detection). Panics on a model the flat layout
    /// cannot represent — use [`FlatModel::try_compile`] to surface
    /// that as an error instead.
    pub fn compile(model: &UleenModel) -> Self {
        Self::compile_with_kernel(model, KernelPath::resolve())
    }

    /// [`FlatModel::compile`] with a forced dispatch tier — the testing
    /// override the SIMD conformance suite is built on. An unsupported
    /// `kernel` is clamped to scalar, never trusted.
    pub fn compile_with_kernel(model: &UleenModel, kernel: KernelPath) -> Self {
        Self::try_compile_with_kernel(model, kernel)
            .expect("FlatModel::compile: model incompatible with the flat engine")
    }

    /// Fallible compile — the class-capacity check every serving path
    /// funnels through (the `.uln` loader re-checks at parse time so
    /// hostile artifacts fail before any allocation).
    pub fn try_compile(model: &UleenModel) -> crate::Result<Self> {
        Self::try_compile_with_kernel(model, KernelPath::resolve())
    }

    fn try_compile_with_kernel(model: &UleenModel, kernel: KernelPath) -> crate::Result<Self> {
        let m = model.num_classes();
        anyhow::ensure!(
            (1..=32).contains(&m),
            "flat engine: {m} classes exceed the 32-class capacity of the u32 \
             class-mask planes (one bit per class; split the label space to serve \
             this model)"
        );
        let submodels = model
            .submodels
            .iter()
            .map(|sm| {
                let nf = sm.cfg.num_filters();
                let e = sm.cfg.entries_per_filter;
                let mut class_masks = vec![0u32; nf * e];
                for (c, disc) in sm.discriminators.iter().enumerate() {
                    for (f, filt) in disc.filters.iter().enumerate() {
                        if let Some(filt) = filt {
                            for entry in 0..e {
                                if filt.table.get(entry) {
                                    class_masks[f * e + entry] |= 1 << c;
                                }
                            }
                        }
                    }
                }
                let k = sm.cfg.k_hashes;
                let n = sm.cfg.inputs_per_filter;
                let mut hash_params = vec![0u64; k * n];
                for (j, h) in sm.hash.fns.iter().enumerate() {
                    hash_params[j * n..(j + 1) * n].copy_from_slice(&h.params);
                }
                // Build the scatter CSR: slot s = f*n + i reads input bit
                // input_order[s] and contributes params_j[i] to filter f's
                // j-th hash.
                let total_bits = sm.cfg.total_input_bits;
                let mut per_src: Vec<Vec<(u32, Vec<u64>)>> = vec![Vec::new(); total_bits];
                for f in 0..nf {
                    for i in 0..n {
                        let src = sm.input_order[f * n + i] as usize;
                        let ps: Vec<u64> =
                            (0..k).map(|j| hash_params[j * n + i]).collect();
                        per_src[src].push((f as u32, ps));
                    }
                }
                let mut csr_off = Vec::with_capacity(total_bits + 1);
                let mut csr_filter = Vec::new();
                let mut csr_params = Vec::new();
                csr_off.push(0u32);
                for src in 0..total_bits {
                    for (f, ps) in &per_src[src] {
                        csr_filter.push(*f);
                        csr_params.extend_from_slice(ps);
                    }
                    csr_off.push(csr_filter.len() as u32);
                }
                FlatSubmodel {
                    cfg: sm.cfg,
                    input_order: sm.input_order.clone(),
                    hash_params,
                    k,
                    class_masks,
                    bias: sm.bias.clone(),
                    csr_off,
                    csr_filter,
                    csr_params,
                }
            })
            .collect();
        Ok(Self { submodels, num_classes: m, kernel: kernel.or_scalar() })
    }

    /// The SIMD dispatch tier this model's tile kernel runs on —
    /// resolved at compile time, surfaced through engine `/metrics`
    /// (`kernel_path`) and bench JSON.
    pub fn kernel_path(&self) -> KernelPath {
        self.kernel
    }

    /// Force a dispatch tier after compilation (clamped to scalar if
    /// the host can't run it). Testing/diagnostics hook; normal code
    /// lets [`FlatModel::compile`] decide once.
    pub fn set_kernel_path(&mut self, kernel: KernelPath) {
        self.kernel = kernel.or_scalar();
    }

    /// Per-class responses for an encoded input, accumulated into `out`
    /// (caller zeroes). `scratch` holds the per-filter hash accumulators
    /// (no allocation after warmup).
    ///
    /// §Perf v3 scatter-hash: H3 is linear, so instead of gathering `n`
    /// bits per filter we stream the encoded input's SET bits once and XOR
    /// each bit's precomputed contribution into its filter's `k` hash
    /// accumulators (sequential CSR reads, work ∝ set bits ≈ I/2). The
    /// class-mask probe then collapses the per-class Bloom AND into one
    /// u32 AND per hash.
    pub fn responses_encoded(
        &self,
        encoded: &BitVec,
        scratch: &mut FlatScratch,
        out: &mut [i32],
    ) {
        debug_assert_eq!(out.len(), self.num_classes);
        let m = self.num_classes;
        let enc_words = encoded.words();
        for sm in &self.submodels {
            let e = sm.cfg.entries_per_filter;
            let nf = sm.cfg.num_filters();
            let k = sm.k;
            scratch.h.clear();
            scratch.h.resize(nf * k, 0);
            let h = &mut scratch.h[..];
            // stream set bits of the encoded input
            for (w_idx, &w) in enc_words.iter().enumerate() {
                let mut w = w;
                while w != 0 {
                    let bit = w.trailing_zeros() as usize;
                    w &= w - 1;
                    let src = (w_idx << 6) | bit;
                    let lo = unsafe { *sm.csr_off.get_unchecked(src) } as usize;
                    let hi = unsafe { *sm.csr_off.get_unchecked(src + 1) } as usize;
                    for t in lo..hi {
                        let f = unsafe { *sm.csr_filter.get_unchecked(t) } as usize;
                        let pbase = t * k;
                        for j in 0..k {
                            unsafe {
                                *h.get_unchecked_mut(f * k + j) ^=
                                    *sm.csr_params.get_unchecked(pbase + j);
                            }
                        }
                    }
                }
            }
            // probe class masks per filter
            for f in 0..nf {
                let mut mask = u32::MAX;
                for j in 0..k {
                    let idx = unsafe { *h.get_unchecked(f * k + j) } as usize;
                    mask &= unsafe { *sm.class_masks.get_unchecked(f * e + idx) };
                }
                for (c, o) in out.iter_mut().enumerate().take(m) {
                    *o += ((mask >> c) & 1) as i32;
                }
            }
            for c in 0..m {
                out[c] += sm.bias[c];
            }
        }
    }

    /// Argmax prediction from an encoded input (ties break low).
    pub fn predict_encoded(&self, encoded: &BitVec, scratch: &mut FlatScratch) -> usize {
        scratch.resp.clear();
        scratch.resp.resize(self.num_classes, 0);
        let mut resp = std::mem::take(&mut scratch.resp);
        self.responses_encoded(encoded, scratch, &mut resp);
        let best = crate::util::argmax_tie_low(&resp);
        scratch.resp = resp;
        best
    }

    /// Samples per bit-sliced tile: one per bit of the slice word.
    pub const TILE: usize = 64;

    /// Per-class responses for a batch of encoded inputs (§Perf v4
    /// bit-sliced batch kernel). `out` is row-major `encoded.len() ×
    /// num_classes` and is zeroed here. Bit-exact with per-sample
    /// [`FlatModel::responses_encoded`] — asserted by the cross-engine
    /// conformance proptests.
    ///
    /// Samples are processed in tiles of up to [`FlatModel::TILE`] = 64.
    /// Within a tile everything is *sample-sliced*: word `slices[src]`
    /// holds bit `src` of all 64 samples, and the H3 accumulators become
    /// `out_bits` bit-planes per (filter, hash). H3 linearity turns the
    /// per-sample XOR of parameters into whole-word XORs of sample slices
    /// (bit `b` of a parameter set → XOR the slice into hash plane `b`),
    /// so one CSR traversal — the memory-bound stage that dominates the
    /// scalar path — serves all 64 samples.
    pub fn responses_batch(
        &self,
        encoded: &[BitVec],
        scratch: &mut FlatBatchScratch,
        out: &mut [i32],
    ) {
        let m = self.num_classes;
        assert_eq!(out.len(), encoded.len() * m);
        out.iter_mut().for_each(|o| *o = 0);
        let mut start = 0usize;
        while start < encoded.len() {
            let nt = (encoded.len() - start).min(Self::TILE);
            self.responses_tile(
                &encoded[start..start + nt],
                scratch,
                &mut out[start * m..(start + nt) * m],
            );
            start += nt;
        }
    }

    /// One ≤64-sample tile of [`FlatModel::responses_batch`], fed
    /// pre-encoded `BitVec`s. Thin adapter over
    /// [`FlatModel::responses_tile_slices`]: transposes the tile into the
    /// sample-slice layout (streaming set bits keeps this at O(set bits))
    /// and delegates. The fused path skips this transpose entirely by
    /// encoding straight into slices.
    fn responses_tile(&self, tile: &[BitVec], scratch: &mut FlatBatchScratch, out: &mut [i32]) {
        let nt = tile.len();
        debug_assert!(nt >= 1 && nt <= Self::TILE);
        let total_bits = self.submodels[0].cfg.total_input_bits;
        let mut slices = std::mem::take(&mut scratch.slices);
        slices.clear();
        slices.resize(total_bits, 0);
        for (s, enc) in tile.iter().enumerate() {
            debug_assert_eq!(enc.len(), total_bits);
            let sbit = 1u64 << s;
            for (w_idx, &w) in enc.words().iter().enumerate() {
                let mut w = w;
                while w != 0 {
                    let bit = w.trailing_zeros() as usize;
                    w &= w - 1;
                    slices[(w_idx << 6) | bit] |= sbit;
                }
            }
        }
        self.responses_tile_slices(TileSlices::new(&slices, nt), scratch, out);
        scratch.slices = slices;
    }

    /// Per-class responses for raw float rows (§Perf v5 **fused batch
    /// path**): thermometer-encodes each ≤64-sample tile directly into the
    /// kernel's sample-slice layout
    /// ([`ThermometerEncoder::encode_tile_slices`]) and runs
    /// [`FlatModel::responses_tile_slices`] on the borrowed view — no
    /// per-sample `BitVec`, no transpose, no intermediate allocation after
    /// warmup. `x` is row-major `n × encoder.num_inputs`; `out` is
    /// row-major `n × num_classes` and is zeroed here. Bit-exact with
    /// encode-then-[`FlatModel::responses_batch`] (conformance proptests).
    pub fn responses_batch_fused(
        &self,
        encoder: &ThermometerEncoder,
        x: &[f32],
        n: usize,
        scratch: &mut FlatBatchScratch,
        out: &mut [i32],
    ) {
        let f = encoder.num_inputs;
        assert_eq!(x.len(), n * f);
        let m = self.num_classes;
        assert_eq!(out.len(), n * m);
        debug_assert_eq!(
            encoder.encoded_bits(),
            self.submodels[0].cfg.total_input_bits,
            "encoder/model width mismatch"
        );
        out.iter_mut().for_each(|o| *o = 0);
        let mut slices = std::mem::take(&mut scratch.slices);
        let mut start = 0usize;
        while start < n {
            let nt = (n - start).min(Self::TILE);
            encoder.encode_tile_slices(&x[start * f..(start + nt) * f], nt, &mut slices);
            self.responses_tile_slices(
                TileSlices::new(&slices, nt),
                scratch,
                &mut out[start * m..(start + nt) * m],
            );
            start += nt;
        }
        scratch.slices = slices;
    }

    /// [`FlatModel::responses_batch_fused`] writing **f32** responses into
    /// a caller-owned plane — the write-into primitive every engine's
    /// `responses_into` bottoms out in. Only the `n * num_classes` prefix
    /// of `out` is written (oversized planes are fine, and a dirty prefix
    /// is fully overwritten); the integer tile staging lives in
    /// `scratch.resp`, so the i32 → f32 conversion costs one tile-sized
    /// pass and the whole call allocates nothing after warmup.
    pub fn responses_batch_fused_into(
        &self,
        encoder: &ThermometerEncoder,
        x: &[f32],
        n: usize,
        scratch: &mut FlatBatchScratch,
        out: &mut [f32],
    ) {
        let f = encoder.num_inputs;
        assert_eq!(x.len(), n * f);
        let m = self.num_classes;
        assert!(out.len() >= n * m, "output plane too short: {} < {}", out.len(), n * m);
        if n == 0 {
            return;
        }
        debug_assert_eq!(
            encoder.encoded_bits(),
            self.submodels[0].cfg.total_input_bits,
            "encoder/model width mismatch"
        );
        let mut slices = std::mem::take(&mut scratch.slices);
        let mut resp = std::mem::take(&mut scratch.resp);
        let mut start = 0usize;
        while start < n {
            let nt = (n - start).min(Self::TILE);
            encoder.encode_tile_slices(&x[start * f..(start + nt) * f], nt, &mut slices);
            resp.clear();
            resp.resize(nt * m, 0); // the tile kernel wants a zeroed plane
            self.responses_tile_slices(TileSlices::new(&slices, nt), scratch, &mut resp);
            for (o, &r) in out[start * m..(start + nt) * m].iter_mut().zip(resp.iter()) {
                *o = r as f32;
            }
            start += nt;
        }
        scratch.resp = resp;
        scratch.slices = slices;
    }

    /// The bit-sliced tile kernel proper, operating on a borrowed
    /// [`TileSlices`] view (`out` row-major `nt × num_classes`,
    /// pre-zeroed). Per submodel it prepares the shared scratch and
    /// delegates the three hot phases — CSR hash-slice XOR
    /// accumulation, per-filter index reassembly, class-mask fold +
    /// response scatter — to [`simd::submodel_tile_kernel`] on the
    /// dispatch tier baked in at compile time ([`KernelPath::resolve`];
    /// scalar is bit-exact reference, AVX2/NEON asserted against it).
    /// Both the BitVec adapter and the fused encode feed it. The bias
    /// add stays here: it is path-independent.
    pub fn responses_tile_slices(
        &self,
        tile: TileSlices<'_>,
        scratch: &mut FlatBatchScratch,
        out: &mut [i32],
    ) {
        let nt = tile.num_samples();
        let slices = tile.slices();
        debug_assert!(nt >= 1);
        let m = self.num_classes;
        debug_assert_eq!(out.len(), nt * m);
        let total_bits = self.submodels[0].cfg.total_input_bits;
        assert_eq!(slices.len(), total_bits, "slice view/model width mismatch");
        for sm in &self.submodels {
            let e = sm.cfg.entries_per_filter;
            let nf = sm.cfg.num_filters();
            let k = sm.k;
            let ob = sm.cfg.out_bits() as usize;
            // the probe reassembles indices into u32 (4 Gi-entry filters
            // are far beyond anything compile() could even allocate)
            debug_assert!(ob <= 32, "batch kernel supports out_bits <= 32");
            scratch.hash_slices.clear();
            scratch.hash_slices.resize(nf * k * ob, 0);
            scratch.idx.clear();
            scratch.idx.resize(nt, 0);
            scratch.masks.clear();
            scratch.masks.resize(nt, 0);
            simd::submodel_tile_kernel(
                self.kernel,
                simd::SubmodelTileArgs {
                    slices,
                    nt,
                    m,
                    e,
                    nf,
                    k,
                    ob,
                    csr_off: &sm.csr_off,
                    csr_filter: &sm.csr_filter,
                    csr_params: &sm.csr_params,
                    class_masks: &sm.class_masks,
                    hash_slices: &mut scratch.hash_slices,
                    idx: &mut scratch.idx,
                    masks: &mut scratch.masks,
                    out: &mut *out,
                },
            );
            for s in 0..nt {
                for c in 0..m {
                    out[s * m + c] += sm.bias[c];
                }
            }
        }
    }
}

/// Reusable scratch for [`FlatModel`] inference.
#[derive(Default)]
pub struct FlatScratch {
    /// per-filter hash accumulators (nf × k)
    pub h: Vec<u64>,
    pub resp: Vec<i32>,
}

/// Reusable scratch for the bit-sliced batch kernel
/// ([`FlatModel::responses_batch`]). All buffers grow to the model's shape
/// on first use and are reused afterwards (no allocation after warmup).
#[derive(Default)]
pub struct FlatBatchScratch {
    /// backing store for the tile's sample slices (`slices[src]` bit `s`
    /// = bit `src` of tile sample `s`, length `total_input_bits`), lent
    /// out as a [`TileSlices`] view. Written by the fused encode or the
    /// BitVec transpose adapter; every (re)use resizes it to the exact
    /// model width, so swapping models of a different encoded width
    /// through one scratch is safe.
    slices: Vec<u64>,
    /// bit-sliced H3 accumulators: `[(f*k + j) * out_bits + b]`
    hash_slices: Vec<u64>,
    /// per-sample table index for one (filter, hash) during the probe
    idx: Vec<u32>,
    /// per-sample accumulated class mask for one filter
    masks: Vec<u32>,
    /// tile-sized i32 response staging for the f32 write-into path
    /// ([`FlatModel::responses_batch_fused_into`]) — ≤ 64 × classes
    resp: Vec<i32>,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth_uci::{synth_uci, uci_spec};
    use crate::model::ensemble::EnsembleScratch;
    use crate::train::oneshot::{train_oneshot, OneShotConfig};
    use crate::train::prune::prune_model;

    #[test]
    fn flat_matches_reference_responses_exactly() {
        let ds = synth_uci(3, uci_spec("vowel").unwrap());
        let (mut model, _) = train_oneshot(
            &ds,
            &OneShotConfig { inputs_per_filter: 10, entries_per_filter: 128, therm_bits: 6, ..Default::default() },
        );
        // include pruning + bias to exercise the keep/bias paths
        prune_model(&mut model, &ds, 0.3);
        let flat = FlatModel::compile(&model);
        let mut s = EnsembleScratch::default();
        let mut fs = FlatScratch::default();
        let mut out = vec![0i32; model.num_classes()];
        for i in 0..ds.n_test() {
            let enc = model.encoder.encode(ds.test_row(i));
            let want = model.responses_encoded(&enc, &mut s).to_vec();
            out.iter_mut().for_each(|x| *x = 0);
            flat.responses_encoded(&enc, &mut fs, &mut out);
            assert_eq!(out, want, "sample {i}");
        }
    }

    #[test]
    fn batch_kernel_matches_scalar_path_bit_exactly() {
        let ds = synth_uci(11, uci_spec("vowel").unwrap());
        let (mut model, _) = train_oneshot(
            &ds,
            &OneShotConfig { inputs_per_filter: 10, entries_per_filter: 128, therm_bits: 6, ..Default::default() },
        );
        prune_model(&mut model, &ds, 0.25); // exercise pruned slots + bias
        let flat = FlatModel::compile(&model);
        let m = model.num_classes();
        let mut fs = FlatScratch::default();
        let mut bs = FlatBatchScratch::default();
        // batch sizes straddling the 64-sample tile boundary, plus empty
        for n in [0usize, 1, 63, 64, 65, 130] {
            let n = n.min(ds.n_test());
            let encoded: Vec<_> =
                (0..n).map(|i| model.encoder.encode(ds.test_row(i))).collect();
            let mut got = vec![0i32; n * m];
            flat.responses_batch(&encoded, &mut bs, &mut got);
            for (i, enc) in encoded.iter().enumerate() {
                let mut want = vec![0i32; m];
                flat.responses_encoded(enc, &mut fs, &mut want);
                assert_eq!(&got[i * m..(i + 1) * m], &want[..], "n={n} sample {i}");
            }
        }
    }

    #[test]
    fn fused_batch_path_matches_encode_then_batch_kernel() {
        let ds = synth_uci(19, uci_spec("vowel").unwrap());
        let (mut model, _) = train_oneshot(
            &ds,
            &OneShotConfig { inputs_per_filter: 10, entries_per_filter: 128, therm_bits: 6, ..Default::default() },
        );
        prune_model(&mut model, &ds, 0.2);
        let flat = FlatModel::compile(&model);
        let m = model.num_classes();
        let mut bs_bv = FlatBatchScratch::default();
        let mut bs_fused = FlatBatchScratch::default();
        for n in [0usize, 1, 63, 64, 65, 130] {
            let n = n.min(ds.n_test());
            let x = &ds.test_x[..n * ds.num_features];
            let encoded: Vec<_> =
                (0..n).map(|i| model.encoder.encode(ds.test_row(i))).collect();
            let mut want = vec![0i32; n * m];
            flat.responses_batch(&encoded, &mut bs_bv, &mut want);
            let mut got = vec![0i32; n * m];
            flat.responses_batch_fused(&model.encoder, x, n, &mut bs_fused, &mut got);
            assert_eq!(got, want, "n={n}");
        }
    }

    #[test]
    fn fused_into_matches_i32_kernel_and_respects_the_prefix_contract() {
        let ds = synth_uci(23, uci_spec("vowel").unwrap());
        let (mut model, _) = train_oneshot(
            &ds,
            &OneShotConfig { inputs_per_filter: 10, entries_per_filter: 128, therm_bits: 5, ..Default::default() },
        );
        prune_model(&mut model, &ds, 0.2);
        let flat = FlatModel::compile(&model);
        let m = model.num_classes();
        let mut bs_i32 = FlatBatchScratch::default();
        let mut bs_f32 = FlatBatchScratch::default();
        const PAD: usize = 17;
        const SENTINEL: f32 = -4242.5;
        for n in [0usize, 1, 63, 64, 65, 130] {
            let n = n.min(ds.n_test());
            let x = &ds.test_x[..n * ds.num_features];
            let mut want = vec![0i32; n * m];
            flat.responses_batch_fused(&model.encoder, x, n, &mut bs_i32, &mut want);
            // dirty, oversized plane: the n*m prefix must be fully
            // overwritten, the suffix untouched
            let mut got = vec![SENTINEL; n * m + PAD];
            flat.responses_batch_fused_into(&model.encoder, x, n, &mut bs_f32, &mut got);
            for (i, (&g, &w)) in got.iter().zip(want.iter()).enumerate() {
                assert_eq!(g, w as f32, "n={n} slot {i}");
            }
            assert!(
                got[n * m..].iter().all(|&v| v == SENTINEL),
                "n={n}: the suffix beyond n*m must stay untouched"
            );
        }
    }

    #[test]
    fn forced_kernel_paths_match_scalar_bit_exactly_end_to_end() {
        let ds = synth_uci(29, uci_spec("vowel").unwrap());
        let (mut model, _) = train_oneshot(
            &ds,
            &OneShotConfig { inputs_per_filter: 10, entries_per_filter: 128, therm_bits: 6, ..Default::default() },
        );
        prune_model(&mut model, &ds, 0.2);
        let scalar = FlatModel::compile_with_kernel(&model, KernelPath::Scalar);
        assert_eq!(scalar.kernel_path(), KernelPath::Scalar);
        let m = model.num_classes();
        let mut bs_a = FlatBatchScratch::default();
        let mut bs_b = FlatBatchScratch::default();
        for path in KernelPath::all_supported() {
            let forced = FlatModel::compile_with_kernel(&model, path);
            assert_eq!(forced.kernel_path(), path, "supported paths must stick");
            for n in [1usize, 63, 64, 65, 130] {
                let n = n.min(ds.n_test());
                let x = &ds.test_x[..n * ds.num_features];
                let mut want = vec![0i32; n * m];
                scalar.responses_batch_fused(&model.encoder, x, n, &mut bs_a, &mut want);
                let mut got = vec![0i32; n * m];
                forced.responses_batch_fused(&model.encoder, x, n, &mut bs_b, &mut got);
                assert_eq!(got, want, "{} vs scalar at n={n}", path.label());
            }
        }
        // an unsupported forced path clamps to scalar instead of faulting
        let mut clamped = FlatModel::compile_with_kernel(&model, KernelPath::Scalar);
        for p in [KernelPath::Avx2, KernelPath::Neon] {
            clamped.set_kernel_path(p);
            assert!(clamped.kernel_path().is_supported());
        }
    }

    #[test]
    fn compile_rejects_more_than_32_classes_with_a_clear_error() {
        use crate::encoding::thermometer::ThermometerKind;
        use crate::model::submodel::Submodel;
        use crate::util::rng::Rng;
        let data: Vec<f32> = (0..400).map(|i| (i % 97) as f32).collect();
        let encoder = ThermometerEncoder::fit(ThermometerKind::Gaussian, &data, 8, 8);
        let cfg = SubmodelConfig {
            inputs_per_filter: 8,
            entries_per_filter: 64,
            k_hashes: 2,
            num_classes: 33, // one past the u32 class-mask capacity
            total_input_bits: 64,
        };
        let mut rng = Rng::new(5);
        let sm = Submodel::new_random(&mut rng, cfg);
        let model = UleenModel { name: "too-wide".into(), encoder, submodels: vec![sm] };
        let err = FlatModel::try_compile(&model).unwrap_err().to_string();
        assert!(err.contains("32-class capacity"), "got: {err}");
    }

    #[test]
    fn batch_kernel_handles_multi_submodel_ensembles() {
        let ds = synth_uci(13, uci_spec("wine").unwrap());
        let (a, _) = train_oneshot(
            &ds,
            &OneShotConfig { inputs_per_filter: 8, entries_per_filter: 64, therm_bits: 4, seed: 7, ..Default::default() },
        );
        let (b, _) = train_oneshot(
            &ds,
            &OneShotConfig { inputs_per_filter: 12, entries_per_filter: 256, therm_bits: 4, seed: 8, ..Default::default() },
        );
        let mut ens = a.clone();
        ens.submodels.extend(b.submodels.iter().cloned());
        let flat = FlatModel::compile(&ens);
        let m = ens.num_classes();
        let n = ds.n_test();
        let encoded: Vec<_> = (0..n).map(|i| ens.encoder.encode(ds.test_row(i))).collect();
        let mut bs = FlatBatchScratch::default();
        let mut got = vec![0i32; n * m];
        flat.responses_batch(&encoded, &mut bs, &mut got);
        let mut es = EnsembleScratch::default();
        for (i, enc) in encoded.iter().enumerate() {
            let want = ens.responses_encoded(enc, &mut es);
            assert_eq!(&got[i * m..(i + 1) * m], want, "sample {i}");
        }
    }

    #[test]
    fn flat_predictions_match_for_multi_submodel_models() {
        let ds = synth_uci(9, uci_spec("wine").unwrap());
        let (a, _) = train_oneshot(
            &ds,
            &OneShotConfig { inputs_per_filter: 8, entries_per_filter: 64, therm_bits: 4, seed: 1, ..Default::default() },
        );
        let (b, _) = train_oneshot(
            &ds,
            &OneShotConfig { inputs_per_filter: 12, entries_per_filter: 128, therm_bits: 4, seed: 2, ..Default::default() },
        );
        let mut ens = a.clone();
        ens.submodels.extend(b.submodels.iter().cloned());
        let flat = FlatModel::compile(&ens);
        let mut s = EnsembleScratch::default();
        let mut scratch = FlatScratch::default();
        for i in 0..ds.n_test() {
            let enc = ens.encoder.encode(ds.test_row(i));
            assert_eq!(
                flat.predict_encoded(&enc, &mut scratch),
                ens.predict_encoded(&enc, &mut s)
            );
        }
    }
}
