//! Flat compiled model — the §Perf-optimized inference representation.
//!
//! `UleenModel` keeps each filter's table as its own heap allocation
//! (ergonomic for training/pruning, terrible for the inference cache):
//! profiling showed the lookup stage dominating the hot path (~70% of
//! per-sample time) with pointer-chasing through `Vec<Option<BinaryBloom>>`.
//!
//! [`FlatModel::compile`] re-lays every submodel into single contiguous
//! buffers with **filter-major, class-minor** order — all classes' table
//! words for a filter are adjacent, matching the traversal order of the
//! response loop (hash filter once → probe every class). Pruned filters
//! become all-zero table slots plus a keep-bit, so the inner loop is
//! branchless on structure. Semantics are identical to the reference path
//! (asserted by tests and the cross-engine integration suite).

use crate::model::ensemble::UleenModel;
use crate::model::submodel::SubmodelConfig;
use crate::util::bitvec::BitVec;

/// One submodel compiled to flat arrays.
///
/// The table storage is TRANSPOSED relative to the hardware's per-
/// discriminator view: `class_masks[f * E + e]` is a bitmask over classes
/// — bit `c` set iff discriminator `c`'s filter `f` is kept AND its table
/// entry `e` is 1. One probe then costs ONE u32 load for all classes
/// (instead of `classes` separate random loads), and the AND-over-k probes
/// is a single word AND. Pruning folds into the masks for free.
pub struct FlatSubmodel {
    pub cfg: SubmodelConfig,
    pub input_order: Vec<u32>,
    /// H3 params flattened: [k][n] row-major (k rows of n params).
    pub hash_params: Vec<u64>,
    pub k: usize,
    /// class-mask bitplanes, layout [filter][entry] (supports ≤32 classes)
    pub class_masks: Vec<u32>,
    pub bias: Vec<i32>,
    /// Scatter-hash CSR (§Perf v3): instead of gathering every key bit,
    /// iterate the SET bits of the encoded input once and XOR their hash
    /// contributions into per-filter accumulators. `csr_off[src]..csr_off
    /// [src+1]` indexes entries of `(filter, k params)` for input bit `src`
    /// — H3 linearity makes the order irrelevant.
    pub csr_off: Vec<u32>,
    /// filter index per entry
    pub csr_filter: Vec<u32>,
    /// k hash-param words per entry (stride k, aligned with csr_filter)
    pub csr_params: Vec<u64>,
}

/// A compiled inference-only model.
pub struct FlatModel {
    pub submodels: Vec<FlatSubmodel>,
    pub num_classes: usize,
}

impl FlatModel {
    pub fn compile(model: &UleenModel) -> Self {
        let m = model.num_classes();
        assert!(m <= 32, "flat engine supports up to 32 classes");
        let submodels = model
            .submodels
            .iter()
            .map(|sm| {
                let nf = sm.cfg.num_filters();
                let e = sm.cfg.entries_per_filter;
                let mut class_masks = vec![0u32; nf * e];
                for (c, disc) in sm.discriminators.iter().enumerate() {
                    for (f, filt) in disc.filters.iter().enumerate() {
                        if let Some(filt) = filt {
                            for entry in 0..e {
                                if filt.table.get(entry) {
                                    class_masks[f * e + entry] |= 1 << c;
                                }
                            }
                        }
                    }
                }
                let k = sm.cfg.k_hashes;
                let n = sm.cfg.inputs_per_filter;
                let mut hash_params = vec![0u64; k * n];
                for (j, h) in sm.hash.fns.iter().enumerate() {
                    hash_params[j * n..(j + 1) * n].copy_from_slice(&h.params);
                }
                // Build the scatter CSR: slot s = f*n + i reads input bit
                // input_order[s] and contributes params_j[i] to filter f's
                // j-th hash.
                let total_bits = sm.cfg.total_input_bits;
                let mut per_src: Vec<Vec<(u32, Vec<u64>)>> = vec![Vec::new(); total_bits];
                for f in 0..nf {
                    for i in 0..n {
                        let src = sm.input_order[f * n + i] as usize;
                        let ps: Vec<u64> =
                            (0..k).map(|j| hash_params[j * n + i]).collect();
                        per_src[src].push((f as u32, ps));
                    }
                }
                let mut csr_off = Vec::with_capacity(total_bits + 1);
                let mut csr_filter = Vec::new();
                let mut csr_params = Vec::new();
                csr_off.push(0u32);
                for src in 0..total_bits {
                    for (f, ps) in &per_src[src] {
                        csr_filter.push(*f);
                        csr_params.extend_from_slice(ps);
                    }
                    csr_off.push(csr_filter.len() as u32);
                }
                FlatSubmodel {
                    cfg: sm.cfg,
                    input_order: sm.input_order.clone(),
                    hash_params,
                    k,
                    class_masks,
                    bias: sm.bias.clone(),
                    csr_off,
                    csr_filter,
                    csr_params,
                }
            })
            .collect();
        Self { submodels, num_classes: m }
    }

    /// Per-class responses for an encoded input, accumulated into `out`
    /// (caller zeroes). `scratch` holds the per-filter hash accumulators
    /// (no allocation after warmup).
    ///
    /// §Perf v3 scatter-hash: H3 is linear, so instead of gathering `n`
    /// bits per filter we stream the encoded input's SET bits once and XOR
    /// each bit's precomputed contribution into its filter's `k` hash
    /// accumulators (sequential CSR reads, work ∝ set bits ≈ I/2). The
    /// class-mask probe then collapses the per-class Bloom AND into one
    /// u32 AND per hash.
    pub fn responses_encoded(
        &self,
        encoded: &BitVec,
        scratch: &mut FlatScratch,
        out: &mut [i32],
    ) {
        debug_assert_eq!(out.len(), self.num_classes);
        let m = self.num_classes;
        let enc_words = encoded.words();
        for sm in &self.submodels {
            let e = sm.cfg.entries_per_filter;
            let nf = sm.cfg.num_filters();
            let k = sm.k;
            scratch.h.clear();
            scratch.h.resize(nf * k, 0);
            let h = &mut scratch.h[..];
            // stream set bits of the encoded input
            for (w_idx, &w) in enc_words.iter().enumerate() {
                let mut w = w;
                while w != 0 {
                    let bit = w.trailing_zeros() as usize;
                    w &= w - 1;
                    let src = (w_idx << 6) | bit;
                    let lo = unsafe { *sm.csr_off.get_unchecked(src) } as usize;
                    let hi = unsafe { *sm.csr_off.get_unchecked(src + 1) } as usize;
                    for t in lo..hi {
                        let f = unsafe { *sm.csr_filter.get_unchecked(t) } as usize;
                        let pbase = t * k;
                        for j in 0..k {
                            unsafe {
                                *h.get_unchecked_mut(f * k + j) ^=
                                    *sm.csr_params.get_unchecked(pbase + j);
                            }
                        }
                    }
                }
            }
            // probe class masks per filter
            for f in 0..nf {
                let mut mask = u32::MAX;
                for j in 0..k {
                    let idx = unsafe { *h.get_unchecked(f * k + j) } as usize;
                    mask &= unsafe { *sm.class_masks.get_unchecked(f * e + idx) };
                }
                for (c, o) in out.iter_mut().enumerate().take(m) {
                    *o += ((mask >> c) & 1) as i32;
                }
            }
            for c in 0..m {
                out[c] += sm.bias[c];
            }
        }
    }

    /// Argmax prediction from an encoded input (ties break low).
    pub fn predict_encoded(&self, encoded: &BitVec, scratch: &mut FlatScratch) -> usize {
        scratch.resp.clear();
        scratch.resp.resize(self.num_classes, 0);
        let mut resp = std::mem::take(&mut scratch.resp);
        self.responses_encoded(encoded, scratch, &mut resp);
        let mut best = 0usize;
        for (c, &r) in resp.iter().enumerate() {
            if r > resp[best] {
                best = c;
            }
        }
        scratch.resp = resp;
        best
    }
}

/// Reusable scratch for [`FlatModel`] inference.
#[derive(Default)]
pub struct FlatScratch {
    /// per-filter hash accumulators (nf × k)
    pub h: Vec<u64>,
    pub resp: Vec<i32>,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth_uci::{synth_uci, uci_spec};
    use crate::model::ensemble::EnsembleScratch;
    use crate::train::oneshot::{train_oneshot, OneShotConfig};
    use crate::train::prune::prune_model;

    #[test]
    fn flat_matches_reference_responses_exactly() {
        let ds = synth_uci(3, uci_spec("vowel").unwrap());
        let (mut model, _) = train_oneshot(
            &ds,
            &OneShotConfig { inputs_per_filter: 10, entries_per_filter: 128, therm_bits: 6, ..Default::default() },
        );
        // include pruning + bias to exercise the keep/bias paths
        prune_model(&mut model, &ds, 0.3);
        let flat = FlatModel::compile(&model);
        let mut s = EnsembleScratch::default();
        let mut fs = FlatScratch::default();
        let mut out = vec![0i32; model.num_classes()];
        for i in 0..ds.n_test() {
            let enc = model.encoder.encode(ds.test_row(i));
            let want = model.responses_encoded(&enc, &mut s).to_vec();
            out.iter_mut().for_each(|x| *x = 0);
            flat.responses_encoded(&enc, &mut fs, &mut out);
            assert_eq!(out, want, "sample {i}");
        }
    }

    #[test]
    fn flat_predictions_match_for_multi_submodel_models() {
        let ds = synth_uci(9, uci_spec("wine").unwrap());
        let (a, _) = train_oneshot(
            &ds,
            &OneShotConfig { inputs_per_filter: 8, entries_per_filter: 64, therm_bits: 4, seed: 1, ..Default::default() },
        );
        let (b, _) = train_oneshot(
            &ds,
            &OneShotConfig { inputs_per_filter: 12, entries_per_filter: 128, therm_bits: 4, seed: 2, ..Default::default() },
        );
        let mut ens = a.clone();
        ens.submodels.extend(b.submodels.iter().cloned());
        let flat = FlatModel::compile(&ens);
        let mut s = EnsembleScratch::default();
        let mut scratch = FlatScratch::default();
        for i in 0..ds.n_test() {
            let enc = ens.encoder.encode(ds.test_row(i));
            assert_eq!(
                flat.predict_encoded(&enc, &mut scratch),
                ens.predict_encoded(&enc, &mut s)
            );
        }
    }
}
