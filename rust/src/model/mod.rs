//! The ULEEN model core (paper §III) plus the two prior-work baselines it
//! is measured against:
//!
//! * [`submodel`] / [`ensemble`] — the ULEEN model: ensembles of WNN
//!   submodels whose RAM nodes are Bloom filters with shared H3 hashing.
//! * [`wisard`] — classic WiSARD (1981): direct 2^n-entry RAM nodes.
//! * [`bloom_wisard`] — Bloom WiSARD (2019): Bloom-filter RAM nodes with
//!   MurmurHash double hashing and *no* bleaching — the state of the art
//!   ULEEN improves on (Table IV, Fig 10).
//! * [`uln_format`] — the `.uln` binary interchange format shared with the
//!   Python compile path.
//! * [`simd`] — runtime-dispatched SIMD tiers (AVX2/NEON/scalar) for the
//!   [`flat`] engine's bit-sliced tile kernel.

pub mod bloom_wisard;
pub mod ensemble;
pub mod flat;
pub mod simd;
pub mod submodel;
pub mod uln_format;
pub mod wisard;

pub use ensemble::UleenModel;
pub use submodel::{Discriminator, Submodel, SubmodelConfig, SubmodelScratch};
