//! Bloom WiSARD (de Araújo et al., 2019) — the state-of-the-art memory-
//! efficient WNN that ULEEN is compared against in Table IV and Fig 10.
//!
//! Faithful to the original: binary Bloom filters addressed by
//! Kirsch–Mitzenmacher double hashing over MurmurHash3, one-shot set-on-
//! seen training, **no bleaching** (which is exactly why it saturates on
//! skewed data like Shuttle — paper §V-E).

use crate::encoding::thermometer::ThermometerEncoder;
use crate::hash::murmur::DoubleHash;
use crate::util::bitvec::BitVec;
use crate::util::rng::Rng;
use crate::util::stats::Confusion;

/// A Bloom WiSARD model.
#[derive(Clone, Debug)]
pub struct BloomWisard {
    pub inputs_per_filter: usize,
    pub entries_per_filter: usize,
    pub num_classes: usize,
    pub total_input_bits: usize,
    pub input_order: Vec<u32>,
    pub hash: DoubleHash,
    /// tables[class][filter] — bit-packed Bloom tables.
    pub tables: Vec<Vec<BitVec>>,
    pub encoder: ThermometerEncoder,
}

impl BloomWisard {
    pub fn num_filters(&self) -> usize {
        self.total_input_bits.div_ceil(self.inputs_per_filter)
    }

    pub fn new(
        rng: &mut Rng,
        encoder: ThermometerEncoder,
        inputs_per_filter: usize,
        entries_per_filter: usize,
        k_hashes: usize,
        num_classes: usize,
    ) -> Self {
        let total_input_bits = encoder.encoded_bits();
        let cfg = crate::model::submodel::SubmodelConfig {
            inputs_per_filter,
            entries_per_filter,
            k_hashes,
            num_classes,
            total_input_bits,
        };
        let input_order = crate::model::submodel::Submodel::make_input_order(rng, &cfg);
        let nf = total_input_bits.div_ceil(inputs_per_filter);
        let tables = (0..num_classes)
            .map(|_| (0..nf).map(|_| BitVec::zeros(entries_per_filter)).collect())
            .collect();
        let hash = DoubleHash::new(k_hashes, entries_per_filter as u32, rng.next_u32());
        Self {
            inputs_per_filter,
            entries_per_filter,
            num_classes,
            total_input_bits,
            input_order,
            hash,
            tables,
            encoder,
        }
    }

    fn keys(&self, encoded: &BitVec, keys: &mut Vec<u64>) {
        let n = self.inputs_per_filter;
        keys.clear();
        for f in 0..self.num_filters() {
            let mut key = 0u64;
            for i in 0..n {
                let src = self.input_order[f * n + i] as usize;
                key |= (encoded.get(src) as u64) << i;
            }
            keys.push(key);
        }
    }

    pub fn train_sample(&mut self, sample: &[f32], label: usize) {
        let encoded = self.encoder.encode(sample);
        let mut keys = Vec::new();
        self.keys(&encoded, &mut keys);
        let mut idxs = vec![0u32; self.hash.k];
        for (f, &key) in keys.iter().enumerate() {
            self.hash.indices(key, &mut idxs);
            for &i in &idxs {
                self.tables[label][f].set(i as usize);
            }
        }
    }

    pub fn train(&mut self, xs: &[f32], ys: &[u16], num_features: usize) {
        for (i, &y) in ys.iter().enumerate() {
            self.train_sample(&xs[i * num_features..(i + 1) * num_features], y as usize);
        }
    }

    pub fn predict(&self, sample: &[f32]) -> usize {
        let encoded = self.encoder.encode(sample);
        let mut keys = Vec::new();
        self.keys(&encoded, &mut keys);
        let mut idxs = vec![0u32; self.hash.k];
        let mut resp = Vec::with_capacity(self.num_classes);
        for c in 0..self.num_classes {
            let mut acc = 0i32;
            for (f, &key) in keys.iter().enumerate() {
                self.hash.indices(key, &mut idxs);
                if idxs.iter().all(|&i| self.tables[c][f].get(i as usize)) {
                    acc += 1;
                }
            }
            resp.push(acc);
        }
        crate::util::argmax_tie_low(&resp)
    }

    pub fn evaluate(&self, xs: &[f32], ys: &[u16], num_features: usize) -> Confusion {
        let mut conf = Confusion::new(self.num_classes);
        for (i, &y) in ys.iter().enumerate() {
            let p = self.predict(&xs[i * num_features..(i + 1) * num_features]);
            conf.record(y as usize, p);
        }
        conf
    }

    pub fn size_kib(&self) -> f64 {
        (self.num_classes * self.num_filters() * self.entries_per_filter) as f64 / 8.0 / 1024.0
    }

    /// Mean table occupancy — diagnoses saturation (paper §V-E).
    pub fn mean_fill(&self) -> f64 {
        let mut ones = 0usize;
        let mut total = 0usize;
        for class in &self.tables {
            for t in class {
                ones += t.count_ones();
                total += t.len();
            }
        }
        ones as f64 / total as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::encoding::thermometer::ThermometerKind;

    fn encoder() -> ThermometerEncoder {
        let data: Vec<f32> = (0..600).map(|i| (i % 100) as f32).collect();
        ThermometerEncoder::fit(ThermometerKind::Linear, &data, 6, 4)
    }

    #[test]
    fn recalls_training_samples() {
        let mut rng = Rng::new(1);
        let mut m = BloomWisard::new(&mut rng, encoder(), 8, 128, 2, 3);
        let samples: Vec<Vec<f32>> = vec![
            vec![5.0, 10.0, 90.0, 20.0, 30.0, 70.0],
            vec![90.0, 80.0, 10.0, 60.0, 5.0, 15.0],
            vec![30.0, 70.0, 20.0, 80.0, 95.0, 45.0],
        ];
        for (c, s) in samples.iter().enumerate() {
            m.train_sample(s, c);
        }
        for (c, s) in samples.iter().enumerate() {
            assert_eq!(m.predict(s), c);
        }
    }

    #[test]
    fn no_false_negatives_vs_direct_ram() {
        // Bloom response must be a superset of direct-RAM response: a
        // trained pattern always responds 1 (FPs allowed, FNs not).
        let mut rng = Rng::new(2);
        let mut m = BloomWisard::new(&mut rng, encoder(), 6, 64, 2, 2);
        let mut r = Rng::new(3);
        let samples: Vec<Vec<f32>> = (0..40)
            .map(|_| (0..6).map(|_| r.below(100) as f32).collect())
            .collect();
        for s in &samples {
            m.train_sample(s, 0);
        }
        // every trained sample gives the maximum response for class 0
        for s in &samples {
            let encoded = m.encoder.encode(s);
            let mut keys = Vec::new();
            m.keys(&encoded, &mut keys);
            let mut idxs = vec![0u32; m.hash.k];
            for (f, &key) in keys.iter().enumerate() {
                m.hash.indices(key, &mut idxs);
                assert!(
                    idxs.iter().all(|&i| m.tables[0][f].get(i as usize)),
                    "false negative"
                );
            }
        }
    }

    #[test]
    fn smaller_than_classic_wisard() {
        let mut rng = Rng::new(4);
        let m = BloomWisard::new(&mut rng, encoder(), 16, 256, 2, 3);
        // classic 16-input RAM node would be 65536 bits; bloom uses 256
        assert!(m.size_kib() < 1.0);
    }
}
