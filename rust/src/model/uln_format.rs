//! `.uln` — the binary model interchange format between the Python compile
//! path (multi-shot trained models, `python/compile/uln.py`) and this
//! crate's native engine. Little-endian throughout.
//!
//! Layout:
//! ```text
//! magic "ULN1" | u32 version=1
//! u32 encoder_kind (0=linear, 1=gaussian) | u32 num_inputs | u32 bits_per_input
//! f32 thresholds[num_inputs * bits]
//! u32 num_submodels
//! per submodel:
//!   u32 inputs_per_filter | u32 entries_per_filter | u32 k_hashes
//!   u32 num_classes | u32 num_filters
//!   u32 input_order[num_filters * inputs_per_filter]
//!   u64 hash_params[k_hashes * inputs_per_filter]
//!   i32 bias[num_classes]
//!   per class:
//!     u8 keep[num_filters]
//!     for each kept filter: entries/8 bytes, LSB-first bit order
//! u32 meta_len | meta JSON bytes
//! u64 FNV-1a checksum of everything before it
//! ```
//!
//! **Hostile input:** the checksum only catches *accidental* corruption
//! — an adversarial author forges a valid checksum trivially, so the
//! parser itself must stay safe. Every count field is bounded before it
//! sizes an allocation (`k_hashes ≤ 16`, `num_classes ≤ 4096` for
//! plausibility and ≤ 32 for the flat engine's u32 class-mask capacity,
//! `entries_per_filter ≤ 2^24`, encoder dims ≤ 2^26 bits), and every
//! large buffer is preceded by a remaining-byte check
//! ([`Reader::need`]) so a forged header can never make `load` allocate
//! more than ~the file's own size. Truncation, absurd counts and
//! checksum mismatch all return `Err` — never a panic, never an OOM.

use crate::encoding::thermometer::{ThermometerEncoder, ThermometerKind};
use crate::hash::h3::{H3Family, H3Hash};
use crate::model::ensemble::UleenModel;
use crate::model::submodel::{Discriminator, Submodel, SubmodelConfig};
use crate::bloom::binary::BinaryBloom;
use crate::util::bitvec::BitVec;
use crate::util::json::Json;
use anyhow::{bail, Context, Result};
use std::path::Path;

const MAGIC: &[u8; 4] = b"ULN1";

fn fnv1a(bytes: &[u8]) -> u64 {
    bytes.iter().fold(0xcbf29ce484222325u64, |h, &b| {
        (h ^ b as u64).wrapping_mul(0x100000001b3)
    })
}

struct Writer {
    buf: Vec<u8>,
}

impl Writer {
    fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    fn i32(&mut self, v: i32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    fn u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    fn f32(&mut self, v: f32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
}

/// Serialize a model (with optional metadata JSON) to bytes.
pub fn to_bytes(model: &UleenModel, meta: &Json) -> Vec<u8> {
    let mut w = Writer { buf: Vec::new() };
    w.buf.extend_from_slice(MAGIC);
    w.u32(1);
    w.u32(match model.encoder.kind {
        ThermometerKind::Linear => 0,
        ThermometerKind::Gaussian => 1,
    });
    w.u32(model.encoder.num_inputs as u32);
    w.u32(model.encoder.bits as u32);
    for &t in &model.encoder.thresholds {
        w.f32(t);
    }
    w.u32(model.submodels.len() as u32);
    for sm in &model.submodels {
        w.u32(sm.cfg.inputs_per_filter as u32);
        w.u32(sm.cfg.entries_per_filter as u32);
        w.u32(sm.cfg.k_hashes as u32);
        w.u32(sm.cfg.num_classes as u32);
        w.u32(sm.cfg.num_filters() as u32);
        for &o in &sm.input_order {
            w.u32(o);
        }
        for f in &sm.hash.fns {
            for &p in &f.params {
                w.u64(p);
            }
        }
        for &b in &sm.bias {
            w.i32(b);
        }
        let table_bytes = sm.cfg.entries_per_filter / 8;
        for disc in &sm.discriminators {
            for f in &disc.filters {
                w.buf.push(f.is_some() as u8);
            }
            for f in disc.filters.iter().flatten() {
                let bytes = f.table.to_le_bytes();
                w.buf.extend_from_slice(&bytes[..table_bytes]);
            }
        }
    }
    let meta_bytes = meta.to_string().into_bytes();
    w.u32(meta_bytes.len() as u32);
    w.buf.extend_from_slice(&meta_bytes);
    let sum = fnv1a(&w.buf);
    w.u64(sum);
    w.buf
}

pub fn save(model: &UleenModel, meta: &Json, path: &Path) -> Result<()> {
    std::fs::write(path, to_bytes(model, meta))
        .with_context(|| format!("write {}", path.display()))
}

struct Reader<'a> {
    b: &'a [u8],
    off: usize,
}

impl<'a> Reader<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if n > self.b.len() - self.off {
            bail!("truncated .uln at offset {}", self.off);
        }
        let s = &self.b[self.off..self.off + n];
        self.off += n;
        Ok(s)
    }

    /// Pre-allocation guard: verify `n` bytes remain BEFORE a
    /// header-sized `Vec::with_capacity` — a forged-but-checksummed
    /// count must not reserve memory the buffer cannot even back.
    fn need(&self, n: usize, what: &str) -> Result<()> {
        if n > self.b.len() - self.off {
            bail!(
                "truncated .uln: {what} wants {n} bytes at offset {}, {} remain",
                self.off,
                self.b.len() - self.off
            );
        }
        Ok(())
    }
    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }
    fn i32(&mut self) -> Result<i32> {
        Ok(i32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }
    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }
    fn f32(&mut self) -> Result<f32> {
        Ok(f32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }
}

/// Deserialize a model (+ metadata) from bytes.
pub fn from_bytes(bytes: &[u8], name: &str) -> Result<(UleenModel, Json)> {
    if bytes.len() < 12 {
        bail!("file too small for .uln");
    }
    let (body, sum_bytes) = bytes.split_at(bytes.len() - 8);
    let stored = u64::from_le_bytes(sum_bytes.try_into().unwrap());
    let actual = fnv1a(body);
    if stored != actual {
        bail!(".uln checksum mismatch: stored {stored:#x}, computed {actual:#x}");
    }
    let mut r = Reader { b: body, off: 0 };
    if r.take(4)? != MAGIC {
        bail!("bad .uln magic");
    }
    let version = r.u32()?;
    if version != 1 {
        bail!("unsupported .uln version {version}");
    }
    let kind = match r.u32()? {
        0 => ThermometerKind::Linear,
        1 => ThermometerKind::Gaussian,
        k => bail!("bad encoder kind {k}"),
    };
    let num_inputs = r.u32()? as usize;
    let bits = r.u32()? as usize;
    // u64 math: both fields are attacker-controlled u32s, so the product
    // must not be trusted to fit usize before the bound check
    if num_inputs == 0 || bits == 0 || (num_inputs as u64) * (bits as u64) > 1 << 26 {
        bail!("implausible encoder dims {num_inputs}x{bits}");
    }
    r.need(num_inputs * bits * 4, "thresholds")?;
    let mut thresholds = Vec::with_capacity(num_inputs * bits);
    for _ in 0..num_inputs * bits {
        thresholds.push(r.f32()?);
    }
    let encoder = ThermometerEncoder { kind, num_inputs, bits, thresholds };
    let num_submodels = r.u32()? as usize;
    if num_submodels == 0 || num_submodels > 64 {
        bail!("implausible submodel count {num_submodels}");
    }
    let mut submodels = Vec::with_capacity(num_submodels);
    for si in 0..num_submodels {
        let inputs_per_filter = r.u32()? as usize;
        let entries_per_filter = r.u32()? as usize;
        let k_hashes = r.u32()? as usize;
        let num_classes = r.u32()? as usize;
        let num_filters = r.u32()? as usize;
        if !entries_per_filter.is_power_of_two()
            || !(8..=1 << 24).contains(&entries_per_filter)
        {
            bail!("submodel {si}: bad table size {entries_per_filter}");
        }
        if inputs_per_filter == 0 || inputs_per_filter > 64 {
            bail!("submodel {si}: bad inputs/filter {inputs_per_filter}");
        }
        if k_hashes == 0 || k_hashes > 16 {
            bail!("submodel {si}: implausible hash count {k_hashes}");
        }
        if num_classes == 0 || num_classes > 4096 {
            bail!("submodel {si}: implausible class count {num_classes}");
        }
        // Distinct from the plausibility bound above: the flat engine packs
        // one bit per class into width-adaptive (u8/u16/u32) class-mask
        // planes, so every serving path tops out at 32 classes. Reject at
        // load time — not deep in `FlatModel` compile — so a bad artifact
        // fails before allocation.
        if num_classes > 32 {
            bail!(
                "submodel {si}: {num_classes} classes exceed the 32-class capacity \
                 of the flat engine's class-mask planes (u32 at the widest)"
            );
        }
        let cfg = SubmodelConfig {
            inputs_per_filter,
            entries_per_filter,
            k_hashes,
            num_classes,
            total_input_bits: num_inputs * bits,
        };
        if cfg.num_filters() != num_filters {
            bail!(
                "submodel {si}: filter count {num_filters} inconsistent with ceil({}/{})",
                cfg.total_input_bits,
                inputs_per_filter
            );
        }
        r.need(num_filters * inputs_per_filter * 4, "input_order")?;
        let mut input_order = Vec::with_capacity(num_filters * inputs_per_filter);
        for _ in 0..num_filters * inputs_per_filter {
            let o = r.u32()?;
            if o as usize >= cfg.total_input_bits {
                bail!("submodel {si}: input_order entry {o} out of range");
            }
            input_order.push(o);
        }
        let out_bits = cfg.out_bits();
        let mask = (1u64 << out_bits) - 1;
        let mut fns = Vec::with_capacity(k_hashes);
        for _ in 0..k_hashes {
            let mut params = Vec::with_capacity(inputs_per_filter);
            for _ in 0..inputs_per_filter {
                let p = r.u64()?;
                if p & !mask != 0 {
                    bail!("submodel {si}: hash param exceeds out_bits");
                }
                params.push(p);
            }
            fns.push(H3Hash { params, out_bits });
        }
        let mut bias = Vec::with_capacity(num_classes);
        for _ in 0..num_classes {
            bias.push(r.i32()?);
        }
        let table_bytes = entries_per_filter / 8;
        let mut discriminators = Vec::with_capacity(num_classes);
        for _ in 0..num_classes {
            let keep = r.take(num_filters)?.to_vec();
            let mut filters = Vec::with_capacity(num_filters);
            for &kept in &keep {
                if kept != 0 {
                    let raw = r.take(table_bytes)?;
                    let mut padded = raw.to_vec();
                    padded.resize(table_bytes.div_ceil(8) * 8, 0);
                    let table = BitVec::from_le_bytes(&padded, entries_per_filter);
                    filters.push(Some(BinaryBloom { table }));
                } else {
                    filters.push(None);
                }
            }
            discriminators.push(Discriminator { filters });
        }
        submodels.push(Submodel {
            cfg,
            input_order,
            hash: H3Family { fns },
            discriminators,
            bias,
        });
    }
    let meta_len = r.u32()? as usize;
    let meta_bytes = r.take(meta_len)?;
    if r.off != body.len() {
        bail!("trailing bytes in .uln body");
    }
    let meta = Json::parse(std::str::from_utf8(meta_bytes)?)
        .map_err(|e| anyhow::anyhow!("bad .uln metadata: {e}"))?;
    let model_name = meta
        .get("name")
        .and_then(|j| j.as_str())
        .unwrap_or(name)
        .to_string();
    let model = UleenModel { name: model_name, encoder, submodels };
    model.validate().map_err(|e| anyhow::anyhow!(e))?;
    Ok((model, meta))
}

pub fn load(path: &Path) -> Result<(UleenModel, Json)> {
    let bytes = std::fs::read(path).with_context(|| format!("read {}", path.display()))?;
    let stem = path.file_stem().and_then(|s| s.to_str()).unwrap_or("model");
    from_bytes(&bytes, stem)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::encoding::thermometer::ThermometerKind;
    use crate::util::rng::Rng;

    fn sample_model() -> UleenModel {
        let data: Vec<f32> = (0..400).map(|i| (i % 97) as f32).collect();
        let encoder = ThermometerEncoder::fit(ThermometerKind::Gaussian, &data, 8, 4);
        let mut rng = Rng::new(17);
        let cfg = SubmodelConfig {
            inputs_per_filter: 8,
            entries_per_filter: 32,
            k_hashes: 2,
            num_classes: 3,
            total_input_bits: 32,
        };
        let mut submodels = Vec::new();
        for _ in 0..2 {
            let mut sm = Submodel::new_random(&mut rng, cfg);
            // random tables, a pruned filter and nonzero bias for coverage
            for d in &mut sm.discriminators {
                for f in d.filters.iter_mut() {
                    let filt = f.as_mut().unwrap();
                    for i in 0..filt.entries() {
                        if rng.below(3) == 0 {
                            filt.table.set(i);
                        }
                    }
                }
            }
            sm.discriminators[1].filters[2] = None;
            sm.bias = vec![1, -2, 3];
            submodels.push(sm);
        }
        UleenModel { name: "roundtrip".into(), encoder, submodels }
    }

    #[test]
    fn roundtrip_preserves_everything() {
        let m = sample_model();
        let mut meta = Json::obj();
        meta.set("name", Json::Str("roundtrip".into()))
            .set("accuracy", Json::Num(0.91));
        let bytes = to_bytes(&m, &meta);
        let (back, meta2) = from_bytes(&bytes, "x").unwrap();
        assert_eq!(back.name, "roundtrip");
        assert_eq!(meta2.get("accuracy").unwrap().as_f64(), Some(0.91));
        assert_eq!(back.submodels.len(), 2);
        assert_eq!(back.encoder.thresholds, m.encoder.thresholds);
        for (a, b) in m.submodels.iter().zip(back.submodels.iter()) {
            assert_eq!(a.cfg, b.cfg);
            assert_eq!(a.input_order, b.input_order);
            assert_eq!(a.hash, b.hash);
            assert_eq!(a.bias, b.bias);
            for (da, db) in a.discriminators.iter().zip(b.discriminators.iter()) {
                for (fa, fb) in da.filters.iter().zip(db.filters.iter()) {
                    assert_eq!(fa, fb);
                }
            }
        }
    }

    #[test]
    fn predictions_survive_roundtrip() {
        let m = sample_model();
        let bytes = to_bytes(&m, &Json::obj());
        let (back, _) = from_bytes(&bytes, "x").unwrap();
        let mut s1 = crate::model::ensemble::EnsembleScratch::default();
        let mut s2 = crate::model::ensemble::EnsembleScratch::default();
        let mut rng = Rng::new(8);
        for _ in 0..50 {
            let sample: Vec<f32> = (0..8).map(|_| rng.below(97) as f32).collect();
            assert_eq!(m.predict(&sample, &mut s1), back.predict(&sample, &mut s2));
        }
    }

    #[test]
    fn a_33_class_artifact_is_rejected_at_load_time() {
        // Build a structurally valid 33-class model — within the 4096
        // plausibility bound but past the flat engine's u32 class-mask
        // capacity — and assert the loader names the real limit.
        let data: Vec<f32> = (0..400).map(|i| (i % 97) as f32).collect();
        let encoder = ThermometerEncoder::fit(ThermometerKind::Gaussian, &data, 8, 4);
        let mut rng = Rng::new(23);
        let cfg = SubmodelConfig {
            inputs_per_filter: 8,
            entries_per_filter: 32,
            k_hashes: 2,
            num_classes: 33,
            total_input_bits: 32,
        };
        let sm = Submodel::new_random(&mut rng, cfg);
        let m = UleenModel { name: "too-wide".into(), encoder, submodels: vec![sm] };
        let bytes = to_bytes(&m, &Json::obj());
        let err = from_bytes(&bytes, "x").unwrap_err().to_string();
        assert!(err.contains("32-class capacity"), "got: {err}");
    }

    #[test]
    fn mask_width_choice_survives_save_load() {
        // The `.uln` format stores the SOURCE model; the mask-plane width
        // is a pure function of its class count (plus compile options),
        // so a loaded artifact must compile to the same width — at every
        // forcing, and at the default resolution — as the original.
        use crate::model::flat::{CompileOptions, FlatModel};
        use crate::model::simd::MaskWidth;
        let m = sample_model(); // 3 classes → u8 when unforced
        let bytes = to_bytes(&m, &Json::obj());
        let (back, _) = from_bytes(&bytes, "x").unwrap();
        assert_eq!(back.num_classes(), m.num_classes());
        let defaults = (
            FlatModel::compile(&m).mask_width(),
            FlatModel::compile(&back).mask_width(),
        );
        assert_eq!(defaults.0, defaults.1, "default width must survive save/load");
        assert_eq!(defaults.0, MaskWidth::resolve(m.num_classes()));
        for w in MaskWidth::all() {
            let opts = CompileOptions { mask_width: Some(w), ..Default::default() };
            let a = FlatModel::compile_with(&m, opts);
            let b = FlatModel::compile_with(&back, opts);
            assert_eq!(a.mask_width(), b.mask_width(), "forced {} must survive", w.label());
            assert_eq!(a.model_bytes(), b.model_bytes(), "identical layouts byte for byte");
            assert_eq!(a.mask_plane_bytes(), b.mask_plane_bytes());
        }
    }

    #[test]
    fn corruption_is_detected() {
        let m = sample_model();
        let mut bytes = to_bytes(&m, &Json::obj());
        let mid = bytes.len() / 2;
        bytes[mid] ^= 1;
        assert!(from_bytes(&bytes, "x").is_err());
    }

    #[test]
    fn truncation_is_detected() {
        let m = sample_model();
        let bytes = to_bytes(&m, &Json::obj());
        assert!(from_bytes(&bytes[..bytes.len() - 9], "x").is_err());
    }
}
