//! The full ULEEN model: a thermometer encoder + an ensemble of submodels
//! whose per-class responses are summed ("Vectorized Addition" in Fig 3),
//! with argmax prediction.

use crate::encoding::thermometer::ThermometerEncoder;
use crate::model::submodel::{Submodel, SubmodelScratch};
use crate::util::bitvec::BitVec;
use crate::util::stats::Confusion;

/// A complete inference-ready ULEEN model.
#[derive(Clone, Debug)]
pub struct UleenModel {
    pub name: String,
    pub encoder: ThermometerEncoder,
    pub submodels: Vec<Submodel>,
}

/// Per-thread scratch for ensemble inference.
#[derive(Clone, Debug, Default)]
pub struct EnsembleScratch {
    pub sub: SubmodelScratch,
    pub responses: Vec<i32>,
    pub acc: Vec<i32>,
}

impl UleenModel {
    pub fn num_classes(&self) -> usize {
        self.submodels[0].cfg.num_classes
    }

    /// Encoded input width (must equal every submodel's total_input_bits).
    pub fn encoded_bits(&self) -> usize {
        self.encoder.encoded_bits()
    }

    /// Validate internal consistency (used after deserialization).
    pub fn validate(&self) -> Result<(), String> {
        if self.submodels.is_empty() {
            return Err("model has no submodels".into());
        }
        let classes = self.num_classes();
        for (i, sm) in self.submodels.iter().enumerate() {
            if sm.cfg.num_classes != classes {
                return Err(format!("submodel {i} class-count mismatch"));
            }
            if sm.cfg.total_input_bits != self.encoded_bits() {
                return Err(format!(
                    "submodel {i} expects {} input bits, encoder provides {}",
                    sm.cfg.total_input_bits,
                    self.encoded_bits()
                ));
            }
            if sm.input_order.len() != sm.cfg.num_filters() * sm.cfg.inputs_per_filter {
                return Err(format!("submodel {i} input_order length mismatch"));
            }
            for d in &sm.discriminators {
                if d.filters.len() != sm.cfg.num_filters() {
                    return Err(format!("submodel {i} filter-count mismatch"));
                }
            }
        }
        Ok(())
    }

    /// Ensemble responses for an already-encoded input.
    pub fn responses_encoded<'a>(
        &self,
        encoded: &BitVec,
        scratch: &'a mut EnsembleScratch,
    ) -> &'a [i32] {
        let m = self.num_classes();
        scratch.acc.clear();
        scratch.acc.resize(m, 0);
        scratch.responses.resize(m, 0);
        for sm in &self.submodels {
            sm.responses(encoded, &mut scratch.sub, &mut scratch.responses);
            for c in 0..m {
                scratch.acc[c] += scratch.responses[c];
            }
        }
        &scratch.acc
    }

    /// Predict the class of a raw (unencoded) sample.
    pub fn predict(&self, sample: &[f32], scratch: &mut EnsembleScratch) -> usize {
        let encoded = self.encoder.encode(sample);
        self.predict_encoded(&encoded, scratch)
    }

    /// Predict from an encoded sample (argmax of summed responses; ties
    /// break to the lowest class index, matching the hardware comparator).
    pub fn predict_encoded(&self, encoded: &BitVec, scratch: &mut EnsembleScratch) -> usize {
        let resp = self.responses_encoded(encoded, scratch);
        crate::util::argmax_tie_low(resp)
    }

    /// Evaluate accuracy over a feature matrix (row-major) with labels.
    pub fn evaluate(&self, xs: &[f32], ys: &[u16], num_features: usize) -> Confusion {
        assert_eq!(xs.len(), ys.len() * num_features);
        let mut scratch = EnsembleScratch::default();
        let mut conf = Confusion::new(self.num_classes());
        for (i, &y) in ys.iter().enumerate() {
            let row = &xs[i * num_features..(i + 1) * num_features];
            let p = self.predict(row, &mut scratch);
            conf.record(y as usize, p);
        }
        conf
    }

    /// Total model size in KiB (tables; the paper's accounting).
    pub fn size_kib(&self) -> f64 {
        self.submodels.iter().map(|s| s.size_kib()).sum()
    }

    /// Total hash computations per inference (hardware cost driver).
    pub fn hashes_per_inference(&self) -> usize {
        self.submodels.iter().map(|s| s.hashes_per_inference()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::encoding::thermometer::{ThermometerEncoder, ThermometerKind};
    use crate::model::submodel::SubmodelConfig;
    use crate::util::rng::Rng;

    fn tiny_model(num_sub: usize) -> UleenModel {
        let data: Vec<f32> = (0..400).map(|i| (i % 100) as f32).collect();
        let encoder = ThermometerEncoder::fit(ThermometerKind::Gaussian, &data, 8, 4);
        let mut rng = Rng::new(9);
        let cfg = SubmodelConfig {
            inputs_per_filter: 8,
            entries_per_filter: 32,
            k_hashes: 2,
            num_classes: 3,
            total_input_bits: 32,
        };
        let submodels = (0..num_sub)
            .map(|_| Submodel::new_random(&mut rng, cfg))
            .collect();
        UleenModel { name: "tiny".into(), encoder, submodels }
    }

    #[test]
    fn validate_accepts_consistent_model() {
        tiny_model(2).validate().unwrap();
    }

    #[test]
    fn validate_rejects_mismatched_encoder() {
        let mut m = tiny_model(1);
        m.submodels[0].cfg.total_input_bits = 64;
        assert!(m.validate().is_err());
    }

    #[test]
    fn ensemble_sums_submodel_responses() {
        let mut m = tiny_model(2);
        // bias one class in each submodel; ensemble must add them
        m.submodels[0].bias[1] = 5;
        m.submodels[1].bias[1] = 7;
        let mut scratch = EnsembleScratch::default();
        let sample = vec![50.0f32; 8];
        let encoded = m.encoder.encode(&sample);
        let resp = m.responses_encoded(&encoded, &mut scratch).to_vec();
        let mut m0 = m.clone();
        m0.submodels.truncate(1);
        let r0 = m0.responses_encoded(&encoded, &mut scratch).to_vec();
        let mut m1 = m.clone();
        m1.submodels.remove(0);
        let r1 = m1.responses_encoded(&encoded, &mut scratch).to_vec();
        for c in 0..3 {
            assert_eq!(resp[c], r0[c] + r1[c]);
        }
        assert!(resp[1] >= 12);
    }

    #[test]
    fn predict_is_argmax_with_low_tie_break() {
        let mut m = tiny_model(1);
        m.submodels[0].bias = vec![2, 2, 0];
        let mut scratch = EnsembleScratch::default();
        // all-zero sample → empty-table responses are biases
        let p = m.predict(&vec![-1e9f32; 8], &mut scratch);
        assert_eq!(p, 0, "tie between class 0 and 1 breaks low");
    }

    #[test]
    fn evaluate_counts_everything() {
        let m = tiny_model(1);
        let xs: Vec<f32> = (0..80).map(|i| (i % 100) as f32).collect();
        let ys: Vec<u16> = (0..10).map(|i| (i % 3) as u16).collect();
        let conf = m.evaluate(&xs, &ys, 8);
        assert_eq!(conf.total(), 10);
    }

    #[test]
    fn size_accounting() {
        let m = tiny_model(2);
        // 2 submodels × 3 classes × 4 filters × 32 bits = 768 bits
        assert!((m.size_kib() - 768.0 / 8.0 / 1024.0).abs() < 1e-12);
        assert_eq!(m.hashes_per_inference(), 2 * 4 * 2);
    }
}
