//! One ULEEN submodel: `num_classes` discriminators over a shared
//! pseudo-random input mapping, Bloom-filter RAM nodes, and a single shared
//! H3 hash block (paper §III-C: hashing is computed once per input and
//! reused by every discriminator — we mirror that structure exactly, which
//! is also what makes the software hot path fast).

use crate::bloom::binary::BinaryBloom;
use crate::hash::h3::H3Family;
use crate::util::bitvec::BitVec;
use crate::util::rng::Rng;

/// Hyperparameters of a submodel.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SubmodelConfig {
    /// Bits of encoded input consumed by each filter (paper `n`).
    pub inputs_per_filter: usize,
    /// Bloom-filter table entries (power of two).
    pub entries_per_filter: usize,
    /// Hash functions per filter (paper uses 2).
    pub k_hashes: usize,
    pub num_classes: usize,
    /// Total encoded input bits (paper `I`).
    pub total_input_bits: usize,
}

impl SubmodelConfig {
    /// Number of filters per discriminator: ceil(I / n) (paper: N ≡ I/n;
    /// we pad the mapping by wrapping when n does not divide I).
    pub fn num_filters(&self) -> usize {
        self.total_input_bits.div_ceil(self.inputs_per_filter)
    }

    pub fn out_bits(&self) -> u32 {
        debug_assert!(self.entries_per_filter.is_power_of_two());
        self.entries_per_filter.trailing_zeros()
    }
}

/// One discriminator: a filter per slot; `None` = pruned away.
#[derive(Clone, Debug)]
pub struct Discriminator {
    pub filters: Vec<Option<BinaryBloom>>,
}

impl Discriminator {
    pub fn kept(&self) -> usize {
        self.filters.iter().filter(|f| f.is_some()).count()
    }
}

/// Reusable per-thread scratch for inference (no allocation on the hot path).
#[derive(Clone, Debug, Default)]
pub struct SubmodelScratch {
    pub keys: Vec<u64>,
    /// filter-major: idxs[f * k + j]
    pub idxs: Vec<u64>,
}

/// A fully-assembled inference-time submodel.
#[derive(Clone, Debug)]
pub struct Submodel {
    pub cfg: SubmodelConfig,
    /// Pseudo-random input mapping, length `num_filters * inputs_per_filter`;
    /// entry = index into the encoded input bit vector. Shared by all
    /// discriminators (paper §II).
    pub input_order: Vec<u32>,
    /// H3 parameters shared by every filter in the submodel (paper §III-C).
    pub hash: H3Family,
    pub discriminators: Vec<Discriminator>,
    /// Per-class bias added to the response (paper §III-A4; 0 if unpruned).
    pub bias: Vec<i32>,
}

impl Submodel {
    /// Build the shared input mapping: a permutation of `0..I`, wrapped to
    /// fill `num_filters * n` slots when n does not divide I.
    pub fn make_input_order(rng: &mut Rng, cfg: &SubmodelConfig) -> Vec<u32> {
        let total = cfg.num_filters() * cfg.inputs_per_filter;
        let perm = rng.permutation(cfg.total_input_bits);
        (0..total)
            .map(|i| perm[i % cfg.total_input_bits])
            .collect()
    }

    /// Fresh all-zeros submodel with random mapping + hash parameters.
    pub fn new_random(rng: &mut Rng, cfg: SubmodelConfig) -> Self {
        let input_order = Self::make_input_order(rng, &cfg);
        let hash = H3Family::random(rng, cfg.k_hashes, cfg.inputs_per_filter, cfg.out_bits());
        let discriminators = (0..cfg.num_classes)
            .map(|_| Discriminator {
                filters: (0..cfg.num_filters())
                    .map(|_| Some(BinaryBloom::zeros(cfg.entries_per_filter)))
                    .collect(),
            })
            .collect();
        Self { cfg, input_order, hash, discriminators, bias: vec![0; cfg.num_classes] }
    }

    /// Gather the per-filter keys from an encoded input (bit i of key f =
    /// encoded[input_order[f*n + i]]).
    pub fn gather_keys(&self, encoded: &BitVec, keys: &mut Vec<u64>) {
        let n = self.cfg.inputs_per_filter;
        let nf = self.cfg.num_filters();
        keys.clear();
        keys.reserve(nf);
        debug_assert_eq!(encoded.len(), self.cfg.total_input_bits);
        for f in 0..nf {
            let base = f * n;
            let mut key = 0u64;
            for i in 0..n {
                let src = self.input_order[base + i] as usize;
                key |= (encoded.get(src) as u64) << i;
            }
            keys.push(key);
        }
    }

    /// Hash all keys with the shared family (filter-major layout).
    pub fn hash_keys(&self, keys: &[u64], idxs: &mut Vec<u64>) {
        let k = self.cfg.k_hashes;
        idxs.clear();
        idxs.resize(keys.len() * k, 0);
        for (f, &key) in keys.iter().enumerate() {
            self.hash.hash_all(key, &mut idxs[f * k..(f + 1) * k]);
        }
    }

    /// Per-class responses for an encoded input: popcount of filter hits
    /// plus the class bias. `scratch` avoids per-call allocation.
    pub fn responses(
        &self,
        encoded: &BitVec,
        scratch: &mut SubmodelScratch,
        out: &mut [i32],
    ) {
        debug_assert_eq!(out.len(), self.cfg.num_classes);
        self.gather_keys(encoded, &mut scratch.keys);
        self.hash_keys(&scratch.keys, &mut scratch.idxs);
        let k = self.cfg.k_hashes;
        for (c, disc) in self.discriminators.iter().enumerate() {
            let mut acc = 0i32;
            for (f, filter) in disc.filters.iter().enumerate() {
                if let Some(filter) = filter {
                    if filter.test_indices(&scratch.idxs[f * k..(f + 1) * k]) {
                        acc += 1;
                    }
                }
            }
            out[c] = acc + self.bias[c];
        }
    }

    /// Model size in bits: kept filter tables only (biases are counted by
    /// the ensemble; matches the paper's "model size" accounting which
    /// reports table storage).
    pub fn size_bits(&self) -> usize {
        self.discriminators
            .iter()
            .map(|d| d.kept() * self.cfg.entries_per_filter)
            .sum()
    }

    pub fn size_kib(&self) -> f64 {
        self.size_bits() as f64 / 8.0 / 1024.0
    }

    /// Total hash invocations per inference (for the hardware model):
    /// filters × k, regardless of pruning (hashing is shared; pruned
    /// filters still have their slots hashed — paper §V-F1 notes hashing
    /// does not shrink with pruning).
    pub fn hashes_per_inference(&self) -> usize {
        self.cfg.num_filters() * self.cfg.k_hashes
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> SubmodelConfig {
        SubmodelConfig {
            inputs_per_filter: 8,
            entries_per_filter: 64,
            k_hashes: 2,
            num_classes: 4,
            total_input_bits: 64,
        }
    }

    #[test]
    fn num_filters_rounds_up() {
        let mut c = cfg();
        assert_eq!(c.num_filters(), 8);
        c.inputs_per_filter = 10;
        assert_eq!(c.num_filters(), 7); // ceil(64/10)
        assert_eq!(c.out_bits(), 6);
    }

    #[test]
    fn input_order_covers_all_bits() {
        let mut rng = Rng::new(1);
        let c = cfg();
        let order = Submodel::make_input_order(&mut rng, &c);
        assert_eq!(order.len(), 64);
        let mut seen = vec![false; 64];
        for &i in &order {
            seen[i as usize] = true;
        }
        assert!(seen.iter().all(|&b| b), "permutation must cover all inputs");
    }

    #[test]
    fn gather_keys_reflects_input_bits() {
        let mut rng = Rng::new(2);
        let sm = Submodel::new_random(&mut rng, cfg());
        // all-ones input → all keys are full n-bit masks
        let ones = BitVec::from_bools(&vec![true; 64]);
        let mut keys = Vec::new();
        sm.gather_keys(&ones, &mut keys);
        assert!(keys.iter().all(|&k| k == 0xFF));
        // all-zeros input → all keys zero
        let zeros = BitVec::zeros(64);
        sm.gather_keys(&zeros, &mut keys);
        assert!(keys.iter().all(|&k| k == 0));
    }

    #[test]
    fn responses_count_trained_patterns() {
        let mut rng = Rng::new(3);
        let mut sm = Submodel::new_random(&mut rng, cfg());
        let sample = BitVec::from_bools(
            &(0..64).map(|i| i % 3 == 0).collect::<Vec<_>>(),
        );
        // Manually "train" class 2 on this sample: set all its filters.
        let mut scratch = SubmodelScratch::default();
        sm.gather_keys(&sample, &mut scratch.keys);
        sm.hash_keys(&scratch.keys, &mut scratch.idxs);
        let k = sm.cfg.k_hashes;
        for f in 0..sm.cfg.num_filters() {
            let idxs = scratch.idxs[f * k..(f + 1) * k].to_vec();
            sm.discriminators[2].filters[f]
                .as_mut()
                .unwrap()
                .set_indices(&idxs);
        }
        let mut out = vec![0i32; 4];
        sm.responses(&sample, &mut scratch, &mut out);
        assert_eq!(out[2], sm.cfg.num_filters() as i32, "exact pattern → max response");
        assert!(out[0] <= out[2] && out[1] <= out[2] && out[3] <= out[2]);
    }

    #[test]
    fn pruned_filters_reduce_size_and_response() {
        let mut rng = Rng::new(4);
        let mut sm = Submodel::new_random(&mut rng, cfg());
        // saturate every filter of class 0 so everything responds
        for f in sm.discriminators[0].filters.iter_mut() {
            let filt = f.as_mut().unwrap();
            for i in 0..filt.entries() {
                filt.table.set(i);
            }
        }
        let full_size = sm.size_bits();
        let mut scratch = SubmodelScratch::default();
        let sample = BitVec::from_bools(&(0..64).map(|i| i % 2 == 0).collect::<Vec<_>>());
        let mut out = vec![0i32; 4];
        sm.responses(&sample, &mut scratch, &mut out);
        assert_eq!(out[0], 8);
        // prune half of class 0's filters
        for f in 0..4 {
            sm.discriminators[0].filters[f] = None;
        }
        sm.responses(&sample, &mut scratch, &mut out);
        assert_eq!(out[0], 4);
        assert_eq!(sm.size_bits(), full_size - 4 * 64);
    }

    #[test]
    fn bias_shifts_response() {
        let mut rng = Rng::new(5);
        let mut sm = Submodel::new_random(&mut rng, cfg());
        sm.bias[1] = 3;
        let mut scratch = SubmodelScratch::default();
        let mut out = vec![0i32; 4];
        sm.responses(&BitVec::zeros(64), &mut scratch, &mut out);
        // empty filters: responses are just biases... except key 0 hashes to
        // index 0 for all H3 fns and table bit 0 is unset, so hits are 0.
        assert_eq!(out[1] - out[0], 3);
    }
}
