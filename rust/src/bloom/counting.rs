//! Counting Bloom filter with the paper's min-increment update and
//! bleaching-threshold binarization (§III-A1, Fig 4).

use crate::bloom::binary::BinaryBloom;
use crate::hash::h3::H3Family;

/// Counting Bloom filter: u16 counters (saturating), `k` hash positions.
///
/// Training update: find the minimum of the `k` addressed counters and
/// increment **all counters equal to that minimum** (paper: "the smallest
/// of its corresponding counter values is incremented (multiple counters
/// in the event of a tie)"). Query: minimum of addressed counters; the
/// filter responds 1 iff that minimum is ≥ the bleaching threshold `b`.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct CountingBloom {
    pub counters: Vec<u16>,
}

impl CountingBloom {
    pub fn zeros(entries: usize) -> Self {
        assert!(entries.is_power_of_two(), "table size must be a power of two");
        Self { counters: vec![0; entries] }
    }

    pub fn entries(&self) -> usize {
        self.counters.len()
    }

    /// Min-increment training update on precomputed indices.
    #[inline]
    pub fn train_indices(&mut self, idxs: &[u64]) {
        let min = idxs
            .iter()
            .map(|&i| self.counters[i as usize])
            .min()
            .expect("k >= 1");
        if min == u16::MAX {
            return; // saturated
        }
        for &i in idxs {
            if self.counters[i as usize] == min {
                self.counters[i as usize] = min + 1;
            }
        }
    }

    /// Minimum addressed counter — the value compared against `b`.
    #[inline]
    pub fn query_min_indices(&self, idxs: &[u64]) -> u16 {
        idxs.iter()
            .map(|&i| self.counters[i as usize])
            .min()
            .expect("k >= 1")
    }

    /// Response under bleaching threshold `b` ("possibly seen ≥ b times").
    #[inline]
    pub fn test_indices(&self, idxs: &[u64], b: u16) -> bool {
        self.query_min_indices(idxs) >= b
    }

    /// Convenience key-based train (tests only).
    pub fn train_key(&mut self, fam: &H3Family, key: u64) {
        let mut idxs = vec![0u64; fam.k()];
        fam.hash_all(key, &mut idxs);
        self.train_indices(&idxs);
    }

    /// Convenience key-based query (tests only).
    pub fn query_min_key(&self, fam: &H3Family, key: u64) -> u16 {
        let mut idxs = vec![0u64; fam.k()];
        fam.hash_all(key, &mut idxs);
        self.query_min_indices(&idxs)
    }

    /// Binarize at bleaching threshold `b` → inference-time binary filter
    /// (entry = 1 iff counter ≥ b).
    pub fn binarize(&self, b: u16) -> BinaryBloom {
        let mut f = BinaryBloom::zeros(self.entries());
        for (i, &c) in self.counters.iter().enumerate() {
            if c >= b {
                f.table.set(i);
            }
        }
        f
    }

    /// Largest counter value (upper bound for the bleaching search).
    pub fn max_counter(&self) -> u16 {
        self.counters.iter().copied().max().unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{check, Config};
    use crate::util::rng::Rng;

    fn fam(seed: u64) -> H3Family {
        let mut rng = Rng::new(seed);
        H3Family::random(&mut rng, 3, 16, 8)
    }

    #[test]
    fn repeated_pattern_raises_min_count() {
        let fam = fam(1);
        let mut f = CountingBloom::zeros(256);
        let key = 0xABCD & 0xFFFF;
        for i in 1..=5 {
            f.train_key(&fam, key);
            assert_eq!(f.query_min_key(&fam, key), i as u16);
        }
    }

    #[test]
    fn min_increment_never_overshoots() {
        // Property: after training a multiset of keys, the min-count of a
        // key never exceeds the number of times it was trained (collisions
        // can only inflate individual counters, not the minimum beyond the
        // insertion count... actually collisions CAN inflate the min; the
        // sound invariant is the Bloom-side one: min-count >= times trained).
        check(
            "counting-bloom-lower-bound",
            &Config { cases: 64, ..Config::default() },
            |rng, size| {
                let fam = H3Family::random(rng, 2, 16, 7);
                let keys: Vec<u64> =
                    (0..size.min(40)).map(|_| rng.next_u64() & 0xFFFF).collect();
                let reps = 1 + (rng.below(4) as usize);
                (fam, keys, reps)
            },
            |(fam, keys, reps)| {
                let mut f = CountingBloom::zeros(128);
                for _ in 0..*reps {
                    for &k in keys {
                        f.train_key(fam, k);
                    }
                }
                for &k in keys {
                    let m = f.query_min_key(fam, k) as usize;
                    let times = keys.iter().filter(|&&x| x == k).count() * reps;
                    if m < times {
                        return Err(format!(
                            "min count {m} < train count {times} for key {k:#x}"
                        ));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn binarize_matches_threshold_query() {
        check(
            "binarize-equiv",
            &Config { cases: 48, ..Config::default() },
            |rng, size| {
                let fam = H3Family::random(rng, 2, 16, 7);
                let keys: Vec<u64> =
                    (0..size.min(60)).map(|_| rng.next_u64() & 0xFFFF).collect();
                let b = 1 + rng.below(3) as u16;
                (fam, keys, b)
            },
            |(fam, keys, b)| {
                let mut f = CountingBloom::zeros(128);
                for &k in keys {
                    f.train_key(fam, k);
                }
                let bin = f.binarize(*b);
                let mut idxs = vec![0u64; fam.k()];
                for probe in 0..256u64 {
                    fam.hash_all(probe, &mut idxs);
                    let via_count = f.test_indices(&idxs, *b);
                    let via_bin = bin.test_indices(&idxs);
                    if via_count != via_bin {
                        return Err(format!("mismatch at probe {probe}"));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn bleaching_filters_rare_patterns() {
        let fam = fam(3);
        let mut f = CountingBloom::zeros(256);
        let common = 0x1111u64 & 0xFFFF;
        let rare = 0x2222u64 & 0xFFFF;
        for _ in 0..10 {
            f.train_key(&fam, common);
        }
        f.train_key(&fam, rare);
        let b = 3;
        let mut idxs = vec![0u64; fam.k()];
        fam.hash_all(common, &mut idxs);
        assert!(f.test_indices(&idxs, b));
        fam.hash_all(rare, &mut idxs);
        assert!(!f.test_indices(&idxs, b));
    }

    #[test]
    fn counters_saturate_instead_of_wrapping() {
        let fam = fam(4);
        let mut f = CountingBloom::zeros(256);
        f.counters.iter_mut().for_each(|c| *c = u16::MAX - 1);
        for _ in 0..10 {
            f.train_key(&fam, 1);
        }
        assert!(f.counters.iter().all(|&c| c >= u16::MAX - 1));
    }
}
