//! Continuous Bloom filter — f32 entries, unit-step binarization.
//!
//! Multi-shot training (paper §III-B2) happens in JAX (L2); this Rust
//! mirror exists so the `.uln` import path and the binarization semantics
//! can be cross-checked natively, and so the one-shot ↔ multi-shot code
//! paths share an interface.

use crate::bloom::binary::BinaryBloom;

/// Continuous Bloom filter: entries in `[-1, 1]`; the filter responds 1
/// iff the **minimum** addressed entry is ≥ 0 (unit step of the min).
#[derive(Clone, Debug)]
pub struct ContinuousBloom {
    pub weights: Vec<f32>,
}

impl ContinuousBloom {
    pub fn new(entries: usize, init: f32) -> Self {
        assert!(entries.is_power_of_two());
        Self { weights: vec![init; entries] }
    }

    pub fn entries(&self) -> usize {
        self.weights.len()
    }

    #[inline]
    pub fn min_indices(&self, idxs: &[u64]) -> f32 {
        idxs.iter()
            .map(|&i| self.weights[i as usize])
            .fold(f32::INFINITY, f32::min)
    }

    /// Unit-step response: 1 iff min entry ≥ 0.
    #[inline]
    pub fn test_indices(&self, idxs: &[u64]) -> bool {
        self.min_indices(idxs) >= 0.0
    }

    /// Binarize with the unit step (entry ≥ 0 → 1).
    pub fn binarize(&self) -> BinaryBloom {
        let mut f = BinaryBloom::zeros(self.entries());
        for (i, &w) in self.weights.iter().enumerate() {
            if w >= 0.0 {
                f.table.set(i);
            }
        }
        f
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hash::h3::H3Family;
    use crate::util::rng::Rng;

    #[test]
    fn step_semantics_on_min() {
        let mut f = ContinuousBloom::new(8, -1.0);
        f.weights[2] = 0.5;
        f.weights[5] = 0.0;
        assert!(f.test_indices(&[2, 5])); // min = 0.0 → 1
        assert!(!f.test_indices(&[2, 5, 7])); // min = -1.0 → 0
    }

    #[test]
    fn binarize_equivalence_exhaustive() {
        let mut rng = Rng::new(20);
        let fam = H3Family::random(&mut rng, 2, 12, 5);
        let mut f = ContinuousBloom::new(32, -1.0);
        for i in 0..32 {
            f.weights[i] = (rng.f64() * 2.0 - 1.0) as f32;
        }
        let bin = f.binarize();
        let mut idxs = vec![0u64; 2];
        for key in 0..4096u64 {
            fam.hash_all(key, &mut idxs);
            assert_eq!(f.test_indices(&idxs), bin.test_indices(&idxs), "key {key}");
        }
    }
}
