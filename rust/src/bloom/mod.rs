//! Bloom-filter RAM nodes (paper §III-A1).
//!
//! Three variants mirror the paper's training story:
//! * [`BinaryBloom`] — the inference-time filter: bit-packed table, `k` H3
//!   hashes, responds 1 iff **all** hashed positions are set.
//! * [`CountingBloom`] — one-shot training: multi-bit counters with the
//!   "increment the minimum (ties: all minima)" update, enabling
//!   *bleaching* (threshold `b`).
//! * [`ContinuousBloom`] — multi-shot training parity: f32 entries,
//!   binarized by a unit step; the JAX side trains these, this struct
//!   exists for cross-checking the binarization.

pub mod binary;
pub mod continuous;
pub mod counting;

pub use binary::BinaryBloom;
pub use continuous::ContinuousBloom;
pub use counting::CountingBloom;
