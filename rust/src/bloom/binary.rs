//! Inference-time binary Bloom filter.

use crate::hash::h3::H3Family;
use crate::util::bitvec::BitVec;

/// Bit-packed Bloom filter over packed `u64` keys; hash functions are held
/// externally ([`H3Family`] is shared across all filters of a submodel, per
/// the paper's central hash block) and indices are passed in.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct BinaryBloom {
    pub table: BitVec,
}

impl BinaryBloom {
    pub fn zeros(entries: usize) -> Self {
        assert!(entries.is_power_of_two(), "table size must be a power of two");
        Self { table: BitVec::zeros(entries) }
    }

    pub fn entries(&self) -> usize {
        self.table.len()
    }

    /// Membership test given precomputed hash indices.
    #[inline]
    pub fn test_indices(&self, idxs: &[u64]) -> bool {
        idxs.iter().all(|&i| self.table.get(i as usize))
    }

    /// Insert given precomputed hash indices.
    #[inline]
    pub fn set_indices(&mut self, idxs: &[u64]) {
        for &i in idxs {
            self.table.set(i as usize);
        }
    }

    /// Convenience: test a key through a family (allocates; tests only).
    pub fn test_key(&self, fam: &H3Family, key: u64) -> bool {
        let mut idxs = vec![0u64; fam.k()];
        fam.hash_all(key, &mut idxs);
        self.test_indices(&idxs)
    }

    /// Convenience: insert a key through a family (allocates; tests only).
    pub fn set_key(&mut self, fam: &H3Family, key: u64) {
        let mut idxs = vec![0u64; fam.k()];
        fam.hash_all(key, &mut idxs);
        self.set_indices(&idxs);
    }

    /// Occupancy in [0,1] — used to diagnose saturation.
    pub fn fill_ratio(&self) -> f64 {
        self.table.count_ones() as f64 / self.table.len() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{check, Config};
    use crate::util::rng::Rng;

    #[test]
    fn no_false_negatives() {
        // The defining Bloom guarantee: every inserted key tests positive.
        check(
            "bloom-no-false-negatives",
            &Config::default(),
            |rng, size| {
                let n_inputs = 16;
                let fam = H3Family::random(rng, 2, n_inputs, 8);
                let keys: Vec<u64> = (0..size)
                    .map(|_| rng.next_u64() & 0xFFFF)
                    .collect();
                (fam, keys)
            },
            |(fam, keys)| {
                let mut f = BinaryBloom::zeros(256);
                for &k in keys {
                    f.set_key(fam, k);
                }
                for &k in keys {
                    if !f.test_key(fam, k) {
                        return Err(format!("false negative for key {k:#x}"));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn empty_filter_rejects_nonzero_hash_keys() {
        let mut rng = Rng::new(10);
        let fam = H3Family::random(&mut rng, 2, 16, 8);
        let f = BinaryBloom::zeros(256);
        let mut rejected = 0;
        for k in 1..100u64 {
            if !f.test_key(&fam, k) {
                rejected += 1;
            }
        }
        // key 0 hashes to index 0 on all fns (H3 of 0 is 0), which is unset
        // here anyway; a fresh filter must reject essentially everything.
        assert!(rejected >= 99);
    }

    #[test]
    fn false_positive_rate_is_plausible() {
        let mut rng = Rng::new(11);
        let fam = H3Family::random(&mut rng, 2, 20, 10); // 1024 entries
        let mut f = BinaryBloom::zeros(1024);
        let mut r = Rng::new(12);
        let inserted: Vec<u64> = (0..200).map(|_| r.next_u64() & 0xFFFFF).collect();
        for &k in &inserted {
            f.set_key(&fam, k);
        }
        // measure FP rate on fresh keys
        let mut fp = 0;
        let trials = 5000;
        for _ in 0..trials {
            let k = r.next_u64() & 0xFFFFF;
            if inserted.contains(&k) {
                continue;
            }
            if f.test_key(&fam, k) {
                fp += 1;
            }
        }
        let rate = fp as f64 / trials as f64;
        // theory: (1 - e^{-kn/m})^k ≈ (1-e^{-400/1024})^2 ≈ 0.105
        assert!(rate < 0.2, "fp rate {rate}");
    }

    #[test]
    #[should_panic(expected = "power of two")]
    fn non_pow2_table_rejected() {
        let _ = BinaryBloom::zeros(100);
    }
}
