//! Hash substrates: the arithmetic-free H3 family used by ULEEN's Bloom
//! filters (paper §III-A1) and MurmurHash3 double-hashing used by the
//! Bloom WiSARD baseline we compare against.

pub mod h3;
pub mod murmur;

pub use h3::{H3Family, H3Hash};
pub use murmur::{murmur3_32, DoubleHash};
