//! H3 universal hash family (Carter & Wegman 1979), paper §III-A1.
//!
//! An H3 hash of an `n`-bit key is `h(x) = XOR over { p_i : x_i = 1 }` for
//! random parameters `p_i`. It is **arithmetic-free** — AND/XOR only —
//! which is exactly why ULEEN uses it instead of MurmurHash: the hardware
//! hash unit is a tree of AND/XOR gates.
//!
//! H3 is linear: `h(a ⊕ b) = h(a) ⊕ h(b)` — a property we exploit in tests.
//! Keys are packed LSB-first into a `u64` (filters take ≤ 64 inputs; the
//! paper's largest is 36).

use crate::util::rng::Rng;

/// One H3 hash function: `n` parameters of `out_bits` bits each.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct H3Hash {
    /// One parameter per input bit; only the low `out_bits` are used.
    pub params: Vec<u64>,
    pub out_bits: u32,
}

impl H3Hash {
    /// Draw a random member of the family.
    pub fn random(rng: &mut Rng, n_inputs: usize, out_bits: u32) -> Self {
        assert!(out_bits >= 1 && out_bits <= 63);
        let mask = (1u64 << out_bits) - 1;
        let params = (0..n_inputs).map(|_| rng.next_u64() & mask).collect();
        Self { params, out_bits }
    }

    #[inline]
    pub fn n_inputs(&self) -> usize {
        self.params.len()
    }

    /// Hash a key given as packed bits (bit `i` of `key` = input `i`).
    #[inline]
    pub fn hash(&self, key: u64) -> u64 {
        let mut h = 0u64;
        let mut k = key;
        // Iterate only over set bits — the hot path is sparse-ish keys.
        while k != 0 {
            let i = k.trailing_zeros() as usize;
            debug_assert!(i < self.params.len(), "key has bits beyond n_inputs");
            h ^= self.params[i];
            k &= k - 1;
        }
        h
    }

    /// Hash from a bool slice (slow path, used by reference code and tests).
    pub fn hash_bits(&self, bits: &[bool]) -> u64 {
        assert_eq!(bits.len(), self.params.len());
        let mut h = 0u64;
        for (i, &b) in bits.iter().enumerate() {
            if b {
                h ^= self.params[i];
            }
        }
        h
    }
}

/// `k` independent H3 functions sharing an input width — one Bloom filter's
/// worth of hashing. Parameters are shared across all filters in a submodel
/// (paper §III-C: a central "Param RF" + hash block).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct H3Family {
    pub fns: Vec<H3Hash>,
}

impl H3Family {
    pub fn random(rng: &mut Rng, k: usize, n_inputs: usize, out_bits: u32) -> Self {
        Self {
            fns: (0..k).map(|_| H3Hash::random(rng, n_inputs, out_bits)).collect(),
        }
    }

    #[inline]
    pub fn k(&self) -> usize {
        self.fns.len()
    }

    #[inline]
    pub fn out_bits(&self) -> u32 {
        self.fns[0].out_bits
    }

    /// All `k` hashes of a packed key.
    #[inline]
    pub fn hash_all(&self, key: u64, out: &mut [u64]) {
        debug_assert_eq!(out.len(), self.fns.len());
        for (o, f) in out.iter_mut().zip(self.fns.iter()) {
            *o = f.hash(key);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{check, Config};

    #[test]
    fn zero_key_hashes_to_zero() {
        let mut rng = Rng::new(1);
        let h = H3Hash::random(&mut rng, 20, 10);
        assert_eq!(h.hash(0), 0);
    }

    #[test]
    fn output_fits_in_out_bits() {
        let mut rng = Rng::new(2);
        let h = H3Hash::random(&mut rng, 16, 7);
        for i in 0..1000u64 {
            assert!(h.hash((i * 0x9E37) & 0xFFFF) < 128);
        }
    }

    #[test]
    fn hash_matches_bool_slice_path() {
        let mut rng = Rng::new(3);
        let h = H3Hash::random(&mut rng, 24, 9);
        let mut r = Rng::new(55);
        for _ in 0..200 {
            let key = r.next_u64() & ((1 << 24) - 1);
            let bits: Vec<bool> = (0..24).map(|i| (key >> i) & 1 == 1).collect();
            assert_eq!(h.hash(key), h.hash_bits(&bits));
        }
    }

    #[test]
    fn h3_linearity_property() {
        // h(a ^ b) == h(a) ^ h(b) — the defining algebraic property.
        check(
            "h3-linearity",
            &Config::default(),
            |rng, size| {
                let n = (size % 48) + 8;
                let h = H3Hash::random(rng, n, 12);
                let mask = if n == 64 { u64::MAX } else { (1u64 << n) - 1 };
                let a = rng.next_u64() & mask;
                let b = rng.next_u64() & mask;
                (h, a, b)
            },
            |(h, a, b)| {
                if h.hash(a ^ b) == h.hash(*a) ^ h.hash(*b) {
                    Ok(())
                } else {
                    Err("linearity violated".into())
                }
            },
        );
    }

    #[test]
    fn family_members_differ() {
        let mut rng = Rng::new(4);
        let fam = H3Family::random(&mut rng, 3, 16, 10);
        let key = 0xBEEF & 0xFFFF;
        let mut out = [0u64; 3];
        fam.hash_all(key, &mut out);
        assert!(out[0] != out[1] || out[1] != out[2]);
    }

    #[test]
    fn distribution_is_roughly_uniform() {
        let mut rng = Rng::new(6);
        let h = H3Hash::random(&mut rng, 20, 6); // 64 buckets
        let mut counts = [0u32; 64];
        let mut r = Rng::new(7);
        let n = 64_000;
        for _ in 0..n {
            let key = r.next_u64() & ((1 << 20) - 1);
            counts[h.hash(key) as usize] += 1;
        }
        let expect = n as f64 / 64.0;
        for (b, &c) in counts.iter().enumerate() {
            assert!(
                (c as f64) > expect * 0.7 && (c as f64) < expect * 1.3,
                "bucket {b} count {c} vs expect {expect}"
            );
        }
    }
}
