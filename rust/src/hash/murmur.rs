//! MurmurHash3 (x86_32) and Kirsch–Mitzenmacher double hashing.
//!
//! This is the hashing scheme of the **Bloom WiSARD baseline** (de Araújo
//! et al. 2019) that ULEEN compares against in Table IV and Fig 10: `k`
//! hash values derived as `h1 + i*h2` from two Murmur hashes. The paper
//! calls this scheme out as impractical in hardware (variable-length
//! arithmetic hashing) — we implement it faithfully for the baseline.

/// MurmurHash3 x86 32-bit of a byte slice.
pub fn murmur3_32(data: &[u8], seed: u32) -> u32 {
    const C1: u32 = 0xcc9e2d51;
    const C2: u32 = 0x1b873593;
    let mut h = seed;
    let chunks = data.len() / 4;
    for i in 0..chunks {
        let mut k = u32::from_le_bytes(data[i * 4..i * 4 + 4].try_into().unwrap());
        k = k.wrapping_mul(C1);
        k = k.rotate_left(15);
        k = k.wrapping_mul(C2);
        h ^= k;
        h = h.rotate_left(13);
        h = h.wrapping_mul(5).wrapping_add(0xe6546b64);
    }
    let tail = &data[chunks * 4..];
    let mut k = 0u32;
    for (i, &b) in tail.iter().enumerate() {
        k |= (b as u32) << (8 * i);
    }
    if !tail.is_empty() {
        k = k.wrapping_mul(C1);
        k = k.rotate_left(15);
        k = k.wrapping_mul(C2);
        h ^= k;
    }
    h ^= data.len() as u32;
    h ^= h >> 16;
    h = h.wrapping_mul(0x85ebca6b);
    h ^= h >> 13;
    h = h.wrapping_mul(0xc2b2ae35);
    h ^= h >> 16;
    h
}

/// Kirsch–Mitzenmacher double hashing: `g_i(x) = h1(x) + i * h2(x) mod m`.
#[derive(Clone, Debug)]
pub struct DoubleHash {
    pub k: usize,
    pub table_size: u32,
    pub seed: u32,
}

impl DoubleHash {
    pub fn new(k: usize, table_size: u32, seed: u32) -> Self {
        assert!(table_size > 0);
        Self { k, table_size, seed }
    }

    /// The `k` table indices for a key (packed input bits as LE bytes).
    pub fn indices(&self, key: u64, out: &mut [u32]) {
        debug_assert_eq!(out.len(), self.k);
        let bytes = key.to_le_bytes();
        let h1 = murmur3_32(&bytes, self.seed);
        let h2 = murmur3_32(&bytes, self.seed.wrapping_add(0x9747b28c)) | 1; // odd
        for (i, o) in out.iter_mut().enumerate() {
            *o = h1.wrapping_add((i as u32).wrapping_mul(h2)) % self.table_size;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn murmur_reference_vectors() {
        // Public reference vectors for MurmurHash3 x86_32.
        assert_eq!(murmur3_32(b"", 0), 0);
        assert_eq!(murmur3_32(b"", 1), 0x514E28B7);
        assert_eq!(murmur3_32(b"test", 0), 0xba6bd213);
        assert_eq!(murmur3_32(b"Hello, world!", 0), 0xc0363e43);
        assert_eq!(murmur3_32(b"The quick brown fox jumps over the lazy dog", 0), 0x2e4ff723);
    }

    #[test]
    fn double_hash_in_range_and_distinct_fns() {
        let dh = DoubleHash::new(4, 1021, 7);
        let mut out = [0u32; 4];
        for key in 0..500u64 {
            dh.indices(key * 0x5DEECE66D, &mut out);
            for &i in &out {
                assert!(i < 1021);
            }
        }
        // different i's give (generically) different indices
        dh.indices(12345, &mut out);
        assert!(out.windows(2).any(|w| w[0] != w[1]));
    }

    #[test]
    fn double_hash_deterministic() {
        let dh = DoubleHash::new(3, 512, 1);
        let mut a = [0u32; 3];
        let mut b = [0u32; 3];
        dh.indices(999, &mut a);
        dh.indices(999, &mut b);
        assert_eq!(a, b);
    }
}
