//! Std-only HTTP/1.1 serving front-end — the network edge of the
//! coordinator (ROADMAP direction 2).
//!
//! The paper's always-resident datapath (and the FINN-style streaming
//! architecture it builds on) assumes clients stream requests *into* the
//! accelerator; until this module, only in-process callers could reach
//! [`Server::submit_tiered`]. `HttpFrontend` opens that edge with the
//! same machinery the rest of the crate uses — no tokio/axum (offline,
//! no registry), just `TcpListener` plus the persistent worker-pool
//! pattern proven in `runtime/sharded.rs`: one acceptor thread feeds a
//! bounded connection channel drained by a fixed pool of handler
//! threads, so a connection flood backpressures at `accept` time
//! instead of spawning unbounded threads.
//!
//! Routes:
//!
//! * `GET /health` — liveness + queue depth (unauthenticated, for
//!   load-balancer probes).
//! * `GET /metrics` — the live [`MetricsReport`] serialized by
//!   [`MetricsReport::to_json`](crate::coordinator::metrics::MetricsReport::to_json).
//!   When the latency autopilot is armed (`--target-p99-ms`), the JSON
//!   carries an `"autopilot"` object: target, current knob positions
//!   (`margin`, `dwell_us`) and AIMD decision counts.
//! * `POST /v1/classify` — `{"rows": [[f32; width], ...], "tier":
//!   "fast|balanced|accurate"?}` → `{"predictions": [class, ...]}` in
//!   row order.
//!
//! Every failure is a **well-formed HTTP error**, never a dropped
//! connection — the whole point of fronting the bounded batcher:
//!
//! | status | meaning |
//! |--------|---------|
//! | 400    | bad JSON / wrong-width row (the body names the row index) |
//! | 401    | missing/wrong API key (`x-api-key` or `Authorization: Bearer`) |
//! | 404/405| unknown route / method |
//! | 408    | read deadline exceeded (slow-loris guard) |
//! | 413    | body over `max_body_bytes` (rejected before it is read) |
//! | 429    | token-bucket admission refused, or [`SubmitError::Full`] |
//! | 503    | accept backlog full, or [`SubmitError::Closed`] (shutdown) |
//!
//! Request reads are double-bounded: every `read` carries
//! `read_timeout`, and the whole request must arrive within
//! `request_deadline` — a client trickling one byte per poll cannot pin
//! a handler.

use crate::coordinator::batcher::SubmitError;
use crate::coordinator::router::Tier;
use crate::coordinator::server::Server;
use crate::util::json::Json;
use std::collections::HashMap;
use std::io::{Read, Write};
use std::net::{IpAddr, Ipv4Addr, SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Per-client token-bucket admission limit: a client IP may hold up to
/// `burst` tokens and regains `per_sec` tokens per second; each
/// `/v1/classify` request spends one. `per_sec: 0.0` never refills —
/// useful for tests and hard caps.
#[derive(Clone, Copy, Debug)]
pub struct RateLimit {
    pub burst: f64,
    pub per_sec: f64,
}

/// Front-end tuning. The defaults suit a loopback load test; production
/// callers set `api_key` and a `rate`.
#[derive(Clone, Debug)]
pub struct HttpConfig {
    /// Required on `/metrics` and `/v1/classify` when set (`/health`
    /// stays open for probes). Clients send `x-api-key: <key>` or
    /// `Authorization: Bearer <key>`.
    pub api_key: Option<String>,
    /// Persistent connection-handler threads.
    pub handlers: usize,
    /// Accepted-but-unhandled connection backlog; overflow is answered
    /// with an immediate 503 instead of an unbounded queue.
    pub backlog: usize,
    /// Hard cap on request bodies — larger `Content-Length`s get 413
    /// before a single body byte is read.
    pub max_body_bytes: usize,
    /// Hard cap on rows per classify request.
    pub max_rows: usize,
    /// Per-`read` socket timeout.
    pub read_timeout: Duration,
    /// Whole-request arrival deadline (slow-loris guard).
    pub request_deadline: Duration,
    /// Per-client-IP admission limit; `None` admits everything.
    pub rate: Option<RateLimit>,
}

impl Default for HttpConfig {
    fn default() -> Self {
        Self {
            api_key: None,
            // topology default: one handler per detected logical core
            handlers: crate::util::detected_cores(),
            backlog: 64,
            max_body_bytes: 1 << 20,
            max_rows: 256,
            read_timeout: Duration::from_secs(2),
            request_deadline: Duration::from_secs(5),
            rate: None,
        }
    }
}

struct Bucket {
    tokens: f64,
    last: Instant,
}

/// Token buckets keyed by client IP. The map is bounded: once it holds
/// more than `MAX_TRACKED` clients, fully-replenished buckets (which
/// carry no information beyond the default) are dropped.
struct Limiter {
    cfg: RateLimit,
    map: Mutex<HashMap<IpAddr, Bucket>>,
}

const MAX_TRACKED: usize = 8192;

impl Limiter {
    fn new(cfg: RateLimit) -> Self {
        Self { cfg, map: Mutex::new(HashMap::new()) }
    }

    fn admit(&self, ip: IpAddr) -> bool {
        let now = Instant::now();
        let mut map = self.map.lock().unwrap();
        if map.len() > MAX_TRACKED {
            let (burst, per_sec) = (self.cfg.burst, self.cfg.per_sec);
            map.retain(|_, b| {
                b.tokens + now.saturating_duration_since(b.last).as_secs_f64() * per_sec < burst
            });
            if map.len() > 2 * MAX_TRACKED {
                // pathological IP churn with zero refill: fail open
                // (fresh bursts) rather than grow without bound
                map.clear();
            }
        }
        let b = map
            .entry(ip)
            .or_insert(Bucket { tokens: self.cfg.burst, last: now });
        let refill = now.saturating_duration_since(b.last).as_secs_f64() * self.cfg.per_sec;
        b.tokens = (b.tokens + refill).min(self.cfg.burst);
        b.last = now;
        if b.tokens >= 1.0 {
            b.tokens -= 1.0;
            true
        } else {
            false
        }
    }
}

struct Ctx {
    server: Arc<Server>,
    cfg: HttpConfig,
    limiter: Option<Limiter>,
    closing: AtomicBool,
}

/// A running HTTP front-end over an [`Arc<Server>`]. Bind with
/// [`HttpFrontend::start`]; stop with [`HttpFrontend::shutdown`] (the
/// `Server` itself is closed/joined separately by its owner).
pub struct HttpFrontend {
    local_addr: SocketAddr,
    ctx: Arc<Ctx>,
    acceptor: Option<JoinHandle<()>>,
    handlers: Vec<JoinHandle<()>>,
}

impl HttpFrontend {
    /// Bind `addr` (use port 0 for an ephemeral port — read it back via
    /// [`HttpFrontend::local_addr`]) and start the acceptor + handler
    /// pool. Handlers submit into `server` and complete requests from
    /// its responses; its metrics sink also counts every HTTP status
    /// served.
    pub fn start(addr: &str, server: Arc<Server>, cfg: HttpConfig) -> crate::Result<Self> {
        anyhow::ensure!(cfg.handlers > 0, "http front-end needs at least one handler");
        let listener = TcpListener::bind(addr)
            .map_err(|e| anyhow::anyhow!("bind {addr}: {e}"))?;
        let local_addr = listener.local_addr()?;
        let ctx = Arc::new(Ctx {
            limiter: cfg.rate.map(Limiter::new),
            server,
            cfg,
            closing: AtomicBool::new(false),
        });
        let (conn_tx, conn_rx) = mpsc::sync_channel::<TcpStream>(ctx.cfg.backlog);
        let conn_rx = Arc::new(Mutex::new(conn_rx));
        let mut handlers = Vec::with_capacity(ctx.cfg.handlers);
        for _ in 0..ctx.cfg.handlers {
            let rx = conn_rx.clone();
            let ctx = ctx.clone();
            handlers.push(std::thread::spawn(move || loop {
                // Scope the lock to the recv: exactly one idle handler
                // waits on the channel at a time; the rest queue on the
                // mutex — the `runtime/sharded.rs` pool shape.
                let next = rx.lock().unwrap().recv();
                match next {
                    Ok(stream) => handle_connection(&ctx, stream),
                    Err(_) => return, // acceptor gone and backlog drained
                }
            }));
        }
        let acceptor_ctx = ctx.clone();
        let acceptor = std::thread::spawn(move || {
            for conn in listener.incoming() {
                if acceptor_ctx.closing.load(Ordering::SeqCst) {
                    return; // drops conn_tx → handlers drain and exit
                }
                let Ok(stream) = conn else { continue };
                match conn_tx.try_send(stream) {
                    Ok(()) => {}
                    Err(mpsc::TrySendError::Full(mut s)) => {
                        // Connection flood: answer, don't drop or queue.
                        acceptor_ctx.server.metrics.record_http(503);
                        let _ = write_response(
                            &mut s,
                            503,
                            &err_body("overloaded", "connection backlog full"),
                            false,
                            &mut RespBuf::default(),
                        );
                    }
                    Err(mpsc::TrySendError::Disconnected(_)) => return,
                }
            }
        });
        Ok(Self { local_addr, ctx, acceptor: Some(acceptor), handlers })
    }

    pub fn local_addr(&self) -> SocketAddr {
        self.local_addr
    }

    /// Stop accepting, drain queued connections, join every thread.
    /// In-flight requests finish with `Connection: close`; the wrapped
    /// `Server` keeps running until its owner shuts it down.
    pub fn shutdown(mut self) {
        self.ctx.closing.store(true, Ordering::SeqCst);
        // Wake the acceptor out of a blocking `accept`.
        let _ = TcpStream::connect_timeout(&self.local_addr, Duration::from_secs(1));
        if let Some(a) = self.acceptor.take() {
            let _ = a.join();
        }
        for h in self.handlers.drain(..) {
            let _ = h.join();
        }
    }
}

struct HttpRequest {
    method: String,
    path: String,
    headers: Vec<(String, String)>,
    body: Vec<u8>,
    keep_alive: bool,
}

impl HttpRequest {
    fn header(&self, name: &str) -> Option<&str> {
        self.headers
            .iter()
            .find(|(k, _)| k.as_str() == name)
            .map(|(_, v)| v.as_str())
    }
}

/// An error that still gets a well-formed response: a status, a stable
/// machine-readable code, and a human detail line.
struct HttpError {
    status: u16,
    code: &'static str,
    detail: String,
}

impl HttpError {
    fn new(status: u16, code: &'static str, detail: impl Into<String>) -> Self {
        Self { status, code, detail: detail.into() }
    }
}

fn err_body(code: &str, detail: &str) -> Json {
    let mut j = Json::obj();
    j.set("error", Json::Str(code.to_string()))
        .set("detail", Json::Str(detail.to_string()));
    j
}

const MAX_HEADER_BYTES: usize = 8 << 10;

fn handle_connection(ctx: &Ctx, mut stream: TcpStream) {
    let peer = stream
        .peer_addr()
        .map(|a| a.ip())
        .unwrap_or(IpAddr::V4(Ipv4Addr::UNSPECIFIED));
    let _ = stream.set_nodelay(true);
    let _ = stream.set_read_timeout(Some(ctx.cfg.read_timeout));
    let mut buf: Vec<u8> = Vec::new();
    // Grow-only response scratch: every response on this connection
    // serializes into the same buffers, so steady-state keep-alive
    // traffic stops allocating a String pair per response.
    let mut resp = RespBuf::default();
    loop {
        if ctx.closing.load(Ordering::SeqCst) {
            return;
        }
        match read_request(&ctx.cfg, &mut stream, &mut buf) {
            Ok(Some(req)) => {
                let keep = req.keep_alive && !ctx.closing.load(Ordering::SeqCst);
                let (status, body) = route(ctx, peer, &req);
                ctx.server.metrics.record_http(status);
                if write_response(&mut stream, status, &body, keep, &mut resp).is_err()
                    || !keep
                {
                    return;
                }
            }
            Ok(None) => return, // clean EOF or idle timeout between requests
            Err(e) => {
                ctx.server.metrics.record_http(e.status);
                let _ = write_response(
                    &mut stream,
                    e.status,
                    &err_body(e.code, &e.detail),
                    false,
                    &mut resp,
                );
                return;
            }
        }
    }
}

fn find_header_end(buf: &[u8]) -> Option<usize> {
    buf.windows(4).position(|w| w == b"\r\n\r\n")
}

/// Read one request off the connection. `buf` carries leftover bytes
/// between keep-alive requests. `Ok(None)` means the peer is gone (or
/// idle past the read timeout) with no request in flight; a timeout
/// mid-request is a 408.
fn read_request(
    cfg: &HttpConfig,
    stream: &mut TcpStream,
    buf: &mut Vec<u8>,
) -> Result<Option<HttpRequest>, HttpError> {
    let started = Instant::now();
    let mut tmp = [0u8; 4096];
    let deadline_hit = |buf: &[u8]| -> Result<Option<HttpRequest>, HttpError> {
        if buf.is_empty() {
            Ok(None) // idle keep-alive connection: close silently
        } else {
            Err(HttpError::new(408, "timeout", "read deadline exceeded"))
        }
    };
    // headers
    let header_end = loop {
        if let Some(pos) = find_header_end(buf) {
            break pos;
        }
        if buf.len() > MAX_HEADER_BYTES {
            return Err(HttpError::new(431, "headers_too_large", "header block over 8 KiB"));
        }
        if started.elapsed() > cfg.request_deadline {
            return deadline_hit(buf);
        }
        match stream.read(&mut tmp) {
            Ok(0) => {
                return if buf.is_empty() {
                    Ok(None)
                } else {
                    Err(HttpError::new(400, "truncated", "connection closed mid-request"))
                };
            }
            Ok(n) => buf.extend_from_slice(&tmp[..n]),
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                return deadline_hit(buf);
            }
            Err(_) => return Ok(None), // peer reset
        }
    };
    let head = std::str::from_utf8(&buf[..header_end])
        .map_err(|_| HttpError::new(400, "bad_request", "non-utf8 request head"))?;
    let mut lines = head.split("\r\n");
    let request_line = lines.next().unwrap_or("");
    let mut parts = request_line.split(' ');
    let (method, path, version) = match (parts.next(), parts.next(), parts.next()) {
        (Some(m), Some(p), Some(v)) if !m.is_empty() && p.starts_with('/') => {
            (m.to_string(), p.to_string(), v)
        }
        _ => {
            return Err(HttpError::new(
                400,
                "bad_request",
                format!("malformed request line '{request_line}'"),
            ))
        }
    };
    if !version.starts_with("HTTP/1.") {
        return Err(HttpError::new(400, "bad_request", format!("unsupported {version}")));
    }
    let mut headers = Vec::new();
    let mut keep_alive = version == "HTTP/1.1";
    let mut content_len = 0usize;
    for line in lines {
        if line.is_empty() {
            continue;
        }
        let Some((name, val)) = line.split_once(':') else {
            return Err(HttpError::new(400, "bad_request", format!("malformed header '{line}'")));
        };
        let name = name.trim().to_ascii_lowercase();
        let val = val.trim().to_string();
        match name.as_str() {
            "content-length" => {
                content_len = val.parse().map_err(|_| {
                    HttpError::new(400, "bad_request", format!("bad content-length '{val}'"))
                })?;
            }
            "connection" => {
                if val.eq_ignore_ascii_case("close") {
                    keep_alive = false;
                } else if val.eq_ignore_ascii_case("keep-alive") {
                    keep_alive = true;
                }
            }
            "transfer-encoding" => {
                return Err(HttpError::new(
                    501,
                    "unsupported",
                    "chunked bodies unsupported; send content-length",
                ));
            }
            _ => {}
        }
        headers.push((name, val));
    }
    // Size gate BEFORE reading the body: a hostile content-length never
    // costs more than the header read.
    if content_len > cfg.max_body_bytes {
        return Err(HttpError::new(
            413,
            "body_too_large",
            format!("content-length {content_len} over limit {}", cfg.max_body_bytes),
        ));
    }
    let body_start = header_end + 4;
    while buf.len() < body_start + content_len {
        if started.elapsed() > cfg.request_deadline {
            return Err(HttpError::new(408, "timeout", "read deadline exceeded mid-body"));
        }
        match stream.read(&mut tmp) {
            Ok(0) => {
                return Err(HttpError::new(400, "truncated", "connection closed mid-body"));
            }
            Ok(n) => buf.extend_from_slice(&tmp[..n]),
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                return Err(HttpError::new(408, "timeout", "read deadline exceeded mid-body"));
            }
            Err(_) => {
                return Err(HttpError::new(400, "truncated", "connection lost mid-body"));
            }
        }
    }
    let body = buf[body_start..body_start + content_len].to_vec();
    buf.drain(..body_start + content_len); // keep pipelined leftovers
    Ok(Some(HttpRequest { method, path, headers, body, keep_alive }))
}

fn reason(status: u16) -> &'static str {
    match status {
        200 => "OK",
        400 => "Bad Request",
        401 => "Unauthorized",
        404 => "Not Found",
        405 => "Method Not Allowed",
        408 => "Request Timeout",
        413 => "Payload Too Large",
        429 => "Too Many Requests",
        431 => "Request Header Fields Too Large",
        500 => "Internal Server Error",
        501 => "Not Implemented",
        503 => "Service Unavailable",
        _ => "Status",
    }
}

/// Grow-only per-connection response scratch. `write_response` clears
/// and refills it in place, so a keep-alive connection settles at the
/// high-water mark of its responses and never reallocates again.
#[derive(Default)]
struct RespBuf {
    head: String,
    body: String,
}

fn write_response(
    stream: &mut TcpStream,
    status: u16,
    body: &Json,
    keep_alive: bool,
    buf: &mut RespBuf,
) -> std::io::Result<()> {
    use std::fmt::Write as _;
    buf.body.clear();
    body.write_to(&mut buf.body);
    buf.head.clear();
    // write! into a String is infallible; the let _ silences the Result.
    let _ = write!(
        buf.head,
        "HTTP/1.1 {status} {}\r\nContent-Type: application/json\r\nContent-Length: {}\r\nConnection: {}\r\n\r\n",
        reason(status),
        buf.body.len(),
        if keep_alive { "keep-alive" } else { "close" },
    );
    stream.write_all(buf.head.as_bytes())?;
    stream.write_all(buf.body.as_bytes())?;
    stream.flush()
}

fn authorized(ctx: &Ctx, req: &HttpRequest) -> bool {
    let Some(key) = &ctx.cfg.api_key else { return true };
    if req.header("x-api-key") == Some(key.as_str()) {
        return true;
    }
    matches!(req.header("authorization"),
        Some(v) if v.strip_prefix("Bearer ").map(str::trim) == Some(key.as_str()))
}

fn route(ctx: &Ctx, peer: IpAddr, req: &HttpRequest) -> (u16, Json) {
    match (req.method.as_str(), req.path.as_str()) {
        ("GET", "/health") => {
            let mut j = Json::obj();
            j.set("status", Json::Str("ok".into()))
                .set("queue_depth", Json::Num(ctx.server.queue_depth() as f64));
            (200, j)
        }
        ("GET", "/metrics") => {
            if !authorized(ctx, req) {
                return (401, err_body("unauthorized", "missing or wrong api key"));
            }
            (200, ctx.server.metrics.report(ctx.server.max_batch()).to_json())
        }
        ("POST", "/v1/classify") => {
            if !authorized(ctx, req) {
                return (401, err_body("unauthorized", "missing or wrong api key"));
            }
            if let Some(limiter) = &ctx.limiter {
                if !limiter.admit(peer) {
                    return (429, err_body("rate_limited", "per-client admission limit"));
                }
            }
            match classify(ctx, req) {
                Ok(j) => (200, j),
                Err(e) => (e.status, err_body(e.code, &e.detail)),
            }
        }
        (_, "/health" | "/metrics" | "/v1/classify") => {
            (405, err_body("method_not_allowed", "wrong method for this route"))
        }
        _ => (404, err_body("not_found", "unknown route")),
    }
}

fn parse_tier(s: &str) -> Option<Tier> {
    match s {
        "fast" => Some(Tier::Fast),
        "balanced" => Some(Tier::Balanced),
        "accurate" => Some(Tier::Accurate),
        _ => None,
    }
}

fn classify(ctx: &Ctx, req: &HttpRequest) -> Result<Json, HttpError> {
    let bad = |detail: String| HttpError::new(400, "bad_request", detail);
    let text = std::str::from_utf8(&req.body)
        .map_err(|_| bad("body is not utf-8".into()))?;
    let doc = Json::parse(text).map_err(|e| bad(format!("bad json: {e}")))?;
    let rows = doc
        .get("rows")
        .and_then(Json::as_arr)
        .ok_or_else(|| bad("missing 'rows' array".into()))?;
    if rows.is_empty() {
        return Err(bad("'rows' is empty".into()));
    }
    if rows.len() > ctx.cfg.max_rows {
        return Err(bad(format!("{} rows over limit {}", rows.len(), ctx.cfg.max_rows)));
    }
    let tier = match doc.get("tier") {
        None | Some(Json::Null) => None,
        Some(Json::Str(s)) => Some(
            parse_tier(s)
                .ok_or_else(|| bad(format!("unknown tier '{s}' (fast|balanced|accurate)")))?,
        ),
        Some(_) => return Err(bad("'tier' must be a string".into())),
    };
    // Validate EVERY row before submitting ANY: a 400 must name the bad
    // row and leave the queue untouched.
    let width = ctx.server.num_features();
    for (i, row) in rows.iter().enumerate() {
        let vals = row
            .as_arr()
            .ok_or_else(|| bad(format!("row {i} is not an array")))?;
        if vals.len() != width {
            return Err(bad(format!("row {i} has width {}, want {width}", vals.len())));
        }
        for x in vals {
            x.as_f64().ok_or_else(|| bad(format!("row {i} has a non-number")))?;
        }
    }
    let n = rows.len();
    let (tx, rx) = mpsc::channel();
    let mut id2row = HashMap::with_capacity(n);
    // One reusable scratch row: values go parsed JSON → scratch → arena
    // slot, with no per-row Vec and no Vec<Vec<f32>> staging buffer.
    let mut row_buf: Vec<f32> = Vec::with_capacity(width);
    for (i, row) in rows.iter().enumerate() {
        row_buf.clear();
        // Both unwraps are unreachable: the validation pass above
        // rejected non-array rows and non-number values with a 400.
        for x in row.as_arr().unwrap() {
            row_buf.push(x.as_f64().unwrap() as f32);
        }
        match ctx.server.submit_tiered(&row_buf, tier, tx.clone()) {
            Ok(id) => {
                id2row.insert(id, i);
            }
            // Earlier rows of this request are already in flight; their
            // completions land on a dropped receiver (harmless) and the
            // client retries the whole batch — rejecting the remainder
            // is what keeps the queue bound meaningful under overload.
            Err(SubmitError::Full) => {
                return Err(HttpError::new(
                    429,
                    "queue_full",
                    format!("queue full after {i}/{n} rows; retry with backoff"),
                ));
            }
            Err(SubmitError::Closed) => {
                return Err(HttpError::new(503, "shutting_down", "server is closing"));
            }
        }
    }
    drop(tx);
    let mut preds = vec![0usize; n];
    for _ in 0..n {
        match rx.recv_timeout(Duration::from_secs(30)) {
            Ok((id, pred)) => {
                if let Some(&row) = id2row.get(&id) {
                    preds[row] = pred;
                }
            }
            // All senders dropped before n completions: the server shed
            // this work (failed batch / malformed) — its metrics count it.
            Err(_) => {
                return Err(HttpError::new(
                    500,
                    "incomplete",
                    "server dropped part of the batch",
                ));
            }
        }
    }
    let mut j = Json::obj();
    j.set(
        "predictions",
        Json::Arr(preds.into_iter().map(|p| Json::Num(p as f64)).collect()),
    );
    Ok(j)
}

/// Minimal loopback HTTP/1.1 client — shared by the integration tests,
/// the `edge_serving` load-test example and the bench sweep (std-only,
/// like the server it talks to).
pub mod client {
    use std::io::{Read, Write};
    use std::net::TcpStream;
    use std::time::Duration;

    /// A parsed response: status code plus the (JSON) body text.
    #[derive(Debug)]
    pub struct Response {
        pub status: u16,
        pub body: String,
    }

    /// One request over a fresh connection (`Connection: close`).
    pub fn request(
        addr: &str,
        method: &str,
        path: &str,
        api_key: Option<&str>,
        body: Option<&str>,
    ) -> std::io::Result<Response> {
        let mut stream = TcpStream::connect(addr)?;
        send(&mut stream, method, path, api_key, body, false)?;
        read_response(&mut stream)
    }

    /// One request over an existing connection (keep-alive).
    pub fn request_on(
        stream: &mut TcpStream,
        method: &str,
        path: &str,
        api_key: Option<&str>,
        body: Option<&str>,
    ) -> std::io::Result<Response> {
        send(stream, method, path, api_key, body, true)?;
        read_response(stream)
    }

    fn send(
        stream: &mut TcpStream,
        method: &str,
        path: &str,
        api_key: Option<&str>,
        body: Option<&str>,
        keep_alive: bool,
    ) -> std::io::Result<()> {
        stream.set_read_timeout(Some(Duration::from_secs(30)))?;
        let body = body.unwrap_or("");
        let mut head = format!("{method} {path} HTTP/1.1\r\nHost: uleen\r\n");
        if let Some(k) = api_key {
            head.push_str(&format!("x-api-key: {k}\r\n"));
        }
        if !body.is_empty() {
            head.push_str(&format!(
                "Content-Type: application/json\r\nContent-Length: {}\r\n",
                body.len()
            ));
        }
        head.push_str(if keep_alive {
            "Connection: keep-alive\r\n\r\n"
        } else {
            "Connection: close\r\n\r\n"
        });
        stream.write_all(head.as_bytes())?;
        stream.write_all(body.as_bytes())?;
        stream.flush()
    }

    fn read_response(stream: &mut TcpStream) -> std::io::Result<Response> {
        let mut buf = Vec::new();
        let mut tmp = [0u8; 4096];
        let header_end = loop {
            if let Some(p) = buf.windows(4).position(|w| w == b"\r\n\r\n") {
                break p;
            }
            match stream.read(&mut tmp)? {
                0 => {
                    return Err(std::io::Error::new(
                        std::io::ErrorKind::UnexpectedEof,
                        "connection closed before response headers",
                    ))
                }
                n => buf.extend_from_slice(&tmp[..n]),
            }
        };
        let head = String::from_utf8_lossy(&buf[..header_end]).to_string();
        let status: u16 = head
            .split(' ')
            .nth(1)
            .and_then(|s| s.parse().ok())
            .ok_or_else(|| {
                std::io::Error::new(std::io::ErrorKind::InvalidData, "bad status line")
            })?;
        let content_len: usize = head
            .lines()
            .find_map(|l| {
                let (k, v) = l.split_once(':')?;
                k.trim()
                    .eq_ignore_ascii_case("content-length")
                    .then(|| v.trim().parse().ok())?
            })
            .unwrap_or(0);
        let body_start = header_end + 4;
        while buf.len() < body_start + content_len {
            match stream.read(&mut tmp)? {
                0 => {
                    return Err(std::io::Error::new(
                        std::io::ErrorKind::UnexpectedEof,
                        "connection closed mid-body",
                    ))
                }
                n => buf.extend_from_slice(&tmp[..n]),
            }
        }
        Ok(Response {
            status,
            body: String::from_utf8_lossy(&buf[body_start..body_start + content_len])
                .to_string(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn token_bucket_spends_and_refills() {
        let l = Limiter::new(RateLimit { burst: 2.0, per_sec: 0.0 });
        let ip = IpAddr::V4(Ipv4Addr::LOCALHOST);
        assert!(l.admit(ip));
        assert!(l.admit(ip));
        assert!(!l.admit(ip), "burst exhausted, zero refill");
        let l = Limiter::new(RateLimit { burst: 1.0, per_sec: 1e6 });
        assert!(l.admit(ip));
        std::thread::sleep(Duration::from_millis(1));
        assert!(l.admit(ip), "fast refill re-admits");
    }

    #[test]
    fn limiter_map_stays_bounded() {
        let l = Limiter::new(RateLimit { burst: 4.0, per_sec: 1e9 });
        for i in 0..(MAX_TRACKED as u32 + 600) {
            let ip = IpAddr::V4(Ipv4Addr::from(i));
            l.admit(ip);
        }
        // instant refill means every bucket is prunable the moment the
        // cap trips, so the sweep holds the map near MAX_TRACKED
        assert!(
            l.map.lock().unwrap().len() <= MAX_TRACKED + 1,
            "replenished buckets must be swept once the cap is hit"
        );
    }

    #[test]
    fn tier_parsing_matches_route_names() {
        assert_eq!(parse_tier("fast"), Some(Tier::Fast));
        assert_eq!(parse_tier("balanced"), Some(Tier::Balanced));
        assert_eq!(parse_tier("accurate"), Some(Tier::Accurate));
        assert_eq!(parse_tier("warp"), None);
    }

    #[test]
    fn header_end_finder() {
        assert_eq!(find_header_end(b"GET / HTTP/1.1\r\n\r\nrest"), Some(14));
        assert_eq!(find_header_end(b"partial\r\n"), None);
    }
}
