//! The serving loop: worker threads pull micro-batches from the bounded
//! queue, run an [`InferenceEngine`], and complete requests. One engine
//! instance per worker (engines are stateful: scratch buffers / PJRT
//! executables), shared queue + metrics.

use crate::coordinator::batcher::{BatcherConfig, BoundedQueue, Request, SubmitError};
use crate::coordinator::metrics::ServerMetrics;
use crate::runtime::InferenceEngine;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc};
use std::thread::JoinHandle;
use std::time::Instant;

#[derive(Clone, Copy, Debug)]
pub struct ServerConfig {
    pub batcher: BatcherConfig,
    pub workers: usize,
}

impl Default for ServerConfig {
    fn default() -> Self {
        Self { batcher: BatcherConfig::default(), workers: 2 }
    }
}

/// A running server. Submit requests with [`Server::submit`]; call
/// [`Server::shutdown`] to drain and join workers.
pub struct Server {
    queue: Arc<BoundedQueue>,
    pub metrics: Arc<ServerMetrics>,
    workers: Vec<JoinHandle<()>>,
    next_id: AtomicU64,
    num_features: usize,
}

impl Server {
    /// Spawn `cfg.workers` threads, each owning one engine from `make_engine`.
    pub fn start(
        cfg: ServerConfig,
        make_engine: impl Fn(usize) -> crate::Result<Box<dyn InferenceEngine>>,
    ) -> crate::Result<Self> {
        let queue = Arc::new(BoundedQueue::new(cfg.batcher));
        let metrics = Arc::new(ServerMetrics::new());
        let mut workers = Vec::with_capacity(cfg.workers);
        let mut num_features = 0;
        for w in 0..cfg.workers {
            let mut engine = make_engine(w)?;
            num_features = engine.num_features();
            let queue = queue.clone();
            let metrics = metrics.clone();
            workers.push(std::thread::spawn(move || {
                worker_loop(&mut *engine, &queue, &metrics);
            }));
        }
        Ok(Self { queue, metrics, workers, next_id: AtomicU64::new(0), num_features })
    }

    /// Start a server whose single worker owns one
    /// [`ShardedEngine`](crate::runtime::ShardedEngine) fanning each
    /// micro-batch across `shards` threads — the alternative to
    /// `cfg.workers` independent engines when batches are large: one big
    /// batch split N ways beats N engines pulling small batches, because
    /// the fused bit-sliced kernel amortizes its CSR traversal over 64
    /// samples. The engine's worker pool spawns once here and is reused
    /// across every micro-batch for the server's lifetime (zero thread
    /// spawns on the serving hot path); it joins when the worker drops
    /// the engine during [`Server::shutdown`].
    pub fn start_sharded(
        cfg: ServerConfig,
        model: crate::model::ensemble::UleenModel,
        shards: usize,
    ) -> crate::Result<Self> {
        let cfg = ServerConfig { workers: 1, ..cfg };
        Self::start(cfg, move |_| {
            Ok(Box::new(crate::runtime::ShardedEngine::new(model.clone(), shards))
                as Box<dyn InferenceEngine>)
        })
    }

    pub fn num_features(&self) -> usize {
        self.num_features
    }

    /// Submit one request; the prediction arrives on `done`.
    pub fn submit(
        &self,
        features: Vec<f32>,
        done: mpsc::Sender<(u64, usize, Vec<f32>)>,
    ) -> Result<u64, SubmitError> {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        self.metrics.mark_start();
        let req = Request { id, features, enqueued: Instant::now(), done };
        match self.queue.submit(req) {
            Ok(()) => Ok(id),
            Err((e, _req)) => {
                self.metrics.record_reject(e == SubmitError::Full);
                Err(e)
            }
        }
    }

    pub fn queue_depth(&self) -> usize {
        self.queue.depth()
    }

    /// Stop accepting new requests — submitters observe
    /// [`SubmitError::Closed`] — while workers keep draining the backlog.
    /// Idempotent; call [`Server::shutdown`] afterwards to join workers.
    pub fn close(&self) {
        self.queue.close();
    }

    /// Drain and stop. Returns when every worker has exited.
    pub fn shutdown(self) {
        self.queue.close();
        for w in self.workers {
            let _ = w.join();
        }
    }
}

fn worker_loop(
    engine: &mut dyn InferenceEngine,
    queue: &BoundedQueue,
    metrics: &ServerMetrics,
) {
    let f = engine.num_features();
    let mut flat: Vec<f32> = Vec::new();
    while let Some(batch) = queue.next_batch() {
        flat.clear();
        let mut ok = true;
        for r in &batch {
            if r.features.len() != f {
                ok = false;
            }
            flat.extend_from_slice(&r.features);
        }
        if !ok {
            // malformed request in batch: fail the whole batch loudly by
            // dropping completions (senders see disconnect); keep serving.
            continue;
        }
        match engine.classify(&flat, batch.len()) {
            Ok(preds) => {
                let now = Instant::now();
                let lats: Vec<_> = batch.iter().map(|r| now - r.enqueued).collect();
                metrics.record_batch(batch.len(), &lats);
                for (r, p) in batch.into_iter().zip(preds) {
                    let _ = r.done.send((r.id, p, Vec::new()));
                }
            }
            Err(_) => {
                // engine failure: drop the batch (callers observe the
                // closed channel); a real deployment would requeue.
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::NativeEngine;
    use crate::data::synth_uci::{synth_uci, uci_spec};
    use crate::train::oneshot::{train_oneshot, OneShotConfig};
    use std::time::Duration;

    fn served_model() -> crate::model::ensemble::UleenModel {
        let ds = synth_uci(5, uci_spec("iris").unwrap());
        train_oneshot(&ds, &OneShotConfig::default()).0
    }

    #[test]
    fn serves_requests_and_matches_direct_inference() {
        let model = served_model();
        let ds = synth_uci(5, uci_spec("iris").unwrap());
        let expected: Vec<usize> = {
            let mut s = crate::model::ensemble::EnsembleScratch::default();
            (0..ds.n_test()).map(|i| model.predict(ds.test_row(i), &mut s)).collect()
        };
        let cfg = ServerConfig {
            batcher: BatcherConfig {
                max_batch: 8,
                max_wait: Duration::from_micros(100),
                capacity: 1024,
            },
            workers: 3,
        };
        let m2 = model.clone();
        let server = Server::start(cfg, move |_| {
            Ok(Box::new(NativeEngine::new(m2.clone())))
        })
        .unwrap();
        let (tx, rx) = mpsc::channel();
        let mut id2row = std::collections::HashMap::new();
        for i in 0..ds.n_test() {
            let id = server.submit(ds.test_row(i).to_vec(), tx.clone()).unwrap();
            id2row.insert(id, i);
        }
        drop(tx);
        let mut got = vec![usize::MAX; ds.n_test()];
        for _ in 0..ds.n_test() {
            let (id, pred, _) = rx.recv_timeout(Duration::from_secs(5)).unwrap();
            got[id2row[&id]] = pred;
        }
        server.shutdown();
        assert_eq!(got, expected, "served predictions must equal direct inference");
    }

    #[test]
    fn shutdown_drains_inflight() {
        let model = served_model();
        let server = Server::start(ServerConfig::default(), move |_| {
            Ok(Box::new(NativeEngine::new(model.clone())))
        })
        .unwrap();
        let (tx, rx) = mpsc::channel();
        let n = 64;
        for _ in 0..n {
            server
                .submit(vec![0.5; server.num_features()], tx.clone())
                .unwrap();
        }
        drop(tx);
        server.shutdown();
        let mut count = 0;
        while rx.try_recv().is_ok() {
            count += 1;
        }
        assert_eq!(count, n, "all in-flight requests complete before shutdown");
    }

    #[test]
    fn overload_rejects_with_backpressure() {
        let model = served_model();
        let cfg = ServerConfig {
            batcher: BatcherConfig {
                max_batch: 4,
                max_wait: Duration::from_millis(50),
                capacity: 4,
            },
            workers: 1,
        };
        let server = Server::start(cfg, move |_| {
            Ok(Box::new(NativeEngine::new(model.clone())))
        })
        .unwrap();
        let (tx, _rx) = mpsc::channel();
        let mut rejected = 0;
        for _ in 0..256 {
            if server
                .submit(vec![0.5; server.num_features()], tx.clone())
                .is_err()
            {
                rejected += 1;
            }
        }
        assert!(rejected > 0, "tiny queue must reject under burst load");
        server.shutdown();
    }
}
