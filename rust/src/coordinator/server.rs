//! The serving loop: worker threads pull micro-batches from the bounded
//! queue, run an [`InferenceEngine`], and complete requests. One engine
//! instance per worker (engines are stateful: scratch buffers / PJRT
//! executables), shared queue + metrics.

use crate::coordinator::batcher::{BatcherConfig, BoundedQueue, Request, SubmitError};
use crate::coordinator::metrics::ServerMetrics;
use crate::coordinator::router::{ModelRouter, RouterEngine};
use crate::runtime::{InferenceEngine, SharedModel, ShardedRouterEngine, Tier};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc};
use std::thread::JoinHandle;
use std::time::Instant;

#[derive(Clone, Copy, Debug)]
pub struct ServerConfig {
    pub batcher: BatcherConfig,
    pub workers: usize,
}

impl Default for ServerConfig {
    fn default() -> Self {
        Self { batcher: BatcherConfig::default(), workers: 2 }
    }
}

/// A running server. Submit requests with [`Server::submit`]; call
/// [`Server::shutdown`] to drain and join workers.
pub struct Server {
    queue: Arc<BoundedQueue>,
    pub metrics: Arc<ServerMetrics>,
    workers: Vec<JoinHandle<()>>,
    next_id: AtomicU64,
    num_features: usize,
    /// Zoo depth when workers own tier-aware engines; 0 on single-model
    /// servers. `submit_tiered` canonicalizes tiers against this —
    /// aliased tiers (and, on tier-blind servers, every pin) must not
    /// fragment micro-batches at boundaries the engine cannot even see.
    num_tiers: usize,
}

impl Server {
    /// Spawn `cfg.workers` threads, each owning one engine from `make_engine`.
    pub fn start(
        cfg: ServerConfig,
        make_engine: impl Fn(usize) -> crate::Result<Box<dyn InferenceEngine>>,
    ) -> crate::Result<Self> {
        let metrics = Arc::new(ServerMetrics::new());
        Self::start_with_metrics(cfg, metrics, make_engine)
    }

    /// [`Server::start`] with a caller-provided metrics sink, so engine
    /// factories can hook the same sink into their engines (the zoo path:
    /// `RouterEngine::with_metrics` flushes per-tier counters into it).
    /// The zoo depth is read off the engines themselves
    /// ([`InferenceEngine::num_tiers`]), so ANY tier-aware engine served
    /// through [`Server::start`] — not just `start_zoo`'s — keeps its
    /// tier pins.
    fn start_with_metrics(
        cfg: ServerConfig,
        metrics: Arc<ServerMetrics>,
        make_engine: impl Fn(usize) -> crate::Result<Box<dyn InferenceEngine>>,
    ) -> crate::Result<Self> {
        let queue = Arc::new(BoundedQueue::new(cfg.batcher));
        let mut workers = Vec::with_capacity(cfg.workers);
        let mut num_features = 0;
        let mut num_tiers = 0;
        let mut kernel_path = "n/a";
        for w in 0..cfg.workers {
            let mut engine = make_engine(w)?;
            num_features = engine.num_features();
            num_tiers = engine.num_tiers();
            kernel_path = engine.kernel_path();
            let queue = queue.clone();
            let metrics = metrics.clone();
            workers.push(std::thread::spawn(move || {
                worker_loop(&mut *engine, &queue, &metrics);
            }));
        }
        metrics.set_kernel_path(kernel_path);
        Ok(Self { queue, metrics, workers, next_id: AtomicU64::new(0), num_features, num_tiers })
    }

    /// Start a server whose workers each own a **model zoo**: a
    /// [`ModelRouter`] over one [`NativeEngine`](crate::runtime::NativeEngine)
    /// per model (small → large), wrapped in a [`RouterEngine`]. Tier-pinned requests
    /// ([`Server::submit_tiered`] with `Some(tier)`) dispatch as one
    /// batch call on that tier's engine; default requests run the batched
    /// confidence cascade. Per-tier served/escalation/latency counters
    /// flush into [`Server::metrics`] after every micro-batch and are
    /// part of the shutdown [`MetricsReport`](crate::coordinator::metrics::MetricsReport).
    pub fn start_zoo(
        cfg: ServerConfig,
        models: Vec<crate::model::ensemble::UleenModel>,
        margin_threshold: f32,
    ) -> crate::Result<Self> {
        let tiers = compile_zoo(models)?;
        Self::start_zoo_shared(cfg, tiers, margin_threshold)
    }

    /// [`Server::start_zoo`] over already-compiled tiers: every worker's
    /// router is built with [`ModelRouter::from_shared`], so N workers
    /// hold `Arc` handles into ONE copy of each tier instead of cloning
    /// the zoo per worker (memory used to grow ∝ workers × tiers —
    /// ROADMAP follow-up (h); the `Arc::strong_count` witness test pins
    /// the sharing down).
    pub fn start_zoo_shared(
        cfg: ServerConfig,
        tiers: Vec<SharedModel>,
        margin_threshold: f32,
    ) -> crate::Result<Self> {
        let metrics = Arc::new(ServerMetrics::new());
        let shared = metrics.clone();
        Self::start_with_metrics(cfg, metrics, move |_| {
            let mut router = ModelRouter::from_shared(&tiers);
            router.margin_threshold = margin_threshold;
            Ok(Box::new(RouterEngine::new(router).with_metrics(shared.clone()))
                as Box<dyn InferenceEngine>)
        })
    }

    /// Start a server whose single worker owns a
    /// [`ShardedRouterEngine`]: the cascade × shard composition — each
    /// micro-batch splits into contiguous row ranges, every range runs
    /// the batched confidence cascade (or its pinned tier) on a persistent
    /// pool worker, per-tier counters merge deterministically into
    /// [`Server::metrics`], and all `shards` workers probe ONE `Arc`-shared
    /// copy of each tier. The alternative to [`Server::start_zoo`]'s
    /// per-worker zoos when batches are large: one big batch split N ways
    /// beats N zoos pulling small batches.
    pub fn start_zoo_sharded(
        cfg: ServerConfig,
        models: Vec<crate::model::ensemble::UleenModel>,
        margin_threshold: f32,
        shards: usize,
    ) -> crate::Result<Self> {
        let tiers = compile_zoo(models)?;
        let cfg = ServerConfig { workers: 1, ..cfg };
        let metrics = Arc::new(ServerMetrics::new());
        let shared = metrics.clone();
        Self::start_with_metrics(cfg, metrics, move |_| {
            Ok(Box::new(
                ShardedRouterEngine::from_shared(tiers.clone(), margin_threshold, shards)
                    .with_metrics(shared.clone()),
            ) as Box<dyn InferenceEngine>)
        })
    }

    /// Start a server whose single worker owns one
    /// [`ShardedEngine`](crate::runtime::ShardedEngine) fanning each
    /// micro-batch across `shards` threads — the alternative to
    /// `cfg.workers` independent engines when batches are large: one big
    /// batch split N ways beats N engines pulling small batches, because
    /// the fused bit-sliced kernel amortizes its CSR traversal over 64
    /// samples. The engine's worker pool spawns once here and is reused
    /// across every micro-batch for the server's lifetime (zero thread
    /// spawns on the serving hot path); it joins when the worker drops
    /// the engine during [`Server::shutdown`].
    pub fn start_sharded(
        cfg: ServerConfig,
        model: crate::model::ensemble::UleenModel,
        shards: usize,
    ) -> crate::Result<Self> {
        let cfg = ServerConfig { workers: 1, ..cfg };
        Self::start(cfg, move |_| {
            Ok(Box::new(crate::runtime::ShardedEngine::new(model.clone(), shards))
                as Box<dyn InferenceEngine>)
        })
    }

    pub fn num_features(&self) -> usize {
        self.num_features
    }

    /// The serving micro-batch ceiling — what metrics reports take as
    /// their batch-fill denominator (the HTTP front-end's `/metrics`
    /// route needs it without seeing the queue).
    pub fn max_batch(&self) -> usize {
        self.queue.config().max_batch
    }

    /// Submit one request on the default path (cascade on zoo servers);
    /// the prediction arrives on `done`.
    pub fn submit(
        &self,
        features: Vec<f32>,
        done: mpsc::Sender<(u64, usize, Vec<f32>)>,
    ) -> Result<u64, SubmitError> {
        self.submit_tiered(features, None, done)
    }

    /// Submit one request with an optional service class: `Some(tier)`
    /// pins it to that zoo tier, `None` takes the default path (the
    /// batched confidence cascade on zoo servers, the single model
    /// otherwise). The batcher keeps batches tier-homogeneous, so the
    /// tier is canonicalized first: on tier-blind servers every pin
    /// becomes `None`, and on a zoo aliased tiers (Balanced vs Accurate
    /// on 2 tiers) collapse to one value — a hint the engine resolves
    /// identically must not split micro-batches.
    pub fn submit_tiered(
        &self,
        features: Vec<f32>,
        tier: Option<Tier>,
        done: mpsc::Sender<(u64, usize, Vec<f32>)>,
    ) -> Result<u64, SubmitError> {
        let tier = match self.num_tiers {
            0 => None,
            k => tier.map(|t| crate::coordinator::router::canonical_tier(t, k)),
        };
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let enqueued = Instant::now();
        let req = Request { id, features, tier, enqueued, done };
        match self.queue.submit(req) {
            Ok(()) => {
                // Start the throughput wall-clock only on ACCEPTED work
                // (at its enqueue time): a burst that is entirely
                // rejected must not start — and thereby skew — the
                // denominator of every later rate.
                self.metrics.mark_start_at(enqueued);
                Ok(id)
            }
            Err((e, _req)) => {
                self.metrics.record_reject(e == SubmitError::Full);
                Err(e)
            }
        }
    }

    pub fn queue_depth(&self) -> usize {
        self.queue.depth()
    }

    /// Stop accepting new requests — submitters observe
    /// [`SubmitError::Closed`] — while workers keep draining the backlog.
    /// Idempotent; call [`Server::shutdown`] afterwards to join workers.
    pub fn close(&self) {
        self.queue.close();
    }

    /// Drain and stop. Returns when every worker has exited.
    pub fn shutdown(self) {
        self.queue.close();
        for w in self.workers {
            let _ = w.join();
        }
    }
}

/// Validate a zoo (1..=3 models ordered small → large, all sharing one
/// feature width and class count) and compile each tier exactly ONCE into
/// an `Arc`-shared [`SharedModel`] — the single zoo-construction funnel
/// for [`Server::start_zoo`] and [`Server::start_zoo_sharded`].
fn compile_zoo(
    models: Vec<crate::model::ensemble::UleenModel>,
) -> crate::Result<Vec<SharedModel>> {
    anyhow::ensure!(
        (1..=3).contains(&models.len()),
        "zoo wants 1..=3 models, got {}",
        models.len()
    );
    for m in &models[1..] {
        anyhow::ensure!(
            m.encoder.num_inputs == models[0].encoder.num_inputs
                && m.num_classes() == models[0].num_classes(),
            "zoo models must share feature width and class count"
        );
    }
    Ok(models.into_iter().map(SharedModel::compile).collect())
}

fn worker_loop(
    engine: &mut dyn InferenceEngine,
    queue: &BoundedQueue,
    metrics: &ServerMetrics,
) {
    let f = engine.num_features();
    // Grow-only per-worker buffers, reused across every micro-batch: the
    // flattened input plane, the accepted requests, the prediction plane
    // the engine writes into (`classify_routed_into`), and the latency
    // staging. A warm worker's serving loop performs no steady-state
    // allocations of its own — the engines underneath uphold the same
    // contract (see the `InferenceEngine` write-into docs).
    let mut flat: Vec<f32> = Vec::new();
    let mut good: Vec<crate::coordinator::batcher::Request> = Vec::new();
    let mut preds: Vec<usize> = Vec::new();
    let mut lats: Vec<std::time::Duration> = Vec::new();
    while let Some(batch) = queue.next_batch() {
        // Batches are tier-homogeneous by construction (next_batch), so
        // the whole batch dispatches as one routed engine call.
        // (next_batch never yields an empty batch; guard anyway so a
        // future batcher change cannot panic the worker.)
        let Some(first) = batch.first() else { continue };
        let tier = first.tier;
        // Reject ONLY wrong-width requests (their senders disconnect, so
        // callers observe the drop); their batch-mates still complete.
        flat.clear();
        good.clear();
        let mut malformed = 0u64;
        for r in batch {
            if r.features.len() == f {
                flat.extend_from_slice(&r.features);
                good.push(r);
            } else {
                malformed += 1;
            }
        }
        if malformed > 0 {
            metrics.record_malformed(malformed);
        }
        if good.is_empty() {
            continue;
        }
        let n = good.len();
        if preds.len() < n {
            preds.resize(n, 0);
        }
        match engine.classify_routed_into(&flat, n, tier, &mut preds) {
            Ok(()) => {
                let now = Instant::now();
                lats.clear();
                lats.extend(good.iter().map(|r| now - r.enqueued));
                metrics.record_batch(n, &lats);
                for (r, &p) in good.drain(..).zip(preds.iter()) {
                    let _ = r.done.send((r.id, p, Vec::new()));
                }
            }
            Err(_) => {
                // Engine failure: drop the batch (callers observe the
                // closed channel) but COUNT it — overload tests and
                // operators watch `batches_failed`.
                metrics.record_batch_failure();
                good.clear();
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::NativeEngine;
    use crate::data::synth_uci::{synth_uci, uci_spec};
    use crate::train::oneshot::{train_oneshot, OneShotConfig};
    use std::time::Duration;

    fn served_model() -> crate::model::ensemble::UleenModel {
        let ds = synth_uci(5, uci_spec("iris").unwrap());
        train_oneshot(&ds, &OneShotConfig::default()).0
    }

    #[test]
    fn serves_requests_and_matches_direct_inference() {
        let model = served_model();
        let ds = synth_uci(5, uci_spec("iris").unwrap());
        let expected: Vec<usize> = {
            let mut s = crate::model::ensemble::EnsembleScratch::default();
            (0..ds.n_test()).map(|i| model.predict(ds.test_row(i), &mut s)).collect()
        };
        let cfg = ServerConfig {
            batcher: BatcherConfig {
                max_batch: 8,
                max_wait: Duration::from_micros(100),
                capacity: 1024,
            },
            workers: 3,
        };
        let m2 = model.clone();
        let server = Server::start(cfg, move |_| {
            Ok(Box::new(NativeEngine::new(m2.clone())))
        })
        .unwrap();
        let (tx, rx) = mpsc::channel();
        let mut id2row = std::collections::HashMap::new();
        for i in 0..ds.n_test() {
            let id = server.submit(ds.test_row(i).to_vec(), tx.clone()).unwrap();
            id2row.insert(id, i);
        }
        drop(tx);
        let mut got = vec![usize::MAX; ds.n_test()];
        for _ in 0..ds.n_test() {
            let (id, pred, _) = rx.recv_timeout(Duration::from_secs(5)).unwrap();
            got[id2row[&id]] = pred;
        }
        server.shutdown();
        assert_eq!(got, expected, "served predictions must equal direct inference");
    }

    #[test]
    fn shutdown_drains_inflight() {
        let model = served_model();
        let server = Server::start(ServerConfig::default(), move |_| {
            Ok(Box::new(NativeEngine::new(model.clone())))
        })
        .unwrap();
        let (tx, rx) = mpsc::channel();
        let n = 64;
        for _ in 0..n {
            server
                .submit(vec![0.5; server.num_features()], tx.clone())
                .unwrap();
        }
        drop(tx);
        server.shutdown();
        let mut count = 0;
        while rx.try_recv().is_ok() {
            count += 1;
        }
        assert_eq!(count, n, "all in-flight requests complete before shutdown");
    }

    #[test]
    fn zoo_server_serves_pinned_and_cascade_with_tier_metrics() {
        let ds = synth_uci(5, uci_spec("iris").unwrap());
        let mut models = Vec::new();
        for (inputs, entries, bits) in [(6usize, 64usize, 2usize), (10, 128, 4)] {
            models.push(
                train_oneshot(
                    &ds,
                    &OneShotConfig {
                        inputs_per_filter: inputs,
                        entries_per_filter: entries,
                        therm_bits: bits,
                        ..Default::default()
                    },
                )
                .0,
            );
        }
        let cfg = ServerConfig {
            batcher: BatcherConfig {
                max_batch: 16,
                max_wait: Duration::from_micros(100),
                capacity: 1024,
            },
            workers: 2,
        };
        let server = Server::start_zoo(cfg, models, 0.05).unwrap();
        let (tx, rx) = mpsc::channel();
        let n = ds.n_test();
        for i in 0..n {
            let tier = match i % 3 {
                0 => None, // cascade
                1 => Some(Tier::Fast),
                _ => Some(Tier::Accurate),
            };
            loop {
                match server.submit_tiered(ds.test_row(i).to_vec(), tier, tx.clone()) {
                    Ok(_) => break,
                    Err(SubmitError::Full) => std::thread::sleep(Duration::from_micros(20)),
                    Err(e) => panic!("{e:?}"),
                }
            }
        }
        drop(tx);
        let mut served = 0;
        while rx.recv_timeout(Duration::from_secs(10)).is_ok() {
            served += 1;
        }
        assert_eq!(served, n, "every pinned and cascade request completes");
        let report = server.metrics.report(16);
        server.shutdown();
        // every request touches tier 0 unless pinned Accurate; pinned
        // Accurate traffic plus escalations land on the last tier
        assert!(report.tier_served[0] as usize >= 2 * n / 3, "fast tier traffic");
        assert!(report.tier_served[1] as usize >= n / 3, "accurate tier pinned traffic");
        assert!(report.tier_mean_us[0] > 0.0, "tier latency counters populate");
    }

    #[test]
    fn zoo_workers_share_one_arc_copy_per_tier() {
        // ROADMAP follow-up (h): N workers' routers must hold Arc handles
        // into ONE copy of each tier, not per-worker clones.
        let ds = synth_uci(5, uci_spec("iris").unwrap());
        let mut tiers = Vec::new();
        for (inputs, entries, bits) in [(6usize, 64usize, 2usize), (10, 128, 4)] {
            let model = train_oneshot(
                &ds,
                &OneShotConfig {
                    inputs_per_filter: inputs,
                    entries_per_filter: entries,
                    therm_bits: bits,
                    ..Default::default()
                },
            )
            .0;
            tiers.push(crate::runtime::SharedModel::compile(model));
        }
        let workers = 3usize;
        let cfg = ServerConfig { batcher: BatcherConfig::default(), workers };
        let server = Server::start_zoo_shared(cfg, tiers.clone(), 0.05).unwrap();
        for (i, t) in tiers.iter().enumerate() {
            assert_eq!(
                Arc::strong_count(t.model()),
                1 + workers,
                "tier {i}: one handle here + one per worker, zero clones"
            );
            assert_eq!(Arc::strong_count(t.flat()), 1 + workers, "tier {i} flat layout");
        }
        // the shared zoo still serves
        let (tx, rx) = mpsc::channel();
        server.submit(ds.test_row(0).to_vec(), tx).unwrap();
        rx.recv_timeout(Duration::from_secs(5)).unwrap();
        server.shutdown();
        for t in &tiers {
            assert_eq!(Arc::strong_count(t.model()), 1, "shutdown releases every handle");
        }
    }

    #[test]
    fn overload_rejects_with_backpressure() {
        let model = served_model();
        let cfg = ServerConfig {
            batcher: BatcherConfig {
                max_batch: 4,
                max_wait: Duration::from_millis(50),
                capacity: 4,
            },
            workers: 1,
        };
        let server = Server::start(cfg, move |_| {
            Ok(Box::new(NativeEngine::new(model.clone())))
        })
        .unwrap();
        let (tx, _rx) = mpsc::channel();
        let mut rejected = 0;
        for _ in 0..256 {
            if server
                .submit(vec![0.5; server.num_features()], tx.clone())
                .is_err()
            {
                rejected += 1;
            }
        }
        assert!(rejected > 0, "tiny queue must reject under burst load");
        server.shutdown();
    }
}
