//! The serving loop: worker threads pull micro-batches from the bounded
//! queue, run an [`InferenceEngine`], and complete requests. One engine
//! instance per worker (engines are stateful: scratch buffers / PJRT
//! executables), shared queue + metrics.

use crate::coordinator::autopilot::{DwellKnob, MarginKnob};
use crate::coordinator::batcher::{BatcherConfig, BoundedQueue, Request, SubmitError};
use crate::coordinator::metrics::ServerMetrics;
use crate::coordinator::router::{ModelRouter, RouterEngine};
use crate::runtime::{InferenceEngine, SharedModel, ShardedRouterEngine, Tier};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc};
use std::thread::JoinHandle;
use std::time::Instant;

#[derive(Clone, Copy, Debug)]
pub struct ServerConfig {
    pub batcher: BatcherConfig,
    pub workers: usize,
}

impl Default for ServerConfig {
    fn default() -> Self {
        Self { batcher: BatcherConfig::default(), workers: 2 }
    }
}

/// A running server. Submit requests with [`Server::submit`]; call
/// [`Server::shutdown`] to drain and join workers.
pub struct Server {
    queue: Arc<BoundedQueue>,
    pub metrics: Arc<ServerMetrics>,
    workers: Vec<JoinHandle<()>>,
    next_id: AtomicU64,
    num_features: usize,
    /// Zoo depth when workers own tier-aware engines; 0 on single-model
    /// servers. `submit_tiered` canonicalizes tiers against this —
    /// aliased tiers (and, on tier-blind servers, every pin) must not
    /// fragment micro-batches at boundaries the engine cannot even see.
    num_tiers: usize,
    /// The ONE cascade-margin knob shared by every worker's router on
    /// zoo servers (`None` on single-model servers — no cascade, no
    /// margin). The autopilot clones this to steer.
    margin: Option<MarginKnob>,
}

impl Server {
    /// Spawn `cfg.workers` threads, each owning one engine from `make_engine`.
    pub fn start(
        cfg: ServerConfig,
        make_engine: impl Fn(usize) -> crate::Result<Box<dyn InferenceEngine>>,
    ) -> crate::Result<Self> {
        let metrics = Arc::new(ServerMetrics::new());
        Self::start_with_metrics(cfg, metrics, make_engine)
    }

    /// [`Server::start`] with a caller-provided metrics sink, so engine
    /// factories can hook the same sink into their engines (the zoo path:
    /// `RouterEngine::with_metrics` flushes per-tier counters into it).
    /// The zoo depth is read off the engines themselves
    /// ([`InferenceEngine::num_tiers`]), so ANY tier-aware engine served
    /// through [`Server::start`] — not just `start_zoo`'s — keeps its
    /// tier pins.
    fn start_with_metrics(
        cfg: ServerConfig,
        metrics: Arc<ServerMetrics>,
        make_engine: impl Fn(usize) -> crate::Result<Box<dyn InferenceEngine>>,
    ) -> crate::Result<Self> {
        // Engines are built BEFORE the queue: the slab feature arena is
        // sized `capacity + workers × max_batch` rows of the engines'
        // feature width, so in-flight batches can never starve admission
        // (`SubmitError::Full` keeps meaning exactly "queue full"). A
        // worker-less server still probes the factory once to learn the
        // served shape.
        let mut engines = Vec::with_capacity(cfg.workers.max(1));
        for w in 0..cfg.workers.max(1) {
            engines.push(make_engine(w)?);
        }
        let num_features = engines[0].num_features();
        let num_tiers = engines[0].num_tiers();
        metrics.set_kernel_path(engines[0].kernel_path());
        // Workers share Arc'd tables, so engine 0 speaks for the
        // server's resident model footprint (zoo engines re-report on
        // swap through their own with_metrics hook).
        metrics.set_model_bytes(engines[0].model_bytes(), engines[0].tier_model_bytes());
        let queue = Arc::new(BoundedQueue::with_in_flight(
            cfg.batcher,
            num_features,
            cfg.workers.max(1) * cfg.batcher.max_batch,
        ));
        engines.truncate(cfg.workers); // drop the shape probe on workers == 0
        let mut workers = Vec::with_capacity(cfg.workers);
        for mut engine in engines {
            let queue = queue.clone();
            let metrics = metrics.clone();
            workers.push(std::thread::spawn(move || {
                worker_loop(&mut *engine, &queue, &metrics);
            }));
        }
        Ok(Self {
            queue,
            metrics,
            workers,
            next_id: AtomicU64::new(0),
            num_features,
            num_tiers,
            margin: None,
        })
    }

    /// Start a server whose workers each own a **model zoo**: a
    /// [`ModelRouter`] over one [`NativeEngine`](crate::runtime::NativeEngine)
    /// per model (small → large), wrapped in a [`RouterEngine`]. Tier-pinned requests
    /// ([`Server::submit_tiered`] with `Some(tier)`) dispatch as one
    /// batch call on that tier's engine; default requests run the batched
    /// confidence cascade. Per-tier served/escalation/latency counters
    /// flush into [`Server::metrics`] after every micro-batch and are
    /// part of the shutdown [`MetricsReport`](crate::coordinator::metrics::MetricsReport).
    pub fn start_zoo(
        cfg: ServerConfig,
        models: Vec<crate::model::ensemble::UleenModel>,
        margin_threshold: f32,
    ) -> crate::Result<Self> {
        let tiers = compile_zoo(models)?;
        Self::start_zoo_shared(cfg, tiers, margin_threshold)
    }

    /// [`Server::start_zoo`] over already-compiled tiers: every worker's
    /// router is built with [`ModelRouter::from_shared`], so N workers
    /// hold `Arc` handles into ONE copy of each tier instead of cloning
    /// the zoo per worker (memory used to grow ∝ workers × tiers —
    /// ROADMAP follow-up (h); the `Arc::strong_count` witness test pins
    /// the sharing down).
    pub fn start_zoo_shared(
        cfg: ServerConfig,
        tiers: Vec<SharedModel>,
        margin_threshold: f32,
    ) -> crate::Result<Self> {
        let metrics = Arc::new(ServerMetrics::new());
        let shared = metrics.clone();
        // ONE margin knob across all workers' routers: the autopilot (or
        // any holder of Server::margin_knob) turns it and every worker
        // follows at its next batch.
        let knob = MarginKnob::new(margin_threshold);
        let worker_knob = knob.clone();
        let mut server = Self::start_with_metrics(cfg, metrics, move |_| {
            let mut router = ModelRouter::from_shared(&tiers);
            router.share_margin(&worker_knob);
            Ok(Box::new(RouterEngine::new(router).with_metrics(shared.clone()))
                as Box<dyn InferenceEngine>)
        })?;
        server.margin = Some(knob);
        Ok(server)
    }

    /// Start a server whose single worker owns a
    /// [`ShardedRouterEngine`]: the cascade × shard composition — each
    /// micro-batch splits into contiguous row ranges, every range runs
    /// the batched confidence cascade (or its pinned tier) on a persistent
    /// pool worker, per-tier counters merge deterministically into
    /// [`Server::metrics`], and all `shards` workers probe ONE `Arc`-shared
    /// copy of each tier. The alternative to [`Server::start_zoo`]'s
    /// per-worker zoos when batches are large: one big batch split N ways
    /// beats N zoos pulling small batches.
    pub fn start_zoo_sharded(
        cfg: ServerConfig,
        models: Vec<crate::model::ensemble::UleenModel>,
        margin_threshold: f32,
        shards: usize,
    ) -> crate::Result<Self> {
        let tiers = compile_zoo(models)?;
        let cfg = ServerConfig { workers: 1, ..cfg };
        let metrics = Arc::new(ServerMetrics::new());
        let shared = metrics.clone();
        let knob = MarginKnob::new(margin_threshold);
        let worker_knob = knob.clone();
        let mut server = Self::start_with_metrics(cfg, metrics, move |_| {
            let mut eng = ShardedRouterEngine::from_shared(tiers.clone(), margin_threshold, shards);
            eng.share_margin(&worker_knob);
            Ok(Box::new(eng.with_metrics(shared.clone())) as Box<dyn InferenceEngine>)
        })?;
        server.margin = Some(knob);
        Ok(server)
    }

    /// Start a server whose single worker owns one
    /// [`ShardedEngine`](crate::runtime::ShardedEngine) fanning each
    /// micro-batch across `shards` threads — the alternative to
    /// `cfg.workers` independent engines when batches are large: one big
    /// batch split N ways beats N engines pulling small batches, because
    /// the fused bit-sliced kernel amortizes its CSR traversal over 64
    /// samples. The engine's worker pool spawns once here and is reused
    /// across every micro-batch for the server's lifetime (zero thread
    /// spawns on the serving hot path); it joins when the worker drops
    /// the engine during [`Server::shutdown`].
    pub fn start_sharded(
        cfg: ServerConfig,
        model: crate::model::ensemble::UleenModel,
        shards: usize,
    ) -> crate::Result<Self> {
        let cfg = ServerConfig { workers: 1, ..cfg };
        Self::start(cfg, move |_| {
            Ok(Box::new(crate::runtime::ShardedEngine::new(model.clone(), shards))
                as Box<dyn InferenceEngine>)
        })
    }

    pub fn num_features(&self) -> usize {
        self.num_features
    }

    /// The serving micro-batch ceiling — what metrics reports take as
    /// their batch-fill denominator (the HTTP front-end's `/metrics`
    /// route needs it without seeing the queue).
    pub fn max_batch(&self) -> usize {
        self.queue.config().max_batch
    }

    /// The shared cascade-margin knob every worker router reads, on zoo
    /// servers (`None` when there is no cascade to steer). Clone it into
    /// an [`Autopilot`](crate::coordinator::autopilot::Autopilot).
    pub fn margin_knob(&self) -> Option<MarginKnob> {
        self.margin.clone()
    }

    /// The queue's live dwell budget — every consumer reads it at the
    /// top of each dwell, so a retune applies to the very next batch.
    pub fn dwell_knob(&self) -> DwellKnob {
        self.queue.dwell_knob()
    }

    /// Submit one request on the default path (cascade on zoo servers);
    /// the prediction arrives on `done` as `(id, predicted class)`. The
    /// row is copied straight into the queue's slab arena — the caller
    /// keeps ownership of (and may immediately reuse) `features`.
    pub fn submit(
        &self,
        features: &[f32],
        done: mpsc::Sender<(u64, usize)>,
    ) -> Result<u64, SubmitError> {
        self.submit_tiered(features, None, done)
    }

    /// Submit one request with an optional service class: `Some(tier)`
    /// pins it to that zoo tier, `None` takes the default path (the
    /// batched confidence cascade on zoo servers, the single model
    /// otherwise). The batcher keeps batches tier-homogeneous, so the
    /// tier is canonicalized first: on tier-blind servers every pin
    /// becomes `None`, and on a zoo aliased tiers (Balanced vs Accurate
    /// on 2 tiers) collapse to one value — a hint the engine resolves
    /// identically must not split micro-batches.
    pub fn submit_tiered(
        &self,
        features: &[f32],
        tier: Option<Tier>,
        done: mpsc::Sender<(u64, usize)>,
    ) -> Result<u64, SubmitError> {
        let tier = match self.num_tiers {
            0 => None,
            k => tier.map(|t| crate::coordinator::router::canonical_tier(t, k)),
        };
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let enqueued = Instant::now();
        match self.queue.submit_row(id, features, tier, enqueued, done) {
            Ok(()) => {
                // Start the throughput wall-clock only on ACCEPTED work
                // (at its enqueue time): a burst that is entirely
                // rejected must not start — and thereby skew — the
                // denominator of every later rate.
                self.metrics.mark_start_at(enqueued);
                Ok(id)
            }
            Err(e) => {
                self.metrics.record_reject(e == SubmitError::Full);
                Err(e)
            }
        }
    }

    pub fn queue_depth(&self) -> usize {
        self.queue.depth()
    }

    /// Arena witness: `(free slots now, total slots)`. Leak tests assert
    /// free == total once the server has drained — every dispatched
    /// batch (served, malformed, or engine-failed) must hand its slots
    /// back.
    pub fn arena_slots(&self) -> (usize, usize) {
        (self.queue.free_slots(), self.queue.arena_slots())
    }

    /// Stop accepting new requests — submitters observe
    /// [`SubmitError::Closed`] — while workers keep draining the backlog.
    /// Idempotent; call [`Server::shutdown`] afterwards to join workers.
    pub fn close(&self) {
        self.queue.close();
    }

    /// Drain and stop. Returns when every worker has exited.
    pub fn shutdown(self) {
        self.queue.close();
        for w in self.workers {
            let _ = w.join();
        }
    }
}

/// Validate a zoo (1..=3 models ordered small → large, all sharing one
/// feature width and class count) and compile each tier exactly ONCE into
/// an `Arc`-shared [`SharedModel`] — the single zoo-construction funnel
/// for [`Server::start_zoo`] and [`Server::start_zoo_sharded`].
fn compile_zoo(
    models: Vec<crate::model::ensemble::UleenModel>,
) -> crate::Result<Vec<SharedModel>> {
    anyhow::ensure!(
        (1..=3).contains(&models.len()),
        "zoo wants 1..=3 models, got {}",
        models.len()
    );
    for m in &models[1..] {
        anyhow::ensure!(
            m.encoder.num_inputs == models[0].encoder.num_inputs
                && m.num_classes() == models[0].num_classes(),
            "zoo models must share feature width and class count"
        );
    }
    Ok(models.into_iter().map(SharedModel::compile).collect())
}

fn worker_loop(
    engine: &mut dyn InferenceEngine,
    queue: &BoundedQueue,
    metrics: &ServerMetrics,
) {
    let f = engine.num_features();
    // Grow-only per-worker buffers, reused across every micro-batch: the
    // popped batch, the gather scratch (used only when a batch's arena
    // slots are non-consecutive — consecutive runs are borrowed straight
    // out of the slab), the accepted requests, the prediction plane the
    // engine writes into (`classify_routed_into`), and the latency
    // staging. A warm worker's serving loop performs no steady-state
    // allocations of its own — the engines underneath uphold the same
    // contract (see the `InferenceEngine` write-into docs).
    let mut batch: Vec<Request> = Vec::new();
    let mut flat: Vec<f32> = Vec::new();
    let mut good: Vec<Request> = Vec::new();
    let mut preds: Vec<usize> = Vec::new();
    let mut lats: Vec<std::time::Duration> = Vec::new();
    while queue.next_batch_into(&mut batch) {
        // Batches are tier-homogeneous by construction, so the whole
        // batch dispatches as one routed engine call. (next_batch_into
        // never yields an empty batch; guard anyway so a future batcher
        // change cannot panic the worker.)
        let Some(first) = batch.first() else { continue };
        let tier = first.tier;
        // Reject ONLY wrong-width requests (their senders disconnect, so
        // callers observe the drop); their batch-mates still complete.
        // Malformed slots go straight back to the free-list.
        good.clear();
        let mut malformed = 0u64;
        for r in batch.drain(..) {
            if r.is_well_formed(f) {
                good.push(r);
            } else {
                malformed += 1;
                queue.release(std::slice::from_ref(&r));
            }
        }
        if malformed > 0 {
            metrics.record_malformed(malformed);
        }
        if good.is_empty() {
            continue;
        }
        let n = good.len();
        if preds.len() < n {
            preds.resize(n, 0);
        }
        let result = {
            let x = queue.gather(&good, &mut flat);
            engine.classify_routed_into(x, n, tier, &mut preds)
        };
        // Slots return to the free-list on BOTH paths — an engine
        // failure must not leak arena capacity. The gathered slice is
        // dead by here, so recycling is safe.
        queue.release(&good);
        match result {
            Ok(()) => {
                let now = Instant::now();
                lats.clear();
                lats.extend(good.iter().map(|r| now - r.enqueued));
                metrics.record_batch(n, &lats);
                for (r, &p) in good.drain(..).zip(preds.iter()) {
                    let _ = r.done.send((r.id, p));
                }
            }
            Err(_) => {
                // Engine failure: drop the batch (callers observe the
                // closed channel) but COUNT it — overload tests and
                // operators watch `batches_failed`.
                metrics.record_batch_failure();
                good.clear();
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::NativeEngine;
    use crate::data::synth_uci::{synth_uci, uci_spec};
    use crate::train::oneshot::{train_oneshot, OneShotConfig};
    use std::time::Duration;

    fn served_model() -> crate::model::ensemble::UleenModel {
        let ds = synth_uci(5, uci_spec("iris").unwrap());
        train_oneshot(&ds, &OneShotConfig::default()).0
    }

    #[test]
    fn serves_requests_and_matches_direct_inference() {
        let model = served_model();
        let ds = synth_uci(5, uci_spec("iris").unwrap());
        let expected: Vec<usize> = {
            let mut s = crate::model::ensemble::EnsembleScratch::default();
            (0..ds.n_test()).map(|i| model.predict(ds.test_row(i), &mut s)).collect()
        };
        let cfg = ServerConfig {
            batcher: BatcherConfig {
                max_batch: 8,
                max_wait: Duration::from_micros(100),
                capacity: 1024,
            },
            workers: 3,
        };
        let m2 = model.clone();
        let server = Server::start(cfg, move |_| {
            Ok(Box::new(NativeEngine::new(m2.clone())))
        })
        .unwrap();
        let (tx, rx) = mpsc::channel();
        let mut id2row = std::collections::HashMap::new();
        for i in 0..ds.n_test() {
            let id = server.submit(ds.test_row(i), tx.clone()).unwrap();
            id2row.insert(id, i);
        }
        drop(tx);
        let mut got = vec![usize::MAX; ds.n_test()];
        for _ in 0..ds.n_test() {
            let (id, pred) = rx.recv_timeout(Duration::from_secs(5)).unwrap();
            got[id2row[&id]] = pred;
        }
        server.shutdown();
        assert_eq!(got, expected, "served predictions must equal direct inference");
    }

    #[test]
    fn shutdown_drains_inflight() {
        let model = served_model();
        let server = Server::start(ServerConfig::default(), move |_| {
            Ok(Box::new(NativeEngine::new(model.clone())))
        })
        .unwrap();
        let (tx, rx) = mpsc::channel();
        let n = 64;
        let row = vec![0.5; server.num_features()];
        for _ in 0..n {
            server.submit(&row, tx.clone()).unwrap();
        }
        drop(tx);
        server.shutdown();
        let mut count = 0;
        while rx.try_recv().is_ok() {
            count += 1;
        }
        assert_eq!(count, n, "all in-flight requests complete before shutdown");
    }

    #[test]
    fn zoo_server_serves_pinned_and_cascade_with_tier_metrics() {
        let ds = synth_uci(5, uci_spec("iris").unwrap());
        let mut models = Vec::new();
        for (inputs, entries, bits) in [(6usize, 64usize, 2usize), (10, 128, 4)] {
            models.push(
                train_oneshot(
                    &ds,
                    &OneShotConfig {
                        inputs_per_filter: inputs,
                        entries_per_filter: entries,
                        therm_bits: bits,
                        ..Default::default()
                    },
                )
                .0,
            );
        }
        let cfg = ServerConfig {
            batcher: BatcherConfig {
                max_batch: 16,
                max_wait: Duration::from_micros(100),
                capacity: 1024,
            },
            workers: 2,
        };
        let server = Server::start_zoo(cfg, models, 0.05).unwrap();
        // zoo servers expose both autopilot knobs, seeded from the config
        let knob = server.margin_knob().expect("zoo servers expose the margin knob");
        assert_eq!(knob.get(), 0.05);
        assert_eq!(server.dwell_knob().get(), Duration::from_micros(100));
        let (tx, rx) = mpsc::channel();
        let n = ds.n_test();
        for i in 0..n {
            let tier = match i % 3 {
                0 => None, // cascade
                1 => Some(Tier::Fast),
                _ => Some(Tier::Accurate),
            };
            loop {
                match server.submit_tiered(ds.test_row(i), tier, tx.clone()) {
                    Ok(_) => break,
                    Err(SubmitError::Full) => std::thread::sleep(Duration::from_micros(20)),
                    Err(e) => panic!("{e:?}"),
                }
            }
        }
        drop(tx);
        let mut served = 0;
        while rx.recv_timeout(Duration::from_secs(10)).is_ok() {
            served += 1;
        }
        assert_eq!(served, n, "every pinned and cascade request completes");
        let report = server.metrics.report(16);
        server.shutdown();
        // every request touches tier 0 unless pinned Accurate; pinned
        // Accurate traffic plus escalations land on the last tier
        assert!(report.tier_served[0] as usize >= 2 * n / 3, "fast tier traffic");
        assert!(report.tier_served[1] as usize >= n / 3, "accurate tier pinned traffic");
        assert!(report.tier_mean_us[0] > 0.0, "tier latency counters populate");
    }

    #[test]
    fn zoo_workers_share_one_arc_copy_per_tier() {
        // ROADMAP follow-up (h): N workers' routers must hold Arc handles
        // into ONE copy of each tier, not per-worker clones.
        let ds = synth_uci(5, uci_spec("iris").unwrap());
        let mut tiers = Vec::new();
        for (inputs, entries, bits) in [(6usize, 64usize, 2usize), (10, 128, 4)] {
            let model = train_oneshot(
                &ds,
                &OneShotConfig {
                    inputs_per_filter: inputs,
                    entries_per_filter: entries,
                    therm_bits: bits,
                    ..Default::default()
                },
            )
            .0;
            tiers.push(crate::runtime::SharedModel::compile(model));
        }
        let workers = 3usize;
        let cfg = ServerConfig { batcher: BatcherConfig::default(), workers };
        let server = Server::start_zoo_shared(cfg, tiers.clone(), 0.05).unwrap();
        for (i, t) in tiers.iter().enumerate() {
            assert_eq!(
                Arc::strong_count(t.model()),
                1 + workers,
                "tier {i}: one handle here + one per worker, zero clones"
            );
            assert_eq!(Arc::strong_count(t.flat()), 1 + workers, "tier {i} flat layout");
        }
        // the shared zoo still serves
        let (tx, rx) = mpsc::channel();
        server.submit(ds.test_row(0), tx).unwrap();
        rx.recv_timeout(Duration::from_secs(5)).unwrap();
        server.shutdown();
        for t in &tiers {
            assert_eq!(Arc::strong_count(t.model()), 1, "shutdown releases every handle");
        }
    }

    #[test]
    fn overload_rejects_with_backpressure() {
        let model = served_model();
        let cfg = ServerConfig {
            batcher: BatcherConfig {
                max_batch: 4,
                max_wait: Duration::from_millis(50),
                capacity: 4,
            },
            workers: 1,
        };
        let server = Server::start(cfg, move |_| {
            Ok(Box::new(NativeEngine::new(model.clone())))
        })
        .unwrap();
        let (tx, _rx) = mpsc::channel();
        let row = vec![0.5; server.num_features()];
        let mut rejected = 0;
        for _ in 0..256 {
            if server.submit(&row, tx.clone()).is_err() {
                rejected += 1;
            }
        }
        assert!(rejected > 0, "tiny queue must reject under burst load");
        server.shutdown();
    }

    #[test]
    fn submit_to_complete_is_allocation_free_on_the_caller_thread() {
        // The queue side of the zero-alloc contract: once the channel
        // flavor has upgraded and every grow-only buffer is warm, a
        // submit→complete round trip performs ZERO heap allocations on
        // the caller thread — the row goes into a slab slot, the request
        // into a ring cell, and the completion is a plain (id, pred)
        // tuple. (The worker thread's mpsc send node is the documented
        // per-thread exception, same as the shard pool's channel nodes.)
        let model = served_model();
        let cfg = ServerConfig {
            batcher: BatcherConfig {
                max_batch: 64,
                max_wait: Duration::from_micros(50),
                capacity: 1024,
            },
            workers: 1,
        };
        let server = Server::start(cfg, move |_| {
            Ok(Box::new(NativeEngine::new(model.clone())))
        })
        .unwrap();
        let (tx, rx) = mpsc::channel();
        let row = vec![0.5; server.num_features()];
        let mut wave = |k: usize| {
            for _ in 0..k {
                server.submit(&row, tx.clone()).unwrap();
            }
            for _ in 0..k {
                rx.recv_timeout(Duration::from_secs(5)).unwrap();
            }
        };
        for _ in 0..3 {
            wave(64); // warm: channel upgrade, ring/scratch/plane growth
        }
        let w = crate::util::alloc_witness::Witness::begin();
        for _ in 0..4 {
            wave(64);
        }
        assert_eq!(
            w.allocations(),
            0,
            "steady-state submit→complete must not allocate on the caller thread"
        );
        server.shutdown();
    }

    #[test]
    fn arena_free_list_never_leaks_slots_under_close_while_draining() {
        // Every dispatched request — served, malformed, or part of a
        // batch the engine failed — must hand its arena slot back. Close
        // the server mid-drain and assert the free-list refills to the
        // arena's full size.
        let model = served_model();
        let cfg = ServerConfig {
            batcher: BatcherConfig {
                max_batch: 8,
                max_wait: Duration::from_micros(50),
                capacity: 512,
            },
            workers: 2,
        };
        let server = Server::start(cfg, move |_| {
            Ok(Box::new(NativeEngine::new(model.clone())))
        })
        .unwrap();
        let (tx, rx) = mpsc::channel();
        let f = server.num_features();
        let row = vec![0.5; f];
        let bad = vec![0.5; f + 2];
        let mut accepted = 0usize;
        let mut malformed_sent = 0usize;
        for i in 0..256 {
            let r: &[f32] = if i % 9 == 0 { &bad } else { &row };
            match server.submit(r, tx.clone()) {
                Ok(_) => {
                    accepted += 1;
                    if i % 9 == 0 {
                        malformed_sent += 1;
                    }
                }
                Err(SubmitError::Full) => std::thread::sleep(Duration::from_micros(20)),
                // Racing submits after the close below are the point —
                // they must reject cleanly while the drain continues.
                Err(SubmitError::Closed) => break,
            }
            if i == 128 {
                server.close(); // close mid-stream; workers keep draining
            }
        }
        drop(tx);
        let mut served = 0usize;
        while rx.recv_timeout(Duration::from_secs(5)).is_ok() {
            served += 1;
        }
        assert_eq!(
            served,
            accepted - malformed_sent,
            "every accepted well-formed request completes through the drain"
        );
        // Workers release slots after completing; poll briefly for the
        // last batch's release before asserting.
        let deadline = std::time::Instant::now() + Duration::from_secs(5);
        loop {
            let (free, total) = server.arena_slots();
            if free == total {
                break;
            }
            assert!(
                std::time::Instant::now() < deadline,
                "arena leaked slots: {free} free of {total}"
            );
            std::thread::sleep(Duration::from_millis(1));
        }
        server.shutdown();
    }
}
