//! Latency autopilot: an SLO controller that tunes the two serving
//! latency knobs — cascade margin and batcher dwell — online against a
//! target p99 (`uleen serve --target-p99-ms X`).
//!
//! The control loop is bounded AIMD with a hysteresis band: each tick it
//! drains the windowed latency view from [`ServerMetrics`] (recent
//! completions only — the cumulative histogram keeps serving `/metrics`)
//! and compares the window's p99 against the target. Above the band it
//! **tightens** multiplicatively (halve margin → fewer cascade
//! escalations, halve dwell → less queueing); below the band it
//! **relaxes** additively (margin back up toward accuracy, dwell back up
//! toward batch fill). Both knobs are hard-clamped to configured
//! `[min, max]` ranges, so a misbehaving window can never drive the
//! server into a degenerate configuration. Inside the band — or when the
//! window is too thin to trust — it holds.
//!
//! The knobs themselves are lock-free shared handles: [`MarginKnob`] is
//! one `Arc<AtomicU32>` (f32 bit-cast) read by `ModelRouter`,
//! `RouterEngine` and every per-worker router inside
//! `ShardedRouterEngine` (one knob, N readers — cloning the handle
//! clones the `Arc`, not the value), and [`DwellKnob`] is an
//! `Arc<AtomicU64>` of nanoseconds the batcher reads at the top of each
//! dwell. With no autopilot attached both knobs simply hold their static
//! CLI values, so serving behavior is bit-exact with the pre-autopilot
//! code path.

use crate::coordinator::metrics::{AutopilotStatus, LatencyWindow, ServerMetrics};
use std::sync::atomic::{AtomicBool, AtomicU32, AtomicU64, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

/// Shared cascade-margin knob: an f32 bit-cast through one
/// `Arc<AtomicU32>`. Clones share the SAME atomic, so one `set` is seen
/// by every router holding a handle.
#[derive(Clone, Debug)]
pub struct MarginKnob {
    bits: Arc<AtomicU32>,
}

impl MarginKnob {
    pub fn new(margin: f32) -> Self {
        Self { bits: Arc::new(AtomicU32::new(margin.to_bits())) }
    }

    pub fn get(&self) -> f32 {
        f32::from_bits(self.bits.load(Ordering::Relaxed))
    }

    pub fn set(&self, margin: f32) {
        self.bits.store(margin.to_bits(), Ordering::Relaxed);
    }

    /// True when both handles wrap the same underlying atomic — the
    /// "one knob, N readers" sharing tests pin this down.
    pub fn shares_with(&self, other: &MarginKnob) -> bool {
        Arc::ptr_eq(&self.bits, &other.bits)
    }
}

/// Shared batch-dwell knob: nanoseconds in one `Arc<AtomicU64>`, read by
/// the batcher at the top of each dwell (so a change applies from the
/// next micro-batch on, never mid-dwell).
#[derive(Clone, Debug)]
pub struct DwellKnob {
    nanos: Arc<AtomicU64>,
}

impl DwellKnob {
    pub fn new(dwell: Duration) -> Self {
        Self { nanos: Arc::new(AtomicU64::new(dwell.as_nanos().min(u64::MAX as u128) as u64)) }
    }

    pub fn get(&self) -> Duration {
        Duration::from_nanos(self.nanos.load(Ordering::Relaxed))
    }

    pub fn set(&self, dwell: Duration) {
        self.nanos
            .store(dwell.as_nanos().min(u64::MAX as u128) as u64, Ordering::Relaxed);
    }

    /// True when both handles wrap the same underlying atomic.
    pub fn shares_with(&self, other: &DwellKnob) -> bool {
        Arc::ptr_eq(&self.nanos, &other.nanos)
    }
}

/// Controller parameters: target, cadence, hysteresis, and the hard
/// clamp ranges + step sizes for both knobs.
#[derive(Clone, Debug)]
pub struct AutopilotConfig {
    /// The p99 SLO, in milliseconds, the controller steers toward.
    pub target_p99_ms: f64,
    /// Control period: one window drain + at most one decision per tick.
    pub interval: Duration,
    /// Hysteresis band as a fraction of the target: no action while the
    /// window p99 sits inside `target * (1 ± hysteresis)`.
    pub hysteresis: f64,
    /// Windows with fewer samples than this are held, not acted on —
    /// a thin window's p99 is noise.
    pub min_window: u64,
    /// Hard clamp range for the cascade margin.
    pub margin_min: f32,
    pub margin_max: f32,
    /// Additive margin step on relax (decrease is multiplicative: ×1/2).
    pub margin_step: f32,
    /// Hard clamp range for the batch dwell.
    pub dwell_min: Duration,
    pub dwell_max: Duration,
    /// Additive dwell step on relax (decrease is multiplicative: ×1/2).
    pub dwell_step: Duration,
}

impl Default for AutopilotConfig {
    fn default() -> Self {
        Self {
            target_p99_ms: 5.0,
            interval: Duration::from_millis(20),
            hysteresis: 0.1,
            min_window: 16,
            margin_min: 0.0,
            margin_max: 1.0,
            margin_step: 0.01,
            dwell_min: Duration::from_micros(50),
            dwell_max: Duration::from_millis(5),
            dwell_step: Duration::from_micros(20),
        }
    }
}

/// What one control tick did.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Decision {
    /// Window p99 above the band: multiplicative decrease of both knobs.
    Tighten,
    /// Window p99 below the band: additive increase of both knobs.
    Relax,
    /// Inside the band, or the window was too thin to trust.
    Hold,
}

/// One AIMD step, pure in everything but the knob stores: reads the
/// drained window, moves the knobs (margin is optional — tier-blind
/// servers have no cascade), returns what it decided. The clamps apply
/// on EVERY write, so knobs that start outside `[min, max]` (a static
/// CLI value beyond the clamp) are pulled into range on first action.
pub fn step(
    cfg: &AutopilotConfig,
    window: &LatencyWindow,
    margin: Option<&MarginKnob>,
    dwell: &DwellKnob,
) -> Decision {
    if window.count < cfg.min_window {
        return Decision::Hold;
    }
    let p99_ms = window.p99_us / 1e3;
    if p99_ms > cfg.target_p99_ms * (1.0 + cfg.hysteresis) {
        if let Some(m) = margin {
            // Halving asymptotes toward margin_min but never lands on it,
            // so sustained overload would leave a uselessly-tiny-but-
            // nonzero margin forever (and tie rows treat 1e-19 and 0.0
            // differently). Snap to the floor once a halving lands
            // within one relax step of it — the AIMD floor.
            let halved = m.get() * 0.5;
            let next = if halved <= cfg.margin_min + cfg.margin_step {
                cfg.margin_min
            } else {
                halved
            };
            m.set(next.clamp(cfg.margin_min, cfg.margin_max));
        }
        dwell.set((dwell.get() / 2).clamp(cfg.dwell_min, cfg.dwell_max));
        Decision::Tighten
    } else if p99_ms < cfg.target_p99_ms * (1.0 - cfg.hysteresis) {
        if let Some(m) = margin {
            m.set((m.get() + cfg.margin_step).clamp(cfg.margin_min, cfg.margin_max));
        }
        dwell.set(
            dwell
                .get()
                .saturating_add(cfg.dwell_step)
                .clamp(cfg.dwell_min, cfg.dwell_max),
        );
        Decision::Relax
    } else {
        Decision::Hold
    }
}

/// The controller thread. Started only when `--target-p99-ms` is given;
/// with no autopilot the knobs hold their static values and the serving
/// path is byte-for-byte the pre-autopilot one.
pub struct Autopilot {
    stop: Arc<AtomicBool>,
    handle: Option<JoinHandle<()>>,
}

impl Autopilot {
    /// Spawn the control loop over a server's metrics sink and knob
    /// handles. `margin` is `None` for tier-blind (single-model)
    /// servers — the autopilot then steers dwell alone.
    pub fn start(
        cfg: AutopilotConfig,
        metrics: Arc<ServerMetrics>,
        margin: Option<MarginKnob>,
        dwell: DwellKnob,
    ) -> Self {
        let stop = Arc::new(AtomicBool::new(false));
        let stop_flag = stop.clone();
        let handle = std::thread::Builder::new()
            .name("uleen-autopilot".into())
            .spawn(move || {
                let mut tighten = 0u64;
                let mut relax = 0u64;
                let mut hold = 0u64;
                // Publish the starting knob values immediately so a
                // `/metrics` scrape shows the controller attached even
                // before the first decision.
                publish(&metrics, &cfg, margin.as_ref(), &dwell, tighten, relax, hold);
                while !stop_flag.load(Ordering::Relaxed) {
                    // Sleep in small slices so stop() never waits a full
                    // interval behind a long cadence.
                    let mut slept = Duration::ZERO;
                    while slept < cfg.interval && !stop_flag.load(Ordering::Relaxed) {
                        let chunk = (cfg.interval - slept).min(Duration::from_millis(5));
                        std::thread::sleep(chunk);
                        slept += chunk;
                    }
                    if stop_flag.load(Ordering::Relaxed) {
                        break;
                    }
                    let window = metrics.drain_latency_window();
                    match step(&cfg, &window, margin.as_ref(), &dwell) {
                        Decision::Tighten => tighten += 1,
                        Decision::Relax => relax += 1,
                        Decision::Hold => hold += 1,
                    }
                    publish(&metrics, &cfg, margin.as_ref(), &dwell, tighten, relax, hold);
                }
            })
            .expect("spawn autopilot thread");
        Self { stop, handle: Some(handle) }
    }

    /// Signal the loop and join it. Idempotent via Drop.
    pub fn stop(mut self) {
        self.halt();
    }

    fn halt(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for Autopilot {
    fn drop(&mut self) {
        self.halt();
    }
}

fn publish(
    metrics: &ServerMetrics,
    cfg: &AutopilotConfig,
    margin: Option<&MarginKnob>,
    dwell: &DwellKnob,
    tighten: u64,
    relax: u64,
    hold: u64,
) {
    metrics.set_autopilot(AutopilotStatus {
        target_p99_ms: cfg.target_p99_ms,
        margin: margin.map(|m| m.get()),
        dwell_us: dwell.get().as_secs_f64() * 1e6,
        tighten,
        relax,
        hold,
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    fn window(count: u64, p99_us: f64) -> LatencyWindow {
        LatencyWindow { count, p50_us: p99_us / 2.0, p99_us }
    }

    #[test]
    fn step_tightens_above_the_band_and_relaxes_below() {
        let cfg = AutopilotConfig { target_p99_ms: 2.0, ..Default::default() };
        let margin = MarginKnob::new(0.8);
        let dwell = DwellKnob::new(Duration::from_millis(4));
        // 10 ms ≫ 2 ms target: multiplicative decrease on both knobs
        let d = step(&cfg, &window(100, 10_000.0), Some(&margin), &dwell);
        assert_eq!(d, Decision::Tighten);
        assert_eq!(margin.get(), 0.4);
        assert_eq!(dwell.get(), Duration::from_millis(2));
        // 0.1 ms ≪ 2 ms target: additive increase on both knobs
        let d = step(&cfg, &window(100, 100.0), Some(&margin), &dwell);
        assert_eq!(d, Decision::Relax);
        assert!((margin.get() - 0.41).abs() < 1e-6);
        assert_eq!(dwell.get(), Duration::from_millis(2) + cfg.dwell_step);
        // inside the hysteresis band: hold, knobs untouched
        let (m0, w0) = (margin.get(), dwell.get());
        let d = step(&cfg, &window(100, 2_000.0), Some(&margin), &dwell);
        assert_eq!(d, Decision::Hold);
        assert_eq!(margin.get(), m0);
        assert_eq!(dwell.get(), w0);
    }

    #[test]
    fn step_holds_on_thin_windows_and_respects_clamps() {
        let cfg = AutopilotConfig { target_p99_ms: 1.0, min_window: 16, ..Default::default() };
        let margin = MarginKnob::new(0.05);
        let dwell = DwellKnob::new(Duration::from_micros(200));
        assert_eq!(step(&cfg, &window(3, 99_000.0), Some(&margin), &dwell), Decision::Hold);
        assert_eq!(margin.get(), 0.05);
        // Hammer tighten: both knobs pin at their minima, never below.
        for _ in 0..40 {
            step(&cfg, &window(100, 50_000.0), Some(&margin), &dwell);
        }
        assert_eq!(margin.get(), cfg.margin_min);
        assert_eq!(dwell.get(), cfg.dwell_min);
        // Hammer relax: both knobs pin at their maxima, never above.
        for _ in 0..2_000 {
            step(&cfg, &window(100, 1.0), Some(&margin), &dwell);
        }
        assert_eq!(margin.get(), cfg.margin_max);
        assert_eq!(dwell.get(), cfg.dwell_max);
    }

    #[test]
    fn knob_clones_share_one_atomic() {
        let m = MarginKnob::new(0.1);
        let m2 = m.clone();
        m2.set(0.7);
        assert_eq!(m.get(), 0.7);
        assert!(m.shares_with(&m2));
        assert!(!m.shares_with(&MarginKnob::new(0.7)));
        let d = DwellKnob::new(Duration::from_micros(100));
        let d2 = d.clone();
        d2.set(Duration::from_micros(900));
        assert_eq!(d.get(), Duration::from_micros(900));
        assert!(d.shares_with(&d2));
        assert!(!d.shares_with(&DwellKnob::new(Duration::ZERO)));
    }

    #[test]
    fn autopilot_thread_publishes_and_steers_to_the_metrics_sink() {
        let metrics = Arc::new(ServerMetrics::new());
        let margin = MarginKnob::new(0.9);
        let dwell = DwellKnob::new(Duration::from_millis(5));
        let cfg = AutopilotConfig {
            target_p99_ms: 1.0,
            interval: Duration::from_millis(5),
            min_window: 1,
            ..Default::default()
        };
        let ap = Autopilot::start(cfg, metrics.clone(), Some(margin.clone()), dwell.clone());
        // Feed the window slow completions until the controller reacts.
        let slow = [Duration::from_millis(20); 4];
        let deadline = std::time::Instant::now() + Duration::from_secs(10);
        while margin.get() >= 0.9 && std::time::Instant::now() < deadline {
            metrics.record_batch(4, &slow);
            std::thread::sleep(Duration::from_millis(2));
        }
        ap.stop();
        assert!(margin.get() < 0.9, "controller never tightened the margin");
        assert!(dwell.get() < Duration::from_millis(5), "controller never cut the dwell");
        let status = metrics.report(16).autopilot.expect("autopilot status published");
        assert!(status.tighten >= 1);
        assert_eq!(status.target_p99_ms, 1.0);
        assert_eq!(status.margin, Some(margin.get()));
    }
}
