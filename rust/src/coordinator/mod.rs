//! Layer-3 serving coordinator — the runtime system around the model.
//!
//! The paper's accelerator (Fig 8) operates in lockstep on whole input
//! batches fed over a fixed-width bus; this module is the software
//! coordinator a deployment would wrap around it (or around the native /
//! PJRT engines): a bounded request queue with backpressure, a dynamic
//! micro-batcher (size + deadline), a worker pool, and latency/throughput
//! metrics. Threads + channels, no async runtime (tokio is unavailable
//! offline; the lockstep batching model needs none).
//!
//! Serving is **zoo-aware**: [`router::ModelRouter`] holds 1..=3 engines
//! (ULN-S/M/L, small → large) and serves tier-pinned batches or the
//! batched confidence cascade ([`router::RouterEngine`] adapts it to the
//! engine trait); [`server::Server::start_zoo`] gives every worker its
//! own zoo (all workers sharing ONE `Arc`'d copy of each tier), the
//! batcher keeps micro-batches tier-homogeneous, and
//! [`metrics::ServerMetrics`] carries per-tier counters. The two scaling
//! axes compose: [`server::Server::start_zoo_sharded`] serves the
//! cascade × shard fan-out
//! ([`ShardedRouterEngine`](crate::runtime::ShardedRouterEngine)) —
//! contiguous row ranges of every micro-batch run the cascade in
//! parallel on a persistent pool, per-tier counters merging
//! deterministically ([`router::RouterStats::merge`]).

//!
//! [`http::HttpFrontend`] is the network edge: a std-only HTTP/1.1
//! server (`uleen serve --listen ADDR`) exposing `/health`, `/metrics`
//! and `/v1/classify` over the same bounded queue, with API-key auth,
//! per-client token-bucket admission, and queue-full/closed
//! backpressure surfaced as 429/503 instead of dropped connections.
//!
//! [`autopilot::Autopilot`] closes the latency control loop: an SLO
//! controller thread that drains the metrics sink's windowed latency
//! view each interval and AIMD-steers the two live knobs — the shared
//! cascade margin ([`autopilot::MarginKnob`]) and the batcher dwell
//! ([`autopilot::DwellKnob`]) — toward a target p99
//! (`uleen serve --target-p99-ms X`).

pub mod autopilot;
pub mod batcher;
pub mod cli;
pub mod http;
pub mod metrics;
pub mod router;
pub mod server;

pub use autopilot::{Autopilot, AutopilotConfig, DwellKnob, MarginKnob};
pub use batcher::{BatcherConfig, BoundedQueue, Request, SubmitError};
pub use http::{HttpConfig, HttpFrontend, RateLimit};
pub use metrics::ServerMetrics;
pub use router::{
    canonical_tier, max_response_of, tier_names, ModelRouter, RouterEngine, RouterStats, Tier,
};
pub use server::{Server, ServerConfig};
