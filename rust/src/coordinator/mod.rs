//! Layer-3 serving coordinator — the runtime system around the model.
//!
//! The paper's accelerator (Fig 8) operates in lockstep on whole input
//! batches fed over a fixed-width bus; this module is the software
//! coordinator a deployment would wrap around it (or around the native /
//! PJRT engines): a bounded request queue with backpressure, a dynamic
//! micro-batcher (size + deadline), a worker pool, and latency/throughput
//! metrics. Threads + channels, no async runtime (tokio is unavailable
//! offline; the lockstep batching model needs none).

pub mod batcher;
pub mod cli;
pub mod metrics;
pub mod router;
pub mod server;

pub use batcher::{BatcherConfig, BoundedQueue, Request, SubmitError};
pub use metrics::ServerMetrics;
pub use server::{Server, ServerConfig};
