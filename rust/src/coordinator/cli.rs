//! `uleen serve` — run the serving coordinator on a trained model with a
//! synthetic open-loop load and print the metrics report.

use crate::coordinator::server::{Server, ServerConfig};
use crate::coordinator::batcher::BatcherConfig;
use crate::data::synth_mnist;
use crate::model::uln_format;
use crate::runtime::NativeEngine;
#[cfg(feature = "pjrt")]
use crate::runtime::PjrtEngine;
use crate::util::cli::Args;
use std::path::Path;
use std::sync::mpsc;
use std::time::Duration;

pub fn cmd_serve(args: &Args) -> anyhow::Result<()> {
    let model_path = args
        .get("model")
        .ok_or_else(|| anyhow::anyhow!("--model <file.uln> required"))?;
    let batch = args.get_usize("batch", 16).map_err(anyhow::Error::msg)?;
    let requests = args.get_usize("requests", 10_000).map_err(anyhow::Error::msg)?;
    let workers = args.get_usize("workers", 4).map_err(anyhow::Error::msg)?;
    let shards = args.get_usize("shards", 0).map_err(anyhow::Error::msg)?;
    let seed = args.get_u64("seed", 2024).map_err(anyhow::Error::msg)?;
    let hlo = args.get("hlo");

    let (model, _) = uln_format::load(Path::new(model_path))?;
    let num_features = model.encoder.num_inputs;
    let cfg = ServerConfig {
        batcher: BatcherConfig {
            max_batch: batch,
            max_wait: Duration::from_micros(200),
            capacity: 16384,
        },
        workers,
    };
    #[cfg(not(feature = "pjrt"))]
    if hlo.is_some() {
        anyhow::bail!("--hlo needs the PJRT engine: rebuild with --features pjrt (and an xla dependency)");
    }
    if hlo.is_some() && shards > 0 {
        anyhow::bail!("--hlo and --shards are mutually exclusive (sharding is native-only)");
    }
    let server = match hlo {
        #[cfg(feature = "pjrt")]
        Some(hlo_path) => {
            let hlo_path = hlo_path.to_string();
            Server::start(cfg, move |_| {
                Ok(Box::new(PjrtEngine::load(Path::new(&hlo_path), batch, num_features)?))
            })?
        }
        _ if shards > 0 => {
            // one sharded engine fanning each micro-batch across threads
            Server::start_sharded(cfg, model, shards)?
        }
        _ => Server::start(cfg, move |_| Ok(Box::new(NativeEngine::new(model.clone()))))?,
    };

    // Open-loop load from the test split of SynthMNIST-like data (or the
    // model's own feature width if it is not an image model).
    let ds = if num_features == 784 {
        synth_mnist(seed, 16, requests.min(4000))
    } else {
        // synthesize uniform feature noise for non-image models
        let mut rng = crate::util::rng::Rng::new(seed);
        let n = requests.min(4000);
        crate::data::Dataset {
            name: "noise".into(),
            num_features,
            num_classes: 2,
            train_x: vec![],
            train_y: vec![],
            test_x: (0..n * num_features).map(|_| rng.f64() as f32).collect(),
            test_y: vec![0; n],
        }
    };
    let (tx, rx) = mpsc::channel();
    let mut correct = 0usize;
    let mut submitted = 0usize;
    let n_test = ds.n_test();
    let mut id2label = std::collections::HashMap::new();
    for i in 0..requests {
        let row = ds.test_row(i % n_test).to_vec();
        loop {
            match server.submit(row.clone(), tx.clone()) {
                Ok(id) => {
                    id2label.insert(id, ds.test_y[i % n_test] as usize);
                    submitted += 1;
                    break;
                }
                Err(crate::coordinator::batcher::SubmitError::Full) => {
                    std::thread::sleep(Duration::from_micros(50));
                }
                Err(e) => anyhow::bail!("submit failed: {e:?}"),
            }
        }
    }
    drop(tx);
    for _ in 0..submitted {
        let (id, pred, _) = rx.recv_timeout(Duration::from_secs(30))?;
        if id2label.get(&id) == Some(&pred) {
            correct += 1;
        }
    }
    let report = server.metrics.report(batch);
    server.shutdown();
    println!("served {} requests on {} workers (batch {})", submitted, workers, batch);
    println!(
        "throughput: {:.0} inf/s | latency p50/p99: {:.1}/{:.1} µs | batch fill {:.0}%",
        report.throughput_rps,
        report.latency_us_p50,
        report.latency_us_p99,
        report.mean_batch_fill * 100.0
    );
    println!(
        "accuracy on served traffic: {:.4} | rejected(full): {}",
        correct as f64 / submitted as f64,
        report.rejected_full
    );
    println!("json: {}", report.to_json().to_string());
    Ok(())
}
