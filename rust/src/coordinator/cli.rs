//! `uleen serve` — run the serving coordinator on a trained model (or a
//! tiered model zoo) with a synthetic open-loop load and print the
//! metrics report.
//!
//! Two modes:
//!
//! * `--model m.uln` — single model, per-worker [`NativeEngine`]s or one
//!   sharded engine (`--shards N`).
//! * `--zoo s,m,l` — tiered zoo serving ([`Server::start_zoo`]): each
//!   worker owns a `ModelRouter` over the listed models (comma-separated
//!   size presets `s|m|l` trained on `--dataset`, or `.uln` paths, small
//!   → large) — every worker's router shares ONE `Arc`'d copy of each
//!   tier. Default traffic runs the **batched confidence cascade**
//!   (`--cascade-margin` sets the escalation threshold); every 4th
//!   request is pinned to a cycling tier to exercise tier-homogeneous
//!   batching. Per-tier served/escalation/latency counters print at
//!   shutdown. Adding `--shards N` composes the two scaling axes
//!   ([`Server::start_zoo_sharded`]): one worker owns a
//!   `ShardedRouterEngine` that splits every micro-batch into contiguous
//!   row ranges, runs the cascade on each range on a persistent pool
//!   worker, and merges per-tier counters deterministically.
//!
//! Either mode swaps the synthetic load for a network edge with
//! `--listen ADDR` ([`HttpFrontend`]): the server answers `GET /health`,
//! `GET /metrics` and `POST /v1/classify` until `--duration-secs`
//! elapses (0 = until killed). `--api-key K` gates the authenticated
//! routes, `--rate-rps R` arms the per-client token bucket, and
//! `--max-body-kib N` caps request bodies.
//!
//! `--target-p99-ms X` (either mode, either load) arms the **latency
//! autopilot** ([`Autopilot`]): an SLO controller thread that AIMD-tunes
//! the live cascade-margin and batcher-dwell knobs against the target
//! p99, reading a windowed (recent, not lifetime) latency view each
//! interval. Final knob positions and decision counts print in the
//! shutdown report and ride the `/metrics` JSON.

use crate::coordinator::autopilot::{Autopilot, AutopilotConfig};
use crate::coordinator::batcher::BatcherConfig;
use crate::coordinator::http::{HttpConfig, HttpFrontend, RateLimit};
use crate::coordinator::metrics::MetricsReport;
use crate::coordinator::router::Tier;
use crate::coordinator::server::{Server, ServerConfig};
use crate::data::synth_mnist;
use crate::model::uln_format;
use crate::runtime::NativeEngine;
#[cfg(feature = "pjrt")]
use crate::runtime::PjrtEngine;
use crate::train::oneshot::train_oneshot;
use crate::util::cli::Args;
use std::path::Path;
use std::sync::mpsc;
use std::time::Duration;

pub fn cmd_serve(args: &Args) -> anyhow::Result<()> {
    if let Some(spec) = args.get("zoo") {
        let spec = spec.to_string();
        return cmd_serve_zoo(args, &spec);
    }
    let model_path = args
        .get("model")
        .ok_or_else(|| anyhow::anyhow!("--model <file.uln> (or --zoo s,m,l) required"))?;
    let batch = args.get_usize("batch", 16).map_err(anyhow::Error::msg)?;
    let requests = args.get_usize("requests", 10_000).map_err(anyhow::Error::msg)?;
    let workers = args.get_usize("workers", 4).map_err(anyhow::Error::msg)?;
    let hlo = args.get("hlo");
    // Topology default: unless the caller sizes the pool explicitly
    // (--shards N, or --workers N to keep the unsharded worker-pool
    // path) or picks the PJRT engine, shard the engine across every
    // detected core.
    let detected = crate::util::detected_cores();
    let shards = if args.get("shards").is_some() {
        args.get_usize("shards", 0).map_err(anyhow::Error::msg)?
    } else if args.get("workers").is_some() || hlo.is_some() {
        0
    } else {
        detected
    };
    println!("topology: {detected} cores detected, serving with {shards} shards");
    let seed = args.get_u64("seed", 2024).map_err(anyhow::Error::msg)?;

    let (model, _) = uln_format::load(Path::new(model_path))?;
    let num_features = model.encoder.num_inputs;
    let cfg = ServerConfig {
        batcher: BatcherConfig {
            max_batch: batch,
            max_wait: Duration::from_micros(200),
            capacity: 16384,
        },
        workers,
    };
    #[cfg(not(feature = "pjrt"))]
    if hlo.is_some() {
        anyhow::bail!("--hlo needs the PJRT engine: rebuild with --features pjrt (and an xla dependency)");
    }
    if hlo.is_some() && shards > 0 {
        anyhow::bail!("--hlo and --shards are mutually exclusive (sharding is native-only)");
    }
    let server = match hlo {
        #[cfg(feature = "pjrt")]
        Some(hlo_path) => {
            let hlo_path = hlo_path.to_string();
            Server::start(cfg, move |_| {
                Ok(Box::new(PjrtEngine::load(Path::new(&hlo_path), batch, num_features)?))
            })?
        }
        _ if shards > 0 => {
            // one sharded engine fanning each micro-batch across threads
            Server::start_sharded(cfg, model, shards)?
        }
        _ => Server::start(cfg, move |_| Ok(Box::new(NativeEngine::new(model.clone()))))?,
    };

    if args.get("listen").is_some() {
        return serve_http(args, server, batch);
    }

    // Open-loop load from the test split of SynthMNIST-like data (or the
    // model's own feature width if it is not an image model).
    let ds = if num_features == 784 {
        synth_mnist(seed, 16, requests.min(4000))
    } else {
        // synthesize uniform feature noise for non-image models
        let mut rng = crate::util::rng::Rng::new(seed);
        let n = requests.min(4000);
        crate::data::Dataset {
            name: "noise".into(),
            num_features,
            num_classes: 2,
            train_x: vec![],
            train_y: vec![],
            test_x: (0..n * num_features).map(|_| rng.f64() as f32).collect(),
            test_y: vec![0; n],
        }
    };
    let autopilot = start_autopilot(args, &server)?;
    let (correct, delivered, submitted) = drive_load(&server, &ds, requests, false)?;
    if let Some(ap) = autopilot {
        ap.stop();
    }
    let report = server.metrics.report(batch);
    server.shutdown();
    println!("served {} requests on {} workers (batch {})", submitted, workers, batch);
    print_report(&report, correct, delivered, submitted);
    Ok(())
}

/// `--target-p99-ms X` arms the latency autopilot on a running server:
/// the controller thread drains the windowed latency view each interval
/// and AIMD-steers the cascade margin (zoo servers) and the batcher
/// dwell (every server) toward the target. Returns `None` when the flag
/// is absent — serving then behaves bit-exactly like the static config.
fn start_autopilot(args: &Args, server: &Server) -> anyhow::Result<Option<Autopilot>> {
    if args.get("target-p99-ms").is_none() {
        return Ok(None);
    }
    let target = args.get_f64("target-p99-ms", 5.0).map_err(anyhow::Error::msg)?;
    anyhow::ensure!(target > 0.0, "--target-p99-ms wants a positive millisecond value");
    let cfg = AutopilotConfig { target_p99_ms: target, ..Default::default() };
    let steers = if server.margin_knob().is_some() {
        "cascade margin + batcher dwell"
    } else {
        "batcher dwell (single model: no cascade margin)"
    };
    println!("autopilot: holding p99 <= {target} ms, steering {steers}");
    Ok(Some(Autopilot::start(
        cfg,
        server.metrics.clone(),
        server.margin_knob(),
        server.dwell_knob(),
    )))
}

/// `--listen ADDR` mode, shared by both serve paths: expose the running
/// server over HTTP instead of driving synthetic load. Runs for
/// `--duration-secs` (0, the default, = until the process is killed),
/// then drains and prints the shutdown report.
fn serve_http(args: &Args, server: Server, batch: usize) -> anyhow::Result<()> {
    let addr = args.get("listen").expect("caller checked --listen").to_string();
    let api_key = args.get("api-key").map(str::to_string);
    let rate_rps = args.get_f64("rate-rps", 0.0).map_err(anyhow::Error::msg)?;
    let max_body_kib = args.get_usize("max-body-kib", 1024).map_err(anyhow::Error::msg)?;
    let duration = args.get_u64("duration-secs", 0).map_err(anyhow::Error::msg)?;
    let authed = api_key.is_some();
    let cfg = HttpConfig {
        api_key,
        max_body_bytes: max_body_kib * 1024,
        // burst = 2 s of the sustained rate, so short spikes pass
        rate: (rate_rps > 0.0)
            .then(|| RateLimit { burst: (2.0 * rate_rps).max(1.0), per_sec: rate_rps }),
        ..Default::default()
    };
    let autopilot = start_autopilot(args, &server)?;
    let server = std::sync::Arc::new(server);
    let frontend = HttpFrontend::start(&addr, server.clone(), cfg)?;
    println!(
        "listening on http://{} ({}, {}) — GET /health | GET /metrics | POST /v1/classify",
        frontend.local_addr(),
        if authed { "api-key auth" } else { "unauthenticated" },
        if rate_rps > 0.0 {
            format!("{rate_rps} req/s per client")
        } else {
            "no rate limit".to_string()
        },
    );
    if duration == 0 {
        println!("serving until killed (pass --duration-secs N for a timed run)");
        loop {
            std::thread::sleep(Duration::from_secs(3600));
        }
    }
    std::thread::sleep(Duration::from_secs(duration));
    frontend.shutdown();
    if let Some(ap) = autopilot {
        ap.stop(); // final knob positions land in the metrics sink
    }
    let server = std::sync::Arc::try_unwrap(server)
        .ok()
        .expect("shut-down frontend must drop its server handle");
    server.close();
    let report = server.metrics.report(batch);
    server.shutdown();
    println!(
        "served over HTTP for {duration} s | throughput: {:.0} inf/s | \
         latency p50/p99: {:.1}/{:.1} µs | rejected(full): {}",
        report.throughput_rps, report.latency_us_p50, report.latency_us_p99, report.rejected_full
    );
    println!("json: {}", report.to_json().to_string());
    Ok(())
}

/// Materialize the dataset that trains zoo presets and generates load
/// (shared name resolver; same SynthMNIST split defaults the help text
/// documents for every other subcommand).
fn serve_dataset(args: &Args) -> anyhow::Result<crate::data::Dataset> {
    let name = args.get_or("dataset", "mnist");
    let seed = args.get_u64("seed", 2024).map_err(anyhow::Error::msg)?;
    let tr = args.get_usize("mnist-train", 8000).map_err(anyhow::Error::msg)?;
    let te = args.get_usize("mnist-test", 2000).map_err(anyhow::Error::msg)?;
    crate::data::load_by_name(name, seed, tr, te)
}

/// Submit the open-loop load and drain completions. When `mixed_tiers`,
/// every 4th request is pinned to a cycling tier (fast → balanced →
/// accurate) and the rest take the cascade; otherwise everything goes
/// down the default path. Returns (correct, delivered, submitted) —
/// delivered can trail submitted when the server drops work (malformed
/// requests, failed batches), which its metrics count; a drop must not
/// abort the run before the report that exists to expose it prints.
fn drive_load(
    server: &Server,
    ds: &crate::data::Dataset,
    requests: usize,
    mixed_tiers: bool,
) -> anyhow::Result<(usize, usize, usize)> {
    let (tx, rx) = mpsc::channel();
    let n_test = ds.n_test();
    let mut id2label = std::collections::HashMap::new();
    let mut submitted = 0usize;
    for i in 0..requests {
        // Borrowed row: submit copies it straight into its arena slot,
        // so the load loop never clones a feature Vec per request.
        let row = ds.test_row(i % n_test);
        let tier = if mixed_tiers && i % 4 == 3 {
            Some([Tier::Fast, Tier::Balanced, Tier::Accurate][(i / 4) % 3])
        } else {
            None
        };
        loop {
            match server.submit_tiered(row, tier, tx.clone()) {
                Ok(id) => {
                    id2label.insert(id, ds.test_y[i % n_test] as usize);
                    submitted += 1;
                    break;
                }
                Err(crate::coordinator::batcher::SubmitError::Full) => {
                    std::thread::sleep(Duration::from_micros(50));
                }
                Err(e) => anyhow::bail!("submit failed: {e:?}"),
            }
        }
    }
    drop(tx);
    let mut correct = 0usize;
    let mut delivered = 0usize;
    for _ in 0..submitted {
        match rx.recv_timeout(Duration::from_secs(30)) {
            Ok((id, pred)) => {
                delivered += 1;
                if id2label.get(&id) == Some(&pred) {
                    correct += 1;
                }
            }
            // every sender gone: the remaining completions were dropped
            // by the server and show up in its malformed/failed counters
            Err(mpsc::RecvTimeoutError::Disconnected) => break,
            Err(e) => anyhow::bail!("serving stalled: {e:?}"),
        }
    }
    Ok((correct, delivered, submitted))
}

/// The shutdown report both serve modes share: headline numbers,
/// accuracy over DELIVERED completions, drop counters, per-tier lines
/// for zoo servers (the report itself knows its zoo depth — 0 =
/// single-model, no tier lines), and the JSON line.
fn print_report(report: &MetricsReport, correct: usize, delivered: usize, submitted: usize) {
    println!(
        "throughput: {:.0} inf/s | latency p50/p99: {:.1}/{:.1} µs \
         (reservoir cross-check {:.1}/{:.1}) | batch fill {:.0}%",
        report.throughput_rps,
        report.latency_us_p50,
        report.latency_us_p99,
        report.latency_us_p50_reservoir,
        report.latency_us_p99_reservoir,
        report.mean_batch_fill * 100.0
    );
    if report.model_bytes > 0 {
        println!(
            "resident model plane: {:.1} KiB (compiled tables + bias, Arc-shared across workers)",
            report.model_bytes as f64 / 1024.0
        );
    }
    for (i, name) in crate::coordinator::router::tier_names(report.num_tiers)
        .iter()
        .enumerate()
        .take(report.num_tiers)
    {
        println!(
            "  tier {name:<9} served {:>8} samples | escalated {:>7} | mean engine {:.2} µs/sample \
             | model {:.1} KiB",
            report.tier_served[i],
            report.tier_escalations[i],
            report.tier_mean_us[i],
            report.tier_model_bytes[i] as f64 / 1024.0
        );
    }
    if report.num_tiers > 0 {
        let t0 = report.tier_served[0];
        if t0 > 0 {
            println!(
                "tier-0 resolution rate: {:.1}% (served minus escalations, incl. pinned-fast)",
                (t0 - report.tier_escalations[0].min(t0)) as f64 / t0 as f64 * 100.0
            );
        }
        // Wall-time vs latency view of the same engine work: tier lines
        // above SUM time across parallel shard ranges; the critical path
        // takes each batch's slowest range — the SLO-facing number.
        println!(
            "engine critical path: {:.2} ms total (per-batch max over parallel \
             shard ranges; vs {:.2} ms summed tier time)",
            report.critical_path_ms,
            report.tier_mean_us.iter().zip(report.tier_served.iter())
                .map(|(us, &n)| us * n as f64)
                .sum::<f64>() / 1e3
        );
    }
    if let Some(ap) = &report.autopilot {
        let margin = match ap.margin {
            Some(m) => format!("{m:.3}"),
            None => "n/a".to_string(),
        };
        println!(
            "autopilot: target p99 {:.2} ms | final margin {margin} | final dwell {:.0} µs | \
             decisions tighten/relax/hold {}/{}/{}",
            ap.target_p99_ms, ap.dwell_us, ap.tighten, ap.relax, ap.hold
        );
    }
    println!(
        "accuracy on delivered traffic: {:.4} ({delivered}/{submitted} delivered) | \
         rejected(full): {} | malformed: {} | failed batches: {}",
        correct as f64 / delivered.max(1) as f64,
        report.rejected_full,
        report.malformed,
        report.batches_failed
    );
    println!("json: {}", report.to_json().to_string());
}

fn cmd_serve_zoo(args: &Args, spec: &str) -> anyhow::Result<()> {
    let batch = args.get_usize("batch", 64).map_err(anyhow::Error::msg)?;
    let requests = args.get_usize("requests", 10_000).map_err(anyhow::Error::msg)?;
    let workers = args.get_usize("workers", 2).map_err(anyhow::Error::msg)?;
    let margin = args.get_f64("cascade-margin", 0.05).map_err(anyhow::Error::msg)? as f32;
    // Topology default, mirroring `cmd_serve`: explicit --shards wins,
    // explicit --workers keeps the per-worker-zoo path, otherwise shard
    // the cascade across every detected core.
    let detected = crate::util::detected_cores();
    let shards = if args.get("shards").is_some() {
        args.get_usize("shards", 0).map_err(anyhow::Error::msg)?
    } else if args.get("workers").is_some() {
        0
    } else {
        detected
    };
    println!("topology: {detected} cores detected, serving zoo with {shards} shards");
    anyhow::ensure!(args.get("hlo").is_none(), "--zoo and --hlo are mutually exclusive");
    anyhow::ensure!(
        args.get("model").is_none(),
        "--zoo and --model are mutually exclusive (list the .uln path inside --zoo instead)"
    );
    let tokens: Vec<&str> = spec
        .split(',')
        .map(str::trim)
        .filter(|t| !t.is_empty())
        .collect();
    anyhow::ensure!(
        !tokens.is_empty(),
        "--zoo wants 1..=3 comma-separated tiers (presets s|m|l or .uln paths), got '{spec}'"
    );

    let ds = serve_dataset(args)?;
    let mut models = Vec::new();
    for tok in tokens {
        let model = if tok.contains('.') || tok.contains('/') {
            let (m, _) = uln_format::load(Path::new(tok))?;
            println!("loaded '{tok}': {} ({:.2} KiB)", m.name, m.size_kib());
            m
        } else {
            let cfg = crate::train::oneshot::zoo_preset(tok).ok_or_else(|| {
                anyhow::anyhow!("unknown zoo tier '{tok}' (want s|m|l or a .uln path)")
            })?;
            let (m, rep) = train_oneshot(&ds, &cfg);
            println!(
                "trained preset '{tok}' on {}: {:.2} KiB, val acc {:.4}",
                ds.name,
                m.size_kib(),
                rep.val_accuracy
            );
            m
        };
        models.push(model);
    }
    let tiers = models.len();
    anyhow::ensure!(
        models[0].encoder.num_inputs == ds.num_features,
        "zoo feature width {} != dataset width {} (loaded models must match --dataset)",
        models[0].encoder.num_inputs,
        ds.num_features
    );
    let cfg = ServerConfig {
        batcher: BatcherConfig {
            max_batch: batch,
            max_wait: Duration::from_micros(200),
            capacity: 16384,
        },
        workers,
    };
    // --shards N composes the cascade with shard fan-out: one worker, one
    // ShardedRouterEngine splitting every micro-batch across N pool
    // threads that all share the same Arc'd tiers.
    let server = if shards > 0 {
        // parallelism comes from the shard pool, so the worker count is
        // forced to 1 — say so instead of silently eating --workers
        if args.get("workers").is_some() && workers != 1 {
            println!(
                "(--zoo with --shards {shards} serves on 1 worker; \
                 ignoring --workers {workers} — the pool supplies the parallelism)"
            );
        }
        Server::start_zoo_sharded(cfg, models, margin, shards)?
    } else {
        Server::start_zoo(cfg, models, margin)?
    };

    if args.get("listen").is_some() {
        return serve_http(args, server, batch);
    }

    let autopilot = start_autopilot(args, &server)?;
    let (correct, delivered, submitted) = drive_load(&server, &ds, requests, true)?;
    if let Some(ap) = autopilot {
        ap.stop();
    }
    let report = server.metrics.report(batch);
    server.shutdown();

    if shards > 0 {
        println!(
            "zoo[{tiers} tiers × {shards} shards] served {submitted} requests on 1 worker \
             (batch {batch}, cascade margin {margin})"
        );
    } else {
        println!(
            "zoo[{tiers} tiers] served {submitted} requests on {workers} workers \
             (batch {batch}, cascade margin {margin})"
        );
    }
    print_report(&report, correct, delivered, submitted);
    Ok(())
}
