//! Bounded request queue + dynamic micro-batcher.
//!
//! Requests enter through [`BoundedQueue::submit`] (non-blocking reject on
//! overflow = explicit backpressure) and leave in batches via
//! [`BoundedQueue::next_batch`]: a worker takes up to `max_batch` requests,
//! waiting at most `max_wait` after the first request arrives — the classic
//! size-or-deadline batching rule the paper's fixed-batch accelerator
//! implies for real deployments.

use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

/// One inference request travelling through the coordinator.
#[derive(Debug)]
pub struct Request {
    pub id: u64,
    pub features: Vec<f32>,
    pub enqueued: Instant,
    /// Completion channel: (request id, predicted class, response scores).
    pub done: std::sync::mpsc::Sender<(u64, usize, Vec<f32>)>,
}

/// Why a submit was refused.
#[derive(Debug, PartialEq, Eq)]
pub enum SubmitError {
    /// Queue at capacity — caller should back off (backpressure).
    Full,
    /// Server is shutting down.
    Closed,
}

#[derive(Clone, Copy, Debug)]
pub struct BatcherConfig {
    pub max_batch: usize,
    pub max_wait: Duration,
    pub capacity: usize,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        Self { max_batch: 16, max_wait: Duration::from_micros(200), capacity: 4096 }
    }
}

struct State {
    queue: VecDeque<Request>,
    closed: bool,
}

/// MPMC bounded queue with condvar wakeups.
pub struct BoundedQueue {
    cfg: BatcherConfig,
    state: Mutex<State>,
    nonempty: Condvar,
}

impl BoundedQueue {
    pub fn new(cfg: BatcherConfig) -> Self {
        Self {
            cfg,
            state: Mutex::new(State { queue: VecDeque::new(), closed: false }),
            nonempty: Condvar::new(),
        }
    }

    pub fn config(&self) -> &BatcherConfig {
        &self.cfg
    }

    /// Non-blocking submit; rejects when full (backpressure) or closed.
    pub fn submit(&self, req: Request) -> Result<(), (SubmitError, Request)> {
        let mut st = self.state.lock().unwrap();
        if st.closed {
            return Err((SubmitError::Closed, req));
        }
        if st.queue.len() >= self.cfg.capacity {
            return Err((SubmitError::Full, req));
        }
        st.queue.push_back(req);
        drop(st);
        self.nonempty.notify_one();
        Ok(())
    }

    /// Current depth (approximate — for metrics/backpressure decisions).
    pub fn depth(&self) -> usize {
        self.state.lock().unwrap().queue.len()
    }

    /// Take the next micro-batch: blocks until at least one request is
    /// available (or closed+empty → None), then waits up to `max_wait` for
    /// the batch to fill to `max_batch`.
    pub fn next_batch(&self) -> Option<Vec<Request>> {
        let mut st = self.state.lock().unwrap();
        loop {
            if !st.queue.is_empty() {
                break;
            }
            if st.closed {
                return None;
            }
            st = self.nonempty.wait(st).unwrap();
        }
        // got the first request; optionally dwell for more
        let deadline = Instant::now() + self.cfg.max_wait;
        while st.queue.len() < self.cfg.max_batch && !st.closed {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            let (next, timeout) = self
                .nonempty
                .wait_timeout(st, deadline - now)
                .unwrap();
            st = next;
            if timeout.timed_out() {
                break;
            }
        }
        let take = st.queue.len().min(self.cfg.max_batch);
        Some(st.queue.drain(..take).collect())
    }

    /// Close the queue: no new submissions; workers drain what remains.
    pub fn close(&self) {
        self.state.lock().unwrap().closed = true;
        self.nonempty.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc;
    use std::sync::Arc;

    fn req(id: u64, tx: &mpsc::Sender<(u64, usize, Vec<f32>)>) -> Request {
        Request { id, features: vec![0.0], enqueued: Instant::now(), done: tx.clone() }
    }

    #[test]
    fn batch_respects_max_batch() {
        let q = BoundedQueue::new(BatcherConfig {
            max_batch: 4,
            max_wait: Duration::from_millis(1),
            capacity: 100,
        });
        let (tx, _rx) = mpsc::channel();
        for i in 0..10 {
            q.submit(req(i, &tx)).unwrap();
        }
        let b1 = q.next_batch().unwrap();
        let b2 = q.next_batch().unwrap();
        assert_eq!(b1.len(), 4);
        assert_eq!(b2.len(), 4);
        assert_eq!(b1[0].id, 0);
        assert_eq!(b2[0].id, 4, "FIFO order preserved");
    }

    #[test]
    fn backpressure_on_full_queue() {
        let q = BoundedQueue::new(BatcherConfig {
            max_batch: 4,
            max_wait: Duration::from_micros(10),
            capacity: 2,
        });
        let (tx, _rx) = mpsc::channel();
        q.submit(req(0, &tx)).unwrap();
        q.submit(req(1, &tx)).unwrap();
        let err = q.submit(req(2, &tx)).unwrap_err();
        assert_eq!(err.0, SubmitError::Full);
    }

    #[test]
    fn close_rejects_and_drains() {
        let q = BoundedQueue::new(BatcherConfig::default());
        let (tx, _rx) = mpsc::channel();
        q.submit(req(0, &tx)).unwrap();
        q.close();
        let err = q.submit(req(1, &tx)).unwrap_err();
        assert_eq!(err.0, SubmitError::Closed);
        // drains the remaining request, then None
        assert_eq!(q.next_batch().unwrap().len(), 1);
        assert!(q.next_batch().is_none());
    }

    #[test]
    fn deadline_fires_with_partial_batch() {
        let q = Arc::new(BoundedQueue::new(BatcherConfig {
            max_batch: 64,
            max_wait: Duration::from_millis(5),
            capacity: 100,
        }));
        let (tx, _rx) = mpsc::channel();
        q.submit(req(0, &tx)).unwrap();
        let t0 = Instant::now();
        let b = q.next_batch().unwrap();
        assert_eq!(b.len(), 1);
        assert!(t0.elapsed() >= Duration::from_millis(4), "should dwell ~max_wait");
    }

    #[test]
    fn concurrent_producers_no_loss_no_dup() {
        let q = Arc::new(BoundedQueue::new(BatcherConfig {
            max_batch: 8,
            max_wait: Duration::from_micros(50),
            capacity: 10_000,
        }));
        let (tx, _rx) = mpsc::channel();
        let mut handles = Vec::new();
        for p in 0..4u64 {
            let q = q.clone();
            let tx = tx.clone();
            handles.push(std::thread::spawn(move || {
                for i in 0..250u64 {
                    q.submit(req(p * 1000 + i, &tx)).unwrap();
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        q.close();
        let mut seen = std::collections::HashSet::new();
        while let Some(batch) = q.next_batch() {
            for r in batch {
                assert!(seen.insert(r.id), "duplicate id {}", r.id);
            }
        }
        assert_eq!(seen.len(), 1000, "all requests delivered exactly once");
    }
}
