//! Bounded request queue + dynamic micro-batcher over a slab feature arena.
//!
//! Requests enter through [`BoundedQueue::submit_row`] (non-blocking reject
//! on overflow = explicit backpressure) and leave in batches via
//! [`BoundedQueue::next_batch_into`]: a worker takes up to `max_batch`
//! requests, waiting at most `max_wait` after the first request arrives —
//! the classic size-or-deadline batching rule the paper's fixed-batch
//! accelerator implies for real deployments.
//!
//! Requests optionally carry a [`Tier`] (zoo serving): a batch is always
//! **tier-homogeneous** — the batcher takes the longest same-tier prefix
//! of the queue, so a worker can dispatch the whole micro-batch as one
//! tier-pinned (`Some(tier)`) or cascade (`None`) engine call. FIFO order
//! is preserved; mixed traffic simply splits at tier boundaries.
//!
//! ## The zero-allocation request plane (PR 8)
//!
//! Three structures make the queue side of the serving stack free of
//! steady-state heap traffic, matching the write-into inference plane
//! underneath it:
//!
//! - **Slab feature arena.** Feature rows live in one fixed
//!   `slots × num_features` f32 slab owned by the queue, managed by a
//!   free-list. A [`Request`] carries a slot *index*, not a `Vec<f32>`:
//!   submit pops a slot, copies the caller's row straight into it, and
//!   enqueues; the worker reads the slot through
//!   [`BoundedQueue::gather`] and returns it with
//!   [`BoundedQueue::release`] once the engine call finishes (success
//!   *or* failure — failed batches must not leak capacity). Slot
//!   ownership is exclusive by construction: an index is either on the
//!   free-list (nobody touches it), held by the submitting thread
//!   (between pop and enqueue), parked in the ring (nobody touches it),
//!   or held by the consumer that popped its request (until `release`).
//!   Every handoff goes through the state mutex, so the exclusivity
//!   carries the needed happens-before edges.
//! - **Ring-buffer batcher.** The queue itself is a fixed ring of
//!   `capacity` request cells, filled at submit and drained by
//!   [`BoundedQueue::next_batch_into`] into a caller-owned, grow-only
//!   `Vec<Request>` — no per-batch `drain().collect()` allocation. The
//!   historical [`BoundedQueue::next_batch`] remains as a thin
//!   allocating wrapper for tests and simple callers.
//! - **Slim completion tuple.** Completions are `(id, predicted class)`;
//!   the dead per-completion `Vec<f32>` scores field is gone.
//!
//! Wrong-width rows still travel the queue (truncated into their slot,
//! with the submitted width recorded on the request) so the *dispatcher*
//! counts them malformed and drops them — submit-time behavior is
//! byte-compatible with the pre-arena queue, which accepted any width.
//!
//! ## Shutdown-race audit (PR 6, re-audited for the ring in PR 8)
//!
//! - `close` → `notify_all` wakes EVERY parked consumer; each re-checks
//!   `closed` under the lock, drains any leftover prefix, and only then
//!   returns `false` — queued work is never stranded by shutdown.
//! - A consumer's dwell wait can wake empty (competing consumer stole the
//!   prefix); it loops back to park rather than returning an empty batch.
//! - A tier boundary mid-queue re-notifies (`notify_one`) after a partial
//!   take, so a second parked consumer picks up the remainder without
//!   waiting for a fresh submit.
//! - `submit_row` after `close` fails with [`SubmitError::Closed`] (the
//!   HTTP layer maps it to 503). The row copy happens *outside* the
//!   state lock, so a close landing between slot reservation and enqueue
//!   returns the slot to the free-list before reporting `Closed`.

use crate::coordinator::autopilot::DwellKnob;
use crate::runtime::Tier;
use std::cell::UnsafeCell;
use std::sync::{mpsc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// One inference request travelling through the coordinator. Features
/// live in the queue's slab arena; the request carries only the slot
/// index (private — slot access is brokered by [`BoundedQueue::gather`]).
#[derive(Debug)]
pub struct Request {
    pub id: u64,
    /// Arena slot holding this request's feature row.
    pub(crate) slot: u32,
    /// The width the caller actually submitted. The arena slot is exactly
    /// `num_features` wide, so a mismatch marks the request malformed —
    /// the dispatcher counts and drops it without an engine call.
    pub(crate) width: u32,
    /// `Some(tier)` pins the request to one zoo tier; `None` means the
    /// default path (confidence cascade on zoo servers, the single model
    /// otherwise).
    pub tier: Option<Tier>,
    pub enqueued: Instant,
    /// Completion channel: (request id, predicted class).
    pub done: mpsc::Sender<(u64, usize)>,
}

impl Request {
    /// Whether the submitted row width matches the arena width `f`.
    pub fn is_well_formed(&self, f: usize) -> bool {
        self.width as usize == f
    }
}

/// Why a submit was refused.
#[derive(Debug, PartialEq, Eq)]
pub enum SubmitError {
    /// Queue at capacity — caller should back off (backpressure).
    Full,
    /// Server is shutting down.
    Closed,
}

#[derive(Clone, Copy, Debug)]
pub struct BatcherConfig {
    pub max_batch: usize,
    pub max_wait: Duration,
    pub capacity: usize,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        Self { max_batch: 16, max_wait: Duration::from_micros(200), capacity: 4096 }
    }
}

/// The fixed feature slab: `slots × width` f32s behind an `UnsafeCell`.
///
/// Interior mutability is required because producers write rows while
/// consumers concurrently read *different* slots. Soundness rests on the
/// slot-exclusivity invariant documented on the module: at any instant a
/// slot index is reachable from exactly one place (free-list, one
/// producer's stack, one ring cell, or one consumer's batch), and every
/// transfer happens under the queue's state mutex.
struct FeatureArena {
    width: usize,
    slots: usize,
    data: UnsafeCell<Box<[f32]>>,
}

// SAFETY: see the slot-exclusivity invariant above — distinct slots are
// disjoint regions, and a single slot is never accessed from two threads
// without a mutex handoff in between.
unsafe impl Sync for FeatureArena {}

impl FeatureArena {
    fn new(slots: usize, width: usize) -> Self {
        let data = vec![0.0f32; slots * width].into_boxed_slice();
        Self { width, slots, data: UnsafeCell::new(data) }
    }

    /// Copy `row` into `slot`, truncated to the arena width (wrong-width
    /// rows are tagged via [`Request::width`] and never read back).
    ///
    /// SAFETY: caller must hold `slot` exclusively (just popped from the
    /// free-list, not yet enqueued).
    unsafe fn write(&self, slot: u32, row: &[f32]) {
        let n = row.len().min(self.width);
        let base = (*self.data.get()).as_mut_ptr().add(slot as usize * self.width);
        std::ptr::copy_nonoverlapping(row.as_ptr(), base, n);
    }

    /// Borrow `count` consecutive slots starting at `first` as one flat
    /// row-major slice.
    ///
    /// SAFETY: caller must hold all `count` slots exclusively and keep
    /// them held (un-released) while the returned slice is alive.
    unsafe fn read_run(&self, first: u32, count: usize) -> &[f32] {
        let base = (*self.data.get()).as_ptr().add(first as usize * self.width);
        std::slice::from_raw_parts(base, count * self.width)
    }
}

/// Fixed ring of request cells — the `VecDeque` replacement. Capacity is
/// exact: the queue's admission check guarantees `push` never overflows.
struct Ring {
    buf: Box<[Option<Request>]>,
    head: usize,
    len: usize,
}

impl Ring {
    fn with_capacity(cap: usize) -> Self {
        let mut buf = Vec::with_capacity(cap.max(1));
        buf.resize_with(cap.max(1), || None);
        Self { buf: buf.into_boxed_slice(), head: 0, len: 0 }
    }

    fn len(&self) -> usize {
        self.len
    }

    fn is_empty(&self) -> bool {
        self.len == 0
    }

    fn push(&mut self, r: Request) {
        debug_assert!(self.len < self.buf.len(), "ring admission check violated");
        let i = (self.head + self.len) % self.buf.len();
        self.buf[i] = Some(r);
        self.len += 1;
    }

    fn pop(&mut self) -> Option<Request> {
        if self.len == 0 {
            return None;
        }
        let r = self.buf[self.head].take();
        self.head = (self.head + 1) % self.buf.len();
        self.len -= 1;
        r
    }

    fn tier_at(&self, i: usize) -> Option<Tier> {
        debug_assert!(i < self.len);
        let idx = (self.head + i) % self.buf.len();
        self.buf[idx].as_ref().and_then(|r| r.tier)
    }
}

struct State {
    ring: Ring,
    /// Free arena slot indices (LIFO).
    free: Vec<u32>,
    /// Slots popped by in-progress submits that have not pushed into the
    /// ring yet — counted against capacity so two racing producers cannot
    /// both pass the admission check and overflow the fixed ring.
    reserved: usize,
    closed: bool,
}

/// MPMC bounded queue with condvar wakeups, backed by the slab arena.
pub struct BoundedQueue {
    cfg: BatcherConfig,
    /// Live dwell budget, seeded from `cfg.max_wait`. Read once at the
    /// top of each dwell (a retune mid-dwell applies to the *next*
    /// batch), so the autopilot can shrink/grow batching latency online
    /// without a queue rebuild.
    dwell: DwellKnob,
    arena: FeatureArena,
    state: Mutex<State>,
    nonempty: Condvar,
}

impl BoundedQueue {
    /// A queue sized for one consumer: `max_batch` extra arena slots
    /// cover the single in-flight batch. Servers with several workers
    /// should use [`BoundedQueue::with_in_flight`].
    pub fn new(cfg: BatcherConfig, num_features: usize) -> Self {
        let extra = cfg.max_batch;
        Self::with_in_flight(cfg, num_features, extra)
    }

    /// A queue whose arena holds `capacity + in_flight_slots` rows.
    /// `in_flight_slots` must cover the worst-case number of slots held
    /// by dispatched-but-unreleased batches (`workers × max_batch`); with
    /// that bound the arena can never be the binding constraint —
    /// admission rejects on ring capacity first — so `SubmitError::Full`
    /// keeps meaning exactly "queue full".
    pub fn with_in_flight(cfg: BatcherConfig, num_features: usize, in_flight_slots: usize) -> Self {
        let slots = cfg.capacity + in_flight_slots;
        let free: Vec<u32> = (0..slots as u32).rev().collect();
        Self {
            dwell: DwellKnob::new(cfg.max_wait),
            cfg,
            arena: FeatureArena::new(slots, num_features),
            state: Mutex::new(State {
                ring: Ring::with_capacity(cfg.capacity),
                free,
                reserved: 0,
                closed: false,
            }),
            nonempty: Condvar::new(),
        }
    }

    pub fn config(&self) -> &BatcherConfig {
        &self.cfg
    }

    /// Shared handle to the live dwell budget. `cfg.max_wait` is only the
    /// seed; the autopilot (or a test) retunes through this knob and every
    /// consumer picks the new value up at its next dwell.
    pub fn dwell_knob(&self) -> DwellKnob {
        self.dwell.clone()
    }

    /// The arena's row width (the served model's feature count).
    pub fn num_features(&self) -> usize {
        self.arena.width
    }

    /// Non-blocking submit; rejects when full (backpressure) or closed.
    /// Copies `row` into a fresh arena slot — truncated to the arena
    /// width if it mismatches (the request is then tagged malformed and
    /// dropped, counted, at dispatch). The copy runs outside the state
    /// lock so producers do not serialize on memcpy.
    pub fn submit_row(
        &self,
        id: u64,
        row: &[f32],
        tier: Option<Tier>,
        enqueued: Instant,
        done: mpsc::Sender<(u64, usize)>,
    ) -> Result<(), SubmitError> {
        let slot = {
            let mut st = self.state.lock().unwrap();
            if st.closed {
                return Err(SubmitError::Closed);
            }
            if st.ring.len() + st.reserved >= self.cfg.capacity {
                return Err(SubmitError::Full);
            }
            // Unreachable while the in-flight sizing contract holds
            // (outstanding = queued + reserved + dispatched < slots), but
            // a dry free-list must surface as backpressure, not a panic.
            let Some(slot) = st.free.pop() else {
                return Err(SubmitError::Full);
            };
            st.reserved += 1;
            slot
        };
        // SAFETY: `slot` just left the free-list and is not yet in the
        // ring — this thread holds it exclusively.
        unsafe { self.arena.write(slot, row) };
        let width = u32::try_from(row.len()).unwrap_or(u32::MAX);
        let mut st = self.state.lock().unwrap();
        st.reserved -= 1;
        if st.closed {
            // close() raced the copy: hand the slot back before failing.
            st.free.push(slot);
            return Err(SubmitError::Closed);
        }
        st.ring.push(Request { id, slot, width, tier, enqueued, done });
        drop(st);
        self.nonempty.notify_one();
        Ok(())
    }

    /// Current depth (approximate — for metrics/backpressure decisions).
    pub fn depth(&self) -> usize {
        self.state.lock().unwrap().ring.len()
    }

    /// Arena witness: free slots right now (tests assert the free-list
    /// refills completely after drains — no slot leaks).
    pub fn free_slots(&self) -> usize {
        self.state.lock().unwrap().free.len()
    }

    /// Arena witness: total slot count (`capacity + in_flight_slots`).
    pub fn arena_slots(&self) -> usize {
        self.arena.slots
    }

    /// Take the next micro-batch into the caller's grow-only buffer:
    /// blocks until at least one request is available (or closed+empty →
    /// `false`), then waits up to `max_wait` for the batch to fill to
    /// `max_batch`. The batch is the longest same-tier prefix of the
    /// queue (≤ `max_batch`), so it can be dispatched as a single
    /// tier-pinned or cascade engine call. `out` is cleared first and
    /// never yields empty on `true`; a warm caller reusing one buffer
    /// performs zero allocations per batch.
    pub fn next_batch_into(&self, out: &mut Vec<Request>) -> bool {
        out.clear();
        // Dwelling is pointless once a tier boundary lands inside the
        // takeable prefix: arrivals only append behind it, so the
        // same-tier batch we will take can never grow — dispatch
        // immediately instead of burning max_wait.
        let prefix_capped = |ring: &Ring| {
            if ring.is_empty() {
                return false;
            }
            let head = ring.tier_at(0);
            let lim = ring.len().min(self.cfg.max_batch);
            (1..lim).any(|i| ring.tier_at(i) != head)
        };
        let mut st = self.state.lock().unwrap();
        loop {
            // block until at least one request is queued (or closed+empty)
            while st.ring.is_empty() {
                if st.closed {
                    return false;
                }
                st = self.nonempty.wait(st).unwrap();
            }
            // got a head request; optionally dwell for more — budget read
            // through the knob so the autopilot can retune it live
            let deadline = Instant::now() + self.dwell.get();
            while !st.ring.is_empty()
                && st.ring.len() < self.cfg.max_batch
                && !st.closed
                && !prefix_capped(&st.ring)
            {
                let now = Instant::now();
                if now >= deadline {
                    break;
                }
                let (next, timeout) = self
                    .nonempty
                    .wait_timeout(st, deadline - now)
                    .unwrap();
                st = next;
                if timeout.timed_out() {
                    break;
                }
            }
            // A competing consumer may have drained the queue while we
            // slept in the dwell (the queue is MPMC) — restart the
            // blocking wait rather than take an empty batch.
            if st.ring.is_empty() {
                continue;
            }
            // Longest same-tier prefix: requests behind a tier boundary
            // stay queued for the next batch (FIFO preserved). Never
            // empty: the queue is non-empty and we hold the lock.
            let lim = st.ring.len().min(self.cfg.max_batch);
            let tier = st.ring.tier_at(0);
            let mut take = 1;
            while take < lim && st.ring.tier_at(take) == tier {
                take += 1;
            }
            for _ in 0..take {
                out.push(st.ring.pop().expect("take <= ring.len"));
            }
            // We may have absorbed notifications meant for other
            // consumers while dwelling; if a remainder stays queued
            // (routine with tier splits, not just len > max_batch),
            // wake one peer so it isn't stranded until the next submit.
            let leftover = !st.ring.is_empty();
            drop(st);
            if leftover {
                self.nonempty.notify_one();
            }
            return true;
        }
    }

    /// Allocating wrapper over [`BoundedQueue::next_batch_into`] — kept
    /// for tests and callers that do not reuse a batch buffer.
    pub fn next_batch(&self) -> Option<Vec<Request>> {
        let mut out = Vec::new();
        if self.next_batch_into(&mut out) {
            Some(out)
        } else {
            None
        }
    }

    /// Flatten a batch's feature rows into one row-major `&[f32]` plane.
    /// When the batch happens to occupy consecutive ascending slots the
    /// arena run is borrowed directly (zero copy); otherwise rows are
    /// gathered into the caller's grow-only `scratch`. Every request must
    /// be well-formed ([`Request::is_well_formed`]) — the dispatcher
    /// filters malformed ones first.
    ///
    /// The returned slice is valid until the batch's slots are
    /// [`release`](BoundedQueue::release)d.
    pub fn gather<'q>(&'q self, batch: &[Request], scratch: &'q mut Vec<f32>) -> &'q [f32] {
        let f = self.arena.width;
        debug_assert!(batch.iter().all(|r| r.is_well_formed(f)));
        if !batch.is_empty() && batch.windows(2).all(|w| w[1].slot == w[0].slot + 1) {
            // SAFETY: the consumer holds every slot in `batch`
            // exclusively until `release`, and the run is contiguous.
            return unsafe { self.arena.read_run(batch[0].slot, batch.len()) };
        }
        scratch.clear();
        for r in batch {
            // SAFETY: per-slot exclusive hold, as above.
            scratch.extend_from_slice(unsafe { self.arena.read_run(r.slot, 1) });
        }
        scratch
    }

    /// Return a batch's arena slots to the free-list. Must be called
    /// exactly once per dispatched request — on engine success AND
    /// failure — after any slice from [`BoundedQueue::gather`] is dead.
    pub fn release(&self, batch: &[Request]) {
        if batch.is_empty() {
            return;
        }
        let mut st = self.state.lock().unwrap();
        for r in batch {
            st.free.push(r.slot);
        }
    }

    /// Close the queue: no new submissions; workers drain what remains.
    pub fn close(&self) {
        self.state.lock().unwrap().closed = true;
        self.nonempty.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc;
    use std::sync::Arc;

    fn submit(q: &BoundedQueue, id: u64, tx: &mpsc::Sender<(u64, usize)>) -> Result<(), SubmitError> {
        submit_at(q, id, None, tx)
    }

    fn submit_at(
        q: &BoundedQueue,
        id: u64,
        tier: Option<Tier>,
        tx: &mpsc::Sender<(u64, usize)>,
    ) -> Result<(), SubmitError> {
        q.submit_row(id, &[id as f32], tier, Instant::now(), tx.clone())
    }

    #[test]
    fn batch_respects_max_batch() {
        let q = BoundedQueue::new(
            BatcherConfig {
                max_batch: 4,
                max_wait: Duration::from_millis(1),
                capacity: 100,
            },
            1,
        );
        let (tx, _rx) = mpsc::channel();
        for i in 0..10 {
            submit(&q, i, &tx).unwrap();
        }
        let b1 = q.next_batch().unwrap();
        let b2 = q.next_batch().unwrap();
        assert_eq!(b1.len(), 4);
        assert_eq!(b2.len(), 4);
        assert_eq!(b1[0].id, 0);
        assert_eq!(b2[0].id, 4, "FIFO order preserved");
    }

    #[test]
    fn batches_split_at_tier_boundaries_preserving_fifo() {
        let q = BoundedQueue::new(
            BatcherConfig {
                max_batch: 8,
                max_wait: Duration::from_micros(10),
                capacity: 100,
            },
            1,
        );
        let (tx, _rx) = mpsc::channel();
        // cascade, cascade | fast, fast, fast | accurate | cascade
        for (id, tier) in [
            (0, None),
            (1, None),
            (2, Some(Tier::Fast)),
            (3, Some(Tier::Fast)),
            (4, Some(Tier::Fast)),
            (5, Some(Tier::Accurate)),
            (6, None),
        ] {
            submit_at(&q, id, tier, &tx).unwrap();
        }
        let batches: Vec<Vec<u64>> = (0..4)
            .map(|_| q.next_batch().unwrap().iter().map(|r| r.id).collect())
            .collect();
        assert_eq!(
            batches,
            [vec![0u64, 1], vec![2, 3, 4], vec![5], vec![6]],
            "each batch is one same-tier run, in FIFO order"
        );
    }

    #[test]
    fn tier_boundary_cuts_the_dwell_short() {
        // Once a different-tier request queues behind the head, the
        // takeable same-tier prefix can never grow — next_batch must
        // dispatch immediately instead of sleeping out max_wait.
        let q = BoundedQueue::new(
            BatcherConfig {
                max_batch: 64,
                max_wait: Duration::from_secs(5),
                capacity: 100,
            },
            1,
        );
        let (tx, _rx) = mpsc::channel();
        submit_at(&q, 0, None, &tx).unwrap();
        submit_at(&q, 1, Some(Tier::Fast), &tx).unwrap();
        let t0 = Instant::now();
        let b = q.next_batch().unwrap();
        assert_eq!(b.len(), 1, "only the head's same-tier prefix is taken");
        assert!(
            t0.elapsed() < Duration::from_secs(1),
            "boundary-capped batch must not dwell out max_wait"
        );
    }

    #[test]
    fn backpressure_on_full_queue() {
        let q = BoundedQueue::new(
            BatcherConfig {
                max_batch: 4,
                max_wait: Duration::from_micros(10),
                capacity: 2,
            },
            1,
        );
        let (tx, _rx) = mpsc::channel();
        submit(&q, 0, &tx).unwrap();
        submit(&q, 1, &tx).unwrap();
        let err = submit(&q, 2, &tx).unwrap_err();
        assert_eq!(err, SubmitError::Full);
    }

    #[test]
    fn close_rejects_and_drains() {
        let q = BoundedQueue::new(BatcherConfig::default(), 1);
        let (tx, _rx) = mpsc::channel();
        submit(&q, 0, &tx).unwrap();
        q.close();
        let err = submit(&q, 1, &tx).unwrap_err();
        assert_eq!(err, SubmitError::Closed);
        // drains the remaining request, then None
        assert_eq!(q.next_batch().unwrap().len(), 1);
        assert!(q.next_batch().is_none());
    }

    #[test]
    fn deadline_fires_with_partial_batch() {
        let q = Arc::new(BoundedQueue::new(
            BatcherConfig {
                max_batch: 64,
                max_wait: Duration::from_millis(5),
                capacity: 100,
            },
            1,
        ));
        let (tx, _rx) = mpsc::channel();
        submit(&q, 0, &tx).unwrap();
        let t0 = Instant::now();
        let b = q.next_batch().unwrap();
        assert_eq!(b.len(), 1);
        assert!(t0.elapsed() >= Duration::from_millis(4), "should dwell ~max_wait");
    }

    #[test]
    fn dwell_knob_retunes_the_dwell_without_a_queue_rebuild() {
        // Config asks for an absurd 5 s dwell; turning the knob down to
        // 2 ms must take effect on the very next batch.
        let q = BoundedQueue::new(
            BatcherConfig {
                max_batch: 64,
                max_wait: Duration::from_secs(5),
                capacity: 100,
            },
            1,
        );
        assert_eq!(q.dwell_knob().get(), Duration::from_secs(5), "knob seeds from cfg.max_wait");
        q.dwell_knob().set(Duration::from_millis(2));
        let (tx, _rx) = mpsc::channel();
        submit(&q, 0, &tx).unwrap();
        let t0 = Instant::now();
        let b = q.next_batch().unwrap();
        assert_eq!(b.len(), 1);
        assert!(
            t0.elapsed() < Duration::from_secs(1),
            "retuned dwell must cut the 5 s config budget to ~2 ms"
        );
    }

    #[test]
    fn competing_consumers_never_panic_on_a_drained_queue() {
        // MPMC race: two consumers can both pass the non-empty check and
        // dwell; the loser wakes to a queue its rival already drained and
        // must loop back to the blocking wait, not index into nothing.
        let q = Arc::new(BoundedQueue::new(
            BatcherConfig {
                max_batch: 64,
                max_wait: Duration::from_millis(20),
                capacity: 100,
            },
            1,
        ));
        let consumers: Vec<_> = (0..2)
            .map(|_| {
                let q = q.clone();
                std::thread::spawn(move || {
                    let mut got = 0usize;
                    let mut buf = Vec::new();
                    while q.next_batch_into(&mut buf) {
                        got += buf.len();
                        q.release(&buf);
                    }
                    got
                })
            })
            .collect();
        let (tx, _rx) = mpsc::channel();
        for i in 0..5 {
            submit(&q, i, &tx).unwrap();
            std::thread::sleep(Duration::from_millis(2));
        }
        std::thread::sleep(Duration::from_millis(30));
        q.close();
        let total: usize = consumers
            .into_iter()
            .map(|h| h.join().expect("consumer must not panic"))
            .sum();
        assert_eq!(total, 5, "every request delivered exactly once");
        assert_eq!(
            q.free_slots(),
            q.arena_slots(),
            "released batches refill the free-list completely"
        );
    }

    #[test]
    fn concurrent_producers_no_loss_no_dup() {
        let q = Arc::new(BoundedQueue::new(
            BatcherConfig {
                max_batch: 8,
                max_wait: Duration::from_micros(50),
                capacity: 10_000,
            },
            1,
        ));
        let (tx, _rx) = mpsc::channel();
        let mut handles = Vec::new();
        for p in 0..4u64 {
            let q = q.clone();
            let tx = tx.clone();
            handles.push(std::thread::spawn(move || {
                for i in 0..250u64 {
                    submit(&q, p * 1000 + i, &tx).unwrap();
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        q.close();
        let mut seen = std::collections::HashSet::new();
        while let Some(batch) = q.next_batch() {
            for r in &batch {
                assert!(seen.insert(r.id), "duplicate id {}", r.id);
            }
            q.release(&batch);
        }
        assert_eq!(seen.len(), 1000, "all requests delivered exactly once");
        assert_eq!(q.free_slots(), q.arena_slots(), "no slot leaks under producer contention");
    }

    #[test]
    fn arena_preserves_row_payloads_across_ring_wraparound() {
        // Drive several times the ring capacity through the queue so both
        // the ring head and the slot free-list cycle; every gathered row
        // must carry exactly the floats its submit wrote.
        let q = BoundedQueue::new(
            BatcherConfig {
                max_batch: 4,
                max_wait: Duration::from_micros(10),
                capacity: 6,
            },
            3,
        );
        let (tx, _rx) = mpsc::channel();
        let mut buf = Vec::new();
        let mut scratch = Vec::new();
        let mut next_id = 0u64;
        for _round in 0..10 {
            for _ in 0..6 {
                let v = next_id as f32;
                q.submit_row(next_id, &[v, v + 0.25, v + 0.5], None, Instant::now(), tx.clone())
                    .unwrap();
                next_id += 1;
            }
            while q.depth() > 0 {
                assert!(q.next_batch_into(&mut buf));
                let flat = q.gather(&buf, &mut scratch);
                for (k, r) in buf.iter().enumerate() {
                    let v = r.id as f32;
                    assert_eq!(flat[3 * k..3 * k + 3], [v, v + 0.25, v + 0.5], "row {}", r.id);
                }
                q.release(&buf);
            }
        }
        assert_eq!(q.free_slots(), q.arena_slots());
    }

    #[test]
    fn wrong_width_rows_ride_the_queue_tagged_malformed() {
        // Submit-time behavior is width-blind (byte-compatible with the
        // pre-arena queue): wrong-width rows occupy queue capacity and
        // are tagged for the dispatcher to count and drop.
        let q = BoundedQueue::new(
            BatcherConfig {
                max_batch: 8,
                max_wait: Duration::from_micros(10),
                capacity: 8,
            },
            4,
        );
        let (tx, _rx) = mpsc::channel();
        q.submit_row(0, &[], None, Instant::now(), tx.clone()).unwrap();
        q.submit_row(1, &[0.5; 4], None, Instant::now(), tx.clone()).unwrap();
        q.submit_row(2, &[0.5; 9], None, Instant::now(), tx.clone()).unwrap();
        let batch = q.next_batch().unwrap();
        assert_eq!(batch.len(), 3, "malformed rows still travel the queue");
        let ok: Vec<bool> = batch.iter().map(|r| r.is_well_formed(4)).collect();
        assert_eq!(ok, [false, true, false]);
        q.release(&batch);
        assert_eq!(q.free_slots(), q.arena_slots());
    }

    #[test]
    fn close_between_reserve_and_enqueue_returns_the_slot() {
        // The two-phase submit's close race: closing after every submit
        // completed must leave the free-list whole — and a close() racing
        // live submitters (exercised here just by interleaving) must
        // never strand a reserved slot.
        let q = BoundedQueue::new(BatcherConfig::default(), 2);
        let (tx, _rx) = mpsc::channel();
        q.submit_row(0, &[1.0, 2.0], None, Instant::now(), tx.clone()).unwrap();
        q.close();
        assert_eq!(
            q.submit_row(1, &[3.0, 4.0], None, Instant::now(), tx.clone()).unwrap_err(),
            SubmitError::Closed
        );
        let batch = q.next_batch().unwrap();
        assert_eq!(batch.len(), 1);
        q.release(&batch);
        assert_eq!(q.free_slots(), q.arena_slots(), "rejected submit returned its slot");
    }
}
