//! Bounded request queue + dynamic micro-batcher.
//!
//! Requests enter through [`BoundedQueue::submit`] (non-blocking reject on
//! overflow = explicit backpressure) and leave in batches via
//! [`BoundedQueue::next_batch`]: a worker takes up to `max_batch` requests,
//! waiting at most `max_wait` after the first request arrives — the classic
//! size-or-deadline batching rule the paper's fixed-batch accelerator
//! implies for real deployments.
//!
//! Requests optionally carry a [`Tier`] (zoo serving): a batch is always
//! **tier-homogeneous** — `next_batch` takes the longest same-tier prefix
//! of the queue, so a worker can dispatch the whole micro-batch as one
//! tier-pinned (`Some(tier)`) or cascade (`None`) engine call. FIFO order
//! is preserved; mixed traffic simply splits at tier boundaries.
//!
//! ## Shutdown-race audit (PR 6)
//!
//! The close/submit/dwell interleavings were re-audited when the HTTP
//! front-end moved these paths onto untrusted network input:
//!
//! - `close` → `notify_all` wakes EVERY parked consumer; each re-checks
//!   `closed` under the lock, drains any leftover prefix, and only then
//!   returns `None` — queued work is never stranded by shutdown.
//! - A consumer's dwell wait can wake empty (competing consumer stole the
//!   prefix); it loops back to park rather than returning an empty batch.
//! - A tier boundary mid-queue re-notifies (`notify_one`) after a partial
//!   take, so a second parked consumer picks up the remainder without
//!   waiting for a fresh submit.
//! - `submit` after `close` fails with [`SubmitError::Closed`] and hands
//!   the request back to the caller (the HTTP layer maps it to 503).
//!
//! The one real defect found was OUTSIDE this module: the server marked
//! the metrics wall-clock before `submit` could reject, so a load test
//! that only ever got 429s still reported nonzero serving wall time. The
//! fix (mark on accept, in `server.rs`) is covered by
//! `wall_clock_never_starts_on_rejects_and_never_goes_negative`.

use crate::runtime::Tier;
use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

/// One inference request travelling through the coordinator.
#[derive(Debug)]
pub struct Request {
    pub id: u64,
    pub features: Vec<f32>,
    /// `Some(tier)` pins the request to one zoo tier; `None` means the
    /// default path (confidence cascade on zoo servers, the single model
    /// otherwise).
    pub tier: Option<Tier>,
    pub enqueued: Instant,
    /// Completion channel: (request id, predicted class, response scores).
    pub done: std::sync::mpsc::Sender<(u64, usize, Vec<f32>)>,
}

/// Why a submit was refused.
#[derive(Debug, PartialEq, Eq)]
pub enum SubmitError {
    /// Queue at capacity — caller should back off (backpressure).
    Full,
    /// Server is shutting down.
    Closed,
}

#[derive(Clone, Copy, Debug)]
pub struct BatcherConfig {
    pub max_batch: usize,
    pub max_wait: Duration,
    pub capacity: usize,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        Self { max_batch: 16, max_wait: Duration::from_micros(200), capacity: 4096 }
    }
}

struct State {
    queue: VecDeque<Request>,
    closed: bool,
}

/// MPMC bounded queue with condvar wakeups.
pub struct BoundedQueue {
    cfg: BatcherConfig,
    state: Mutex<State>,
    nonempty: Condvar,
}

impl BoundedQueue {
    pub fn new(cfg: BatcherConfig) -> Self {
        Self {
            cfg,
            state: Mutex::new(State { queue: VecDeque::new(), closed: false }),
            nonempty: Condvar::new(),
        }
    }

    pub fn config(&self) -> &BatcherConfig {
        &self.cfg
    }

    /// Non-blocking submit; rejects when full (backpressure) or closed.
    pub fn submit(&self, req: Request) -> Result<(), (SubmitError, Request)> {
        let mut st = self.state.lock().unwrap();
        if st.closed {
            return Err((SubmitError::Closed, req));
        }
        if st.queue.len() >= self.cfg.capacity {
            return Err((SubmitError::Full, req));
        }
        st.queue.push_back(req);
        drop(st);
        self.nonempty.notify_one();
        Ok(())
    }

    /// Current depth (approximate — for metrics/backpressure decisions).
    pub fn depth(&self) -> usize {
        self.state.lock().unwrap().queue.len()
    }

    /// Take the next micro-batch: blocks until at least one request is
    /// available (or closed+empty → None), then waits up to `max_wait` for
    /// the batch to fill to `max_batch`. The batch is the longest
    /// same-tier prefix of the queue (≤ `max_batch`), so it can be
    /// dispatched as a single tier-pinned or cascade engine call.
    pub fn next_batch(&self) -> Option<Vec<Request>> {
        // Dwelling is pointless once a tier boundary lands inside the
        // takeable prefix: arrivals only append behind it, so the
        // same-tier batch we will take can never grow — dispatch
        // immediately instead of burning max_wait.
        let prefix_capped = |q: &VecDeque<Request>| match q.front() {
            None => false,
            Some(head) => {
                let lim = q.len().min(self.cfg.max_batch);
                (1..lim).any(|i| q[i].tier != head.tier)
            }
        };
        let mut st = self.state.lock().unwrap();
        loop {
            // block until at least one request is queued (or closed+empty)
            while st.queue.is_empty() {
                if st.closed {
                    return None;
                }
                st = self.nonempty.wait(st).unwrap();
            }
            // got a head request; optionally dwell for more
            let deadline = Instant::now() + self.cfg.max_wait;
            while !st.queue.is_empty()
                && st.queue.len() < self.cfg.max_batch
                && !st.closed
                && !prefix_capped(&st.queue)
            {
                let now = Instant::now();
                if now >= deadline {
                    break;
                }
                let (next, timeout) = self
                    .nonempty
                    .wait_timeout(st, deadline - now)
                    .unwrap();
                st = next;
                if timeout.timed_out() {
                    break;
                }
            }
            // A competing consumer may have drained the queue while we
            // slept in the dwell (the queue is MPMC) — restart the
            // blocking wait rather than take an empty batch.
            if st.queue.is_empty() {
                continue;
            }
            // Longest same-tier prefix: requests behind a tier boundary
            // stay queued for the next batch (FIFO preserved). Never
            // empty: the queue is non-empty and we hold the lock.
            let lim = st.queue.len().min(self.cfg.max_batch);
            let tier = st.queue[0].tier;
            let mut take = 1;
            while take < lim && st.queue[take].tier == tier {
                take += 1;
            }
            let batch: Vec<Request> = st.queue.drain(..take).collect();
            // We may have absorbed notifications meant for other
            // consumers while dwelling; if a remainder stays queued
            // (routine with tier splits, not just len > max_batch),
            // wake one peer so it isn't stranded until the next submit.
            let leftover = !st.queue.is_empty();
            drop(st);
            if leftover {
                self.nonempty.notify_one();
            }
            return Some(batch);
        }
    }

    /// Close the queue: no new submissions; workers drain what remains.
    pub fn close(&self) {
        self.state.lock().unwrap().closed = true;
        self.nonempty.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc;
    use std::sync::Arc;

    fn req(id: u64, tx: &mpsc::Sender<(u64, usize, Vec<f32>)>) -> Request {
        req_at(id, None, tx)
    }

    fn req_at(
        id: u64,
        tier: Option<Tier>,
        tx: &mpsc::Sender<(u64, usize, Vec<f32>)>,
    ) -> Request {
        Request { id, features: vec![0.0], tier, enqueued: Instant::now(), done: tx.clone() }
    }

    #[test]
    fn batch_respects_max_batch() {
        let q = BoundedQueue::new(BatcherConfig {
            max_batch: 4,
            max_wait: Duration::from_millis(1),
            capacity: 100,
        });
        let (tx, _rx) = mpsc::channel();
        for i in 0..10 {
            q.submit(req(i, &tx)).unwrap();
        }
        let b1 = q.next_batch().unwrap();
        let b2 = q.next_batch().unwrap();
        assert_eq!(b1.len(), 4);
        assert_eq!(b2.len(), 4);
        assert_eq!(b1[0].id, 0);
        assert_eq!(b2[0].id, 4, "FIFO order preserved");
    }

    #[test]
    fn batches_split_at_tier_boundaries_preserving_fifo() {
        let q = BoundedQueue::new(BatcherConfig {
            max_batch: 8,
            max_wait: Duration::from_micros(10),
            capacity: 100,
        });
        let (tx, _rx) = mpsc::channel();
        // cascade, cascade | fast, fast, fast | accurate | cascade
        for (id, tier) in [
            (0, None),
            (1, None),
            (2, Some(Tier::Fast)),
            (3, Some(Tier::Fast)),
            (4, Some(Tier::Fast)),
            (5, Some(Tier::Accurate)),
            (6, None),
        ] {
            q.submit(req_at(id, tier, &tx)).unwrap();
        }
        let batches: Vec<Vec<u64>> = (0..4)
            .map(|_| q.next_batch().unwrap().iter().map(|r| r.id).collect())
            .collect();
        assert_eq!(
            batches,
            [vec![0u64, 1], vec![2, 3, 4], vec![5], vec![6]],
            "each batch is one same-tier run, in FIFO order"
        );
    }

    #[test]
    fn tier_boundary_cuts_the_dwell_short() {
        // Once a different-tier request queues behind the head, the
        // takeable same-tier prefix can never grow — next_batch must
        // dispatch immediately instead of sleeping out max_wait.
        let q = BoundedQueue::new(BatcherConfig {
            max_batch: 64,
            max_wait: Duration::from_secs(5),
            capacity: 100,
        });
        let (tx, _rx) = mpsc::channel();
        q.submit(req_at(0, None, &tx)).unwrap();
        q.submit(req_at(1, Some(Tier::Fast), &tx)).unwrap();
        let t0 = Instant::now();
        let b = q.next_batch().unwrap();
        assert_eq!(b.len(), 1, "only the head's same-tier prefix is taken");
        assert!(
            t0.elapsed() < Duration::from_secs(1),
            "boundary-capped batch must not dwell out max_wait"
        );
    }

    #[test]
    fn backpressure_on_full_queue() {
        let q = BoundedQueue::new(BatcherConfig {
            max_batch: 4,
            max_wait: Duration::from_micros(10),
            capacity: 2,
        });
        let (tx, _rx) = mpsc::channel();
        q.submit(req(0, &tx)).unwrap();
        q.submit(req(1, &tx)).unwrap();
        let err = q.submit(req(2, &tx)).unwrap_err();
        assert_eq!(err.0, SubmitError::Full);
    }

    #[test]
    fn close_rejects_and_drains() {
        let q = BoundedQueue::new(BatcherConfig::default());
        let (tx, _rx) = mpsc::channel();
        q.submit(req(0, &tx)).unwrap();
        q.close();
        let err = q.submit(req(1, &tx)).unwrap_err();
        assert_eq!(err.0, SubmitError::Closed);
        // drains the remaining request, then None
        assert_eq!(q.next_batch().unwrap().len(), 1);
        assert!(q.next_batch().is_none());
    }

    #[test]
    fn deadline_fires_with_partial_batch() {
        let q = Arc::new(BoundedQueue::new(BatcherConfig {
            max_batch: 64,
            max_wait: Duration::from_millis(5),
            capacity: 100,
        }));
        let (tx, _rx) = mpsc::channel();
        q.submit(req(0, &tx)).unwrap();
        let t0 = Instant::now();
        let b = q.next_batch().unwrap();
        assert_eq!(b.len(), 1);
        assert!(t0.elapsed() >= Duration::from_millis(4), "should dwell ~max_wait");
    }

    #[test]
    fn competing_consumers_never_panic_on_a_drained_queue() {
        // MPMC race: two consumers can both pass the non-empty check and
        // dwell; the loser wakes to a queue its rival already drained and
        // must loop back to the blocking wait, not index into nothing.
        let q = Arc::new(BoundedQueue::new(BatcherConfig {
            max_batch: 64,
            max_wait: Duration::from_millis(20),
            capacity: 100,
        }));
        let consumers: Vec<_> = (0..2)
            .map(|_| {
                let q = q.clone();
                std::thread::spawn(move || {
                    let mut got = 0usize;
                    while let Some(b) = q.next_batch() {
                        got += b.len();
                    }
                    got
                })
            })
            .collect();
        let (tx, _rx) = mpsc::channel();
        for i in 0..5 {
            q.submit(req(i, &tx)).unwrap();
            std::thread::sleep(Duration::from_millis(2));
        }
        std::thread::sleep(Duration::from_millis(30));
        q.close();
        let total: usize = consumers
            .into_iter()
            .map(|h| h.join().expect("consumer must not panic"))
            .sum();
        assert_eq!(total, 5, "every request delivered exactly once");
    }

    #[test]
    fn concurrent_producers_no_loss_no_dup() {
        let q = Arc::new(BoundedQueue::new(BatcherConfig {
            max_batch: 8,
            max_wait: Duration::from_micros(50),
            capacity: 10_000,
        }));
        let (tx, _rx) = mpsc::channel();
        let mut handles = Vec::new();
        for p in 0..4u64 {
            let q = q.clone();
            let tx = tx.clone();
            handles.push(std::thread::spawn(move || {
                for i in 0..250u64 {
                    q.submit(req(p * 1000 + i, &tx)).unwrap();
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        q.close();
        let mut seen = std::collections::HashSet::new();
        while let Some(batch) = q.next_batch() {
            for r in batch {
                assert!(seen.insert(r.id), "duplicate id {}", r.id);
            }
        }
        assert_eq!(seen.len(), 1000, "all requests delivered exactly once");
    }
}
