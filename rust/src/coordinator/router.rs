//! Model router — tiered dispatch across the ULN-S/M/L zoo.
//!
//! The paper's §V-D point is that ULEEN exposes an accuracy/efficiency/area
//! *interplay*; a deployment exploits it by keeping several model sizes
//! loaded and routing each request by its requirements. This router
//! implements the two policies a serving stack actually needs:
//!
//! * **tier routing** — requests carry a [`Tier`] (latency-critical →
//!   smallest model; accuracy-critical → largest);
//! * **confidence escalation** — classify on the small model first and
//!   escalate to the next tier when the response margin (top1 − top2,
//!   normalized by filter count) is below a threshold. This mirrors
//!   cascade inference and preserves the energy story: most requests take
//!   the cheap path.
//!
//! Both policies are **batch-native**: [`ModelRouter::classify_batch`]
//! runs a whole micro-batch on one tier through
//! [`InferenceEngine::responses`] (the fused bit-sliced kernel for
//! `n > 1`), and [`ModelRouter::classify_cascade_batch`] runs the
//! escalation cascade as a sequence of ever-thinner compacted
//! sub-batches: the full batch hits the Fast tier once, the thin-margin
//! rows are gathered into a contiguous sub-batch, that sub-batch hits the
//! next tier, and so on; results scatter back in row order. The batched
//! cascade is bit-exact with N sequential [`ModelRouter::classify_cascade`]
//! calls (enforced by `prop_batched_cascade_matches_sequential`) — same
//! predictions, same per-tier served/escalation counts.
//!
//! [`RouterEngine`] packages a router as an [`InferenceEngine`] so the
//! serving worker pool can own one zoo per worker ([`Server::start_zoo`])
//! and dispatch tier-pinned and cascade micro-batches through the same
//! `classify_routed` entry point, flushing per-tier counters into
//! [`ServerMetrics`] as it goes. Routers are cheap to replicate:
//! [`ModelRouter::from_shared`] builds each tier as a
//! [`NativeEngine`](crate::runtime::NativeEngine) over an `Arc`-shared
//! [`SharedModel`](crate::runtime::SharedModel), so N routers (per
//! serving worker, or per shard-pool worker in
//! [`ShardedRouterEngine`](crate::runtime::ShardedRouterEngine)) share
//! ONE copy of every tier, and per-router counters fold together with
//! [`RouterStats::merge`].
//!
//! [`Server::start_zoo`]: crate::coordinator::server::Server::start_zoo

use crate::coordinator::autopilot::MarginKnob;
use crate::coordinator::metrics::ServerMetrics;
use crate::runtime::InferenceEngine;
use std::sync::Arc;
use std::time::Instant;

pub use crate::runtime::Tier;

/// Routing statistics. `served[i]` counts samples evaluated by tier `i`
/// (a cascaded sample counts once per tier it visits);
/// `escalations_from[i]` counts tier `i` → `i + 1` hand-offs, so
/// first-tier resolutions are `served[0] - escalations_from[0]`.
/// `tier_ns[i]` accumulates wall time spent inside tier `i`'s engine.
///
/// `critical_path_ns` is the latency-side counterpart of `tier_ns`
/// (ROADMAP follow-up (k)): engine nanoseconds on the LONGEST serial
/// chain of calls. A sequential router's calls all serialize on one
/// thread, so it advances in lockstep with `Σ tier_ns`; when a batch is
/// partitioned across pool workers, [`RouterStats::merge`] takes the
/// **max over worker ranges** instead of the wall-time sum — the number
/// an SLO controller can actually compare against a latency budget.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct RouterStats {
    pub served: [u64; 3],
    pub escalations_from: [u64; 3],
    pub tier_ns: [u64; 3],
    pub critical_path_ns: u64,
}

impl RouterStats {
    /// Total escalations across all tier boundaries (derived — there is
    /// exactly one source of truth, `escalations_from`).
    pub fn escalations(&self) -> u64 {
        self.escalations_from.iter().sum()
    }

    /// Counter deltas since an earlier snapshot (used to flush per-batch
    /// increments into [`ServerMetrics`]).
    pub fn diff(&self, base: &RouterStats) -> RouterStats {
        RouterStats {
            served: std::array::from_fn(|i| self.served[i] - base.served[i]),
            escalations_from: std::array::from_fn(|i| {
                self.escalations_from[i] - base.escalations_from[i]
            }),
            tier_ns: std::array::from_fn(|i| self.tier_ns[i] - base.tier_ns[i]),
            critical_path_ns: self.critical_path_ns - base.critical_path_ns,
        }
    }

    /// Fold the counters of a router that ran IN PARALLEL with this one —
    /// the shard-merge primitive. Every per-row count (and the `tier_ns`
    /// wall-time sum) is additive, so merging per-shard stats of a
    /// partitioned batch in ANY fixed order reproduces the sequential
    /// counters bit-exactly; the sharded cascade merges in worker order
    /// (`prop_sharded_cascade_matches_sequential` pins this down).
    /// `critical_path_ns` takes the MAX — parallel workers overlap in
    /// time, so the slowest range is the batch's latency path.
    pub fn merge(&mut self, other: &RouterStats) {
        for i in 0..3 {
            self.served[i] += other.served[i];
            self.escalations_from[i] += other.escalations_from[i];
            self.tier_ns[i] += other.tier_ns[i];
        }
        self.critical_path_ns = self.critical_path_ns.max(other.critical_path_ns);
    }

    /// Fold the counters of work that ran strictly AFTER this one (e.g.
    /// a new zoo generation chained onto swap-retired history): every
    /// field adds, **including** `critical_path_ns` — serial paths
    /// concatenate, they don't overlap.
    pub fn chain(&mut self, later: &RouterStats) {
        for i in 0..3 {
            self.served[i] += later.served[i];
            self.escalations_from[i] += later.escalations_from[i];
            self.tier_ns[i] += later.tier_ns[i];
        }
        self.critical_path_ns += later.critical_path_ns;
    }
}

/// Reusable buffers for the batched cascade's gather/compact phase and
/// per-tier response staging — after warmup the cascade hot path
/// allocates **nothing**: predictions and scores go into caller-owned
/// planes (`classify_cascade_batch_into`) and every escalation
/// sub-batch stages its responses in the one grow-only `resp` arena,
/// matching the crate's scratch style (`FlatBatchScratch`,
/// `ShardScratch`).
#[derive(Default)]
struct CascadeScratch {
    /// original row ids of the current compacted sub-batch
    rows: Vec<usize>,
    next_rows: Vec<usize>,
    /// compacted feature rows for tiers > 0 (tier 0 reads the caller's x)
    gathered: Vec<f32>,
    next_gathered: Vec<f32>,
    /// grow-only response arena shared by EVERY tier's sub-batch: sized
    /// once for the widest sub-batch (tier 0's full batch) and reused by
    /// each thinner escalation sub-batch's `responses_into` call
    resp: Vec<f32>,
}

/// A tiered router over 1..=3 engines ordered small → large.
pub struct ModelRouter {
    engines: Vec<Box<dyn InferenceEngine>>,
    /// per-engine maximum possible response (for margin normalization)
    max_response: Vec<f32>,
    pub stats: RouterStats,
    /// escalate when (top1-top2)/max_response < threshold — a shared
    /// atomic knob so the latency autopilot can retune it while N
    /// routers are serving (see [`ModelRouter::margin_knob`]); loaded
    /// ONCE per classify call, so a mid-batch retune never splits one
    /// batch across two thresholds
    margin: MarginKnob,
    cascade_scratch: CascadeScratch,
    /// grow-only prediction arena for scores-only callers
    /// ([`ModelRouter::cascade_scores_into`]); lives outside
    /// `CascadeScratch` so the cascade core can borrow both at once
    pred_arena: Vec<usize>,
}

impl ModelRouter {
    /// SIMD dispatch tier of the Fast tier's kernel (all tiers compile
    /// under the same dispatch decision), `"n/a"` for non-native tiers.
    pub fn kernel_path(&self) -> &'static str {
        self.engines.first().map(|e| e.kernel_path()).unwrap_or("n/a")
    }

    /// Resident model bytes summed over the zoo's tiers (each tier
    /// answers for its own compiled tables; non-native tiers report 0).
    pub fn model_bytes(&self) -> u64 {
        self.engines.iter().map(|e| e.model_bytes()).sum()
    }

    /// Per-tier resident model bytes, small → large, aligned with
    /// [`tier_names`]; unused slots stay 0.
    pub fn tier_model_bytes(&self) -> [u64; 3] {
        let mut per = [0u64; 3];
        for (slot, e) in per.iter_mut().zip(self.engines.iter()) {
            *slot = e.model_bytes();
        }
        per
    }

    pub fn new(engines: Vec<Box<dyn InferenceEngine>>, max_response: Vec<f32>) -> Self {
        assert!(!engines.is_empty() && engines.len() <= 3);
        assert_eq!(engines.len(), max_response.len());
        let f = engines[0].num_features();
        let m = engines[0].num_classes();
        for e in &engines {
            assert_eq!(e.num_features(), f, "feature width mismatch across tiers");
            assert_eq!(e.num_classes(), m, "class count mismatch across tiers");
        }
        Self {
            engines,
            max_response,
            stats: RouterStats::default(),
            margin: MarginKnob::new(0.05),
            cascade_scratch: CascadeScratch::default(),
            pred_arena: Vec::new(),
        }
    }

    /// Current escalation threshold (one relaxed atomic load).
    pub fn margin_threshold(&self) -> f32 {
        self.margin.get()
    }

    /// Set the escalation threshold — through THIS router's knob, so
    /// every router sharing the knob sees the new value too.
    pub fn set_margin_threshold(&self, threshold: f32) {
        self.margin.set(threshold);
    }

    /// Handle to the shared margin knob (cloning shares the atomic).
    pub fn margin_knob(&self) -> MarginKnob {
        self.margin.clone()
    }

    /// Adopt an existing shared knob in place of this router's own —
    /// how N per-worker routers become N readers of ONE knob.
    pub fn share_margin(&mut self, knob: &MarginKnob) {
        self.margin = knob.clone();
    }

    /// Build a router of [`NativeEngine`]s over `models` (ordered small →
    /// large), with margin normalization from [`max_response_of`].
    /// Compiles each model once and routes through
    /// [`ModelRouter::from_shared`] — the ONE construction path shared by
    /// the zoo server, the benches, the examples, and the tests.
    ///
    /// [`NativeEngine`]: crate::runtime::NativeEngine
    pub fn from_models(models: &[crate::model::ensemble::UleenModel]) -> Self {
        let shared: Vec<crate::runtime::SharedModel> = models
            .iter()
            .map(|m| crate::runtime::SharedModel::compile(m.clone()))
            .collect();
        Self::from_shared(&shared)
    }

    /// Build a router over already-compiled, `Arc`-shared tiers (small →
    /// large): each tier becomes a [`NativeEngine::from_shared`] holding
    /// two `Arc` handles — zero model/table clones. N routers built from
    /// the same slice (per serving worker, or per shard-pool worker in
    /// [`ShardedRouterEngine`]) share ONE copy of every tier; the
    /// `Arc::strong_count` witness tests pin that down.
    ///
    /// [`NativeEngine::from_shared`]: crate::runtime::NativeEngine::from_shared
    /// [`ShardedRouterEngine`]: crate::runtime::ShardedRouterEngine
    pub fn from_shared(tiers: &[crate::runtime::SharedModel]) -> Self {
        let engines: Vec<Box<dyn InferenceEngine>> = tiers
            .iter()
            .map(|t| {
                Box::new(crate::runtime::NativeEngine::from_shared(t.clone()))
                    as Box<dyn InferenceEngine>
            })
            .collect();
        let max_response = tiers.iter().map(|t| max_response_of(t.model())).collect();
        Self::new(engines, max_response)
    }

    pub fn num_tiers(&self) -> usize {
        self.engines.len()
    }

    pub fn num_features(&self) -> usize {
        self.engines[0].num_features()
    }

    pub fn num_classes(&self) -> usize {
        self.engines[0].num_classes()
    }

    fn tier_index(&self, tier: Tier) -> usize {
        // canonical_tier guarantees the index is in range for this zoo
        match canonical_tier(tier, self.engines.len()) {
            Tier::Fast => 0,
            Tier::Balanced => 1,
            Tier::Accurate => 2,
        }
    }

    /// Route one sample at a fixed tier (no escalation).
    pub fn classify_tier(&mut self, x: &[f32], tier: Tier) -> crate::Result<usize> {
        Ok(self.classify_batch(x, 1, tier)?[0])
    }

    /// Route a whole micro-batch at a fixed tier (no escalation),
    /// predictions written into `out[..n]` (write-into contract: a short
    /// plane is an `Err` before the engine runs). `n > 1` takes the
    /// engine's fused batch path; the tier engine's own `classify_into`
    /// override keeps the whole call allocation-free.
    pub fn classify_batch_into(
        &mut self,
        x: &[f32],
        n: usize,
        tier: Tier,
        out: &mut [usize],
    ) -> crate::Result<()> {
        anyhow::ensure!(out.len() >= n, "prediction plane too short: {} < {n}", out.len());
        let i = self.tier_index(tier);
        let t0 = Instant::now();
        self.engines[i].classify_into(x, n, out)?;
        let elapsed = t0.elapsed().as_nanos() as u64;
        self.stats.tier_ns[i] += elapsed;
        self.stats.critical_path_ns += elapsed;
        self.stats.served[i] += n as u64;
        Ok(())
    }

    /// [`ModelRouter::classify_batch_into`] into a fresh `Vec`.
    pub fn classify_batch(
        &mut self,
        x: &[f32],
        n: usize,
        tier: Tier,
    ) -> crate::Result<Vec<usize>> {
        let mut out = vec![0usize; n];
        self.classify_batch_into(x, n, tier, &mut out)?;
        Ok(out)
    }

    /// Cascade: start at Fast; escalate while the decision margin is thin.
    pub fn classify_cascade(&mut self, x: &[f32]) -> crate::Result<usize> {
        let mut pred = 0usize;
        // one knob load per call: a concurrent retune applies to the
        // NEXT call, keeping each cascade internally consistent
        let threshold = self.margin.get();
        for i in 0..self.engines.len() {
            let t0 = Instant::now();
            let resp = self.engines[i].responses(x, 1)?;
            let elapsed = t0.elapsed().as_nanos() as u64;
            self.stats.tier_ns[i] += elapsed;
            self.stats.critical_path_ns += elapsed;
            let (top1, top2, arg) = top2(&resp);
            pred = arg;
            let margin = (top1 - top2) / self.max_response[i].max(1.0);
            self.stats.served[i] += 1;
            if margin >= threshold || i + 1 == self.engines.len() {
                return Ok(pred);
            }
            self.stats.escalations_from[i] += 1;
        }
        Ok(pred)
    }

    /// Batched cascade, predictions written into `preds[..n]`: the whole
    /// batch hits the first tier through ONE
    /// [`InferenceEngine::responses_into`] call (the fused bit-sliced
    /// kernel for `n > 1`); thin-margin rows are gathered into a
    /// compacted sub-batch which escalates to the next tier, repeating
    /// until the last tier; predictions scatter back in original row
    /// order. Bit-exact with `n` sequential
    /// [`ModelRouter::classify_cascade`] calls, including every per-tier
    /// counter — and allocation-free after warmup.
    pub fn classify_cascade_batch_into(
        &mut self,
        x: &[f32],
        n: usize,
        preds: &mut [usize],
    ) -> crate::Result<()> {
        self.cascade_batch_into(x, n, None, preds)
    }

    /// [`ModelRouter::classify_cascade_batch_into`] into a fresh `Vec`.
    pub fn classify_cascade_batch(&mut self, x: &[f32], n: usize) -> crate::Result<Vec<usize>> {
        let mut preds = vec![0usize; n];
        self.classify_cascade_batch_into(x, n, &mut preds)?;
        Ok(preds)
    }

    /// Batched cascade writing both planes: row `r` of `scores[..n*m]`
    /// holds the per-class scores of the tier that RESOLVED row `r` (so
    /// rows resolved at different tiers carry that tier's score scale —
    /// normalize by tier `max_response` to compare), `preds[..n]` the
    /// predictions.
    pub fn cascade_responses_batch_into(
        &mut self,
        x: &[f32],
        n: usize,
        scores: &mut [f32],
        preds: &mut [usize],
    ) -> crate::Result<()> {
        self.cascade_batch_into(x, n, Some(scores), preds)
    }

    /// [`ModelRouter::cascade_responses_batch_into`] into fresh `Vec`s.
    pub fn cascade_responses_batch(
        &mut self,
        x: &[f32],
        n: usize,
    ) -> crate::Result<(Vec<f32>, Vec<usize>)> {
        let mut scores = vec![0f32; n * self.num_classes()];
        let mut preds = vec![0usize; n];
        self.cascade_batch_into(x, n, Some(&mut scores), &mut preds)?;
        Ok((scores, preds))
    }

    /// Resolution-tier scores only, predictions staged in the router's
    /// grow-only arena — what a scores-only caller (`RouterEngine::
    /// responses_into`) uses to stay allocation-free.
    pub fn cascade_scores_into(
        &mut self,
        x: &[f32],
        n: usize,
        scores: &mut [f32],
    ) -> crate::Result<()> {
        let mut preds = std::mem::take(&mut self.pred_arena);
        if preds.len() < n {
            preds.resize(n, 0);
        }
        let res = self.cascade_batch_into(x, n, Some(scores), &mut preds);
        self.pred_arena = preds;
        res
    }

    /// Core batched cascade under the write-into contract: plane sizes
    /// are validated up front (`Err`, never a panic), only the `n`-row
    /// prefixes are written, and they are written COMPLETELY (every row
    /// resolves at some tier), so dirty oversized planes are fine.
    /// `scores` is only filled when a caller wants the resolution-tier
    /// response matrix — the serving hot path
    /// (`classify_cascade_batch_into`) skips it entirely. Gather buffers
    /// and the per-tier response arena live in `cascade_scratch`, so
    /// after warmup the cascade allocates nothing at all.
    fn cascade_batch_into(
        &mut self,
        x: &[f32],
        n: usize,
        mut scores: Option<&mut [f32]>,
        preds: &mut [usize],
    ) -> crate::Result<()> {
        let f = self.num_features();
        let m = self.num_classes();
        anyhow::ensure!(x.len() == n * f, "bad input length");
        anyhow::ensure!(
            preds.len() >= n,
            "prediction plane too short: {} < {n}",
            preds.len()
        );
        if let Some(sc) = scores.as_deref_mut() {
            anyhow::ensure!(
                sc.len() >= n * m,
                "score plane too short: {} < {}",
                sc.len(),
                n * m
            );
        }
        if n == 0 {
            return Ok(());
        }
        let tiers = self.engines.len();
        // one knob load per batch: dynamic-margin runs are bit-exact
        // with a static cascade re-run at the loaded value, and a
        // mid-batch retune can never split one batch across thresholds
        let threshold = self.margin.get();
        // Scratch is taken for the duration of the call and restored on
        // every exit path (including tier-engine errors), so one warmup
        // lasts the router's lifetime. `rows` holds the original row ids
        // of the current compacted sub-batch; tier 0 reads the caller's
        // buffer directly, later tiers the gathered one.
        let mut s = std::mem::take(&mut self.cascade_scratch);
        s.rows.clear();
        s.rows.extend(0..n);
        for i in 0..tiers {
            let cnt = s.rows.len();
            if cnt == 0 {
                break;
            }
            // the one grow-only arena serves every tier's sub-batch
            if s.resp.len() < cnt * m {
                s.resp.resize(cnt * m, 0.0);
            }
            let t0 = Instant::now();
            let call = {
                let xb: &[f32] = if i == 0 { x } else { &s.gathered };
                self.engines[i].responses_into(xb, cnt, &mut s.resp[..cnt * m])
            };
            if let Err(e) = call {
                self.cascade_scratch = s;
                return Err(e);
            }
            let elapsed = t0.elapsed().as_nanos() as u64;
            self.stats.tier_ns[i] += elapsed;
            self.stats.critical_path_ns += elapsed;
            self.stats.served[i] += cnt as u64;
            let last = i + 1 == tiers;
            s.next_rows.clear();
            s.next_gathered.clear();
            for (r, &row) in s.rows.iter().enumerate() {
                let rr = &s.resp[r * m..(r + 1) * m];
                let (top1, top2, arg) = top2(rr);
                let margin = (top1 - top2) / self.max_response[i].max(1.0);
                if margin >= threshold || last {
                    preds[row] = arg;
                    if let Some(sc) = scores.as_deref_mut() {
                        sc[row * m..(row + 1) * m].copy_from_slice(rr);
                    }
                } else {
                    self.stats.escalations_from[i] += 1;
                    s.next_rows.push(row);
                    s.next_gathered.extend_from_slice(&x[row * f..(row + 1) * f]);
                }
            }
            std::mem::swap(&mut s.rows, &mut s.next_rows);
            std::mem::swap(&mut s.gathered, &mut s.next_gathered);
        }
        self.cascade_scratch = s;
        Ok(())
    }

    /// Fraction of first-tier traffic resolved WITHOUT escalating —
    /// computed from tier-0 resolutions directly, so escalations at
    /// deeper tier boundaries (tier 1 → 2 on a 3-tier zoo) don't distort
    /// it the way the old `served[0] - total_escalations` formula did.
    pub fn fast_path_fraction(&self) -> f64 {
        let total = self.stats.served[0];
        if total == 0 {
            return 0.0;
        }
        (total - self.stats.escalations_from[0].min(total)) as f64 / total as f64
    }
}

/// Resolve a pinned tier to its canonical representative on an
/// `num_tiers`-tier zoo. Aliased tiers (`Balanced` and `Accurate` both
/// clamp to the middle=last engine on a 2-tier zoo) map to the SAME
/// value, so the tier-homogeneous batcher cannot split a micro-batch
/// between two names for one engine. The single source of the tier →
/// index mapping ([`ModelRouter`]'s `tier_index` delegates here).
pub fn canonical_tier(tier: Tier, num_tiers: usize) -> Tier {
    const BY_INDEX: [Tier; 3] = [Tier::Fast, Tier::Balanced, Tier::Accurate];
    // clamp to the 3 service classes — an engine reporting a deeper zoo
    // still only distinguishes three pin levels
    let last = (num_tiers.max(1) - 1).min(2);
    let idx = match tier {
        Tier::Fast => 0,
        Tier::Balanced => last.min(1),
        Tier::Accurate => last,
    };
    BY_INDEX[idx]
}

/// Human labels for the tier indices of an `num_tiers`-tier zoo,
/// mirroring [`ModelRouter`]'s tier clamping (on a 2-tier zoo both
/// `Balanced` and `Accurate` pin to index 1, so it reads "accurate").
/// The one place index → name lives; the CLI report uses it.
pub fn tier_names(num_tiers: usize) -> &'static [&'static str] {
    match num_tiers {
        0 | 1 => &["fast"],
        2 => &["fast", "accurate"],
        _ => &["fast", "balanced", "accurate"],
    }
}

fn top2(resp: &[f32]) -> (f32, f32, usize) {
    let arg = crate::util::argmax_tie_low(resp);
    let best = resp.get(arg).copied().unwrap_or(f32::NEG_INFINITY);
    let mut second = f32::NEG_INFINITY;
    for (c, &r) in resp.iter().enumerate() {
        if c != arg && r > second {
            second = r;
        }
    }
    (best, second, arg)
}

/// Max possible response of a model = total kept filters + biases (used to
/// normalize cascade margins).
pub fn max_response_of(model: &crate::model::ensemble::UleenModel) -> f32 {
    model
        .submodels
        .iter()
        .map(|sm| {
            let kept_max = sm
                .discriminators
                .iter()
                .map(|d| d.kept())
                .max()
                .unwrap_or(0) as f32;
            let bias_max = sm.bias.iter().copied().max().unwrap_or(0) as f32;
            kept_max + bias_max
        })
        .sum()
}

/// A model zoo behind the [`InferenceEngine`] trait, so the serving
/// worker pool can own one router per worker. `responses`/`classify` run
/// the **batched cascade**; `classify_routed` additionally dispatches
/// tier-pinned micro-batches. When hooked to a [`ServerMetrics`] (see
/// [`Server::start_zoo`]), every call flushes its per-tier
/// served/escalation/latency deltas so the serve loop can report them.
///
/// [`Server::start_zoo`]: crate::coordinator::server::Server::start_zoo
pub struct RouterEngine {
    router: ModelRouter,
    metrics: Option<Arc<ServerMetrics>>,
}

impl RouterEngine {
    pub fn new(router: ModelRouter) -> Self {
        Self { router, metrics: None }
    }

    /// Flush per-tier counter deltas into `metrics` after every call
    /// (and tell the sink this zoo's depth so reports label exactly the
    /// tiers that exist).
    pub fn with_metrics(mut self, metrics: Arc<ServerMetrics>) -> Self {
        metrics.set_num_tiers(self.router.num_tiers());
        metrics.set_model_bytes(self.router.model_bytes(), self.router.tier_model_bytes());
        self.metrics = Some(metrics);
        self
    }

    pub fn router(&self) -> &ModelRouter {
        &self.router
    }

    pub fn router_mut(&mut self) -> &mut ModelRouter {
        &mut self.router
    }

    /// Handle to the wrapped router's shared margin knob — the engine
    /// and its router are always two readers of the same atomic.
    pub fn margin_knob(&self) -> MarginKnob {
        self.router.margin_knob()
    }

    /// Run `call` on the router and flush the per-tier stat deltas it
    /// produced into the hooked metrics sink (if any).
    fn record<T>(&mut self, call: impl FnOnce(&mut ModelRouter) -> T) -> T {
        let before = self.router.stats.clone();
        let out = call(&mut self.router);
        if let Some(m) = &self.metrics {
            m.record_tiers(&self.router.stats.diff(&before));
        }
        out
    }
}

impl InferenceEngine for RouterEngine {
    fn label(&self) -> String {
        format!("zoo[{} tiers]", self.router.num_tiers())
    }

    fn num_features(&self) -> usize {
        self.router.num_features()
    }

    fn num_classes(&self) -> usize {
        self.router.num_classes()
    }

    fn num_tiers(&self) -> usize {
        self.router.num_tiers()
    }

    fn kernel_path(&self) -> &'static str {
        self.router.kernel_path()
    }

    fn model_bytes(&self) -> u64 {
        self.router.model_bytes()
    }

    fn tier_model_bytes(&self) -> [u64; 3] {
        self.router.tier_model_bytes()
    }

    /// Batched-cascade responses: each row carries the scores of the tier
    /// that resolved it (predictions land in the router's grow-only
    /// arena, not a per-call `Vec`).
    fn responses_into(&mut self, x: &[f32], n: usize, out: &mut [f32]) -> crate::Result<()> {
        self.record(|r| r.cascade_scores_into(x, n, out))
    }

    fn classify_into(&mut self, x: &[f32], n: usize, out: &mut [usize]) -> crate::Result<()> {
        self.record(|r| r.classify_cascade_batch_into(x, n, out))
    }

    fn classify_routed_into(
        &mut self,
        x: &[f32],
        n: usize,
        tier: Option<Tier>,
        out: &mut [usize],
    ) -> crate::Result<()> {
        match tier {
            Some(t) => self.record(|r| r.classify_batch_into(x, n, t, out)),
            None => self.record(|r| r.classify_cascade_batch_into(x, n, out)),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth_uci::{synth_uci, uci_spec};
    use crate::runtime::NativeEngine;
    use crate::train::oneshot::{train_oneshot, OneShotConfig};

    fn zoo() -> (ModelRouter, crate::data::Dataset) {
        let ds = synth_uci(5, uci_spec("vowel").unwrap());
        let mut engines: Vec<Box<dyn InferenceEngine>> = Vec::new();
        let mut maxr = Vec::new();
        for (n, e, bits) in [(8usize, 64usize, 2usize), (10, 128, 4), (10, 256, 8)] {
            let (m, _) = train_oneshot(
                &ds,
                &OneShotConfig {
                    inputs_per_filter: n,
                    entries_per_filter: e,
                    therm_bits: bits,
                    ..Default::default()
                },
            );
            maxr.push(max_response_of(&m));
            engines.push(Box::new(NativeEngine::new(m)));
        }
        (ModelRouter::new(engines, maxr), ds)
    }

    #[test]
    fn canonical_tier_collapses_aliases_per_zoo_depth() {
        // 2-tier zoo: Balanced and Accurate are the same engine — one
        // canonical value, so the batcher never splits between them
        assert_eq!(canonical_tier(Tier::Accurate, 2), Tier::Balanced);
        assert_eq!(canonical_tier(Tier::Balanced, 2), Tier::Balanced);
        assert_eq!(canonical_tier(Tier::Fast, 2), Tier::Fast);
        // 1-tier zoo: everything is the one engine
        assert_eq!(canonical_tier(Tier::Accurate, 1), Tier::Fast);
        // 3-tier zoo: identity
        assert_eq!(canonical_tier(Tier::Balanced, 3), Tier::Balanced);
        assert_eq!(canonical_tier(Tier::Accurate, 3), Tier::Accurate);
    }

    #[test]
    fn tier_routing_uses_the_right_engine() {
        let (mut r, ds) = zoo();
        let x = ds.test_row(0);
        r.classify_tier(x, Tier::Fast).unwrap();
        r.classify_tier(x, Tier::Balanced).unwrap();
        r.classify_tier(x, Tier::Accurate).unwrap();
        assert_eq!(r.stats.served, [1, 1, 1]);
    }

    #[test]
    fn tier_batch_routing_matches_per_sample() {
        let (mut r, ds) = zoo();
        let n = 70.min(ds.n_test());
        let x = &ds.test_x[..n * ds.num_features];
        for tier in [Tier::Fast, Tier::Balanced, Tier::Accurate] {
            let batch = r.classify_batch(x, n, tier).unwrap();
            let single: Vec<usize> = (0..n)
                .map(|i| r.classify_tier(ds.test_row(i), tier).unwrap())
                .collect();
            assert_eq!(batch, single, "{tier:?}");
        }
        assert_eq!(r.stats.served, [2 * n as u64, 2 * n as u64, 2 * n as u64]);
    }

    #[test]
    fn cascade_resolves_everything_and_tracks_escalations() {
        let (mut r, ds) = zoo();
        let mut correct = 0;
        for i in 0..ds.n_test() {
            let p = r.classify_cascade(ds.test_row(i)).unwrap();
            if p == ds.test_y[i] as usize {
                correct += 1;
            }
        }
        // every request hits tier 0; escalations bounded by requests
        assert_eq!(r.stats.served[0] as usize, ds.n_test());
        assert!(r.stats.escalations() <= 2 * ds.n_test() as u64);
        // cascade should not be (much) worse than the big model alone
        let acc = correct as f64 / ds.n_test() as f64;
        assert!(acc > 0.35, "cascade accuracy {acc}");
    }

    #[test]
    fn batched_cascade_matches_sequential_on_real_models() {
        let (mut batch_r, ds) = zoo();
        let (mut seq_r, _) = zoo();
        let n = ds.n_test();
        let x = &ds.test_x[..n * ds.num_features];
        let got = batch_r.classify_cascade_batch(x, n).unwrap();
        let want: Vec<usize> = (0..n)
            .map(|i| seq_r.classify_cascade(ds.test_row(i)).unwrap())
            .collect();
        assert_eq!(got, want, "batched cascade must be bit-exact");
        assert_eq!(batch_r.stats.served, seq_r.stats.served);
        assert_eq!(batch_r.stats.escalations_from, seq_r.stats.escalations_from);
    }

    #[test]
    fn zero_threshold_never_escalates() {
        let (mut r, ds) = zoo();
        r.set_margin_threshold(0.0);
        for i in 0..20 {
            r.classify_cascade(ds.test_row(i)).unwrap();
        }
        assert_eq!(r.stats.escalations(), 0);
        assert_eq!(r.fast_path_fraction(), 1.0);
    }

    #[test]
    fn huge_threshold_always_escalates_to_last_tier() {
        let (mut r, ds) = zoo();
        r.set_margin_threshold(10.0);
        for i in 0..10 {
            r.classify_cascade(ds.test_row(i)).unwrap();
        }
        assert_eq!(r.stats.served[2], 10);
        assert_eq!(r.stats.escalations(), 20);
        assert_eq!(r.stats.escalations_from, [10, 10, 0]);
        assert_eq!(r.fast_path_fraction(), 0.0);
    }

    #[test]
    fn dynamic_margin_knob_steers_live_and_matches_a_static_rerun() {
        // The autopilot contract: retuning the shared knob between calls
        // must land exactly where a fresh router statically configured
        // at that margin lands — same predictions, same counters.
        let (mut dynamic, ds) = zoo();
        let knob = dynamic.margin_knob();
        let n = 50.min(ds.n_test());
        let x = &ds.test_x[..n * ds.num_features];
        for threshold in [0.0f32, 0.1, 10.0] {
            knob.set(threshold);
            dynamic.stats = RouterStats::default();
            let got = dynamic.classify_cascade_batch(x, n).unwrap();
            let (mut fixed, _) = zoo();
            fixed.set_margin_threshold(threshold);
            let want = fixed.classify_cascade_batch(x, n).unwrap();
            assert_eq!(got, want, "threshold {threshold}");
            assert_eq!(dynamic.stats.served, fixed.stats.served, "threshold {threshold}");
            assert_eq!(
                dynamic.stats.escalations_from, fixed.stats.escalations_from,
                "threshold {threshold}"
            );
        }
        // and the knob is truly shared: a clone's set is the router's set
        let clone = knob.clone();
        clone.set(0.25);
        assert_eq!(dynamic.margin_threshold(), 0.25);
        assert!(knob.shares_with(&clone));
    }

    #[test]
    fn empty_batch_is_a_no_op() {
        let (mut r, _) = zoo();
        assert!(r.classify_cascade_batch(&[], 0).unwrap().is_empty());
        assert_eq!(r.stats, RouterStats::default());
    }

    #[test]
    fn critical_path_tracks_serial_engine_time_exactly() {
        // On a sequential router every engine call serializes, so the
        // critical path IS the total engine time — bit-for-bit.
        let (mut r, ds) = zoo();
        r.set_margin_threshold(0.1);
        let n = 40.min(ds.n_test());
        r.classify_cascade_batch(&ds.test_x[..n * ds.num_features], n).unwrap();
        r.classify_batch(&ds.test_x[..n * ds.num_features], n, Tier::Accurate).unwrap();
        for i in 0..5 {
            r.classify_cascade(ds.test_row(i)).unwrap();
        }
        assert!(r.stats.critical_path_ns > 0);
        assert_eq!(
            r.stats.critical_path_ns,
            r.stats.tier_ns.iter().sum::<u64>(),
            "sequential critical path must equal summed tier time"
        );
    }

    #[test]
    fn merge_maxes_critical_path_and_chain_adds_it() {
        let a = RouterStats {
            served: [10, 2, 0],
            escalations_from: [2, 0, 0],
            tier_ns: [500, 300, 0],
            critical_path_ns: 800,
        };
        let b = RouterStats {
            served: [8, 1, 1],
            escalations_from: [1, 1, 0],
            tier_ns: [400, 200, 100],
            critical_path_ns: 700,
        };
        // parallel fold: counts add, the slowest worker is the path
        let mut merged = a.clone();
        merged.merge(&b);
        assert_eq!(merged.served, [18, 3, 1]);
        assert_eq!(merged.tier_ns, [900, 500, 100]);
        assert_eq!(merged.critical_path_ns, 800, "merge takes the max path");
        // serial fold: everything adds, including the path
        let mut chained = a.clone();
        chained.chain(&b);
        assert_eq!(chained.served, [18, 3, 1]);
        assert_eq!(chained.critical_path_ns, 1500, "chain concatenates paths");
        // diff stays exact over both
        let d = merged.diff(&a);
        assert_eq!(d.served, b.served);
        assert_eq!(d.critical_path_ns, 0, "a slower base absorbs the max");
    }

    #[test]
    fn cascade_into_honors_the_write_into_contract() {
        let (mut r, ds) = zoo();
        r.set_margin_threshold(0.1);
        let m = r.num_classes();
        let n = 30.min(ds.n_test());
        let x = &ds.test_x[..n * ds.num_features];
        let want = r.classify_cascade_batch(x, n).unwrap();
        let (want_scores, _) = r.cascade_responses_batch(x, n).unwrap();
        // dirty oversized planes: prefixes fully overwritten, suffixes kept
        let mut preds = vec![usize::MAX; n + 4];
        r.classify_cascade_batch_into(x, n, &mut preds).unwrap();
        assert_eq!(&preds[..n], &want[..]);
        assert!(preds[n..].iter().all(|&p| p == usize::MAX));
        let mut scores = vec![-1.5f32; n * m + 6];
        r.cascade_scores_into(x, n, &mut scores).unwrap();
        assert_eq!(&scores[..n * m], &want_scores[..]);
        assert!(scores[n * m..].iter().all(|&v| v == -1.5));
        // short planes are an Err before any engine runs
        let before = r.stats.clone();
        assert!(r.classify_cascade_batch_into(x, n, &mut preds[..n - 1]).is_err());
        assert!(r.classify_batch_into(x, n, Tier::Fast, &mut preds[..n - 1]).is_err());
        assert!(r.cascade_scores_into(x, n, &mut scores[..n * m - 1]).is_err());
        assert_eq!(r.stats, before, "rejected calls must not advance counters");
        // n = 0 touches nothing
        let mut untouched = vec![usize::MAX; 3];
        r.classify_cascade_batch_into(&[], 0, &mut untouched).unwrap();
        assert!(untouched.iter().all(|&p| p == usize::MAX));
    }

    #[test]
    fn router_engine_cascade_responses_resolve_rows() {
        let (r, ds) = zoo();
        let mut eng = RouterEngine::new(r);
        let n = 65.min(ds.n_test());
        let x = &ds.test_x[..n * ds.num_features];
        let m = eng.num_classes();
        let resp = eng.responses(x, n).unwrap();
        let preds = eng.classify(x, n).unwrap();
        assert_eq!(resp.len(), n * m);
        for (i, &p) in preds.iter().enumerate() {
            assert_eq!(
                crate::util::argmax_tie_low(&resp[i * m..(i + 1) * m]),
                p,
                "row {i}: resolution-tier scores must argmax to the prediction"
            );
        }
    }
}
