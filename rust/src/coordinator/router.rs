//! Model router — tiered dispatch across the ULN-S/M/L zoo.
//!
//! The paper's §V-D point is that ULEEN exposes an accuracy/efficiency/area
//! *interplay*; a deployment exploits it by keeping several model sizes
//! loaded and routing each request by its requirements. This router
//! implements the two policies a serving stack actually needs:
//!
//! * **tier routing** — requests carry a [`Tier`] (latency-critical →
//!   smallest model; accuracy-critical → largest);
//! * **confidence escalation** — classify on the small model first and
//!   escalate to the next tier when the response margin (top1 − top2,
//!   normalized by filter count) is below a threshold. This mirrors
//!   cascade inference and preserves the energy story: most requests take
//!   the cheap path.

use crate::runtime::InferenceEngine;

/// Request service class.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Tier {
    /// lowest latency/energy: smallest model only
    Fast,
    /// balanced: middle model
    Balanced,
    /// best accuracy: largest model
    Accurate,
}

/// Routing statistics (escalations tell you the cascade's cost).
#[derive(Clone, Debug, Default)]
pub struct RouterStats {
    pub served: [u64; 3],
    pub escalations: u64,
}

/// A tiered router over 1..=3 engines ordered small → large.
pub struct ModelRouter {
    engines: Vec<Box<dyn InferenceEngine>>,
    /// per-engine maximum possible response (for margin normalization)
    max_response: Vec<f32>,
    pub stats: RouterStats,
    /// escalate when (top1-top2)/max_response < threshold
    pub margin_threshold: f32,
}

impl ModelRouter {
    pub fn new(engines: Vec<Box<dyn InferenceEngine>>, max_response: Vec<f32>) -> Self {
        assert!(!engines.is_empty() && engines.len() <= 3);
        assert_eq!(engines.len(), max_response.len());
        let f = engines[0].num_features();
        let m = engines[0].num_classes();
        for e in &engines {
            assert_eq!(e.num_features(), f, "feature width mismatch across tiers");
            assert_eq!(e.num_classes(), m, "class count mismatch across tiers");
        }
        Self { engines, max_response, stats: RouterStats::default(), margin_threshold: 0.05 }
    }

    fn tier_index(&self, tier: Tier) -> usize {
        match tier {
            Tier::Fast => 0,
            Tier::Balanced => (self.engines.len() - 1).min(1),
            Tier::Accurate => self.engines.len() - 1,
        }
    }

    /// Route one sample at a fixed tier (no escalation).
    pub fn classify_tier(&mut self, x: &[f32], tier: Tier) -> crate::Result<usize> {
        let i = self.tier_index(tier);
        self.stats.served[i] += 1;
        Ok(self.engines[i].classify(x, 1)?[0])
    }

    /// Cascade: start at Fast; escalate while the decision margin is thin.
    pub fn classify_cascade(&mut self, x: &[f32]) -> crate::Result<usize> {
        let mut pred = 0usize;
        for i in 0..self.engines.len() {
            let resp = self.engines[i].responses(x, 1)?;
            let (top1, top2, arg) = top2(&resp);
            pred = arg;
            let margin = (top1 - top2) / self.max_response[i].max(1.0);
            self.stats.served[i] += 1;
            if margin >= self.margin_threshold || i + 1 == self.engines.len() {
                return Ok(pred);
            }
            self.stats.escalations += 1;
        }
        Ok(pred)
    }

    /// Fraction of cascade requests resolved by the first tier.
    pub fn fast_path_fraction(&self) -> f64 {
        let total = self.stats.served[0];
        if total == 0 {
            return 0.0;
        }
        (total - self.stats.escalations.min(total)) as f64 / total as f64
    }
}

fn top2(resp: &[f32]) -> (f32, f32, usize) {
    let arg = crate::util::argmax_tie_low(resp);
    let best = resp.get(arg).copied().unwrap_or(f32::NEG_INFINITY);
    let mut second = f32::NEG_INFINITY;
    for (c, &r) in resp.iter().enumerate() {
        if c != arg && r > second {
            second = r;
        }
    }
    (best, second, arg)
}

/// Max possible response of a model = total kept filters + biases (used to
/// normalize cascade margins).
pub fn max_response_of(model: &crate::model::ensemble::UleenModel) -> f32 {
    model
        .submodels
        .iter()
        .map(|sm| {
            let kept_max = sm
                .discriminators
                .iter()
                .map(|d| d.kept())
                .max()
                .unwrap_or(0) as f32;
            let bias_max = sm.bias.iter().copied().max().unwrap_or(0) as f32;
            kept_max + bias_max
        })
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth_uci::{synth_uci, uci_spec};
    use crate::runtime::NativeEngine;
    use crate::train::oneshot::{train_oneshot, OneShotConfig};

    fn zoo() -> (ModelRouter, crate::data::Dataset) {
        let ds = synth_uci(5, uci_spec("vowel").unwrap());
        let mut engines: Vec<Box<dyn InferenceEngine>> = Vec::new();
        let mut maxr = Vec::new();
        for (n, e, bits) in [(8usize, 64usize, 2usize), (10, 128, 4), (10, 256, 8)] {
            let (m, _) = train_oneshot(
                &ds,
                &OneShotConfig {
                    inputs_per_filter: n,
                    entries_per_filter: e,
                    therm_bits: bits,
                    ..Default::default()
                },
            );
            maxr.push(max_response_of(&m));
            engines.push(Box::new(NativeEngine::new(m)));
        }
        (ModelRouter::new(engines, maxr), ds)
    }

    #[test]
    fn tier_routing_uses_the_right_engine() {
        let (mut r, ds) = zoo();
        let x = ds.test_row(0);
        r.classify_tier(x, Tier::Fast).unwrap();
        r.classify_tier(x, Tier::Balanced).unwrap();
        r.classify_tier(x, Tier::Accurate).unwrap();
        assert_eq!(r.stats.served, [1, 1, 1]);
    }

    #[test]
    fn cascade_resolves_everything_and_tracks_escalations() {
        let (mut r, ds) = zoo();
        let mut correct = 0;
        for i in 0..ds.n_test() {
            let p = r.classify_cascade(ds.test_row(i)).unwrap();
            if p == ds.test_y[i] as usize {
                correct += 1;
            }
        }
        // every request hits tier 0; escalations bounded by requests
        assert_eq!(r.stats.served[0] as usize, ds.n_test());
        assert!(r.stats.escalations <= 2 * ds.n_test() as u64);
        // cascade should not be (much) worse than the big model alone
        let acc = correct as f64 / ds.n_test() as f64;
        assert!(acc > 0.35, "cascade accuracy {acc}");
    }

    #[test]
    fn zero_threshold_never_escalates() {
        let (mut r, ds) = zoo();
        r.margin_threshold = 0.0;
        for i in 0..20 {
            r.classify_cascade(ds.test_row(i)).unwrap();
        }
        assert_eq!(r.stats.escalations, 0);
        assert_eq!(r.fast_path_fraction(), 1.0);
    }

    #[test]
    fn huge_threshold_always_escalates_to_last_tier() {
        let (mut r, ds) = zoo();
        r.margin_threshold = 10.0;
        for i in 0..10 {
            r.classify_cascade(ds.test_row(i)).unwrap();
        }
        assert_eq!(r.stats.served[2], 10);
        assert_eq!(r.stats.escalations, 20);
    }
}
