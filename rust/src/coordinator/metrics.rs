//! Serving metrics: latency distribution, throughput, batch-fill factor,
//! rejection counts, per-tier zoo counters — the numbers the E2E example
//! and EXPERIMENTS.md report.

use crate::coordinator::router::RouterStats;
use crate::util::rng::Rng;
use crate::util::stats::{percentile, LogHistogram, OnlineStats};
use std::collections::BTreeMap;
use std::sync::Mutex;
use std::time::{Duration, Instant};

/// Cap on retained latency samples. Latencies feed an Algorithm R
/// reservoir: every completed request has an equal probability of being
/// in the sample, so the reservoir percentiles stay unbiased estimates
/// while memory stays O(1). Since the log2 histogram landed, the
/// reservoir is a cross-check witness (`latency_us_p50_reservoir`) —
/// the headline `latency_us_p50/p99` come from [`LogHistogram`], which
/// sees EVERY completion exactly (up to ≤1/128 bucket quantization)
/// instead of a uniform sample.
pub const LATENCY_RESERVOIR_CAP: usize = 4096;

/// One drained epoch of the windowed latency view (µs): the recent
/// completions recorded since the previous drain. This is what the
/// autopilot steers by — the cumulative histogram would answer lifetime
/// p99, which stops reacting to the present after enough history.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct LatencyWindow {
    pub count: u64,
    pub p50_us: f64,
    pub p99_us: f64,
}

/// Live controller state published by [`coordinator::autopilot`]: the
/// target, both knobs' current values, and the decision counters.
/// Serialized under the `"autopilot"` key in `/metrics` and the
/// shutdown report whenever a controller is attached.
///
/// [`coordinator::autopilot`]: crate::coordinator::autopilot
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct AutopilotStatus {
    pub target_p99_ms: f64,
    /// Current cascade margin (`None` on tier-blind servers, where the
    /// controller steers dwell alone).
    pub margin: Option<f32>,
    pub dwell_us: f64,
    pub tighten: u64,
    pub relax: u64,
    pub hold: u64,
}

struct Inner {
    /// Every completion's latency (µs), log2-bucketed: exact-up-to-
    /// quantization percentiles in fixed memory, no sort per scrape.
    latency_hist: LogHistogram,
    /// Epoch-swapped *windowed* latency pair: completions also record
    /// into `latency_window[window_active]`; `drain_latency_window`
    /// retires the live half (swap, read, reset) so the autopilot sees
    /// only the completions since its previous tick. Fixed memory, no
    /// allocation per epoch.
    latency_window: [LogHistogram; 2],
    window_active: usize,
    /// Controller state, present iff an autopilot is attached.
    autopilot: Option<AutopilotStatus>,
    /// ≤ [`LATENCY_RESERVOIR_CAP`] uniformly-sampled latencies (µs).
    latency_reservoir: Vec<f64>,
    /// Total latencies ever offered to the reservoir.
    latency_seen: u64,
    /// Exact running mean/min/max over ALL latencies (the reservoir only
    /// approximates percentiles; mean and max stay exact).
    latency_stats: OnlineStats,
    /// Deterministic replacement stream (seeded, so identical runs keep
    /// identical samples).
    reservoir_rng: Rng,
    /// HTTP responses served by the front-end, keyed by status code.
    http_responses: BTreeMap<u16, u64>,
    batch_sizes: OnlineStats,
    completed: u64,
    rejected_full: u64,
    rejected_closed: u64,
    /// requests dropped mid-batch for a wrong feature width
    malformed: u64,
    /// whole micro-batches dropped because the engine errored
    batches_failed: u64,
    /// per-tier samples served by zoo workers (tier-pinned + cascade)
    tier_served: [u64; 3],
    /// per-tier cascade escalations (out of tier i, into tier i+1)
    tier_escalations: [u64; 3],
    /// per-tier wall time spent inside the tier's engine
    tier_ns: [u64; 3],
    /// engine time on the latency-critical path: per-batch max over
    /// parallel worker ranges (== Σ tier_ns on unsharded zoos), summed
    /// over batches
    critical_path_ns: u64,
    /// zoo depth of the serving engines (0 = tier-blind server); set by
    /// `RouterEngine::with_metrics`, drives which tier keys serialize
    num_tiers: usize,
    /// SIMD dispatch tier of the serving engines' compiled kernel
    /// ("avx2" / "neon" / "scalar"; "n/a" until an engine reports in)
    kernel_path: &'static str,
    /// resident bytes of the serving engines' compiled model tables
    /// (summed over tiers; 0 until an engine reports in)
    model_bytes: u64,
    /// per-tier resident model bytes, small → large (all zero on
    /// tier-blind servers)
    tier_model_bytes: [u64; 3],
    started: Option<Instant>,
    finished: Option<Instant>,
}

impl Default for Inner {
    fn default() -> Self {
        Self {
            latency_hist: LogHistogram::new(),
            latency_window: [LogHistogram::new(), LogHistogram::new()],
            window_active: 0,
            autopilot: None,
            // Pre-size to the cap: the reservoir never reallocates on
            // the record path once the steady state is reached (and the
            // fill phase is alloc-free too).
            latency_reservoir: Vec::with_capacity(LATENCY_RESERVOIR_CAP),
            latency_seen: 0,
            latency_stats: OnlineStats::new(),
            reservoir_rng: Rng::new(0x5EED_1A7E),
            http_responses: BTreeMap::new(),
            batch_sizes: OnlineStats::new(),
            completed: 0,
            rejected_full: 0,
            rejected_closed: 0,
            malformed: 0,
            batches_failed: 0,
            tier_served: [0; 3],
            tier_escalations: [0; 3],
            tier_ns: [0; 3],
            critical_path_ns: 0,
            num_tiers: 0,
            kernel_path: "n/a",
            model_bytes: 0,
            tier_model_bytes: [0; 3],
            started: None,
            finished: None,
        }
    }
}

/// Thread-safe metrics sink shared by workers and producers.
#[derive(Default)]
pub struct ServerMetrics {
    inner: Mutex<Inner>,
}

/// A finished-run summary (all derived numbers precomputed).
#[derive(Clone, Debug)]
pub struct MetricsReport {
    pub completed: u64,
    pub rejected_full: u64,
    pub rejected_closed: u64,
    /// requests dropped mid-batch for a wrong feature width (the rest of
    /// their batch still completed)
    pub malformed: u64,
    /// whole micro-batches dropped because the engine errored
    pub batches_failed: u64,
    /// per-tier samples served by zoo workers (all zero on single-model
    /// servers)
    pub tier_served: [u64; 3],
    /// per-tier cascade escalations (out of tier i)
    pub tier_escalations: [u64; 3],
    /// mean engine-side µs per sample at each tier (0 where unserved)
    pub tier_mean_us: [f64; 3],
    /// engine milliseconds on the latency-critical path (ROADMAP (k)):
    /// each batch contributes the MAX over its parallel worker ranges —
    /// not the wall-time sum `tier_ns` reports — so this is the number
    /// to hold against a latency SLO. Equals Σ tier_ns on unsharded zoos.
    pub critical_path_ms: f64,
    /// zoo depth of the serving engines (0 = tier-blind server)
    pub num_tiers: usize,
    /// SIMD dispatch tier of the serving engines' compiled kernel
    /// (`"avx2"` / `"neon"` / `"scalar"`; `"n/a"` for engines that don't
    /// run the flat native kernel)
    pub kernel_path: &'static str,
    /// resident bytes of the serving engines' compiled model tables
    /// (arena + bias, summed over tiers; 0 = unaccounted, e.g. engines
    /// not built on the flat native layout)
    pub model_bytes: u64,
    /// per-tier resident model bytes, small → large (all zero on
    /// tier-blind servers; indexed like `tier_served`)
    pub tier_model_bytes: [u64; 3],
    pub wall_secs: f64,
    pub throughput_rps: f64,
    pub mean_batch_fill: f64,
    /// Histogram-exact p50 over EVERY completion (≤1/128 quantization).
    pub latency_us_p50: f64,
    /// Histogram-exact p99 over EVERY completion (≤1/128 quantization).
    pub latency_us_p99: f64,
    /// Reservoir-sampled p50 — retained as a cross-check witness for
    /// the histogram (large disagreement ⇒ a bucketing bug, not load).
    pub latency_us_p50_reservoir: f64,
    /// Reservoir-sampled p99 cross-check (see `latency_us_p50_reservoir`).
    pub latency_us_p99_reservoir: f64,
    pub latency_us_mean: f64,
    pub latency_us_max: f64,
    /// HTTP responses served by the front-end as (status, count),
    /// ascending by status; empty when no front-end is attached.
    pub http_responses: Vec<(u16, u64)>,
    /// NaN latencies rejected by the histogram (0 in healthy runs; a
    /// nonzero count means a corrupted clock reading, not load).
    pub latency_dropped_nan: u64,
    /// Controller state, present iff a latency autopilot is attached.
    pub autopilot: Option<AutopilotStatus>,
}

impl ServerMetrics {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn mark_start(&self) {
        self.mark_start_at(Instant::now());
    }

    /// Start the throughput wall-clock at `t` unless already started.
    /// The server calls this with the enqueue timestamp of the first
    /// ACCEPTED request — rejected bursts never start the clock.
    pub fn mark_start_at(&self, t: Instant) {
        let mut g = self.inner.lock().unwrap();
        if g.started.is_none() {
            g.started = Some(t);
        }
    }

    pub fn record_batch(&self, batch_size: usize, latencies: &[Duration]) {
        let mut g = self.inner.lock().unwrap();
        let inner = &mut *g;
        inner.batch_sizes.push(batch_size as f64);
        inner.completed += latencies.len() as u64;
        for l in latencies {
            let us = l.as_secs_f64() * 1e6;
            inner.latency_hist.record(us);
            inner.latency_window[inner.window_active].record(us);
            inner.latency_stats.push(us);
            inner.latency_seen += 1;
            if inner.latency_reservoir.len() < LATENCY_RESERVOIR_CAP {
                inner.latency_reservoir.push(us);
            } else {
                // Algorithm R: keep sample i with probability CAP/i.
                let j = inner.reservoir_rng.below(inner.latency_seen) as usize;
                if j < LATENCY_RESERVOIR_CAP {
                    inner.latency_reservoir[j] = us;
                }
            }
        }
        inner.finished = Some(Instant::now());
    }

    /// Count one HTTP response served by the front-end, keyed by status.
    pub fn record_http(&self, status: u16) {
        *self.inner.lock().unwrap().http_responses.entry(status).or_insert(0) += 1;
    }

    /// Retire the live latency window: swap the epoch pair so new
    /// completions record into the other half, then read + reset the
    /// half that just retired. Returns exactly the completions recorded
    /// since the previous drain (zero `count` when nothing completed) —
    /// each epoch is observed once and then gone, so consecutive drains
    /// of an idle server answer `count == 0`. The cumulative histogram
    /// behind `/metrics` is untouched.
    pub fn drain_latency_window(&self) -> LatencyWindow {
        let mut g = self.inner.lock().unwrap();
        let retired = g.window_active;
        g.window_active ^= 1;
        let h = &mut g.latency_window[retired];
        let out = LatencyWindow {
            count: h.count(),
            p50_us: h.percentile(0.50),
            p99_us: h.percentile(0.99),
        };
        h.reset();
        out
    }

    /// Completions recorded into the live (not-yet-drained) window —
    /// test/debug visibility into the epoch swap.
    pub fn latency_window_depth(&self) -> u64 {
        let g = self.inner.lock().unwrap();
        g.latency_window[g.window_active].count()
    }

    /// Publish controller state (called by the autopilot each tick);
    /// `/metrics` and the shutdown report carry it from then on.
    pub fn set_autopilot(&self, status: AutopilotStatus) {
        self.inner.lock().unwrap().autopilot = Some(status);
    }

    /// (retained latency samples, total latencies seen) — the retained
    /// count never exceeds [`LATENCY_RESERVOIR_CAP`]; the bounded-memory
    /// regression tests pin this down.
    pub fn latency_samples(&self) -> (usize, u64) {
        let g = self.inner.lock().unwrap();
        (g.latency_reservoir.len(), g.latency_seen)
    }

    pub fn record_reject(&self, full: bool) {
        let mut g = self.inner.lock().unwrap();
        if full {
            g.rejected_full += 1;
        } else {
            g.rejected_closed += 1;
        }
    }

    /// Count `n` requests dropped from a micro-batch for a wrong feature
    /// width (their batch-mates still complete — see `worker_loop`).
    pub fn record_malformed(&self, n: u64) {
        self.inner.lock().unwrap().malformed += n;
    }

    /// Count one whole micro-batch dropped because the engine errored.
    pub fn record_batch_failure(&self) {
        self.inner.lock().unwrap().batches_failed += 1;
    }

    /// Record the zoo depth behind this sink (called once when a
    /// `RouterEngine` hooks in) so reports label exactly the tiers that
    /// exist.
    pub fn set_num_tiers(&self, num_tiers: usize) {
        self.inner.lock().unwrap().num_tiers = num_tiers;
    }

    /// Record the serving engines' SIMD dispatch tier (called once at
    /// server construction from `InferenceEngine::kernel_path`) so a
    /// silently-degraded dispatch — scalar where AVX2 was expected —
    /// shows up on every `/metrics` scrape.
    pub fn set_kernel_path(&self, kernel_path: &'static str) {
        self.inner.lock().unwrap().kernel_path = kernel_path;
    }

    /// Record the serving engines' resident model footprint (called once
    /// when an engine hooks in, from `InferenceEngine::model_bytes` /
    /// `tier_model_bytes`, and again on a zoo swap) so every `/metrics`
    /// scrape carries the memory side of the accuracy/latency/memory
    /// trade — the accounting hook the multi-tenant registry (ROADMAP
    /// item 5) builds on.
    pub fn set_model_bytes(&self, total: u64, per_tier: [u64; 3]) {
        let mut g = self.inner.lock().unwrap();
        g.model_bytes = total;
        g.tier_model_bytes = per_tier;
    }

    /// Fold a router's per-tier counter delta into the serving totals
    /// (called by `RouterEngine` after every zoo micro-batch, and by
    /// `ShardedRouterEngine` with the POOL-MERGED delta of a fanned-out
    /// batch). Every per-tier field is additive, so folding one merged
    /// delta or each shard's delta separately — in any order — lands on
    /// identical totals (`shard_split_deltas_fold_identically_to_merged`);
    /// nothing here may ever average or overwrite. `critical_path_ns` is
    /// the exception that makes the merged-delta flush mandatory for
    /// sharded engines: per-shard paths fold by MAX inside
    /// `RouterStats::merge`, so only a pool-merged delta carries the
    /// batch's true path (summing raw per-shard paths would rebuild the
    /// wall-time overcount this field exists to fix).
    pub fn record_tiers(&self, delta: &RouterStats) {
        let mut g = self.inner.lock().unwrap();
        for i in 0..3 {
            g.tier_served[i] += delta.served[i];
            g.tier_escalations[i] += delta.escalations_from[i];
            g.tier_ns[i] += delta.tier_ns[i];
        }
        g.critical_path_ns += delta.critical_path_ns;
    }

    pub fn completed(&self) -> u64 {
        self.inner.lock().unwrap().completed
    }

    pub fn report(&self, max_batch: usize) -> MetricsReport {
        let g = self.inner.lock().unwrap();
        // `saturating` because started is now stamped on the ACCEPTED
        // submit path, which can lose a race with the worker completing
        // that very request — a clock running backwards must report 0,
        // not panic a scrape.
        let wall = match (g.started, g.finished) {
            (Some(a), Some(b)) => b.saturating_duration_since(a).as_secs_f64(),
            _ => 0.0,
        };
        // Headline percentiles come from the histogram: every
        // completion is recorded, so p50/p99 are exact up to ≤1/128
        // bucket quantization, with no sort and no sampling noise.
        let (p50, p99, mean, max) = if g.latency_hist.count() == 0 {
            (0.0, 0.0, 0.0, 0.0)
        } else {
            (
                g.latency_hist.percentile(0.50),
                g.latency_hist.percentile(0.99),
                g.latency_stats.mean(),
                g.latency_stats.max(),
            )
        };
        // The reservoir answers the same questions from a uniform
        // sample — kept as an independent cross-check witness.
        let (p50_res, p99_res) = if g.latency_reservoir.is_empty() {
            (0.0, 0.0)
        } else {
            // The clone is bounded by LATENCY_RESERVOIR_CAP — scrapes
            // are O(cap log cap) no matter how long the server has run.
            let mut v = g.latency_reservoir.clone();
            (percentile(&mut v, 0.50), percentile(&mut v, 0.99))
        };
        MetricsReport {
            completed: g.completed,
            rejected_full: g.rejected_full,
            rejected_closed: g.rejected_closed,
            malformed: g.malformed,
            batches_failed: g.batches_failed,
            tier_served: g.tier_served,
            tier_escalations: g.tier_escalations,
            tier_mean_us: std::array::from_fn(|i| {
                if g.tier_served[i] > 0 {
                    g.tier_ns[i] as f64 / g.tier_served[i] as f64 / 1e3
                } else {
                    0.0
                }
            }),
            critical_path_ms: g.critical_path_ns as f64 / 1e6,
            num_tiers: g.num_tiers,
            kernel_path: g.kernel_path,
            model_bytes: g.model_bytes,
            tier_model_bytes: g.tier_model_bytes,
            wall_secs: wall,
            throughput_rps: if wall > 0.0 { g.completed as f64 / wall } else { 0.0 },
            mean_batch_fill: if max_batch > 0 { g.batch_sizes.mean() / max_batch as f64 } else { 0.0 },
            latency_us_p50: p50,
            latency_us_p99: p99,
            latency_us_p50_reservoir: p50_res,
            latency_us_p99_reservoir: p99_res,
            latency_us_mean: mean,
            latency_us_max: max,
            http_responses: g.http_responses.iter().map(|(&k, &v)| (k, v)).collect(),
            latency_dropped_nan: g.latency_hist.dropped(),
            autopilot: g.autopilot,
        }
    }
}

impl MetricsReport {
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        let mut j = Json::obj();
        j.set("completed", Json::Num(self.completed as f64))
            .set("rejected_full", Json::Num(self.rejected_full as f64))
            .set("rejected_closed", Json::Num(self.rejected_closed as f64))
            .set("malformed", Json::Num(self.malformed as f64))
            .set("batches_failed", Json::Num(self.batches_failed as f64))
            .set("wall_secs", Json::Num(self.wall_secs))
            .set("throughput_rps", Json::Num(self.throughput_rps))
            .set("mean_batch_fill", Json::Num(self.mean_batch_fill))
            .set("latency_us_p50", Json::Num(self.latency_us_p50))
            .set("latency_us_p99", Json::Num(self.latency_us_p99))
            .set("latency_us_p50_reservoir", Json::Num(self.latency_us_p50_reservoir))
            .set("latency_us_p99_reservoir", Json::Num(self.latency_us_p99_reservoir))
            .set("latency_us_mean", Json::Num(self.latency_us_mean))
            .set("kernel_path", Json::Str(self.kernel_path.to_string()))
            .set("model_bytes", Json::Num(self.model_bytes as f64));
        // One key per tier that actually exists, named by the shared
        // index → label mapping (tier-blind servers emit none).
        let names = crate::coordinator::router::tier_names(self.num_tiers);
        for (i, name) in names.iter().enumerate().take(self.num_tiers) {
            let mut t = Json::obj();
            t.set("served", Json::Num(self.tier_served[i] as f64))
                .set("escalations", Json::Num(self.tier_escalations[i] as f64))
                .set("mean_engine_us", Json::Num(self.tier_mean_us[i]))
                .set("model_bytes", Json::Num(self.tier_model_bytes[i] as f64));
            j.set(&format!("tier_{name}"), t);
        }
        if self.num_tiers > 0 {
            j.set("critical_path_ms", Json::Num(self.critical_path_ms));
        }
        if !self.http_responses.is_empty() {
            let mut h = Json::obj();
            for &(status, count) in &self.http_responses {
                h.set(&status.to_string(), Json::Num(count as f64));
            }
            j.set("http", h);
        }
        if self.latency_dropped_nan > 0 {
            j.set("latency_dropped_nan", Json::Num(self.latency_dropped_nan as f64));
        }
        if let Some(ap) = &self.autopilot {
            let mut a = Json::obj();
            a.set("target_p99_ms", Json::Num(ap.target_p99_ms))
                .set("dwell_us", Json::Num(ap.dwell_us))
                .set("decisions_tighten", Json::Num(ap.tighten as f64))
                .set("decisions_relax", Json::Num(ap.relax as f64))
                .set("decisions_hold", Json::Num(ap.hold as f64));
            if let Some(m) = ap.margin {
                a.set("margin", Json::Num(m as f64));
            }
            j.set("autopilot", a);
        }
        j
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn report_computes_percentiles_and_throughput() {
        let m = ServerMetrics::new();
        m.mark_start();
        let lats: Vec<Duration> = (1..=100).map(Duration::from_micros).collect();
        m.record_batch(10, &lats[..50]);
        m.record_batch(6, &lats[50..]);
        let r = m.report(10);
        assert_eq!(r.completed, 100);
        // Histogram nearest-rank over 1..=100: p50 = 51, p99 = 99
        // (sub-128 values land in exact unit buckets).
        assert!((r.latency_us_p50 - 50.0).abs() <= 1.0);
        assert!((r.latency_us_p99 - 99.0).abs() <= 1.0);
        // Under the reservoir cap every sample is retained, so the
        // cross-check percentiles are exact nearest-rank answers.
        assert_eq!(r.latency_us_p50_reservoir, 51.0);
        assert_eq!(r.latency_us_p99_reservoir, 99.0);
        assert!((r.mean_batch_fill - 0.8).abs() < 1e-9);
        assert!(r.throughput_rps > 0.0);
        let json = r.to_json().to_string();
        assert!(json.contains("latency_us_p50_reservoir"), "cross-check key must serialize");
        assert!(json.contains("latency_us_p99_reservoir"), "cross-check key must serialize");
    }

    #[test]
    fn tier_counters_fold_router_deltas() {
        let m = ServerMetrics::new();
        let d = RouterStats {
            served: [10, 4, 1],
            escalations_from: [4, 1, 0],
            tier_ns: [10_000, 8_000, 3_000],
            critical_path_ns: 14_000,
        };
        m.set_num_tiers(3);
        m.set_model_bytes(6_000, [1_000, 2_000, 3_000]);
        m.record_tiers(&d);
        m.record_tiers(&d);
        m.record_malformed(3);
        m.record_batch_failure();
        let r = m.report(16);
        assert_eq!(r.tier_served, [20, 8, 2]);
        assert_eq!(r.tier_escalations, [8, 2, 0]);
        assert!((r.tier_mean_us[0] - 1.0).abs() < 1e-9, "20µs over 20 samples");
        assert!(
            (r.critical_path_ms - 28_000.0 / 1e6).abs() < 1e-12,
            "per-batch critical-path deltas accumulate additively"
        );
        assert_eq!(r.malformed, 3);
        assert_eq!(r.batches_failed, 1);
        assert_eq!(r.model_bytes, 6_000);
        assert_eq!(r.tier_model_bytes, [1_000, 2_000, 3_000]);
        let json = r.to_json().to_string();
        assert!(json.contains("tier_fast"), "per-tier counters must serialize");
        assert!(json.contains("critical_path_ms"), "the SLO metric must serialize");
        assert!(json.contains("\"model_bytes\":6000"), "footprint must serialize: {json}");
    }

    #[test]
    fn shard_split_deltas_fold_identically_to_merged() {
        // The sharded zoo may flush one pool-merged delta per batch or —
        // after a refactor — one delta per shard; the per-tier totals
        // must be identical either way, in any fold order. The critical
        // path is the deliberate exception: it only means "max over
        // parallel ranges" when the shards of one batch are merged FIRST
        // (summing raw per-shard paths rebuilds the wall-time overcount).
        let shard_deltas = [
            RouterStats { served: [7, 2, 1], escalations_from: [2, 1, 0], tier_ns: [700, 400, 90], critical_path_ns: 1190 },
            RouterStats { served: [5, 0, 0], escalations_from: [0, 0, 0], tier_ns: [512, 0, 0], critical_path_ns: 512 },
            RouterStats { served: [9, 4, 4], escalations_from: [4, 4, 0], tier_ns: [903, 800, 410], critical_path_ns: 2113 },
        ];
        let split = ServerMetrics::new();
        split.set_num_tiers(3);
        for d in &shard_deltas {
            split.record_tiers(d);
        }
        let merged_sink = ServerMetrics::new();
        merged_sink.set_num_tiers(3);
        let mut merged = RouterStats::default();
        // reverse order: the fold must be order-independent
        for d in shard_deltas.iter().rev() {
            merged.merge(d);
        }
        merged_sink.record_tiers(&merged);
        let (a, b) = (split.report(16), merged_sink.report(16));
        assert_eq!(a.tier_served, b.tier_served);
        assert_eq!(a.tier_served, [21, 6, 5]);
        assert_eq!(a.tier_escalations, b.tier_escalations);
        assert_eq!(a.tier_escalations, [6, 5, 0]);
        assert_eq!(a.tier_mean_us, b.tier_mean_us);
        assert!(
            (b.critical_path_ms - 2113.0 / 1e6).abs() < 1e-12,
            "the merged delta carries the slowest range as the batch's path"
        );
        assert!(
            a.critical_path_ms > b.critical_path_ms,
            "summing per-shard paths overcounts — merged-first is the contract"
        );
    }

    #[test]
    fn latency_memory_is_bounded_while_percentiles_stay_sound() {
        // Regression: latencies used to accumulate in an unbounded Vec
        // (O(requests) memory, O(n log n) per scrape). Record ≫ cap
        // samples and demand a capped buffer WITH sound percentiles.
        let m = ServerMetrics::new();
        m.mark_start();
        let total = 160_000usize; // ~39× the cap, multiple of 1000
        assert!(total > 2 * LATENCY_RESERVOIR_CAP);
        let lats: Vec<Duration> =
            (0..total).map(|i| Duration::from_micros((i % 1000 + 1) as u64)).collect();
        for chunk in lats.chunks(512) {
            m.record_batch(chunk.len(), chunk);
        }
        let (kept, seen) = m.latency_samples();
        assert_eq!(kept, LATENCY_RESERVOIR_CAP, "reservoir must stay at its cap");
        assert_eq!(seen, total as u64);
        let r = m.report(512);
        assert_eq!(r.completed, total as u64);
        // Uniform 1..=1000 µs: true p50 = 500, p99 = 990. The headline
        // numbers are histogram-exact up to ≤1/128 bucket quantization
        // (answers 502 and 988 here — bucket midpoints).
        assert!((r.latency_us_p50 - 500.0).abs() <= 8.0, "p50 {}", r.latency_us_p50);
        assert!((r.latency_us_p99 - 990.0).abs() <= 10.0, "p99 {}", r.latency_us_p99);
        // The reservoir cross-check sees a 4096-sample uniform sample:
        // σ(p50) ≈ 7.8 µs — ±60 is > 7σ.
        assert!(
            (r.latency_us_p50_reservoir - 500.0).abs() < 60.0,
            "reservoir p50 {}",
            r.latency_us_p50_reservoir
        );
        assert!(
            (r.latency_us_p99_reservoir - 990.0).abs() < 60.0,
            "reservoir p99 {}",
            r.latency_us_p99_reservoir
        );
        // The two estimators must agree with each other too — a large
        // split here means a bucketing bug, not sampling noise.
        assert!((r.latency_us_p50 - r.latency_us_p50_reservoir).abs() < 60.0);
        assert!((r.latency_us_p99 - r.latency_us_p99_reservoir).abs() < 60.0);
        // mean and max are exact (running stats, not the reservoir)
        assert!((r.latency_us_mean - 500.5).abs() < 1e-6, "mean {}", r.latency_us_mean);
        assert!((r.latency_us_max - 1000.0).abs() < 1e-6, "max {}", r.latency_us_max);
    }

    #[test]
    fn http_status_counts_serialize() {
        let m = ServerMetrics::new();
        for _ in 0..3 {
            m.record_http(200);
        }
        m.record_http(429);
        let r = m.report(16);
        assert_eq!(r.http_responses, vec![(200, 3), (429, 1)]);
        let json = r.to_json().to_string();
        assert!(json.contains("\"http\":{\"200\":3,\"429\":1}"), "got {json}");
    }

    #[test]
    fn wall_clock_never_starts_on_rejects_and_never_goes_negative() {
        let m = ServerMetrics::new();
        m.record_reject(true);
        let r = m.report(16);
        assert_eq!(r.wall_secs, 0.0, "a pure-reject run must not start the clock");
        // started stamped AFTER a completion (the accept-path race):
        // the scrape must clamp to zero, not panic
        let m = ServerMetrics::new();
        m.record_batch(1, &[Duration::from_micros(5)]);
        m.mark_start();
        let r = m.report(16);
        assert!(r.wall_secs >= 0.0);
        assert_eq!(r.throughput_rps, 0.0);
    }

    #[test]
    fn zero_request_report_is_all_zeros_and_never_panics() {
        // Regression: the reservoir path used to reach
        // `percentile(&mut empty, _)` whose old assert panicked a scrape
        // of a server that had completed nothing.
        let m = ServerMetrics::new();
        let r = m.report(16);
        assert_eq!(r.completed, 0);
        assert_eq!(r.latency_us_p50, 0.0);
        assert_eq!(r.latency_us_p99, 0.0);
        assert_eq!(r.latency_us_p50_reservoir, 0.0);
        assert_eq!(r.latency_us_p99_reservoir, 0.0);
        assert_eq!(r.throughput_rps, 0.0);
        assert!(r.autopilot.is_none());
        // and the JSON scrape of the empty server serializes too
        let json = r.to_json().to_string();
        assert!(json.contains("\"completed\":0"), "got {json}");
    }

    #[test]
    fn latency_window_drains_to_zero_between_epochs() {
        let m = ServerMetrics::new();
        let lats: Vec<Duration> = (1..=64).map(Duration::from_micros).collect();
        m.record_batch(64, &lats);
        assert_eq!(m.latency_window_depth(), 64);
        let w = m.drain_latency_window();
        assert_eq!(w.count, 64);
        assert!(w.p99_us >= w.p50_us && w.p50_us > 0.0);
        // the drained epoch is gone: an idle server's next drain is empty
        assert_eq!(m.latency_window_depth(), 0);
        let w2 = m.drain_latency_window();
        assert_eq!(w2, LatencyWindow::default());
        // the window is RECENT-only, while the cumulative histogram
        // keeps the full history for /metrics
        m.record_batch(2, &lats[..2]);
        let w3 = m.drain_latency_window();
        assert_eq!(w3.count, 2);
        assert_eq!(m.report(16).completed, 66);
        assert!(m.report(16).latency_us_p99 > 0.0);
    }

    #[test]
    fn autopilot_status_serializes_in_report_json() {
        let m = ServerMetrics::new();
        m.set_autopilot(AutopilotStatus {
            target_p99_ms: 2.5,
            margin: Some(0.125),
            dwell_us: 150.0,
            tighten: 3,
            relax: 1,
            hold: 7,
        });
        let r = m.report(16);
        let ap = r.autopilot.expect("status must surface in the report");
        assert_eq!(ap.tighten, 3);
        let json = r.to_json().to_string();
        assert!(json.contains("\"autopilot\":{"), "got {json}");
        assert!(json.contains("\"target_p99_ms\":2.5"), "got {json}");
        assert!(json.contains("\"margin\":0.125"), "got {json}");
        assert!(json.contains("\"decisions_tighten\":3"), "got {json}");
    }

    #[test]
    fn rejects_are_counted_separately() {
        let m = ServerMetrics::new();
        m.record_reject(true);
        m.record_reject(true);
        m.record_reject(false);
        let r = m.report(16);
        assert_eq!(r.rejected_full, 2);
        assert_eq!(r.rejected_closed, 1);
    }
}
