//! Unary ↔ binary bus-compression codec (paper §III-C).
//!
//! The accelerator can optionally receive inputs with each `t`-bit unary
//! thermometer value replaced by a `ceil(log2(t+1))`-bit binary count of
//! set bits, reducing off-chip data movement; a decompression unit recovers
//! the unary encoding on-chip. This module is the software model of that
//! codec (also used by the coordinator to compute bus-cycle counts).

use crate::util::bitvec::BitVec;

/// Bits needed to carry the count for a `t`-bit thermometer value.
pub fn compressed_bits_per_input(t: usize) -> usize {
    // counts range over 0..=t → t+1 values
    (usize::BITS - t.checked_add(1).unwrap().leading_zeros()) as usize - 1
        + if (t + 1).is_power_of_two() { 0 } else { 1 }
}

/// Compress per-input mercury counts into a packed little-endian bitstream.
pub fn compress(counts: &[u8], t: usize) -> BitVec {
    let w = compressed_bits_per_input(t);
    let mut out = BitVec::zeros(counts.len() * w);
    for (j, &c) in counts.iter().enumerate() {
        debug_assert!((c as usize) <= t);
        for b in 0..w {
            if (c >> b) & 1 == 1 {
                out.set(j * w + b);
            }
        }
    }
    out
}

/// Decompress a packed count stream back to the unary thermometer bits
/// (input-major, `t` bits per input) — the hardware decompressor's job.
pub fn decompress(stream: &BitVec, num_inputs: usize, t: usize) -> BitVec {
    let w = compressed_bits_per_input(t);
    assert_eq!(stream.len(), num_inputs * w);
    let mut out = BitVec::zeros(num_inputs * t);
    for j in 0..num_inputs {
        let mut c = 0usize;
        for b in 0..w {
            if stream.get(j * w + b) {
                c |= 1 << b;
            }
        }
        let c = c.min(t);
        for i in 0..c {
            out.set(j * t + i);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn width_formula() {
        assert_eq!(compressed_bits_per_input(1), 1); // counts 0..=1
        assert_eq!(compressed_bits_per_input(2), 2);
        assert_eq!(compressed_bits_per_input(3), 2); // 4 values
        assert_eq!(compressed_bits_per_input(7), 3);
        assert_eq!(compressed_bits_per_input(8), 4);
        assert_eq!(compressed_bits_per_input(15), 4);
    }

    #[test]
    fn roundtrip_random_counts() {
        let mut rng = Rng::new(21);
        for t in [1usize, 2, 3, 4, 7, 8, 15] {
            let counts: Vec<u8> =
                (0..50).map(|_| rng.below((t + 1) as u64) as u8).collect();
            let stream = compress(&counts, t);
            let unary = decompress(&stream, counts.len(), t);
            for (j, &c) in counts.iter().enumerate() {
                for i in 0..t {
                    assert_eq!(unary.get(j * t + i), i < c as usize, "t={t} j={j} i={i}");
                }
            }
        }
    }

    #[test]
    fn compression_actually_saves_for_large_t() {
        // 7-bit thermometer → 3-bit counts: 2.33x bus saving.
        assert!(compressed_bits_per_input(7) * 2 < 7);
    }
}
