//! Input encodings for weightless networks (paper §III-A2 and §III-C):
//! linear and Gaussian thermometer encoders, plus the unary↔binary bus
//! compression codec used by the accelerator's input interface.

pub mod codec;
pub mod thermometer;

pub use codec::{compress, decompress, compressed_bits_per_input};
pub use thermometer::{ThermometerEncoder, ThermometerKind};
