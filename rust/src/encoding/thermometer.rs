//! Thermometer encodings (paper §III-A2).
//!
//! A `t`-bit thermometer encoding compares a scalar input against `t`
//! increasing thresholds; bit `i` is set iff `x > threshold_i`, so bits
//! fill from least to most significant like mercury in a thermometer.
//!
//! * **Linear**: thresholds split `[min, max]` of the training data into
//!   equal intervals (prior work's choice).
//! * **Gaussian** (ULEEN's contribution): assume each input is normal with
//!   the training mean/std and place thresholds at the quantiles that cut
//!   the Gaussian into `t+1` equal-probability regions — more resolution
//!   near the centre of the range, fewer bits wasted on outliers.

use crate::util::bitvec::BitVec;

/// Which threshold-placement rule to use.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ThermometerKind {
    Linear,
    Gaussian,
}

/// A fitted per-input thermometer encoder.
///
/// `thresholds[input * bits + i]` is the i-th (increasing) threshold of
/// `input`. Encoded layout is input-major: bit `input * bits + i`.
#[derive(Clone, Debug)]
pub struct ThermometerEncoder {
    pub kind: ThermometerKind,
    pub num_inputs: usize,
    pub bits: usize,
    pub thresholds: Vec<f32>,
}

/// Mercury level of one input: how many of its (sorted, increasing)
/// thresholds the value exceeds. This is THE thermometer comparison —
/// every encode path (`encode_into`, `encode_counts`,
/// `encode_tile_slices`) goes through it so the branchless-count vs
/// `partition_point` cutover lives in exactly one place.
#[inline]
pub fn level(x: f32, thr: &[f32]) -> usize {
    // thresholds are sorted; for the small t used in practice a
    // branchless linear count beats a binary search
    if thr.len() <= 24 {
        thr.iter().map(|&th| (x > th) as usize).sum()
    } else {
        thr.partition_point(|&th| x > th)
    }
}

/// Inverse standard-normal CDF (Acklam's rational approximation,
/// |relative error| < 1.15e-9). Only +,*,/, sqrt, ln — portable enough for
/// threshold fitting (thresholds are stored as f32, crushing ULP noise).
pub fn inv_norm_cdf(p: f64) -> f64 {
    assert!(p > 0.0 && p < 1.0, "inv_norm_cdf domain");
    const A: [f64; 6] = [
        -3.969683028665376e+01,
        2.209460984245205e+02,
        -2.759285104469687e+02,
        1.383577518672690e+02,
        -3.066479806614716e+01,
        2.506628277459239e+00,
    ];
    const B: [f64; 5] = [
        -5.447609879822406e+01,
        1.615858368580409e+02,
        -1.556989798598866e+02,
        6.680131188771972e+01,
        -1.328068155288572e+01,
    ];
    const C: [f64; 6] = [
        -7.784894002430293e-03,
        -3.223964580411365e-01,
        -2.400758277161838e+00,
        -2.549732539343734e+00,
        4.374664141464968e+00,
        2.938163982698783e+00,
    ];
    const D: [f64; 4] = [
        7.784695709041462e-03,
        3.224671290700398e-01,
        2.445134137142996e+00,
        3.754408661907416e+00,
    ];
    const P_LOW: f64 = 0.02425;
    if p < P_LOW {
        let q = (-2.0 * p.ln()).sqrt();
        (((((C[0] * q + C[1]) * q + C[2]) * q + C[3]) * q + C[4]) * q + C[5])
            / ((((D[0] * q + D[1]) * q + D[2]) * q + D[3]) * q + 1.0)
    } else if p <= 1.0 - P_LOW {
        let q = p - 0.5;
        let r = q * q;
        (((((A[0] * r + A[1]) * r + A[2]) * r + A[3]) * r + A[4]) * r + A[5]) * q
            / (((((B[0] * r + B[1]) * r + B[2]) * r + B[3]) * r + B[4]) * r + 1.0)
    } else {
        let q = (-2.0 * (1.0 - p).ln()).sqrt();
        -((((((C[0] * q + C[1]) * q + C[2]) * q + C[3]) * q + C[4]) * q + C[5])
            / ((((D[0] * q + D[1]) * q + D[2]) * q + D[3]) * q + 1.0))
    }
}

impl ThermometerEncoder {
    /// Fit an encoder from training data: `data` is sample-major
    /// (`num_samples × num_inputs` flattened).
    pub fn fit(kind: ThermometerKind, data: &[f32], num_inputs: usize, bits: usize) -> Self {
        assert!(bits >= 1);
        assert!(!data.is_empty() && data.len() % num_inputs == 0);
        let n = data.len() / num_inputs;
        let mut thresholds = vec![0f32; num_inputs * bits];
        for j in 0..num_inputs {
            // mean/std and min/max of column j
            let mut mean = 0.0f64;
            let mut min = f64::INFINITY;
            let mut max = f64::NEG_INFINITY;
            for s in 0..n {
                let x = data[s * num_inputs + j] as f64;
                mean += x;
                min = min.min(x);
                max = max.max(x);
            }
            mean /= n as f64;
            let mut var = 0.0f64;
            for s in 0..n {
                let d = data[s * num_inputs + j] as f64 - mean;
                var += d * d;
            }
            var /= n as f64;
            let std = var.sqrt();
            for i in 0..bits {
                let th = match kind {
                    ThermometerKind::Linear => {
                        // t thresholds splitting [min,max] into t+1 equal bins
                        min + (max - min) * (i as f64 + 1.0) / (bits as f64 + 1.0)
                    }
                    ThermometerKind::Gaussian => {
                        let p = (i as f64 + 1.0) / (bits as f64 + 1.0);
                        // Degenerate column (constant) → all thresholds at mean.
                        if std > 0.0 {
                            mean + std * inv_norm_cdf(p)
                        } else {
                            mean
                        }
                    }
                };
                thresholds[j * bits + i] = th as f32;
            }
        }
        Self { kind, num_inputs, bits, thresholds }
    }

    /// Total encoded bits per sample.
    pub fn encoded_bits(&self) -> usize {
        self.num_inputs * self.bits
    }

    /// Encode one sample (length `num_inputs`) into a bit-packed vector of
    /// `encoded_bits()` bits, input-major.
    pub fn encode(&self, sample: &[f32]) -> BitVec {
        let mut out = BitVec::zeros(self.encoded_bits());
        self.encode_into(sample, &mut out);
        out
    }

    /// Zero-allocation encode into an existing vector (§Perf: the hot path
    /// re-uses one buffer). Thermometer codes are contiguous runs of ones
    /// from the LSB, so we binary-search the mercury level per input and
    /// set whole bit-runs with word masks instead of per-bit stores.
    pub fn encode_into(&self, sample: &[f32], out: &mut BitVec) {
        assert_eq!(sample.len(), self.num_inputs);
        assert_eq!(out.len(), self.encoded_bits());
        out.clear_all();
        let t = self.bits;
        for (j, &x) in sample.iter().enumerate() {
            let thr = &self.thresholds[j * t..(j + 1) * t];
            let mut level = level(x, thr);
            // set bits [j*t, j*t + level) as word-masked runs
            let mut pos = j * t;
            while level > 0 {
                let word = pos >> 6;
                let off = pos & 63;
                let take = level.min(64 - off);
                let mask = if take == 64 { u64::MAX } else { ((1u64 << take) - 1) << off };
                out.or_word(word, mask);
                pos += take;
                level -= take;
            }
        }
    }

    /// Encode a batch (sample-major flattened) into a vector of BitVecs.
    pub fn encode_batch(&self, data: &[f32]) -> Vec<BitVec> {
        assert_eq!(data.len() % self.num_inputs, 0);
        data.chunks(self.num_inputs).map(|s| self.encode(s)).collect()
    }

    /// Per-input set-bit count (the "mercury level"), used by the bus
    /// compression codec.
    pub fn encode_counts(&self, sample: &[f32]) -> Vec<u8> {
        assert_eq!(sample.len(), self.num_inputs);
        let t = self.bits;
        sample
            .iter()
            .enumerate()
            .map(|(j, &x)| level(x, &self.thresholds[j * t..(j + 1) * t]) as u8)
            .collect()
    }

    /// Fused tile encode (§Perf v5): encode up to 64 samples straight into
    /// the bit-sliced batch kernel's **native sample-slice layout**,
    /// skipping the per-sample `BitVec` and the O(set bits) transpose the
    /// old batch path paid per tile.
    ///
    /// `xs` is row-major (`nt × num_inputs`); on return `slices` has
    /// [`ThermometerEncoder::encoded_bits`] words and bit `s` of
    /// `slices[src]` is encoded bit `src` of sample `s` — exactly what
    /// `FlatModel::responses_tile_slices` consumes. Thermometer bit
    /// `j*t + i` of sample `s` is just `xs[s][j] > thresholds[j][i]`, so
    /// each sample's mercury level (shared [`level`] helper) directly
    /// yields a run of slice words to OR its sample bit into: work is one
    /// level search plus O(level) word-ORs per (sample, input), with no
    /// intermediate materialization.
    pub fn encode_tile_slices(&self, xs: &[f32], nt: usize, slices: &mut Vec<u64>) {
        assert!(nt <= 64, "a tile holds at most 64 samples");
        assert_eq!(xs.len(), nt * self.num_inputs);
        let t = self.bits;
        slices.clear();
        slices.resize(self.encoded_bits(), 0);
        for j in 0..self.num_inputs {
            let thr = &self.thresholds[j * t..(j + 1) * t];
            let col = &mut slices[j * t..(j + 1) * t];
            for s in 0..nt {
                let lvl = level(xs[s * self.num_inputs + j], thr);
                let sbit = 1u64 << s;
                for w in &mut col[..lvl] {
                    *w |= sbit;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn inv_norm_cdf_known_points() {
        assert!(inv_norm_cdf(0.5).abs() < 1e-9);
        assert!((inv_norm_cdf(0.975) - 1.959964).abs() < 1e-5);
        assert!((inv_norm_cdf(0.025) + 1.959964).abs() < 1e-5);
        assert!((inv_norm_cdf(0.8413447460685429) - 1.0).abs() < 1e-6);
        // deep tails use the other branch
        assert!((inv_norm_cdf(0.001) + 3.0902).abs() < 1e-3);
    }

    #[test]
    fn thermometer_monotone_in_input() {
        let data: Vec<f32> = (0..100).map(|i| i as f32).collect();
        let enc = ThermometerEncoder::fit(ThermometerKind::Linear, &data, 1, 8);
        let mut prev = 0;
        for x in [0.0f32, 10.0, 25.0, 50.0, 75.0, 99.0] {
            let ones = enc.encode(&[x]).count_ones();
            assert!(ones >= prev, "not monotone at {x}");
            prev = ones;
        }
    }

    #[test]
    fn thermometer_bits_are_contiguous_from_lsb() {
        let data: Vec<f32> = (0..100).map(|i| i as f32).collect();
        for kind in [ThermometerKind::Linear, ThermometerKind::Gaussian] {
            let enc = ThermometerEncoder::fit(kind, &data, 1, 6);
            for x in [3.0f32, 42.0, 77.0] {
                let v = enc.encode(&[x]);
                let ones = v.count_ones();
                for i in 0..6 {
                    assert_eq!(v.get(i), i < ones, "bit {i} of {x} ({kind:?})");
                }
            }
        }
    }

    #[test]
    fn gaussian_thresholds_increasing_and_centered() {
        // Symmetric data around 10.0
        let data: Vec<f32> = (0..1000)
            .map(|i| 10.0 + ((i % 21) as f32 - 10.0) * 0.3)
            .collect();
        let enc = ThermometerEncoder::fit(ThermometerKind::Gaussian, &data, 1, 5);
        for i in 1..5 {
            assert!(enc.thresholds[i] > enc.thresholds[i - 1]);
        }
        // middle threshold of odd count = mean for symmetric quantiles
        assert!((enc.thresholds[2] - 10.0).abs() < 0.05);
    }

    #[test]
    fn gaussian_denser_near_center_than_linear() {
        let data: Vec<f32> = (0..1000).map(|i| (i % 256) as f32).collect();
        let lin = ThermometerEncoder::fit(ThermometerKind::Linear, &data, 1, 7);
        let gau = ThermometerEncoder::fit(ThermometerKind::Gaussian, &data, 1, 7);
        let span = |t: &[f32]| t[4] - t[2]; // spacing around the median
        assert!(span(&gau.thresholds) < span(&lin.thresholds));
    }

    #[test]
    fn constant_column_does_not_panic() {
        let data = vec![5.0f32; 40];
        let enc = ThermometerEncoder::fit(ThermometerKind::Gaussian, &data, 2, 3);
        let v = enc.encode(&[5.0, 5.0]);
        assert_eq!(v.count_ones(), 0); // x > mean is false at equality
        let v = enc.encode(&[6.0, 4.0]);
        assert_eq!(v.count_ones(), 3);
    }

    #[test]
    fn tile_slices_match_per_sample_encode_plus_transpose() {
        let data: Vec<f32> = (0..400).map(|i| (i % 97) as f32).collect();
        for kind in [ThermometerKind::Linear, ThermometerKind::Gaussian] {
            let enc = ThermometerEncoder::fit(kind, &data, 4, 5);
            let f = enc.num_inputs;
            for nt in [1usize, 2, 63, 64] {
                let xs: Vec<f32> = (0..nt * f)
                    .map(|i| ((i * 31 + 7) % 113) as f32 - 5.0)
                    .collect();
                let mut slices = Vec::new();
                enc.encode_tile_slices(&xs, nt, &mut slices);
                assert_eq!(slices.len(), enc.encoded_bits());
                // reference: per-sample encode, transposed by hand
                let mut want = vec![0u64; enc.encoded_bits()];
                for s in 0..nt {
                    let v = enc.encode(&xs[s * f..(s + 1) * f]);
                    for src in 0..enc.encoded_bits() {
                        if v.get(src) {
                            want[src] |= 1u64 << s;
                        }
                    }
                }
                assert_eq!(slices, want, "kind={kind:?} nt={nt}");
            }
        }
    }

    #[test]
    fn tile_slices_handle_constant_columns_and_resize() {
        // degenerate (constant) feature column: level is 0 at the mean
        let data = vec![5.0f32; 60];
        let enc = ThermometerEncoder::fit(ThermometerKind::Gaussian, &data, 2, 3);
        let xs = [5.0f32, 5.0, 6.0, 4.0]; // 2 samples × 2 inputs
        // seed the buffer with a stale larger shape: must shrink + rezero
        let mut slices = vec![u64::MAX; 64];
        enc.encode_tile_slices(&xs, 2, &mut slices);
        assert_eq!(slices.len(), 6);
        // sample 0 is all-equal → no bits; sample 1 sets input 0's run only
        for (src, &w) in slices.iter().enumerate() {
            let expect = if src < 3 { 0b10 } else { 0 };
            assert_eq!(w, expect, "slice {src}");
        }
        // empty tile is legal and yields an all-zero slice buffer
        enc.encode_tile_slices(&[], 0, &mut slices);
        assert!(slices.iter().all(|&w| w == 0));
    }

    #[test]
    fn counts_agree_with_bits() {
        let data: Vec<f32> = (0..300).map(|i| (i % 100) as f32).collect();
        let enc = ThermometerEncoder::fit(ThermometerKind::Gaussian, &data, 3, 4);
        let sample = [12.0f32, 55.0, 91.0];
        let counts = enc.encode_counts(&sample);
        let bits = enc.encode(&sample);
        for j in 0..3 {
            let ones = (0..4).filter(|&i| bits.get(j * 4 + i)).count() as u8;
            assert_eq!(counts[j], ones);
        }
    }
}
