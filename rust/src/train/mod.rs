//! Training for the native engine (paper §III-B):
//!
//! * [`oneshot`] — the enhanced one-shot rule: counting Bloom filters +
//!   bleaching threshold found by binary search on a validation split.
//! * [`prune`] — post-training correlation pruning + integer bias learning
//!   (§III-A4). (Fine-tuning after pruning is gradient-based and lives in
//!   the JAX layer; the Rust side prunes one-shot models and re-biases.)
//! * [`sweep`] — the hyperparameter sweep driver behind Fig 14.

pub mod oneshot;
pub mod prune;
pub mod sweep;

pub use oneshot::{train_oneshot, OneShotConfig, OneShotReport};
pub use prune::{prune_model, prune_submodel, PruneReport};
pub use sweep::{sweep_oneshot, SweepPoint};
