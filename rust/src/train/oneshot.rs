//! One-shot training with counting Bloom filters and bleaching (paper
//! §III-B1, Fig 7a).
//!
//! Encoded training samples are presented once to the true class's
//! discriminator; counting filters apply the min-increment rule. The
//! bleaching threshold `b` is then chosen by a golden-section-style binary
//! search over the validation accuracy curve (the paper uses binary
//! search; accuracy(b) is near-unimodal in practice), and the counting
//! filters are binarized at `b` into the inference-time model.

use crate::bloom::counting::CountingBloom;
use crate::data::Dataset;
use crate::encoding::thermometer::{ThermometerEncoder, ThermometerKind};
use crate::model::ensemble::UleenModel;
use crate::model::submodel::{Discriminator, Submodel, SubmodelConfig};
use crate::util::rng::Rng;

/// Hyperparameters for one-shot training of a single-submodel model.
#[derive(Clone, Copy, Debug)]
pub struct OneShotConfig {
    pub inputs_per_filter: usize,
    pub entries_per_filter: usize,
    pub k_hashes: usize,
    pub therm_bits: usize,
    pub therm_kind: ThermometerKind,
    /// Fraction of the training set held out to tune the bleaching value.
    pub val_fraction: f64,
    pub seed: u64,
}

impl Default for OneShotConfig {
    fn default() -> Self {
        Self {
            inputs_per_filter: 16,
            entries_per_filter: 256,
            k_hashes: 2,
            therm_bits: 4,
            therm_kind: ThermometerKind::Gaussian,
            val_fraction: 0.1,
            seed: 0xB1EAC4,
        }
    }
}

/// The ULN-S/M/L one-shot shape presets (the paper's §V-D size classes)
/// as `(inputs_per_filter, entries_per_filter, therm_bits)`, small →
/// large. The ONE table behind `uleen serve --zoo s,m,l`, the
/// `engine_hot` cascade sweep, and the `edge_serving` zoo leg — tune a
/// preset here and all three stay in agreement.
pub const ZOO_PRESET_SHAPES: [(usize, usize, usize); 3] = [(8, 64, 2), (12, 128, 3), (16, 256, 4)];

/// Resolve a zoo preset name (`s|m|l` and long aliases) to its training
/// config; `None` for unknown names.
pub fn zoo_preset(name: &str) -> Option<OneShotConfig> {
    let idx = match name {
        "s" | "small" => 0,
        "m" | "med" | "medium" => 1,
        "l" | "large" => 2,
        _ => return None,
    };
    let (inputs_per_filter, entries_per_filter, therm_bits) = ZOO_PRESET_SHAPES[idx];
    Some(OneShotConfig { inputs_per_filter, entries_per_filter, therm_bits, ..Default::default() })
}

/// Outcome facts recorded next to the trained model.
#[derive(Clone, Debug)]
pub struct OneShotReport {
    pub bleach: u16,
    pub val_accuracy: f64,
    pub train_samples: usize,
    pub val_samples: usize,
    /// Validation accuracy at b=1 (no bleaching) — quantifies the benefit.
    pub val_accuracy_no_bleach: f64,
}

/// Train a one-shot ULEEN model (single submodel — the paper does not use
/// ensembles with the one-shot rule).
pub fn train_oneshot(ds: &Dataset, cfg: &OneShotConfig) -> (UleenModel, OneShotReport) {
    let mut rng = Rng::new(cfg.seed);
    let encoder = ThermometerEncoder::fit(
        cfg.therm_kind,
        &ds.train_x,
        ds.num_features,
        cfg.therm_bits,
    );
    let smcfg = SubmodelConfig {
        inputs_per_filter: cfg.inputs_per_filter,
        entries_per_filter: cfg.entries_per_filter,
        k_hashes: cfg.k_hashes,
        num_classes: ds.num_classes,
        total_input_bits: encoder.encoded_bits(),
    };
    let skeleton = Submodel::new_random(&mut rng, smcfg);
    let nf = smcfg.num_filters();
    let k = smcfg.k_hashes;

    // Split train/val deterministically.
    let n = ds.n_train();
    let n_val = ((n as f64 * cfg.val_fraction) as usize).clamp(1, n - 1);
    let mut order: Vec<u32> = rng.permutation(n);
    let val_idx: Vec<usize> = order.drain(..n_val).map(|i| i as usize).collect();
    let train_idx: Vec<usize> = order.into_iter().map(|i| i as usize).collect();

    // Counting filters per (class, filter).
    let mut counters: Vec<Vec<CountingBloom>> = (0..ds.num_classes)
        .map(|_| (0..nf).map(|_| CountingBloom::zeros(smcfg.entries_per_filter)).collect())
        .collect();

    let mut keys = Vec::new();
    let mut idxs: Vec<u64> = Vec::new();
    for &i in &train_idx {
        let encoded = encoder.encode(ds.train_row(i));
        skeleton.gather_keys(&encoded, &mut keys);
        skeleton.hash_keys(&keys, &mut idxs);
        let class = ds.train_y[i] as usize;
        for f in 0..nf {
            counters[class][f].train_indices(&idxs[f * k..(f + 1) * k]);
        }
    }

    // Precompute per-val-sample min-counts: minc[sample][class][filter].
    let mut minc: Vec<u16> = Vec::with_capacity(val_idx.len() * ds.num_classes * nf);
    let mut val_labels = Vec::with_capacity(val_idx.len());
    for &i in &val_idx {
        let encoded = encoder.encode(ds.train_row(i));
        skeleton.gather_keys(&encoded, &mut keys);
        skeleton.hash_keys(&keys, &mut idxs);
        for counters_c in counters.iter() {
            for f in 0..nf {
                minc.push(counters_c[f].query_min_indices(&idxs[f * k..(f + 1) * k]));
            }
        }
        val_labels.push(ds.train_y[i] as usize);
    }

    let acc_at = |b: u16| -> f64 {
        let mut correct = 0usize;
        let stride = ds.num_classes * nf;
        for (s, &label) in val_labels.iter().enumerate() {
            let base = s * stride;
            let mut best_c = 0usize;
            let mut best_r = -1i64;
            for c in 0..ds.num_classes {
                let row = &minc[base + c * nf..base + (c + 1) * nf];
                let r = row.iter().filter(|&&m| m >= b).count() as i64;
                if r > best_r {
                    best_r = r;
                    best_c = c;
                }
            }
            if best_c == label {
                correct += 1;
            }
        }
        correct as f64 / val_labels.len().max(1) as f64
    };

    let max_b = counters
        .iter()
        .flat_map(|cs| cs.iter().map(|c| c.max_counter()))
        .max()
        .unwrap_or(1)
        .max(1);

    // Bleaching search: accuracy(b) is only *near*-unimodal, so a pure
    // binary search can land in a bad basin. We combine (a) a dense scan of
    // small b (where the optimum almost always lives), (b) a geometric scan
    // up to max_b, and (c) golden-section refinement around the incumbent —
    // same spirit as the paper's binary search, robust to local dips.
    let mut candidates: Vec<u16> = (1..=max_b.min(16)).collect();
    let mut g = 16u32;
    while (g as u16) < max_b {
        candidates.push(g as u16);
        g = g * 3 / 2 + 1;
    }
    candidates.push(max_b);
    candidates.dedup();
    let mut best = (f64::MIN, 1u16);
    for &b in &candidates {
        let a = acc_at(b);
        if a > best.0 {
            best = (a, b);
        }
    }
    // local refinement around the incumbent
    let lo = best.1.saturating_sub(4).max(1);
    let hi = (best.1 + 4).min(max_b);
    for b in lo..=hi {
        let a = acc_at(b);
        if a > best.0 {
            best = (a, b);
        }
    }
    let (val_accuracy, bleach) = best;
    let val_accuracy_no_bleach = acc_at(1);

    // Binarize into the inference model.
    let discriminators: Vec<Discriminator> = counters
        .iter()
        .map(|cs| Discriminator {
            filters: cs.iter().map(|c| Some(c.binarize(bleach))).collect(),
        })
        .collect();
    let submodel = Submodel {
        cfg: smcfg,
        input_order: skeleton.input_order,
        hash: skeleton.hash,
        discriminators,
        bias: vec![0; ds.num_classes],
    };
    let model = UleenModel {
        name: format!("oneshot_{}", ds.name),
        encoder,
        submodels: vec![submodel],
    };
    let report = OneShotReport {
        bleach,
        val_accuracy,
        train_samples: train_idx.len(),
        val_samples: val_idx.len(),
        val_accuracy_no_bleach,
    };
    (model, report)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth_uci::{synth_uci, uci_spec, UciSpec};

    fn small_iris() -> Dataset {
        synth_uci(11, uci_spec("iris").unwrap())
    }

    #[test]
    fn learns_iris_like_data() {
        let ds = small_iris();
        let cfg = OneShotConfig {
            inputs_per_filter: 8,
            entries_per_filter: 128,
            therm_bits: 8,
            ..Default::default()
        };
        let (model, report) = train_oneshot(&ds, &cfg);
        model.validate().unwrap();
        let acc = model.evaluate(&ds.test_x, &ds.test_y, ds.num_features).accuracy();
        assert!(acc > 0.85, "one-shot test accuracy {acc}");
        assert!(report.bleach >= 1);
        assert!(report.val_accuracy > 0.8);
    }

    #[test]
    fn bleaching_rescues_skewed_data() {
        // Shuttle-like skew saturates the majority discriminator without
        // bleaching (paper §V-E); with bleaching, accuracy must be better
        // than the b=1 model on validation.
        let spec = UciSpec {
            n_train: 1500,
            n_test: 400,
            ..*uci_spec("shuttle").unwrap()
        };
        let ds = synth_uci(13, &spec);
        let cfg = OneShotConfig {
            inputs_per_filter: 12,
            entries_per_filter: 128,
            therm_bits: 6,
            ..Default::default()
        };
        let (_, report) = train_oneshot(&ds, &cfg);
        assert!(
            report.val_accuracy >= report.val_accuracy_no_bleach,
            "bleaching search must not do worse than b=1 ({} vs {})",
            report.val_accuracy,
            report.val_accuracy_no_bleach
        );
        assert!(report.bleach >= 1);
    }

    #[test]
    fn deterministic_given_seed() {
        let ds = small_iris();
        let cfg = OneShotConfig { therm_bits: 4, ..Default::default() };
        let (m1, r1) = train_oneshot(&ds, &cfg);
        let (m2, r2) = train_oneshot(&ds, &cfg);
        assert_eq!(r1.bleach, r2.bleach);
        assert_eq!(
            crate::model::uln_format::to_bytes(&m1, &crate::util::json::Json::obj()),
            crate::model::uln_format::to_bytes(&m2, &crate::util::json::Json::obj())
        );
    }
}
