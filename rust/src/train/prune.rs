//! Correlation-based pruning + integer bias learning (paper §III-A4).
//!
//! For every RAM node we compute the phi coefficient (Pearson correlation
//! of binary variables) between the filter's output and the "label ==
//! this discriminator's class" indicator over the training set. The
//! lowest-|phi| fraction of filters in **each discriminator** is removed,
//! and an integer bias equal to the rounded mean response lost is added so
//! discriminator response scales stay comparable.

use crate::data::Dataset;
use crate::model::ensemble::UleenModel;
use crate::model::submodel::{Submodel, SubmodelScratch};

/// What pruning did to one submodel.
#[derive(Clone, Debug)]
pub struct PruneReport {
    pub ratio: f64,
    pub filters_before: usize,
    pub filters_after: usize,
    pub size_kib_before: f64,
    pub size_kib_after: f64,
}

/// Per-(class, filter) activation statistics on a dataset.
struct ActStats {
    /// hits[class][filter] split by label match: (n11, n10, n01, n00)
    counts: Vec<(u64, u64, u64, u64)>,
    /// mean activation of filter on samples OF its class: used for bias
    mean_act_onclass: Vec<f64>,
    nf: usize,
}

fn activation_stats(sm: &Submodel, encoder: &crate::encoding::thermometer::ThermometerEncoder, ds: &Dataset) -> ActStats {
    let nf = sm.cfg.num_filters();
    let m = sm.cfg.num_classes;
    let k = sm.cfg.k_hashes;
    let mut counts = vec![(0u64, 0u64, 0u64, 0u64); m * nf];
    let mut on_hits = vec![0u64; m * nf];
    let mut on_total = vec![0u64; m];
    let mut scratch = SubmodelScratch::default();
    for i in 0..ds.n_train() {
        let encoded = encoder.encode(ds.train_row(i));
        sm.gather_keys(&encoded, &mut scratch.keys);
        sm.hash_keys(&scratch.keys, &mut scratch.idxs);
        let label = ds.train_y[i] as usize;
        on_total[label] += 1;
        for (c, disc) in sm.discriminators.iter().enumerate() {
            let is_class = c == label;
            for f in 0..nf {
                let fired = match &disc.filters[f] {
                    Some(filt) => filt.test_indices(&scratch.idxs[f * k..(f + 1) * k]),
                    None => false,
                };
                let e = &mut counts[c * nf + f];
                match (fired, is_class) {
                    (true, true) => e.0 += 1,
                    (true, false) => e.1 += 1,
                    (false, true) => e.2 += 1,
                    (false, false) => e.3 += 1,
                }
                if fired && is_class {
                    on_hits[c * nf + f] += 1;
                }
            }
        }
    }
    let mean_act_onclass = (0..m * nf)
        .map(|i| {
            let c = i / nf;
            if on_total[c] == 0 {
                0.0
            } else {
                on_hits[i] as f64 / on_total[c] as f64
            }
        })
        .collect();
    ActStats { counts, mean_act_onclass, nf }
}

/// Phi coefficient from a 2×2 contingency table.
fn phi(n11: u64, n10: u64, n01: u64, n00: u64) -> f64 {
    let (a, b, c, d) = (n11 as f64, n10 as f64, n01 as f64, n00 as f64);
    let den = ((a + b) * (c + d) * (a + c) * (b + d)).sqrt();
    if den == 0.0 {
        0.0
    } else {
        (a * d - b * c) / den
    }
}

/// Prune `ratio` of the filters in each discriminator of `sm` (lowest
/// |phi| first) and set integer biases compensating the lost mean
/// response. Returns the report; mutates the submodel in place.
pub fn prune_submodel(
    sm: &mut Submodel,
    encoder: &crate::encoding::thermometer::ThermometerEncoder,
    ds: &Dataset,
    ratio: f64,
) -> PruneReport {
    assert!((0.0..1.0).contains(&ratio));
    let stats = activation_stats(sm, encoder, ds);
    let nf = stats.nf;
    let size_before = sm.size_kib();
    let kept_before: usize = sm.discriminators.iter().map(|d| d.kept()).sum();
    let n_prune = ((nf as f64) * ratio).floor() as usize;
    for (c, disc) in sm.discriminators.iter_mut().enumerate() {
        // rank live filters by |phi| ascending
        let mut ranked: Vec<(f64, usize)> = (0..nf)
            .filter(|&f| disc.filters[f].is_some())
            .map(|f| {
                let (a, b, cc, d) = stats.counts[c * nf + f];
                (phi(a, b, cc, d).abs(), f)
            })
            .collect();
        ranked.sort_by(|x, y| x.0.partial_cmp(&y.0).unwrap());
        let mut lost_response = 0.0f64;
        for &(_, f) in ranked.iter().take(n_prune) {
            disc.filters[f] = None;
            lost_response += stats.mean_act_onclass[c * nf + f];
        }
        sm.bias[c] += lost_response.round() as i32;
    }
    let kept_after: usize = sm.discriminators.iter().map(|d| d.kept()).sum();
    PruneReport {
        ratio,
        filters_before: kept_before,
        filters_after: kept_after,
        size_kib_before: size_before,
        size_kib_after: sm.size_kib(),
    }
}

/// Prune every submodel of an ensemble at the same ratio.
pub fn prune_model(model: &mut UleenModel, ds: &Dataset, ratio: f64) -> Vec<PruneReport> {
    let encoder = model.encoder.clone();
    model
        .submodels
        .iter_mut()
        .map(|sm| prune_submodel(sm, &encoder, ds, ratio))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth_uci::{synth_uci, uci_spec};
    use crate::train::oneshot::{train_oneshot, OneShotConfig};

    #[test]
    fn phi_known_values() {
        assert!((phi(10, 0, 0, 10) - 1.0).abs() < 1e-12); // perfect correlation
        assert!((phi(0, 10, 10, 0) + 1.0).abs() < 1e-12); // perfect anti
        assert!(phi(5, 5, 5, 5).abs() < 1e-12); // independence
        assert_eq!(phi(0, 0, 0, 0), 0.0); // degenerate
    }

    #[test]
    fn pruning_reduces_size_proportionally_with_small_accuracy_cost() {
        let ds = synth_uci(31, uci_spec("vowel").unwrap());
        let cfg = OneShotConfig {
            inputs_per_filter: 10,
            entries_per_filter: 128,
            therm_bits: 6,
            ..Default::default()
        };
        let (mut model, _) = train_oneshot(&ds, &cfg);
        let acc_before = model.evaluate(&ds.test_x, &ds.test_y, ds.num_features).accuracy();
        let size_before = model.size_kib();
        let nf = model.submodels[0].cfg.num_filters();
        let expect_pruned = ((nf as f64) * 0.3).floor();
        let reports = prune_model(&mut model, &ds, 0.3);
        let acc_after = model.evaluate(&ds.test_x, &ds.test_y, ds.num_features).accuracy();
        let size_after = model.size_kib();
        let expect_after = size_before * (nf as f64 - expect_pruned) / nf as f64;
        assert!(
            (size_after - expect_after).abs() < 1e-9,
            "size {size_before} -> {size_after}, expected {expect_after}"
        );
        assert!(
            acc_after > acc_before - 0.08,
            "pruning 30% cost too much accuracy: {acc_before} -> {acc_after}"
        );
        assert_eq!(reports.len(), 1);
        assert!(reports[0].filters_after < reports[0].filters_before);
    }

    #[test]
    fn heavy_pruning_degrades_gracefully() {
        let ds = synth_uci(32, uci_spec("wine").unwrap());
        let (mut model, _) = train_oneshot(
            &ds,
            &OneShotConfig { inputs_per_filter: 8, entries_per_filter: 64, therm_bits: 4, ..Default::default() },
        );
        let chance = 1.0 / ds.num_classes as f64;
        prune_model(&mut model, &ds, 0.9);
        let acc = model.evaluate(&ds.test_x, &ds.test_y, ds.num_features).accuracy();
        // 90% pruning still leaves a working (if weak) model
        assert!(acc > chance, "90%-pruned model below chance: {acc}");
    }

    #[test]
    fn zero_ratio_is_identity() {
        let ds = synth_uci(33, uci_spec("iris").unwrap());
        let (mut model, _) = train_oneshot(&ds, &OneShotConfig::default());
        let size = model.size_kib();
        let rep = prune_model(&mut model, &ds, 0.0);
        assert_eq!(model.size_kib(), size);
        assert_eq!(rep[0].filters_before, rep[0].filters_after);
    }
}
