//! One-shot hyperparameter sweep (paper §V-F2 / Fig 14): grid over
//! thermometer bits, inputs per filter and entries per filter; records the
//! accuracy/size frontier.

use crate::data::Dataset;
use crate::encoding::thermometer::ThermometerKind;
use crate::train::oneshot::{train_oneshot, OneShotConfig};

/// One grid point's outcome.
#[derive(Clone, Debug)]
pub struct SweepPoint {
    pub therm_bits: usize,
    pub inputs_per_filter: usize,
    pub entries_per_filter: usize,
    pub size_kib: f64,
    pub test_accuracy: f64,
    pub bleach: u16,
}

/// Run the sweep. `grid` axes mirror the paper's sweep: thermometer bits,
/// inputs/filter, entries/filter (hash count fixed at 2 per §V-A).
pub fn sweep_oneshot(
    ds: &Dataset,
    bits_axis: &[usize],
    inputs_axis: &[usize],
    entries_axis: &[usize],
    seed: u64,
) -> Vec<SweepPoint> {
    let mut out = Vec::new();
    for &tb in bits_axis {
        for &ipf in inputs_axis {
            for &epf in entries_axis {
                let cfg = OneShotConfig {
                    inputs_per_filter: ipf,
                    entries_per_filter: epf,
                    k_hashes: 2,
                    therm_bits: tb,
                    therm_kind: ThermometerKind::Gaussian,
                    val_fraction: 0.1,
                    seed,
                };
                let (model, report) = train_oneshot(ds, &cfg);
                let acc = model
                    .evaluate(&ds.test_x, &ds.test_y, ds.num_features)
                    .accuracy();
                out.push(SweepPoint {
                    therm_bits: tb,
                    inputs_per_filter: ipf,
                    entries_per_filter: epf,
                    size_kib: model.size_kib(),
                    test_accuracy: acc,
                    bleach: report.bleach,
                });
            }
        }
    }
    out
}

/// "Best accuracy at size ≤ X" frontier used by Fig 14's left panel.
pub fn accuracy_size_frontier(points: &[SweepPoint]) -> Vec<(f64, f64)> {
    let mut sorted: Vec<&SweepPoint> = points.iter().collect();
    sorted.sort_by(|a, b| a.size_kib.partial_cmp(&b.size_kib).unwrap());
    let mut best = 0.0f64;
    let mut out = Vec::new();
    for p in sorted {
        if p.test_accuracy > best {
            best = p.test_accuracy;
            out.push((p.size_kib, best));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth_uci::{synth_uci, uci_spec};

    #[test]
    fn sweep_covers_grid_and_frontier_is_monotone() {
        let ds = synth_uci(41, uci_spec("wine").unwrap());
        let points = sweep_oneshot(&ds, &[2, 4], &[8, 12], &[64], 7);
        assert_eq!(points.len(), 4);
        let frontier = accuracy_size_frontier(&points);
        assert!(!frontier.is_empty());
        for w in frontier.windows(2) {
            assert!(w[1].0 >= w[0].0 && w[1].1 >= w[0].1);
        }
    }

    #[test]
    fn more_encoding_bits_do_not_hurt_much() {
        // Fig 14 middle panel shape: accuracy grows (with diminishing
        // returns) in thermometer bits.
        let ds = synth_uci(42, uci_spec("vehicle").unwrap());
        let pts = sweep_oneshot(&ds, &[1, 6], &[9], &[128], 3);
        let acc1 = pts[0].test_accuracy;
        let acc6 = pts[1].test_accuracy;
        assert!(acc6 >= acc1 - 0.05, "bits=1 {acc1} vs bits=6 {acc6}");
    }
}
