//! SynthMNIST — procedural 28×28 digit images (MNIST substitution).
//!
//! Integer-only pipeline so Rust and Python render **bit-identical** images
//! (DESIGN.md §2): digit stroke templates (polylines, Q8.8 fixed point) →
//! per-sample affine jitter (translate/scale/shear from the sample's PRNG
//! stream) → distance-field rasterization with integer arithmetic →
//! salt-noise speckles. Labels are balanced (`label = index % 10`).

use crate::data::{Dataset, DOMAIN_MNIST};
use crate::util::rng::Rng;

pub const IMG_W: usize = 28;
pub const IMG_H: usize = 28;
pub const IMG_PIXELS: usize = IMG_W * IMG_H;
const Q: i64 = 256; // fixed-point scale

/// Digit stroke templates in pixel coordinates (x0,y0,x1,y1). Mirrored
/// exactly in python/compile/data.py — keep the two tables in sync.
pub fn digit_segments(digit: usize) -> &'static [(i64, i64, i64, i64)] {
    const D0: &[(i64, i64, i64, i64)] =
        &[(9, 5, 18, 5), (18, 5, 19, 23), (19, 23, 9, 23), (9, 23, 8, 5), (8, 5, 9, 5)];
    const D1: &[(i64, i64, i64, i64)] = &[(14, 4, 14, 24), (14, 4, 10, 9), (11, 24, 17, 24)];
    const D2: &[(i64, i64, i64, i64)] =
        &[(8, 7, 12, 5), (12, 5, 18, 6), (18, 6, 19, 12), (19, 12, 8, 23), (8, 23, 20, 23)];
    const D3: &[(i64, i64, i64, i64)] = &[
        (8, 5, 19, 5),
        (19, 5, 14, 13),
        (14, 13, 19, 17),
        (19, 17, 18, 22),
        (18, 22, 8, 23),
    ];
    const D4: &[(i64, i64, i64, i64)] = &[(16, 4, 7, 17), (7, 17, 21, 17), (17, 10, 17, 24)];
    const D5: &[(i64, i64, i64, i64)] = &[
        (19, 5, 8, 5),
        (8, 5, 8, 13),
        (8, 13, 17, 13),
        (17, 13, 18, 18),
        (18, 18, 16, 23),
        (16, 23, 8, 23),
    ];
    const D6: &[(i64, i64, i64, i64)] = &[
        (18, 5, 11, 6),
        (11, 6, 9, 14),
        (9, 14, 9, 22),
        (9, 22, 18, 23),
        (18, 23, 19, 15),
        (19, 15, 9, 15),
    ];
    const D7: &[(i64, i64, i64, i64)] = &[(8, 5, 20, 5), (20, 5, 12, 24), (10, 14, 17, 14)];
    const D8: &[(i64, i64, i64, i64)] = &[
        (9, 5, 18, 5),
        (18, 5, 18, 13),
        (18, 13, 9, 13),
        (9, 13, 9, 5),
        (9, 13, 8, 23),
        (8, 23, 19, 23),
        (19, 23, 18, 13),
    ];
    const D9: &[(i64, i64, i64, i64)] = &[
        (19, 14, 9, 14),
        (9, 14, 9, 6),
        (9, 6, 18, 5),
        (18, 5, 19, 14),
        (19, 14, 18, 24),
        (18, 24, 11, 24),
    ];
    match digit {
        0 => D0,
        1 => D1,
        2 => D2,
        3 => D3,
        4 => D4,
        5 => D5,
        6 => D6,
        7 => D7,
        8 => D8,
        9 => D9,
        _ => panic!("digit out of range"),
    }
}

/// Squared point-to-segment distance, all Q8.8 integers. Non-negative
/// integer division only (floor == trunc), so Rust/Python agree exactly.
#[inline]
fn seg_dist2(px: i64, py: i64, ax: i64, ay: i64, bx: i64, by: i64) -> i64 {
    let abx = bx - ax;
    let aby = by - ay;
    let apx = px - ax;
    let apy = py - ay;
    let den = abx * abx + aby * aby;
    if den == 0 {
        return apx * apx + apy * apy;
    }
    let num = apx * abx + apy * aby;
    if num <= 0 {
        apx * apx + apy * apy
    } else if num >= den {
        let bpx = px - bx;
        let bpy = py - by;
        bpx * bpx + bpy * bpy
    } else {
        // |ap|^2 - num^2/den, num,den > 0: all magnitudes < 2^50 so num*num
        // fits i64; non-negative floor division is identical across languages.
        let ap2 = apx * apx + apy * apy;
        ap2 - num * num / den
    }
}

/// Maximum segments in any digit template (stream-alignment constant).
pub const MAX_SEGS: usize = 7;

/// round(sin(d°)*256) for d in 0..=28 — integer rotation table shared with
/// the Python generator (transcendental-free determinism).
const SIN_Q: [i64; 29] = [
    0, 4, 9, 13, 18, 22, 27, 31, 36, 40, 45, 49, 53, 58, 62, 66, 71, 75, 79, 83, 88, 92, 96,
    100, 104, 108, 112, 116, 120,
];
/// round(cos(d°)*256) for d in 0..=28.
const COS_Q: [i64; 29] = [
    256, 256, 256, 256, 255, 255, 255, 254, 254, 253, 252, 251, 250, 249, 248, 247, 246, 245,
    244, 242, 241, 239, 237, 236, 234, 232, 230, 228, 226,
];

/// Render one sample deterministically from `(seed, index)`.
///
/// Draw order (mirrored EXACTLY in python/compile/data.py): dx, dy, scale,
/// shear, radius, angle, 4×MAX_SEGS endpoint jitters, MAX_SEGS dropout
/// draws, n_noise, then 2×n_noise noise draws.
pub fn render_digit(seed: u64, index: u64) -> (Vec<u8>, u16) {
    let label = (index % 10) as u16;
    let mut rng = Rng::for_item(seed, DOMAIN_MNIST, index);
    let dx = rng.range_i64(-2 * Q, 2 * Q);
    let dy = rng.range_i64(-2 * Q, 2 * Q);
    let scale = rng.range_i64(225, 287); // 0.88 .. 1.12 (×256)
    let shear = rng.range_i64(-38, 38); // ±0.15 (×256)
    let radius = rng.range_i64(260, 430); // stroke half-width ~1.0 .. 1.68 px
    let angle = rng.range_i64(-20, 20); // rotation in degrees
    let mut seg_jit = [0i64; 4 * MAX_SEGS];
    for j in seg_jit.iter_mut() {
        *j = rng.range_i64(-300, 300); // ±1.17 px endpoint wobble
    }
    let mut seg_drop = [0u64; MAX_SEGS];
    for d in seg_drop.iter_mut() {
        *d = rng.below(100);
    }
    let n_noise = rng.range_i64(10, 40);

    let cx = 14 * Q;
    let cy = 14 * Q;
    let r2 = radius * radius;
    let (sin_q, cos_q) = {
        let a = angle.unsigned_abs() as usize;
        (if angle < 0 { -SIN_Q[a] } else { SIN_Q[a] }, COS_Q[a])
    };

    // Transform template segments (rotate → scale/shear → translate), with
    // per-endpoint wobble and random stroke dropout (≥2 segments kept).
    let template = digit_segments(label as usize);
    let mut segs: Vec<(i64, i64, i64, i64)> = Vec::with_capacity(template.len());
    let mut dropped = 0usize;
    for (si, &(x0, y0, x1, y1)) in template.iter().enumerate() {
        if seg_drop[si] < 12 && template.len() - dropped > 2 {
            dropped += 1;
            continue;
        }
        let tf = |x: i64, y: i64, jx: i64, jy: i64| -> (i64, i64) {
            let xq = x * Q - cx;
            let yq = y * Q - cy;
            // div_euclid == Python floor-division for positive divisors,
            // keeping the two generators bit-identical on negatives.
            let xr = (xq * cos_q - yq * sin_q).div_euclid(Q);
            let yr = (xq * sin_q + yq * cos_q).div_euclid(Q);
            let xt = cx + (xr * scale + yr * shear).div_euclid(Q) + dx + jx;
            let yt = cy + (yr * scale).div_euclid(Q) + dy + jy;
            (xt, yt)
        };
        let (ax, ay) = tf(x0, y0, seg_jit[4 * si], seg_jit[4 * si + 1]);
        let (bx, by) = tf(x1, y1, seg_jit[4 * si + 2], seg_jit[4 * si + 3]);
        segs.push((ax, ay, bx, by));
    }

    let mut img = vec![0u8; IMG_PIXELS];
    for py in 0..IMG_H {
        for px in 0..IMG_W {
            let pxq = px as i64 * Q + Q / 2;
            let pyq = py as i64 * Q + Q / 2;
            let mut best = i64::MAX;
            for &(ax, ay, bx, by) in &segs {
                let d2 = seg_dist2(pxq, pyq, ax, ay, bx, by);
                if d2 < best {
                    best = d2;
                }
            }
            if best < r2 {
                // intensity = 255 * (r2 - d2) / r2, saturating ink response
                let v = 255 * (r2 - best) / r2;
                // sharpen: anything within 60% radius is full ink
                let v = if best * 25 < r2 * 9 { 255 } else { v * 5 / 3 };
                img[py * IMG_W + px] = v.min(255) as u8;
            }
        }
    }
    // Salt noise speckles.
    for _ in 0..n_noise {
        let pos = rng.below(IMG_PIXELS as u64) as usize;
        let val = rng.below(140) as i64;
        let nv = img[pos] as i64 + 40 + val;
        img[pos] = nv.min(255) as u8;
    }
    (img, label)
}

/// Generate a SynthMNIST dataset: `n_train` + `n_test` samples. Test
/// samples use indices `n_train..n_train+n_test` of the same stream family.
pub fn synth_mnist(seed: u64, n_train: usize, n_test: usize) -> Dataset {
    let mut train_x = Vec::with_capacity(n_train * IMG_PIXELS);
    let mut train_y = Vec::with_capacity(n_train);
    for i in 0..n_train {
        let (img, y) = render_digit(seed, i as u64);
        train_x.extend(img.iter().map(|&p| p as f32));
        train_y.push(y);
    }
    let mut test_x = Vec::with_capacity(n_test * IMG_PIXELS);
    let mut test_y = Vec::with_capacity(n_test);
    for i in 0..n_test {
        let (img, y) = render_digit(seed, (n_train + i) as u64);
        test_x.extend(img.iter().map(|&p| p as f32));
        test_y.push(y);
    }
    Dataset {
        name: "synth_mnist".into(),
        num_features: IMG_PIXELS,
        num_classes: 10,
        train_x,
        train_y,
        test_x,
        test_y,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_rendering() {
        let (a, la) = render_digit(42, 7);
        let (b, lb) = render_digit(42, 7);
        assert_eq!(a, b);
        assert_eq!(la, lb);
        let (c, _) = render_digit(42, 8);
        assert_ne!(a, c);
    }

    #[test]
    fn labels_are_balanced() {
        let d = synth_mnist(1, 100, 20);
        let counts = d.train_class_counts();
        assert!(counts.iter().all(|&c| c == 10), "{counts:?}");
        d.validate().unwrap();
    }

    #[test]
    fn images_have_ink_and_background() {
        for i in 0..20 {
            let (img, _) = render_digit(3, i);
            let ink = img.iter().filter(|&&p| p > 128).count();
            let bg = img.iter().filter(|&&p| p == 0).count();
            assert!(ink > 20, "sample {i}: too little ink ({ink})");
            assert!(bg > 300, "sample {i}: too little background ({bg})");
        }
    }

    #[test]
    fn same_class_varies_between_samples() {
        // jitter must actually vary the rendering
        let (a, _) = render_digit(5, 0); // label 0
        let (b, _) = render_digit(5, 10); // label 0 again
        assert_ne!(a, b);
    }

    #[test]
    fn classes_are_visually_distinct() {
        // crude separability check: mean per-pixel L1 distance between
        // class prototypes must exceed within-class distance.
        let proto = |digit: u64| -> Vec<f64> {
            let mut acc = vec![0f64; IMG_PIXELS];
            for rep in 0..10 {
                let (img, _) = render_digit(9, digit + rep * 10);
                for (a, &p) in acc.iter_mut().zip(img.iter()) {
                    *a += p as f64 / 10.0;
                }
            }
            acc
        };
        let p1 = proto(1);
        let p8 = proto(8);
        let dist: f64 = p1.iter().zip(&p8).map(|(a, b)| (a - b).abs()).sum();
        assert!(dist > 5000.0, "digit 1 vs 8 prototype distance {dist}");
    }

    #[test]
    fn seg_dist2_basics() {
        // point on segment → 0-ish; point off end → euclidean to endpoint
        assert_eq!(seg_dist2(0, 0, 0, 0, 10 * Q, 0), 0);
        let d = seg_dist2(-Q, 0, 0, 0, 10 * Q, 0);
        assert_eq!(d, Q * Q);
        // perpendicular distance
        let d = seg_dist2(5 * Q, 3 * Q, 0, 0, 10 * Q, 0);
        let err = (d - 9 * Q * Q).abs();
        assert!(err <= 2 * Q * Q / 100 + 1, "err {err}");
    }
}
