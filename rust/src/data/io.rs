//! Dataset binary format (`artifacts/data/*.uds`) — the interchange used
//! to verify that the Rust and Python generators produce identical data,
//! and to let benches reuse datasets exported at artifact-build time.
//!
//! Layout (little-endian): magic `UDS1`, u32 name_len + name bytes,
//! u32 num_features, u32 num_classes, u32 n_train, u32 n_test,
//! f32 train_x, u16 train_y, f32 test_x, u16 test_y, u64 fnv checksum.

use crate::data::Dataset;
use anyhow::{bail, Context, Result};
use std::io::{Read, Write};
use std::path::Path;

const MAGIC: &[u8; 4] = b"UDS1";

pub fn save(ds: &Dataset, path: &Path) -> Result<()> {
    let mut f = std::fs::File::create(path)
        .with_context(|| format!("create {}", path.display()))?;
    let mut w = std::io::BufWriter::new(&mut f);
    w.write_all(MAGIC)?;
    let name = ds.name.as_bytes();
    w.write_all(&(name.len() as u32).to_le_bytes())?;
    w.write_all(name)?;
    for v in [ds.num_features as u32, ds.num_classes as u32, ds.n_train() as u32, ds.n_test() as u32] {
        w.write_all(&v.to_le_bytes())?;
    }
    for x in &ds.train_x {
        w.write_all(&x.to_le_bytes())?;
    }
    for y in &ds.train_y {
        w.write_all(&y.to_le_bytes())?;
    }
    for x in &ds.test_x {
        w.write_all(&x.to_le_bytes())?;
    }
    for y in &ds.test_y {
        w.write_all(&y.to_le_bytes())?;
    }
    w.write_all(&ds.checksum().to_le_bytes())?;
    Ok(())
}

pub fn load(path: &Path) -> Result<Dataset> {
    let mut bytes = Vec::new();
    std::fs::File::open(path)
        .with_context(|| format!("open {}", path.display()))?
        .read_to_end(&mut bytes)?;
    let mut off = 0usize;
    let take = |off: &mut usize, n: usize| -> Result<&[u8]> {
        if *off + n > bytes.len() {
            bail!("truncated dataset file at offset {off}");
        }
        let s = &bytes[*off..*off + n];
        *off += n;
        Ok(s)
    };
    if take(&mut off, 4)? != MAGIC {
        bail!("bad magic in {}", path.display());
    }
    let u32_at = |off: &mut usize| -> Result<u32> {
        Ok(u32::from_le_bytes(take(off, 4)?.try_into().unwrap()))
    };
    let name_len = u32_at(&mut off)? as usize;
    let name = String::from_utf8(take(&mut off, name_len)?.to_vec())?;
    let num_features = u32_at(&mut off)? as usize;
    let num_classes = u32_at(&mut off)? as usize;
    let n_train = u32_at(&mut off)? as usize;
    let n_test = u32_at(&mut off)? as usize;
    let mut train_x = Vec::with_capacity(n_train * num_features);
    for _ in 0..n_train * num_features {
        train_x.push(f32::from_le_bytes(take(&mut off, 4)?.try_into().unwrap()));
    }
    let mut train_y = Vec::with_capacity(n_train);
    for _ in 0..n_train {
        train_y.push(u16::from_le_bytes(take(&mut off, 2)?.try_into().unwrap()));
    }
    let mut test_x = Vec::with_capacity(n_test * num_features);
    for _ in 0..n_test * num_features {
        test_x.push(f32::from_le_bytes(take(&mut off, 4)?.try_into().unwrap()));
    }
    let mut test_y = Vec::with_capacity(n_test);
    for _ in 0..n_test {
        test_y.push(u16::from_le_bytes(take(&mut off, 2)?.try_into().unwrap()));
    }
    let stored_sum = u64::from_le_bytes(take(&mut off, 8)?.try_into().unwrap());
    let ds = Dataset { name, num_features, num_classes, train_x, train_y, test_x, test_y };
    ds.validate().map_err(|e| anyhow::anyhow!(e))?;
    let actual = ds.checksum();
    if actual != stored_sum {
        bail!("checksum mismatch: stored {stored_sum:#x}, computed {actual:#x}");
    }
    Ok(ds)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth_uci::{synth_uci, uci_spec};

    #[test]
    fn roundtrip() {
        let ds = synth_uci(1, uci_spec("iris").unwrap());
        let dir = std::env::temp_dir().join("uleen_test_io");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("iris.uds");
        save(&ds, &p).unwrap();
        let back = load(&p).unwrap();
        assert_eq!(ds.checksum(), back.checksum());
        assert_eq!(ds.name, back.name);
        assert_eq!(ds.num_classes, back.num_classes);
    }

    #[test]
    fn corruption_detected() {
        let ds = synth_uci(2, uci_spec("wine").unwrap());
        let dir = std::env::temp_dir().join("uleen_test_io");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("wine.uds");
        save(&ds, &p).unwrap();
        let mut bytes = std::fs::read(&p).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0xFF;
        std::fs::write(&p, &bytes).unwrap();
        assert!(load(&p).is_err());
    }

    #[test]
    fn truncation_detected() {
        let ds = synth_uci(3, uci_spec("iris").unwrap());
        let dir = std::env::temp_dir().join("uleen_test_io");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("trunc.uds");
        save(&ds, &p).unwrap();
        let bytes = std::fs::read(&p).unwrap();
        std::fs::write(&p, &bytes[..bytes.len() - 16]).unwrap();
        assert!(load(&p).is_err());
    }
}
