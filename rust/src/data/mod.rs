//! Synthetic datasets (substitution for MNIST + the eight UCI datasets —
//! see DESIGN.md §2).
//!
//! All generators are **integer-deterministic and language-portable**: the
//! Python compile path (`python/compile/data.py`) implements the same
//! algorithms over the same PRNG streams, so both halves of the system
//! train and evaluate on bit-identical data. Each sample is generated from
//! its own derived PRNG stream (`Rng::for_item`), making generation order
//! irrelevant and parallelizable.

pub mod dataset;
pub mod io;
pub mod synth_mnist;
pub mod synth_uci;

pub use dataset::Dataset;
pub use synth_mnist::synth_mnist;
pub use synth_uci::{synth_uci, uci_specs, UciSpec};

/// PRNG domain tags (shared with python/compile/data.py).
pub const DOMAIN_MNIST: u64 = 0x4D4E4953; // "MNIS"
pub const DOMAIN_UCI: u64 = 0x55434931; // "UCI1"
