//! Synthetic datasets (substitution for MNIST + the eight UCI datasets —
//! see DESIGN.md §2).
//!
//! All generators are **integer-deterministic and language-portable**: the
//! Python compile path (`python/compile/data.py`) implements the same
//! algorithms over the same PRNG streams, so both halves of the system
//! train and evaluate on bit-identical data. Each sample is generated from
//! its own derived PRNG stream (`Rng::for_item`), making generation order
//! irrelevant and parallelizable.

pub mod dataset;
pub mod io;
pub mod synth_mnist;
pub mod synth_uci;

pub use dataset::Dataset;
pub use synth_mnist::synth_mnist;
pub use synth_uci::{synth_uci, uci_specs, UciSpec};

/// PRNG domain tags (shared with python/compile/data.py).
pub const DOMAIN_MNIST: u64 = 0x4D4E4953; // "MNIS"
pub const DOMAIN_UCI: u64 = 0x55434931; // "UCI1"

/// Materialize a dataset by name (generates on the fly; no files needed).
/// `mnist` / `synth_mnist` takes the two size knobs; UCI names accept an
/// optional `synth_` prefix. The one resolver behind both the `uleen`
/// CLI subcommands and the serve loop — keep name handling here so the
/// two can't drift.
pub fn load_by_name(
    name: &str,
    seed: u64,
    mnist_train: usize,
    mnist_test: usize,
) -> crate::Result<Dataset> {
    if name == "synth_mnist" || name == "mnist" {
        return Ok(synth_mnist(seed, mnist_train, mnist_test));
    }
    let bare = name.strip_prefix("synth_").unwrap_or(name);
    match synth_uci::uci_spec(bare) {
        Some(spec) => Ok(synth_uci(seed, spec)),
        None => anyhow::bail!("unknown dataset '{name}'"),
    }
}

#[cfg(test)]
mod tests {
    #[test]
    fn load_by_name_resolves_aliases_and_rejects_unknown() {
        assert!(super::load_by_name("iris", 1, 10, 5).is_ok());
        assert!(super::load_by_name("synth_iris", 1, 10, 5).is_ok());
        assert_eq!(super::load_by_name("mnist", 1, 8, 4).unwrap().n_test(), 4);
        assert!(super::load_by_name("nope", 1, 10, 5).is_err());
    }
}
