//! SynthUCI — synthetic stand-ins for the eight UCI tabular datasets used
//! in the Bloom WiSARD comparison (paper Table IV).
//!
//! Each spec matches the real dataset's feature count, class count, sample
//! counts and class skew (Shuttle keeps its 80 % "normal"-class imbalance,
//! which drives the paper's saturation finding). Samples are Gaussian
//! class clusters (CLT normals — no transcendentals) with per-dataset
//! separation tuned to land baseline accuracies in the band the real
//! datasets exhibit. Language-portable: same streams in data.py.

use crate::data::{Dataset, DOMAIN_UCI};
use crate::util::rng::Rng;

/// Static description of one synthetic dataset.
#[derive(Clone, Copy, Debug)]
pub struct UciSpec {
    pub name: &'static str,
    pub id: u64,
    pub features: usize,
    pub classes: usize,
    pub n_train: usize,
    pub n_test: usize,
    /// Per-mille probability of class 0 (0 = balanced across all classes).
    pub skew_permille: u64,
    /// Cluster spread / separation knob: larger = harder.
    pub spread: f64,
}

/// The eight UCI datasets of Table IV (MNIST is handled by synth_mnist).
pub fn uci_specs() -> &'static [UciSpec] {
    &[
        UciSpec { name: "ecoli", id: 1, features: 7, classes: 8, n_train: 224, n_test: 112, skew_permille: 420, spread: 0.33 },
        UciSpec { name: "iris", id: 2, features: 4, classes: 3, n_train: 100, n_test: 50, skew_permille: 0, spread: 0.18 },
        UciSpec { name: "letter", id: 3, features: 16, classes: 26, n_train: 13000, n_test: 6500, skew_permille: 0, spread: 0.42 },
        UciSpec { name: "satimage", id: 4, features: 36, classes: 6, n_train: 4435, n_test: 2000, skew_permille: 0, spread: 0.40 },
        UciSpec { name: "shuttle", id: 5, features: 9, classes: 7, n_train: 8000, n_test: 2000, skew_permille: 800, spread: 0.30 },
        UciSpec { name: "vehicle", id: 6, features: 18, classes: 4, n_train: 564, n_test: 282, skew_permille: 0, spread: 0.52 },
        UciSpec { name: "vowel", id: 7, features: 10, classes: 11, n_train: 660, n_test: 330, skew_permille: 0, spread: 0.35 },
        UciSpec { name: "wine", id: 8, features: 13, classes: 3, n_train: 118, n_test: 60, skew_permille: 0, spread: 0.28 },
    ]
}

pub fn uci_spec(name: &str) -> Option<&'static UciSpec> {
    uci_specs().iter().find(|s| s.name == name)
}

/// Class centroids: `classes × features` uniform in [0,1], from the
/// dataset's own stream (index 0 of its domain).
fn centroids(seed: u64, spec: &UciSpec) -> Vec<f64> {
    let mut rng = Rng::for_item(seed, DOMAIN_UCI ^ spec.id, 0);
    (0..spec.classes * spec.features).map(|_| rng.f64()).collect()
}

/// Draw one sample (index ≥ 1; 0 is reserved for the centroid stream).
fn draw_sample(seed: u64, spec: &UciSpec, cents: &[f64], index: u64) -> (Vec<f32>, u16) {
    let mut rng = Rng::for_item(seed, DOMAIN_UCI ^ spec.id, index);
    // Draw counts are unconditional so the vectorised Python generator
    // consumes the stream identically (see python/compile/data.py).
    let class = if spec.skew_permille > 0 {
        let u = rng.below(1000);
        let v = rng.below((spec.classes - 1) as u64) as usize;
        if u < spec.skew_permille {
            0
        } else {
            1 + v
        }
    } else {
        rng.below(spec.classes as u64) as usize
    };
    let mut x = Vec::with_capacity(spec.features);
    for f in 0..spec.features {
        let c = cents[class * spec.features + f];
        let v = c + spec.spread * rng.normal_clt();
        x.push(v as f32);
    }
    (x, class as u16)
}

/// Generate a synthetic UCI-like dataset.
pub fn synth_uci(seed: u64, spec: &UciSpec) -> Dataset {
    let cents = centroids(seed, spec);
    let mut train_x = Vec::with_capacity(spec.n_train * spec.features);
    let mut train_y = Vec::with_capacity(spec.n_train);
    for i in 0..spec.n_train {
        let (x, y) = draw_sample(seed, spec, &cents, 1 + i as u64);
        train_x.extend_from_slice(&x);
        train_y.push(y);
    }
    let mut test_x = Vec::with_capacity(spec.n_test * spec.features);
    let mut test_y = Vec::with_capacity(spec.n_test);
    for i in 0..spec.n_test {
        let (x, y) = draw_sample(seed, spec, &cents, 1 + (spec.n_train + i) as u64);
        test_x.extend_from_slice(&x);
        test_y.push(y);
    }
    Dataset {
        name: format!("synth_{}", spec.name),
        num_features: spec.features,
        num_classes: spec.classes,
        train_x,
        train_y,
        test_x,
        test_y,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn specs_match_paper_shapes() {
        let specs = uci_specs();
        assert_eq!(specs.len(), 8);
        let iris = uci_spec("iris").unwrap();
        assert_eq!((iris.features, iris.classes), (4, 3));
        let letter = uci_spec("letter").unwrap();
        assert_eq!((letter.features, letter.classes), (16, 26));
        let shuttle = uci_spec("shuttle").unwrap();
        assert_eq!(shuttle.skew_permille, 800);
    }

    #[test]
    fn deterministic_generation() {
        let spec = uci_spec("wine").unwrap();
        let a = synth_uci(7, spec);
        let b = synth_uci(7, spec);
        assert_eq!(a.checksum(), b.checksum());
        let c = synth_uci(8, spec);
        assert_ne!(a.checksum(), c.checksum());
    }

    #[test]
    fn shuttle_skew_is_realized() {
        let spec = uci_spec("shuttle").unwrap();
        let d = synth_uci(3, spec);
        let counts = d.train_class_counts();
        let frac0 = counts[0] as f64 / d.n_train() as f64;
        assert!((frac0 - 0.8).abs() < 0.03, "class-0 fraction {frac0}");
        // all classes present
        assert!(counts.iter().all(|&c| c > 0), "{counts:?}");
    }

    #[test]
    fn all_datasets_validate() {
        for spec in uci_specs() {
            // shrink big ones for test speed
            let small = UciSpec {
                n_train: spec.n_train.min(300),
                n_test: spec.n_test.min(100),
                ..*spec
            };
            let d = synth_uci(1, &small);
            d.validate().unwrap();
            assert_eq!(d.num_features, spec.features);
        }
    }

    #[test]
    fn clusters_are_separable_but_noisy() {
        // nearest-centroid classification should beat chance but not be
        // perfect for harder datasets — sanity on spread tuning.
        let spec = uci_spec("vehicle").unwrap();
        let d = synth_uci(5, spec);
        let cents = centroids(5, spec);
        let mut correct = 0;
        for i in 0..d.n_test() {
            let row = d.test_row(i);
            let mut best = (f64::INFINITY, 0usize);
            for c in 0..spec.classes {
                let mut dist = 0f64;
                for f in 0..spec.features {
                    let diff = row[f] as f64 - cents[c * spec.features + f];
                    dist += diff * diff;
                }
                if dist < best.0 {
                    best = (dist, c);
                }
            }
            if best.1 == d.test_y[i] as usize {
                correct += 1;
            }
        }
        let acc = correct as f64 / d.n_test() as f64;
        assert!(acc > 0.5, "nearest-centroid acc {acc}");
        assert!(acc < 0.999, "too easy: {acc}");
    }
}
