//! In-memory classification dataset (row-major f32 features, u16 labels).

/// A train/test split of a classification dataset.
#[derive(Clone, Debug)]
pub struct Dataset {
    pub name: String,
    pub num_features: usize,
    pub num_classes: usize,
    pub train_x: Vec<f32>,
    pub train_y: Vec<u16>,
    pub test_x: Vec<f32>,
    pub test_y: Vec<u16>,
}

impl Dataset {
    pub fn n_train(&self) -> usize {
        self.train_y.len()
    }

    pub fn n_test(&self) -> usize {
        self.test_y.len()
    }

    pub fn train_row(&self, i: usize) -> &[f32] {
        &self.train_x[i * self.num_features..(i + 1) * self.num_features]
    }

    pub fn test_row(&self, i: usize) -> &[f32] {
        &self.test_x[i * self.num_features..(i + 1) * self.num_features]
    }

    /// Sanity checks used by loaders and tests.
    pub fn validate(&self) -> Result<(), String> {
        if self.train_x.len() != self.n_train() * self.num_features {
            return Err("train_x size mismatch".into());
        }
        if self.test_x.len() != self.n_test() * self.num_features {
            return Err("test_x size mismatch".into());
        }
        for &y in self.train_y.iter().chain(self.test_y.iter()) {
            if y as usize >= self.num_classes {
                return Err(format!("label {y} out of range"));
            }
        }
        Ok(())
    }

    /// FNV-1a checksum over the raw bytes — cross-language equality check.
    pub fn checksum(&self) -> u64 {
        let mut h = 0xcbf29ce484222325u64;
        let mut eat = |b: u8| {
            h ^= b as u64;
            h = h.wrapping_mul(0x100000001b3);
        };
        for x in self.train_x.iter().chain(self.test_x.iter()) {
            for b in x.to_le_bytes() {
                eat(b);
            }
        }
        for y in self.train_y.iter().chain(self.test_y.iter()) {
            for b in y.to_le_bytes() {
                eat(b);
            }
        }
        h
    }

    /// Per-class counts over the training labels.
    pub fn train_class_counts(&self) -> Vec<usize> {
        let mut c = vec![0usize; self.num_classes];
        for &y in &self.train_y {
            c[y as usize] += 1;
        }
        c
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> Dataset {
        Dataset {
            name: "t".into(),
            num_features: 2,
            num_classes: 2,
            train_x: vec![0.0, 1.0, 2.0, 3.0],
            train_y: vec![0, 1],
            test_x: vec![4.0, 5.0],
            test_y: vec![1],
        }
    }

    #[test]
    fn validate_ok_and_rows() {
        let d = tiny();
        d.validate().unwrap();
        assert_eq!(d.train_row(1), &[2.0, 3.0]);
        assert_eq!(d.test_row(0), &[4.0, 5.0]);
    }

    #[test]
    fn validate_catches_bad_labels_and_sizes() {
        let mut d = tiny();
        d.train_y[0] = 9;
        assert!(d.validate().is_err());
        let mut d = tiny();
        d.train_x.pop();
        assert!(d.validate().is_err());
    }

    #[test]
    fn checksum_changes_with_content() {
        let a = tiny();
        let mut b = tiny();
        b.test_x[0] = 4.5;
        assert_ne!(a.checksum(), b.checksum());
        assert_eq!(a.checksum(), tiny().checksum());
    }
}
