//! The PJRT-backed engine: compile `artifacts/<model>_b<batch>.hlo.txt`
//! on the CPU PJRT client and execute it for batched inference.

use crate::runtime::InferenceEngine;
use anyhow::{Context, Result};
use std::path::Path;

/// An AOT inference graph loaded through the `xla` crate.
pub struct PjrtEngine {
    exe: xla::PjRtLoadedExecutable,
    /// compiled (fixed) batch size — inputs are padded up to this
    batch: usize,
    num_features: usize,
    num_classes: usize,
    name: String,
}

// The xla crate's client/executable wrap thread-safe C++ objects; the
// crate just doesn't declare it. We only move the engine whole across
// threads (one engine per worker), never share references concurrently.
unsafe impl Send for PjrtEngine {}

impl PjrtEngine {
    /// Load + compile an HLO-text artifact. `num_classes` is probed with a
    /// zero-batch execution so mismatched artifacts fail at load time.
    pub fn load(path: &Path, batch: usize, num_features: usize) -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("create PJRT CPU client")?;
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 path")?,
        )
        .with_context(|| format!("parse HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client.compile(&comp).context("PJRT compile")?;
        let name = path
            .file_stem()
            .and_then(|s| s.to_str())
            .unwrap_or("model")
            .to_string();
        let mut eng = Self { exe, batch, num_features, num_classes: 0, name };
        // probe output shape
        let probe = vec![0f32; batch * num_features];
        let out = eng.run_padded(&probe)?;
        anyhow::ensure!(
            out.len() % batch == 0 && !out.is_empty(),
            "unexpected output length {} for batch {batch}",
            out.len()
        );
        eng.num_classes = out.len() / batch;
        Ok(eng)
    }

    pub fn batch(&self) -> usize {
        self.batch
    }

    /// Execute exactly one compiled batch (input length batch*features).
    fn run_padded(&self, x: &[f32]) -> Result<Vec<f32>> {
        debug_assert_eq!(x.len(), self.batch * self.num_features);
        let lit = xla::Literal::vec1(x)
            .reshape(&[self.batch as i64, self.num_features as i64])
            .context("reshape input literal")?;
        let result = self.exe.execute::<xla::Literal>(&[lit]).context("execute")?;
        let out = result[0][0]
            .to_literal_sync()
            .context("fetch result")?
            .to_tuple1()
            .context("unwrap 1-tuple (lowered with return_tuple=True)")?;
        out.to_vec::<f32>().context("read f32 output")
    }
}

impl InferenceEngine for PjrtEngine {
    fn label(&self) -> String {
        format!("pjrt:{}", self.name)
    }

    fn num_features(&self) -> usize {
        self.num_features
    }

    fn num_classes(&self) -> usize {
        self.num_classes
    }

    fn responses_into(&mut self, x: &[f32], n: usize, out: &mut [f32]) -> Result<()> {
        let f = self.num_features;
        anyhow::ensure!(x.len() == n * f, "bad input length");
        let m = self.num_classes;
        anyhow::ensure!(
            out.len() >= n * m,
            "response plane too short: {} < {}",
            out.len(),
            n * m
        );
        // The XLA round-trip materializes its own buffers regardless, so
        // this engine is write-into-correct but not allocation-free.
        let mut padded = vec![0f32; self.batch * f];
        let mut i = 0;
        while i < n {
            let take = (n - i).min(self.batch);
            padded[..take * f].copy_from_slice(&x[i * f..(i + take) * f]);
            padded[take * f..].fill(0.0);
            let resp = self.run_padded(&padded)?;
            out[i * m..(i + take) * m].copy_from_slice(&resp[..take * m]);
            i += take;
        }
        Ok(())
    }
}
