//! Sharded batch engines — data-parallel fan-out across a **persistent
//! worker pool**.
//!
//! The paper's accelerator hits 14.3M inferences/s by evaluating whole
//! batches in lockstep hardware; the software analogue is one compiled
//! model shared (read-only, behind `Arc`) by N worker threads, each
//! running a kernel over a contiguous slice of the batch's raw float
//! rows. Rows are split round-robin-free — each shard owns one
//! contiguous row range and writes its results straight into the
//! corresponding region of the output buffer, so result stitching is
//! deterministic row-major by construction (no reordering, no locks on
//! the hot path).
//!
//! Two engines share ONE pool implementation (`ShardPool`) and one
//! generalized job type (`Job`: row range over a model, or row range
//! over a router):
//!
//! * [`ShardedEngine`] — row-range-over-one-model: each job runs the
//!   fused encode + bit-sliced batch kernel
//!   ([`FlatModel::responses_batch_fused`]) on its range.
//! * [`ShardedRouterEngine`] — row-range-over-a-router: each job runs
//!   the **batched confidence cascade**
//!   ([`ModelRouter::classify_cascade_batch`]) — or a tier-pinned batch —
//!   on its range, against a per-worker [`ModelRouter`] whose tiers are
//!   all `Arc`-shared [`SharedModel`]s (per-worker state is scratch +
//!   counters only; the tables exist once per tier, not once per
//!   worker). Per-tier counters merge deterministically
//!   ([`RouterStats::merge`]) and stay bit-exact with the sequential
//!   cascade (`prop_sharded_cascade_matches_sequential`).
//!
//! ## Pool lifecycle
//!
//! Threads spawn **once**, in the engine constructor, and live until the
//! engine is dropped — steady state does zero thread spawns per call.
//! Every job writes straight into a disjoint row range of ONE
//! caller-owned output plane (the `_into` write-into contract), workers
//! reuse their own scratch, router jobs run the write-into cascade
//! (`classify_cascade_batch_into`) against grow-only router arenas, and
//! the engines reuse their job buffers — so a warm engine's data plane
//! allocates **nothing** per call; the only remaining heap traffic is
//! the pool's channel nodes, O(shards) amortized and independent of the
//! batch size (witnessed by the counting-allocator tests). Every call
//! hands each participating worker one `Job` over its channel and then
//! blocks on the shared completion channel until all dispatched jobs are
//! acknowledged; workers it didn't use stay parked in `recv`. `Drop`
//! closes the job channels and joins every thread.
//!
//! ## Topology
//!
//! Pool workers are **pinned to distinct cores** at spawn time
//! (`sched_setaffinity`, hand-bound — no libc crate offline — behind
//! `cfg(target_os = "linux")`, a no-op elsewhere): worker `w` goes to
//! core `w % detected_cores`, so each shard's scratch stays core-local
//! instead of migrating with the scheduler. Opt out with the
//! `ULEEN_NO_PIN` env var (set to anything); `workers_pinned()` on both
//! engines witnesses how many workers the kernel actually accepted, and
//! the serve CLI defaults the shard count itself from
//! `std::thread::available_parallelism` (see `util::detected_cores`).
//!
//! ## Failure containment
//!
//! Workers wrap every job in `catch_unwind`: a panicking kernel or tier
//! engine surfaces as an `Err` from the dispatching call — after ALL
//! in-flight jobs are drained — instead of a poisoned pool or a
//! deadlocked `recv`. The pool stays serviceable, so the serving worker
//! above counts the failed micro-batch (`batches_failed`) and keeps
//! going (covered by the fault-injection suite in
//! `integration_coordinator.rs`).

use crate::coordinator::autopilot::MarginKnob;
use crate::coordinator::metrics::ServerMetrics;
use crate::coordinator::router::{ModelRouter, RouterStats};
use crate::encoding::thermometer::ThermometerEncoder;
use crate::model::ensemble::UleenModel;
use crate::model::flat::{FlatBatchScratch, FlatModel};
use crate::runtime::{InferenceEngine, SharedModel, Tier};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;

/// Per-shard reusable state: fused-kernel scratch (including its tile
/// response staging). Owned by its worker thread; shapes follow each job
/// exactly (every buffer is cleared and resized per use), so model swaps
/// are safe.
#[derive(Default)]
struct ShardScratch {
    batch: FlatBatchScratch,
}

/// Row-range-over-one-model: run the fused kernel on `rows` rows and
/// write `rows * m` response floats.
struct ResponsesJob {
    flat: *const FlatModel,
    encoder: *const ThermometerEncoder,
    x: *const f32,
    out: *mut f32,
    rows: usize,
    f: usize,
    m: usize,
}

/// Row-range-over-a-router: run the batched cascade (`tier: None`) or a
/// tier-pinned batch (`tier: Some`) on `rows` rows against THIS worker's
/// router, writing predictions (and, when `scores` is non-null,
/// resolution-tier response rows). Counters accumulate in the router and
/// are merged by the dispatching engine.
struct RouterJob {
    router: *mut ModelRouter,
    x: *const f32,
    preds: *mut usize,
    /// null unless the caller wants the resolution-tier score matrix
    scores: *mut f32,
    rows: usize,
    f: usize,
    m: usize,
    tier: Option<Tier>,
}

/// One unit of work: a contiguous row range of the current batch, either
/// over one flat model or over a per-worker router.
///
/// Raw pointers stand in for borrows because the pool threads outlive any
/// single call. SAFETY contract (upheld by the dispatching engines):
/// `flat`/`encoder` point into `Arc` allocations the engine keeps alive,
/// `router` to the dispatching engine's per-worker router (each worker
/// receives only its own), `x` into the caller's input — which, when a
/// serving `worker_loop` dispatches here, is the slab feature arena's
/// gathered slot run (the dispatcher owns every slot in the batch until
/// after this call returns, so those rows are frozen for the job's
/// lifetime; see `coordinator/batcher.rs`) — and
/// `preds`/`scores`/`out` into the call's output buffers; the dispatching
/// call holds `&mut self` and blocks until every job is acknowledged, so
/// everything outlives the job, nothing mutates the shared inputs
/// meanwhile, and output ranges of concurrent jobs are disjoint by
/// construction.
enum Job {
    Responses(ResponsesJob),
    Router(RouterJob),
}

// SAFETY: see the `Job` contract above — the pointers are only
// dereferenced while the dispatching call keeps their targets alive and
// unaliased (`ModelRouter` itself is `Send`: its engines are
// `Box<dyn InferenceEngine>` and the trait requires `Send`).
unsafe impl Send for Job {}

/// Why a dispatched job did not complete.
enum JobFailure {
    /// the kernel / a tier engine panicked (caught; the worker lives on)
    Panicked,
    /// a tier engine returned an error
    Engine(String),
}

/// Pin the calling thread to one CPU. Linux-only: glibc's
/// `sched_setaffinity` is declared by hand (the offline environment has
/// no `libc` crate; std already links glibc, so the symbol resolves at
/// link time). `pid` 0 = the calling thread; the mask is `cpu_set_t`-
/// sized (1024 bits). Returns whether the kernel accepted the mask —
/// failure (e.g. a cgroup cpuset excluding the target core) is benign:
/// the worker just runs unpinned.
#[cfg(target_os = "linux")]
fn pin_current_thread(cpu: usize) -> bool {
    extern "C" {
        fn sched_setaffinity(pid: i32, cpusetsize: usize, mask: *const u64) -> i32;
    }
    let mut mask = [0u64; 16]; // 1024 CPUs, matching glibc's cpu_set_t
    let cpu = cpu % 1024;
    mask[cpu / 64] |= 1u64 << (cpu % 64);
    // SAFETY: the mask outlives the call and the size matches the buffer.
    unsafe { sched_setaffinity(0, std::mem::size_of_val(&mask), mask.as_ptr()) == 0 }
}

#[cfg(not(target_os = "linux"))]
fn pin_current_thread(_cpu: usize) -> bool {
    false
}

/// The persistent worker pool both sharded engines run on: one job
/// channel per worker, one shared completion channel, threads spawned
/// once and joined on drop. Dispatch is engine-specific (each engine
/// builds its own jobs); the pool owns delivery, failure containment,
/// the ack rendezvous, and worker→core pinning.
struct ShardPool {
    /// job channel per worker, index-aligned with `handles`
    job_txs: Vec<Sender<Job>>,
    handles: Vec<JoinHandle<()>>,
    /// shared completion channel: one outcome per finished job
    done_rx: Receiver<Result<(), JobFailure>>,
    /// total threads ever spawned by this pool (pool-liveness witness)
    spawned: Arc<AtomicUsize>,
    /// workers whose `sched_setaffinity` the kernel accepted (topology
    /// witness: 0 on non-Linux, under `ULEEN_NO_PIN`, or in restrictive
    /// cpusets)
    pinned: Arc<AtomicUsize>,
}

impl ShardPool {
    /// Spawn `shards` worker threads (the caller clamps to ≥ 1), parked
    /// on their job channels until the first dispatch. Each worker pins
    /// itself to core `w % detected_cores` before first recv (skipped
    /// when `ULEEN_NO_PIN` is set), keeping shard scratch core-local.
    fn spawn(shards: usize) -> Self {
        let spawned = Arc::new(AtomicUsize::new(0));
        let pinned = Arc::new(AtomicUsize::new(0));
        let want_pin = std::env::var_os("ULEEN_NO_PIN").is_none();
        let cores = crate::util::detected_cores();
        let (done_tx, done_rx) = channel();
        let mut job_txs = Vec::with_capacity(shards);
        let mut handles = Vec::with_capacity(shards);
        for w in 0..shards {
            let (tx, rx) = channel::<Job>();
            let done = done_tx.clone();
            let spawned = spawned.clone();
            let pinned = pinned.clone();
            let handle = std::thread::Builder::new()
                .name(format!("uleen-shard-{w}"))
                .spawn(move || {
                    spawned.fetch_add(1, Ordering::SeqCst);
                    if want_pin && pin_current_thread(w % cores) {
                        pinned.fetch_add(1, Ordering::SeqCst);
                    }
                    worker_loop(&rx, &done);
                })
                .expect("failed to spawn shard worker");
            job_txs.push(tx);
            handles.push(handle);
        }
        Self { job_txs, handles, done_rx, spawned, pinned }
    }

    fn threads_spawned(&self) -> usize {
        self.spawned.load(Ordering::SeqCst)
    }

    fn workers_pinned(&self) -> usize {
        self.pinned.load(Ordering::SeqCst)
    }

    /// Send job `i` to worker `i`, then block until every job is
    /// acknowledged — this rendezvous is what makes the raw-pointer
    /// handoff sound (and keeps `&mut self` semantics upstream: no two
    /// calls ever interleave on the pool). ALL acks are drained before a
    /// failure surfaces: unwinding with jobs still in flight would free
    /// the output buffers under a worker's pen. `jobs` is drained, not
    /// consumed, so the dispatching engine reuses one job buffer across
    /// calls (part of the steady-state zero-allocation story).
    fn run(&self, jobs: &mut Vec<Job>) -> crate::Result<()> {
        let dispatched = jobs.len();
        debug_assert!(dispatched <= self.job_txs.len());
        for (tx, job) in self.job_txs.iter().zip(jobs.drain(..)) {
            tx.send(job).expect("shard worker exited while engine alive");
        }
        let mut panicked = 0usize;
        let mut engine_err: Option<String> = None;
        for _ in 0..dispatched {
            match self
                .done_rx
                .recv()
                .expect("shard worker exited while engine alive")
            {
                Ok(()) => {}
                Err(JobFailure::Panicked) => panicked += 1,
                Err(JobFailure::Engine(e)) => {
                    if engine_err.is_none() {
                        engine_err = Some(e);
                    }
                }
            }
        }
        if panicked > 0 {
            anyhow::bail!(
                "{panicked} shard worker(s) panicked while evaluating a batch \
                 (pool still serviceable)"
            );
        }
        if let Some(e) = engine_err {
            anyhow::bail!("shard worker engine error: {e}");
        }
        Ok(())
    }
}

impl Drop for ShardPool {
    fn drop(&mut self) {
        // Closing the job channels wakes each worker out of `recv`;
        // joining makes engine drop a clean rendezvous (no detached
        // threads holding dangling pointers).
        self.job_txs.clear();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

fn worker_loop(rx: &Receiver<Job>, done: &Sender<Result<(), JobFailure>>) {
    let mut scratch = ShardScratch::default();
    while let Ok(job) = rx.recv() {
        // Catch panics so a poisoned kernel invariant (or a panicking
        // tier engine) surfaces as a deterministic `Err` in the
        // dispatching call instead of a deadlocked `done_rx.recv()` —
        // and the worker survives to serve the next batch.
        let outcome = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            run_job(job, &mut scratch)
        }))
        .map_err(|_| JobFailure::Panicked)
        .and_then(|r| r.map_err(|e| JobFailure::Engine(e.to_string())));
        if done.send(outcome).is_err() {
            break; // engine gone: exit quietly
        }
    }
}

fn run_job(job: Job, scratch: &mut ShardScratch) -> crate::Result<()> {
    match job {
        Job::Responses(j) => {
            // SAFETY: the `Job` contract (see its doc) — the dispatching
            // call keeps all pointers alive and the out range exclusive
            // until we acknowledge.
            let flat = unsafe { &*j.flat };
            let encoder = unsafe { &*j.encoder };
            let x = unsafe { std::slice::from_raw_parts(j.x, j.rows * j.f) };
            let out = unsafe { std::slice::from_raw_parts_mut(j.out, j.rows * j.m) };
            flat.responses_batch_fused_into(encoder, x, j.rows, &mut scratch.batch, out);
            Ok(())
        }
        Job::Router(j) => {
            // SAFETY: same contract; additionally `router` points at THIS
            // worker's router — the dispatcher never hands one router to
            // two jobs — so the mutable borrow is exclusive. Every branch
            // is a write-into call: the job's output ranges are the final
            // resting place, no staging `Vec`s.
            let router = unsafe { &mut *j.router };
            let x = unsafe { std::slice::from_raw_parts(j.x, j.rows * j.f) };
            let preds_out = unsafe { std::slice::from_raw_parts_mut(j.preds, j.rows) };
            if let Some(tier) = j.tier {
                router.classify_batch_into(x, j.rows, tier, preds_out)?;
            } else if j.scores.is_null() {
                router.classify_cascade_batch_into(x, j.rows, preds_out)?;
            } else {
                let scores_out =
                    unsafe { std::slice::from_raw_parts_mut(j.scores, j.rows * j.m) };
                router.cascade_responses_batch_into(x, j.rows, scores_out, preds_out)?;
            }
            Ok(())
        }
    }
}

/// One [`ModelRouter`] per pool worker over the same `Arc`-shared tiers,
/// every one reading the SAME shared margin knob (one knob, N readers —
/// the autopilot turns one atomic and all workers follow) — the ONE
/// construction loop shared by [`ShardedRouterEngine::from_shared`] and
/// [`ShardedRouterEngine::swap_shared`], so freshly built and swapped-in
/// zoos can never diverge in router initialization.
fn build_routers(tiers: &[SharedModel], margin: &MarginKnob, shards: usize) -> Vec<ModelRouter> {
    (0..shards)
        .map(|_| {
            let mut r = ModelRouter::from_shared(tiers);
            r.share_margin(margin);
            r
        })
        .collect()
}

/// Contiguous row ranges of `per = ceil(n / workers)` rows each (the last
/// may be short): shard `w` owns rows `[w*per, w*per + rows)` and writes
/// straight into its region of the output — deterministic row-major
/// stitching, no post-pass. Shared by both sharded engines so the split
/// (and therefore the counter merge order) is identical everywhere.
fn row_ranges(n: usize, workers: usize) -> impl Iterator<Item = (usize, usize)> {
    let per = n.div_ceil(workers.max(1));
    (0..workers)
        .map(move |w| w * per)
        .take_while(move |&row0| row0 < n)
        .map(move |row0| (row0, per.min(n - row0)))
}

/// An [`InferenceEngine`] that splits every batch across a persistent
/// pool of `shards` worker threads, each running the fused slice kernel
/// on its own contiguous row range of ONE `Arc`-shared model. Results are
/// bit-exact with [`NativeEngine`] and the reference ensemble (asserted
/// by the conformance proptests), and repeated calls reuse the same
/// threads (asserted by `pool_threads_spawn_once_across_calls`).
///
/// [`NativeEngine`]: crate::runtime::NativeEngine
pub struct ShardedEngine {
    shared: SharedModel,
    shards: usize,
    pool: ShardPool,
    /// reusable job buffer (drained by the pool each call)
    job_buf: Vec<Job>,
    /// grow-only response plane backing `classify_into`
    resp_plane: Vec<f32>,
}

impl ShardedEngine {
    /// Compile `model` once and spawn the persistent pool: `shards`
    /// worker threads (clamped to ≥ 1), parked on their job channels
    /// until the first call. A batch of `n` rows dispatches to at most
    /// `min(shards, n)` of them, so tiny batches stay cheap.
    pub fn new(model: UleenModel, shards: usize) -> Self {
        Self::from_shared(SharedModel::compile(model), shards)
    }

    /// [`ShardedEngine::new`] over an already-compiled [`SharedModel`] —
    /// zero model clones; the pool probes the same `Arc`'d tables as
    /// every other holder.
    pub fn from_shared(shared: SharedModel, shards: usize) -> Self {
        let shards = shards.max(1);
        Self {
            shared,
            shards,
            pool: ShardPool::spawn(shards),
            job_buf: Vec::new(),
            resp_plane: Vec::new(),
        }
    }

    /// The served model (read-only; `Arc`-shared).
    pub fn model(&self) -> &UleenModel {
        self.shared.model()
    }

    pub fn shards(&self) -> usize {
        self.shards
    }

    /// How many pool threads this engine has ever spawned. Steady state
    /// this equals [`ShardedEngine::shards`] forever — calls never spawn.
    pub fn threads_spawned(&self) -> usize {
        self.pool.threads_spawned()
    }

    /// Workers the kernel accepted a core-affinity mask for (0 on
    /// non-Linux or under `ULEEN_NO_PIN`).
    pub fn workers_pinned(&self) -> usize {
        self.pool.workers_pinned()
    }

    /// Replace the served model in place (recompiles the flat layout).
    /// The pool is untouched: workers hold no model state — each job
    /// carries its model/encoder pointers, and worker scratch reshapes to
    /// every job exactly — so models of different encoded widths or class
    /// counts can be swapped through one running pool.
    pub fn swap_model(&mut self, model: UleenModel) {
        self.swap_shared(SharedModel::compile(model));
    }

    /// [`ShardedEngine::swap_model`] without recompiling: adopt an
    /// already-shared model (re-shares; the old `Arc`s are released).
    /// The classify plane resets so a wide model's staging doesn't pin
    /// memory under a narrow one (every call rewrites its prefix anyway).
    pub fn swap_shared(&mut self, shared: SharedModel) {
        self.resp_plane = Vec::new();
        self.shared = shared;
    }
}

impl InferenceEngine for ShardedEngine {
    fn label(&self) -> String {
        format!("sharded[{}]:{}", self.shards, self.model().name)
    }

    fn num_features(&self) -> usize {
        self.model().encoder.num_inputs
    }

    fn num_classes(&self) -> usize {
        self.model().num_classes()
    }

    fn kernel_path(&self) -> &'static str {
        self.shared.kernel_path().label()
    }

    fn model_bytes(&self) -> u64 {
        // one Arc-shared compiled model regardless of shard count
        self.shared.model_bytes()
    }

    fn responses_into(&mut self, x: &[f32], n: usize, out: &mut [f32]) -> crate::Result<()> {
        let f = self.num_features();
        anyhow::ensure!(x.len() == n * f, "bad input length");
        let m = self.num_classes();
        // Sizing is validated BEFORE any job exists: a short plane is a
        // clean Err, never a worker writing out of bounds.
        anyhow::ensure!(
            out.len() >= n * m,
            "response plane too short: {} < {}",
            out.len(),
            n * m
        );
        if n == 0 {
            return Ok(());
        }
        let out = &mut out[..n * m];
        // One as_mut_ptr() BEFORE dispatching anything: re-borrowing `out`
        // after a worker has started writing through a previously derived
        // pointer would invalidate that pointer's provenance under the
        // aliasing model (Miri flags it), even though the ranges never
        // overlap.
        let out_ptr = out.as_mut_ptr();
        let flat: *const FlatModel = Arc::as_ptr(self.shared.flat());
        let encoder: *const ThermometerEncoder = &self.shared.model().encoder;
        self.job_buf.clear();
        self.job_buf
            .extend(row_ranges(n, self.shards.min(n)).map(|(row0, rows)| {
                Job::Responses(ResponsesJob {
                    flat,
                    encoder,
                    x: x[row0 * f..].as_ptr(),
                    // SAFETY: in-bounds offset; ranges of distinct jobs
                    // are disjoint ([row0*m, (row0+rows)*m) with strictly
                    // increasing row0).
                    out: unsafe { out_ptr.add(row0 * m) },
                    rows,
                    f,
                    m,
                })
            }));
        self.pool.run(&mut self.job_buf)
    }

    fn classify_into(&mut self, x: &[f32], n: usize, out: &mut [usize]) -> crate::Result<()> {
        let m = self.num_classes();
        let mut plane = std::mem::take(&mut self.resp_plane);
        let res = crate::runtime::classify_via_plane(&mut plane, m, n, out, |p| {
            self.responses_into(x, n, p)
        });
        self.resp_plane = plane;
        res
    }
}

/// Cascade × shard fan-out: the model-zoo confidence cascade
/// ([`ModelRouter::classify_cascade_batch`]) run data-parallel across the
/// persistent shard pool. Big batches split into contiguous row ranges;
/// each range runs the full cascade (or a tier-pinned batch) on a
/// per-worker router whose tiers are all `Arc`-shared [`SharedModel`]s —
/// per-worker state is scratch buffers and counters only, so memory for
/// the tables is ∝ tiers, NOT ∝ workers × tiers (witnessed by
/// `Arc::strong_count` tests). Per-tier counters merge deterministically
/// in worker order via [`RouterStats::merge`]; because the cascade is
/// row-independent, merged counters and predictions are bit-exact with N
/// sequential [`ModelRouter::classify_cascade`] calls
/// (`prop_sharded_cascade_matches_sequential`).
///
/// This engine unifies the two serving axes PRs 1–3 grew in parallel:
/// shard fan-out (one model, many threads) and the tier cascade (many
/// models, one thread) now compose behind one [`InferenceEngine`].
pub struct ShardedRouterEngine {
    /// the zoo, small → large, `Arc`-shared with every per-worker router
    tiers: Vec<SharedModel>,
    /// one router per pool worker; worker `w`'s jobs address `routers[w]`
    routers: Vec<ModelRouter>,
    /// the ONE margin knob every per-worker router reads — survives zoo
    /// swaps, so an autopilot holding a clone keeps steering generation
    /// after generation
    margin: MarginKnob,
    shards: usize,
    pool: ShardPool,
    /// counters of routers retired by [`ShardedRouterEngine::swap_shared`]
    /// — keeps [`ShardedRouterEngine::merged_stats`] monotonic, which the
    /// metrics delta-flush relies on
    retired: RouterStats,
    metrics: Option<Arc<ServerMetrics>>,
    /// reusable job buffer (drained by the pool each call)
    job_buf: Vec<Job>,
    /// grow-only prediction plane backing `responses_into` (whose caller
    /// wants scores, not predictions — the cascade produces both)
    pred_plane: Vec<usize>,
    /// per-router `critical_path_ns` snapshot taken at the top of each
    /// dispatch (reusable; one slot per worker)
    cp_snapshot: Vec<u64>,
    /// Σ over batches of (max over that batch's worker ranges) — the
    /// engine's true critical path. Kept OUTSIDE the per-router stats:
    /// diffing max-of-cumulative worker paths would under-count whenever
    /// the slowest range moves between workers across batches (the
    /// normal case, since escalation-heavy rows move around).
    cp_total: u64,
}

impl ShardedRouterEngine {
    /// Compile each tier once, then build the pool and one router per
    /// worker over the shared tiers.
    pub fn new(models: Vec<UleenModel>, margin_threshold: f32, shards: usize) -> Self {
        let tiers: Vec<SharedModel> = models.into_iter().map(SharedModel::compile).collect();
        Self::from_shared(tiers, margin_threshold, shards)
    }

    /// Build over already-compiled tiers: the pool's routers hold `Arc`
    /// handles into `tiers` — zero model clones per worker (the
    /// `Arc::strong_count` witness tests assert exactly
    /// `2 + shards` handles per tier: caller + engine + one per worker).
    pub fn from_shared(tiers: Vec<SharedModel>, margin_threshold: f32, shards: usize) -> Self {
        assert!(!tiers.is_empty(), "sharded zoo wants at least one tier");
        let shards = shards.max(1);
        let margin = MarginKnob::new(margin_threshold);
        let routers = build_routers(&tiers, &margin, shards);
        Self {
            tiers,
            routers,
            margin,
            shards,
            pool: ShardPool::spawn(shards),
            retired: RouterStats::default(),
            metrics: None,
            job_buf: Vec::new(),
            pred_plane: Vec::new(),
            cp_snapshot: Vec::new(),
            cp_total: 0,
        }
    }

    /// Build from caller-supplied per-worker routers (one per shard, all
    /// agreeing on feature width / class count / tier depth). The
    /// fault-injection suite uses this to put panicking or failing tier
    /// engines on the pool; production paths use
    /// [`ShardedRouterEngine::from_shared`].
    pub fn from_routers(mut routers: Vec<ModelRouter>) -> Self {
        assert!(!routers.is_empty(), "sharded zoo wants at least one worker router");
        let (f, m, t) = (
            routers[0].num_features(),
            routers[0].num_classes(),
            routers[0].num_tiers(),
        );
        for r in &routers[1..] {
            assert_eq!(r.num_features(), f, "worker routers disagree on feature width");
            assert_eq!(r.num_classes(), m, "worker routers disagree on class count");
            assert_eq!(r.num_tiers(), t, "worker routers disagree on tier depth");
        }
        // One knob, N readers — same invariant as from_shared: adopt the
        // first router's knob and point every sibling at it.
        let margin = routers[0].margin_knob();
        for r in &mut routers[1..] {
            r.share_margin(&margin);
        }
        let shards = routers.len();
        Self {
            tiers: Vec::new(),
            routers,
            margin,
            shards,
            pool: ShardPool::spawn(shards),
            retired: RouterStats::default(),
            metrics: None,
            job_buf: Vec::new(),
            pred_plane: Vec::new(),
            cp_snapshot: Vec::new(),
            cp_total: 0,
        }
    }

    /// Flush per-tier counter deltas into `metrics` after every call
    /// (and tell the sink this zoo's depth so reports label exactly the
    /// tiers that exist) — the sharded analogue of
    /// [`RouterEngine::with_metrics`].
    ///
    /// [`RouterEngine::with_metrics`]: crate::coordinator::router::RouterEngine::with_metrics
    pub fn with_metrics(mut self, metrics: Arc<ServerMetrics>) -> Self {
        metrics.set_num_tiers(self.routers[0].num_tiers());
        metrics.set_model_bytes(self.model_bytes(), self.tier_model_bytes());
        self.metrics = Some(metrics);
        self
    }

    pub fn shards(&self) -> usize {
        self.shards
    }

    /// Pool-liveness witness, same contract as
    /// [`ShardedEngine::threads_spawned`].
    pub fn threads_spawned(&self) -> usize {
        self.pool.threads_spawned()
    }

    /// Workers the kernel accepted a core-affinity mask for, same
    /// contract as [`ShardedEngine::workers_pinned`].
    pub fn workers_pinned(&self) -> usize {
        self.pool.workers_pinned()
    }

    /// The `Arc`-shared tiers (empty for
    /// [`ShardedRouterEngine::from_routers`]-built engines).
    pub fn tiers(&self) -> &[SharedModel] {
        &self.tiers
    }

    /// The shared cascade-margin knob every per-worker router reads.
    /// Setting it retunes ALL workers at their next batch; the handle
    /// stays live across [`ShardedRouterEngine::swap_shared`].
    pub fn margin_knob(&self) -> MarginKnob {
        self.margin.clone()
    }

    /// Adopt a caller-owned margin knob (e.g. the serving layer's, so an
    /// autopilot outside the engine steers it): the engine and every
    /// per-worker router drop their own knob for `knob`. The current
    /// threshold becomes whatever `knob` holds.
    pub fn share_margin(&mut self, knob: &MarginKnob) {
        self.margin = knob.clone();
        for r in &mut self.routers {
            r.share_margin(knob);
        }
    }

    /// Per-tier counters merged deterministically across the pool, in
    /// worker order, plus everything accumulated by routers retired via
    /// swap — monotonically non-decreasing across calls, which the
    /// metrics delta-flush relies on. Workers running in parallel fold
    /// with [`RouterStats::merge`] (counts add); retired zoo generations
    /// compose serially with [`RouterStats::chain`]. `critical_path_ns`
    /// is the one field NOT taken from the fold: it reports the
    /// engine-level accumulator (Σ over batches of each batch's
    /// max-over-worker-ranges, maintained by `dispatch`), because the
    /// max of CUMULATIVE worker paths under-counts whenever the slowest
    /// range moves between workers across batches. A batch that FAILED
    /// part-way may still have advanced counters for the rows its
    /// workers finished; the serving layer separately counts the whole
    /// batch in `batches_failed`.
    pub fn merged_stats(&self) -> RouterStats {
        let mut pool = RouterStats::default();
        for r in &self.routers {
            pool.merge(&r.stats);
        }
        let mut total = self.retired.clone();
        total.chain(&pool);
        total.critical_path_ns = self.cp_total;
        total
    }

    /// Replace the whole zoo in place (recompiling each tier once). The
    /// pool is untouched — workers hold no router state between jobs.
    pub fn swap_models(&mut self, models: Vec<UleenModel>) {
        let tiers: Vec<SharedModel> = models.into_iter().map(SharedModel::compile).collect();
        self.swap_shared(tiers);
    }

    /// [`ShardedRouterEngine::swap_models`] without recompiling: re-share
    /// already-compiled tiers across every worker router. Old tiers'
    /// `Arc`s are fully released (witness-tested); retired counters fold
    /// into [`ShardedRouterEngine::merged_stats`] so serving totals never
    /// go backwards.
    pub fn swap_shared(&mut self, tiers: Vec<SharedModel>) {
        assert!(!tiers.is_empty(), "sharded zoo wants at least one tier");
        // parallel fold across the outgoing pool, then chain it onto the
        // retired history (generations are serial — see merged_stats)
        let mut pool = RouterStats::default();
        for r in &self.routers {
            pool.merge(&r.stats);
        }
        // The engine-level `cp_total` accumulator is the ONLY critical-
        // path source this engine reports (merged_stats overrides the
        // fold) — zero the max-of-cumulatives value so `retired` never
        // stores the under-counting number that accumulator exists to
        // avoid.
        pool.critical_path_ns = 0;
        self.retired.chain(&pool);
        // Rebuild over the engine's own knob (NOT a fresh one): a clone
        // held by the autopilot keeps steering the swapped-in generation.
        self.routers = build_routers(&tiers, &self.margin, self.shards);
        self.tiers = tiers;
        if let Some(m) = &self.metrics {
            m.set_num_tiers(self.routers[0].num_tiers());
            m.set_model_bytes(self.model_bytes(), self.tier_model_bytes());
        }
    }

    /// Fan one batch across the pool: contiguous row ranges, one
    /// [`RouterJob`] per participating worker, predictions (and optional
    /// resolution-tier scores) written straight into the caller-owned
    /// planes, per-tier counter deltas flushed to the hooked metrics
    /// sink. Plane sizes are validated BEFORE any job is built (a short
    /// plane is an `Err`, never an out-of-bounds worker write), and only
    /// the `n`-row prefix of each plane is touched. Counters advanced by
    /// finished ranges flush even when a sibling range failed — operators
    /// see the partial work AND the `batches_failed` bump.
    fn dispatch(
        &mut self,
        x: &[f32],
        n: usize,
        tier: Option<Tier>,
        scores: Option<&mut [f32]>,
        preds: &mut [usize],
    ) -> crate::Result<()> {
        let f = self.routers[0].num_features();
        let m = self.routers[0].num_classes();
        anyhow::ensure!(x.len() == n * f, "bad input length");
        anyhow::ensure!(
            preds.len() >= n,
            "prediction plane too short: {} < {n}",
            preds.len()
        );
        if let Some(sc) = &scores {
            anyhow::ensure!(
                sc.len() >= n * m,
                "score plane too short: {} < {}",
                sc.len(),
                n * m
            );
        }
        if n == 0 {
            return Ok(());
        }
        let before = self.metrics.as_ref().map(|_| self.merged_stats());
        // Per-router critical-path snapshot: this batch's path is the MAX
        // over the per-worker deltas (parallel ranges overlap in time),
        // which `merged_stats` then reports summed over batches. One
        // reusable slot per worker — no steady-state allocation.
        self.cp_snapshot.clear();
        self.cp_snapshot
            .extend(self.routers.iter().map(|r| r.stats.critical_path_ns));
        // Pointers derived once, BEFORE any job is dispatched (see the
        // provenance note in `ShardedEngine::responses_into`).
        let preds_ptr = preds[..n].as_mut_ptr();
        let scores_ptr: *mut f32 = match scores {
            Some(sc) => sc[..n * m].as_mut_ptr(),
            None => std::ptr::null_mut(),
        };
        let routers_ptr = self.routers.as_mut_ptr();
        self.job_buf.clear();
        self.job_buf.extend(
            row_ranges(n, self.shards.min(n))
                .enumerate()
                .map(|(w, (row0, rows))| {
                    Job::Router(RouterJob {
                        // SAFETY: w < shards = routers.len(); each worker
                        // gets its own router exactly once per dispatch.
                        router: unsafe { routers_ptr.add(w) },
                        x: x[row0 * f..].as_ptr(),
                        // SAFETY: in-bounds offsets; output ranges of
                        // distinct jobs are disjoint (strictly increasing
                        // row0).
                        preds: unsafe { preds_ptr.add(row0) },
                        scores: if scores_ptr.is_null() {
                            std::ptr::null_mut()
                        } else {
                            unsafe { scores_ptr.add(row0 * m) }
                        },
                        rows,
                        f,
                        m,
                        tier,
                    })
                }),
        );
        let result = self.pool.run(&mut self.job_buf);
        // Fold this batch's critical path BEFORE the metrics flush, so
        // the flushed delta carries it. Computed even when a sibling
        // range failed — finished ranges did real serial work.
        let batch_cp = self
            .routers
            .iter()
            .zip(self.cp_snapshot.iter())
            .map(|(r, &base)| r.stats.critical_path_ns - base)
            .max()
            .unwrap_or(0);
        self.cp_total += batch_cp;
        if let (Some(sink), Some(before)) = (&self.metrics, before) {
            sink.record_tiers(&self.merged_stats().diff(&before));
        }
        result
    }
}

impl InferenceEngine for ShardedRouterEngine {
    fn label(&self) -> String {
        format!(
            "sharded-zoo[{} tiers × {} shards]",
            self.routers[0].num_tiers(),
            self.shards
        )
    }

    fn num_features(&self) -> usize {
        self.routers[0].num_features()
    }

    fn num_classes(&self) -> usize {
        self.routers[0].num_classes()
    }

    fn num_tiers(&self) -> usize {
        self.routers[0].num_tiers()
    }

    fn kernel_path(&self) -> &'static str {
        // every tier compiles under the same dispatch decision, so the
        // first shared tier speaks for the zoo ("n/a" for the
        // from_routers test path, which holds no shared tiers)
        self.tiers
            .first()
            .map(|t| t.kernel_path().label())
            .unwrap_or("n/a")
    }

    fn model_bytes(&self) -> u64 {
        // tiers are Arc-shared across the pool: ONE copy per tier, so
        // the zoo total is a plain sum (0 for the from_routers test
        // path, which holds no shared tiers — "unaccounted")
        self.tiers.iter().map(SharedModel::model_bytes).sum()
    }

    fn tier_model_bytes(&self) -> [u64; 3] {
        let mut per = [0u64; 3];
        for (slot, t) in per.iter_mut().zip(self.tiers.iter()) {
            *slot = t.model_bytes();
        }
        per
    }

    /// Sharded batched-cascade responses: each row carries the scores of
    /// the tier that resolved it (same contract as `RouterEngine`). The
    /// cascade also produces predictions; they land in the engine's
    /// grow-only plane, not a per-call `Vec`.
    fn responses_into(&mut self, x: &[f32], n: usize, out: &mut [f32]) -> crate::Result<()> {
        let mut preds = std::mem::take(&mut self.pred_plane);
        if preds.len() < n {
            preds.resize(n, 0);
        }
        let res = self.dispatch(x, n, None, Some(out), &mut preds);
        self.pred_plane = preds;
        res
    }

    fn classify_into(&mut self, x: &[f32], n: usize, out: &mut [usize]) -> crate::Result<()> {
        self.dispatch(x, n, None, None, out)
    }

    fn classify_routed_into(
        &mut self,
        x: &[f32],
        n: usize,
        tier: Option<Tier>,
        out: &mut [usize],
    ) -> crate::Result<()> {
        self.dispatch(x, n, tier, None, out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth_uci::{synth_uci, uci_spec};
    use crate::runtime::NativeEngine;
    use crate::train::oneshot::{train_oneshot, OneShotConfig};

    fn model() -> UleenModel {
        let ds = synth_uci(5, uci_spec("vowel").unwrap());
        train_oneshot(
            &ds,
            &OneShotConfig { inputs_per_filter: 10, entries_per_filter: 128, therm_bits: 4, ..Default::default() },
        )
        .0
    }

    fn zoo_models() -> Vec<UleenModel> {
        let ds = synth_uci(5, uci_spec("vowel").unwrap());
        [(8usize, 64usize, 2usize), (10, 128, 4), (10, 256, 8)]
            .iter()
            .map(|&(ipf, epf, bits)| {
                train_oneshot(
                    &ds,
                    &OneShotConfig {
                        inputs_per_filter: ipf,
                        entries_per_filter: epf,
                        therm_bits: bits,
                        ..Default::default()
                    },
                )
                .0
            })
            .collect()
    }

    #[test]
    fn sharded_matches_native_for_all_shard_counts() {
        let m = model();
        let ds = synth_uci(5, uci_spec("vowel").unwrap());
        let n = ds.n_test();
        let mut native = NativeEngine::new(m.clone());
        let want_resp = native.responses(&ds.test_x, n).unwrap();
        let want_pred = native.classify(&ds.test_x, n).unwrap();
        for shards in [1usize, 2, 3, 7, 64] {
            let mut sh = ShardedEngine::new(m.clone(), shards);
            assert_eq!(sh.responses(&ds.test_x, n).unwrap(), want_resp, "shards={shards}");
            assert_eq!(sh.classify(&ds.test_x, n).unwrap(), want_pred, "shards={shards}");
        }
    }

    #[test]
    fn sharded_handles_degenerate_batches() {
        let m = model();
        let f = m.encoder.num_inputs;
        let classes = m.num_classes();
        let mut sh = ShardedEngine::new(m, 4);
        // empty batch
        assert!(sh.responses(&[], 0).unwrap().is_empty());
        assert!(sh.classify(&[], 0).unwrap().is_empty());
        // batch smaller than the shard count
        let x = vec![0.5f32; 2 * f];
        assert_eq!(sh.responses(&x, 2).unwrap().len(), 2 * classes);
        // repeated calls reuse scratch without shape confusion
        let x = vec![0.25f32; 9 * f];
        assert_eq!(sh.classify(&x, 9).unwrap().len(), 9);
        // bad input length is rejected
        assert!(sh.responses(&x, 5).is_err());
    }

    #[test]
    fn shard_count_is_clamped_to_at_least_one() {
        let m = model();
        let sh = ShardedEngine::new(m, 0);
        assert_eq!(sh.shards(), 1);
        assert!(sh.threads_spawned() <= 1);
    }

    #[test]
    fn pool_threads_spawn_once_across_calls() {
        let m = model();
        let f = m.encoder.num_inputs;
        let mut sh = ShardedEngine::new(m, 4);
        // wait for all workers to come up (spawn happens in new(), the
        // counter increment races only with this assertion, not with use)
        while sh.threads_spawned() < 4 {
            std::thread::yield_now();
        }
        for n in [1usize, 3, 64, 200, 7, 1, 129] {
            let x = vec![0.5f32; n * f];
            sh.responses(&x, n).unwrap();
            assert_eq!(
                sh.threads_spawned(),
                4,
                "steady state must never spawn: n={n}"
            );
        }
    }

    #[test]
    fn sharded_results_identical_across_repeated_calls_and_shard_counts() {
        let m = model();
        let ds = synth_uci(5, uci_spec("vowel").unwrap());
        let n = ds.n_test();
        let mut first: Option<Vec<f32>> = None;
        for shards in [1usize, 2, 5, 8] {
            let mut sh = ShardedEngine::new(m.clone(), shards);
            for call in 0..3 {
                let got = sh.responses(&ds.test_x, n).unwrap();
                match &first {
                    None => first = Some(got),
                    Some(want) => {
                        assert_eq!(&got, want, "shards={shards} call={call}")
                    }
                }
            }
        }
    }

    #[test]
    fn sharded_router_matches_single_router_cascade_and_pins() {
        let models = zoo_models();
        let ds = synth_uci(5, uci_spec("vowel").unwrap());
        let n = ds.n_test();
        let mut reference = ModelRouter::from_models(&models);
        let want_cascade = reference.classify_cascade_batch(&ds.test_x, n).unwrap();
        let want_fast = reference.classify_batch(&ds.test_x, n, Tier::Fast).unwrap();
        for shards in [1usize, 3, 5] {
            let mut eng = ShardedRouterEngine::new(models.clone(), 0.05, shards);
            assert_eq!(
                eng.classify(&ds.test_x, n).unwrap(),
                want_cascade,
                "cascade, shards={shards}"
            );
            assert_eq!(
                eng.classify_routed(&ds.test_x, n, Some(Tier::Fast)).unwrap(),
                want_fast,
                "pinned fast, shards={shards}"
            );
            assert!(eng.threads_spawned() <= shards, "no extra spawns");
        }
    }

    #[test]
    fn sharded_router_responses_argmax_to_predictions() {
        let models = zoo_models();
        let ds = synth_uci(5, uci_spec("vowel").unwrap());
        let n = 65.min(ds.n_test());
        let x = &ds.test_x[..n * ds.num_features];
        let mut eng = ShardedRouterEngine::new(models, 0.05, 4);
        let m = eng.num_classes();
        let resp = eng.responses(x, n).unwrap();
        let preds = eng.classify(x, n).unwrap();
        assert_eq!(resp.len(), n * m);
        for (i, &p) in preds.iter().enumerate() {
            assert_eq!(
                crate::util::argmax_tie_low(&resp[i * m..(i + 1) * m]),
                p,
                "row {i}: resolution-tier scores must argmax to the prediction"
            );
        }
    }

    #[test]
    fn sharded_router_empty_batch_is_a_no_op() {
        let models = zoo_models();
        let mut eng = ShardedRouterEngine::new(models, 0.05, 3);
        assert!(eng.classify(&[], 0).unwrap().is_empty());
        assert!(eng.responses(&[], 0).unwrap().is_empty());
        assert_eq!(eng.merged_stats(), RouterStats::default());
    }

    #[test]
    fn sharded_into_contract_rejects_short_planes_without_wedging_the_pool() {
        // A too-short output plane must surface as Err BEFORE any job is
        // built — never a panic inside a pool worker — and the same pool
        // must keep serving afterwards. Dirty oversized planes get their
        // prefix fully overwritten and their suffix preserved.
        let m = model();
        let ds = synth_uci(5, uci_spec("vowel").unwrap());
        let n = 20.min(ds.n_test());
        let f = ds.num_features;
        let x = &ds.test_x[..n * f];
        let mut sh = ShardedEngine::new(m, 3);
        let classes = sh.num_classes();
        let want_resp = sh.responses(x, n).unwrap();
        let want_pred = sh.classify(x, n).unwrap();
        let mut short_resp = vec![0f32; n * classes - 1];
        assert!(sh.responses_into(x, n, &mut short_resp).is_err());
        let mut short_pred = vec![0usize; n - 1];
        assert!(sh.classify_into(x, n, &mut short_pred).is_err());
        let mut resp = vec![-7.25f32; n * classes + 9];
        sh.responses_into(x, n, &mut resp).unwrap();
        assert_eq!(&resp[..n * classes], &want_resp[..]);
        assert!(resp[n * classes..].iter().all(|&v| v == -7.25));
        let mut preds = vec![usize::MAX; n + 2];
        sh.classify_into(x, n, &mut preds).unwrap();
        assert_eq!(&preds[..n], &want_pred[..]);
        assert!(preds[n..].iter().all(|&p| p == usize::MAX));

        // same contract on the sharded zoo
        let models = zoo_models();
        let mut eng = ShardedRouterEngine::new(models, 0.05, 3);
        let zm = eng.num_classes();
        let want = eng.classify(x, n).unwrap();
        let stats_before = eng.merged_stats();
        let mut short = vec![0usize; n - 1];
        assert!(eng.classify_into(x, n, &mut short).is_err());
        let mut short_scores = vec![0f32; n * zm - 1];
        assert!(eng.responses_into(x, n, &mut short_scores).is_err());
        assert_eq!(
            eng.merged_stats(),
            stats_before,
            "rejected dispatches must not advance counters"
        );
        let mut zp = vec![usize::MAX; n + 3];
        eng.classify_into(x, n, &mut zp).unwrap();
        assert_eq!(&zp[..n], &want[..]);
        assert!(zp[n..].iter().all(|&p| p == usize::MAX));
        // n = 0 touches nothing on either engine
        sh.responses_into(&[], 0, &mut resp).unwrap();
        eng.classify_into(&[], 0, &mut zp[..0]).unwrap();
    }

    #[test]
    fn sharded_engines_caller_side_allocations_are_batch_independent() {
        // The write-into data plane is allocation-free on the caller
        // thread; the only remaining heap traffic is pool channel nodes
        // — O(shards) per call at worst, amortized across channel
        // blocks, and INDEPENDENT of the batch size. Per-thread counting
        // keeps worker threads and parallel tests out of the measurement.
        use crate::util::alloc_witness::Witness;
        let shards = 4usize;
        let calls = 8u64;
        let ds = synth_uci(5, uci_spec("vowel").unwrap());
        let f = ds.num_features;
        let budget = calls * shards as u64 + 8;

        let mut sh = ShardedEngine::new(model(), shards);
        let classes = sh.num_classes();
        for &n in &[16usize, 200] {
            let x = &ds.test_x[..n.min(ds.n_test()) * f];
            let n = n.min(ds.n_test());
            let mut resp = vec![0f32; n * classes];
            let mut preds = vec![0usize; n];
            for _ in 0..2 {
                sh.responses_into(x, n, &mut resp).unwrap();
                sh.classify_into(x, n, &mut preds).unwrap();
            }
            let w = Witness::begin();
            for _ in 0..calls {
                sh.responses_into(x, n, &mut resp).unwrap();
                sh.classify_into(x, n, &mut preds).unwrap();
            }
            assert!(
                w.allocations() <= 2 * budget,
                "ShardedEngine n={n}: {} caller-side allocations over {calls} \
                 call pairs exceeds the channel-node budget {}",
                w.allocations(),
                2 * budget
            );
        }

        let mut eng = ShardedRouterEngine::new(zoo_models(), 0.05, shards);
        let n = 200.min(ds.n_test());
        let x = &ds.test_x[..n * f];
        let mut preds = vec![0usize; n];
        for _ in 0..3 {
            eng.classify_into(x, n, &mut preds).unwrap();
        }
        let w = Witness::begin();
        for _ in 0..calls {
            eng.classify_into(x, n, &mut preds).unwrap();
        }
        assert!(
            w.allocations() <= budget,
            "ShardedRouterEngine: {} caller-side allocations over {calls} calls \
             exceeds the channel-node budget {budget}",
            w.allocations()
        );
    }

    #[test]
    fn sharded_router_critical_path_is_summed_per_batch_maxes() {
        let models = zoo_models();
        let ds = synth_uci(5, uci_spec("vowel").unwrap());
        let n = ds.n_test();
        for shards in [1usize, 4] {
            let mut eng = ShardedRouterEngine::new(models.clone(), 0.05, shards);
            eng.classify(&ds.test_x, n).unwrap();
            let merged = eng.merged_stats();
            let wall: u64 = merged.tier_ns.iter().sum();
            assert!(merged.critical_path_ns > 0, "shards={shards}: path populated");
            assert!(
                merged.critical_path_ns <= wall,
                "shards={shards}: the critical path can never exceed summed wall time"
            );
            if shards == 1 {
                assert_eq!(
                    merged.critical_path_ns, wall,
                    "one worker serializes everything: path == wall"
                );
            }
            // Each batch contributes its own max-over-ranges: the path
            // must keep growing per batch (NOT a max of cumulative
            // worker paths, which can stall when the slowest range moves
            // between workers), while staying under summed wall time.
            let after_one = merged.critical_path_ns;
            eng.classify(&ds.test_x, n).unwrap();
            eng.classify(&ds.test_x, n).unwrap();
            let merged2 = eng.merged_stats();
            assert!(
                merged2.critical_path_ns > after_one,
                "shards={shards}: every batch must extend the path"
            );
            let wall2: u64 = merged2.tier_ns.iter().sum();
            assert!(merged2.critical_path_ns <= wall2, "shards={shards}");
            if shards == 1 {
                assert_eq!(merged2.critical_path_ns, wall2);
            }
        }
    }

    #[test]
    fn worker_routers_all_read_the_engines_one_margin_knob() {
        let eng = ShardedRouterEngine::new(zoo_models(), 0.05, 4);
        let knob = eng.margin_knob();
        for r in &eng.routers {
            assert!(knob.shares_with(&r.margin_knob()), "one knob, N readers");
        }
        knob.set(0.5);
        for r in &eng.routers {
            assert_eq!(r.margin_threshold(), 0.5, "one turn retunes every worker");
        }
    }

    #[test]
    fn sharded_router_swap_preserves_monotonic_stats_and_margin() {
        let models = zoo_models();
        let ds = synth_uci(5, uci_spec("vowel").unwrap());
        let n = ds.n_test();
        let mut eng = ShardedRouterEngine::new(models[..2].to_vec(), 0.2, 4);
        eng.classify(&ds.test_x, n).unwrap();
        let before = eng.merged_stats();
        assert!(before.served[0] > 0);
        let spawned = eng.threads_spawned();
        let knob = eng.margin_knob();
        assert_eq!(knob.get(), 0.2);
        eng.swap_models(models);
        assert_eq!(eng.num_tiers(), 3, "swap adopts the new zoo depth");
        assert_eq!(eng.threads_spawned(), spawned, "swap must not respawn the pool");
        assert!(
            knob.shares_with(&eng.margin_knob()),
            "a pre-swap knob clone keeps steering the swapped-in zoo"
        );
        knob.set(0.35);
        assert_eq!(
            eng.margin_knob().get(),
            0.35,
            "retune through the old handle reaches every rebuilt worker router"
        );
        let after_swap = eng.merged_stats();
        assert_eq!(after_swap, before, "retired counters survive the swap");
        eng.classify(&ds.test_x, n).unwrap();
        let after = eng.merged_stats();
        assert!(
            after.served[0] >= before.served[0] + n as u64,
            "stats stay monotonic across swaps"
        );
    }
}
