//! Sharded batch engine — data-parallel fan-out of the bit-sliced kernel.
//!
//! The paper's accelerator hits 14.3M inferences/s by evaluating whole
//! batches in lockstep hardware; the software analogue is one flat model
//! shared (read-only) by N worker threads, each running the bit-sliced
//! batch kernel over a contiguous slice of the batch rows. Rows are split
//! round-robin-free — each shard owns one contiguous row range and writes
//! its responses straight into the corresponding region of the output
//! buffer, so result stitching is deterministic row-major by construction
//! (no reordering, no locks on the hot path).
//!
//! Threads come from [`std::thread::scope`]: no pool to manage, and the
//! per-shard scratch ([`ShardScratch`]) lives in the engine so repeated
//! calls allocate nothing after warmup.

use crate::model::ensemble::UleenModel;
use crate::model::flat::{FlatBatchScratch, FlatModel};
use crate::runtime::InferenceEngine;
use crate::util::bitvec::BitVec;

/// Per-shard reusable state: encoded tile + batch-kernel scratch.
#[derive(Default)]
struct ShardScratch {
    enc: Vec<BitVec>,
    batch: FlatBatchScratch,
    resp: Vec<i32>,
}

/// An [`InferenceEngine`] that splits every batch across `shards` worker
/// threads, each running [`FlatModel::responses_batch`] on its own row
/// range. Results are bit-exact with [`NativeEngine`] and the reference
/// ensemble (asserted by the conformance proptests).
///
/// [`NativeEngine`]: crate::runtime::NativeEngine
pub struct ShardedEngine {
    pub model: UleenModel,
    flat: FlatModel,
    shards: usize,
    scratch: Vec<ShardScratch>,
}

impl ShardedEngine {
    /// `shards` worker threads (clamped to ≥ 1). A batch of `n` rows uses
    /// at most `min(shards, n)` threads, so tiny batches stay cheap.
    pub fn new(model: UleenModel, shards: usize) -> Self {
        let shards = shards.max(1);
        let flat = FlatModel::compile(&model);
        let scratch = (0..shards).map(|_| ShardScratch::default()).collect();
        Self { model, flat, shards, scratch }
    }

    pub fn shards(&self) -> usize {
        self.shards
    }
}

impl InferenceEngine for ShardedEngine {
    fn label(&self) -> String {
        format!("sharded[{}]:{}", self.shards, self.model.name)
    }

    fn num_features(&self) -> usize {
        self.model.encoder.num_inputs
    }

    fn num_classes(&self) -> usize {
        self.model.num_classes()
    }

    fn responses(&mut self, x: &[f32], n: usize) -> crate::Result<Vec<f32>> {
        let f = self.num_features();
        anyhow::ensure!(x.len() == n * f, "bad input length");
        let m = self.num_classes();
        let mut out = vec![0f32; n * m];
        if n == 0 {
            return Ok(out);
        }
        let workers = self.shards.min(n);
        // Contiguous row ranges of `per` rows each (the last may be short):
        // shard w owns rows [w*per, w*per+rows) and writes them straight
        // into its chunk of `out` — deterministic row-major stitching.
        let per = n.div_ceil(workers);
        let flat = &self.flat;
        let encoder = &self.model.encoder;
        let bits = self.model.encoder.encoded_bits();
        std::thread::scope(|scope| {
            for ((w, chunk), scratch) in
                out.chunks_mut(per * m).enumerate().zip(self.scratch.iter_mut())
            {
                let rows = chunk.len() / m;
                let row0 = w * per;
                let xs = &x[row0 * f..(row0 + rows) * f];
                scope.spawn(move || {
                    if scratch.enc.len() < rows || scratch.enc[0].len() != bits {
                        scratch.enc = (0..rows).map(|_| BitVec::zeros(bits)).collect();
                    }
                    for i in 0..rows {
                        encoder.encode_into(&xs[i * f..(i + 1) * f], &mut scratch.enc[i]);
                    }
                    scratch.resp.clear();
                    scratch.resp.resize(rows * m, 0);
                    flat.responses_batch(&scratch.enc[..rows], &mut scratch.batch, &mut scratch.resp);
                    for (o, &v) in chunk.iter_mut().zip(scratch.resp.iter()) {
                        *o = v as f32;
                    }
                });
            }
        });
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth_uci::{synth_uci, uci_spec};
    use crate::runtime::NativeEngine;
    use crate::train::oneshot::{train_oneshot, OneShotConfig};

    fn model() -> UleenModel {
        let ds = synth_uci(5, uci_spec("vowel").unwrap());
        train_oneshot(
            &ds,
            &OneShotConfig { inputs_per_filter: 10, entries_per_filter: 128, therm_bits: 4, ..Default::default() },
        )
        .0
    }

    #[test]
    fn sharded_matches_native_for_all_shard_counts() {
        let m = model();
        let ds = synth_uci(5, uci_spec("vowel").unwrap());
        let n = ds.n_test();
        let mut native = NativeEngine::new(m.clone());
        let want_resp = native.responses(&ds.test_x, n).unwrap();
        let want_pred = native.classify(&ds.test_x, n).unwrap();
        for shards in [1usize, 2, 3, 7, 64] {
            let mut sh = ShardedEngine::new(m.clone(), shards);
            assert_eq!(sh.responses(&ds.test_x, n).unwrap(), want_resp, "shards={shards}");
            assert_eq!(sh.classify(&ds.test_x, n).unwrap(), want_pred, "shards={shards}");
        }
    }

    #[test]
    fn sharded_handles_degenerate_batches() {
        let m = model();
        let f = m.encoder.num_inputs;
        let classes = m.num_classes();
        let mut sh = ShardedEngine::new(m, 4);
        // empty batch
        assert!(sh.responses(&[], 0).unwrap().is_empty());
        assert!(sh.classify(&[], 0).unwrap().is_empty());
        // batch smaller than the shard count
        let x = vec![0.5f32; 2 * f];
        assert_eq!(sh.responses(&x, 2).unwrap().len(), 2 * classes);
        // repeated calls reuse scratch without shape confusion
        let x = vec![0.25f32; 9 * f];
        assert_eq!(sh.classify(&x, 9).unwrap().len(), 9);
        // bad input length is rejected
        assert!(sh.responses(&x, 5).is_err());
    }

    #[test]
    fn shard_count_is_clamped_to_at_least_one() {
        let m = model();
        let sh = ShardedEngine::new(m, 0);
        assert_eq!(sh.shards(), 1);
    }
}
