//! Sharded batch engine — data-parallel fan-out of the fused slice
//! kernel across a **persistent worker pool**.
//!
//! The paper's accelerator hits 14.3M inferences/s by evaluating whole
//! batches in lockstep hardware; the software analogue is one flat model
//! shared (read-only) by N worker threads, each running the fused
//! encode + bit-sliced batch kernel
//! ([`FlatModel::responses_batch_fused`]) over a contiguous slice of the
//! batch's raw float rows. Rows are split round-robin-free — each shard
//! owns one contiguous row range and writes its responses straight into
//! the corresponding region of the output buffer, so result stitching is
//! deterministic row-major by construction (no reordering, no locks on
//! the hot path).
//!
//! ## Pool lifecycle
//!
//! Threads spawn **once**, in [`ShardedEngine::new`], and live until the
//! engine is dropped — steady state does zero thread spawns and no
//! scratch allocations per call (each worker keeps its own
//! [`ShardScratch`]; the returned output `Vec` is the one per-call
//! allocation).
//! Every call to [`InferenceEngine::responses`] hands each participating
//! worker one [`Job`] over its channel and then blocks on the shared
//! completion channel until all dispatched jobs are acknowledged; workers
//! it didn't use stay parked in `recv`. `Drop` closes the job channels
//! and joins every thread. This replaces PR 1's per-call
//! [`std::thread::scope`], whose spawn/join pair dominated small-batch
//! latency (ROADMAP follow-up (c)) — `Server::start_sharded` now reuses
//! one pool across every micro-batch.

use crate::encoding::thermometer::ThermometerEncoder;
use crate::model::ensemble::UleenModel;
use crate::model::flat::{FlatBatchScratch, FlatModel};
use crate::runtime::InferenceEngine;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;

/// Per-shard reusable state: fused-kernel scratch + response staging.
/// Owned by its worker thread; shapes follow each job exactly (every
/// buffer is cleared and resized per use), so model swaps are safe.
#[derive(Default)]
struct ShardScratch {
    batch: FlatBatchScratch,
    resp: Vec<i32>,
}

/// One unit of work: a contiguous row range of the current batch.
///
/// Raw pointers stand in for borrows because the pool threads outlive any
/// single call. SAFETY contract (upheld by [`ShardedEngine::responses`]):
/// `flat`/`encoder` point into the engine, `x` into the caller's input
/// and `out` into the call's output buffer; the dispatching call holds
/// `&mut self` and blocks until every job is acknowledged, so all four
/// outlive the job, nothing mutates the shared inputs meanwhile, and
/// `out` ranges of concurrent jobs are disjoint by construction.
struct Job {
    flat: *const FlatModel,
    encoder: *const ThermometerEncoder,
    x: *const f32,
    out: *mut f32,
    rows: usize,
    f: usize,
    m: usize,
}

// SAFETY: see the `Job` contract above — the pointers are only
// dereferenced while the dispatching `responses` call keeps their
// targets alive and unaliased.
unsafe impl Send for Job {}

/// An [`InferenceEngine`] that splits every batch across a persistent
/// pool of `shards` worker threads, each running the fused slice kernel
/// on its own contiguous row range. Results are bit-exact with
/// [`NativeEngine`] and the reference ensemble (asserted by the
/// conformance proptests), and repeated calls reuse the same threads
/// (asserted by `pool_threads_spawn_once_across_calls`).
///
/// [`NativeEngine`]: crate::runtime::NativeEngine
pub struct ShardedEngine {
    pub model: UleenModel,
    flat: FlatModel,
    shards: usize,
    /// job channel per worker, index-aligned with `handles`
    job_txs: Vec<Sender<Job>>,
    handles: Vec<JoinHandle<()>>,
    /// shared completion channel: one `true` per finished job
    done_rx: Receiver<bool>,
    /// total threads ever spawned by this engine (pool-liveness witness)
    spawned: Arc<AtomicUsize>,
}

impl ShardedEngine {
    /// Spawn the persistent pool: `shards` worker threads (clamped to
    /// ≥ 1), parked on their job channels until the first call. A batch
    /// of `n` rows dispatches to at most `min(shards, n)` of them, so
    /// tiny batches stay cheap.
    pub fn new(model: UleenModel, shards: usize) -> Self {
        let shards = shards.max(1);
        let flat = FlatModel::compile(&model);
        let spawned = Arc::new(AtomicUsize::new(0));
        let (done_tx, done_rx) = channel();
        let mut job_txs = Vec::with_capacity(shards);
        let mut handles = Vec::with_capacity(shards);
        for w in 0..shards {
            let (tx, rx) = channel::<Job>();
            let done = done_tx.clone();
            let spawned = spawned.clone();
            let handle = std::thread::Builder::new()
                .name(format!("uleen-shard-{w}"))
                .spawn(move || {
                    spawned.fetch_add(1, Ordering::SeqCst);
                    worker_loop(&rx, &done);
                })
                .expect("failed to spawn shard worker");
            job_txs.push(tx);
            handles.push(handle);
        }
        Self { model, flat, shards, job_txs, handles, done_rx, spawned }
    }

    pub fn shards(&self) -> usize {
        self.shards
    }

    /// How many pool threads this engine has ever spawned. Steady state
    /// this equals [`ShardedEngine::shards`] forever — calls never spawn.
    pub fn threads_spawned(&self) -> usize {
        self.spawned.load(Ordering::SeqCst)
    }

    /// Replace the served model in place (recompiles the flat layout).
    /// The pool is untouched: workers hold no model state — each job
    /// carries its model/encoder pointers, and worker scratch reshapes to
    /// every job exactly — so models of different encoded widths or class
    /// counts can be swapped through one running pool.
    pub fn swap_model(&mut self, model: UleenModel) {
        self.flat = FlatModel::compile(&model);
        self.model = model;
    }
}

impl Drop for ShardedEngine {
    fn drop(&mut self) {
        // Closing the job channels wakes each worker out of `recv`;
        // joining makes engine drop a clean rendezvous (no detached
        // threads holding dangling model pointers).
        self.job_txs.clear();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

fn worker_loop(rx: &Receiver<Job>, done: &Sender<bool>) {
    let mut scratch = ShardScratch::default();
    while let Ok(job) = rx.recv() {
        // Catch panics so a poisoned kernel invariant surfaces as a
        // deterministic panic in the dispatching call instead of a
        // deadlocked `done_rx.recv()`.
        let ok = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            // SAFETY: the `Job` contract (see its doc) — the dispatching
            // `responses` call keeps all four pointers alive and the out
            // range exclusive until we acknowledge below.
            let flat = unsafe { &*job.flat };
            let encoder = unsafe { &*job.encoder };
            let x = unsafe { std::slice::from_raw_parts(job.x, job.rows * job.f) };
            let out =
                unsafe { std::slice::from_raw_parts_mut(job.out, job.rows * job.m) };
            scratch.resp.clear();
            scratch.resp.resize(job.rows * job.m, 0);
            flat.responses_batch_fused(
                encoder,
                x,
                job.rows,
                &mut scratch.batch,
                &mut scratch.resp,
            );
            for (o, &v) in out.iter_mut().zip(scratch.resp.iter()) {
                *o = v as f32;
            }
        }))
        .is_ok();
        if done.send(ok).is_err() {
            break; // engine gone: exit quietly
        }
    }
}

impl InferenceEngine for ShardedEngine {
    fn label(&self) -> String {
        format!("sharded[{}]:{}", self.shards, self.model.name)
    }

    fn num_features(&self) -> usize {
        self.model.encoder.num_inputs
    }

    fn num_classes(&self) -> usize {
        self.model.num_classes()
    }

    fn responses(&mut self, x: &[f32], n: usize) -> crate::Result<Vec<f32>> {
        let f = self.num_features();
        anyhow::ensure!(x.len() == n * f, "bad input length");
        let m = self.num_classes();
        let mut out = vec![0f32; n * m];
        if n == 0 {
            return Ok(out);
        }
        // Contiguous row ranges of `per` rows each (the last may be
        // short): shard w owns rows [w*per, w*per+rows) and writes them
        // straight into its region of `out` — deterministic row-major
        // stitching, no post-pass.
        let workers = self.shards.min(n);
        let per = n.div_ceil(workers);
        // One as_mut_ptr() BEFORE dispatching anything: re-borrowing `out`
        // after a worker has started writing through a previously derived
        // pointer would invalidate that pointer's provenance under the
        // aliasing model (Miri flags it), even though the ranges never
        // overlap.
        let out_ptr = out.as_mut_ptr();
        let mut dispatched = 0usize;
        let mut row0 = 0usize;
        for tx in &self.job_txs {
            if row0 >= n {
                break;
            }
            let rows = per.min(n - row0);
            let job = Job {
                flat: &self.flat,
                encoder: &self.model.encoder,
                x: x[row0 * f..].as_ptr(),
                // SAFETY: in-bounds offset; ranges of distinct jobs are
                // disjoint ([row0*m, (row0+rows)*m) with strictly
                // increasing row0).
                out: unsafe { out_ptr.add(row0 * m) },
                rows,
                f,
                m,
            };
            tx.send(job).expect("shard worker exited while engine alive");
            dispatched += 1;
            row0 += rows;
        }
        // Block until every dispatched job is acknowledged — this is what
        // makes the raw-pointer handoff sound (and keeps `&mut self`
        // semantics: no two calls ever interleave on the pool). Drain ALL
        // acks before surfacing a failure: unwinding with jobs still in
        // flight would free `out` under a worker's pen.
        let mut all_ok = true;
        for _ in 0..dispatched {
            all_ok &= self
                .done_rx
                .recv()
                .expect("shard worker exited while engine alive");
        }
        if !all_ok {
            panic!("shard worker panicked while evaluating a batch");
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth_uci::{synth_uci, uci_spec};
    use crate::runtime::NativeEngine;
    use crate::train::oneshot::{train_oneshot, OneShotConfig};

    fn model() -> UleenModel {
        let ds = synth_uci(5, uci_spec("vowel").unwrap());
        train_oneshot(
            &ds,
            &OneShotConfig { inputs_per_filter: 10, entries_per_filter: 128, therm_bits: 4, ..Default::default() },
        )
        .0
    }

    #[test]
    fn sharded_matches_native_for_all_shard_counts() {
        let m = model();
        let ds = synth_uci(5, uci_spec("vowel").unwrap());
        let n = ds.n_test();
        let mut native = NativeEngine::new(m.clone());
        let want_resp = native.responses(&ds.test_x, n).unwrap();
        let want_pred = native.classify(&ds.test_x, n).unwrap();
        for shards in [1usize, 2, 3, 7, 64] {
            let mut sh = ShardedEngine::new(m.clone(), shards);
            assert_eq!(sh.responses(&ds.test_x, n).unwrap(), want_resp, "shards={shards}");
            assert_eq!(sh.classify(&ds.test_x, n).unwrap(), want_pred, "shards={shards}");
        }
    }

    #[test]
    fn sharded_handles_degenerate_batches() {
        let m = model();
        let f = m.encoder.num_inputs;
        let classes = m.num_classes();
        let mut sh = ShardedEngine::new(m, 4);
        // empty batch
        assert!(sh.responses(&[], 0).unwrap().is_empty());
        assert!(sh.classify(&[], 0).unwrap().is_empty());
        // batch smaller than the shard count
        let x = vec![0.5f32; 2 * f];
        assert_eq!(sh.responses(&x, 2).unwrap().len(), 2 * classes);
        // repeated calls reuse scratch without shape confusion
        let x = vec![0.25f32; 9 * f];
        assert_eq!(sh.classify(&x, 9).unwrap().len(), 9);
        // bad input length is rejected
        assert!(sh.responses(&x, 5).is_err());
    }

    #[test]
    fn shard_count_is_clamped_to_at_least_one() {
        let m = model();
        let sh = ShardedEngine::new(m, 0);
        assert_eq!(sh.shards(), 1);
        assert!(sh.threads_spawned() <= 1);
    }

    #[test]
    fn pool_threads_spawn_once_across_calls() {
        let m = model();
        let f = m.encoder.num_inputs;
        let mut sh = ShardedEngine::new(m, 4);
        // wait for all workers to come up (spawn happens in new(), the
        // counter increment races only with this assertion, not with use)
        while sh.threads_spawned() < 4 {
            std::thread::yield_now();
        }
        for n in [1usize, 3, 64, 200, 7, 1, 129] {
            let x = vec![0.5f32; n * f];
            sh.responses(&x, n).unwrap();
            assert_eq!(
                sh.threads_spawned(),
                4,
                "steady state must never spawn: n={n}"
            );
        }
    }

    #[test]
    fn sharded_results_identical_across_repeated_calls_and_shard_counts() {
        let m = model();
        let ds = synth_uci(5, uci_spec("vowel").unwrap());
        let n = ds.n_test();
        let mut first: Option<Vec<f32>> = None;
        for shards in [1usize, 2, 5, 8] {
            let mut sh = ShardedEngine::new(m.clone(), shards);
            for call in 0..3 {
                let got = sh.responses(&ds.test_x, n).unwrap();
                match &first {
                    None => first = Some(got),
                    Some(want) => {
                        assert_eq!(&got, want, "shards={shards} call={call}")
                    }
                }
            }
        }
    }
}
