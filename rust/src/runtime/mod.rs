//! PJRT runtime — loads the AOT artifacts (`artifacts/*.hlo.txt`, lowered
//! once by `python/compile/aot.py`) and executes them on the hot path.
//!
//! Interchange is HLO **text**: jax ≥ 0.5 serializes HloModuleProto with
//! 64-bit instruction ids that xla_extension 0.5.1 rejects; the text parser
//! reassigns ids (see /opt/xla-example/README.md). One compiled executable
//! per (model, batch-size) pair; Python never runs at serving time.

pub mod pjrt;

pub use pjrt::PjrtEngine;

use crate::model::ensemble::{EnsembleScratch, UleenModel};

/// A batch classifier — implemented by both the native bit-packed engine
/// and the PJRT-loaded AOT graph, so the coordinator and the benches can
/// swap them freely (and cross-check one against the other).
pub trait InferenceEngine: Send {
    /// Human-readable engine label for logs/benches.
    fn label(&self) -> String;
    fn num_features(&self) -> usize;
    fn num_classes(&self) -> usize;
    /// Per-class responses for `n` samples (row-major `x`, length
    /// `n * num_features`). Returns row-major `n * num_classes` scores.
    fn responses(&mut self, x: &[f32], n: usize) -> crate::Result<Vec<f32>>;

    /// Argmax classification built on `responses` (ties break low, like
    /// the hardware comparator).
    fn classify(&mut self, x: &[f32], n: usize) -> crate::Result<Vec<usize>> {
        let m = self.num_classes();
        let resp = self.responses(x, n)?;
        Ok((0..n)
            .map(|i| {
                let row = &resp[i * m..(i + 1) * m];
                let mut best = 0usize;
                for (c, &v) in row.iter().enumerate() {
                    if v > row[best] {
                        best = c;
                    }
                }
                best
            })
            .collect())
    }
}

/// The native Rust engine: bit-packed tables, shared H3 hash block,
/// flat-compiled for the hot path (see `model::flat` — §Perf).
pub struct NativeEngine {
    pub model: UleenModel,
    flat: crate::model::flat::FlatModel,
    resp_scratch: Vec<i32>,
    flat_scratch: crate::model::flat::FlatScratch,
    encoded_buf: crate::util::bitvec::BitVec,
    #[allow(dead_code)]
    scratch: EnsembleScratch,
}

impl NativeEngine {
    pub fn new(model: UleenModel) -> Self {
        let flat = crate::model::flat::FlatModel::compile(&model);
        let encoded_buf = crate::util::bitvec::BitVec::zeros(model.encoded_bits());
        Self {
            model,
            flat,
            resp_scratch: Vec::new(),
            flat_scratch: crate::model::flat::FlatScratch::default(),
            encoded_buf,
            scratch: EnsembleScratch::default(),
        }
    }
}

impl InferenceEngine for NativeEngine {
    fn label(&self) -> String {
        format!("native:{}", self.model.name)
    }

    fn num_features(&self) -> usize {
        self.model.encoder.num_inputs
    }

    fn num_classes(&self) -> usize {
        self.model.num_classes()
    }

    fn responses(&mut self, x: &[f32], n: usize) -> crate::Result<Vec<f32>> {
        let f = self.num_features();
        anyhow::ensure!(x.len() == n * f, "bad input length");
        let m = self.num_classes();
        let mut out = Vec::with_capacity(n * m);
        if self.encoded_buf.len() != self.model.encoded_bits() {
            self.encoded_buf = crate::util::bitvec::BitVec::zeros(self.model.encoded_bits());
        }
        for i in 0..n {
            self.model
                .encoder
                .encode_into(&x[i * f..(i + 1) * f], &mut self.encoded_buf);
            self.resp_scratch.clear();
            self.resp_scratch.resize(m, 0);
            self.flat.responses_encoded(
                &self.encoded_buf,
                &mut self.flat_scratch,
                &mut self.resp_scratch,
            );
            out.extend(self.resp_scratch.iter().map(|&r| r as f32));
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth_uci::{synth_uci, uci_spec};
    use crate::train::oneshot::{train_oneshot, OneShotConfig};

    #[test]
    fn native_engine_matches_model_evaluate() {
        let ds = synth_uci(5, uci_spec("iris").unwrap());
        let (model, _) = train_oneshot(&ds, &OneShotConfig::default());
        let conf = model.evaluate(&ds.test_x, &ds.test_y, ds.num_features);
        let mut eng = NativeEngine::new(model);
        let preds = eng.classify(&ds.test_x, ds.n_test()).unwrap();
        let correct = preds
            .iter()
            .zip(ds.test_y.iter())
            .filter(|(p, y)| **p == **y as usize)
            .count();
        assert_eq!(correct as f64 / ds.n_test() as f64, conf.accuracy());
    }

    #[test]
    fn classify_tie_breaks_low() {
        struct Fake;
        impl InferenceEngine for Fake {
            fn label(&self) -> String { "fake".into() }
            fn num_features(&self) -> usize { 1 }
            fn num_classes(&self) -> usize { 3 }
            fn responses(&mut self, _x: &[f32], n: usize) -> crate::Result<Vec<f32>> {
                Ok(vec![2.0, 2.0, 1.0].repeat(n))
            }
        }
        let mut f = Fake;
        assert_eq!(f.classify(&[0.0], 1).unwrap(), vec![0]);
    }
}
