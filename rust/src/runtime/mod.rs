//! Inference engines behind one batch-classifier trait.
//!
//! * [`NativeEngine`] — the bit-packed native path: scalar scatter-hash
//!   for single samples, the bit-sliced 64-sample-tile kernel for batches.
//! * [`ShardedEngine`] — the batch kernel fanned across worker threads
//!   with deterministic row-major stitching.
//! * `PjrtEngine` (feature `pjrt`) — loads the AOT artifacts
//!   (`artifacts/*.hlo.txt`, lowered once by `python/compile/aot.py`) and
//!   executes them through XLA. Interchange is HLO **text**: jax ≥ 0.5
//!   serializes HloModuleProto with 64-bit instruction ids that
//!   xla_extension 0.5.1 rejects; the text parser reassigns ids (see
//!   /opt/xla-example/README.md). One compiled executable per
//!   (model, batch-size) pair; Python never runs at serving time. Gated
//!   because the `xla` crate is unavailable offline.

#[cfg(feature = "pjrt")]
pub mod pjrt;
pub mod sharded;

#[cfg(feature = "pjrt")]
pub use pjrt::PjrtEngine;
pub use sharded::ShardedEngine;

use crate::model::ensemble::{EnsembleScratch, UleenModel};

/// A batch classifier — implemented by both the native bit-packed engine
/// and the PJRT-loaded AOT graph, so the coordinator and the benches can
/// swap them freely (and cross-check one against the other).
pub trait InferenceEngine: Send {
    /// Human-readable engine label for logs/benches.
    fn label(&self) -> String;
    fn num_features(&self) -> usize;
    fn num_classes(&self) -> usize;
    /// Per-class responses for `n` samples (row-major `x`, length
    /// `n * num_features`). Returns row-major `n * num_classes` scores.
    fn responses(&mut self, x: &[f32], n: usize) -> crate::Result<Vec<f32>>;

    /// Argmax classification built on `responses` (ties break low, like
    /// the hardware comparator).
    fn classify(&mut self, x: &[f32], n: usize) -> crate::Result<Vec<usize>> {
        let m = self.num_classes();
        let resp = self.responses(x, n)?;
        Ok((0..n)
            .map(|i| crate::util::argmax_tie_low(&resp[i * m..(i + 1) * m]))
            .collect())
    }
}

/// The native Rust engine: bit-packed tables, shared H3 hash block,
/// flat-compiled for the hot path (see `model::flat` — §Perf). Single
/// samples take the scalar scatter-hash path; batches (`n > 1`) take the
/// bit-sliced 64-sample-tile kernel ([`responses_batch`]) — both are
/// bit-exact with the reference ensemble.
///
/// [`responses_batch`]: crate::model::flat::FlatModel::responses_batch
pub struct NativeEngine {
    pub model: UleenModel,
    flat: crate::model::flat::FlatModel,
    resp_scratch: Vec<i32>,
    flat_scratch: crate::model::flat::FlatScratch,
    batch_scratch: crate::model::flat::FlatBatchScratch,
    encoded_buf: crate::util::bitvec::BitVec,
    /// reusable encoded tile for the batch kernel
    encoded_batch: Vec<crate::util::bitvec::BitVec>,
    #[allow(dead_code)]
    scratch: EnsembleScratch,
}

impl NativeEngine {
    pub fn new(model: UleenModel) -> Self {
        let flat = crate::model::flat::FlatModel::compile(&model);
        let encoded_buf = crate::util::bitvec::BitVec::zeros(model.encoded_bits());
        Self {
            model,
            flat,
            resp_scratch: Vec::new(),
            flat_scratch: crate::model::flat::FlatScratch::default(),
            batch_scratch: crate::model::flat::FlatBatchScratch::default(),
            encoded_buf,
            encoded_batch: Vec::new(),
            scratch: EnsembleScratch::default(),
        }
    }
}

impl InferenceEngine for NativeEngine {
    fn label(&self) -> String {
        format!("native:{}", self.model.name)
    }

    fn num_features(&self) -> usize {
        self.model.encoder.num_inputs
    }

    fn num_classes(&self) -> usize {
        self.model.num_classes()
    }

    fn responses(&mut self, x: &[f32], n: usize) -> crate::Result<Vec<f32>> {
        let f = self.num_features();
        anyhow::ensure!(x.len() == n * f, "bad input length");
        let m = self.num_classes();
        let bits = self.model.encoded_bits();
        if n > 1 {
            // Bit-sliced batch kernel: one CSR traversal per 64 samples.
            if self.encoded_batch.len() < n
                || self.encoded_batch[0].len() != bits
            {
                self.encoded_batch =
                    (0..n).map(|_| crate::util::bitvec::BitVec::zeros(bits)).collect();
            }
            for i in 0..n {
                self.model
                    .encoder
                    .encode_into(&x[i * f..(i + 1) * f], &mut self.encoded_batch[i]);
            }
            self.resp_scratch.clear();
            self.resp_scratch.resize(n * m, 0);
            self.flat.responses_batch(
                &self.encoded_batch[..n],
                &mut self.batch_scratch,
                &mut self.resp_scratch,
            );
            return Ok(self.resp_scratch.iter().map(|&r| r as f32).collect());
        }
        let mut out = Vec::with_capacity(n * m);
        if self.encoded_buf.len() != bits {
            self.encoded_buf = crate::util::bitvec::BitVec::zeros(bits);
        }
        for i in 0..n {
            self.model
                .encoder
                .encode_into(&x[i * f..(i + 1) * f], &mut self.encoded_buf);
            self.resp_scratch.clear();
            self.resp_scratch.resize(m, 0);
            self.flat.responses_encoded(
                &self.encoded_buf,
                &mut self.flat_scratch,
                &mut self.resp_scratch,
            );
            out.extend(self.resp_scratch.iter().map(|&r| r as f32));
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth_uci::{synth_uci, uci_spec};
    use crate::train::oneshot::{train_oneshot, OneShotConfig};

    #[test]
    fn native_engine_matches_model_evaluate() {
        let ds = synth_uci(5, uci_spec("iris").unwrap());
        let (model, _) = train_oneshot(&ds, &OneShotConfig::default());
        let conf = model.evaluate(&ds.test_x, &ds.test_y, ds.num_features);
        let mut eng = NativeEngine::new(model);
        let preds = eng.classify(&ds.test_x, ds.n_test()).unwrap();
        let correct = preds
            .iter()
            .zip(ds.test_y.iter())
            .filter(|(p, y)| **p == **y as usize)
            .count();
        assert_eq!(correct as f64 / ds.n_test() as f64, conf.accuracy());
    }

    #[test]
    fn classify_tie_breaks_low() {
        struct Fake;
        impl InferenceEngine for Fake {
            fn label(&self) -> String { "fake".into() }
            fn num_features(&self) -> usize { 1 }
            fn num_classes(&self) -> usize { 3 }
            fn responses(&mut self, _x: &[f32], n: usize) -> crate::Result<Vec<f32>> {
                Ok(vec![2.0, 2.0, 1.0].repeat(n))
            }
        }
        let mut f = Fake;
        assert_eq!(f.classify(&[0.0], 1).unwrap(), vec![0]);
    }
}
