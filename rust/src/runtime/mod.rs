//! Inference engines behind one batch-classifier trait.
//!
//! * [`NativeEngine`] — the bit-packed native path: scalar scatter-hash
//!   for single samples, the fused slice path for batches (thermometer
//!   encode straight into the bit-sliced 64-sample-tile layout).
//! * [`ShardedEngine`] — the fused kernel fanned across a persistent
//!   worker pool (threads spawn once, jobs flow over channels, joined on
//!   drop) with deterministic row-major stitching.
//! * [`ShardedRouterEngine`] — the cascade × shard composition: the
//!   model-zoo confidence cascade run data-parallel across the same kind
//!   of pool, per-tier counters merged deterministically.
//! * [`SharedModel`] — one compiled model behind `Arc`s; EVERY engine
//!   construction path goes through it, so replicating an engine across
//!   workers or shards shares the tables instead of cloning them.
//! * `PjrtEngine` (feature `pjrt`) — loads the AOT artifacts
//!   (`artifacts/*.hlo.txt`, lowered once by `python/compile/aot.py`) and
//!   executes them through XLA. Interchange is HLO **text**: jax ≥ 0.5
//!   serializes HloModuleProto with 64-bit instruction ids that
//!   xla_extension 0.5.1 rejects; the text parser reassigns ids (see
//!   /opt/xla-example/README.md). One compiled executable per
//!   (model, batch-size) pair; Python never runs at serving time. Gated
//!   because the `xla` crate is unavailable offline.

#[cfg(feature = "pjrt")]
pub mod pjrt;
pub mod sharded;

#[cfg(feature = "pjrt")]
pub use pjrt::PjrtEngine;
pub use sharded::{ShardedEngine, ShardedRouterEngine};

use crate::model::ensemble::UleenModel;
use std::sync::Arc;

/// Request service class — which point on the paper's §V-D
/// accuracy/efficiency frontier a request asks for. Single-model engines
/// ignore it; zoo engines (`coordinator::router::RouterEngine`) map it
/// onto their tier list (small → large).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Tier {
    /// lowest latency/energy: smallest model only
    Fast,
    /// balanced: middle model
    Balanced,
    /// best accuracy: largest model
    Accurate,
}

/// One served model, compiled once and shared by reference.
///
/// The model (`UleenModel`: encoder + trainable tables) and its compiled
/// inference layout (`FlatModel`) both sit behind `Arc`s, so every
/// consumer of the same tier — per-worker [`NativeEngine`]s in a zoo,
/// the shard pool behind [`ShardedRouterEngine`], the scalar path —
/// holds a reference to ONE copy instead of cloning the tables per
/// worker (memory used to grow ∝ workers × tiers). Cloning a
/// `SharedModel` clones two `Arc`s, never the tables; the
/// `Arc::strong_count` witness tests pin that down.
#[derive(Clone)]
pub struct SharedModel {
    model: Arc<UleenModel>,
    flat: Arc<crate::model::flat::FlatModel>,
}

impl SharedModel {
    /// Compile `model`'s flat inference layout and wrap both behind
    /// `Arc`s. The ONE place a served model is compiled; every engine
    /// construction path (scalar, sharded, zoo, sharded zoo) goes
    /// through a `SharedModel`.
    pub fn compile(model: UleenModel) -> Self {
        let flat = Arc::new(crate::model::flat::FlatModel::compile(&model));
        Self { model: Arc::new(model), flat }
    }

    /// [`SharedModel::compile`] with a forced SIMD dispatch tier — the
    /// testing override the SIMD≡scalar conformance proptests drive
    /// whole engines through (an unsupported path clamps to scalar,
    /// exactly like `FlatModel::compile_with_kernel`).
    pub fn compile_with_kernel(model: UleenModel, kernel: crate::model::simd::KernelPath) -> Self {
        let flat =
            Arc::new(crate::model::flat::FlatModel::compile_with_kernel(&model, kernel));
        Self { model: Arc::new(model), flat }
    }

    /// [`SharedModel::compile`] with explicit
    /// [`CompileOptions`](crate::model::flat::CompileOptions) — force any
    /// of kernel tier, mask-plane width, prefetch. The conformance
    /// proptests drive whole engines through this to pin a memory-plane
    /// configuration without mutating process-global env vars.
    pub fn compile_with(model: UleenModel, opts: crate::model::flat::CompileOptions) -> Self {
        let flat = Arc::new(crate::model::flat::FlatModel::compile_with(&model, opts));
        Self { model: Arc::new(model), flat }
    }

    /// The compiled tile kernel's SIMD dispatch tier.
    pub fn kernel_path(&self) -> crate::model::simd::KernelPath {
        self.flat.kernel_path()
    }

    /// Resident bytes of the compiled inference tables (arena + bias) —
    /// see [`FlatModel::model_bytes`](crate::model::flat::FlatModel::model_bytes).
    pub fn model_bytes(&self) -> u64 {
        self.flat.model_bytes()
    }

    pub fn model(&self) -> &Arc<UleenModel> {
        &self.model
    }

    pub fn flat(&self) -> &Arc<crate::model::flat::FlatModel> {
        &self.flat
    }

    pub fn num_features(&self) -> usize {
        self.model.encoder.num_inputs
    }

    pub fn num_classes(&self) -> usize {
        self.model.num_classes()
    }
}

/// A batch classifier — implemented by both the native bit-packed engine
/// and the PJRT-loaded AOT graph, so the coordinator and the benches can
/// swap them freely (and cross-check one against the other).
///
/// ## The write-into contract
///
/// The **primitive** operations are the `_into` forms: the caller owns
/// the output plane and the engine owns (and reuses) every piece of
/// scratch, so a warm engine serves micro-batches with **zero
/// steady-state allocations** (witnessed by the counting-allocator tests
/// and the `engine_hot` alloc gate). For every `_into` method:
///
/// * `out` must hold at least the written prefix (`n * num_classes`
///   response floats, or `n` predictions) — a shorter plane is an `Err`
///   *before any work happens* (never a panic, so a pool job can't die
///   mid-flight on a sizing bug);
/// * exactly that prefix is overwritten — passing a dirty, oversized
///   grow-only buffer is the intended usage, and anything beyond the
///   prefix is left untouched (`prop_into_matches_vec` pins this down);
/// * the `Vec`-returning forms are thin default wrappers that allocate a
///   fresh plane and delegate, preserving every historical call site.
pub trait InferenceEngine: Send {
    /// Human-readable engine label for logs/benches.
    fn label(&self) -> String;
    fn num_features(&self) -> usize;
    fn num_classes(&self) -> usize;

    /// PRIMITIVE: per-class responses for `n` samples (row-major `x`,
    /// length `n * num_features`), written row-major into
    /// `out[..n * num_classes]` under the trait's write-into contract.
    fn responses_into(&mut self, x: &[f32], n: usize, out: &mut [f32]) -> crate::Result<()>;

    /// Per-class responses in a freshly allocated plane — a thin wrapper
    /// over [`InferenceEngine::responses_into`]. Input length is checked
    /// BEFORE the plane is allocated, so an inconsistent `n` is an `Err`,
    /// never an attempted `n * m` allocation.
    fn responses(&mut self, x: &[f32], n: usize) -> crate::Result<Vec<f32>> {
        anyhow::ensure!(x.len() == n * self.num_features(), "bad input length");
        let mut out = vec![0f32; n * self.num_classes()];
        self.responses_into(x, n, &mut out)?;
        Ok(out)
    }

    /// Argmax classification written into `out[..n]` (ties break low,
    /// like the hardware comparator). The default stages responses
    /// through a fresh plane; engines with reusable scratch override it
    /// to stay allocation-free.
    fn classify_into(&mut self, x: &[f32], n: usize, out: &mut [usize]) -> crate::Result<()> {
        anyhow::ensure!(out.len() >= n, "prediction plane too short: {} < {n}", out.len());
        let m = self.num_classes();
        let resp = self.responses(x, n)?;
        for (row, o) in out.iter_mut().enumerate().take(n) {
            *o = crate::util::argmax_tie_low(&resp[row * m..(row + 1) * m]);
        }
        Ok(())
    }

    /// Argmax classification in a freshly allocated `Vec` — a thin
    /// wrapper over [`InferenceEngine::classify_into`] (input length
    /// checked before the plane is allocated).
    fn classify(&mut self, x: &[f32], n: usize) -> crate::Result<Vec<usize>> {
        anyhow::ensure!(x.len() == n * self.num_features(), "bad input length");
        let mut out = vec![0usize; n];
        self.classify_into(x, n, &mut out)?;
        Ok(out)
    }

    /// Zoo depth for tier-aware engines; 0 = tier-blind (the default).
    /// The server canonicalizes pinned tiers against this so aliased
    /// tiers cannot fragment micro-batches, and strips pins entirely for
    /// tier-blind engines.
    fn num_tiers(&self) -> usize {
        0
    }

    /// The SIMD dispatch tier of the engine's compiled tile kernel
    /// (`"avx2"` / `"neon"` / `"scalar"`), surfaced in `/metrics` as
    /// `kernel_path` so a silently-degraded dispatch is observable.
    /// Engines not built on the flat native kernel report `"n/a"`.
    fn kernel_path(&self) -> &'static str {
        "n/a"
    }

    /// Resident bytes of the engine's compiled model tables (summed over
    /// every tier for zoo engines), surfaced in `/metrics` as
    /// `model_bytes` — the memory-accounting hook the multi-tenant
    /// registry (ROADMAP item 5) builds on. Engines not built on the
    /// flat native layout report 0 ("unaccounted", not "free").
    fn model_bytes(&self) -> u64 {
        0
    }

    /// Per-tier resident model bytes for zoo engines, small → large,
    /// aligned with the `/metrics` tier naming (`fast`/`balanced`/
    /// `accurate`); unused slots stay 0. Tier-blind engines keep the
    /// default all-zero answer.
    fn tier_model_bytes(&self) -> [u64; 3] {
        [0; 3]
    }

    /// Tier-routed batch classification into `out[..n]` — what the
    /// serving worker calls. Engines owning a model zoo dispatch
    /// `Some(tier)` to that pinned tier and `None` to the batched
    /// confidence cascade; single-model engines serve every tier with
    /// their one model (the tier is a routing hint, not a correctness
    /// contract).
    fn classify_routed_into(
        &mut self,
        x: &[f32],
        n: usize,
        tier: Option<Tier>,
        out: &mut [usize],
    ) -> crate::Result<()> {
        let _ = tier;
        self.classify_into(x, n, out)
    }

    /// Tier-routed classification in a freshly allocated `Vec` — a thin
    /// wrapper over [`InferenceEngine::classify_routed_into`] (input
    /// length checked before the plane is allocated).
    fn classify_routed(
        &mut self,
        x: &[f32],
        n: usize,
        tier: Option<Tier>,
    ) -> crate::Result<Vec<usize>> {
        anyhow::ensure!(x.len() == n * self.num_features(), "bad input length");
        let mut out = vec![0usize; n];
        self.classify_routed_into(x, n, tier, &mut out)?;
        Ok(out)
    }
}

/// Stage responses through an engine-owned grow-only plane and argmax
/// each row into `out[..n]` — the one implementation behind every
/// engine's allocation-free `classify_into` override. The caller takes
/// its plane out of `self` first (so `fill` may borrow the engine
/// mutably) and restores it afterwards; on a `fill` error nothing is
/// written to `out`.
pub(crate) fn classify_via_plane(
    plane: &mut Vec<f32>,
    m: usize,
    n: usize,
    out: &mut [usize],
    fill: impl FnOnce(&mut [f32]) -> crate::Result<()>,
) -> crate::Result<()> {
    anyhow::ensure!(out.len() >= n, "prediction plane too short: {} < {n}", out.len());
    if plane.len() < n * m {
        plane.resize(n * m, 0.0);
    }
    fill(&mut plane[..])?;
    for (row, o) in out.iter_mut().enumerate().take(n) {
        *o = crate::util::argmax_tie_low(&plane[row * m..(row + 1) * m]);
    }
    Ok(())
}

/// The native Rust engine: bit-packed tables, shared H3 hash block,
/// flat-compiled for the hot path (see `model::flat` — §Perf). Single
/// samples take the scalar scatter-hash path; batches (`n > 1`) take the
/// fused slice path ([`responses_batch_fused`]): raw float rows are
/// thermometer-encoded straight into the bit-sliced tile layout, with no
/// per-sample `BitVec` and no transpose — both paths are bit-exact with
/// the reference ensemble.
///
/// [`responses_batch_fused`]: crate::model::flat::FlatModel::responses_batch_fused
pub struct NativeEngine {
    shared: SharedModel,
    /// scalar-path i32 response staging (one row)
    resp_scratch: Vec<i32>,
    flat_scratch: crate::model::flat::FlatScratch,
    batch_scratch: crate::model::flat::FlatBatchScratch,
    encoded_buf: crate::util::bitvec::BitVec,
    /// grow-only response plane backing `classify_into` (so argmax
    /// classification allocates nothing after warmup)
    resp_plane: Vec<f32>,
}

impl NativeEngine {
    pub fn new(model: UleenModel) -> Self {
        Self::from_shared(SharedModel::compile(model))
    }

    /// Build an engine over an already-compiled [`SharedModel`] — two
    /// `Arc` clones, zero model/table clones. The construction path the
    /// zoo router and the shard pool use so N workers share one copy of
    /// every tier.
    pub fn from_shared(shared: SharedModel) -> Self {
        let encoded_buf = crate::util::bitvec::BitVec::zeros(shared.model().encoded_bits());
        Self {
            shared,
            resp_scratch: Vec::new(),
            flat_scratch: crate::model::flat::FlatScratch::default(),
            batch_scratch: crate::model::flat::FlatBatchScratch::default(),
            encoded_buf,
            resp_plane: Vec::new(),
        }
    }

    /// The served model (read-only; shared with every other holder of the
    /// same [`SharedModel`]).
    pub fn model(&self) -> &UleenModel {
        self.shared.model()
    }

    /// The engine's shared handle (cloning it shares, never copies).
    pub fn shared(&self) -> &SharedModel {
        &self.shared
    }

    /// Replace the served model in place, recompiling the flat layout and
    /// resetting every shape-dependent buffer. The same engine may serve
    /// models of different encoded widths / feature counts / class counts
    /// across calls — stale scratch shapes cannot leak into the new model
    /// (covered by `engine_survives_model_swaps_of_different_widths`).
    pub fn swap_model(&mut self, model: UleenModel) {
        self.swap_shared(SharedModel::compile(model));
    }

    /// [`NativeEngine::swap_model`] without recompiling: adopt an
    /// already-shared model (the old model's `Arc`s are released, so a
    /// fully swapped-out zoo frees its tables exactly once).
    pub fn swap_shared(&mut self, shared: SharedModel) {
        self.encoded_buf = crate::util::bitvec::BitVec::zeros(shared.model().encoded_bits());
        self.flat_scratch = crate::model::flat::FlatScratch::default();
        self.batch_scratch = crate::model::flat::FlatBatchScratch::default();
        self.resp_scratch = Vec::new();
        self.resp_plane = Vec::new();
        self.shared = shared;
    }
}

impl InferenceEngine for NativeEngine {
    fn label(&self) -> String {
        format!("native:{}", self.model().name)
    }

    fn num_features(&self) -> usize {
        self.model().encoder.num_inputs
    }

    fn num_classes(&self) -> usize {
        self.model().num_classes()
    }

    fn kernel_path(&self) -> &'static str {
        self.shared.kernel_path().label()
    }

    fn model_bytes(&self) -> u64 {
        self.shared.model_bytes()
    }

    fn responses_into(&mut self, x: &[f32], n: usize, out: &mut [f32]) -> crate::Result<()> {
        let f = self.num_features();
        anyhow::ensure!(x.len() == n * f, "bad input length");
        let m = self.num_classes();
        anyhow::ensure!(
            out.len() >= n * m,
            "response plane too short: {} < {}",
            out.len(),
            n * m
        );
        if n == 0 {
            return Ok(());
        }
        if n > 1 {
            // Fused slice path: encode straight into the bit-sliced tile
            // layout, one CSR traversal per 64 samples, i32 staging one
            // tile at a time inside the batch scratch.
            self.shared.flat().responses_batch_fused_into(
                &self.shared.model().encoder,
                x,
                n,
                &mut self.batch_scratch,
                out,
            );
            return Ok(());
        }
        let bits = self.shared.model().encoded_bits();
        if self.encoded_buf.len() != bits {
            self.encoded_buf = crate::util::bitvec::BitVec::zeros(bits);
        }
        self.shared
            .model()
            .encoder
            .encode_into(&x[..f], &mut self.encoded_buf);
        self.resp_scratch.clear();
        self.resp_scratch.resize(m, 0);
        self.shared.flat().responses_encoded(
            &self.encoded_buf,
            &mut self.flat_scratch,
            &mut self.resp_scratch,
        );
        for (o, &r) in out[..m].iter_mut().zip(self.resp_scratch.iter()) {
            *o = r as f32;
        }
        Ok(())
    }

    fn classify_into(&mut self, x: &[f32], n: usize, out: &mut [usize]) -> crate::Result<()> {
        let m = self.num_classes();
        let mut plane = std::mem::take(&mut self.resp_plane);
        let res = classify_via_plane(&mut plane, m, n, out, |p| self.responses_into(x, n, p));
        self.resp_plane = plane;
        res
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth_uci::{synth_uci, uci_spec};
    use crate::train::oneshot::{train_oneshot, OneShotConfig};

    #[test]
    fn native_engine_matches_model_evaluate() {
        let ds = synth_uci(5, uci_spec("iris").unwrap());
        let (model, _) = train_oneshot(&ds, &OneShotConfig::default());
        let conf = model.evaluate(&ds.test_x, &ds.test_y, ds.num_features);
        let mut eng = NativeEngine::new(model);
        let preds = eng.classify(&ds.test_x, ds.n_test()).unwrap();
        let correct = preds
            .iter()
            .zip(ds.test_y.iter())
            .filter(|(p, y)| **p == **y as usize)
            .count();
        assert_eq!(correct as f64 / ds.n_test() as f64, conf.accuracy());
    }

    #[test]
    fn engine_survives_model_swaps_of_different_widths() {
        // Swap models whose encoded widths, feature counts and class
        // counts all differ through ONE engine of each kind: stale
        // scratch shapes (slice buffers, response staging, encode
        // buffers) must never leak across models.
        let ds_a = synth_uci(5, uci_spec("iris").unwrap());
        let ds_b = synth_uci(6, uci_spec("vowel").unwrap());
        let (model_a, _) = train_oneshot(
            &ds_a,
            &OneShotConfig { inputs_per_filter: 6, entries_per_filter: 64, therm_bits: 3, ..Default::default() },
        );
        let (model_b, _) = train_oneshot(
            &ds_b,
            &OneShotConfig { inputs_per_filter: 10, entries_per_filter: 128, therm_bits: 7, ..Default::default() },
        );
        assert_ne!(model_a.encoded_bits(), model_b.encoded_bits());
        let mut fresh_a = NativeEngine::new(model_a.clone());
        let mut fresh_b = NativeEngine::new(model_b.clone());
        let want_a = fresh_a.responses(&ds_a.test_x, ds_a.n_test()).unwrap();
        let want_b = fresh_b.responses(&ds_b.test_x, ds_b.n_test()).unwrap();

        let mut eng = NativeEngine::new(model_a.clone());
        // warm the wide-model scratch shapes, then swap down and back up
        assert_eq!(eng.responses(&ds_a.test_x, ds_a.n_test()).unwrap(), want_a);
        eng.swap_model(model_b.clone());
        assert_eq!(eng.responses(&ds_b.test_x, ds_b.n_test()).unwrap(), want_b);
        eng.swap_model(model_a.clone());
        assert_eq!(eng.responses(&ds_a.test_x, ds_a.n_test()).unwrap(), want_a);
        // single-sample (scalar path) after a swap reuses encoded_buf
        assert_eq!(
            eng.responses(&ds_a.test_x[..eng.num_features()], 1).unwrap(),
            want_a[..eng.num_classes()].to_vec()
        );

        let mut sh = crate::runtime::ShardedEngine::new(model_a, 3);
        assert_eq!(sh.responses(&ds_a.test_x, ds_a.n_test()).unwrap(), want_a);
        let spawned = sh.threads_spawned();
        sh.swap_model(model_b);
        assert_eq!(sh.responses(&ds_b.test_x, ds_b.n_test()).unwrap(), want_b);
        assert_eq!(sh.threads_spawned(), spawned, "swap must not respawn the pool");
    }

    #[test]
    fn classify_tie_breaks_low() {
        struct Fake;
        impl InferenceEngine for Fake {
            fn label(&self) -> String { "fake".into() }
            fn num_features(&self) -> usize { 1 }
            fn num_classes(&self) -> usize { 3 }
            fn responses_into(&mut self, _x: &[f32], n: usize, out: &mut [f32]) -> crate::Result<()> {
                for row in out[..3 * n].chunks_mut(3) {
                    row.copy_from_slice(&[2.0, 2.0, 1.0]);
                }
                Ok(())
            }
        }
        let mut f = Fake;
        assert_eq!(f.classify(&[0.0], 1).unwrap(), vec![0]);
        // default classify_into honors the prefix contract
        let mut preds = [usize::MAX; 4];
        f.classify_into(&[0.0, 0.0], 2, &mut preds).unwrap();
        assert_eq!(preds, [0, 0, usize::MAX, usize::MAX]);
        assert!(f.classify_into(&[0.0; 3], 3, &mut preds[..2]).is_err());
    }

    #[test]
    fn into_paths_match_vec_paths_and_respect_the_prefix_contract() {
        let ds = synth_uci(5, uci_spec("iris").unwrap());
        let (model, _) = train_oneshot(&ds, &OneShotConfig::default());
        let mut eng = NativeEngine::new(model);
        let m = eng.num_classes();
        const SENTINEL_F: f32 = -4242.5;
        for n in [0usize, 1, 2, 65] {
            let n = n.min(ds.n_test());
            let x = &ds.test_x[..n * ds.num_features];
            let want_resp = eng.responses(x, n).unwrap();
            let want_pred = eng.classify(x, n).unwrap();
            // dirty, oversized planes: prefix fully overwritten, suffix kept
            let mut resp = vec![SENTINEL_F; n * m + 5];
            eng.responses_into(x, n, &mut resp).unwrap();
            assert_eq!(&resp[..n * m], &want_resp[..], "n={n}");
            assert!(resp[n * m..].iter().all(|&v| v == SENTINEL_F), "n={n} suffix");
            let mut pred = vec![usize::MAX; n + 3];
            eng.classify_into(x, n, &mut pred).unwrap();
            assert_eq!(&pred[..n], &want_pred[..], "n={n}");
            assert!(pred[n..].iter().all(|&v| v == usize::MAX), "n={n} suffix");
        }
        // too-short planes are an Err, not a panic
        let x = &ds.test_x[..2 * ds.num_features];
        let mut short = vec![0f32; 2 * m - 1];
        assert!(eng.responses_into(x, 2, &mut short).is_err());
        let mut short_p = vec![0usize; 1];
        assert!(eng.classify_into(x, 2, &mut short_p).is_err());
    }

    #[test]
    fn native_engine_steady_state_is_allocation_free() {
        // The zero-allocation witness the refactor exists for: a warm
        // NativeEngine serves `responses_into`/`classify_into` (fused
        // batch AND scalar path) without touching the heap. Counting is
        // per-thread, so concurrently running tests can't pollute it.
        use crate::util::alloc_witness::Witness;
        let ds = synth_uci(5, uci_spec("iris").unwrap());
        let (model, _) = train_oneshot(&ds, &OneShotConfig::default());
        let mut eng = NativeEngine::new(model);
        let m = eng.num_classes();
        let f = eng.num_features();
        let n = 65.min(ds.n_test());
        let x = &ds.test_x[..n * f];
        let mut resp = vec![0f32; n * m];
        let mut pred = vec![0usize; n];
        // warmup grows every scratch buffer to its steady shape
        for _ in 0..2 {
            eng.responses_into(x, n, &mut resp).unwrap();
            eng.classify_into(x, n, &mut pred).unwrap();
            eng.responses_into(&x[..f], 1, &mut resp).unwrap();
            eng.classify_into(&x[..f], 1, &mut pred).unwrap();
        }
        let w = Witness::begin();
        for _ in 0..8 {
            eng.responses_into(x, n, &mut resp).unwrap();
            eng.classify_into(x, n, &mut pred).unwrap();
            eng.responses_into(&x[..f], 1, &mut resp).unwrap();
            eng.classify_into(&x[..f], 1, &mut pred).unwrap();
        }
        assert_eq!(
            w.allocations(),
            0,
            "a warm NativeEngine must not allocate on the write-into hot path"
        );
    }
}
