//! Counting-allocator witness for the zero-allocation serving plane.
//!
//! [`CountingAlloc`] wraps the system allocator and counts every
//! allocation (alloc / alloc_zeroed / realloc — deallocation is free and
//! uncounted) into a process-wide AND a per-thread counter. It is a pure
//! pass-through: installing it costs one relaxed atomic increment plus
//! one thread-local `Cell` bump per heap allocation, so the lib's own
//! unit tests run under it wholesale (see `lib.rs`) and the `engine_hot`
//! bench opts in behind the `alloc-witness` feature.
//!
//! The per-thread counter is what makes steady-state assertions
//! trustworthy under `cargo test`'s parallel runner: a [`Witness`] scope
//! observes only the measuring thread, so concurrently running tests
//! (or pool workers acking jobs) can't pollute a zero-allocation check.
//! For the sharded engines the caller-side count is the contract: the
//! data plane (inputs, outputs, scratch) must be allocation-free, while
//! the pool's mpsc channel nodes remain the one bounded, O(shards),
//! batch-size-independent exception.
//!
//! Since the slab-arena request plane (see `coordinator::batcher`) the
//! witnessed scope extends past the engines to the whole submit→complete
//! loop: a warm caller thread driving `Server::submit` through completion
//! recv must count ZERO allocations per request — rows copy into arena
//! slots, batches drain into reused buffers, and completions are plain
//! `(id, prediction)` tuples. The `engine_hot` bench enforces this as the
//! `allocs_per_request` gate; the worker-side `mpsc::Sender::send` node
//! is invisible to the caller-thread witness by design (it lands on the
//! worker's thread-local counter, not the submitter's).
//!
//! Counting must never itself allocate: the counters are a static atomic
//! and a const-initialized thread-local `Cell`, and the thread-local is
//! accessed via `try_with` so allocations during TLS teardown fall back
//! to the process counter instead of aborting.

use std::alloc::{GlobalAlloc, Layout, System};
use std::cell::Cell;
use std::sync::atomic::{AtomicU64, Ordering};

static PROCESS_ALLOCS: AtomicU64 = AtomicU64::new(0);

thread_local! {
    static THREAD_ALLOCS: Cell<u64> = const { Cell::new(0) };
}

#[inline]
fn count_one() {
    PROCESS_ALLOCS.fetch_add(1, Ordering::Relaxed);
    // TLS may be mid-destruction on a dying thread; losing its local
    // count is fine (the process counter still has it).
    let _ = THREAD_ALLOCS.try_with(|c| c.set(c.get() + 1));
}

/// A pass-through [`GlobalAlloc`] that counts allocations. Install with
/// `#[global_allocator]` in the binary that wants witnessing.
pub struct CountingAlloc;

// SAFETY: pure delegation to `System`; the bookkeeping touches only a
// static atomic and a const-init TLS cell, neither of which allocates.
unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        count_one();
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        count_one();
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        count_one();
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

/// Allocations observed by THIS thread so far (0 if the witness
/// allocator is not installed as `#[global_allocator]`).
pub fn thread_allocations() -> u64 {
    THREAD_ALLOCS.try_with(|c| c.get()).unwrap_or(0)
}

/// Allocations observed process-wide so far (0 if not installed).
pub fn process_allocations() -> u64 {
    PROCESS_ALLOCS.load(Ordering::Relaxed)
}

/// A scoped allocation count on the current thread:
/// `Witness::begin()` … do work … `witness.allocations()`.
pub struct Witness {
    start: u64,
}

impl Witness {
    pub fn begin() -> Self {
        Self { start: thread_allocations() }
    }

    /// Heap allocations made by this thread since [`Witness::begin`].
    pub fn allocations(&self) -> u64 {
        thread_allocations() - self.start
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn witness_counts_this_threads_allocations() {
        // lib.rs installs CountingAlloc for the lib test harness, so a
        // fresh Vec must register and a no-op scope must not.
        let w = Witness::begin();
        let v: Vec<u8> = Vec::with_capacity(1024);
        std::hint::black_box(&v);
        assert!(w.allocations() >= 1, "an allocation must be observed");
        drop(v);
        let quiet = Witness::begin();
        std::hint::black_box(quiet.allocations());
        assert_eq!(quiet.allocations(), 0, "dealloc and reads don't count");
        assert!(process_allocations() >= thread_allocations());
    }

    #[test]
    fn other_threads_do_not_pollute_a_witness() {
        let w = Witness::begin();
        std::thread::spawn(|| {
            let v: Vec<u64> = (0..4096).collect();
            std::hint::black_box(v.len())
        })
        .join()
        .unwrap();
        // spawning itself allocates on the spawning thread (stack/handle
        // bookkeeping), so assert only that the spawned thread's big
        // buffer is invisible here — the join rendezvous guarantees it
        // happened inside the window.
        assert!(
            w.allocations() < 100,
            "a sibling thread's allocations must not land on this witness"
        );
    }
}
