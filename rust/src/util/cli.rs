//! Minimal declarative CLI parser (clap is unavailable offline).
//!
//! Supports `binary <subcommand> --key value --flag` with typed getters and
//! automatic usage text. Unknown options are an error so typos fail loudly.

use std::collections::BTreeMap;

/// Parsed command line: a subcommand plus `--key value` options and
/// boolean `--flag`s.
#[derive(Debug, Default, Clone)]
pub struct Args {
    pub subcommand: String,
    opts: BTreeMap<String, String>,
    flags: Vec<String>,
    positional: Vec<String>,
}

/// Declarative option spec used for parsing + usage text.
pub struct OptSpec {
    pub name: &'static str,
    pub takes_value: bool,
    pub help: &'static str,
}

impl Args {
    /// Parse `argv[1..]`. The first non-option token is the subcommand; the
    /// remaining non-option tokens are positional.
    pub fn parse(argv: &[String], spec: &[OptSpec]) -> Result<Args, String> {
        let mut out = Args::default();
        let mut i = 0;
        while i < argv.len() {
            let tok = &argv[i];
            if let Some(name) = tok.strip_prefix("--") {
                let (name, inline_val) = match name.split_once('=') {
                    Some((n, v)) => (n, Some(v.to_string())),
                    None => (name, None),
                };
                let s = spec
                    .iter()
                    .find(|s| s.name == name)
                    .ok_or_else(|| format!("unknown option --{name}"))?;
                if s.takes_value {
                    let val = match inline_val {
                        Some(v) => v,
                        None => {
                            i += 1;
                            argv.get(i)
                                .cloned()
                                .ok_or_else(|| format!("--{name} requires a value"))?
                        }
                    };
                    out.opts.insert(name.to_string(), val);
                } else {
                    if inline_val.is_some() {
                        return Err(format!("--{name} does not take a value"));
                    }
                    out.flags.push(name.to_string());
                }
            } else if out.subcommand.is_empty() {
                out.subcommand = tok.clone();
            } else {
                out.positional.push(tok.clone());
            }
            i += 1;
        }
        Ok(out)
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.opts.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn get_usize(&self, name: &str, default: usize) -> Result<usize, String> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| format!("--{name} expects an integer, got '{v}'")),
        }
    }

    pub fn get_u64(&self, name: &str, default: u64) -> Result<u64, String> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| format!("--{name} expects an integer, got '{v}'")),
        }
    }

    pub fn get_f64(&self, name: &str, default: f64) -> Result<f64, String> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| format!("--{name} expects a number, got '{v}'")),
        }
    }

    pub fn positional(&self) -> &[String] {
        &self.positional
    }
}

/// Render usage text for a spec table.
pub fn usage(binary: &str, subcommands: &[(&str, &str)], spec: &[OptSpec]) -> String {
    let mut s = format!("usage: {binary} <subcommand> [options]\n\nsubcommands:\n");
    for (name, help) in subcommands {
        s.push_str(&format!("  {name:<18} {help}\n"));
    }
    s.push_str("\noptions:\n");
    for o in spec {
        let v = if o.takes_value { " <v>" } else { "" };
        s.push_str(&format!("  --{}{v:<6} {}\n", o.name, o.help));
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec() -> Vec<OptSpec> {
        vec![
            OptSpec { name: "model", takes_value: true, help: "" },
            OptSpec { name: "seed", takes_value: true, help: "" },
            OptSpec { name: "verbose", takes_value: false, help: "" },
        ]
    }

    fn sv(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_subcommand_opts_flags() {
        let a = Args::parse(&sv(&["train", "--model", "uln-s", "--verbose", "x.bin"]), &spec())
            .unwrap();
        assert_eq!(a.subcommand, "train");
        assert_eq!(a.get("model"), Some("uln-s"));
        assert!(a.flag("verbose"));
        assert_eq!(a.positional(), &["x.bin".to_string()]);
    }

    #[test]
    fn inline_equals_form() {
        let a = Args::parse(&sv(&["eval", "--seed=42"]), &spec()).unwrap();
        assert_eq!(a.get_u64("seed", 0).unwrap(), 42);
    }

    #[test]
    fn unknown_option_is_error() {
        assert!(Args::parse(&sv(&["t", "--bogus"]), &spec()).is_err());
    }

    #[test]
    fn missing_value_is_error() {
        assert!(Args::parse(&sv(&["t", "--model"]), &spec()).is_err());
    }

    #[test]
    fn typed_getters_defaults_and_errors() {
        let a = Args::parse(&sv(&["t", "--seed", "notanum"]), &spec()).unwrap();
        assert!(a.get_u64("seed", 1).is_err());
        assert_eq!(a.get_usize("missing", 7).unwrap(), 7);
    }
}
