//! Basic statistics: online mean/variance, percentiles, timers and a
//! confusion matrix — shared by the bench harness, the coordinator metrics
//! and the evaluation code.

use std::time::{Duration, Instant};

/// Welford online mean/variance accumulator.
#[derive(Clone, Debug, Default)]
pub struct OnlineStats {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl OnlineStats {
    pub fn new() -> Self {
        Self { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn variance(&self) -> f64 {
        if self.n < 2 { 0.0 } else { self.m2 / (self.n - 1) as f64 }
    }

    pub fn stddev(&self) -> f64 {
        self.variance().sqrt()
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }
}

/// Percentile (nearest-rank) of an unsorted sample; `q` in `[0,1]`.
pub fn percentile(samples: &mut [f64], q: f64) -> f64 {
    assert!(!samples.is_empty());
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let idx = ((samples.len() as f64 - 1.0) * q).round() as usize;
    samples[idx.min(samples.len() - 1)]
}

/// Wall-clock timer with a convenient elapsed-seconds reading.
pub struct Timer {
    start: Instant,
}

impl Timer {
    pub fn start() -> Self {
        Self { start: Instant::now() }
    }

    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }

    pub fn secs(&self) -> f64 {
        self.elapsed().as_secs_f64()
    }
}

/// Confusion matrix for an `n`-class classifier.
#[derive(Clone, Debug)]
pub struct Confusion {
    pub n_classes: usize,
    /// counts[actual * n_classes + predicted]
    counts: Vec<u64>,
}

impl Confusion {
    pub fn new(n_classes: usize) -> Self {
        Self { n_classes, counts: vec![0; n_classes * n_classes] }
    }

    pub fn record(&mut self, actual: usize, predicted: usize) {
        self.counts[actual * self.n_classes + predicted] += 1;
    }

    pub fn total(&self) -> u64 {
        self.counts.iter().sum()
    }

    pub fn correct(&self) -> u64 {
        (0..self.n_classes)
            .map(|c| self.counts[c * self.n_classes + c])
            .sum()
    }

    pub fn accuracy(&self) -> f64 {
        let t = self.total();
        if t == 0 { 0.0 } else { self.correct() as f64 / t as f64 }
    }

    pub fn count(&self, actual: usize, predicted: usize) -> u64 {
        self.counts[actual * self.n_classes + predicted]
    }

    /// Per-class recall (correct / actual-count), NaN-free (0 when empty).
    pub fn recall(&self, class: usize) -> f64 {
        let row: u64 = (0..self.n_classes)
            .map(|p| self.counts[class * self.n_classes + p])
            .sum();
        if row == 0 {
            0.0
        } else {
            self.count(class, class) as f64 / row as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn online_stats_match_closed_form() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        let mut s = OnlineStats::new();
        for &x in &xs {
            s.push(x);
        }
        assert!((s.mean() - 5.0).abs() < 1e-12);
        // sample variance of the classic dataset = 32/7
        assert!((s.variance() - 32.0 / 7.0).abs() < 1e-12);
        assert_eq!(s.min(), 2.0);
        assert_eq!(s.max(), 9.0);
        assert_eq!(s.count(), 8);
    }

    #[test]
    fn percentile_nearest_rank() {
        let mut xs = vec![5.0, 1.0, 3.0, 2.0, 4.0];
        assert_eq!(percentile(&mut xs, 0.0), 1.0);
        assert_eq!(percentile(&mut xs, 0.5), 3.0);
        assert_eq!(percentile(&mut xs, 1.0), 5.0);
    }

    #[test]
    fn confusion_accuracy_and_recall() {
        let mut c = Confusion::new(3);
        c.record(0, 0);
        c.record(0, 0);
        c.record(0, 1);
        c.record(1, 1);
        c.record(2, 0);
        assert_eq!(c.total(), 5);
        assert_eq!(c.correct(), 3);
        assert!((c.accuracy() - 0.6).abs() < 1e-12);
        assert!((c.recall(0) - 2.0 / 3.0).abs() < 1e-12);
        assert_eq!(c.recall(1), 1.0);
        assert_eq!(c.recall(2), 0.0);
    }
}
