//! Basic statistics: online mean/variance, percentiles, timers and a
//! confusion matrix — shared by the bench harness, the coordinator metrics
//! and the evaluation code.

use std::time::{Duration, Instant};

/// Welford online mean/variance accumulator.
#[derive(Clone, Debug)]
pub struct OnlineStats {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Default for OnlineStats {
    // A derived Default would zero min/max, so an all-positive sample
    // set reports min() == 0.0; both constructors must yield the
    // ±INFINITY sentinels.
    fn default() -> Self {
        Self::new()
    }
}

impl OnlineStats {
    pub fn new() -> Self {
        Self { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn variance(&self) -> f64 {
        if self.n < 2 { 0.0 } else { self.m2 / (self.n - 1) as f64 }
    }

    pub fn stddev(&self) -> f64 {
        self.variance().sqrt()
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }
}

/// Percentile (nearest-rank) of an unsorted sample; `q` in `[0,1]`.
/// Total: an empty sample answers 0.0 (callers like the bench harness
/// at zero iterations and a zero-request metrics report reach this
/// legitimately and must not panic).
pub fn percentile(samples: &mut [f64], q: f64) -> f64 {
    if samples.is_empty() {
        return 0.0;
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let idx = ((samples.len() as f64 - 1.0) * q).round() as usize;
    samples[idx.min(samples.len() - 1)]
}

/// Sub-bucket precision bits of [`LogHistogram`]: 2^7 = 128 linear
/// sub-buckets per octave, i.e. ≤ 1/128 (~0.8 %) relative quantization
/// error on every recorded value.
const HIST_SUB_BITS: u32 = 7;
const HIST_SUB_COUNT: usize = 1 << HIST_SUB_BITS; // 128
/// Largest exponent covered: values up to 2^40 (≈ 12.7 days in µs) land
/// in their own bucket; anything beyond saturates into the last one.
const HIST_MAX_EXP: u32 = 40;
/// 128 exact unit buckets for values < 128, then 64 log-spaced buckets
/// per octave up to 2^40.
const HIST_BUCKETS: usize =
    HIST_SUB_COUNT + (HIST_MAX_EXP as usize - HIST_SUB_BITS as usize) * (HIST_SUB_COUNT / 2);

/// Fixed-memory log2-bucketed histogram (HDR-style) for latency
/// percentiles that are **exact up to bucket quantization** over every
/// recorded sample — unlike a sampling reservoir, which is only
/// statistically sound. No sorting, no per-record allocation: `record`
/// is an index computation plus one counter increment, and `percentile`
/// is a cumulative walk over ~2.2k fixed buckets.
///
/// Layout: values in `[0, 128)` get one bucket per unit (the first
/// `HIST_SUB_COUNT` buckets); each octave `[2^k, 2^{k+1})` above that
/// gets 64 linear sub-buckets, so relative error is bounded by 1/128.
/// Values record truncated to integers (the intended unit is
/// microseconds); negatives clamp to 0, overflows saturate into the
/// last bucket, and NaN is **dropped** (counted in [`dropped`], never
/// filed — `NaN as u64 == 0` would masquerade as a sub-µs sample and
/// drag p50 down).
///
/// [`dropped`]: LogHistogram::dropped
#[derive(Clone, Debug)]
pub struct LogHistogram {
    counts: Vec<u64>,
    total: u64,
    dropped: u64,
}

impl Default for LogHistogram {
    fn default() -> Self {
        Self::new()
    }
}

impl LogHistogram {
    pub fn new() -> Self {
        Self { counts: vec![0; HIST_BUCKETS], total: 0, dropped: 0 }
    }

    /// Zero every counter in place (no reallocation) — the windowed
    /// metrics view drains epochs by resetting the retired window.
    pub fn reset(&mut self) {
        self.counts.fill(0);
        self.total = 0;
        self.dropped = 0;
    }

    fn index(v: f64) -> usize {
        let u = if v <= 0.0 { 0u64 } else { v as u64 };
        if u < HIST_SUB_COUNT as u64 {
            return u as usize;
        }
        let msb = 63 - u.leading_zeros(); // >= HIST_SUB_BITS
        let msb = msb.min(HIST_MAX_EXP - 1); // saturate giant values
        // Top 7 significant bits: (u >> shift) is in [64, 128).
        let shift = msb - (HIST_SUB_BITS - 1);
        let top = ((u >> shift) as usize).min(HIST_SUB_COUNT - 1);
        HIST_SUB_COUNT
            + (msb - HIST_SUB_BITS) as usize * (HIST_SUB_COUNT / 2)
            + (top - HIST_SUB_COUNT / 2)
    }

    /// The value a bucket reports back: exact buckets answer their lower
    /// bound (which IS the value for integer samples); octave buckets
    /// answer their midpoint (halving the worst-case quantization
    /// error); the sub-unit bucket answers 0.5 so all-sub-unit
    /// populations still report a positive percentile.
    fn representative(idx: usize) -> f64 {
        if idx == 0 {
            return 0.5;
        }
        if idx < HIST_SUB_COUNT {
            return idx as f64;
        }
        let octave = (idx - HIST_SUB_COUNT) / (HIST_SUB_COUNT / 2);
        let offset = (idx - HIST_SUB_COUNT) % (HIST_SUB_COUNT / 2);
        let width = 1u64 << (octave + 1);
        let low = (HIST_SUB_COUNT as u64 / 2 + offset as u64) << (octave + 1);
        low as f64 + width as f64 / 2.0
    }

    pub fn record(&mut self, v: f64) {
        if v.is_nan() {
            self.dropped += 1;
            return;
        }
        self.counts[Self::index(v)] += 1;
        self.total += 1;
    }

    pub fn count(&self) -> u64 {
        self.total
    }

    /// NaN samples rejected by [`record`](LogHistogram::record) — they
    /// never enter a bucket, so percentiles are NaN-proof.
    pub fn dropped(&self) -> u64 {
        self.dropped
    }

    /// Nearest-rank percentile (`q` in `[0,1]`) over every recorded
    /// value — same rank rule as [`percentile`], so the two agree up to
    /// bucket quantization. 0.0 when empty.
    pub fn percentile(&self, q: f64) -> f64 {
        if self.total == 0 {
            return 0.0;
        }
        let rank = ((self.total - 1) as f64 * q.clamp(0.0, 1.0)).round() as u64;
        let mut cum = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            cum += c;
            if cum > rank {
                return Self::representative(i);
            }
        }
        // Unreachable (cum reaches total > rank); keep the walk total.
        Self::representative(HIST_BUCKETS - 1)
    }
}

/// Wall-clock timer with a convenient elapsed-seconds reading.
pub struct Timer {
    start: Instant,
}

impl Timer {
    pub fn start() -> Self {
        Self { start: Instant::now() }
    }

    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }

    pub fn secs(&self) -> f64 {
        self.elapsed().as_secs_f64()
    }
}

/// Confusion matrix for an `n`-class classifier.
#[derive(Clone, Debug)]
pub struct Confusion {
    pub n_classes: usize,
    /// counts[actual * n_classes + predicted]
    counts: Vec<u64>,
}

impl Confusion {
    pub fn new(n_classes: usize) -> Self {
        Self { n_classes, counts: vec![0; n_classes * n_classes] }
    }

    pub fn record(&mut self, actual: usize, predicted: usize) {
        self.counts[actual * self.n_classes + predicted] += 1;
    }

    pub fn total(&self) -> u64 {
        self.counts.iter().sum()
    }

    pub fn correct(&self) -> u64 {
        (0..self.n_classes)
            .map(|c| self.counts[c * self.n_classes + c])
            .sum()
    }

    pub fn accuracy(&self) -> f64 {
        let t = self.total();
        if t == 0 { 0.0 } else { self.correct() as f64 / t as f64 }
    }

    pub fn count(&self, actual: usize, predicted: usize) -> u64 {
        self.counts[actual * self.n_classes + predicted]
    }

    /// Per-class recall (correct / actual-count), NaN-free (0 when empty).
    pub fn recall(&self, class: usize) -> f64 {
        let row: u64 = (0..self.n_classes)
            .map(|p| self.counts[class * self.n_classes + p])
            .sum();
        if row == 0 {
            0.0
        } else {
            self.count(class, class) as f64 / row as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn online_stats_match_closed_form() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        let mut s = OnlineStats::new();
        for &x in &xs {
            s.push(x);
        }
        assert!((s.mean() - 5.0).abs() < 1e-12);
        // sample variance of the classic dataset = 32/7
        assert!((s.variance() - 32.0 / 7.0).abs() < 1e-12);
        assert_eq!(s.min(), 2.0);
        assert_eq!(s.max(), 9.0);
        assert_eq!(s.count(), 8);
    }

    #[test]
    fn percentile_nearest_rank() {
        let mut xs = vec![5.0, 1.0, 3.0, 2.0, 4.0];
        assert_eq!(percentile(&mut xs, 0.0), 1.0);
        assert_eq!(percentile(&mut xs, 0.5), 3.0);
        assert_eq!(percentile(&mut xs, 1.0), 5.0);
    }

    #[test]
    fn percentile_is_total_on_empty_samples() {
        // Zero-iteration bench runs and zero-request metrics reports
        // hand percentile an empty vector; it must answer 0, not panic.
        let mut none: Vec<f64> = Vec::new();
        assert_eq!(percentile(&mut none, 0.5), 0.0);
        assert_eq!(percentile(&mut none, 0.99), 0.0);
    }

    #[test]
    fn default_online_stats_keep_the_min_max_sentinels() {
        // Regression: the old derived Default zeroed min/max, so an
        // all-positive sample set reported min() == 0.0.
        let xs = [3.0, 7.0, 5.0];
        let mut by_default = OnlineStats::default();
        let mut by_new = OnlineStats::new();
        for &x in &xs {
            by_default.push(x);
            by_new.push(x);
        }
        assert_eq!(by_default.min(), 3.0);
        assert_eq!(by_default.max(), 7.0);
        assert_eq!(by_default.min(), by_new.min());
        assert_eq!(by_default.max(), by_new.max());
        // And before any push, both report the same sentinels.
        assert_eq!(OnlineStats::default().min(), f64::INFINITY);
        assert_eq!(OnlineStats::default().max(), f64::NEG_INFINITY);
    }

    #[test]
    fn log_histogram_is_exact_below_the_sub_bucket_count() {
        let mut h = LogHistogram::new();
        for v in 1..=100 {
            h.record(v as f64);
        }
        assert_eq!(h.count(), 100);
        // Nearest-rank over 1..=100 picks 51 at q=0.5 and 99 at q=0.99
        // (same rule as `percentile`); sub-128 values are unit buckets,
        // so the histogram answers them exactly.
        let mut sorted: Vec<f64> = (1..=100).map(|v| v as f64).collect();
        assert_eq!(h.percentile(0.5), percentile(&mut sorted, 0.5));
        assert_eq!(h.percentile(0.99), percentile(&mut sorted, 0.99));
        assert_eq!(h.percentile(0.0), 1.0);
        assert_eq!(h.percentile(1.0), 100.0);
    }

    #[test]
    fn log_histogram_tracks_sorted_percentiles_within_quantization() {
        // Log-spaced buckets above 128: every answer must sit within
        // 1/128 relative error of the true nearest-rank percentile.
        let mut h = LogHistogram::new();
        let mut vals = Vec::new();
        for i in 0..10_000u64 {
            let v = (i * 37 % 50_000) as f64 + 0.25;
            h.record(v);
            vals.push(v);
        }
        for q in [0.1, 0.5, 0.9, 0.99, 0.999] {
            let exact = percentile(&mut vals.clone(), q);
            let approx = h.percentile(q);
            let tol = exact.abs() / 128.0 + 1.0;
            assert!(
                (approx - exact).abs() <= tol,
                "q={q}: histogram {approx} vs exact {exact} (tol {tol})"
            );
        }
    }

    #[test]
    fn log_histogram_edge_cases_stay_bounded() {
        let mut h = LogHistogram::new();
        assert_eq!(h.percentile(0.5), 0.0, "empty histogram answers 0");
        // Negatives clamp to the sub-unit bucket; its representative is
        // positive so all-tiny populations never report p50 == 0.
        h.record(-3.0);
        h.record(0.2);
        assert!(h.percentile(0.5) > 0.0 && h.percentile(0.5) < 1.0);
        // Values beyond 2^40 saturate into the last bucket, not a panic.
        let mut big = LogHistogram::new();
        big.record(1e18);
        big.record(f64::INFINITY);
        assert!(big.percentile(0.5) >= (1u64 << 39) as f64);
        // Percentiles are monotone in q.
        let mut m = LogHistogram::new();
        for i in 0..1000 {
            m.record((i * i) as f64);
        }
        assert!(m.percentile(0.99) >= m.percentile(0.5));
        assert!(m.percentile(0.5) >= m.percentile(0.1));
    }

    #[test]
    fn log_histogram_drops_nan_without_moving_percentiles() {
        // Regression: `NaN as u64 == 0`, so NaN used to land in bucket 0
        // and masquerade as a sub-µs sample, dragging p50 down.
        let mut clean = LogHistogram::new();
        let mut poisoned = LogHistogram::new();
        for v in 100..200 {
            clean.record(v as f64);
            poisoned.record(v as f64);
        }
        for _ in 0..50 {
            poisoned.record(f64::NAN);
        }
        assert_eq!(poisoned.count(), clean.count());
        assert_eq!(poisoned.dropped(), 50);
        assert_eq!(clean.dropped(), 0);
        for q in [0.0, 0.5, 0.99, 1.0] {
            assert_eq!(
                poisoned.percentile(q),
                clean.percentile(q),
                "NaN stream moved q={q}"
            );
        }
    }

    #[test]
    fn log_histogram_reset_zeroes_in_place() {
        let mut h = LogHistogram::new();
        for v in 0..300 {
            h.record(v as f64);
        }
        h.record(f64::NAN);
        h.reset();
        assert_eq!(h.count(), 0);
        assert_eq!(h.dropped(), 0);
        assert_eq!(h.percentile(0.99), 0.0);
        h.record(42.0);
        assert_eq!(h.percentile(0.5), 42.0);
    }

    #[test]
    fn confusion_accuracy_and_recall() {
        let mut c = Confusion::new(3);
        c.record(0, 0);
        c.record(0, 0);
        c.record(0, 1);
        c.record(1, 1);
        c.record(2, 0);
        assert_eq!(c.total(), 5);
        assert_eq!(c.correct(), 3);
        assert!((c.accuracy() - 0.6).abs() < 1e-12);
        assert!((c.recall(0) - 2.0 / 3.0).abs() < 1e-12);
        assert_eq!(c.recall(1), 1.0);
        assert_eq!(c.recall(2), 0.0);
    }
}
