//! Foundation utilities built from scratch for the offline environment:
//! deterministic PRNGs, bit-packed vectors, a minimal JSON codec, a CLI
//! parser, a property-testing harness and basic statistics.

// Compiled for the lib's own test harness and, for benches/binaries
// that want the allocation gate, behind the `alloc-witness` feature —
// never on the default production build.
#[cfg(any(test, feature = "alloc-witness"))]
pub mod alloc_witness;
pub mod bitvec;
pub mod cli;
pub mod json;
pub mod prop;
pub mod rng;
pub mod stats;

/// Index of the largest element, ties breaking to the LOWEST index — the
/// semantics of the paper's hardware comparator tree. This is THE argmax
/// used by every classification path (reference ensemble, flat engine,
/// batch kernel, engine trait, router) so they can never drift apart.
/// Returns 0 for an empty slice.
pub fn argmax_tie_low<T: PartialOrd>(xs: &[T]) -> usize {
    let mut best = 0usize;
    for i in 1..xs.len() {
        if xs[i] > xs[best] {
            best = i;
        }
    }
    best
}

/// Detected logical core count (`std::thread::available_parallelism`),
/// clamped to 1 where detection is unsupported. The topology default
/// for shard pools, HTTP handler pools and worker pinning — callers
/// that want a different size pass it explicitly.
pub fn detected_cores() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

#[cfg(test)]
mod tests {
    use super::argmax_tie_low;

    #[test]
    fn argmax_picks_max_and_breaks_ties_low() {
        assert_eq!(argmax_tie_low(&[1, 5, 3]), 1);
        assert_eq!(argmax_tie_low(&[2, 2, 1]), 0, "tie breaks to lowest index");
        assert_eq!(argmax_tie_low(&[0, 7, 7, 7]), 1);
        assert_eq!(argmax_tie_low(&[-3i32, -1, -2]), 1);
        assert_eq!(argmax_tie_low::<i32>(&[]), 0, "empty defaults to 0");
        assert_eq!(argmax_tie_low(&[4.0f32]), 0);
    }

    #[test]
    fn detected_cores_is_at_least_one() {
        assert!(super::detected_cores() >= 1);
    }

    #[test]
    fn argmax_ignores_nan_like_incomparables() {
        // NaN comparisons are false, so NaN never displaces the best —
        // matching the f32 loop the engines used before extraction.
        assert_eq!(argmax_tie_low(&[1.0f32, f32::NAN, 2.0]), 2);
        // a NaN in slot 0 is never displaced (every comparison is false),
        // exactly like the pre-extraction loops
        assert_eq!(argmax_tie_low(&[f32::NAN, 1.0]), 0);
    }
}
