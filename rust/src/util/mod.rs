//! Foundation utilities built from scratch for the offline environment:
//! deterministic PRNGs, bit-packed vectors, a minimal JSON codec, a CLI
//! parser, a property-testing harness and basic statistics.

pub mod bitvec;
pub mod cli;
pub mod json;
pub mod prop;
pub mod rng;
pub mod stats;
