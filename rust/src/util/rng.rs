//! Deterministic, language-portable PRNGs.
//!
//! Everything that generates data in this repository (synthetic datasets,
//! hash parameters, input-order shuffles) is driven by these generators so
//! that the Rust and Python halves produce **bit-identical** streams. Only
//! integer arithmetic and IEEE-exact float operations are used.

/// SplitMix64 — used for seeding and for cheap independent per-item streams.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// Xoshiro256** — the main generator.
///
/// Seeded from SplitMix64 per the reference implementation so a single u64
/// seed fully determines the stream.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
        }
    }

    /// Derive an independent stream for item `index` under a named domain.
    /// Used for per-sample dataset generation so Rust (parallel) and Python
    /// (vectorised) agree regardless of generation order.
    pub fn for_item(seed: u64, domain: u64, index: u64) -> Self {
        let mut sm = SplitMix64::new(seed ^ domain.wrapping_mul(0xA24B_AED4_963E_E407));
        let a = sm.next_u64();
        let mut sm2 = SplitMix64::new(a ^ index.wrapping_mul(0x9FB2_1C65_1E98_DF25));
        Self::new(sm2.next_u64())
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform integer in `[0, bound)`. Plain modulo — bias is negligible for
    /// our bounds (≤ 2^32) and the formula is trivially portable.
    #[inline]
    pub fn below(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0);
        self.next_u64() % bound
    }

    /// Uniform integer in `[lo, hi]` inclusive (i64 domain).
    #[inline]
    pub fn range_i64(&mut self, lo: i64, hi: i64) -> i64 {
        debug_assert!(lo <= hi);
        lo + self.below((hi - lo + 1) as u64) as i64
    }

    /// Uniform f64 in `[0, 1)` with 53-bit resolution (IEEE-exact).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0f64 / (1u64 << 53) as f64)
    }

    /// Approximately standard-normal deviate via CLT (sum of 12 uniforms
    /// minus 6). No transcendental functions → bit-identical across
    /// languages. Tails are clipped at ±6, irrelevant for our use.
    #[inline]
    pub fn normal_clt(&mut self) -> f64 {
        let mut acc = 0.0f64;
        for _ in 0..12 {
            acc += self.f64();
        }
        acc - 6.0
    }

    /// Fisher–Yates shuffle (in place), consuming one `below` per swap.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below((i + 1) as u64) as usize;
            xs.swap(i, j);
        }
    }

    /// A random permutation of `0..n`.
    pub fn permutation(&mut self, n: usize) -> Vec<u32> {
        let mut v: Vec<u32> = (0..n as u32).collect();
        self.shuffle(&mut v);
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_reference_vector() {
        // Reference values for seed 1234567 from the public SplitMix64
        // reference implementation.
        let mut sm = SplitMix64::new(1234567);
        let v: Vec<u64> = (0..3).map(|_| sm.next_u64()).collect();
        assert_eq!(v[0], 6457827717110365317);
        assert_eq!(v[1], 3203168211198807973);
        assert_eq!(v[2], 9817491932198370423);
    }

    #[test]
    fn xoshiro_is_deterministic_and_seed_sensitive() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        let mut c = Rng::new(43);
        let va: Vec<u64> = (0..16).map(|_| a.next_u64()).collect();
        let vb: Vec<u64> = (0..16).map(|_| b.next_u64()).collect();
        let vc: Vec<u64> = (0..16).map(|_| c.next_u64()).collect();
        assert_eq!(va, vb);
        assert_ne!(va, vc);
    }

    #[test]
    fn below_respects_bound() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            assert!(r.below(17) < 17);
        }
    }

    #[test]
    fn f64_in_unit_interval_and_well_spread() {
        let mut r = Rng::new(99);
        let mut mean = 0.0;
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
            mean += x;
        }
        mean /= 10_000.0;
        assert!((mean - 0.5).abs() < 0.02, "mean={mean}");
    }

    #[test]
    fn normal_clt_moments() {
        let mut r = Rng::new(5);
        let n = 20_000;
        let (mut m, mut v) = (0.0, 0.0);
        let xs: Vec<f64> = (0..n).map(|_| r.normal_clt()).collect();
        for &x in &xs {
            m += x;
        }
        m /= n as f64;
        for &x in &xs {
            v += (x - m) * (x - m);
        }
        v /= n as f64;
        assert!(m.abs() < 0.03, "mean={m}");
        assert!((v - 1.0).abs() < 0.05, "var={v}");
    }

    #[test]
    fn permutation_is_a_permutation() {
        let mut r = Rng::new(11);
        let p = r.permutation(100);
        let mut seen = vec![false; 100];
        for &x in &p {
            assert!(!seen[x as usize]);
            seen[x as usize] = true;
        }
        assert!(seen.iter().all(|&b| b));
    }

    #[test]
    fn for_item_streams_are_independent() {
        let a: Vec<u64> = {
            let mut r = Rng::for_item(1, 2, 3);
            (0..4).map(|_| r.next_u64()).collect()
        };
        let b: Vec<u64> = {
            let mut r = Rng::for_item(1, 2, 4);
            (0..4).map(|_| r.next_u64()).collect()
        };
        let a2: Vec<u64> = {
            let mut r = Rng::for_item(1, 2, 3);
            (0..4).map(|_| r.next_u64()).collect()
        };
        assert_eq!(a, a2);
        assert_ne!(a, b);
    }
}
