//! Minimal JSON value, parser and writer.
//!
//! serde is not available in the offline environment, so configs, reports
//! and the `.uln` metadata blob use this hand-rolled codec. Objects keep
//! insertion order (Vec of pairs) so emitted reports are stable.

use std::fmt::Write as _;

/// A JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    pub fn obj() -> Json {
        Json::Obj(Vec::new())
    }

    /// Insert/overwrite a key on an object. Panics on non-objects.
    pub fn set(&mut self, key: &str, val: Json) -> &mut Self {
        match self {
            Json::Obj(pairs) => {
                if let Some(p) = pairs.iter_mut().find(|(k, _)| k == key) {
                    p.1 = val;
                } else {
                    pairs.push((key.to_string(), val));
                }
            }
            _ => panic!("Json::set on non-object"),
        }
        self
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(pairs) => pairs.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// Compact serialization.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    /// Compact serialization appended into a caller-owned buffer —
    /// the alloc-free sibling of [`Json::to_string`] for hot response
    /// paths that reuse a grow-only `String` (see `coordinator/http.rs`).
    pub fn write_to(&self, out: &mut String) {
        self.write(out);
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    let _ = write!(out, "{}", *x as i64);
                } else {
                    let _ = write!(out, "{x}");
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(pairs) => {
                out.push('{');
                for (i, (k, v)) in pairs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    /// Parse a JSON document. Errors carry the byte offset.
    pub fn parse(text: &str) -> Result<Json, String> {
        let bytes = text.as_bytes();
        let mut p = Parser { b: bytes, i: 0, depth: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != bytes.len() {
            return Err(format!("trailing garbage at byte {}", p.i));
        }
        Ok(v)
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Deepest container nesting `parse` accepts. The parser recurses once per
/// `[`/`{`, so without a cap a hostile document of 100k open brackets
/// overflows the thread stack before any semantic validation can run.
const MAX_DEPTH: usize = 128;

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
    depth: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", c as char, self.i))
        }
    }

    fn literal(&mut self, s: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn nested(
        &mut self,
        f: impl FnOnce(&mut Self) -> Result<Json, String>,
    ) -> Result<Json, String> {
        if self.depth >= MAX_DEPTH {
            return Err(format!(
                "nesting deeper than {MAX_DEPTH} at byte {}",
                self.i
            ));
        }
        self.depth += 1;
        let v = f(self);
        self.depth -= 1;
        v
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.peek() {
            Some(b'n') => self.literal("null", Json::Null),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'"') => self.string().map(Json::Str),
            Some(b'[') => self.nested(Self::array),
            Some(b'{') => self.nested(Self::object),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(format!("unexpected byte at {}", self.i)),
        }
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err("bad \\u escape".into());
                            }
                            let hex = std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                .map_err(|_| "bad \\u escape")?;
                            let code =
                                u32::from_str_radix(hex, 16).map_err(|_| "bad \\u escape")?;
                            s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err(format!("bad escape at byte {}", self.i)),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 scalar.
                    let rest = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| "invalid utf-8 in string")?;
                    let c = rest.chars().next().unwrap();
                    s.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(
            self.peek(),
            Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-')
        ) {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| format!("bad number '{s}' at byte {start}"))
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.skip_ws();
            v.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(format!("expected ',' or ']' at byte {}", self.i)),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut pairs = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(pairs));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let v = self.value()?;
            pairs.push((k, v));
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(pairs));
                }
                _ => return Err(format!("expected ',' or '}}' at byte {}", self.i)),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_basic_document() {
        let mut doc = Json::obj();
        doc.set("name", Json::Str("uln-s".into()))
            .set("acc", Json::Num(0.962))
            .set("bits", Json::Num(2.0))
            .set("flags", Json::Arr(vec![Json::Bool(true), Json::Null]));
        let text = doc.to_string();
        let back = Json::parse(&text).unwrap();
        assert_eq!(doc, back);
    }

    #[test]
    fn parses_whitespace_and_nesting() {
        let j = Json::parse(" { \"a\" : [ 1 , 2.5 , { \"b\" : \"x\\ny\" } ] } ").unwrap();
        let arr = j.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr[0].as_f64(), Some(1.0));
        assert_eq!(arr[1].as_f64(), Some(2.5));
        assert_eq!(arr[2].get("b").unwrap().as_str(), Some("x\ny"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("{}extra").is_err());
        assert!(Json::parse("nul").is_err());
    }

    #[test]
    fn negative_and_exponent_numbers() {
        let j = Json::parse("[-3.25e2, -7]").unwrap();
        let a = j.as_arr().unwrap();
        assert_eq!(a[0].as_f64(), Some(-325.0));
        assert_eq!(a[1].as_f64(), Some(-7.0));
    }

    #[test]
    fn escapes_roundtrip() {
        let doc = Json::Str("quote\" slash\\ nl\n tab\t".into());
        let back = Json::parse(&doc.to_string()).unwrap();
        assert_eq!(doc, back);
    }

    #[test]
    fn hostile_nesting_errors_instead_of_overflowing_the_stack() {
        // 100k unmatched brackets: without the depth cap this recurses
        // 100k frames deep and aborts the process, not the test.
        let bomb = "[".repeat(100_000);
        let err = Json::parse(&bomb).unwrap_err();
        assert!(err.contains("nesting deeper than"), "got: {err}");
        // Same shape through objects.
        let obj_bomb = "{\"k\":".repeat(100_000);
        assert!(Json::parse(&obj_bomb).is_err());
    }

    #[test]
    fn reasonable_nesting_still_parses() {
        let depth = 100; // below MAX_DEPTH
        let doc = format!("{}1{}", "[".repeat(depth), "]".repeat(depth));
        let mut j = &Json::parse(&doc).unwrap();
        for _ in 0..depth {
            j = &j.as_arr().unwrap()[0];
        }
        assert_eq!(j.as_f64(), Some(1.0));
    }

    #[test]
    fn set_overwrites_existing_key() {
        let mut doc = Json::obj();
        doc.set("k", Json::Num(1.0));
        doc.set("k", Json::Num(2.0));
        assert_eq!(doc.get("k").unwrap().as_f64(), Some(2.0));
        if let Json::Obj(pairs) = &doc {
            assert_eq!(pairs.len(), 1);
        }
    }
}
