//! Bit-packed vector — the storage substrate for Bloom-filter tables and
//! thermometer-encoded inputs. Backed by `u64` words; hot-path methods are
//! `#[inline]` and branch-free where it matters.

/// A fixed-length vector of bits packed into `u64` words.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct BitVec {
    words: Vec<u64>,
    len: usize,
}

impl BitVec {
    /// All-zeros bit vector of `len` bits.
    pub fn zeros(len: usize) -> Self {
        Self {
            words: vec![0u64; len.div_ceil(64)],
            len,
        }
    }

    /// Build from a bool slice.
    pub fn from_bools(bits: &[bool]) -> Self {
        let mut v = Self::zeros(bits.len());
        for (i, &b) in bits.iter().enumerate() {
            if b {
                v.set(i);
            }
        }
        v
    }

    /// Build from raw words (trailing bits beyond `len` must be zero).
    pub fn from_words(words: Vec<u64>, len: usize) -> Self {
        debug_assert!(words.len() == len.div_ceil(64));
        Self { words, len }
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.len
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    #[inline]
    pub fn get(&self, i: usize) -> bool {
        debug_assert!(i < self.len);
        (self.words[i >> 6] >> (i & 63)) & 1 == 1
    }

    #[inline]
    pub fn set(&mut self, i: usize) {
        debug_assert!(i < self.len);
        self.words[i >> 6] |= 1u64 << (i & 63);
    }

    #[inline]
    pub fn clear(&mut self, i: usize) {
        debug_assert!(i < self.len);
        self.words[i >> 6] &= !(1u64 << (i & 63));
    }

    #[inline]
    pub fn assign(&mut self, i: usize, v: bool) {
        if v {
            self.set(i)
        } else {
            self.clear(i)
        }
    }

    /// Number of set bits.
    pub fn count_ones(&self) -> usize {
        self.words.iter().map(|w| w.count_ones() as usize).sum()
    }

    /// Raw word storage (read-only).
    #[inline]
    pub fn words(&self) -> &[u64] {
        &self.words
    }

    /// Zero every bit (keeps capacity).
    #[inline]
    pub fn clear_all(&mut self) {
        self.words.iter_mut().for_each(|w| *w = 0);
    }

    /// OR a mask into word `w` (hot-path run setter; bounds-checked).
    #[inline]
    pub fn or_word(&mut self, w: usize, mask: u64) {
        self.words[w] |= mask;
    }

    /// Bytes of storage actually used (for model-size accounting we use
    /// `len/8` — the hardware stores exactly `len` bits).
    pub fn storage_bits(&self) -> usize {
        self.len
    }

    /// Iterate over bits as bools.
    pub fn iter(&self) -> impl Iterator<Item = bool> + '_ {
        (0..self.len).map(move |i| self.get(i))
    }

    /// In-place OR with another vector of the same length.
    pub fn or_assign(&mut self, other: &BitVec) {
        assert_eq!(self.len, other.len);
        for (a, b) in self.words.iter_mut().zip(other.words.iter()) {
            *a |= *b;
        }
    }

    /// In-place AND with another vector of the same length.
    pub fn and_assign(&mut self, other: &BitVec) {
        assert_eq!(self.len, other.len);
        for (a, b) in self.words.iter_mut().zip(other.words.iter()) {
            *a &= *b;
        }
    }

    /// Serialize to little-endian bytes (length is carried externally).
    pub fn to_le_bytes(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(self.words.len() * 8);
        for w in &self.words {
            out.extend_from_slice(&w.to_le_bytes());
        }
        out
    }

    /// Deserialize from little-endian bytes produced by [`to_le_bytes`].
    pub fn from_le_bytes(bytes: &[u8], len: usize) -> Self {
        let nwords = len.div_ceil(64);
        assert!(bytes.len() >= nwords * 8, "short bitvec payload");
        let words = (0..nwords)
            .map(|i| u64::from_le_bytes(bytes[i * 8..i * 8 + 8].try_into().unwrap()))
            .collect();
        Self { words, len }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn set_get_clear_roundtrip() {
        let mut v = BitVec::zeros(130);
        assert_eq!(v.len(), 130);
        v.set(0);
        v.set(63);
        v.set(64);
        v.set(129);
        assert!(v.get(0) && v.get(63) && v.get(64) && v.get(129));
        assert!(!v.get(1) && !v.get(65) && !v.get(128));
        assert_eq!(v.count_ones(), 4);
        v.clear(64);
        assert!(!v.get(64));
        assert_eq!(v.count_ones(), 3);
    }

    #[test]
    fn from_bools_matches_gets() {
        let mut rng = Rng::new(3);
        let bits: Vec<bool> = (0..200).map(|_| rng.below(2) == 1).collect();
        let v = BitVec::from_bools(&bits);
        for (i, &b) in bits.iter().enumerate() {
            assert_eq!(v.get(i), b);
        }
        assert_eq!(v.count_ones(), bits.iter().filter(|&&b| b).count());
    }

    #[test]
    fn byte_roundtrip_preserves_bits() {
        let mut rng = Rng::new(9);
        let bits: Vec<bool> = (0..777).map(|_| rng.below(2) == 1).collect();
        let v = BitVec::from_bools(&bits);
        let bytes = v.to_le_bytes();
        let v2 = BitVec::from_le_bytes(&bytes, 777);
        assert_eq!(v, v2);
    }

    #[test]
    fn or_and_semantics() {
        let a = BitVec::from_bools(&[true, false, true, false]);
        let b = BitVec::from_bools(&[true, true, false, false]);
        let mut o = a.clone();
        o.or_assign(&b);
        assert_eq!(o, BitVec::from_bools(&[true, true, true, false]));
        let mut n = a.clone();
        n.and_assign(&b);
        assert_eq!(n, BitVec::from_bools(&[true, false, false, false]));
    }
}
