//! Hand-rolled property-testing harness (proptest is unavailable offline).
//!
//! Deterministic: every case is generated from a seed derived from the
//! property name, so failures are reproducible by construction. On failure
//! the harness performs a light "shrink" pass by re-running earlier cases
//! with smaller size hints and reports the smallest failing seed/size.

use crate::util::rng::Rng;

/// Configuration for a property run.
pub struct Config {
    pub cases: usize,
    /// Size hint grows linearly from `min_size` to `max_size` across cases;
    /// generators use it to scale structure (lengths, magnitudes).
    pub min_size: usize,
    pub max_size: usize,
    pub seed: u64,
}

impl Default for Config {
    fn default() -> Self {
        Self { cases: 128, min_size: 1, max_size: 64, seed: 0x5EED }
    }
}

/// Run a property: `gen` builds a case from (rng, size), `prop` returns
/// `Err(msg)` on violation. Panics with a reproducible report on failure.
///
/// The `PROPTEST_CASES` environment variable overrides `cfg.cases` for
/// EVERY property in the run — the nightly CI profile sets
/// `PROPTEST_CASES=256` to sweep far past the PR-gate budgets. Case
/// seeds stay a pure function of (property name, case index), so any
/// nightly failure reproduces locally with the same variable set.
pub fn check<T: std::fmt::Debug>(
    name: &str,
    cfg: &Config,
    mut gen: impl FnMut(&mut Rng, usize) -> T,
    mut prop: impl FnMut(&T) -> Result<(), String>,
) {
    let cases = std::env::var("PROPTEST_CASES")
        .ok()
        .and_then(|v| v.parse::<usize>().ok())
        .filter(|&c| c > 0)
        .unwrap_or(cfg.cases);
    let name_seed: u64 = name
        .bytes()
        .fold(0xcbf29ce484222325u64, |h, b| (h ^ b as u64).wrapping_mul(0x100000001b3));
    let mut failures: Vec<(usize, usize, String, String)> = Vec::new();
    for case in 0..cases {
        let size = cfg.min_size
            + (cfg.max_size - cfg.min_size) * case / cases.max(1);
        let mut rng = Rng::for_item(cfg.seed ^ name_seed, 0x1234, case as u64);
        let input = gen(&mut rng, size.max(cfg.min_size));
        if let Err(msg) = prop(&input) {
            failures.push((case, size, msg, format!("{input:?}")));
            // Keep scanning a few more cases to find a smaller failure.
            if failures.len() >= 4 {
                break;
            }
        }
    }
    if let Some((case, size, msg, input)) = failures
        .iter()
        .min_by_key(|(_, size, _, _)| *size)
    {
        panic!(
            "property '{name}' failed (case {case}, size {size}, seed {:#x}):\n  {msg}\n  \
             smallest failing input: {input}",
            cfg.seed ^ name_seed
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check(
            "reverse-reverse-is-identity",
            &Config::default(),
            |rng, size| {
                (0..size).map(|_| rng.next_u32()).collect::<Vec<_>>()
            },
            |v| {
                let mut r = v.clone();
                r.reverse();
                r.reverse();
                if r == *v { Ok(()) } else { Err("mismatch".into()) }
            },
        );
    }

    #[test]
    #[should_panic(expected = "property 'always-fails' failed")]
    fn failing_property_panics_with_report() {
        check(
            "always-fails",
            &Config { cases: 8, ..Config::default() },
            |rng, _| rng.next_u32(),
            |_| Err("nope".into()),
        );
    }

    #[test]
    fn deterministic_across_runs() {
        let collect = || {
            let mut out = Vec::new();
            check(
                "collect",
                &Config { cases: 4, ..Config::default() },
                |rng, size| (0..size).map(|_| rng.next_u64()).collect::<Vec<_>>(),
                |v| {
                    out.push(v.iter().fold(0u64, |a, b| a.wrapping_add(*b)));
                    Ok(())
                },
            );
            out
        };
        assert_eq!(collect(), collect());
    }
}
