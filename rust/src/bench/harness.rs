//! Minimal timing harness: warmup, fixed repetitions, mean/std/percentiles.

use crate::util::stats::{percentile, OnlineStats};
use std::time::Instant;

/// Timing outcome of one benchmark.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_ns: f64,
    pub stddev_ns: f64,
    pub p50_ns: f64,
    pub p99_ns: f64,
    /// items processed per iteration (for throughput reporting)
    pub items_per_iter: f64,
}

impl BenchResult {
    pub fn throughput_per_sec(&self) -> f64 {
        if self.mean_ns <= 0.0 {
            0.0
        } else {
            self.items_per_iter / (self.mean_ns * 1e-9)
        }
    }

    pub fn summary(&self) -> String {
        format!(
            "{:<32} {:>12.0} ns/iter (±{:.0}) p50={:.0} p99={:.0} → {:>12.0} items/s",
            self.name,
            self.mean_ns,
            self.stddev_ns,
            self.p50_ns,
            self.p99_ns,
            self.throughput_per_sec()
        )
    }
}

/// Time `f` with `warmup` throwaway runs then `iters` measured runs.
/// `items_per_iter` feeds the derived throughput number.
pub fn bench_fn(
    name: &str,
    warmup: usize,
    iters: usize,
    items_per_iter: f64,
    mut f: impl FnMut(),
) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut stats = OnlineStats::new();
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        let ns = t0.elapsed().as_nanos() as f64;
        stats.push(ns);
        samples.push(ns);
    }
    BenchResult {
        name: name.to_string(),
        iters,
        mean_ns: stats.mean(),
        stddev_ns: stats.stddev(),
        p50_ns: percentile(&mut samples.clone(), 0.5),
        p99_ns: percentile(&mut samples, 0.99),
        items_per_iter,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something_positive() {
        let mut acc = 0u64;
        let r = bench_fn("spin", 2, 16, 1000.0, || {
            for i in 0..1000u64 {
                acc = acc.wrapping_add(i * i);
            }
        });
        assert!(acc > 0);
        assert!(r.mean_ns > 0.0);
        assert!(r.p99_ns >= r.p50_ns);
        assert!(r.throughput_per_sec() > 0.0);
    }
}
