//! Shared row-generation for the paper's tables/figures: Table II (FPGA vs
//! FINN) and Table III (ASIC vs Bit Fusion) rows are produced here once and
//! consumed by `table2_finn`, `table3_bitfusion`, `fig11_pareto` and
//! `fig12_efficiency`.

use crate::hw::arch::{AcceleratorInstance, Target};
use crate::hw::{asic, bitfusion, finn, fpga};
use crate::model::ensemble::UleenModel;
use crate::util::json::Json;

/// One FPGA comparison row (Table II / Fig 11).
#[derive(Clone, Debug)]
pub struct FpgaRow {
    pub name: String,
    pub is_baseline: bool,
    pub latency_us: f64,
    pub kips: f64,
    pub power_w: f64,
    pub uj_b1: f64,
    pub uj_binf: f64,
    pub luts: f64,
    pub bram: f64,
    pub accuracy: f64,
}

/// One ASIC comparison row (Table III / Fig 12).
#[derive(Clone, Debug)]
pub struct AsicRow {
    pub name: String,
    pub is_baseline: bool,
    pub kips: f64,
    pub power_w: f64,
    pub nj_per_inf: f64,
    pub area_mm2: f64,
    pub accuracy: f64,
}

/// ULEEN zoo rows on the FPGA target.
pub fn uleen_fpga_rows(models: &[(UleenModel, Json)]) -> Vec<FpgaRow> {
    models
        .iter()
        .map(|(model, meta)| {
            let mut inst = AcceleratorInstance::generate(model, Target::Fpga);
            let rep = fpga::implement(&mut inst);
            FpgaRow {
                name: model.name.to_uppercase(),
                is_baseline: false,
                latency_us: rep.latency_us,
                kips: rep.throughput_kips,
                power_w: rep.power_w,
                uj_b1: rep.uj_per_inf_single,
                uj_binf: rep.uj_per_inf_steady,
                luts: rep.luts as f64,
                bram: rep.bram as f64,
                accuracy: crate::bench::meta_accuracy(meta),
            }
        })
        .collect()
}

/// FINN baseline rows. `bnn_accs` overrides accuracy with our
/// SynthMNIST-trained BNN accuracies when available (zoo.json), else the
/// published MNIST accuracy is reported (documented substitution).
pub fn finn_fpga_rows(bnn_accs: Option<&[f64; 3]>) -> Vec<FpgaRow> {
    [finn::SFC, finn::MFC, finn::LFC]
        .iter()
        .enumerate()
        .map(|(i, t)| {
            let rep = finn::implement(t, 200.0);
            let pubd = finn::published(t);
            FpgaRow {
                name: t.name.to_string(),
                is_baseline: true,
                latency_us: pubd.latency_us.unwrap_or(rep.latency_us),
                kips: rep.kips,
                power_w: rep.power_w,
                uj_b1: rep.uj_per_inf_single,
                uj_binf: rep.uj_per_inf_steady,
                luts: pubd.luts.unwrap_or(7.2 * rep.synaptic_ops as f64 / rep.ii_cycles as f64),
                bram: pubd.bram.unwrap_or(0.0),
                accuracy: bnn_accs.map(|a| a[i]).unwrap_or(pubd.mnist_accuracy),
            }
        })
        .collect()
}

/// ULEEN zoo rows on the ASIC target.
pub fn uleen_asic_rows(models: &[(UleenModel, Json)]) -> Vec<AsicRow> {
    models
        .iter()
        .map(|(model, meta)| {
            let inst = AcceleratorInstance::generate(model, Target::Asic);
            let rep = asic::implement(&inst);
            AsicRow {
                name: model.name.to_uppercase(),
                is_baseline: false,
                kips: rep.throughput_kips,
                power_w: rep.power_w,
                nj_per_inf: rep.nj_per_inf,
                area_mm2: rep.area_mm2,
                accuracy: crate::bench::meta_accuracy(meta),
            }
        })
        .collect()
}

/// Bit Fusion baseline rows (analytic model at 45nm/500MHz).
pub fn bitfusion_asic_rows() -> Vec<AsicRow> {
    [bitfusion::BF8, bitfusion::BF16, bitfusion::BF32]
        .iter()
        .map(|c| {
            let rep = bitfusion::implement(c, 500.0);
            let pubd = bitfusion::published(c);
            AsicRow {
                name: c.name.to_string(),
                is_baseline: true,
                kips: rep.kips,
                power_w: rep.power_w,
                nj_per_inf: rep.nj_per_inf,
                area_mm2: rep.area_mm2,
                accuracy: pubd.mnist_accuracy,
            }
        })
        .collect()
}

/// Load the ULN-S/M/L zoo from artifacts.
pub fn load_zoo() -> crate::Result<Vec<(UleenModel, Json)>> {
    ["uln_s.uln", "uln_m.uln", "uln_l.uln"]
        .iter()
        .map(|f| crate::bench::load_model(f))
        .collect()
}

/// BNN accuracies from zoo.json if the python build trained them.
pub fn bnn_accuracies() -> Option<[f64; 3]> {
    let path = crate::bench::artifacts_dir().join("zoo.json");
    let text = std::fs::read_to_string(path).ok()?;
    let j = Json::parse(&text).ok()?;
    let b = j.get("bnn")?;
    Some([
        b.get("sfc")?.as_f64()?,
        b.get("mfc")?.as_f64()?,
        b.get("lfc")?.as_f64()?,
    ])
}
