//! Bench harness (criterion is unavailable offline): wall-clock timing with
//! warmup + repetitions, and markdown/CSV table emitters shared by every
//! `rust/benches/*` target that regenerates a paper table or figure.

pub mod harness;
pub mod paper;
pub mod table;

pub use harness::{bench_fn, BenchResult};
pub use table::Table;

use std::path::{Path, PathBuf};

/// Locate `artifacts/` from a bench binary (cwd = package root under
/// `cargo bench`; fall back to CARGO_MANIFEST_DIR).
pub fn artifacts_dir() -> PathBuf {
    for cand in [
        PathBuf::from("artifacts"),
        Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts"),
    ] {
        if cand.is_dir() {
            return cand;
        }
    }
    PathBuf::from("artifacts")
}

/// Load a `.uln` plus metadata, with a friendly error pointing at `make
/// artifacts` when the file is missing.
pub fn load_model(
    rel: &str,
) -> crate::Result<(crate::model::ensemble::UleenModel, crate::util::json::Json)> {
    let path = artifacts_dir().join(rel);
    if !path.exists() {
        anyhow::bail!(
            "artifact {} missing — run `make artifacts` first",
            path.display()
        );
    }
    crate::model::uln_format::load(&path)
}

/// Metadata accuracy field (test_accuracy) of a model artifact.
pub fn meta_accuracy(meta: &crate::util::json::Json) -> f64 {
    meta.get("test_accuracy").and_then(|j| j.as_f64()).unwrap_or(f64::NAN)
}
