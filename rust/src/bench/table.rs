//! Markdown/CSV table emitter for the paper-regeneration benches.

/// A simple column-aligned table with a title, printed as markdown.
#[derive(Clone, Debug)]
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Self {
        Self {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(cells.len(), self.headers.len(), "column count mismatch");
        self.rows.push(cells);
        self
    }

    pub fn to_markdown(&self) -> String {
        let ncol = self.headers.len();
        let mut width = vec![0usize; ncol];
        for (i, h) in self.headers.iter().enumerate() {
            width[i] = width[i].max(h.len());
        }
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                width[i] = width[i].max(c.len());
            }
        }
        let mut s = format!("\n### {}\n\n", self.title);
        let fmt_row = |cells: &[String], width: &[usize]| -> String {
            let mut line = String::from("|");
            for (c, w) in cells.iter().zip(width) {
                line.push_str(&format!(" {c:<w$} |"));
            }
            line.push('\n');
            line
        };
        s.push_str(&fmt_row(&self.headers, &width));
        s.push('|');
        for w in &width {
            s.push_str(&format!("{}|", "-".repeat(w + 2)));
        }
        s.push('\n');
        for r in &self.rows {
            s.push_str(&fmt_row(r, &width));
        }
        s
    }

    pub fn to_csv(&self) -> String {
        let mut s = self.headers.join(",");
        s.push('\n');
        for r in &self.rows {
            s.push_str(&r.join(","));
            s.push('\n');
        }
        s
    }

    pub fn print(&self) {
        println!("{}", self.to_markdown());
    }
}

/// Format helpers for consistent table cells.
pub fn f1(x: f64) -> String {
    format!("{x:.1}")
}
pub fn f2(x: f64) -> String {
    format!("{x:.2}")
}
pub fn f3(x: f64) -> String {
    format!("{x:.3}")
}
pub fn f4(x: f64) -> String {
    format!("{x:.4}")
}
pub fn i0(x: f64) -> String {
    format!("{x:.0}")
}
pub fn pct(x: f64) -> String {
    format!("{:.2}", x * 100.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned_markdown() {
        let mut t = Table::new("Demo", &["name", "value"]);
        t.row(vec!["alpha".into(), "1".into()]);
        t.row(vec!["b".into(), "12345".into()]);
        let md = t.to_markdown();
        assert!(md.contains("### Demo"));
        assert!(md.contains("| alpha | 1     |"));
        let csv = t.to_csv();
        assert!(csv.starts_with("name,value\n"));
    }

    #[test]
    #[should_panic(expected = "column count mismatch")]
    fn rejects_ragged_rows() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(vec!["only-one".into()]);
    }
}
