//! `uleen` — the Layer-3 coordinator binary.
//!
//! Subcommands cover the full lifecycle: dataset generation, one-shot
//! training, evaluation, model inspection, hardware simulation and the
//! serving loop. Multi-shot-trained models arrive as `artifacts/*.uln`
//! from the Python compile path (`make artifacts`).

// Same deliberate-idiom allowances as lib.rs (separate crate root, so
// the attribute must be repeated); CI denies all other clippy warnings
// on lib/bin targets.
#![allow(
    clippy::needless_range_loop,
    clippy::too_many_arguments,
    clippy::type_complexity,
    clippy::collapsible_else_if
)]

use std::path::{Path, PathBuf};

use uleen::data::{self, synth_mnist, synth_uci, uci_specs};
use uleen::encoding::thermometer::ThermometerKind;
use uleen::model::uln_format;
use uleen::train::oneshot::{train_oneshot, OneShotConfig};
use uleen::util::cli::{usage, Args, OptSpec};
use uleen::util::json::Json;

fn opt_specs() -> Vec<OptSpec> {
    vec![
        OptSpec { name: "dataset", takes_value: true, help: "dataset name (synth_mnist, iris, letter, ...)" },
        OptSpec { name: "seed", takes_value: true, help: "PRNG seed (default 2024)" },
        OptSpec { name: "out", takes_value: true, help: "output file" },
        OptSpec { name: "out-dir", takes_value: true, help: "output directory" },
        OptSpec { name: "model", takes_value: true, help: "path to a .uln model" },
        OptSpec { name: "inputs", takes_value: true, help: "inputs per filter (one-shot train)" },
        OptSpec { name: "entries", takes_value: true, help: "entries per filter (one-shot train)" },
        OptSpec { name: "bits", takes_value: true, help: "thermometer bits per input" },
        OptSpec { name: "hashes", takes_value: true, help: "hash functions per filter (default 2)" },
        OptSpec { name: "linear", takes_value: false, help: "linear thermometer (default gaussian)" },
        OptSpec { name: "mnist-train", takes_value: true, help: "SynthMNIST train samples (default 8000)" },
        OptSpec { name: "mnist-test", takes_value: true, help: "SynthMNIST test samples (default 2000)" },
        OptSpec { name: "prune", takes_value: true, help: "pruning ratio after one-shot train" },
        OptSpec { name: "batch", takes_value: true, help: "serving batch size (default 16)" },
        OptSpec { name: "requests", takes_value: true, help: "serving request count (default 10000)" },
        OptSpec { name: "workers", takes_value: true, help: "serving worker threads (default 4)" },
        OptSpec { name: "shards", takes_value: true, help: "serve with one sharded engine over N threads (default: one shard per detected core; pass --workers to keep per-worker engines instead); with --zoo, runs the cascade × shard composition" },
        OptSpec { name: "zoo", takes_value: true, help: "serve a tiered model zoo: comma-separated presets (s,m,l) or .uln paths, small → large" },
        OptSpec { name: "cascade-margin", takes_value: true, help: "zoo cascade escalation threshold on the normalized top1-top2 margin (default 0.05)" },
        OptSpec { name: "target-p99-ms", takes_value: true, help: "arm the latency autopilot: AIMD-tune cascade margin + batcher dwell to hold this p99 (serve)" },
        OptSpec { name: "hlo", takes_value: true, help: "HLO artifact for the PJRT runtime" },
        OptSpec { name: "listen", takes_value: true, help: "serve over HTTP on ADDR (e.g. 127.0.0.1:8080; port 0 picks one) instead of synthetic load" },
        OptSpec { name: "api-key", takes_value: true, help: "require this key on /metrics and /v1/classify (--listen mode)" },
        OptSpec { name: "rate-rps", takes_value: true, help: "per-client token-bucket rate in req/s, 0 = unlimited (--listen mode)" },
        OptSpec { name: "duration-secs", takes_value: true, help: "stop --listen serving after N seconds, 0 = until killed (default 0)" },
        OptSpec { name: "max-body-kib", takes_value: true, help: "HTTP request body cap in KiB (default 1024, --listen mode)" },
        OptSpec { name: "target", takes_value: true, help: "hardware target: fpga | asic" },
        OptSpec { name: "verbose", takes_value: false, help: "extra logging" },
    ]
}

fn subcommands() -> Vec<(&'static str, &'static str)> {
    vec![
        ("gen-data", "generate all synthetic datasets to --out-dir as .uds"),
        ("checksum", "print the checksum of --dataset (cross-language check)"),
        ("train-oneshot", "train a one-shot model on --dataset, save to --out"),
        ("eval", "evaluate --model on --dataset"),
        ("info", "describe a .uln model"),
        ("simulate", "hardware-simulate --model on --target (fpga|asic)"),
        ("serve", "run the serving coordinator on --model (or a tiered zoo: --zoo s,m,l); --listen ADDR exposes it over HTTP"),
    ]
}

/// Materialize a dataset by name (the shared resolver lives in the
/// library so the serve loop uses identical name handling).
fn load_dataset(name: &str, seed: u64, mnist_train: usize, mnist_test: usize) -> anyhow::Result<data::Dataset> {
    data::load_by_name(name, seed, mnist_train, mnist_test)
}

fn cmd_gen_data(args: &Args) -> anyhow::Result<()> {
    let out_dir = PathBuf::from(args.get_or("out-dir", "artifacts/data"));
    std::fs::create_dir_all(&out_dir)?;
    let seed = args.get_u64("seed", 2024).map_err(anyhow::Error::msg)?;
    let mn_train = args.get_usize("mnist-train", 8000).map_err(anyhow::Error::msg)?;
    let mn_test = args.get_usize("mnist-test", 2000).map_err(anyhow::Error::msg)?;
    let ds = synth_mnist(seed, mn_train, mn_test);
    data::io::save(&ds, &out_dir.join("synth_mnist.uds"))?;
    println!("synth_mnist: checksum={:#018x}", ds.checksum());
    for spec in uci_specs() {
        let ds = synth_uci(seed, spec);
        data::io::save(&ds, &out_dir.join(format!("synth_{}.uds", spec.name)))?;
        println!("synth_{}: checksum={:#018x}", spec.name, ds.checksum());
    }
    Ok(())
}

fn cmd_checksum(args: &Args) -> anyhow::Result<()> {
    let name = args.get("dataset").ok_or_else(|| anyhow::anyhow!("--dataset required"))?;
    let seed = args.get_u64("seed", 2024).map_err(anyhow::Error::msg)?;
    let mn_train = args.get_usize("mnist-train", 8000).map_err(anyhow::Error::msg)?;
    let mn_test = args.get_usize("mnist-test", 2000).map_err(anyhow::Error::msg)?;
    let ds = load_dataset(name, seed, mn_train, mn_test)?;
    println!("{:#018x}", ds.checksum());
    Ok(())
}

fn cmd_train_oneshot(args: &Args) -> anyhow::Result<()> {
    let name = args.get("dataset").ok_or_else(|| anyhow::anyhow!("--dataset required"))?;
    let seed = args.get_u64("seed", 2024).map_err(anyhow::Error::msg)?;
    let mn_train = args.get_usize("mnist-train", 8000).map_err(anyhow::Error::msg)?;
    let mn_test = args.get_usize("mnist-test", 2000).map_err(anyhow::Error::msg)?;
    let ds = load_dataset(name, seed, mn_train, mn_test)?;
    let cfg = OneShotConfig {
        inputs_per_filter: args.get_usize("inputs", 16).map_err(anyhow::Error::msg)?,
        entries_per_filter: args.get_usize("entries", 256).map_err(anyhow::Error::msg)?,
        k_hashes: args.get_usize("hashes", 2).map_err(anyhow::Error::msg)?,
        therm_bits: args.get_usize("bits", 4).map_err(anyhow::Error::msg)?,
        therm_kind: if args.flag("linear") { ThermometerKind::Linear } else { ThermometerKind::Gaussian },
        val_fraction: 0.1,
        seed,
    };
    let (mut model, report) = train_oneshot(&ds, &cfg);
    let prune_ratio = args.get_f64("prune", 0.0).map_err(anyhow::Error::msg)?;
    if prune_ratio > 0.0 {
        let reports = uleen::train::prune::prune_model(&mut model, &ds, prune_ratio);
        for r in &reports {
            println!(
                "pruned {} -> {} filters ({:.1} -> {:.1} KiB)",
                r.filters_before, r.filters_after, r.size_kib_before, r.size_kib_after
            );
        }
    }
    let conf = model.evaluate(&ds.test_x, &ds.test_y, ds.num_features);
    println!(
        "{name}: bleach={} val_acc={:.4} test_acc={:.4} size={:.2} KiB",
        report.bleach,
        report.val_accuracy,
        conf.accuracy(),
        model.size_kib()
    );
    if let Some(out) = args.get("out") {
        let mut meta = Json::obj();
        meta.set("name", Json::Str(model.name.clone()))
            .set("dataset", Json::Str(name.to_string()))
            .set("test_accuracy", Json::Num(conf.accuracy()))
            .set("bleach", Json::Num(report.bleach as f64))
            .set("trainer", Json::Str("oneshot-rust".into()));
        uln_format::save(&model, &meta, Path::new(out))?;
        println!("saved {out}");
    }
    Ok(())
}

fn cmd_eval(args: &Args) -> anyhow::Result<()> {
    let model_path = args.get("model").ok_or_else(|| anyhow::anyhow!("--model required"))?;
    let name = args.get("dataset").ok_or_else(|| anyhow::anyhow!("--dataset required"))?;
    let seed = args.get_u64("seed", 2024).map_err(anyhow::Error::msg)?;
    let mn_train = args.get_usize("mnist-train", 8000).map_err(anyhow::Error::msg)?;
    let mn_test = args.get_usize("mnist-test", 2000).map_err(anyhow::Error::msg)?;
    let ds = load_dataset(name, seed, mn_train, mn_test)?;
    let (model, _) = uln_format::load(Path::new(model_path))?;
    let conf = model.evaluate(&ds.test_x, &ds.test_y, ds.num_features);
    println!(
        "{}: test_acc={:.4} size={:.2} KiB ({} submodels)",
        model.name,
        conf.accuracy(),
        model.size_kib(),
        model.submodels.len()
    );
    Ok(())
}

fn cmd_info(args: &Args) -> anyhow::Result<()> {
    let model_path = args.get("model").ok_or_else(|| anyhow::anyhow!("--model required"))?;
    let (model, meta) = uln_format::load(Path::new(model_path))?;
    println!("model: {}", model.name);
    println!("meta:  {}", meta.to_string());
    println!(
        "encoder: {:?} x{} bits ({} inputs, {} encoded bits)",
        model.encoder.kind,
        model.encoder.bits,
        model.encoder.num_inputs,
        model.encoded_bits()
    );
    for (i, sm) in model.submodels.iter().enumerate() {
        println!(
            "  SM{i}: n={} entries={} k={} filters={} kept={} size={:.2} KiB bias={:?}",
            sm.cfg.inputs_per_filter,
            sm.cfg.entries_per_filter,
            sm.cfg.k_hashes,
            sm.cfg.num_filters(),
            sm.discriminators.iter().map(|d| d.kept()).sum::<usize>(),
            sm.size_kib(),
            sm.bias
        );
    }
    println!("total size: {:.2} KiB", model.size_kib());
    Ok(())
}

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let spec = opt_specs();
    let args = match Args::parse(&argv, &spec) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}\n\n{}", usage("uleen", &subcommands(), &spec));
            std::process::exit(2);
        }
    };
    let result = match args.subcommand.as_str() {
        "gen-data" => cmd_gen_data(&args),
        "checksum" => cmd_checksum(&args),
        "train-oneshot" => cmd_train_oneshot(&args),
        "eval" => cmd_eval(&args),
        "info" => cmd_info(&args),
        "simulate" => uleen::hw::cli::cmd_simulate(&args),
        "serve" => uleen::coordinator::cli::cmd_serve(&args),
        "" => {
            println!("{}", usage("uleen", &subcommands(), &spec));
            Ok(())
        }
        other => {
            eprintln!("unknown subcommand '{other}'\n\n{}", usage("uleen", &subcommands(), &spec));
            std::process::exit(2);
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}
