//! Hardware co-design models (paper §III-C, §IV-B, §V-C/D).
//!
//! We cannot run Vivado or Cadence in this environment (DESIGN.md §2), so
//! the paper's FPGA/ASIC numbers are regenerated from first-principles
//! models of the architectures involved:
//!
//! * [`arch`] — sizes a ULEEN accelerator instance from a trained model:
//!   hash units, lookup units, adder trees, bus interface (Figs 8/9).
//! * [`pipeline`] — cycle-level simulator of the lockstep pipeline; the
//!   analytic latency/throughput numbers are *verified against* simulated
//!   cycles in tests.
//! * [`fpga`] — Zynq Z-7045-class resource (LUT/BRAM) + power model.
//! * [`asic`] — FreePDK45-class energy/area model.
//! * [`finn`] — the FINN SFC/MFC/LFC BNN baseline (Table II, Fig 11).
//! * [`bitfusion`] — the Bit Fusion ternary-LeNet-5 baseline (Table III,
//!   Fig 12).
//!
//! Calibration constants are documented inline next to their source.

pub mod arch;
pub mod asic;
pub mod bitfusion;
pub mod cli;
pub mod finn;
pub mod fpga;
pub mod pipeline;

pub use arch::{AcceleratorConfig, AcceleratorInstance, Target};
pub use pipeline::{simulate_stream, PipelineReport};
