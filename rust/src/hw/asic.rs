//! 45 nm ASIC energy/area model (FreePDK45-class, paper §IV-B / Table III).
//!
//! Event-energy accounting: every hash gate toggle, table-bit read and
//! adder-bit op is charged a 45 nm-typical energy; area is a gate-equiv
//! inventory at a 45 nm standard-cell density. The two calibration
//! constants (`PJ_PER_GATE`, `UM2_PER_GATE`) were fit against the paper's
//! ULN-S row of Table III (0.84 W at 55.6 MIPS → ~15 nJ/inf; 0.61 mm²)
//! and held fixed for every other design point.

use crate::hw::arch::AcceleratorInstance;

/// Energy per two-input gate event at 45 nm, pJ. This is a CHIP-LEVEL
/// amortized figure (gate + clock tree + pipeline registers + wiring — the
/// raw 45 nm gate energy is ~0.004 pJ; full-chip accounting runs ~25× that),
/// fit on the paper's ULN-S ASIC row (0.84 W @ 55.6 MIPS ⇒ ~15 nJ/inf).
const PJ_PER_GATE: f64 = 0.10;
/// Energy per table-bit read (cell + mux tree + wordline share), pJ.
const PJ_PER_TABLE_BIT: f64 = 0.40;
/// Layout area per gate-equivalent, µm² (std-cell + routing + registers;
/// fit on ULN-S's published 0.61 mm²).
const UM2_PER_GATE: f64 = 8.0;
/// Leakage fraction of total power at the paper's operating points.
const LEAKAGE_FRAC: f64 = 0.08;

#[derive(Clone, Debug)]
pub struct AsicReport {
    pub freq_mhz: f64,
    pub throughput_kips: f64,
    pub latency_us: f64,
    pub power_w: f64,
    pub nj_per_inf: f64,
    pub area_mm2: f64,
}

/// Dynamic energy of ONE inference, in pJ.
pub fn energy_pj_per_inference(inst: &AcceleratorInstance) -> f64 {
    let mut pj = 0f64;
    for sm in &inst.submodels {
        // hashing: every hash = out_bits × (2n-1) gate events
        let gates_per_hash = sm.out_bits as f64 * (2.0 * sm.inputs_per_filter as f64 - 1.0);
        pj += sm.hashes_per_inference as f64 * gates_per_hash * PJ_PER_GATE;
        // lookups: k reads per kept filter + AND accumulate
        pj += sm.lookup_units as f64
            * sm.k_hashes as f64
            * (PJ_PER_TABLE_BIT + PJ_PER_GATE);
        // adder trees: (NF-1) adds per class, mean width log2/2+1
        let nf = sm.num_filters as f64;
        let width = (nf.log2() / 2.0 + 1.0).max(1.0);
        pj += inst.num_classes as f64 * (nf - 1.0) * width * PJ_PER_GATE;
    }
    // bus receive + decompress + argmax
    pj += inst.input_bits_per_inference as f64 * 0.02; // I/O pad + deser
    pj += inst.encoded_bits as f64 * PJ_PER_GATE; // decompressor
    pj += inst.num_classes as f64 * 24.0 * PJ_PER_GATE;
    pj / (1.0 - LEAKAGE_FRAC)
}

/// Gate-equivalent area inventory (shares the fpga gate model's shape).
pub fn area_mm2(inst: &AcceleratorInstance) -> f64 {
    let mut gates = 0f64;
    for sm in &inst.submodels {
        gates += sm.out_bits as f64
            * (2.0 * sm.inputs_per_filter as f64 - 1.0)
            * sm.hash_units as f64;
        // table bits as dense cells (≈0.35 gate-equiv per bit at 45nm)
        gates += sm.lookup_units as f64 * sm.entries_per_filter as f64 * 0.35;
        gates += sm.out_bits as f64 * sm.num_filters as f64 * 0.5;
        let nf = sm.num_filters as f64;
        let width = (nf.log2() / 2.0 + 1.0).max(1.0);
        gates += inst.num_classes as f64 * (nf - 1.0) * width;
    }
    gates += inst.cfg.bus_bits as f64 * 4.0 + inst.encoded_bits as f64 * 1.2;
    gates * UM2_PER_GATE / 1e6
}

/// Full ASIC report (batch=16 steady stream like the paper's Table III).
pub fn implement(inst: &AcceleratorInstance) -> AsicReport {
    let nj = energy_pj_per_inference(inst) / 1e3;
    let throughput = inst.throughput();
    let power = nj * 1e-9 * throughput;
    AsicReport {
        freq_mhz: inst.freq_mhz,
        throughput_kips: throughput / 1e3,
        latency_us: inst.latency_us(),
        power_w: power,
        nj_per_inf: nj,
        area_mm2: area_mm2(inst),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hw::arch::{AcceleratorInstance, Target};
    use crate::data::synth_uci::{synth_uci, uci_spec};
    use crate::train::oneshot::{train_oneshot, OneShotConfig};

    fn inst(bits: usize, entries: usize) -> AcceleratorInstance {
        let ds = synth_uci(3, uci_spec("vowel").unwrap());
        let (m, _) = train_oneshot(
            &ds,
            &OneShotConfig { inputs_per_filter: 10, entries_per_filter: entries, therm_bits: bits, ..Default::default() },
        );
        AcceleratorInstance::generate(&m, Target::Asic)
    }

    #[test]
    fn energy_grows_with_model_size() {
        let a = inst(4, 64);
        let b = inst(8, 512);
        assert!(energy_pj_per_inference(&b) > energy_pj_per_inference(&a));
        assert!(area_mm2(&b) > area_mm2(&a));
    }

    #[test]
    fn report_is_selfconsistent() {
        let i = inst(6, 128);
        let r = implement(&i);
        // P = E/inf × rate
        let p = r.nj_per_inf * 1e-9 * r.throughput_kips * 1e3;
        assert!((p - r.power_w).abs() < 1e-9);
        assert!(r.area_mm2 > 0.0);
    }

    #[test]
    fn nanojoule_scale_for_small_models() {
        // ULEEN's claim: table lookups cost nJ, not µJ.
        let r = implement(&inst(4, 64));
        assert!(r.nj_per_inf < 1000.0, "nJ/inf = {}", r.nj_per_inf);
    }
}
