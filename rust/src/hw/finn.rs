//! FINN baseline model (Umuroglu et al., FPGA'17) — the BNN accelerator
//! ULEEN is compared against in Table II / Fig 11.
//!
//! We model the published SFC/MFC/LFC "max" dataflow designs from their
//! architecture: three fully-connected binary hidden layers (256/512/1024
//! neurons) over a 784-bit binarized input, XNOR-popcount matrix-vector
//! units per layer, fully unfolded for peak throughput at 200 MHz with a
//! 112-bit AXI input. Published measurement anchors from the FINN paper
//! (throughput, LUTs, power) are kept alongside the analytic values so the
//! benches can report both; accuracy on SynthMNIST comes from our own BNN
//! trained at artifact time (zoo.json) when available.

/// One FINN network topology.
#[derive(Clone, Copy, Debug)]
pub struct FinnTopology {
    pub name: &'static str,
    pub hidden_width: usize,
    pub layers: usize,
    pub input_bits: usize,
    pub classes: usize,
}

pub const SFC: FinnTopology =
    FinnTopology { name: "SFC", hidden_width: 256, layers: 3, input_bits: 784, classes: 10 };
pub const MFC: FinnTopology =
    FinnTopology { name: "MFC", hidden_width: 512, layers: 3, input_bits: 784, classes: 10 };
pub const LFC: FinnTopology =
    FinnTopology { name: "LFC", hidden_width: 1024, layers: 3, input_bits: 784, classes: 10 };

/// Published Table II anchors (FINN paper + ULEEN Table II, shaded rows).
#[derive(Clone, Copy, Debug)]
pub struct FinnPublished {
    pub latency_us: Option<f64>,
    pub kips: f64,
    pub power_w: f64,
    pub luts: Option<f64>,
    pub bram: Option<f64>,
    pub mnist_accuracy: f64,
}

pub fn published(t: &FinnTopology) -> FinnPublished {
    match t.name {
        "SFC" => FinnPublished {
            latency_us: Some(0.31),
            kips: 12_361.0,
            power_w: 7.3,
            luts: Some(91_131.0),
            bram: Some(4.5),
            mnist_accuracy: 0.9583,
        },
        "MFC" => FinnPublished {
            latency_us: None,
            kips: 6_238.0,
            power_w: 11.3,
            luts: None,
            bram: None,
            mnist_accuracy: 0.9769,
        },
        "LFC" => FinnPublished {
            latency_us: Some(2.44),
            kips: 1_561.0,
            power_w: 8.8,
            luts: Some(82_988.0),
            bram: Some(396.0),
            mnist_accuracy: 0.9840,
        },
        _ => unreachable!(),
    }
}

/// Analytic hardware estimate for a FINN-style dataflow BNN.
#[derive(Clone, Debug)]
pub struct FinnReport {
    pub name: &'static str,
    pub synaptic_ops: usize,
    pub ii_cycles: usize,
    pub latency_us: f64,
    pub kips: f64,
    pub power_w: f64,
    pub uj_per_inf_steady: f64,
    pub uj_per_inf_single: f64,
}

/// XNOR-popcount synapses per inference.
pub fn synaptic_ops(t: &FinnTopology) -> usize {
    let mut ops = t.input_bits * t.hidden_width;
    for _ in 1..t.layers {
        ops += t.hidden_width * t.hidden_width;
    }
    ops + t.hidden_width * t.classes
}

/// Model the "-max" design point.
///
/// Calibration: the published SFC-max rate (12.36 MIPS @ 200 MHz) implies
/// II ≈ 16 cycles; LFC-max (1.56 MIPS) implies II ≈ 128 — folding grows
/// ~(width/256)^1.5 as the wider matrix units exceed the area budget.
/// LUTs: published SFC uses 91 k LUTs for 201 k synapses folded 16× →
/// ≈7.2 LUTs per active synapse (XNOR + popcount tree + threshold +
/// control). Power: FINN's XNOR-popcount arrays toggle densely every
/// cycle; the per-LUT activity is ≈1.5× ULEEN's sparse LUT-RAM reads
/// (3.8e-7 vs 2.6e-7 W/LUT/MHz), anchored on SFC-max's published 7.3 W.
pub fn implement(t: &FinnTopology, freq_mhz: f64) -> FinnReport {
    let ops = synaptic_ops(t);
    let ii = (16.0 * (t.hidden_width as f64 / 256.0).powf(1.5)).round() as usize;
    // pipeline depth ≈ layers+2 stages of II each (dataflow handoff)
    let latency_cycles = ii * (t.layers + 2);
    let kips = freq_mhz * 1e6 / ii as f64 / 1e3;
    let luts = 7.2 * ops as f64 / ii as f64;
    let power = 0.35 + luts * freq_mhz * 3.8e-7;
    let latency_us = latency_cycles as f64 / freq_mhz;
    FinnReport {
        name: t.name,
        synaptic_ops: ops,
        ii_cycles: ii,
        latency_us,
        kips,
        power_w: power,
        uj_per_inf_steady: power / (kips * 1e3) * 1e6,
        uj_per_inf_single: power * latency_us,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn synaptic_op_counts() {
        assert_eq!(synaptic_ops(&SFC), 784 * 256 + 2 * 256 * 256 + 256 * 10);
        assert!(synaptic_ops(&LFC) > synaptic_ops(&MFC));
        assert!(synaptic_ops(&MFC) > synaptic_ops(&SFC));
    }

    #[test]
    fn analytic_throughput_matches_published_anchor_within_2x() {
        for t in [SFC, MFC, LFC] {
            let rep = implement(&t, 200.0);
            let pubd = published(&t);
            let ratio = rep.kips / pubd.kips;
            assert!(
                (0.5..2.0).contains(&ratio),
                "{}: analytic {} vs published {} kips",
                t.name,
                rep.kips,
                pubd.kips
            );
        }
    }

    #[test]
    fn bigger_networks_are_slower_and_hungrier() {
        let s = implement(&SFC, 200.0);
        let l = implement(&LFC, 200.0);
        assert!(l.kips < s.kips);
        assert!(l.latency_us > s.latency_us);
        assert!(l.uj_per_inf_steady > s.uj_per_inf_steady);
    }
}
