//! Accelerator instance generation (paper Figs 8–9): size the functional
//! units of a ULEEN inference accelerator from a trained model, exactly as
//! the paper's Mako-templated RTL generator does, and derive the analytic
//! pipeline timing that `hw::pipeline` verifies cycle-by-cycle.

use crate::encoding::codec::compressed_bits_per_input;
use crate::model::ensemble::UleenModel;

/// Deployment target (paper §IV-B).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Target {
    /// Zynq Z-7045: 112-bit I/O, 200 MHz nominal.
    Fpga,
    /// FreePDK45: 192-bit I/O, 500 MHz.
    Asic,
}

/// Interface/clock parameters for a target.
#[derive(Clone, Copy, Debug)]
pub struct AcceleratorConfig {
    pub bus_bits: usize,
    pub freq_mhz: f64,
    /// Use the unary→binary input compression (paper §III-C): fewer bus
    /// cycles, plus a decompression unit.
    pub compress_input: bool,
}

impl AcceleratorConfig {
    pub fn for_target(t: Target) -> Self {
        match t {
            // Same interface widths/frequencies as the paper's comparisons.
            Target::Fpga => Self { bus_bits: 112, freq_mhz: 200.0, compress_input: true },
            Target::Asic => Self { bus_bits: 192, freq_mhz: 500.0, compress_input: true },
        }
    }
}

/// Per-submodel functional-unit inventory.
#[derive(Clone, Debug)]
pub struct SubmodelUnits {
    pub inputs_per_filter: usize,
    pub entries_per_filter: usize,
    pub k_hashes: usize,
    pub num_filters: usize,
    pub kept_filters: usize,
    /// hash invocations per inference = num_filters * k (shared hash block)
    pub hashes_per_inference: usize,
    /// pipelined hash units instantiated (minimum that sustains the bus II)
    pub hash_units: usize,
    /// lookup units = kept filters across discriminators (pruned ones are
    /// removed from the hardware, paper §III-A4)
    pub lookup_units: usize,
    pub out_bits: u32,
}

/// A fully-sized accelerator instance.
#[derive(Clone, Debug)]
pub struct AcceleratorInstance {
    pub cfg: AcceleratorConfig,
    pub num_classes: usize,
    pub encoded_bits: usize,
    /// bits moved over the bus per inference (compressed or raw)
    pub input_bits_per_inference: usize,
    pub submodels: Vec<SubmodelUnits>,
    /// initiation interval in cycles (pipeline bottleneck stage)
    pub ii_cycles: usize,
    /// end-to-end latency in cycles for one inference
    pub latency_cycles: usize,
    /// effective clock (large FPGA designs derate — see `fpga::achievable_freq`)
    pub freq_mhz: f64,
}

fn ceil_div(a: usize, b: usize) -> usize {
    a.div_ceil(b)
}

fn log2_ceil(x: usize) -> usize {
    (usize::BITS - x.max(1).leading_zeros()) as usize - if x.is_power_of_two() { 1 } else { 0 }
}

impl AcceleratorInstance {
    /// Size an accelerator for `model` on `target` (paper's generator flow).
    pub fn generate(model: &UleenModel, target: Target) -> Self {
        let mut cfg = AcceleratorConfig::for_target(target);
        let t = model.encoder.bits;
        let num_inputs = model.encoder.num_inputs;
        let encoded_bits = model.encoded_bits();
        // Bus traffic per inference: raw unary bits, or binary counts.
        let input_bits = if cfg.compress_input {
            num_inputs * compressed_bits_per_input(t)
        } else {
            encoded_bits
        };
        // If compression doesn't help (t == 1), drop the decompressor.
        if input_bits >= encoded_bits {
            cfg.compress_input = false;
        }
        let input_bits_per_inference = input_bits.min(encoded_bits);
        // Deserialization dominates the initiation interval: a new sample
        // can start only when the previous one has streamed in (paper:
        // "an entire input sample must be read in before computation can
        // begin" + "performance ... bottlenecked by off-chip bandwidth").
        let deser_cycles = ceil_div(input_bits_per_inference, cfg.bus_bits);
        let ii_cycles = deser_cycles.max(1);

        let mut submodels = Vec::new();
        let mut max_hash_cycles = 0usize;
        let mut max_adder_depth = 0usize;
        for sm in &model.submodels {
            let nf = sm.cfg.num_filters();
            let hashes = nf * sm.cfg.k_hashes;
            // minimum hash units that produce all hashes within one II
            let hash_units = ceil_div(hashes, ii_cycles).max(1);
            let kept: usize = sm.discriminators.iter().map(|d| d.kept()).sum();
            submodels.push(SubmodelUnits {
                inputs_per_filter: sm.cfg.inputs_per_filter,
                entries_per_filter: sm.cfg.entries_per_filter,
                k_hashes: sm.cfg.k_hashes,
                num_filters: nf,
                kept_filters: kept,
                hashes_per_inference: hashes,
                hash_units,
                lookup_units: kept,
                out_bits: sm.cfg.out_bits(),
            });
            max_hash_cycles = max_hash_cycles.max(ceil_div(hashes, hash_units));
            max_adder_depth = max_adder_depth.max(log2_ceil(nf.max(1)) + 1);
        }
        const HASH_PIPE_DEPTH: usize = 3; // AND stage + XOR-tree stages
        const LOOKUP_CYCLES: usize = 2; // k=2 probes through the 1-bit AND acc
        let argmax_depth = log2_ceil(model.num_classes()) + 1;
        let latency_cycles = ii_cycles // deserialize
            + HASH_PIPE_DEPTH
            + max_hash_cycles
            + LOOKUP_CYCLES
            + max_adder_depth
            + 1 // bias add
            + argmax_depth;
        Self {
            cfg,
            num_classes: model.num_classes(),
            encoded_bits,
            input_bits_per_inference,
            submodels,
            ii_cycles,
            latency_cycles,
            freq_mhz: cfg.freq_mhz,
        }
    }

    /// Peak throughput (inferences/second) at the instance's clock.
    pub fn throughput(&self) -> f64 {
        self.freq_mhz * 1e6 / self.ii_cycles as f64
    }

    /// Single-inference latency in microseconds.
    pub fn latency_us(&self) -> f64 {
        self.latency_cycles as f64 / self.freq_mhz
    }

    pub fn total_hash_units(&self) -> usize {
        self.submodels.iter().map(|s| s.hash_units).sum()
    }

    pub fn total_lookup_units(&self) -> usize {
        self.submodels.iter().map(|s| s.lookup_units).sum()
    }

    /// Total table bits stored on chip.
    pub fn table_bits(&self) -> usize {
        self.submodels
            .iter()
            .map(|s| s.lookup_units * s.entries_per_filter)
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth_uci::{synth_uci, uci_spec};
    use crate::train::oneshot::{train_oneshot, OneShotConfig};

    fn model() -> UleenModel {
        let ds = synth_uci(3, uci_spec("vowel").unwrap());
        train_oneshot(
            &ds,
            &OneShotConfig { inputs_per_filter: 10, entries_per_filter: 128, therm_bits: 6, ..Default::default() },
        )
        .0
    }

    #[test]
    fn log2_ceil_values() {
        assert_eq!(log2_ceil(1), 0);
        assert_eq!(log2_ceil(2), 1);
        assert_eq!(log2_ceil(3), 2);
        assert_eq!(log2_ceil(8), 3);
        assert_eq!(log2_ceil(9), 4);
    }

    #[test]
    fn asic_is_faster_than_fpga() {
        let m = model();
        let f = AcceleratorInstance::generate(&m, Target::Fpga);
        let a = AcceleratorInstance::generate(&m, Target::Asic);
        assert!(a.throughput() > f.throughput());
        assert!(a.latency_us() < f.latency_us());
    }

    #[test]
    fn hash_units_sustain_the_bus() {
        let m = model();
        let inst = AcceleratorInstance::generate(&m, Target::Fpga);
        for sm in &inst.submodels {
            // units * II >= hashes needed (no hash stall)
            assert!(sm.hash_units * inst.ii_cycles >= sm.hashes_per_inference);
            // minimality: one fewer unit would stall
            if sm.hash_units > 1 {
                assert!((sm.hash_units - 1) * inst.ii_cycles < sm.hashes_per_inference);
            }
        }
    }

    #[test]
    fn compression_reduces_bus_traffic_for_multibit_encodings() {
        let m = model(); // 6-bit thermometer
        let inst = AcceleratorInstance::generate(&m, Target::Fpga);
        assert!(inst.input_bits_per_inference < inst.encoded_bits);
        assert!(inst.cfg.compress_input);
    }

    #[test]
    fn pruning_removes_lookup_units() {
        let ds = synth_uci(3, uci_spec("vowel").unwrap());
        let (mut m, _) = train_oneshot(
            &ds,
            &OneShotConfig { inputs_per_filter: 10, entries_per_filter: 128, therm_bits: 6, ..Default::default() },
        );
        let before = AcceleratorInstance::generate(&m, Target::Fpga).total_lookup_units();
        crate::train::prune::prune_model(&mut m, &ds, 0.3);
        let after = AcceleratorInstance::generate(&m, Target::Fpga).total_lookup_units();
        assert!(after < before);
    }

    #[test]
    fn latency_exceeds_ii() {
        let inst = AcceleratorInstance::generate(&model(), Target::Asic);
        assert!(inst.latency_cycles > inst.ii_cycles);
        assert!(inst.latency_us() > 0.0);
    }
}
