//! Cycle-level simulator of the lockstep inference pipeline (Fig 8).
//!
//! Stages: deserialize → hash → lockstep lookup → adder trees → bias/argmax.
//! Each stage is pipelined with its own service time; a sample may enter a
//! stage only when the previous sample has left it. The simulator verifies
//! the analytic `ii_cycles` / `latency_cycles` derived in [`super::arch`]
//! (tests assert they agree), and reports per-stage utilization for the
//! bottleneck analysis in EXPERIMENTS.md.

use crate::hw::arch::AcceleratorInstance;

/// Per-stage timing: `cycles` is the per-sample occupancy (determines the
/// II); `extra` is pipeline-fill latency the stage adds to every sample
/// without occupying it per-sample (e.g. the hash units' internal 3-stage
/// AND/XOR-tree pipeline).
#[derive(Clone, Debug)]
pub struct StageTimes {
    pub names: Vec<&'static str>,
    pub cycles: Vec<usize>,
    pub extra: Vec<usize>,
}

impl StageTimes {
    pub fn from_instance(inst: &AcceleratorInstance) -> Self {
        let max_hash = inst
            .submodels
            .iter()
            .map(|s| s.hashes_per_inference.div_ceil(s.hash_units))
            .max()
            .unwrap_or(1);
        let max_nf = inst
            .submodels
            .iter()
            .map(|s| s.num_filters)
            .max()
            .unwrap_or(1);
        let log2 = |x: usize| {
            (usize::BITS - x.max(1).leading_zeros()) as usize
                - if x.is_power_of_two() { 1 } else { 0 }
        };
        Self {
            names: vec!["deserialize", "hash", "lookup", "reduce", "argmax"],
            cycles: vec![
                inst.ii_cycles,
                max_hash,     // per-unit hash stream occupancy
                2,            // k probes through the AND accumulator
                log2(max_nf) + 1 + 1, // adder tree + bias
                log2(inst.num_classes) + 1,
            ],
            extra: vec![0, 3, 0, 0, 0], // hash-unit internal pipe fill
        }
    }

    pub fn fill_latency(&self) -> usize {
        self.cycles.iter().sum::<usize>() + self.extra.iter().sum::<usize>()
    }
}

/// Simulation outcome for a stream of samples.
#[derive(Clone, Debug)]
pub struct PipelineReport {
    pub samples: usize,
    pub total_cycles: usize,
    pub first_latency_cycles: usize,
    pub steady_ii_cycles: f64,
    /// fraction of total cycles each stage was busy
    pub utilization: Vec<f64>,
    pub stage_names: Vec<&'static str>,
}

/// Simulate `samples` back-to-back inferences through the pipeline.
///
/// Classic pipeline recurrence: sample i enters stage s at
/// `max(done[i][s-1], done[i-1][s])` (in-order, no buffering between
/// stages beyond the pipeline registers — the paper's lockstep design).
pub fn simulate_stream(inst: &AcceleratorInstance, samples: usize) -> PipelineReport {
    let st = StageTimes::from_instance(inst);
    let n_stages = st.cycles.len();
    let mut done_prev = vec![0usize; n_stages]; // completion times of sample i-1
    let mut busy = vec![0usize; n_stages];
    let mut first_latency = 0usize;
    let mut last_done = 0usize;
    let mut prev_done_total = 0usize;
    let mut ii_acc = 0f64;
    for i in 0..samples {
        let mut t_avail = 0usize; // when this sample finished previous stage
        for s in 0..n_stages {
            let start = t_avail.max(done_prev[s]);
            let finish = start + st.cycles[s];
            busy[s] += st.cycles[s];
            done_prev[s] = finish;
            // pipeline-fill latency delays downstream availability but does
            // not re-occupy the stage for the next sample
            t_avail = finish + st.extra[s];
        }
        if i == 0 {
            first_latency = t_avail;
        } else {
            ii_acc += (t_avail - prev_done_total) as f64;
        }
        prev_done_total = t_avail;
        last_done = t_avail;
    }
    PipelineReport {
        samples,
        total_cycles: last_done,
        first_latency_cycles: first_latency,
        steady_ii_cycles: if samples > 1 {
            ii_acc / (samples - 1) as f64
        } else {
            first_latency as f64
        },
        utilization: busy
            .iter()
            .map(|&b| b as f64 / last_done.max(1) as f64)
            .collect(),
        stage_names: st.names,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth_uci::{synth_uci, uci_spec};
    use crate::hw::arch::Target;
    use crate::train::oneshot::{train_oneshot, OneShotConfig};

    fn inst() -> AcceleratorInstance {
        let ds = synth_uci(3, uci_spec("vowel").unwrap());
        let (m, _) = train_oneshot(
            &ds,
            &OneShotConfig { inputs_per_filter: 10, entries_per_filter: 128, therm_bits: 6, ..Default::default() },
        );
        AcceleratorInstance::generate(&m, Target::Fpga)
    }

    #[test]
    fn steady_state_ii_matches_bottleneck_stage() {
        let inst = inst();
        let rep = simulate_stream(&inst, 200);
        let st = StageTimes::from_instance(&inst);
        let bottleneck = *st.cycles.iter().max().unwrap();
        assert!(
            (rep.steady_ii_cycles - bottleneck as f64).abs() < 1e-9,
            "simulated II {} vs bottleneck {}",
            rep.steady_ii_cycles,
            bottleneck
        );
    }

    #[test]
    fn first_latency_is_sum_of_stage_times() {
        let inst = inst();
        let rep = simulate_stream(&inst, 1);
        let st = StageTimes::from_instance(&inst);
        assert_eq!(rep.first_latency_cycles, st.fill_latency());
    }

    #[test]
    fn analytic_latency_close_to_simulated() {
        // arch.rs's closed-form latency must agree with the simulator
        // within the small constant bookkeeping terms.
        let inst = inst();
        let rep = simulate_stream(&inst, 1);
        let diff =
            (rep.first_latency_cycles as i64 - inst.latency_cycles as i64).unsigned_abs();
        assert!(diff <= 2, "analytic {} vs simulated {}", inst.latency_cycles, rep.first_latency_cycles);
    }

    #[test]
    fn conservation_all_samples_complete_in_order() {
        let inst = inst();
        let n = 500;
        let rep = simulate_stream(&inst, n);
        assert_eq!(rep.samples, n);
        // total = fill latency + (n-1) * II
        let expected = rep.first_latency_cycles as f64
            + (n as f64 - 1.0) * rep.steady_ii_cycles;
        assert!((rep.total_cycles as f64 - expected).abs() < 1e-6);
    }

    #[test]
    fn bottleneck_stage_is_fully_utilized() {
        let inst = inst();
        let rep = simulate_stream(&inst, 1000);
        let max_util = rep.utilization.iter().cloned().fold(0.0, f64::max);
        assert!(max_util > 0.95, "bottleneck util {max_util}");
        assert!(rep.utilization.iter().all(|&u| u <= 1.0 + 1e-9));
    }
}
