//! `uleen simulate` — size, simulate and cost a hardware instance for a
//! trained model on an FPGA or ASIC target.

use crate::hw::arch::{AcceleratorInstance, Target};
use crate::hw::pipeline::simulate_stream;
use crate::model::uln_format;
use crate::util::cli::Args;
use std::path::Path;

pub fn cmd_simulate(args: &Args) -> anyhow::Result<()> {
    let model_path = args
        .get("model")
        .ok_or_else(|| anyhow::anyhow!("--model <file.uln> required"))?;
    let target = match args.get_or("target", "fpga") {
        "fpga" => Target::Fpga,
        "asic" => Target::Asic,
        other => anyhow::bail!("unknown target '{other}' (fpga|asic)"),
    };
    let (model, meta) = uln_format::load(Path::new(model_path))?;
    let mut inst = AcceleratorInstance::generate(&model, target);
    println!("model: {} ({:.2} KiB tables)", model.name, model.size_kib());
    if let Some(acc) = meta.get("test_accuracy").and_then(|j| j.as_f64()) {
        println!("accuracy: {:.4}", acc);
    }
    println!(
        "instance: {} submodels | {} hash units | {} lookup units | {} encoded bits ({} on bus)",
        inst.submodels.len(),
        inst.total_hash_units(),
        inst.total_lookup_units(),
        inst.encoded_bits,
        inst.input_bits_per_inference
    );
    match target {
        Target::Fpga => {
            let rep = crate::hw::fpga::implement(&mut inst);
            println!(
                "FPGA: {} LUTs | {} BRAM | {:.0} MHz | {:.2} W",
                rep.luts, rep.bram, rep.freq_mhz, rep.power_w
            );
            println!(
                "      {:.2} µs latency | {:.0} kIPS | {:.3} µJ/inf (b=1) | {:.3} µJ/inf (b=∞)",
                rep.latency_us, rep.throughput_kips, rep.uj_per_inf_single, rep.uj_per_inf_steady
            );
        }
        Target::Asic => {
            let rep = crate::hw::asic::implement(&inst);
            println!(
                "ASIC (45nm): {:.0} MHz | {:.2} W | {:.2} mm²",
                rep.freq_mhz, rep.power_w, rep.area_mm2
            );
            println!(
                "      {:.3} µs latency | {:.0} kIPS | {:.1} nJ/inf",
                rep.latency_us, rep.throughput_kips, rep.nj_per_inf
            );
        }
    }
    let sim = simulate_stream(&inst, 1000);
    println!(
        "pipeline sim (1000 samples): II={:.1} cycles | fill latency {} cycles | stage util {}",
        sim.steady_ii_cycles,
        sim.first_latency_cycles,
        sim.stage_names
            .iter()
            .zip(sim.utilization.iter())
            .map(|(n, u)| format!("{n}={:.0}%", u * 100.0))
            .collect::<Vec<_>>()
            .join(" ")
    );
    Ok(())
}
