//! Bit Fusion baseline (Sharma et al., ISCA'18) — the quantized-DNN ASIC
//! ULEEN compares against in Table III / Fig 12.
//!
//! Bit Fusion runs a ternary (2-bit) LeNet-5 on a dynamically-composable
//! systolic array. We model the three published configurations (BF8/16/32)
//! from the dataflow: MAC count of the 2-bit LeNet-5, array utilization,
//! SRAM traffic through the W/A/O buffers, at 45 nm / 500 MHz — the same
//! technology constants as `hw::asic` so the comparison is apples-to-apples.

/// One Bit Fusion configuration (systolic dims + buffer sizes).
#[derive(Clone, Copy, Debug)]
pub struct BitFusionConfig {
    pub name: &'static str,
    pub rows: usize,
    pub cols: usize,
    pub wbuf_kb: usize,
    pub abuf_kb: usize,
    pub obuf_kb: usize,
}

pub const BF8: BitFusionConfig =
    BitFusionConfig { name: "BF8", rows: 8, cols: 8, wbuf_kb: 32, abuf_kb: 16, obuf_kb: 8 };
pub const BF16: BitFusionConfig =
    BitFusionConfig { name: "BF16", rows: 16, cols: 16, wbuf_kb: 64, abuf_kb: 32, obuf_kb: 16 };
pub const BF32: BitFusionConfig =
    BitFusionConfig { name: "BF32", rows: 32, cols: 32, wbuf_kb: 64, abuf_kb: 32, obuf_kb: 16 };

/// Published Table III anchors (shaded rows).
#[derive(Clone, Copy, Debug)]
pub struct BitFusionPublished {
    pub kips: f64,
    pub power_w: f64,
    pub nj_per_inf: f64,
    pub area_mm2: f64,
    pub mnist_accuracy: f64,
}

pub fn published(c: &BitFusionConfig) -> BitFusionPublished {
    match c.name {
        "BF8" => BitFusionPublished { kips: 2.0, power_w: 0.26, nj_per_inf: 129_731.0, area_mm2: 0.60, mnist_accuracy: 0.9935 },
        "BF16" => BitFusionPublished { kips: 7.1, power_w: 0.81, nj_per_inf: 114_914.0, area_mm2: 1.59, mnist_accuracy: 0.9935 },
        "BF32" => BitFusionPublished { kips: 19.1, power_w: 1.79, nj_per_inf: 93_589.0, area_mm2: 1.65, mnist_accuracy: 0.9935 },
        _ => unreachable!(),
    }
}

/// MACs per inference of LeNet-5 on 28×28 (conv + FC layers).
pub fn lenet5_macs() -> usize {
    // C1: 6 filters 5×5 over 28×28 (padded) → 28×28×6×25
    let c1 = 28 * 28 * 6 * 25;
    // C3: 16 filters 5×5×6 over 10×10 outputs
    let c3 = 10 * 10 * 16 * 25 * 6;
    // C5/FC1: 120 × (16×5×5)
    let c5 = 120 * 400;
    // FC2: 84×120, FC3: 10×84
    let f6 = 84 * 120;
    let out = 10 * 84;
    c1 + c3 + c5 + f6 + out
}

#[derive(Clone, Debug)]
pub struct BitFusionReport {
    pub name: &'static str,
    pub macs: usize,
    pub kips: f64,
    pub power_w: f64,
    pub nj_per_inf: f64,
    pub area_mm2: f64,
}

/// Analytic model at 45 nm / 500 MHz (batch 16 like the paper).
///
/// Calibration: published Bit Fusion runs imply ~1.5–2.6 % effective MAC
/// utilization for ternary LeNet-5 on these configs (small conv layers,
/// per-tile weight/activation refills through the small buffers stall the
/// array). We model `util = 2.8 % · (PEs/64)^-0.2` — fit on BF8, predicts
/// BF16/BF32 within ~15 %. Power is accelerator-level (PE array + SRAM +
/// clock tree): `0.08 W + 2.8 mW · PEs^0.93` — the sublinear exponent
/// reflects clock gating on the bigger arrays. Energy/inference follows as
/// P / rate: at these utilizations the chip burns power for ~10^5 cycles
/// per inference, which is exactly why the paper's numbers are in µJ.
pub fn implement(c: &BitFusionConfig, freq_mhz: f64) -> BitFusionReport {
    let macs = lenet5_macs();
    let pes = (c.rows * c.cols) as f64;
    let util = 0.028 / (pes / 64.0).powf(0.2);
    let cycles = macs as f64 / (pes * util);
    let kips = freq_mhz * 1e6 / cycles / 1e3;
    let power = 0.08 + 0.0028 * pes.powf(0.93);
    let nj = power / (kips * 1e3) * 1e9;
    let sram_bits = (c.wbuf_kb + c.abuf_kb + c.obuf_kb) as f64 * 8192.0;
    // Area: PEs + SRAM macro area at 45nm + control/DMA block
    let area = pes * 2.5e-3 + sram_bits * 0.9e-6 + 0.12;
    BitFusionReport { name: c.name, macs, kips, power_w: power, nj_per_inf: nj, area_mm2: area }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lenet_mac_count_is_right_order() {
        let m = lenet5_macs();
        assert!(m > 300_000 && m < 700_000, "macs={m}");
    }

    #[test]
    fn bigger_arrays_are_faster() {
        let a = implement(&BF8, 500.0);
        let b = implement(&BF32, 500.0);
        assert!(b.kips > a.kips);
    }

    #[test]
    fn analytic_matches_published_within_3x() {
        for c in [BF8, BF16, BF32] {
            let rep = implement(&c, 500.0);
            let pubd = published(&c);
            let r_kips = rep.kips / pubd.kips;
            let r_nj = rep.nj_per_inf / pubd.nj_per_inf;
            assert!((0.33..3.0).contains(&r_kips), "{}: kips ratio {r_kips}", c.name);
            assert!((0.33..3.0).contains(&r_nj), "{}: nJ ratio {r_nj}", c.name);
        }
    }

    #[test]
    fn microjoule_scale_energy() {
        // The paper's headline: DNN inference costs ~100 µJ here vs ULEEN's nJ.
        let rep = implement(&BF16, 500.0);
        assert!(rep.nj_per_inf > 10_000.0, "nJ = {}", rep.nj_per_inf);
    }
}
