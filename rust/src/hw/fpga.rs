//! FPGA (Zynq Z-7045-class) resource + power model.
//!
//! Resource counts are first-principles gate inventories of the Fig 8/9
//! architecture packed into 6-input LUTs; the two global calibration
//! constants (`LUT_PACK_EFF`, `DYN_W_PER_LUT_MHZ`) were fit once against
//! the paper's ULN-S row of Table II (17,319 LUTs, 1.1 W @ 200 MHz) and
//! then held fixed — ULN-M/L and all sweep points are *predictions* of the
//! model, not per-point fits. BRAM is zero by construction: every Bloom
//! table lives in LUT RAM (the paper reports 0 BRAM for all ULEEN designs).

use crate::hw::arch::AcceleratorInstance;

/// How many logic gates one LUT6 absorbs on average (fit: ULN-S LUTs).
const LUT_PACK_EFF: f64 = 2.4;
/// Dynamic power per LUT per MHz (fit: ULN-S power @ 200 MHz).
const DYN_W_PER_LUT_MHZ: f64 = 2.6e-7;
/// Device static power (Z-7045 ballpark).
const STATIC_W: f64 = 0.20;
/// LUTRAM: one LUT6 stores 64 table bits (RAM64X1S).
const LUTRAM_BITS: f64 = 64.0;

/// FPGA implementation estimate for one accelerator instance.
#[derive(Clone, Debug)]
pub struct FpgaReport {
    pub luts: usize,
    pub bram: usize,
    pub freq_mhz: f64,
    pub power_w: f64,
    pub throughput_kips: f64,
    pub latency_us: f64,
    /// energy per inference at steady state (batch=∞), µJ
    pub uj_per_inf_steady: f64,
    /// energy for one isolated inference (batch=1), µJ
    pub uj_per_inf_single: f64,
}

/// Routing-congestion frequency derate: the paper could not close 200 MHz
/// on the largest design (ULN-L ran at 85 MHz). We model a soft knee once
/// the design passes ~60k LUTs (Z-7045 has 218k; congestion hits first).
pub fn achievable_freq(nominal_mhz: f64, luts: usize) -> f64 {
    if luts <= 60_000 {
        nominal_mhz
    } else {
        let derate = 60_000.0 / luts as f64;
        (nominal_mhz * derate.powf(0.75)).max(nominal_mhz * 0.3)
    }
}

/// Gate inventory → LUT count.
pub fn lut_count(inst: &AcceleratorInstance) -> usize {
    let mut gates = 0f64;
    for sm in &inst.submodels {
        // Hash unit: per output bit, an n-input AND-mask + XOR fold
        // (2n-1 two-input gates); out_bits wide; `hash_units` copies.
        let per_hash = sm.out_bits as f64 * (2.0 * sm.inputs_per_filter as f64 - 1.0);
        gates += per_hash * sm.hash_units as f64;
        // Lookup unit: E-bit LUTRAM + address mux + 1-bit AND accumulator.
        let lutram = sm.entries_per_filter as f64 / LUTRAM_BITS;
        let per_lookup = lutram * LUT_PACK_EFF /* LUTRAM isn't packable */ + 3.0;
        gates += per_lookup * sm.lookup_units as f64;
        // Hash-result buffer registers (out_bits × filters), as gate-equiv.
        gates += sm.out_bits as f64 * sm.num_filters as f64 * 0.5;
        // Adder trees: per class, (NF-1) adders of mean width log2(NF)/2+1.
        let nf = sm.num_filters as f64;
        let width = (nf.log2() / 2.0 + 1.0).max(1.0);
        gates += inst.num_classes as f64 * (nf - 1.0) * width;
    }
    // Bus interface + decompressor + argmax comparator chain.
    gates += inst.cfg.bus_bits as f64 * 4.0;
    if inst.cfg.compress_input {
        gates += inst.encoded_bits as f64 * 1.2;
    }
    gates += inst.num_classes as f64 * 24.0; // comparator tree
    (gates / LUT_PACK_EFF).ceil() as usize
}

/// Full FPGA report for an instance (mutates the instance clock to the
/// achievable frequency, like the paper's 85 MHz ULN-L).
pub fn implement(inst: &mut AcceleratorInstance) -> FpgaReport {
    let luts = lut_count(inst);
    let freq = achievable_freq(inst.cfg.freq_mhz, luts);
    inst.freq_mhz = freq;
    let power = STATIC_W + luts as f64 * freq * DYN_W_PER_LUT_MHZ;
    let throughput = inst.throughput(); // uses derated freq
    let latency_us = inst.latency_us();
    let uj_steady = power / throughput * 1e6;
    // batch=1: the whole pipeline is powered for the full latency of one
    // sample instead of amortizing across II.
    let uj_single = power * latency_us; // W * µs = µJ
    FpgaReport {
        luts,
        bram: 0,
        freq_mhz: freq,
        power_w: power,
        throughput_kips: throughput / 1e3,
        latency_us,
        uj_per_inf_steady: uj_steady,
        uj_per_inf_single: uj_single,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hw::arch::{AcceleratorInstance, Target};
    use crate::data::synth_uci::{synth_uci, uci_spec};
    use crate::train::oneshot::{train_oneshot, OneShotConfig};

    fn inst(entries: usize) -> AcceleratorInstance {
        let ds = synth_uci(3, uci_spec("vowel").unwrap());
        let (m, _) = train_oneshot(
            &ds,
            &OneShotConfig { inputs_per_filter: 10, entries_per_filter: entries, therm_bits: 6, ..Default::default() },
        );
        AcceleratorInstance::generate(&m, Target::Fpga)
    }

    #[test]
    fn zero_bram_always() {
        let mut i = inst(128);
        assert_eq!(implement(&mut i).bram, 0);
    }

    #[test]
    fn bigger_tables_cost_more_luts() {
        let mut a = inst(64);
        let mut b = inst(512);
        assert!(implement(&mut b).luts > implement(&mut a).luts);
    }

    #[test]
    fn frequency_derates_only_for_big_designs() {
        assert_eq!(achievable_freq(200.0, 10_000), 200.0);
        assert_eq!(achievable_freq(200.0, 60_000), 200.0);
        let f = achievable_freq(200.0, 123_000);
        assert!(f < 200.0 && f > 60.0, "derated {f}");
    }

    #[test]
    fn single_inference_energy_exceeds_steady_state() {
        let mut i = inst(128);
        let r = implement(&mut i);
        assert!(r.uj_per_inf_single > r.uj_per_inf_steady);
    }

    #[test]
    fn power_scales_with_luts_and_freq() {
        let mut a = inst(64);
        let mut b = inst(512);
        let ra = implement(&mut a);
        let rb = implement(&mut b);
        assert!(rb.power_w > ra.power_w);
        assert!(ra.power_w > STATIC_W);
    }
}
