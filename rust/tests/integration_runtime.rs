//! Integration: the PJRT runtime against the native engine on the AOT
//! artifacts. Skips (with a loud message) when `make artifacts` has not
//! run — the numeric-agreement assertions are the heart of the
//! three-layer story, so they must run in the full flow.
//!
//! The whole suite is gated on the `pjrt` feature (the `xla` crate is
//! unavailable offline); native↔native conformance lives in proptests.rs.

#![cfg(feature = "pjrt")]

use std::path::PathBuf;
use uleen::data::synth_mnist;
use uleen::runtime::{InferenceEngine, NativeEngine, PjrtEngine};

fn artifacts() -> Option<PathBuf> {
    let dir = uleen::bench::artifacts_dir();
    if dir.join("uln_s.uln").exists() && dir.join("uln_s_b16.hlo.txt").exists() {
        Some(dir)
    } else {
        eprintln!("SKIP: artifacts missing — run `make artifacts` for full coverage");
        None
    }
}

#[test]
fn pjrt_matches_native_exactly_on_uln_s() {
    let Some(dir) = artifacts() else { return };
    let (model, _) = uleen::model::uln_format::load(&dir.join("uln_s.uln")).unwrap();
    let ds = synth_mnist(2024, 16, 128);
    let mut native = NativeEngine::new(model);
    let mut pjrt = PjrtEngine::load(&dir.join("uln_s_b16.hlo.txt"), 16, 784).unwrap();
    assert_eq!(pjrt.num_classes(), 10);
    let rn = native.responses(&ds.test_x, ds.n_test()).unwrap();
    let rp = pjrt.responses(&ds.test_x, ds.n_test()).unwrap();
    assert_eq!(rn.len(), rp.len());
    for (i, (a, b)) in rn.iter().zip(rp.iter()).enumerate() {
        assert_eq!(a, b, "response {i} differs: native {a} vs pjrt {b}");
    }
}

#[test]
fn pjrt_batch1_artifact_works_and_agrees() {
    let Some(dir) = artifacts() else { return };
    if !dir.join("uln_s_b1.hlo.txt").exists() {
        return;
    }
    let (model, _) = uleen::model::uln_format::load(&dir.join("uln_s.uln")).unwrap();
    let ds = synth_mnist(2024, 16, 8);
    let mut native = NativeEngine::new(model);
    let mut b1 = PjrtEngine::load(&dir.join("uln_s_b1.hlo.txt"), 1, 784).unwrap();
    let pn = native.classify(&ds.test_x, ds.n_test()).unwrap();
    let p1 = b1.classify(&ds.test_x, ds.n_test()).unwrap();
    assert_eq!(pn, p1);
}

#[test]
fn pjrt_handles_partial_batches_via_padding() {
    let Some(dir) = artifacts() else { return };
    let (model, _) = uleen::model::uln_format::load(&dir.join("uln_s.uln")).unwrap();
    let ds = synth_mnist(2024, 16, 21); // 21 = 16 + 5 (forces padding)
    let mut native = NativeEngine::new(model);
    let mut pjrt = PjrtEngine::load(&dir.join("uln_s_b16.hlo.txt"), 16, 784).unwrap();
    let pn = native.classify(&ds.test_x, 21).unwrap();
    let pp = pjrt.classify(&ds.test_x, 21).unwrap();
    assert_eq!(pn, pp, "padding must not change predictions");
}

#[test]
fn pjrt_rejects_malformed_artifacts() {
    let dir = std::env::temp_dir().join("uleen_runtime_test");
    std::fs::create_dir_all(&dir).unwrap();
    let bad = dir.join("bad.hlo.txt");
    std::fs::write(&bad, "this is not hlo").unwrap();
    assert!(PjrtEngine::load(&bad, 4, 10).is_err());
}
