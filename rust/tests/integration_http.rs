//! Integration: the std-only HTTP/1.1 front-end over real loopback
//! sockets — auth, validation, backpressure (429/503), read deadlines,
//! and shutdown draining. Every test talks to `HttpFrontend` through
//! `TcpStream`s, never in-process shortcuts: the point is the wire
//! contract.

use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::{mpsc, Arc, Mutex};
use std::time::{Duration, Instant};
use uleen::coordinator::batcher::BatcherConfig;
use uleen::coordinator::http::{client, HttpConfig, HttpFrontend, RateLimit};
use uleen::coordinator::server::{Server, ServerConfig};
use uleen::data::synth_uci::{synth_uci, uci_spec};
use uleen::data::Dataset;
use uleen::model::ensemble::{EnsembleScratch, UleenModel};
use uleen::runtime::{InferenceEngine, NativeEngine};
use uleen::train::oneshot::{train_oneshot, OneShotConfig};
use uleen::util::json::Json;

fn iris() -> (UleenModel, Dataset) {
    let ds = synth_uci(5, uci_spec("iris").unwrap());
    let model = train_oneshot(&ds, &OneShotConfig::default()).0;
    (model, ds)
}

fn server_cfg(capacity: usize, workers: usize) -> ServerConfig {
    ServerConfig {
        batcher: BatcherConfig {
            max_batch: 8,
            max_wait: Duration::from_micros(100),
            capacity,
        },
        workers,
    }
}

fn start_native(
    model: &UleenModel,
    http: HttpConfig,
) -> (Arc<Server>, HttpFrontend, String) {
    let mc = model.clone();
    let server = Arc::new(
        Server::start(server_cfg(4096, 2), move |_| {
            Ok(Box::new(NativeEngine::new(mc.clone())) as Box<dyn InferenceEngine>)
        })
        .unwrap(),
    );
    let frontend = HttpFrontend::start("127.0.0.1:0", server.clone(), http).unwrap();
    let addr = frontend.local_addr().to_string();
    (server, frontend, addr)
}

fn stop(server: Arc<Server>, frontend: HttpFrontend) {
    frontend.shutdown();
    let server = Arc::try_unwrap(server).ok().expect("stray Server handle");
    server.shutdown();
}

fn classify_body(rows: &[&[f32]], tier: Option<&str>) -> String {
    let mut j = Json::obj();
    j.set(
        "rows",
        Json::Arr(
            rows.iter()
                .map(|r| Json::Arr(r.iter().map(|&v| Json::Num(v as f64)).collect()))
                .collect(),
        ),
    );
    if let Some(t) = tier {
        j.set("tier", Json::Str(t.into()));
    }
    j.to_string()
}

fn predictions(body: &str) -> Vec<usize> {
    Json::parse(body)
        .unwrap()
        .get("predictions")
        .and_then(Json::as_arr)
        .unwrap()
        .iter()
        .map(|v| v.as_f64().unwrap() as usize)
        .collect()
}

#[test]
fn health_metrics_and_classify_agree_with_local_inference() {
    let (model, ds) = iris();
    let (server, frontend, addr) = start_native(
        &model,
        HttpConfig { api_key: Some("secret".into()), ..Default::default() },
    );

    // /health is open (probes carry no credentials)
    let r = client::request(&addr, "GET", "/health", None, None).unwrap();
    assert_eq!(r.status, 200);
    assert!(Json::parse(&r.body).unwrap().get("queue_depth").is_some());

    // keep-alive classify over one connection, checked against local truth
    let mut scratch = EnsembleScratch::default();
    let mut conn = TcpStream::connect(&addr).unwrap();
    for chunk in (0..ds.n_test().min(24)).collect::<Vec<_>>().chunks(8) {
        let rows: Vec<&[f32]> = chunk.iter().map(|&i| ds.test_row(i)).collect();
        let want: Vec<usize> =
            chunk.iter().map(|&i| model.predict(ds.test_row(i), &mut scratch)).collect();
        let body = classify_body(&rows, None);
        let r = client::request_on(&mut conn, "POST", "/v1/classify", Some("secret"), Some(&body))
            .unwrap();
        assert_eq!(r.status, 200, "body: {}", r.body);
        assert_eq!(predictions(&r.body), want, "served must match local inference");
    }

    // /metrics reports the traffic, including per-status HTTP counters
    let r = client::request(&addr, "GET", "/metrics", Some("secret"), None).unwrap();
    assert_eq!(r.status, 200);
    let m = Json::parse(&r.body).unwrap();
    assert!(m.get("http").is_some(), "metrics must expose HTTP status counts: {}", r.body);
    assert!(m.get("http").unwrap().get("200").unwrap().as_f64().unwrap() >= 3.0);

    stop(server, frontend);
}

#[test]
fn wrong_width_names_the_row_and_submits_nothing() {
    let (model, ds) = iris();
    let (server, frontend, addr) = start_native(&model, HttpConfig::default());

    let good = ds.test_row(0);
    let short = &good[..good.len() - 1];
    let body = classify_body(&[good, short, good], None);
    let r = client::request(&addr, "POST", "/v1/classify", None, Some(&body)).unwrap();
    assert_eq!(r.status, 400);
    assert!(r.body.contains("row 1"), "error must name the offending row: {}", r.body);

    // whole-batch validation: the bad request must not have enqueued rows 0/2
    let (_, seen) = server.metrics.latency_samples();
    assert_eq!(seen, 0, "nothing may reach the batcher before validation passes");

    // and the connection/server still serve a corrected batch
    let r = client::request(&addr, "POST", "/v1/classify", None, Some(&classify_body(&[good], None)))
        .unwrap();
    assert_eq!(r.status, 200);

    stop(server, frontend);
}

#[test]
fn auth_is_enforced_on_metrics_and_classify() {
    let (model, ds) = iris();
    let (server, frontend, addr) = start_native(
        &model,
        HttpConfig { api_key: Some("secret".into()), ..Default::default() },
    );
    let body = classify_body(&[ds.test_row(0)], None);

    for (path, method, req_body) in [
        ("/metrics", "GET", None),
        ("/v1/classify", "POST", Some(body.as_str())),
    ] {
        let r = client::request(&addr, method, path, None, req_body).unwrap();
        assert_eq!(r.status, 401, "{method} {path} without key");
        let r = client::request(&addr, method, path, Some("wrong"), req_body).unwrap();
        assert_eq!(r.status, 401, "{method} {path} with wrong key");
        assert!(r.body.contains("unauthorized"));
    }
    // Bearer form of the right key works too
    let mut conn = TcpStream::connect(&addr).unwrap();
    conn.write_all(
        b"GET /metrics HTTP/1.1\r\nHost: t\r\nAuthorization: Bearer secret\r\nConnection: close\r\n\r\n",
    )
    .unwrap();
    let mut raw = String::new();
    conn.read_to_string(&mut raw).unwrap();
    assert!(raw.starts_with("HTTP/1.1 200"), "bearer auth must pass: {raw}");

    stop(server, frontend);
}

/// Engine that blocks inside `responses_into` until the test feeds it a
/// token — lets a test hold the worker busy and fill the queue to a
/// DETERMINISTIC depth before poking the overflow path.
struct GateEngine {
    gate: mpsc::Receiver<()>,
}

impl InferenceEngine for GateEngine {
    fn label(&self) -> String {
        "gate".into()
    }
    fn num_features(&self) -> usize {
        4
    }
    fn num_classes(&self) -> usize {
        2
    }
    fn responses_into(&mut self, _x: &[f32], n: usize, out: &mut [f32]) -> uleen::Result<()> {
        for _ in 0..n {
            let _ = self.gate.recv(); // closed gate at shutdown = pass-through
        }
        for row in out[..2 * n].chunks_mut(2) {
            row.copy_from_slice(&[1.0, 0.0]);
        }
        Ok(())
    }
}

fn wait_for_depth(server: &Server, want: usize) {
    let t0 = Instant::now();
    while server.queue_depth() != want {
        assert!(t0.elapsed() < Duration::from_secs(5), "queue never reached depth {want}");
        std::thread::sleep(Duration::from_millis(2));
    }
}

#[test]
fn queue_full_is_a_429_response_not_a_dropped_connection() {
    let (gate_tx, gate_rx) = mpsc::channel::<()>();
    let gate = Mutex::new(Some(gate_rx));
    let cfg = ServerConfig {
        batcher: BatcherConfig {
            max_batch: 1,
            max_wait: Duration::from_micros(50),
            capacity: 2,
        },
        workers: 1,
    };
    let server = Arc::new(
        Server::start(cfg, move |_| {
            Ok(Box::new(GateEngine { gate: gate.lock().unwrap().take().unwrap() })
                as Box<dyn InferenceEngine>)
        })
        .unwrap(),
    );
    let frontend = HttpFrontend::start("127.0.0.1:0", server.clone(), HttpConfig::default()).unwrap();
    let addr = frontend.local_addr().to_string();

    let body = classify_body(&[&[0.0, 0.0, 0.0, 0.0]], None);
    let post = |addr: String, body: String| {
        std::thread::spawn(move || {
            client::request(&addr, "POST", "/v1/classify", None, Some(&body)).unwrap()
        })
    };
    // worker drains the first request and blocks on the gate...
    let a = post(addr.clone(), body.clone());
    wait_for_depth(&server, 0);
    // ...two more fill the queue to its capacity of 2...
    let b = post(addr.clone(), body.clone());
    wait_for_depth(&server, 1);
    let c = post(addr.clone(), body.clone());
    wait_for_depth(&server, 2);

    // ...so the next submit MUST bounce with a well-formed 429.
    let r = client::request(&addr, "POST", "/v1/classify", None, Some(&body)).unwrap();
    assert_eq!(r.status, 429);
    assert!(r.body.contains("queue_full"), "{}", r.body);

    // open the gate: the three queued requests all finish with 200s
    for _ in 0..3 {
        gate_tx.send(()).unwrap();
    }
    for h in [a, b, c] {
        let r = h.join().unwrap();
        assert_eq!(r.status, 200, "gated request must complete: {}", r.body);
        assert_eq!(predictions(&r.body), vec![0]);
    }
    stop(server, frontend);
}

#[test]
fn closed_server_answers_503_shutting_down() {
    let (model, ds) = iris();
    let (server, frontend, addr) = start_native(&model, HttpConfig::default());
    server.close();
    let body = classify_body(&[ds.test_row(0)], None);
    let r = client::request(&addr, "POST", "/v1/classify", None, Some(&body)).unwrap();
    assert_eq!(r.status, 503);
    assert!(r.body.contains("shutting_down"), "{}", r.body);
    // health stays answerable for probes during drain
    let r = client::request(&addr, "GET", "/health", None, None).unwrap();
    assert_eq!(r.status, 200);
    stop(server, frontend);
}

#[test]
fn oversized_body_is_rejected_before_it_is_read() {
    let (model, _ds) = iris();
    let (server, frontend, addr) = start_native(
        &model,
        HttpConfig { max_body_bytes: 256, ..Default::default() },
    );
    let big = classify_body(&[&vec![0.0f32; 200][..]], None);
    assert!(big.len() > 256);
    let r = client::request(&addr, "POST", "/v1/classify", None, Some(&big)).unwrap();
    assert_eq!(r.status, 413);
    assert!(r.body.contains("body_too_large"), "{}", r.body);
    stop(server, frontend);
}

#[test]
fn slow_loris_is_cut_off_with_408() {
    let (model, _ds) = iris();
    let (server, frontend, addr) = start_native(
        &model,
        HttpConfig {
            read_timeout: Duration::from_millis(80),
            request_deadline: Duration::from_millis(250),
            ..Default::default()
        },
    );
    let mut conn = TcpStream::connect(&addr).unwrap();
    conn.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
    // a request line and then... nothing. The handler must not wait
    // forever for the rest of the head.
    conn.write_all(b"POST /v1/classify HTTP/1.1\r\n").unwrap();
    let mut raw = Vec::new();
    conn.read_to_end(&mut raw).unwrap(); // server responds then closes
    let raw = String::from_utf8_lossy(&raw);
    assert!(raw.starts_with("HTTP/1.1 408"), "got: {raw}");
    stop(server, frontend);
}

#[test]
fn per_client_rate_limit_answers_429() {
    let (model, ds) = iris();
    let (server, frontend, addr) = start_native(
        &model,
        HttpConfig {
            rate: Some(RateLimit { burst: 2.0, per_sec: 0.0 }),
            ..Default::default()
        },
    );
    let body = classify_body(&[ds.test_row(0)], None);
    for _ in 0..2 {
        let r = client::request(&addr, "POST", "/v1/classify", None, Some(&body)).unwrap();
        assert_eq!(r.status, 200, "within burst: {}", r.body);
    }
    let r = client::request(&addr, "POST", "/v1/classify", None, Some(&body)).unwrap();
    assert_eq!(r.status, 429);
    assert!(r.body.contains("rate_limited"), "{}", r.body);
    // the limit gates classify only; health/metrics stay reachable
    assert_eq!(client::request(&addr, "GET", "/health", None, None).unwrap().status, 200);
    stop(server, frontend);
}

#[test]
fn unknown_routes_and_methods_get_404_405() {
    let (model, _ds) = iris();
    let (server, frontend, addr) = start_native(&model, HttpConfig::default());
    assert_eq!(client::request(&addr, "GET", "/nope", None, None).unwrap().status, 404);
    assert_eq!(client::request(&addr, "DELETE", "/health", None, None).unwrap().status, 405);
    assert_eq!(
        client::request(&addr, "GET", "/v1/classify", None, None).unwrap().status,
        405
    );
    stop(server, frontend);
}

#[test]
fn malformed_and_hostile_json_get_400() {
    let (model, _ds) = iris();
    let (server, frontend, addr) = start_native(&model, HttpConfig::default());
    for bad in [
        "{nope",
        "{\"rows\": 3}",
        "{\"rows\": []}",
        "{\"rows\": [[0,0,0,0]], \"tier\": 7}",
    ] {
        let r = client::request(&addr, "POST", "/v1/classify", None, Some(bad)).unwrap();
        assert_eq!(r.status, 400, "{bad} -> {}", r.body);
    }
    // a 50k-deep bracket bomb must come back as a 400, not a stack
    // overflow in the handler thread
    let bomb = "[".repeat(50_000);
    let r = client::request(&addr, "POST", "/v1/classify", None, Some(&bomb)).unwrap();
    assert_eq!(r.status, 400);
    assert!(r.body.contains("bad json"), "{}", r.body);
    // the connection pool survived: next request is fine
    assert_eq!(client::request(&addr, "GET", "/health", None, None).unwrap().status, 200);
    stop(server, frontend);
}

#[test]
fn tier_pins_route_through_the_zoo() {
    let ds = synth_uci(5, uci_spec("vowel").unwrap());
    let mut models = Vec::new();
    for (ipf, epf, bits) in [(8usize, 64usize, 2usize), (10, 128, 4)] {
        models.push(
            train_oneshot(
                &ds,
                &OneShotConfig {
                    inputs_per_filter: ipf,
                    entries_per_filter: epf,
                    therm_bits: bits,
                    ..Default::default()
                },
            )
            .0,
        );
    }
    let n = 16.min(ds.n_test());
    let fast_want = NativeEngine::new(models[0].clone())
        .classify(&ds.test_x[..n * ds.num_features], n)
        .unwrap();
    let acc_want = NativeEngine::new(models[1].clone())
        .classify(&ds.test_x[..n * ds.num_features], n)
        .unwrap();

    let server = Arc::new(Server::start_zoo(server_cfg(4096, 2), models, 0.05).unwrap());
    let frontend = HttpFrontend::start("127.0.0.1:0", server.clone(), HttpConfig::default()).unwrap();
    let addr = frontend.local_addr().to_string();

    let rows: Vec<&[f32]> = (0..n).map(|i| ds.test_row(i)).collect();
    for (tier, want) in [("fast", &fast_want), ("accurate", &acc_want)] {
        let r = client::request(
            &addr,
            "POST",
            "/v1/classify",
            None,
            Some(&classify_body(&rows, Some(tier))),
        )
        .unwrap();
        assert_eq!(r.status, 200, "{}", r.body);
        assert_eq!(&predictions(&r.body), want, "tier '{tier}' must pin to its engine");
    }
    // a made-up tier is a validation error, not a silent cascade
    let r = client::request(
        &addr,
        "POST",
        "/v1/classify",
        None,
        Some(&classify_body(&rows, Some("warp"))),
    )
    .unwrap();
    assert_eq!(r.status, 400);
    assert!(r.body.contains("warp"), "{}", r.body);

    // per-tier counters surfaced over /metrics
    let m = Json::parse(&client::request(&addr, "GET", "/metrics", None, None).unwrap().body)
        .unwrap();
    let fast_served = m
        .get("tier_fast")
        .and_then(|t| t.get("served"))
        .and_then(Json::as_f64)
        .unwrap_or(0.0);
    assert!(fast_served >= n as f64, "pinned fast traffic must show up: {fast_served}");

    stop(server, frontend);
}

/// Satellite of the batcher shutdown audit: close the server while 8
/// socket clients are mid-flight. Every client must keep receiving
/// well-formed responses — 200s before the close, 503s after — and
/// never a dropped connection or a hung read.
#[test]
fn close_while_draining_over_sockets_keeps_every_response_well_formed() {
    let (model, ds) = iris();
    let (server, frontend, addr) = start_native(&model, HttpConfig::default());
    let ds = Arc::new(ds);

    let clients = 8;
    let (warm_tx, warm_rx) = mpsc::channel::<()>();
    let mut handles = Vec::new();
    for c in 0..clients {
        let addr = addr.clone();
        let ds = ds.clone();
        let warm = warm_tx.clone();
        handles.push(std::thread::spawn(move || {
            let mut oks = 0u32;
            let mut warm = Some(warm);
            for it in 0..5000 {
                let i = (c * 31 + it) % ds.n_test();
                let body = classify_body(&[ds.test_row(i)], None);
                let r = client::request(&addr, "POST", "/v1/classify", None, Some(&body))
                    .expect("connection must never be dropped");
                match r.status {
                    200 => {
                        oks += 1;
                        if let Some(w) = warm.take() {
                            let _ = w.send(()); // signal: this client got served
                        }
                    }
                    503 => {
                        assert!(r.body.contains("shutting_down"), "{}", r.body);
                        return oks; // drain observed; clean exit
                    }
                    s => panic!("unexpected status {s}: {}", r.body),
                }
            }
            panic!("server never closed under client {c}");
        }));
    }
    drop(warm_tx);
    // close only after every client has been served at least once
    for _ in 0..clients {
        warm_rx.recv_timeout(Duration::from_secs(30)).expect("clients never warmed up");
    }
    server.close();
    for h in handles {
        let oks = h.join().unwrap();
        assert!(oks >= 1, "every client must see at least one success before the drain");
    }
    stop(server, frontend);
}
