//! Integration: hardware co-design models across module boundaries — the
//! paper's qualitative claims must hold for models trained end-to-end.

use uleen::data::synth_mnist;
use uleen::hw::arch::{AcceleratorInstance, Target};
use uleen::hw::pipeline::simulate_stream;
use uleen::hw::{asic, bitfusion, finn, fpga};
use uleen::train::oneshot::{train_oneshot, OneShotConfig};

fn mnist_model(bits: usize, entries: usize) -> uleen::model::ensemble::UleenModel {
    let ds = synth_mnist(55, 800, 100);
    train_oneshot(
        &ds,
        &OneShotConfig { inputs_per_filter: 16, entries_per_filter: entries, therm_bits: bits, ..Default::default() },
    )
    .0
}

#[test]
fn uleen_asic_beats_bitfusion_by_orders_of_magnitude() {
    // The paper's headline Table III claim, as an invariant.
    let m = mnist_model(2, 256);
    let inst = AcceleratorInstance::generate(&m, Target::Asic);
    let uleen = asic::implement(&inst);
    for cfg in [bitfusion::BF8, bitfusion::BF16, bitfusion::BF32] {
        let bf = bitfusion::implement(&cfg, 500.0);
        let xput_ratio = uleen.throughput_kips / bf.kips;
        let energy_ratio = bf.nj_per_inf / uleen.nj_per_inf;
        assert!(xput_ratio > 100.0, "{}: xput ratio {xput_ratio}", cfg.name);
        assert!(energy_ratio > 100.0, "{}: energy ratio {energy_ratio}", cfg.name);
    }
}

#[test]
fn uleen_fpga_energy_beats_finn_at_batch_infinity() {
    let m = mnist_model(2, 256);
    let mut inst = AcceleratorInstance::generate(&m, Target::Fpga);
    let uleen = fpga::implement(&mut inst);
    for t in [finn::SFC, finn::MFC, finn::LFC] {
        let f = finn::implement(&t, 200.0);
        assert!(
            uleen.uj_per_inf_steady < f.uj_per_inf_steady,
            "{}: ULEEN {} µJ vs FINN {} µJ",
            t.name,
            uleen.uj_per_inf_steady,
            f.uj_per_inf_steady
        );
    }
}

#[test]
fn pipeline_sim_agrees_with_analytic_model_across_design_space() {
    for (bits, entries) in [(1usize, 64usize), (2, 256), (4, 1024), (8, 512)] {
        let m = mnist_model(bits, entries);
        for target in [Target::Fpga, Target::Asic] {
            let inst = AcceleratorInstance::generate(&m, target);
            let rep = simulate_stream(&inst, 64);
            // simulated steady-state II can exceed the bus-analytic II only
            // if a compute stage dominates; it must never be lower.
            assert!(
                rep.steady_ii_cycles + 1e-9 >= inst.ii_cycles as f64,
                "sim II {} < analytic II {}",
                rep.steady_ii_cycles,
                inst.ii_cycles
            );
            let diff = (rep.first_latency_cycles as i64 - inst.latency_cycles as i64).abs();
            assert!(diff <= 2, "latency mismatch {diff} (bits={bits} entries={entries})");
        }
    }
}

#[test]
fn throughput_energy_tradeoff_is_monotone_in_model_size() {
    // bigger tables ⇒ no faster, no lower-energy (hardware monotonicity)
    let small = mnist_model(2, 64);
    let large = mnist_model(6, 1024);
    let i_small = AcceleratorInstance::generate(&small, Target::Asic);
    let i_large = AcceleratorInstance::generate(&large, Target::Asic);
    assert!(i_large.throughput() <= i_small.throughput());
    assert!(
        asic::energy_pj_per_inference(&i_large) > asic::energy_pj_per_inference(&i_small)
    );
}

#[test]
fn fpga_reports_zero_bram_and_plausible_luts_for_zoo_scale_models() {
    let m = mnist_model(2, 64);
    let mut inst = AcceleratorInstance::generate(&m, Target::Fpga);
    let rep = fpga::implement(&mut inst);
    assert_eq!(rep.bram, 0);
    assert!(rep.luts > 1000 && rep.luts < 300_000, "LUTs {}", rep.luts);
    // Z-7045 has 218k LUTs; our zoo must fit
    assert!(rep.luts < 218_600);
}
