//! Integration: the serving coordinator under load, concurrency and
//! failure injection.

use std::sync::mpsc;
use std::time::Duration;
use uleen::coordinator::batcher::{BatcherConfig, SubmitError};
use uleen::coordinator::server::{Server, ServerConfig};
use uleen::data::synth_uci::{synth_uci, uci_spec};
use uleen::runtime::{InferenceEngine, NativeEngine};
use uleen::train::oneshot::{train_oneshot, OneShotConfig};

fn model() -> uleen::model::ensemble::UleenModel {
    let ds = synth_uci(5, uci_spec("vowel").unwrap());
    train_oneshot(
        &ds,
        &OneShotConfig { inputs_per_filter: 10, entries_per_filter: 128, therm_bits: 4, ..Default::default() },
    )
    .0
}

#[test]
fn many_producers_many_workers_all_served_correctly() {
    let m = model();
    let ds = synth_uci(5, uci_spec("vowel").unwrap());
    let expected: Vec<usize> = {
        let mut s = uleen::model::ensemble::EnsembleScratch::default();
        (0..ds.n_test()).map(|i| m.predict(ds.test_row(i), &mut s)).collect()
    };
    let cfg = ServerConfig {
        batcher: BatcherConfig {
            max_batch: 8,
            max_wait: Duration::from_micros(100),
            capacity: 4096,
        },
        workers: 4,
    };
    let mc = m.clone();
    let server = std::sync::Arc::new(
        Server::start(cfg, move |_| Ok(Box::new(NativeEngine::new(mc.clone())) as Box<dyn InferenceEngine>)).unwrap(),
    );
    let (tx, rx) = mpsc::channel();
    let reps = 8usize;
    let mut handles = Vec::new();
    let ds = std::sync::Arc::new(ds);
    for _ in 0..4 {
        let server = server.clone();
        let tx = tx.clone();
        let ds = ds.clone();
        handles.push(std::thread::spawn(move || {
            let mut ids = Vec::new();
            for r in 0..reps {
                for i in 0..ds.n_test() {
                    loop {
                        match server.submit(ds.test_row(i), tx.clone()) {
                            Ok(id) => {
                                ids.push((id, i));
                                break;
                            }
                            Err(SubmitError::Full) => std::thread::sleep(Duration::from_micros(10)),
                            Err(e) => panic!("{e:?}"),
                        }
                    }
                }
                let _ = r;
            }
            ids
        }));
    }
    drop(tx);
    let mut id2row = std::collections::HashMap::new();
    let mut total = 0usize;
    for h in handles {
        for (id, row) in h.join().unwrap() {
            id2row.insert(id, row);
            total += 1;
        }
    }
    let mut served = 0usize;
    while let Ok((id, pred)) = rx.recv_timeout(Duration::from_secs(20)) {
        let row = id2row[&id];
        assert_eq!(pred, expected[row], "request {id} row {row}");
        served += 1;
        if served == total {
            break;
        }
    }
    assert_eq!(served, total);
    let report = server.metrics.report(8);
    assert_eq!(report.completed as usize, total);
    assert!(report.mean_batch_fill > 0.1);
    std::sync::Arc::try_unwrap(server).ok().map(|s| s.shutdown());
}

#[test]
fn worker_engine_failure_does_not_wedge_the_server() {
    // An engine that fails on every Nth batch: the coordinator must keep
    // serving the rest (failed batches observable as dropped channels).
    struct Flaky {
        calls: usize,
    }
    impl InferenceEngine for Flaky {
        fn label(&self) -> String {
            "flaky".into()
        }
        fn num_features(&self) -> usize {
            4
        }
        fn num_classes(&self) -> usize {
            2
        }
        fn responses_into(&mut self, _x: &[f32], n: usize, out: &mut [f32]) -> uleen::Result<()> {
            self.calls += 1;
            if self.calls % 3 == 0 {
                anyhow::bail!("injected failure");
            }
            for row in out[..2 * n].chunks_mut(2) {
                row.copy_from_slice(&[1.0, 0.0]);
            }
            Ok(())
        }
    }
    let cfg = ServerConfig {
        batcher: BatcherConfig {
            max_batch: 4,
            max_wait: Duration::from_micros(50),
            capacity: 64,
        },
        workers: 1,
    };
    let server = Server::start(cfg, |_| Ok(Box::new(Flaky { calls: 0 }) as Box<dyn InferenceEngine>)).unwrap();
    let (tx, rx) = mpsc::channel();
    let n = 60;
    for _ in 0..n {
        loop {
            match server.submit(&[0.0; 4], tx.clone()) {
                Ok(_) => break,
                Err(SubmitError::Full) => std::thread::sleep(Duration::from_micros(20)),
                Err(e) => panic!("{e:?}"),
            }
        }
    }
    drop(tx);
    // collect whatever completes; must be nonzero and the server must shut
    // down cleanly (no deadlock).
    let mut ok = 0;
    while rx.recv_timeout(Duration::from_millis(500)).is_ok() {
        ok += 1;
    }
    assert!(ok > 0, "some batches must survive the flaky engine");
    assert!(ok < n, "some batches must have failed (injection active)");
    let report = server.metrics.report(4);
    assert!(
        report.batches_failed > 0,
        "engine failures must be observable in metrics, not silently dropped"
    );
    assert_eq!(
        report.completed as usize, ok,
        "completions counted in metrics exclude the failed batches"
    );
    server.shutdown();
}

#[test]
fn zero_length_submit_is_dropped_without_wedging_the_server() {
    // A request with the wrong feature width (here: zero-length) poisons
    // its micro-batch: the coordinator drops the batch's completions
    // (senders disconnect) but must keep serving later traffic.
    let m = model();
    let cfg = ServerConfig {
        batcher: BatcherConfig {
            max_batch: 1, // isolate the malformed request in its own batch
            max_wait: Duration::from_micros(10),
            capacity: 64,
        },
        workers: 1,
    };
    let ds = synth_uci(5, uci_spec("vowel").unwrap());
    let mc = m.clone();
    let server = Server::start(cfg, move |_| {
        Ok(Box::new(NativeEngine::new(mc.clone())) as Box<dyn InferenceEngine>)
    })
    .unwrap();
    // bad request on its own channel: completion never arrives
    let (bad_tx, bad_rx) = mpsc::channel();
    server.submit(&[], bad_tx).unwrap();
    // good requests afterwards must still be served
    let (tx, rx) = mpsc::channel();
    for i in 0..8 {
        loop {
            match server.submit(ds.test_row(i), tx.clone()) {
                Ok(_) => break,
                Err(SubmitError::Full) => std::thread::sleep(Duration::from_micros(20)),
                Err(e) => panic!("{e:?}"),
            }
        }
    }
    drop(tx);
    let mut served = 0;
    while rx.recv_timeout(Duration::from_secs(5)).is_ok() {
        served += 1;
        if served == 8 {
            break;
        }
    }
    assert_eq!(served, 8, "server must keep serving after a malformed request");
    assert!(
        bad_rx.recv_timeout(Duration::from_secs(2)).is_err(),
        "zero-length request must never complete (its sender is dropped)"
    );
    assert_eq!(server.metrics.report(1).malformed, 1, "the drop must be counted");
    server.shutdown();
}

#[test]
fn malformed_request_in_batch_only_drops_the_offender() {
    // A wrong-width request sharing a micro-batch with well-formed ones
    // must NOT take the batch down: its batch-mates complete (with
    // correct predictions), only the offender's sender drops, and the
    // drop is counted in metrics.
    let m = model();
    let ds = synth_uci(5, uci_spec("vowel").unwrap());
    let expected: Vec<usize> = {
        let mut s = uleen::model::ensemble::EnsembleScratch::default();
        (0..ds.n_test()).map(|i| m.predict(ds.test_row(i), &mut s)).collect()
    };
    let cfg = ServerConfig {
        batcher: BatcherConfig {
            max_batch: 8,
            // long dwell so the bad request and its batch-mates coalesce
            // into ONE micro-batch deterministically
            max_wait: Duration::from_millis(100),
            capacity: 64,
        },
        workers: 1,
    };
    let mc = m.clone();
    let server = Server::start(cfg, move |_| {
        Ok(Box::new(NativeEngine::new(mc.clone())) as Box<dyn InferenceEngine>)
    })
    .unwrap();
    let (bad_tx, bad_rx) = mpsc::channel();
    let (tx, rx) = mpsc::channel();
    let f = server.num_features();
    server.submit(&vec![0.5; f + 3], bad_tx).unwrap(); // wrong width
    let mut id2row = std::collections::HashMap::new();
    for i in 0..5 {
        let id = server.submit(ds.test_row(i), tx.clone()).unwrap();
        id2row.insert(id, i);
    }
    drop(tx);
    let mut served = 0;
    while let Ok((id, pred)) = rx.recv_timeout(Duration::from_secs(5)) {
        assert_eq!(pred, expected[id2row[&id]], "batch-mates get correct predictions");
        served += 1;
        if served == 5 {
            break;
        }
    }
    assert_eq!(served, 5, "all well-formed batch-mates must complete");
    assert!(
        bad_rx.recv_timeout(Duration::from_millis(200)).is_err(),
        "the malformed request never completes"
    );
    assert_eq!(server.metrics.report(8).malformed, 1);
    server.shutdown();
}

#[test]
fn fast_path_fraction_counts_first_tier_resolutions_only() {
    use uleen::coordinator::router::ModelRouter;
    use uleen::runtime::Tier;

    // tier 0 resolves rows with x[0] > 0.5 and ties otherwise; tier 1
    // always ties (so every row it sees escalates); tier 2 resolves.
    // With 3 tiers, tier1→tier2 escalations used to be double-counted
    // against tier-0 totals, saturating the fraction to 0.
    struct Gate;
    impl InferenceEngine for Gate {
        fn label(&self) -> String {
            "gate".into()
        }
        fn num_features(&self) -> usize {
            1
        }
        fn num_classes(&self) -> usize {
            2
        }
        fn responses_into(&mut self, x: &[f32], n: usize, out: &mut [f32]) -> uleen::Result<()> {
            for (i, row) in out[..2 * n].chunks_mut(2).enumerate() {
                if x[i] > 0.5 {
                    row.copy_from_slice(&[4.0, 0.0]); // confident
                } else {
                    row.copy_from_slice(&[1.0, 1.0]); // dead tie
                }
            }
            Ok(())
        }
    }
    struct Tie;
    impl InferenceEngine for Tie {
        fn label(&self) -> String {
            "tie".into()
        }
        fn num_features(&self) -> usize {
            1
        }
        fn num_classes(&self) -> usize {
            2
        }
        fn responses_into(&mut self, _x: &[f32], n: usize, out: &mut [f32]) -> uleen::Result<()> {
            for row in out[..2 * n].chunks_mut(2) {
                row.copy_from_slice(&[1.0, 1.0]);
            }
            Ok(())
        }
    }
    struct Last;
    impl InferenceEngine for Last {
        fn label(&self) -> String {
            "last".into()
        }
        fn num_features(&self) -> usize {
            1
        }
        fn num_classes(&self) -> usize {
            2
        }
        fn responses_into(&mut self, _x: &[f32], n: usize, out: &mut [f32]) -> uleen::Result<()> {
            for row in out[..2 * n].chunks_mut(2) {
                row.copy_from_slice(&[2.0, 0.0]);
            }
            Ok(())
        }
    }
    let build = || {
        ModelRouter::new(
            vec![Box::new(Gate) as Box<dyn InferenceEngine>, Box::new(Tie), Box::new(Last)],
            vec![4.0, 2.0, 2.0],
        )
    };
    // 5 confident rows + 5 tie rows
    let x: Vec<f32> = (0..10).map(|i| if i < 5 { 1.0 } else { 0.0 }).collect();

    let mut seq = build();
    for i in 0..10 {
        seq.classify_cascade(&x[i..i + 1]).unwrap();
    }
    assert_eq!(seq.stats.served, [10, 5, 5]);
    assert_eq!(seq.stats.escalations(), 10);
    assert_eq!(seq.stats.escalations_from, [5, 5, 0]);
    // the old formula computed (10 - 10) / 10 = 0.0 here
    assert_eq!(
        seq.fast_path_fraction(),
        0.5,
        "only tier-0 escalations may count against tier-0 resolutions"
    );

    // same traffic through the batched cascade: identical stats
    let mut batch = build();
    batch.classify_cascade_batch(&x, 10).unwrap();
    assert_eq!(batch.stats.served, seq.stats.served);
    assert_eq!(batch.stats.escalations_from, seq.stats.escalations_from);
    assert_eq!(batch.fast_path_fraction(), 0.5);

    // tier-pinned traffic on other tiers must not move the fraction
    batch.classify_batch(&x, 10, Tier::Accurate).unwrap();
    assert_eq!(batch.fast_path_fraction(), 0.5);
}

#[test]
fn zoo_server_end_to_end_matches_local_ground_truth() {
    use uleen::coordinator::router::ModelRouter;
    use uleen::runtime::Tier;

    let ds = synth_uci(5, uci_spec("vowel").unwrap());
    let mut models = Vec::new();
    for (ipf, epf, bits) in [(8usize, 64usize, 2usize), (10, 128, 4)] {
        models.push(
            train_oneshot(
                &ds,
                &OneShotConfig {
                    inputs_per_filter: ipf,
                    entries_per_filter: epf,
                    therm_bits: bits,
                    ..Default::default()
                },
            )
            .0,
        );
    }
    let n = ds.n_test();
    // ground truth: local batched cascade + each tier alone
    let mut local = ModelRouter::from_models(&models);
    let cascade_want = local.classify_cascade_batch(&ds.test_x, n).unwrap();
    let fast_want = NativeEngine::new(models[0].clone()).classify(&ds.test_x, n).unwrap();
    let acc_want = NativeEngine::new(models[1].clone()).classify(&ds.test_x, n).unwrap();

    let cfg = ServerConfig {
        batcher: BatcherConfig {
            max_batch: 16,
            max_wait: Duration::from_micros(100),
            capacity: 4096,
        },
        workers: 3,
    };
    let server = Server::start_zoo(cfg, models, 0.05).unwrap();
    let (tx, rx) = mpsc::channel();
    // every row three ways: cascade, pinned fast, pinned accurate
    let mut id2want = std::collections::HashMap::new();
    for i in 0..n {
        for (tier, want) in [
            (None, cascade_want[i]),
            (Some(Tier::Fast), fast_want[i]),
            (Some(Tier::Accurate), acc_want[i]),
        ] {
            loop {
                match server.submit_tiered(ds.test_row(i), tier, tx.clone()) {
                    Ok(id) => {
                        id2want.insert(id, want);
                        break;
                    }
                    Err(SubmitError::Full) => std::thread::sleep(Duration::from_micros(10)),
                    Err(e) => panic!("{e:?}"),
                }
            }
        }
    }
    drop(tx);
    let mut served = 0usize;
    while let Ok((id, pred)) = rx.recv_timeout(Duration::from_secs(20)) {
        assert_eq!(
            pred, id2want[&id],
            "request {id}: served zoo prediction must match local ground truth"
        );
        served += 1;
        if served == 3 * n {
            break;
        }
    }
    assert_eq!(served, 3 * n, "every cascade and pinned request completes");
    let report = server.metrics.report(16);
    // cascade + pinned-fast traffic lands on tier 0; pinned-accurate (and
    // every cascade escalation) lands on tier 1
    assert!(report.tier_served[0] as usize >= 2 * n, "tier-0 sees cascade + pinned fast");
    assert!(report.tier_served[1] as usize >= n, "tier-1 sees pinned accurate");
    assert_eq!(
        report.tier_served[0] as usize + report.tier_served[1] as usize,
        3 * n + report.tier_escalations[0] as usize,
        "tier totals = requests + escalated sub-batch samples"
    );
    server.shutdown();
}

#[test]
fn queue_full_surfaces_submit_error_and_metrics() {
    // With no workers the queue cannot drain, so capacity overflow is
    // deterministic: the first `capacity` submits succeed, the next is
    // rejected with SubmitError::Full and counted in the metrics.
    let m = model();
    let cfg = ServerConfig {
        batcher: BatcherConfig {
            max_batch: 4,
            max_wait: Duration::from_micros(10),
            capacity: 8,
        },
        workers: 0,
    };
    let server = Server::start(cfg, move |_| {
        Ok(Box::new(NativeEngine::new(m.clone())) as Box<dyn InferenceEngine>)
    })
    .unwrap();
    let (tx, _rx) = mpsc::channel();
    for _ in 0..8 {
        server.submit(&[0.5; 4], tx.clone()).unwrap();
    }
    let err = server.submit(&[0.5; 4], tx.clone()).unwrap_err();
    assert_eq!(err, SubmitError::Full);
    assert_eq!(server.queue_depth(), 8);
    let report = server.metrics.report(4);
    assert_eq!(report.rejected_full, 1);
    server.shutdown();
}

#[test]
fn shutdown_while_producers_still_submitting_drains_accepted_requests() {
    let m = model();
    let cfg = ServerConfig {
        batcher: BatcherConfig {
            max_batch: 8,
            max_wait: Duration::from_micros(50),
            capacity: 4096,
        },
        workers: 2,
    };
    let f = m.encoder.num_inputs;
    let server = Server::start(cfg, move |_| {
        Ok(Box::new(NativeEngine::new(m.clone())) as Box<dyn InferenceEngine>)
    })
    .unwrap();
    let server = std::sync::Arc::new(server);
    let (tx, rx) = mpsc::channel();
    let producer = {
        let server = server.clone();
        let tx = tx.clone();
        std::thread::spawn(move || {
            let mut accepted = 0usize;
            let row = vec![0.5; f];
            loop {
                match server.submit(&row, tx.clone()) {
                    Ok(_) => accepted += 1,
                    Err(SubmitError::Closed) => break, // server closed mid-stream
                    Err(SubmitError::Full) => std::thread::sleep(Duration::from_micros(5)),
                }
            }
            accepted
        })
    };
    drop(tx);
    // let the producer get going, then close the intake mid-stream;
    // workers keep draining whatever was accepted
    std::thread::sleep(Duration::from_millis(5));
    server.close();
    let accepted = producer.join().unwrap();
    let server = std::sync::Arc::try_unwrap(server)
        .unwrap_or_else(|_| panic!("producer dropped its handle"));
    server.shutdown();
    // every ACCEPTED request must have completed (drain-on-shutdown)
    let mut completed = 0usize;
    while rx.try_recv().is_ok() {
        completed += 1;
    }
    assert_eq!(completed, accepted, "shutdown must drain all accepted requests");
    assert!(accepted > 0, "producer should have landed some requests before close");
}

#[test]
fn router_escalation_stats_account_for_forced_low_margin_traffic() {
    use uleen::coordinator::router::ModelRouter;

    // Engines that always return a dead tie → margin 0 → every cascade
    // request escalates through every tier; stats must add up exactly.
    struct Flat0;
    impl InferenceEngine for Flat0 {
        fn label(&self) -> String {
            "tie".into()
        }
        fn num_features(&self) -> usize {
            3
        }
        fn num_classes(&self) -> usize {
            4
        }
        fn responses_into(&mut self, _x: &[f32], n: usize, out: &mut [f32]) -> uleen::Result<()> {
            for row in out[..4 * n].chunks_mut(4) {
                row.copy_from_slice(&[1.0, 1.0, 1.0, 1.0]);
            }
            Ok(())
        }
    }
    let engines: Vec<Box<dyn InferenceEngine>> =
        vec![Box::new(Flat0), Box::new(Flat0), Box::new(Flat0)];
    let mut router = ModelRouter::new(engines, vec![4.0, 4.0, 4.0]);
    router.set_margin_threshold(0.05);
    let n = 25u64;
    for _ in 0..n {
        let p = router.classify_cascade(&[0.0, 0.0, 0.0]).unwrap();
        assert_eq!(p, 0, "dead tie breaks to class 0 at every tier");
    }
    assert_eq!(router.stats.served, [n, n, n], "every tier sees every request");
    assert_eq!(
        router.stats.escalations(),
        2 * n,
        "two escalations per request on a 3-tier zoo"
    );
    assert_eq!(router.fast_path_fraction(), 0.0);

    // Sanity: a huge margin on tier 0 stops the cascade immediately.
    struct Confident;
    impl InferenceEngine for Confident {
        fn label(&self) -> String {
            "confident".into()
        }
        fn num_features(&self) -> usize {
            3
        }
        fn num_classes(&self) -> usize {
            4
        }
        fn responses_into(&mut self, _x: &[f32], n: usize, out: &mut [f32]) -> uleen::Result<()> {
            for row in out[..4 * n].chunks_mut(4) {
                row.copy_from_slice(&[4.0, 0.0, 0.0, 0.0]);
            }
            Ok(())
        }
    }
    let engines: Vec<Box<dyn InferenceEngine>> =
        vec![Box::new(Confident), Box::new(Flat0)];
    let mut router = ModelRouter::new(engines, vec![4.0, 4.0]);
    for _ in 0..10 {
        assert_eq!(router.classify_cascade(&[0.0, 0.0, 0.0]).unwrap(), 0);
    }
    assert_eq!(router.stats.served, [10, 0, 0]);
    assert_eq!(router.stats.escalations(), 0);
    assert_eq!(router.fast_path_fraction(), 1.0);
}

#[test]
fn sharded_server_serves_identically_to_per_worker_engines() {
    let m = model();
    let ds = synth_uci(5, uci_spec("vowel").unwrap());
    let expected: Vec<usize> = {
        let mut s = uleen::model::ensemble::EnsembleScratch::default();
        (0..ds.n_test()).map(|i| m.predict(ds.test_row(i), &mut s)).collect()
    };
    let cfg = ServerConfig {
        batcher: BatcherConfig {
            max_batch: 32,
            max_wait: Duration::from_micros(100),
            capacity: 4096,
        },
        workers: 4, // overridden to 1 by start_sharded
    };
    let server = Server::start_sharded(cfg, m, 3).unwrap();
    let (tx, rx) = mpsc::channel();
    let mut id2row = std::collections::HashMap::new();
    for i in 0..ds.n_test() {
        let id = server.submit(ds.test_row(i), tx.clone()).unwrap();
        id2row.insert(id, i);
    }
    drop(tx);
    let mut got = vec![usize::MAX; ds.n_test()];
    for _ in 0..ds.n_test() {
        let (id, pred) = rx.recv_timeout(Duration::from_secs(10)).unwrap();
        got[id2row[&id]] = pred;
    }
    server.shutdown();
    assert_eq!(got, expected, "sharded serving must match direct inference");
}

/// Zoo models for the sharded-cascade suite (small → large, shared
/// feature width / class count).
fn zoo_models(n_tiers: usize) -> Vec<uleen::model::ensemble::UleenModel> {
    let ds = synth_uci(5, uci_spec("vowel").unwrap());
    [(8usize, 64usize, 2usize), (10, 128, 4), (10, 256, 8)][..n_tiers]
        .iter()
        .map(|&(ipf, epf, bits)| {
            train_oneshot(
                &ds,
                &OneShotConfig {
                    inputs_per_filter: ipf,
                    entries_per_filter: epf,
                    therm_bits: bits,
                    ..Default::default()
                },
            )
            .0
        })
        .collect()
}

#[test]
fn sharded_zoo_panicking_tier_counts_batches_failed_without_wedging_pool() {
    use uleen::coordinator::router::ModelRouter;
    use uleen::runtime::ShardedRouterEngine;

    // A tier engine that panics on a poison input — the stand-in for a
    // violated kernel invariant inside one shard's cascade.
    struct Poisonable;
    impl InferenceEngine for Poisonable {
        fn label(&self) -> String {
            "poisonable".into()
        }
        fn num_features(&self) -> usize {
            2
        }
        fn num_classes(&self) -> usize {
            2
        }
        fn responses_into(&mut self, x: &[f32], n: usize, out: &mut [f32]) -> uleen::Result<()> {
            for (i, row) in out[..2 * n].chunks_mut(2).enumerate() {
                assert!(x[i * 2] < 9000.0, "injected tier panic");
                row.copy_from_slice(&[4.0, 0.0]); // confident: no escalation
            }
            Ok(())
        }
    }
    let make_routers = || -> Vec<ModelRouter> {
        (0..3)
            .map(|_| {
                ModelRouter::new(
                    vec![
                        Box::new(Poisonable) as Box<dyn InferenceEngine>,
                        Box::new(Poisonable),
                    ],
                    vec![4.0, 4.0],
                )
            })
            .collect()
    };

    // Direct: a poison batch surfaces as Err (NOT a panic of the caller,
    // NOT a deadlock), and the SAME pool keeps serving afterwards.
    let mut eng = ShardedRouterEngine::from_routers(make_routers());
    let good = vec![0.5f32; 8 * 2];
    assert_eq!(eng.classify(&good, 8).unwrap(), vec![0; 8]);
    let mut poison = good.clone();
    poison[0] = 9001.0;
    assert!(
        eng.classify(&poison, 8).is_err(),
        "a panicking tier engine must surface as Err to the caller"
    );
    let spawned = eng.threads_spawned();
    assert_eq!(
        eng.classify(&good, 8).unwrap(),
        vec![0; 8],
        "the pool must survive the panic and keep serving"
    );
    assert_eq!(eng.threads_spawned(), spawned, "recovery must not respawn workers");

    // Through the coordinator: the poisoned micro-batch lands in
    // batches_failed, its sender drops, and later traffic completes.
    let cfg = ServerConfig {
        batcher: BatcherConfig {
            max_batch: 1, // isolate the poison request in its own batch
            max_wait: Duration::from_micros(10),
            capacity: 64,
        },
        workers: 1,
    };
    let server = Server::start(cfg, move |_| {
        Ok(Box::new(ShardedRouterEngine::from_routers(make_routers()))
            as Box<dyn InferenceEngine>)
    })
    .unwrap();
    let (tx, rx) = mpsc::channel();
    let (poison_tx, poison_rx) = mpsc::channel();
    for _ in 0..5 {
        server.submit(&[0.5; 2], tx.clone()).unwrap();
    }
    server.submit(&[9001.0, 0.5], poison_tx).unwrap();
    for _ in 0..5 {
        server.submit(&[0.5; 2], tx.clone()).unwrap();
    }
    drop(tx);
    let mut served = 0;
    while rx.recv_timeout(Duration::from_secs(5)).is_ok() {
        served += 1;
    }
    assert_eq!(served, 10, "every well-formed batch completes around the failure");
    assert!(
        poison_rx.recv_timeout(Duration::from_millis(200)).is_err(),
        "the poisoned batch never completes (its sender is dropped)"
    );
    let report = server.metrics.report(1);
    assert_eq!(report.batches_failed, 1, "the failure must be counted, not swallowed");
    assert_eq!(report.completed, 10);
    server.shutdown();
}

#[test]
fn sharded_zoo_malformed_rows_only_drop_the_offender() {
    use uleen::coordinator::router::ModelRouter;

    let models = zoo_models(2);
    let ds = synth_uci(5, uci_spec("vowel").unwrap());
    // ground truth: the local batched cascade, per row (row-independent)
    let mut local = ModelRouter::from_models(&models);
    let cascade_want = local.classify_cascade_batch(&ds.test_x, ds.n_test()).unwrap();
    let cfg = ServerConfig {
        batcher: BatcherConfig {
            max_batch: 8,
            // long dwell so the bad request and its batch-mates coalesce
            // into ONE micro-batch deterministically
            max_wait: Duration::from_millis(100),
            capacity: 64,
        },
        workers: 4, // forced to 1 by start_zoo_sharded
    };
    let server = Server::start_zoo_sharded(cfg, models, 0.05, 3).unwrap();
    let f = server.num_features();
    let (bad_tx, bad_rx) = mpsc::channel();
    let (tx, rx) = mpsc::channel();
    server.submit(&vec![0.5; f + 3], bad_tx).unwrap(); // wrong width
    let mut id2row = std::collections::HashMap::new();
    for i in 0..5 {
        let id = server.submit(ds.test_row(i), tx.clone()).unwrap();
        id2row.insert(id, i);
    }
    drop(tx);
    let mut served = 0;
    while let Ok((id, pred)) = rx.recv_timeout(Duration::from_secs(5)) {
        assert_eq!(
            pred, cascade_want[id2row[&id]],
            "batch-mates complete with bit-exact sharded-cascade predictions"
        );
        served += 1;
        if served == 5 {
            break;
        }
    }
    assert_eq!(served, 5, "all well-formed batch-mates must complete");
    assert!(
        bad_rx.recv_timeout(Duration::from_millis(200)).is_err(),
        "the malformed request never completes"
    );
    let report = server.metrics.report(8);
    assert_eq!(report.malformed, 1, "the drop must be counted");
    assert_eq!(report.batches_failed, 0, "a malformed row is not an engine failure");
    server.shutdown();
}

#[test]
fn close_while_draining_sharded_zoo_accounts_for_every_request() {
    use uleen::runtime::Tier;

    let models = zoo_models(2);
    let cfg = ServerConfig {
        batcher: BatcherConfig {
            max_batch: 16,
            max_wait: Duration::from_micros(50),
            capacity: 4096,
        },
        workers: 1,
    };
    let f = models[0].encoder.num_inputs;
    let server = std::sync::Arc::new(Server::start_zoo_sharded(cfg, models, 0.05, 4).unwrap());
    let (tx, rx) = mpsc::channel();
    let producer = {
        let server = server.clone();
        let tx = tx.clone();
        std::thread::spawn(move || {
            let mut accepted = 0usize;
            let row = vec![0.5; f];
            // mixed cascade + pinned traffic, so the drain crosses
            // tier-homogeneous batch splits too
            for i in 0.. {
                let tier = match i % 3 {
                    0 => None,
                    1 => Some(Tier::Fast),
                    _ => Some(Tier::Accurate),
                };
                match server.submit_tiered(&row, tier, tx.clone()) {
                    Ok(_) => accepted += 1,
                    Err(SubmitError::Closed) => break,
                    Err(SubmitError::Full) => std::thread::sleep(Duration::from_micros(5)),
                }
            }
            accepted
        })
    };
    drop(tx);
    std::thread::sleep(Duration::from_millis(5));
    server.close();
    let accepted = producer.join().unwrap();
    let server = std::sync::Arc::try_unwrap(server)
        .unwrap_or_else(|_| panic!("producer dropped its handle"));
    let metrics = server.metrics.clone();
    server.shutdown();
    let mut completed = 0usize;
    while rx.try_recv().is_ok() {
        completed += 1;
    }
    assert!(accepted > 0, "producer should have landed requests before close");
    let report = metrics.report(16);
    assert_eq!(
        completed as u64 + report.malformed,
        accepted as u64,
        "every accepted request is delivered or accounted (none malformed here, \
         none silently lost)"
    );
    assert_eq!(report.malformed, 0);
    assert_eq!(report.batches_failed, 0);
    assert_eq!(report.completed, completed as u64);
}

#[test]
fn sharded_zoo_shares_each_tier_zero_clones_and_reshares_on_swap() {
    use std::sync::Arc;
    use uleen::runtime::{SharedModel, ShardedRouterEngine};

    let shards = 4usize;
    let tiers: Vec<SharedModel> =
        zoo_models(3).into_iter().map(SharedModel::compile).collect();
    let mut eng = ShardedRouterEngine::from_shared(tiers.clone(), 0.05, shards);
    // 1 handle here + 1 in the engine's tier list + 1 per pool worker's
    // router — and NOT ONE more: the model was cloned zero times after
    // construction (a deep clone would not register in the Arc count,
    // so any extra construction-path clone shows up as a mismatch).
    for (i, t) in tiers.iter().enumerate() {
        assert_eq!(
            Arc::strong_count(t.model()),
            2 + shards,
            "tier {i}: model shared, never cloned"
        );
        assert_eq!(
            Arc::strong_count(t.flat()),
            2 + shards,
            "tier {i}: compiled layout shared, never recompiled"
        );
    }
    let ds = synth_uci(5, uci_spec("vowel").unwrap());
    let preds = eng.classify(&ds.test_x, ds.n_test()).unwrap();
    assert_eq!(preds.len(), ds.n_test());
    for t in &tiers {
        assert_eq!(
            Arc::strong_count(t.model()),
            2 + shards,
            "classification must not clone models either"
        );
    }

    // swap_shared re-shares: the new zoo lands at the same handle count,
    // the old zoo's Arcs are FULLY released (tables freed exactly once).
    let new_tiers: Vec<SharedModel> =
        zoo_models(2).into_iter().map(SharedModel::compile).collect();
    eng.swap_shared(new_tiers.clone());
    for (i, t) in new_tiers.iter().enumerate() {
        assert_eq!(
            Arc::strong_count(t.model()),
            2 + shards,
            "tier {i}: swapped-in zoo re-shares without clones"
        );
    }
    for (i, t) in tiers.iter().enumerate() {
        assert_eq!(
            Arc::strong_count(t.model()),
            1,
            "tier {i}: swapped-out zoo fully released"
        );
    }
    let preds = eng.classify(&ds.test_x, ds.n_test()).unwrap();
    assert_eq!(preds.len(), ds.n_test());
    drop(eng);
    for t in &new_tiers {
        assert_eq!(Arc::strong_count(t.model()), 1, "engine drop releases every handle");
    }
}

#[test]
fn queue_depth_reflects_backlog_and_drains() {
    let m = model();
    let cfg = ServerConfig {
        batcher: BatcherConfig {
            max_batch: 16,
            max_wait: Duration::from_millis(1),
            capacity: 1024,
        },
        workers: 1,
    };
    let server = Server::start(cfg, move |_| Ok(Box::new(NativeEngine::new(m.clone())) as Box<dyn InferenceEngine>)).unwrap();
    let (tx, rx) = mpsc::channel();
    let row = vec![0.5; server.num_features()];
    for _ in 0..256 {
        let _ = server.submit(&row, tx.clone());
    }
    drop(tx);
    let mut got = 0;
    while rx.recv_timeout(Duration::from_secs(10)).is_ok() {
        got += 1;
    }
    assert!(got > 0);
    assert_eq!(server.queue_depth(), 0, "queue must drain");
    server.shutdown();
}
