//! Integration: the serving coordinator under load, concurrency and
//! failure injection.

use std::sync::mpsc;
use std::time::Duration;
use uleen::coordinator::batcher::{BatcherConfig, SubmitError};
use uleen::coordinator::server::{Server, ServerConfig};
use uleen::data::synth_uci::{synth_uci, uci_spec};
use uleen::runtime::{InferenceEngine, NativeEngine};
use uleen::train::oneshot::{train_oneshot, OneShotConfig};

fn model() -> uleen::model::ensemble::UleenModel {
    let ds = synth_uci(5, uci_spec("vowel").unwrap());
    train_oneshot(
        &ds,
        &OneShotConfig { inputs_per_filter: 10, entries_per_filter: 128, therm_bits: 4, ..Default::default() },
    )
    .0
}

#[test]
fn many_producers_many_workers_all_served_correctly() {
    let m = model();
    let ds = synth_uci(5, uci_spec("vowel").unwrap());
    let expected: Vec<usize> = {
        let mut s = uleen::model::ensemble::EnsembleScratch::default();
        (0..ds.n_test()).map(|i| m.predict(ds.test_row(i), &mut s)).collect()
    };
    let cfg = ServerConfig {
        batcher: BatcherConfig {
            max_batch: 8,
            max_wait: Duration::from_micros(100),
            capacity: 4096,
        },
        workers: 4,
    };
    let mc = m.clone();
    let server = std::sync::Arc::new(
        Server::start(cfg, move |_| Ok(Box::new(NativeEngine::new(mc.clone())) as Box<dyn InferenceEngine>)).unwrap(),
    );
    let (tx, rx) = mpsc::channel();
    let reps = 8usize;
    let mut handles = Vec::new();
    let ds = std::sync::Arc::new(ds);
    for _ in 0..4 {
        let server = server.clone();
        let tx = tx.clone();
        let ds = ds.clone();
        handles.push(std::thread::spawn(move || {
            let mut ids = Vec::new();
            for r in 0..reps {
                for i in 0..ds.n_test() {
                    loop {
                        match server.submit(ds.test_row(i).to_vec(), tx.clone()) {
                            Ok(id) => {
                                ids.push((id, i));
                                break;
                            }
                            Err(SubmitError::Full) => std::thread::sleep(Duration::from_micros(10)),
                            Err(e) => panic!("{e:?}"),
                        }
                    }
                }
                let _ = r;
            }
            ids
        }));
    }
    drop(tx);
    let mut id2row = std::collections::HashMap::new();
    let mut total = 0usize;
    for h in handles {
        for (id, row) in h.join().unwrap() {
            id2row.insert(id, row);
            total += 1;
        }
    }
    let mut served = 0usize;
    while let Ok((id, pred, _)) = rx.recv_timeout(Duration::from_secs(20)) {
        let row = id2row[&id];
        assert_eq!(pred, expected[row], "request {id} row {row}");
        served += 1;
        if served == total {
            break;
        }
    }
    assert_eq!(served, total);
    let report = server.metrics.report(8);
    assert_eq!(report.completed as usize, total);
    assert!(report.mean_batch_fill > 0.1);
    std::sync::Arc::try_unwrap(server).ok().map(|s| s.shutdown());
}

#[test]
fn worker_engine_failure_does_not_wedge_the_server() {
    // An engine that fails on every Nth batch: the coordinator must keep
    // serving the rest (failed batches observable as dropped channels).
    struct Flaky {
        calls: usize,
    }
    impl InferenceEngine for Flaky {
        fn label(&self) -> String {
            "flaky".into()
        }
        fn num_features(&self) -> usize {
            4
        }
        fn num_classes(&self) -> usize {
            2
        }
        fn responses(&mut self, _x: &[f32], n: usize) -> uleen::Result<Vec<f32>> {
            self.calls += 1;
            if self.calls % 3 == 0 {
                anyhow::bail!("injected failure");
            }
            Ok(vec![1.0, 0.0].repeat(n))
        }
    }
    let cfg = ServerConfig {
        batcher: BatcherConfig {
            max_batch: 4,
            max_wait: Duration::from_micros(50),
            capacity: 64,
        },
        workers: 1,
    };
    let server = Server::start(cfg, |_| Ok(Box::new(Flaky { calls: 0 }) as Box<dyn InferenceEngine>)).unwrap();
    let (tx, rx) = mpsc::channel();
    let n = 60;
    for _ in 0..n {
        loop {
            match server.submit(vec![0.0; 4], tx.clone()) {
                Ok(_) => break,
                Err(SubmitError::Full) => std::thread::sleep(Duration::from_micros(20)),
                Err(e) => panic!("{e:?}"),
            }
        }
    }
    drop(tx);
    // collect whatever completes; must be nonzero and the server must shut
    // down cleanly (no deadlock).
    let mut ok = 0;
    while rx.recv_timeout(Duration::from_millis(500)).is_ok() {
        ok += 1;
    }
    assert!(ok > 0, "some batches must survive the flaky engine");
    assert!(ok < n, "some batches must have failed (injection active)");
    server.shutdown();
}

#[test]
fn queue_depth_reflects_backlog_and_drains() {
    let m = model();
    let cfg = ServerConfig {
        batcher: BatcherConfig {
            max_batch: 16,
            max_wait: Duration::from_millis(1),
            capacity: 1024,
        },
        workers: 1,
    };
    let server = Server::start(cfg, move |_| Ok(Box::new(NativeEngine::new(m.clone())) as Box<dyn InferenceEngine>)).unwrap();
    let (tx, rx) = mpsc::channel();
    for _ in 0..256 {
        let _ = server.submit(vec![0.5; server.num_features()], tx.clone());
    }
    drop(tx);
    let mut got = 0;
    while rx.recv_timeout(Duration::from_secs(10)).is_ok() {
        got += 1;
    }
    assert!(got > 0);
    assert_eq!(server.queue_depth(), 0, "queue must drain");
    server.shutdown();
}
