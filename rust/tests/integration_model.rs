//! Integration: train → prune → save → load → eval across module
//! boundaries, plus cross-baseline sanity (ULEEN vs WiSARD vs Bloom
//! WiSARD orderings the paper relies on).

use uleen::data::synth_uci::{synth_uci, uci_spec, UciSpec};
use uleen::data::{synth_mnist, Dataset};
use uleen::encoding::thermometer::{ThermometerEncoder, ThermometerKind};
use uleen::model::bloom_wisard::BloomWisard;
use uleen::model::uln_format;
use uleen::train::oneshot::{train_oneshot, OneShotConfig};
use uleen::train::prune::prune_model;
use uleen::util::json::Json;
use uleen::util::rng::Rng;

fn small_mnist() -> Dataset {
    synth_mnist(77, 1500, 400)
}

#[test]
fn full_lifecycle_train_prune_save_load_eval() {
    let ds = small_mnist();
    let cfg = OneShotConfig {
        inputs_per_filter: 16,
        entries_per_filter: 256,
        therm_bits: 2,
        ..Default::default()
    };
    let (mut model, report) = train_oneshot(&ds, &cfg);
    assert!(report.val_accuracy > 0.5);
    let acc0 = model.evaluate(&ds.test_x, &ds.test_y, ds.num_features).accuracy();
    assert!(acc0 > 0.6, "one-shot mnist acc {acc0}");
    prune_model(&mut model, &ds, 0.3);
    let acc1 = model.evaluate(&ds.test_x, &ds.test_y, ds.num_features).accuracy();
    assert!(acc1 > acc0 - 0.1, "pruning cost too much: {acc0} -> {acc1}");
    let dir = std::env::temp_dir().join("uleen_integration");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("lifecycle.uln");
    let mut meta = Json::obj();
    meta.set("name", Json::Str("lifecycle".into()));
    uln_format::save(&model, &meta, &path).unwrap();
    let (back, _) = uln_format::load(&path).unwrap();
    let acc2 = back.evaluate(&ds.test_x, &ds.test_y, ds.num_features).accuracy();
    assert_eq!(acc1, acc2, "accuracy must survive the .uln roundtrip exactly");
}

#[test]
fn bleaching_beats_no_bleaching_on_skewed_data() {
    // The paper's Shuttle finding (§V-E): with 80% of training data in one
    // class and small tables, the majority discriminator SATURATES without
    // bleaching. Same geometry for both models; only counting+bleaching
    // (and H3 vs Murmur) differ.
    let spec = UciSpec { n_train: 8000, n_test: 1500, ..*uci_spec("shuttle").unwrap() };
    let ds = synth_uci(5, &spec);
    let (uleen_model, report) = train_oneshot(
        &ds,
        &OneShotConfig {
            inputs_per_filter: 16,
            entries_per_filter: 64,
            therm_bits: 6,
            therm_kind: ThermometerKind::Linear, // isolate the bleaching effect
            ..Default::default()
        },
    );
    let uleen_acc = uleen_model.evaluate(&ds.test_x, &ds.test_y, ds.num_features).accuracy();
    let enc = ThermometerEncoder::fit(ThermometerKind::Linear, &ds.train_x, ds.num_features, 6);
    let mut rng = Rng::new(9);
    let mut bw = BloomWisard::new(&mut rng, enc, 16, 64, 2, ds.num_classes);
    bw.train(&ds.train_x, &ds.train_y, ds.num_features);
    let bw_acc = bw.evaluate(&ds.test_x, &ds.test_y, ds.num_features).accuracy();
    assert!(bw.mean_fill() > 0.25, "baseline should be partially saturated: {}", bw.mean_fill());
    assert!(
        uleen_acc > bw_acc,
        "bleaching (b={}) must rescue skewed data: uleen {uleen_acc} vs bloom-wisard {bw_acc}",
        report.bleach
    );
}

#[test]
fn gaussian_encoding_beats_linear_on_normal_data_with_outliers() {
    // The paper's §III-A2 rationale: with equal-interval thresholds, "a
    // large number of bits may be dedicated to encoding outlying values".
    // Build a 3-class dataset whose features ARE normal around class means
    // plus rare extreme outliers — Gaussian quantile thresholds must win.
    let mut rng = Rng::new(42);
    let classes = 3usize;
    let features = 6usize;
    let gen = |rng: &mut Rng, n: usize| -> (Vec<f32>, Vec<u16>) {
        let mut xs = Vec::new();
        let mut ys = Vec::new();
        for i in 0..n {
            let c = i % classes;
            ys.push(c as u16);
            for f in 0..features {
                let mean = (c as f64 - 1.0) * 0.4 + f as f64 * 0.01;
                let mut v = mean + 0.5 * rng.normal_clt();
                // 2% extreme outliers stretch the linear range 50x
                if rng.below(50) == 0 {
                    v += if rng.below(2) == 0 { 60.0 } else { -60.0 };
                }
                xs.push(v as f32);
            }
        }
        (xs, ys)
    };
    let (train_x, train_y) = gen(&mut rng, 1200);
    let (test_x, test_y) = gen(&mut rng, 600);
    let ds = uleen::data::Dataset {
        name: "outliers".into(),
        num_features: features,
        num_classes: classes,
        train_x,
        train_y,
        test_x,
        test_y,
    };
    let acc_of = |kind| {
        let (m, _) = train_oneshot(
            &ds,
            &OneShotConfig {
                inputs_per_filter: 8,
                entries_per_filter: 64,
                therm_bits: 6,
                therm_kind: kind,
                ..Default::default()
            },
        );
        m.evaluate(&ds.test_x, &ds.test_y, ds.num_features).accuracy()
    };
    let lin = acc_of(ThermometerKind::Linear);
    let gau = acc_of(ThermometerKind::Gaussian);
    assert!(
        gau > lin,
        "gaussian ({gau}) must beat linear ({lin}) when outliers stretch the range"
    );
}

#[test]
fn ensemble_of_weak_models_beats_members() {
    // Core ensemble claim (§III-A3): combine one-shot submodels trained
    // with different n by summing responses; the ensemble should beat the
    // weakest member and generally match/beat the best.
    let ds = small_mnist();
    let mut models = Vec::new();
    for n in [12usize, 16, 20] {
        let (m, _) = train_oneshot(
            &ds,
            &OneShotConfig {
                inputs_per_filter: n,
                entries_per_filter: 128,
                therm_bits: 2,
                seed: 1000 + n as u64,
                ..Default::default()
            },
        );
        models.push(m);
    }
    let accs: Vec<f64> = models
        .iter()
        .map(|m| m.evaluate(&ds.test_x, &ds.test_y, ds.num_features).accuracy())
        .collect();
    // merge into one ensemble (same encoder config → same thermometer fit)
    let mut ensemble = models[0].clone();
    for m in &models[1..] {
        ensemble.submodels.extend(m.submodels.iter().cloned());
    }
    ensemble.validate().unwrap();
    let eacc = ensemble.evaluate(&ds.test_x, &ds.test_y, ds.num_features).accuracy();
    let worst = accs.iter().cloned().fold(f64::INFINITY, f64::min);
    let best = accs.iter().cloned().fold(0.0f64, f64::max);
    assert!(eacc > worst, "ensemble {eacc} must beat worst member {worst}");
    assert!(eacc > best - 0.02, "ensemble {eacc} should be near/above best member {best}");
}

#[test]
fn thermometer_bits_monotone_data_volume() {
    // more encoding bits → more encoded input bits → more filters
    let ds = synth_uci(3, uci_spec("wine").unwrap());
    let (m2, _) = train_oneshot(
        &ds,
        &OneShotConfig { therm_bits: 2, inputs_per_filter: 8, entries_per_filter: 64, ..Default::default() },
    );
    let (m8, _) = train_oneshot(
        &ds,
        &OneShotConfig { therm_bits: 8, inputs_per_filter: 8, entries_per_filter: 64, ..Default::default() },
    );
    assert!(m8.encoded_bits() == 4 * m2.encoded_bits());
    assert!(m8.size_kib() > m2.size_kib());
}

#[test]
fn corrupted_uln_rejected_loudly() {
    let ds = synth_uci(3, uci_spec("iris").unwrap());
    let (model, _) = train_oneshot(&ds, &OneShotConfig::default());
    let bytes = uln_format::to_bytes(&model, &Json::obj());
    for i in [4usize, 20, bytes.len() / 2, bytes.len() - 12] {
        let mut bad = bytes.clone();
        bad[i] ^= 0x80;
        assert!(
            uln_format::from_bytes(&bad, "x").is_err(),
            "corruption at byte {i} must be detected"
        );
    }
}

// ---------------------------------------------------------------------------
// Hostile .uln input. `corrupted_uln_rejected_loudly` above relies on the
// FNV-1a trailer; these tests RE-SEAL the checksum after every mutation, so
// they exercise the parse-level bounds a deliberate attacker (or a tool that
// recomputes trailers) would face: forged header counts must fail fast on
// their own plausibility checks, never trigger a header-sized allocation,
// and never panic.

fn fnv1a(bytes: &[u8]) -> u64 {
    bytes
        .iter()
        .fold(0xcbf29ce484222325u64, |h, &b| (h ^ b as u64).wrapping_mul(0x100000001b3))
}

/// Append a freshly computed checksum to a checksum-less body.
fn reseal(mut body: Vec<u8>) -> Vec<u8> {
    let sum = fnv1a(&body);
    body.extend_from_slice(&sum.to_le_bytes());
    body
}

/// Overwrite the little-endian u32 at `off`, then re-seal, so only the
/// parse-level guards can reject the result.
fn patch_u32(bytes: &[u8], off: usize, val: u32) -> Vec<u8> {
    let mut body = bytes[..bytes.len() - 8].to_vec();
    body[off..off + 4].copy_from_slice(&val.to_le_bytes());
    reseal(body)
}

/// A small trained model serialized to bytes, plus the byte offset of the
/// first submodel header (fields: ipf, epf, k_hashes, num_classes,
/// num_filters — each 4 bytes).
fn hostile_fixture() -> (Vec<u8>, usize) {
    let ds = synth_uci(11, uci_spec("iris").unwrap());
    let (model, _) = train_oneshot(&ds, &OneShotConfig::default());
    let bytes = uln_format::to_bytes(&model, &Json::obj());
    // Layout: magic(4) version(4) kind(4) num_inputs(4) bits(4),
    // thresholds (num_inputs*bits f32s), num_submodels(4), submodel 0.
    let sm0 = 24 + model.encoder.num_inputs * model.encoder.bits * 4;
    (bytes, sm0)
}

#[test]
fn forged_header_counts_rejected_by_bounds_not_checksum() {
    let (bytes, sm0) = hostile_fixture();
    // Sanity: the pristine file still loads.
    uln_format::from_bytes(&bytes, "x").unwrap();
    let cases: [(usize, u32, &str); 6] = [
        (12, u32::MAX, "implausible encoder dims"), // num_inputs
        (16, u32::MAX, "implausible encoder dims"), // bits
        (sm0 + 4, 1u32 << 31, "bad table size"),    // entries_per_filter
        (sm0 + 8, u32::MAX, "implausible hash count"), // k_hashes
        (sm0 + 12, u32::MAX, "implausible class count"), // num_classes
        (sm0 + 16, u32::MAX, "inconsistent"),       // num_filters
    ];
    for (off, val, want) in cases {
        let bad = patch_u32(&bytes, off, val);
        let err = uln_format::from_bytes(&bad, "x").unwrap_err().to_string();
        assert!(
            !err.contains("checksum"),
            "offset {off}: must be caught by a parse guard, not the trailer: {err}"
        );
        assert!(err.contains(want), "offset {off}: expected '{want}' in: {err}");
    }
}

#[test]
fn truncation_at_any_length_errs_never_panics() {
    let (bytes, _) = hostile_fixture();
    let body = &bytes[..bytes.len() - 8];
    // Every strict prefix, re-sealed so the checksum is valid, must still
    // fail: some declared field always extends past the cut.
    let mut k = 0;
    while k < body.len() {
        let bad = reseal(body[..k].to_vec());
        assert!(
            uln_format::from_bytes(&bad, "x").is_err(),
            "truncation to {k} bytes must be rejected"
        );
        k += 7;
    }
}

#[test]
fn resealed_random_bitflips_never_panic() {
    let (bytes, _) = hostile_fixture();
    let body = &bytes[..bytes.len() - 8];
    let mut rng = Rng::new(0xB17F);
    for _ in 0..400 {
        let mut b = body.to_vec();
        let pos = rng.below(b.len() as u64) as usize;
        b[pos] ^= 1u8 << rng.below(8);
        // Ok is allowed — flipping a threshold mantissa yields a different
        // but well-formed model. Panicking or over-allocating is not.
        let _ = uln_format::from_bytes(&reseal(b), "x");
    }
}

#[test]
fn prop_uln_roundtrip_over_random_shapes() {
    use uleen::model::{Submodel, SubmodelConfig, UleenModel};
    use uleen::util::prop::{check, Config};

    check(
        "uln-roundtrip-random-shapes",
        &Config { cases: 24, min_size: 1, max_size: 24, seed: 0x0A1B },
        |rng, size| {
            let num_inputs = 1 + rng.below(4 + size as u64) as usize;
            let bits = 1 + rng.below(6) as usize;
            let data: Vec<f32> =
                (0..num_inputs * 40).map(|_| rng.f64() as f32 * 10.0).collect();
            let encoder = ThermometerEncoder::fit(
                if rng.below(2) == 0 { ThermometerKind::Linear } else { ThermometerKind::Gaussian },
                &data,
                num_inputs,
                bits,
            );
            let total = num_inputs * bits;
            let num_submodels = 1 + rng.below(3) as usize;
            let num_classes = 2 + rng.below(5) as usize;
            let submodels: Vec<Submodel> = (0..num_submodels)
                .map(|_| {
                    let cfg = SubmodelConfig {
                        inputs_per_filter: 1 + rng.below(total.min(16) as u64) as usize,
                        entries_per_filter: 8 << rng.below(5),
                        k_hashes: 1 + rng.below(4) as usize,
                        num_classes,
                        total_input_bits: total,
                    };
                    let mut sm = Submodel::new_random(rng, cfg);
                    for d in &mut sm.discriminators {
                        for f in d.filters.iter_mut() {
                            if rng.below(8) == 0 {
                                *f = None; // pruned filter
                                continue;
                            }
                            let filt = f.as_mut().unwrap();
                            for i in 0..filt.entries() {
                                if rng.below(3) == 0 {
                                    filt.table.set(i);
                                }
                            }
                        }
                    }
                    for b in &mut sm.bias {
                        *b = rng.below(9) as i32 - 4;
                    }
                    sm
                })
                .collect();
            let model = UleenModel { name: "prop".into(), encoder, submodels };
            uln_format::to_bytes(&model, &Json::obj())
        },
        |bytes| {
            let (back, _) = uln_format::from_bytes(bytes, "prop")
                .map_err(|e| format!("roundtrip load failed: {e}"))?;
            let again = uln_format::to_bytes(&back, &Json::obj());
            if again == *bytes {
                Ok(())
            } else {
                Err("serialize(load(bytes)) != bytes".into())
            }
        },
    );
}
