//! Integration: train → prune → save → load → eval across module
//! boundaries, plus cross-baseline sanity (ULEEN vs WiSARD vs Bloom
//! WiSARD orderings the paper relies on).

use uleen::data::synth_uci::{synth_uci, uci_spec, UciSpec};
use uleen::data::{synth_mnist, Dataset};
use uleen::encoding::thermometer::{ThermometerEncoder, ThermometerKind};
use uleen::model::bloom_wisard::BloomWisard;
use uleen::model::uln_format;
use uleen::train::oneshot::{train_oneshot, OneShotConfig};
use uleen::train::prune::prune_model;
use uleen::util::json::Json;
use uleen::util::rng::Rng;

fn small_mnist() -> Dataset {
    synth_mnist(77, 1500, 400)
}

#[test]
fn full_lifecycle_train_prune_save_load_eval() {
    let ds = small_mnist();
    let cfg = OneShotConfig {
        inputs_per_filter: 16,
        entries_per_filter: 256,
        therm_bits: 2,
        ..Default::default()
    };
    let (mut model, report) = train_oneshot(&ds, &cfg);
    assert!(report.val_accuracy > 0.5);
    let acc0 = model.evaluate(&ds.test_x, &ds.test_y, ds.num_features).accuracy();
    assert!(acc0 > 0.6, "one-shot mnist acc {acc0}");
    prune_model(&mut model, &ds, 0.3);
    let acc1 = model.evaluate(&ds.test_x, &ds.test_y, ds.num_features).accuracy();
    assert!(acc1 > acc0 - 0.1, "pruning cost too much: {acc0} -> {acc1}");
    let dir = std::env::temp_dir().join("uleen_integration");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("lifecycle.uln");
    let mut meta = Json::obj();
    meta.set("name", Json::Str("lifecycle".into()));
    uln_format::save(&model, &meta, &path).unwrap();
    let (back, _) = uln_format::load(&path).unwrap();
    let acc2 = back.evaluate(&ds.test_x, &ds.test_y, ds.num_features).accuracy();
    assert_eq!(acc1, acc2, "accuracy must survive the .uln roundtrip exactly");
}

#[test]
fn bleaching_beats_no_bleaching_on_skewed_data() {
    // The paper's Shuttle finding (§V-E): with 80% of training data in one
    // class and small tables, the majority discriminator SATURATES without
    // bleaching. Same geometry for both models; only counting+bleaching
    // (and H3 vs Murmur) differ.
    let spec = UciSpec { n_train: 8000, n_test: 1500, ..*uci_spec("shuttle").unwrap() };
    let ds = synth_uci(5, &spec);
    let (uleen_model, report) = train_oneshot(
        &ds,
        &OneShotConfig {
            inputs_per_filter: 16,
            entries_per_filter: 64,
            therm_bits: 6,
            therm_kind: ThermometerKind::Linear, // isolate the bleaching effect
            ..Default::default()
        },
    );
    let uleen_acc = uleen_model.evaluate(&ds.test_x, &ds.test_y, ds.num_features).accuracy();
    let enc = ThermometerEncoder::fit(ThermometerKind::Linear, &ds.train_x, ds.num_features, 6);
    let mut rng = Rng::new(9);
    let mut bw = BloomWisard::new(&mut rng, enc, 16, 64, 2, ds.num_classes);
    bw.train(&ds.train_x, &ds.train_y, ds.num_features);
    let bw_acc = bw.evaluate(&ds.test_x, &ds.test_y, ds.num_features).accuracy();
    assert!(bw.mean_fill() > 0.25, "baseline should be partially saturated: {}", bw.mean_fill());
    assert!(
        uleen_acc > bw_acc,
        "bleaching (b={}) must rescue skewed data: uleen {uleen_acc} vs bloom-wisard {bw_acc}",
        report.bleach
    );
}

#[test]
fn gaussian_encoding_beats_linear_on_normal_data_with_outliers() {
    // The paper's §III-A2 rationale: with equal-interval thresholds, "a
    // large number of bits may be dedicated to encoding outlying values".
    // Build a 3-class dataset whose features ARE normal around class means
    // plus rare extreme outliers — Gaussian quantile thresholds must win.
    let mut rng = Rng::new(42);
    let classes = 3usize;
    let features = 6usize;
    let gen = |rng: &mut Rng, n: usize| -> (Vec<f32>, Vec<u16>) {
        let mut xs = Vec::new();
        let mut ys = Vec::new();
        for i in 0..n {
            let c = i % classes;
            ys.push(c as u16);
            for f in 0..features {
                let mean = (c as f64 - 1.0) * 0.4 + f as f64 * 0.01;
                let mut v = mean + 0.5 * rng.normal_clt();
                // 2% extreme outliers stretch the linear range 50x
                if rng.below(50) == 0 {
                    v += if rng.below(2) == 0 { 60.0 } else { -60.0 };
                }
                xs.push(v as f32);
            }
        }
        (xs, ys)
    };
    let (train_x, train_y) = gen(&mut rng, 1200);
    let (test_x, test_y) = gen(&mut rng, 600);
    let ds = uleen::data::Dataset {
        name: "outliers".into(),
        num_features: features,
        num_classes: classes,
        train_x,
        train_y,
        test_x,
        test_y,
    };
    let acc_of = |kind| {
        let (m, _) = train_oneshot(
            &ds,
            &OneShotConfig {
                inputs_per_filter: 8,
                entries_per_filter: 64,
                therm_bits: 6,
                therm_kind: kind,
                ..Default::default()
            },
        );
        m.evaluate(&ds.test_x, &ds.test_y, ds.num_features).accuracy()
    };
    let lin = acc_of(ThermometerKind::Linear);
    let gau = acc_of(ThermometerKind::Gaussian);
    assert!(
        gau > lin,
        "gaussian ({gau}) must beat linear ({lin}) when outliers stretch the range"
    );
}

#[test]
fn ensemble_of_weak_models_beats_members() {
    // Core ensemble claim (§III-A3): combine one-shot submodels trained
    // with different n by summing responses; the ensemble should beat the
    // weakest member and generally match/beat the best.
    let ds = small_mnist();
    let mut models = Vec::new();
    for n in [12usize, 16, 20] {
        let (m, _) = train_oneshot(
            &ds,
            &OneShotConfig {
                inputs_per_filter: n,
                entries_per_filter: 128,
                therm_bits: 2,
                seed: 1000 + n as u64,
                ..Default::default()
            },
        );
        models.push(m);
    }
    let accs: Vec<f64> = models
        .iter()
        .map(|m| m.evaluate(&ds.test_x, &ds.test_y, ds.num_features).accuracy())
        .collect();
    // merge into one ensemble (same encoder config → same thermometer fit)
    let mut ensemble = models[0].clone();
    for m in &models[1..] {
        ensemble.submodels.extend(m.submodels.iter().cloned());
    }
    ensemble.validate().unwrap();
    let eacc = ensemble.evaluate(&ds.test_x, &ds.test_y, ds.num_features).accuracy();
    let worst = accs.iter().cloned().fold(f64::INFINITY, f64::min);
    let best = accs.iter().cloned().fold(0.0f64, f64::max);
    assert!(eacc > worst, "ensemble {eacc} must beat worst member {worst}");
    assert!(eacc > best - 0.02, "ensemble {eacc} should be near/above best member {best}");
}

#[test]
fn thermometer_bits_monotone_data_volume() {
    // more encoding bits → more encoded input bits → more filters
    let ds = synth_uci(3, uci_spec("wine").unwrap());
    let (m2, _) = train_oneshot(
        &ds,
        &OneShotConfig { therm_bits: 2, inputs_per_filter: 8, entries_per_filter: 64, ..Default::default() },
    );
    let (m8, _) = train_oneshot(
        &ds,
        &OneShotConfig { therm_bits: 8, inputs_per_filter: 8, entries_per_filter: 64, ..Default::default() },
    );
    assert!(m8.encoded_bits() == 4 * m2.encoded_bits());
    assert!(m8.size_kib() > m2.size_kib());
}

#[test]
fn corrupted_uln_rejected_loudly() {
    let ds = synth_uci(3, uci_spec("iris").unwrap());
    let (model, _) = train_oneshot(&ds, &OneShotConfig::default());
    let bytes = uln_format::to_bytes(&model, &Json::obj());
    for i in [4usize, 20, bytes.len() / 2, bytes.len() - 12] {
        let mut bad = bytes.clone();
        bad[i] ^= 0x80;
        assert!(
            uln_format::from_bytes(&bad, "x").is_err(),
            "corruption at byte {i} must be detected"
        );
    }
}
