//! Cross-module property tests (hand-rolled harness — see util::prop):
//! invariants of the coordinator, codec, model and formats under random
//! structured inputs.

use uleen::bloom::counting::CountingBloom;
use uleen::data::synth_uci::{synth_uci, uci_spec};
use uleen::encoding::codec;
use uleen::encoding::thermometer::{ThermometerEncoder, ThermometerKind};
use uleen::hash::h3::H3Family;
use uleen::model::flat::{FlatBatchScratch, FlatModel, FlatScratch};
use uleen::model::uln_format;
use uleen::runtime::{InferenceEngine, NativeEngine, ShardedEngine};
use uleen::train::oneshot::{train_oneshot, OneShotConfig};
use uleen::util::argmax_tie_low;
use uleen::util::json::Json;
use uleen::util::prop::{check, Config};


#[test]
fn prop_codec_roundtrip_arbitrary_counts() {
    check(
        "codec-roundtrip",
        &Config { cases: 200, ..Config::default() },
        |rng, size| {
            let t = 1 + rng.below(15) as usize;
            let counts: Vec<u8> = (0..size.max(1))
                .map(|_| rng.below((t + 1) as u64) as u8)
                .collect();
            (t, counts)
        },
        |(t, counts)| {
            let stream = codec::compress(counts, *t);
            let unary = codec::decompress(&stream, counts.len(), *t);
            for (j, &c) in counts.iter().enumerate() {
                for i in 0..*t {
                    if unary.get(j * t + i) != (i < c as usize) {
                        return Err(format!("bit ({j},{i}) wrong"));
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_thermometer_monotone_and_contiguous() {
    check(
        "thermometer-monotone",
        &Config { cases: 100, ..Config::default() },
        |rng, size| {
            let n_inputs = 1 + size % 8;
            let bits = 1 + rng.below(12) as usize;
            let n = 20 + size;
            let data: Vec<f32> = (0..n * n_inputs)
                .map(|_| (rng.f64() * 100.0) as f32)
                .collect();
            let kind = if rng.below(2) == 0 {
                ThermometerKind::Linear
            } else {
                ThermometerKind::Gaussian
            };
            let sample: Vec<f32> = (0..n_inputs).map(|_| (rng.f64() * 120.0 - 10.0) as f32).collect();
            (kind, data, n_inputs, bits, sample)
        },
        |(kind, data, n_inputs, bits, sample)| {
            let enc = ThermometerEncoder::fit(*kind, data, *n_inputs, *bits);
            // thresholds increasing per input
            for j in 0..*n_inputs {
                for i in 1..*bits {
                    let a = enc.thresholds[j * bits + i - 1];
                    let b = enc.thresholds[j * bits + i];
                    if b < a {
                        return Err(format!("thresholds not sorted at ({j},{i})"));
                    }
                }
            }
            // unary contiguity: bits fill LSB-first
            let v = enc.encode(sample);
            for j in 0..*n_inputs {
                let ones = (0..*bits).filter(|&i| v.get(j * bits + i)).count();
                for i in 0..*bits {
                    if v.get(j * bits + i) != (i < ones) {
                        return Err(format!("non-contiguous unary at ({j},{i})"));
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_counting_bloom_binarize_consistent_all_thresholds() {
    check(
        "counting-binarize-all-b",
        &Config { cases: 60, ..Config::default() },
        |rng, size| {
            let fam = H3Family::random(rng, 2, 12, 6);
            let keys: Vec<u64> = (0..size.max(2))
                .map(|_| rng.next_u64() & 0xFFF)
                .collect();
            (fam, keys)
        },
        |(fam, keys)| {
            let mut f = CountingBloom::zeros(64);
            for &k in keys {
                f.train_key(fam, k);
            }
            let mut idxs = vec![0u64; 2];
            for b in 1..=f.max_counter().max(1) {
                let bin = f.binarize(b);
                for probe in 0..512u64 {
                    fam.hash_all(probe, &mut idxs);
                    if bin.test_indices(&idxs) != f.test_indices(&idxs, b) {
                        return Err(format!("b={b} probe={probe}"));
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_uln_roundtrip_random_models() {
    // train tiny models with random hyperparameters; the .uln roundtrip
    // must preserve every prediction.
    check(
        "uln-roundtrip",
        &Config { cases: 10, ..Config::default() },
        |rng, _size| {
            let cfg = OneShotConfig {
                inputs_per_filter: 4 + rng.below(20) as usize,
                entries_per_filter: 1 << (3 + rng.below(6)),
                k_hashes: 1 + rng.below(3) as usize,
                therm_bits: 1 + rng.below(8) as usize,
                therm_kind: if rng.below(2) == 0 {
                    ThermometerKind::Linear
                } else {
                    ThermometerKind::Gaussian
                },
                val_fraction: 0.1,
                seed: rng.next_u64(),
            };
            cfg
        },
        |cfg| {
            let ds = synth_uci(7, uci_spec("wine").unwrap());
            let (model, _) = train_oneshot(&ds, cfg);
            let bytes = uln_format::to_bytes(&model, &Json::obj());
            let (back, _) =
                uln_format::from_bytes(&bytes, "prop").map_err(|e| e.to_string())?;
            let mut s1 = uleen::model::ensemble::EnsembleScratch::default();
            let mut s2 = uleen::model::ensemble::EnsembleScratch::default();
            for i in 0..ds.n_test() {
                let row = ds.test_row(i);
                if model.predict(row, &mut s1) != back.predict(row, &mut s2) {
                    return Err(format!("prediction {i} changed after roundtrip"));
                }
            }
            Ok(())
        },
    );
}

/// The fused tile encode must be bit-exact with the PR-1 sequence it
/// replaces: per-sample `encode_into` into a `BitVec` followed by the
/// sample-slice transpose. Random encoders (both threshold kinds, bit
/// widths crossing the branchless/`partition_point` cutover), tile sizes
/// 1/63/64, and degenerate (constant) feature columns.
#[test]
fn prop_fused_tile_encode_matches_encode_into_plus_transpose() {
    check(
        "fused-tile-encode",
        &Config { cases: 60, ..Config::default() },
        |rng, size| {
            let n_inputs = 1 + size % 6;
            let bits = 1 + rng.below(30) as usize; // crosses the t≤24 cutover
            let kind = if rng.below(2) == 0 {
                ThermometerKind::Linear
            } else {
                ThermometerKind::Gaussian
            };
            // every third case gets a constant (degenerate) column 0
            let constant_col = rng.below(3) == 0;
            let n_fit = 30 + size;
            let data: Vec<f32> = (0..n_fit * n_inputs)
                .map(|i| {
                    if constant_col && i % n_inputs == 0 {
                        42.0
                    } else {
                        (rng.f64() * 100.0) as f32
                    }
                })
                .collect();
            let nt = [1usize, 63, 64][rng.below(3) as usize];
            let xs: Vec<f32> = (0..nt * n_inputs)
                .map(|_| (rng.f64() * 120.0 - 10.0) as f32)
                .collect();
            (kind, data, n_inputs, bits, nt, xs)
        },
        |(kind, data, n_inputs, bits, nt, xs)| {
            let enc = ThermometerEncoder::fit(*kind, data, *n_inputs, *bits);
            let mut slices = Vec::new();
            enc.encode_tile_slices(xs, *nt, &mut slices);
            // PR-1 sequence: encode_into per sample, then transpose
            let mut want = vec![0u64; enc.encoded_bits()];
            let mut buf = uleen::util::bitvec::BitVec::zeros(enc.encoded_bits());
            for s in 0..*nt {
                enc.encode_into(&xs[s * n_inputs..(s + 1) * n_inputs], &mut buf);
                for (w_idx, &w) in buf.words().iter().enumerate() {
                    let mut w = w;
                    while w != 0 {
                        let bit = w.trailing_zeros() as usize;
                        w &= w - 1;
                        want[(w_idx << 6) | bit] |= 1u64 << s;
                    }
                }
            }
            if slices != want {
                let src = slices
                    .iter()
                    .zip(want.iter())
                    .position(|(a, b)| a != b)
                    .unwrap();
                return Err(format!("slice {src} differs (nt={nt}, bits={bits})"));
            }
            Ok(())
        },
    );
}

/// Cross-engine conformance: every native inference path must agree
/// BIT-EXACTLY on every sample — the reference ensemble
/// (`UleenModel::predict`), the flat scalar kernel
/// (`FlatModel::predict_encoded`), the bit-sliced batch kernel fed
/// pre-encoded BitVecs (`responses_batch` + argmax), the fused slice
/// kernel fed raw floats (`responses_batch_fused`), and the pooled
/// sharded engine (`ShardedEngine::classify`, repeated calls through one
/// persistent pool). Batch sizes straddle the 64-sample tile boundary
/// (0, 1, 63, 64, 65) and half the generated models are pruned (all-zero
/// table slots + bias correction on the hot path).
#[test]
fn prop_all_native_engines_agree_bit_exactly() {
    check(
        "cross-engine-conformance",
        &Config { cases: 8, ..Config::default() },
        |rng, _size| {
            let cfg = OneShotConfig {
                inputs_per_filter: 4 + rng.below(16) as usize,
                entries_per_filter: 1 << (4 + rng.below(5)),
                k_hashes: 1 + rng.below(3) as usize,
                therm_bits: 1 + rng.below(6) as usize,
                therm_kind: if rng.below(2) == 0 {
                    ThermometerKind::Linear
                } else {
                    ThermometerKind::Gaussian
                },
                val_fraction: 0.1,
                seed: rng.next_u64(),
            };
            let prune = if rng.below(2) == 0 { 0.0 } else { 0.3 };
            let shards = 1 + rng.below(6) as usize;
            (cfg, prune, shards)
        },
        |(cfg, prune, shards)| {
            let ds = synth_uci(17, uci_spec("vowel").unwrap());
            let (mut model, _) = train_oneshot(&ds, cfg);
            if *prune > 0.0 {
                uleen::train::prune::prune_model(&mut model, &ds, *prune);
            }
            let flat = FlatModel::compile(&model);
            let m = model.num_classes();
            let mut es = uleen::model::ensemble::EnsembleScratch::default();
            let mut fs = FlatScratch::default();
            let mut bs = FlatBatchScratch::default();
            let mut fbs = FlatBatchScratch::default();
            let mut native = NativeEngine::new(model.clone());
            let mut sharded = ShardedEngine::new(model.clone(), *shards);
            for n in [0usize, 1, 63, 64, 65] {
                let n = n.min(ds.n_test());
                let x = &ds.test_x[..n * ds.num_features];
                // reference + flat scalar predictions per row
                let mut want = Vec::with_capacity(n);
                let encoded: Vec<_> =
                    (0..n).map(|i| model.encoder.encode(ds.test_row(i))).collect();
                for (i, enc) in encoded.iter().enumerate() {
                    let p_ref = model.predict(ds.test_row(i), &mut es);
                    let p_flat = flat.predict_encoded(enc, &mut fs);
                    if p_ref != p_flat {
                        return Err(format!("flat != reference at n={n} row {i}"));
                    }
                    want.push(p_ref);
                }
                // bit-sliced batch kernel argmax (pre-encoded BitVecs)
                let mut resp = vec![0i32; n * m];
                flat.responses_batch(&encoded, &mut bs, &mut resp);
                for i in 0..n {
                    let p = argmax_tie_low(&resp[i * m..(i + 1) * m]);
                    if p != want[i] {
                        return Err(format!("batch kernel != reference at n={n} row {i}"));
                    }
                }
                // fused slice kernel (raw floats → responses, no BitVec):
                // must be bit-identical to the BitVec batch kernel
                let mut fused = vec![0i32; n * m];
                flat.responses_batch_fused(&model.encoder, x, n, &mut fbs, &mut fused);
                if fused != resp {
                    return Err(format!("fused kernel != batch kernel at n={n}"));
                }
                // NativeEngine (dispatches to the fused kernel for n > 1)
                let p_native = native.classify(x, n).map_err(|e| e.to_string())?;
                if p_native != want {
                    return Err(format!("NativeEngine != reference at n={n}"));
                }
                // Pooled ShardedEngine (row-major stitching across the
                // persistent worker pool): repeated calls through the same
                // pool must stay bit-identical, with zero new spawns
                let p_sharded = sharded.classify(x, n).map_err(|e| e.to_string())?;
                if p_sharded != want {
                    return Err(format!("ShardedEngine({shards}) != reference at n={n}"));
                }
                let p_again = sharded.classify(x, n).map_err(|e| e.to_string())?;
                if p_again != p_sharded {
                    return Err(format!("ShardedEngine({shards}) unstable across calls at n={n}"));
                }
                // (startup increments race benignly, so only the upper
                // bound is meaningful here: calls must never add threads)
                if sharded.threads_spawned() > *shards {
                    return Err(format!(
                        "pool spawned {} threads, cap is {shards}",
                        sharded.threads_spawned()
                    ));
                }
            }
            Ok(())
        },
    );
}

/// The batched cascade must be PREDICTION-EXACT with N sequential
/// per-sample cascades — same predictions AND the same per-tier
/// served/escalation counters — across margin thresholds (0 = never
/// escalate, huge = every row rides to the last tier, plus realistic
/// values), batch sizes straddling the 64-sample tile boundary
/// (1/63/64/65), zoo depths 2–3, and inputs with dead-tie rows (margin
/// exactly 0, the escalation boundary). The batched side drives every
/// tier through `InferenceEngine::responses` on compacted sub-batches
/// (the fused kernel for n > 1); the sequential side takes the scalar
/// path — agreement here is what makes zoo serving bit-exact no matter
/// how the dynamic batcher slices traffic.
#[test]
fn prop_batched_cascade_matches_sequential() {
    use uleen::coordinator::router::ModelRouter;
    check(
        "batched-cascade-exact",
        &Config { cases: 8, ..Config::default() },
        |rng, _size| {
            let tiers = 2 + rng.below(2) as usize;
            let threshold = [0.0f32, 0.02, 0.1, 1e9][rng.below(4) as usize];
            let n = [1usize, 63, 64, 65][rng.below(4) as usize];
            let seed = rng.next_u64();
            let tie_rows = rng.below(2) == 0;
            (tiers, threshold, n, seed, tie_rows)
        },
        |(tiers, threshold, n, seed, tie_rows)| {
            let ds = synth_uci(9, uci_spec("vowel").unwrap());
            let shapes = [(6usize, 64usize, 2usize), (10, 128, 4), (12, 256, 6)];
            let mut models = Vec::new();
            for &(ipf, epf, bits) in &shapes[..*tiers] {
                let cfg = OneShotConfig {
                    inputs_per_filter: ipf,
                    entries_per_filter: epf,
                    therm_bits: bits,
                    seed: *seed,
                    ..Default::default()
                };
                models.push(train_oneshot(&ds, &cfg).0);
            }
            let build = |models: &[uleen::model::ensemble::UleenModel]| {
                let mut r = ModelRouter::from_models(models);
                r.set_margin_threshold(*threshold);
                r
            };
            let f = ds.num_features;
            let n = (*n).min(ds.n_test());
            let mut x: Vec<f32> = ds.test_x[..n * f].to_vec();
            if *tie_rows {
                // constant rows encode identically → frequent dead ties,
                // i.e. margins exactly on the escalation boundary
                for v in x.iter_mut().take(n * f / 2) {
                    *v = 0.0;
                }
            }
            let mut batch_r = build(&models);
            let mut seq_r = build(&models);
            let got = batch_r
                .classify_cascade_batch(&x, n)
                .map_err(|e| e.to_string())?;
            let mut want = Vec::with_capacity(n);
            for i in 0..n {
                want.push(
                    seq_r
                        .classify_cascade(&x[i * f..(i + 1) * f])
                        .map_err(|e| e.to_string())?,
                );
            }
            if got != want {
                let row = got.iter().zip(&want).position(|(a, b)| a != b).unwrap();
                return Err(format!(
                    "prediction mismatch at row {row}: batch {} vs sequential {} \
                     (tiers={tiers}, threshold={threshold}, n={n})",
                    got[row], want[row]
                ));
            }
            if batch_r.stats.served != seq_r.stats.served {
                return Err(format!(
                    "served counters diverge: batch {:?} vs sequential {:?}",
                    batch_r.stats.served, seq_r.stats.served
                ));
            }
            if batch_r.stats.escalations_from != seq_r.stats.escalations_from {
                return Err(format!(
                    "escalation counters diverge: batch {:?} vs sequential {:?}",
                    batch_r.stats.escalations_from, seq_r.stats.escalations_from
                ));
            }
            Ok(())
        },
    );
}

/// The CASCADE × SHARD composition must be bit-exact with N sequential
/// per-sample cascades — same predictions AND the same POOL-MERGED
/// per-tier served/escalation counters. `ShardedRouterEngine` splits the
/// batch into contiguous row ranges, runs `classify_cascade_batch` on a
/// per-worker router for each range (all routers sharing the same
/// `Arc`'d tiers), and merges counters in worker order — because the
/// cascade is row-independent, ANY partition must land on the sequential
/// answer. Shard counts cycle 1/2/7 and batch sizes 1/63/64/65/257
/// deterministically (so shard boundaries straddle the 64-sample tile
/// boundary and the uneven 257-row split is always exercised); margins
/// cover 0 (never escalate), 0.02 (realistic) and 1e9 (everything rides
/// to the last tier), with dead-tie rows half the time.
#[test]
fn prop_sharded_cascade_matches_sequential() {
    use uleen::coordinator::router::ModelRouter;
    use uleen::runtime::{SharedModel, ShardedRouterEngine};
    let mut case_no = 0usize;
    check(
        "sharded-cascade-exact",
        &Config { cases: 9, ..Config::default() },
        move |rng, _size| {
            let i = case_no;
            case_no += 1;
            // deterministic cycles guarantee full coverage of the shard
            // and batch matrices even at the default case budget
            let shards = [1usize, 2, 7][i % 3];
            let n = [1usize, 63, 64, 65, 257][i % 5];
            let tiers = 2 + rng.below(2) as usize;
            let threshold = [0.0f32, 0.02, 1e9][rng.below(3) as usize];
            let seed = rng.next_u64();
            let tie_rows = rng.below(2) == 0;
            (shards, n, tiers, threshold, seed, tie_rows)
        },
        |(shards, n, tiers, threshold, seed, tie_rows)| {
            let ds = synth_uci(11, uci_spec("vowel").unwrap());
            let shapes = [(6usize, 64usize, 2usize), (10, 128, 4), (12, 256, 6)];
            let mut tiers_shared = Vec::new();
            for &(ipf, epf, bits) in &shapes[..*tiers] {
                let cfg = OneShotConfig {
                    inputs_per_filter: ipf,
                    entries_per_filter: epf,
                    therm_bits: bits,
                    seed: *seed,
                    ..Default::default()
                };
                tiers_shared.push(SharedModel::compile(train_oneshot(&ds, &cfg).0));
            }
            let f = ds.num_features;
            let n = *n;
            // cycle test rows so batch 257 (straddling every shard split)
            // exists regardless of the synthetic split size
            let mut x: Vec<f32> = Vec::with_capacity(n * f);
            for i in 0..n {
                x.extend_from_slice(ds.test_row(i % ds.n_test()));
            }
            if *tie_rows {
                // constant rows encode identically → frequent dead ties,
                // i.e. margins exactly on the escalation boundary
                for v in x.iter_mut().take(n * f / 2) {
                    *v = 0.0;
                }
            }
            let mut eng =
                ShardedRouterEngine::from_shared(tiers_shared.clone(), *threshold, *shards);
            let got = eng.classify(&x, n).map_err(|e| e.to_string())?;
            let mut seq = ModelRouter::from_shared(&tiers_shared);
            seq.set_margin_threshold(*threshold);
            let mut want = Vec::with_capacity(n);
            for i in 0..n {
                want.push(
                    seq.classify_cascade(&x[i * f..(i + 1) * f])
                        .map_err(|e| e.to_string())?,
                );
            }
            if got != want {
                let row = got.iter().zip(&want).position(|(a, b)| a != b).unwrap();
                return Err(format!(
                    "prediction mismatch at row {row}: sharded {} vs sequential {} \
                     (shards={shards}, n={n}, tiers={tiers}, threshold={threshold})",
                    got[row], want[row]
                ));
            }
            let merged = eng.merged_stats();
            if merged.served != seq.stats.served {
                return Err(format!(
                    "merged served counters diverge: sharded {:?} vs sequential {:?} \
                     (shards={shards}, n={n})",
                    merged.served, seq.stats.served
                ));
            }
            if merged.escalations_from != seq.stats.escalations_from {
                return Err(format!(
                    "merged escalation counters diverge: sharded {:?} vs sequential {:?} \
                     (shards={shards}, n={n})",
                    merged.escalations_from, seq.stats.escalations_from
                ));
            }
            // a second identical call through the same pool must stay
            // bit-identical and advance every counter by exactly one
            // batch's worth — merge order is fixed, not racy
            let again = eng.classify(&x, n).map_err(|e| e.to_string())?;
            if again != got {
                return Err(format!("sharded cascade unstable across calls (shards={shards})"));
            }
            let merged2 = eng.merged_stats();
            for t in 0..3 {
                if merged2.served[t] != 2 * merged.served[t]
                    || merged2.escalations_from[t] != 2 * merged.escalations_from[t]
                {
                    return Err(format!(
                        "repeat call did not exactly double tier {t} counters: \
                         {merged2:?} vs {merged:?}"
                    ));
                }
            }
            Ok(())
        },
    );
}

/// The write-into plane refactor's conformance property: for EVERY
/// engine (`NativeEngine` scalar+fused, `ShardedEngine` across shard
/// counts 1/2/7, the cascade `RouterEngine`, and the cascade × shard
/// `ShardedRouterEngine`, margins 0/0.02/1e9, batches 1/63/64/65/257
/// straddling tile and shard boundaries), the `_into` primitives must be
/// bit-exact with their `Vec`-returning wrappers — INCLUDING when the
/// caller hands a dirty, oversized, reused plane: the `n`-row prefix is
/// fully overwritten, nothing past it is touched, repeat calls into the
/// same dirty buffer stay stable, a too-short plane is an `Err` (never a
/// panic, even with a worker pool in flight), n = 0 writes nothing, and
/// the engine keeps serving after every rejected call.
#[test]
fn prop_into_matches_vec() {
    use uleen::coordinator::router::{ModelRouter, RouterEngine};
    use uleen::runtime::{ShardedRouterEngine, SharedModel, Tier};
    let mut case_no = 0usize;
    check(
        "into-matches-vec",
        &Config { cases: 6, ..Config::default() },
        move |rng, _size| {
            let i = case_no;
            case_no += 1;
            // (batch, shards) pairs handpicked so the DEFAULT case
            // budget already hits the shard-boundary-straddling
            // geometries — tile-boundary batches split 7 uneven ways are
            // exactly where an off-by-one in the disjoint-range pointer
            // offsets would hide. Nightly (PROPTEST_CASES=256) cycles
            // the list many times over fresh models.
            const COMBOS: [(usize, usize); 6] =
                [(1, 7), (63, 7), (64, 2), (65, 7), (257, 7), (257, 1)];
            let (n, shards) = COMBOS[i % COMBOS.len()];
            let margin = [0.0f32, 0.02, 1e9][i % 3];
            let seed = rng.next_u64();
            (n, shards, margin, seed)
        },
        |(n, shards, margin, seed)| {
            let ds = synth_uci(13, uci_spec("vowel").unwrap());
            let f = ds.num_features;
            let mk = |ipf: usize, epf: usize, bits: usize| {
                train_oneshot(
                    &ds,
                    &OneShotConfig {
                        inputs_per_filter: ipf,
                        entries_per_filter: epf,
                        therm_bits: bits,
                        seed: *seed,
                        ..Default::default()
                    },
                )
                .0
            };
            let tiers =
                vec![SharedModel::compile(mk(6, 64, 2)), SharedModel::compile(mk(10, 128, 4))];
            let n = *n;
            // cycle test rows so batch 257 exists regardless of split size
            let mut x: Vec<f32> = Vec::with_capacity(n * f);
            for i in 0..n {
                x.extend_from_slice(ds.test_row(i % ds.n_test()));
            }
            let mut engines: Vec<Box<dyn InferenceEngine>> = vec![
                Box::new(NativeEngine::from_shared(tiers[0].clone())),
                Box::new(ShardedEngine::from_shared(tiers[0].clone(), *shards)),
                {
                    let mut r = ModelRouter::from_shared(&tiers);
                    r.set_margin_threshold(*margin);
                    Box::new(RouterEngine::new(r))
                },
                Box::new(ShardedRouterEngine::from_shared(tiers.clone(), *margin, *shards)),
            ];
            const PAD: usize = 7;
            const SF: f32 = -31337.5;
            for eng in engines.iter_mut() {
                let label = eng.label();
                let m = eng.num_classes();
                let want_resp = eng.responses(&x, n).map_err(|e| e.to_string())?;
                let want_pred = eng.classify(&x, n).map_err(|e| e.to_string())?;
                // repeat twice into the SAME dirty plane: scratch reuse
                // must not leak state between calls
                let mut resp = vec![SF; n * m + PAD];
                for round in 0..2 {
                    eng.responses_into(&x, n, &mut resp).map_err(|e| e.to_string())?;
                    if resp[..n * m] != want_resp[..] {
                        return Err(format!(
                            "{label}: responses_into != responses (round {round}, n={n})"
                        ));
                    }
                    if !resp[n * m..].iter().all(|&v| v == SF) {
                        return Err(format!("{label}: responses_into wrote past n*m"));
                    }
                }
                let mut preds = vec![usize::MAX; n + PAD];
                for round in 0..2 {
                    eng.classify_into(&x, n, &mut preds).map_err(|e| e.to_string())?;
                    if preds[..n] != want_pred[..] {
                        return Err(format!(
                            "{label}: classify_into != classify (round {round}, n={n})"
                        ));
                    }
                    if !preds[n..].iter().all(|&p| p == usize::MAX) {
                        return Err(format!("{label}: classify_into wrote past n"));
                    }
                }
                // the tier-routed form agrees with its Vec twin too
                let want_routed = eng
                    .classify_routed(&x, n, Some(Tier::Accurate))
                    .map_err(|e| e.to_string())?;
                eng.classify_routed_into(&x, n, Some(Tier::Accurate), &mut preds)
                    .map_err(|e| e.to_string())?;
                if preds[..n] != want_routed[..] {
                    return Err(format!("{label}: classify_routed_into != classify_routed"));
                }
                // short planes: Err, not panic — even mid-pool
                if eng.responses_into(&x, n, &mut resp[..n * m - 1]).is_ok() {
                    return Err(format!("{label}: short response plane must be Err"));
                }
                if eng.classify_into(&x, n, &mut preds[..n - 1]).is_ok() {
                    return Err(format!("{label}: short prediction plane must be Err"));
                }
                // n = 0 writes nothing
                let mut zero = vec![SF; PAD];
                eng.responses_into(&[], 0, &mut zero).map_err(|e| e.to_string())?;
                if !zero.iter().all(|&v| v == SF) {
                    return Err(format!("{label}: n=0 must write nothing"));
                }
                // and the engine still serves after every rejection
                let after = eng.classify(&x, n).map_err(|e| e.to_string())?;
                if after != want_pred {
                    return Err(format!("{label}: engine degraded after rejected calls"));
                }
            }
            Ok(())
        },
    );
}

/// SIMD dispatch conformance: every kernel path the host CPU supports
/// (AVX2 on x86_64, NEON on aarch64 — `KernelPath::all_supported`
/// always includes Scalar) must produce BIT-EXACT responses and
/// predictions against the forced-scalar kernel. Dispatch is resolved
/// once at compile time and carried by the model, so forcing it through
/// `FlatModel::compile_with_kernel` / `SharedModel::compile_with_kernel`
/// exercises the real per-tile dispatch in `responses_tile_slices`, not
/// a test-only shim. Random model shapes (both threshold kinds, entry
/// counts crossing the gather-table sizes, k 1–3), half the models
/// pruned (all-zero slots + bias correction), dead-tie rows half the
/// time (argmax on equal responses), and batches 1/63/64/65/257 so every
/// vector-width tail in all three phases (4/8-lane x86, 2/4-lane NEON)
/// is hit on both full and partial tiles.
#[test]
fn prop_simd_kernel_paths_match_scalar_bit_exactly() {
    use uleen::model::simd::KernelPath;
    use uleen::runtime::SharedModel;
    let mut case_no = 0usize;
    check(
        "simd-vs-scalar-exact",
        &Config { cases: 6, ..Config::default() },
        move |rng, _size| {
            let i = case_no;
            case_no += 1;
            let cfg = OneShotConfig {
                inputs_per_filter: 4 + rng.below(16) as usize,
                entries_per_filter: 1 << (4 + rng.below(5)),
                k_hashes: 1 + rng.below(3) as usize,
                therm_bits: 1 + rng.below(6) as usize,
                therm_kind: if rng.below(2) == 0 {
                    ThermometerKind::Linear
                } else {
                    ThermometerKind::Gaussian
                },
                val_fraction: 0.1,
                seed: rng.next_u64(),
            };
            let prune = if rng.below(2) == 0 { 0.0 } else { 0.3 };
            let tie_rows = rng.below(2) == 0;
            // deterministic batch cycle so the default case budget hits
            // every tile/vector-tail geometry at least once
            let n = [1usize, 63, 64, 65, 257][i % 5];
            (cfg, prune, tie_rows, n)
        },
        |(cfg, prune, tie_rows, n)| {
            let ds = synth_uci(23, uci_spec("vowel").unwrap());
            let (mut model, _) = train_oneshot(&ds, cfg);
            if *prune > 0.0 {
                uleen::train::prune::prune_model(&mut model, &ds, *prune);
            }
            let f = ds.num_features;
            let n = *n;
            // cycle test rows so batch 257 exists regardless of split size
            let mut x: Vec<f32> = Vec::with_capacity(n * f);
            for i in 0..n {
                x.extend_from_slice(ds.test_row(i % ds.n_test()));
            }
            if *tie_rows {
                // constant rows encode identically → equal responses, so
                // any path-dependent accumulation order would flip argmax
                for v in x.iter_mut().take(n * f / 2) {
                    *v = 0.0;
                }
            }
            let scalar = FlatModel::compile_with_kernel(&model, KernelPath::Scalar);
            let m = scalar.num_classes;
            let mut want = vec![0i32; n * m];
            let mut bs = FlatBatchScratch::default();
            scalar.responses_batch_fused(&model.encoder, &x, n, &mut bs, &mut want);
            let want_pred: Vec<usize> =
                (0..n).map(|i| argmax_tie_low(&want[i * m..(i + 1) * m])).collect();
            for path in KernelPath::all_supported() {
                let forced = FlatModel::compile_with_kernel(&model, path);
                if forced.kernel_path() != path {
                    return Err(format!("{} did not stick at compile", path.label()));
                }
                let mut got = vec![0i32; n * m];
                let mut fbs = FlatBatchScratch::default();
                forced.responses_batch_fused(&model.encoder, &x, n, &mut fbs, &mut got);
                if got != want {
                    let at = got.iter().zip(&want).position(|(a, b)| a != b).unwrap();
                    return Err(format!(
                        "{} response[{at}] = {} != scalar {} (n={n}, prune={prune})",
                        path.label(),
                        got[at],
                        want[at]
                    ));
                }
                // whole engines built over a forced-kernel SharedModel:
                // dispatch is model-resident, so it must ride through the
                // engine layers (single-threaded and pooled) unchanged
                let shared = SharedModel::compile_with_kernel(model.clone(), path);
                let mut native = NativeEngine::from_shared(shared.clone());
                let p_native = native.classify(&x, n).map_err(|e| e.to_string())?;
                if p_native != want_pred {
                    return Err(format!("{}: NativeEngine != scalar (n={n})", path.label()));
                }
                let mut sharded = ShardedEngine::from_shared(shared, 3);
                let p_sharded = sharded.classify(&x, n).map_err(|e| e.to_string())?;
                if p_sharded != want_pred {
                    return Err(format!("{}: ShardedEngine != scalar (n={n})", path.label()));
                }
            }
            Ok(())
        },
    );
}

/// PR-10 memory-plane conformance: the width-adaptive class-mask planes
/// (`u8`/`u16`/`u32`, chosen from the class count or forced via
/// `CompileOptions`/`ULEEN_MASK_WIDTH`) must be BIT-EXACT against a
/// forced-u32 forced-scalar prefetch-off baseline — across every forced
/// width × every supported kernel path × prefetch on/off, on pruned
/// models with dead-tie rows, at batches straddling the 64-sample tile
/// (1/63/64/65/257), and through whole engines (`NativeEngine`,
/// `ShardedRouterEngine`) built over width-forced `SharedModel`s. Width,
/// kernel and prefetch are all model-resident compile decisions, so
/// forcing them here exercises the real per-tile dispatch, not a shim.
/// Too-narrow forcings (u8 on 11-class vowel) must WIDEN to capacity,
/// never truncate a class bit.
#[test]
fn prop_mask_widths_match_u32_baseline() {
    use uleen::model::flat::CompileOptions;
    use uleen::model::simd::{KernelPath, MaskWidth};
    use uleen::runtime::{SharedModel, ShardedRouterEngine};
    let mut case_no = 0usize;
    check(
        "mask-width-vs-u32-exact",
        &Config { cases: 6, ..Config::default() },
        move |rng, _size| {
            let i = case_no;
            case_no += 1;
            let cfg = OneShotConfig {
                inputs_per_filter: 4 + rng.below(16) as usize,
                entries_per_filter: 1 << (4 + rng.below(5)),
                k_hashes: 1 + rng.below(3) as usize,
                therm_bits: 1 + rng.below(6) as usize,
                therm_kind: if rng.below(2) == 0 {
                    ThermometerKind::Linear
                } else {
                    ThermometerKind::Gaussian
                },
                val_fraction: 0.1,
                seed: rng.next_u64(),
            };
            let prune = if rng.below(2) == 0 { 0.0 } else { 0.3 };
            let tie_rows = rng.below(2) == 0;
            // deterministic batch cycle so the default case budget hits
            // every tile/vector-tail geometry at least once
            let n = [1usize, 63, 64, 65, 257][i % 5];
            (cfg, prune, tie_rows, n)
        },
        |(cfg, prune, tie_rows, n)| {
            let ds = synth_uci(41, uci_spec("vowel").unwrap());
            let (mut model, _) = train_oneshot(&ds, cfg);
            if *prune > 0.0 {
                uleen::train::prune::prune_model(&mut model, &ds, *prune);
            }
            let f = ds.num_features;
            let n = *n;
            // cycle test rows so batch 257 exists regardless of split size
            let mut x: Vec<f32> = Vec::with_capacity(n * f);
            for i in 0..n {
                x.extend_from_slice(ds.test_row(i % ds.n_test()));
            }
            if *tie_rows {
                // constant rows encode identically → equal responses, so
                // a width- or prefetch-dependent accumulation order would
                // flip argmax
                for v in x.iter_mut().take(n * f / 2) {
                    *v = 0.0;
                }
            }
            let baseline = FlatModel::compile_with(
                &model,
                CompileOptions {
                    kernel: Some(KernelPath::Scalar),
                    mask_width: Some(MaskWidth::U32),
                    prefetch: Some(false),
                },
            );
            let m = baseline.num_classes;
            let mut want = vec![0i32; n * m];
            let mut bs = FlatBatchScratch::default();
            baseline.responses_batch_fused(&model.encoder, &x, n, &mut bs, &mut want);
            let want_pred: Vec<usize> =
                (0..n).map(|i| argmax_tie_low(&want[i * m..(i + 1) * m])).collect();
            for width in MaskWidth::all() {
                for path in KernelPath::all_supported() {
                    for prefetch in [false, true] {
                        let opts = CompileOptions {
                            kernel: Some(path),
                            mask_width: Some(width),
                            prefetch: Some(prefetch),
                        };
                        let forced = FlatModel::compile_with(&model, opts);
                        if forced.mask_width() != width.widen_to_hold(m) {
                            return Err(format!(
                                "{} did not clamp to capacity for {m} classes",
                                width.label()
                            ));
                        }
                        let mut got = vec![0i32; n * m];
                        let mut fbs = FlatBatchScratch::default();
                        forced.responses_batch_fused(&model.encoder, &x, n, &mut fbs, &mut got);
                        if got != want {
                            let at =
                                got.iter().zip(&want).position(|(a, b)| a != b).unwrap();
                            return Err(format!(
                                "{}/{}/prefetch={prefetch} response[{at}] = {} != baseline {} \
                                 (n={n}, prune={prune})",
                                width.label(),
                                path.label(),
                                got[at],
                                want[at]
                            ));
                        }
                        // the single-sample scatter path probes the same
                        // planes through different code — spot-check it
                        let mut fs = FlatScratch::default();
                        for i in 0..4.min(n) {
                            let enc = model.encoder.encode(&x[i * f..(i + 1) * f]);
                            let mut one = vec![0i32; m];
                            forced.responses_encoded(&enc, &mut fs, &mut one);
                            if one != want[i * m..(i + 1) * m] {
                                return Err(format!(
                                    "{}/{}/prefetch={prefetch}: scalar scatter path diverged \
                                     at row {i}",
                                    width.label(),
                                    path.label()
                                ));
                            }
                        }
                    }
                }
                // whole engines over a width-forced SharedModel: the width
                // is model-resident, so it must ride through the engine
                // layers (single-threaded, and the sharded cascade with a
                // margin that never escalates) unchanged
                let opts = CompileOptions { mask_width: Some(width), ..Default::default() };
                let shared = SharedModel::compile_with(model.clone(), opts);
                if shared.model_bytes() == 0 {
                    return Err("SharedModel must account its resident bytes".into());
                }
                let mut native = NativeEngine::from_shared(shared.clone());
                let p_native = native.classify(&x, n).map_err(|e| e.to_string())?;
                if p_native != want_pred {
                    return Err(format!("{}: NativeEngine != baseline (n={n})", width.label()));
                }
                let mut zoo = ShardedRouterEngine::from_shared(vec![shared], 0.0, 3);
                let p_zoo = zoo.classify(&x, n).map_err(|e| e.to_string())?;
                if p_zoo != want_pred {
                    return Err(format!(
                        "{}: ShardedRouterEngine != baseline (n={n})",
                        width.label()
                    ));
                }
            }
            Ok(())
        },
    );
}

/// Pure reference model of the batcher's split semantics, transliterated
/// from the pre-ring `VecDeque` implementation: FIFO order, each batch is
/// the longest same-tier prefix of what remains, capped at `max_batch`.
/// The ring rewrite must be behavior-identical to this.
fn reference_splits(tiers: &[Option<uleen::runtime::Tier>], max_batch: usize) -> Vec<Vec<usize>> {
    let max_batch = max_batch.max(1);
    let mut out = Vec::new();
    let mut i = 0;
    while i < tiers.len() {
        let head = tiers[i];
        let mut j = i + 1;
        while j < tiers.len() && j - i < max_batch && tiers[j] == head {
            j += 1;
        }
        out.push((i..j).collect());
        i = j;
    }
    out
}

fn tier_of(v: u64) -> Option<uleen::runtime::Tier> {
    use uleen::runtime::Tier;
    match v {
        0 => None,
        1 => Some(Tier::Fast),
        2 => Some(Tier::Balanced),
        _ => Some(Tier::Accurate),
    }
}

/// The slab-arena ring batcher must be BEHAVIOR-IDENTICAL to the old
/// `VecDeque` batcher it replaced: pre-fill the queue with a random
/// tier-clustered request sequence, `close()` (which kills the dwell, so
/// draining is deterministic), then drain with one consumer and compare
/// the exact batch-by-batch id grouping against the pure
/// [`reference_splits`] model. `max_batch` cycles 1/63/64/65/257 so ring
/// wraparound and the capacity cap are both exercised. Along the way the
/// arena contract is checked too: `gather` hands back exactly the row
/// bytes each id submitted (slot indirection never scrambles payloads),
/// and after the drain the free-list holds every slot again.
#[test]
fn prop_ring_batcher_matches_reference_splits() {
    use std::sync::mpsc;
    use std::time::{Duration, Instant};
    use uleen::coordinator::batcher::{BatcherConfig, BoundedQueue};
    let mut case_no = 0usize;
    check(
        "ring-batcher-vs-reference",
        &Config { cases: 15, ..Config::default() },
        move |rng, _size| {
            let i = case_no;
            case_no += 1;
            let max_batch = [1usize, 63, 64, 65, 257][i % 5];
            let n = rng.below(400) as usize; // 0 is a valid (empty) case
            // tier runs, not iid draws: realistic traffic arrives in
            // bursts, and runs are what make prefix splits interesting
            let mut tiers = Vec::with_capacity(n);
            let mut cur = tier_of(rng.below(4));
            for _ in 0..n {
                if rng.below(3) == 0 {
                    cur = tier_of(rng.below(4));
                }
                tiers.push(cur);
            }
            (max_batch, tiers)
        },
        |(max_batch, tiers)| {
            let f = 3usize;
            let cfg = BatcherConfig {
                max_batch: *max_batch,
                max_wait: Duration::from_millis(5),
                capacity: tiers.len().max(1),
            };
            let q = BoundedQueue::new(cfg, f);
            let (tx, _rx) = mpsc::channel();
            for (i, t) in tiers.iter().enumerate() {
                let row: Vec<f32> = (0..f).map(|j| (i * 31 + j) as f32).collect();
                q.submit_row(i as u64, &row, *t, Instant::now(), tx.clone())
                    .map_err(|e| format!("submit {i} refused: {e:?}"))?;
            }
            q.close();
            let want = reference_splits(tiers, *max_batch);
            let mut batch = Vec::new();
            let mut scratch = Vec::new();
            let mut got: Vec<Vec<usize>> = Vec::new();
            while q.next_batch_into(&mut batch) {
                if batch.is_empty() {
                    return Err("next_batch_into returned true with an empty batch".into());
                }
                let head = batch[0].tier;
                if batch.iter().any(|r| r.tier != head) {
                    return Err(format!("mixed-tier batch at index {}", got.len()));
                }
                let plane = q.gather(&batch, &mut scratch);
                for (k, r) in batch.iter().enumerate() {
                    for j in 0..f {
                        let wantv = (r.id as usize * 31 + j) as f32;
                        if plane[k * f + j] != wantv {
                            return Err(format!(
                                "gather scrambled id {} feature {j}: {} != {wantv}",
                                r.id,
                                plane[k * f + j]
                            ));
                        }
                    }
                }
                q.release(&batch);
                got.push(batch.iter().map(|r| r.id as usize).collect());
            }
            if got != want {
                let at = got
                    .iter()
                    .zip(&want)
                    .position(|(a, b)| a != b)
                    .unwrap_or(want.len().min(got.len()));
                return Err(format!(
                    "splits diverge from reference at batch {at} \
                     (max_batch={max_batch}, n={}): ring {:?} vs reference {:?}",
                    tiers.len(),
                    got.get(at),
                    want.get(at)
                ));
            }
            if q.free_slots() != q.arena_slots() {
                return Err(format!(
                    "arena leaked slots after full drain: {} free of {}",
                    q.free_slots(),
                    q.arena_slots()
                ));
            }
            Ok(())
        },
    );
}

/// MPMC safety of the ring batcher: 2–4 consumers racing
/// `next_batch_into` over a closed, pre-filled queue must partition the
/// requests into batches that are each tier-homogeneous, FIFO-contiguous
/// (ids `k, k+1, …` — the lock hands out strict queue prefixes), and
/// ≤ `max_batch`; across all consumers every id appears exactly once
/// (nothing lost, nothing duplicated), and after the drain the arena
/// free-list is whole again. Interleaving is scheduler-random, so this
/// checks invariants rather than one canonical split.
#[test]
fn prop_ring_batcher_competing_consumers_partition_fifo() {
    use std::sync::{mpsc, Mutex};
    use std::time::{Duration, Instant};
    use uleen::coordinator::batcher::{BatcherConfig, BoundedQueue};
    use uleen::runtime::Tier;
    let mut case_no = 0usize;
    check(
        "ring-batcher-mpmc",
        &Config { cases: 8, ..Config::default() },
        move |rng, _size| {
            let i = case_no;
            case_no += 1;
            let max_batch = [1usize, 63, 64, 65, 257][i % 5];
            let consumers = 2 + rng.below(3) as usize;
            let n = rng.below(500) as usize;
            let mut tiers = Vec::with_capacity(n);
            let mut cur = tier_of(rng.below(4));
            for _ in 0..n {
                if rng.below(4) == 0 {
                    cur = tier_of(rng.below(4));
                }
                tiers.push(cur);
            }
            (max_batch, consumers, tiers)
        },
        |(max_batch, consumers, tiers)| {
            let f = 2usize;
            let cfg = BatcherConfig {
                max_batch: *max_batch,
                max_wait: Duration::from_micros(100),
                capacity: tiers.len().max(1),
            };
            let q = BoundedQueue::new(cfg, f);
            let (tx, _rx) = mpsc::channel();
            for (i, t) in tiers.iter().enumerate() {
                let row: Vec<f32> = (0..f).map(|j| (i * 7 + j) as f32).collect();
                q.submit_row(i as u64, &row, *t, Instant::now(), tx.clone())
                    .map_err(|e| format!("submit {i} refused: {e:?}"))?;
            }
            q.close();
            let all: Mutex<Vec<Vec<(u64, Option<Tier>)>>> = Mutex::new(Vec::new());
            std::thread::scope(|s| {
                for _ in 0..*consumers {
                    s.spawn(|| {
                        let mut batch = Vec::new();
                        let mut scratch = Vec::new();
                        let mut mine: Vec<Vec<(u64, Option<Tier>)>> = Vec::new();
                        while q.next_batch_into(&mut batch) {
                            let _ = q.gather(&batch, &mut scratch);
                            q.release(&batch);
                            mine.push(batch.iter().map(|r| (r.id, r.tier)).collect());
                        }
                        all.lock().unwrap().append(&mut mine);
                    });
                }
            });
            let batches = all.into_inner().unwrap();
            let mut seen = vec![false; tiers.len()];
            for (b_idx, b) in batches.iter().enumerate() {
                if b.is_empty() {
                    return Err(format!("consumer took an empty batch ({b_idx})"));
                }
                if b.len() > *max_batch {
                    return Err(format!(
                        "batch {b_idx} has {} requests, cap is {max_batch}",
                        b.len()
                    ));
                }
                let head = b[0].1;
                for (k, &(id, t)) in b.iter().enumerate() {
                    if t != head {
                        return Err(format!("batch {b_idx} mixes tiers"));
                    }
                    if t != tiers[id as usize] {
                        return Err(format!("id {id} changed tier in flight"));
                    }
                    if k > 0 && id != b[k - 1].0 + 1 {
                        return Err(format!(
                            "batch {b_idx} is not a FIFO-contiguous run: {} then {id}",
                            b[k - 1].0
                        ));
                    }
                    if seen[id as usize] {
                        return Err(format!("id {id} delivered twice"));
                    }
                    seen[id as usize] = true;
                }
            }
            if let Some(lost) = seen.iter().position(|&s| !s) {
                return Err(format!("id {lost} was never delivered"));
            }
            if q.free_slots() != q.arena_slots() {
                return Err(format!(
                    "arena leaked slots under competing consumers: {} free of {}",
                    q.free_slots(),
                    q.arena_slots()
                ));
            }
            Ok(())
        },
    );
}

/// The latency autopilot's safety envelope under random traffic: across
/// random targets × bursty window schedules (thin windows, log-uniform
/// p99s from 10 µs to 1 s), (1) both knobs never leave their configured
/// clamp ranges and a `Hold` tick never moves either; (2) sustained
/// overload converges both knobs to their minima and sustained idle to
/// their maxima (bounded AIMD, no runaway); (3) a cascade steered to a
/// random reachable margin m through the SHARED knob is prediction- and
/// counter-exact with a sequential cascade re-run statically configured
/// at m — the dynamic knob cannot take serving outside the existing
/// conformance envelope; (4) the windowed histogram the controller
/// drains empties completely between epochs while the cumulative report
/// keeps its totals.
#[test]
fn prop_autopilot_knobs_stay_clamped_and_converge() {
    use std::time::Duration;
    use uleen::coordinator::autopilot::{step, AutopilotConfig, Decision, DwellKnob, MarginKnob};
    use uleen::coordinator::metrics::{LatencyWindow, ServerMetrics};
    use uleen::coordinator::router::ModelRouter;
    use uleen::runtime::SharedModel;
    check(
        "autopilot-clamped-converge",
        &Config { cases: 6, ..Config::default() },
        |rng, _size| {
            let target_ms = 0.5 + rng.f64() * 19.5;
            let margin0 = rng.f64() as f32; // inside the [0, 1] clamp range
            let dwell0_us = 50 + rng.below(4951); // inside [50 µs, 5 ms]
            let steps = 10 + rng.below(50) as usize;
            let schedule: Vec<(u64, f64)> = (0..steps)
                .map(|_| {
                    let count = rng.below(200);
                    // log-uniform p99 over 10 µs .. 1 s
                    let p99_us = 10.0 * 10f64.powf(rng.f64() * 5.0);
                    (count, p99_us)
                })
                .collect();
            let burst = 1 + rng.below(200);
            let seed = rng.next_u64();
            (target_ms, margin0, dwell0_us, schedule, burst, seed)
        },
        |(target_ms, margin0, dwell0_us, schedule, burst, seed)| {
            let cfg = AutopilotConfig { target_p99_ms: *target_ms, ..Default::default() };
            let margin = MarginKnob::new(*margin0);
            let dwell = DwellKnob::new(Duration::from_micros(*dwell0_us));
            for &(count, p99_us) in schedule {
                let w = LatencyWindow { count, p50_us: p99_us / 2.0, p99_us };
                let before = (margin.get(), dwell.get());
                let d = step(&cfg, &w, Some(&margin), &dwell);
                if count < cfg.min_window && d != Decision::Hold {
                    return Err(format!(
                        "thin window (count {count} < {}) acted: {d:?}",
                        cfg.min_window
                    ));
                }
                if d == Decision::Hold && (margin.get(), dwell.get()) != before {
                    return Err(format!("Hold moved a knob: {before:?} -> ({}, {:?})",
                        margin.get(), dwell.get()));
                }
                if !(cfg.margin_min..=cfg.margin_max).contains(&margin.get()) {
                    return Err(format!(
                        "margin {} escaped [{}, {}] on {d:?} (window p99 {p99_us} µs)",
                        margin.get(), cfg.margin_min, cfg.margin_max
                    ));
                }
                if dwell.get() < cfg.dwell_min || dwell.get() > cfg.dwell_max {
                    return Err(format!(
                        "dwell {:?} escaped [{:?}, {:?}] on {d:?}",
                        dwell.get(), cfg.dwell_min, cfg.dwell_max
                    ));
                }
            }
            // a random reachable margin for the conformance check below
            let m_probe = margin.get();
            // sustained overload pins both knobs at their minima
            let slow = LatencyWindow { count: 100, p50_us: 5e8, p99_us: 1e9 };
            for _ in 0..60 {
                step(&cfg, &slow, Some(&margin), &dwell);
            }
            if margin.get() != cfg.margin_min || dwell.get() != cfg.dwell_min {
                return Err(format!(
                    "overload did not converge to the minima: margin {}, dwell {:?}",
                    margin.get(), dwell.get()
                ));
            }
            // sustained idle pins both knobs at their maxima
            let fast = LatencyWindow { count: 100, p50_us: 0.5, p99_us: 1.0 };
            for _ in 0..400 {
                step(&cfg, &fast, Some(&margin), &dwell);
            }
            if margin.get() != cfg.margin_max || dwell.get() != cfg.dwell_max {
                return Err(format!(
                    "idle did not converge to the maxima: margin {}, dwell {:?}",
                    margin.get(), dwell.get()
                ));
            }
            // dynamic-margin conformance: a cascade steered to m_probe
            // through the shared knob must be bit-exact with a sequential
            // cascade statically configured at m_probe
            let ds = synth_uci(17, uci_spec("vowel").unwrap());
            let mk = |ipf: usize, epf: usize, bits: usize| {
                train_oneshot(
                    &ds,
                    &OneShotConfig {
                        inputs_per_filter: ipf,
                        entries_per_filter: epf,
                        therm_bits: bits,
                        seed: *seed,
                        ..Default::default()
                    },
                )
                .0
            };
            let tiers =
                vec![SharedModel::compile(mk(6, 64, 2)), SharedModel::compile(mk(10, 128, 4))];
            let f = ds.num_features;
            let n = 64.min(ds.n_test());
            let x = &ds.test_x[..n * f];
            let mut dynamic = ModelRouter::from_shared(&tiers);
            dynamic.margin_knob().set(m_probe); // steer through a knob clone
            let got = dynamic.classify_cascade_batch(x, n).map_err(|e| e.to_string())?;
            let mut stat = ModelRouter::from_shared(&tiers);
            stat.set_margin_threshold(m_probe);
            let mut want = Vec::with_capacity(n);
            for i in 0..n {
                want.push(
                    stat.classify_cascade(&x[i * f..(i + 1) * f])
                        .map_err(|e| e.to_string())?,
                );
            }
            if got != want {
                return Err(format!(
                    "knob-steered cascade diverged from static margin {m_probe}"
                ));
            }
            if dynamic.stats.served != stat.stats.served
                || dynamic.stats.escalations_from != stat.stats.escalations_from
            {
                return Err(format!(
                    "counters diverged at margin {m_probe}: dynamic {:?}/{:?} vs static {:?}/{:?}",
                    dynamic.stats.served, dynamic.stats.escalations_from,
                    stat.stats.served, stat.stats.escalations_from
                ));
            }
            // the controller's windowed view drains to zero between
            // epochs; the cumulative report keeps its totals
            let metrics = ServerMetrics::new();
            let k = *burst as usize;
            let lats = vec![Duration::from_micros(123); k];
            metrics.record_batch(k, &lats);
            let w1 = metrics.drain_latency_window();
            if w1.count != *burst {
                return Err(format!("first drain saw {} of {burst} samples", w1.count));
            }
            let w2 = metrics.drain_latency_window();
            if w2 != LatencyWindow::default() {
                return Err(format!("window did not drain to zero: {w2:?}"));
            }
            if metrics.report(16).completed != *burst {
                return Err("draining the window must not touch the cumulative totals".into());
            }
            Ok(())
        },
    );
}

#[test]
fn prop_response_bounded_by_kept_filters() {
    // 0 - bias ≤ response ≤ kept_filters + bias for every input
    check(
        "response-bounds",
        &Config { cases: 20, ..Config::default() },
        |rng, _| {
            let n = rng.next_u64();
            n
        },
        |seed| {
            let ds = synth_uci(3, uci_spec("iris").unwrap());
            let cfg = OneShotConfig { seed: *seed, ..Default::default() };
            let (model, _) = train_oneshot(&ds, &cfg);
            let mut scratch = uleen::model::ensemble::EnsembleScratch::default();
            for i in 0..ds.n_test() {
                let enc = model.encoder.encode(ds.test_row(i));
                let resp = model.responses_encoded(&enc, &mut scratch);
                for (c, &r) in resp.iter().enumerate() {
                    let max: i32 = model
                        .submodels
                        .iter()
                        .map(|sm| sm.discriminators[c].kept() as i32 + sm.bias[c])
                        .sum();
                    let min: i32 = model.submodels.iter().map(|sm| sm.bias[c]).sum();
                    if r < min || r > max {
                        return Err(format!("response {r} outside [{min},{max}]"));
                    }
                }
            }
            Ok(())
        },
    );
}
