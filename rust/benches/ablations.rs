//! Ablation benches for the design choices the paper motivates in §V-A and
//! §III (and DESIGN.md calls out): hash-function count per filter, the
//! input bus-compression codec, ensemble size, and the cascade router's
//! energy/accuracy trade.

use uleen::bench::table::{f1, f2, pct, Table};
use uleen::coordinator::router::{max_response_of, ModelRouter};
use uleen::data::synth_mnist;
use uleen::hw::arch::{AcceleratorInstance, Target};
use uleen::runtime::{InferenceEngine, NativeEngine};
use uleen::train::oneshot::{train_oneshot, OneShotConfig};

fn main() -> anyhow::Result<()> {
    let ds = synth_mnist(2024, 4000, 1000);

    // --- ablation 1: hash functions per filter (paper: k=2 is the spot:
    // k=1 collides, k>2 costs hardware with no accuracy) ---
    let mut t = Table::new(
        "Ablation — hash functions per Bloom filter (one-shot, SynthMNIST)",
        &["k", "Acc.%", "Size KiB", "hash units (FPGA)", "ASIC nJ/inf"],
    );
    for k in [1usize, 2, 4] {
        let (m, _) = train_oneshot(
            &ds,
            &OneShotConfig {
                inputs_per_filter: 16,
                entries_per_filter: 256,
                k_hashes: k,
                therm_bits: 2,
                ..Default::default()
            },
        );
        let acc = m.evaluate(&ds.test_x, &ds.test_y, ds.num_features).accuracy();
        let inst = AcceleratorInstance::generate(&m, Target::Asic);
        let rep = uleen::hw::asic::implement(&inst);
        t.row(vec![
            format!("{k}"),
            pct(acc),
            f2(m.size_kib()),
            format!("{}", inst.total_hash_units()),
            f1(rep.nj_per_inf),
        ]);
    }
    t.print();

    // --- ablation 2: input compression (paper §III-C) ---
    let mut t = Table::new(
        "Ablation — unary→binary input compression (bus traffic per inference)",
        &["bits/input", "raw bits", "compressed bits", "II raw (cycles@112b)", "II compressed"],
    );
    for bits in [1usize, 2, 4, 7, 8] {
        let raw = 784 * bits;
        let comp = 784 * uleen::encoding::codec::compressed_bits_per_input(bits);
        t.row(vec![
            format!("{bits}"),
            format!("{raw}"),
            format!("{}", comp.min(raw)),
            format!("{}", raw.div_ceil(112)),
            format!("{}", comp.min(raw).div_ceil(112)),
        ]);
    }
    t.print();

    // --- ablation 3: ensemble size (merge k one-shot submodels) ---
    let mut t = Table::new(
        "Ablation — ensemble size (one-shot submodels, summed responses)",
        &["submodels", "Acc.%", "Size KiB"],
    );
    let mut ensemble: Option<uleen::model::ensemble::UleenModel> = None;
    for (i, n) in [12usize, 16, 20, 24].iter().enumerate() {
        let (m, _) = train_oneshot(
            &ds,
            &OneShotConfig {
                inputs_per_filter: *n,
                entries_per_filter: 128,
                therm_bits: 2,
                seed: 100 + *n as u64,
                ..Default::default()
            },
        );
        match &mut ensemble {
            None => ensemble = Some(m),
            Some(e) => e.submodels.extend(m.submodels),
        }
        let e = ensemble.as_ref().unwrap();
        let acc = e.evaluate(&ds.test_x, &ds.test_y, ds.num_features).accuracy();
        t.row(vec![format!("{}", i + 1), pct(acc), f2(e.size_kib())]);
    }
    t.print();

    // --- ablation 4: cascade router (energy proxy = expected table bits
    // touched per request) ---
    let mut engines: Vec<Box<dyn InferenceEngine>> = Vec::new();
    let mut maxr = Vec::new();
    let mut sizes = Vec::new();
    for (n, e, bits) in [(12usize, 64usize, 2usize), (16, 256, 2), (16, 1024, 4)] {
        let (m, _) = train_oneshot(
            &ds,
            &OneShotConfig {
                inputs_per_filter: n,
                entries_per_filter: e,
                therm_bits: bits,
                ..Default::default()
            },
        );
        sizes.push(m.size_kib());
        maxr.push(max_response_of(&m));
        engines.push(Box::new(NativeEngine::new(m)));
    }
    let mut router = ModelRouter::new(engines, maxr);
    let mut t = Table::new(
        "Ablation — cascade router (small→large escalation on thin margins)",
        &["margin thr", "Acc.%", "fast-path %", "mean KiB touched/req"],
    );
    for thr in [0.0f32, 0.03, 0.08, 10.0] {
        router.set_margin_threshold(thr);
        router.stats = Default::default();
        let mut correct = 0usize;
        let n_eval = 500usize;
        for i in 0..n_eval {
            let p = router.classify_cascade(ds.test_row(i))?;
            if p == ds.test_y[i] as usize {
                correct += 1;
            }
        }
        let served = router.stats.served;
        let touched: f64 = served
            .iter()
            .zip(sizes.iter())
            .map(|(&s, &kib)| s as f64 * kib)
            .sum::<f64>()
            / n_eval as f64;
        t.row(vec![
            format!("{thr}"),
            pct(correct as f64 / n_eval as f64),
            pct(router.fast_path_fraction()),
            f2(touched),
        ]);
    }
    t.print();
    println!("(shape: k=2 sweet spot; compression shrinks II for t≥4; ensembles improve with diminishing returns; cascades keep most requests on the cheap model)");
    Ok(())
}
