//! Regenerates **Fig 14** — the one-shot hyperparameter sweep: best
//! accuracy vs model size, vs thermometer bits, and vs entries per filter.
//! Trained live with the Rust one-shot trainer (fast).

use uleen::bench::table::{f2, pct, Table};
use uleen::data::synth_mnist;
use uleen::train::sweep::{accuracy_size_frontier, sweep_oneshot};

fn main() -> anyhow::Result<()> {
    // Smaller train set keeps the full grid affordable in a bench run.
    let ds = synth_mnist(2024, 4000, 1000);
    let bits_axis = [1usize, 2, 3, 4, 6];
    let inputs_axis = [12usize, 16, 20];
    let entries_axis = [64usize, 256, 1024];
    let points = sweep_oneshot(&ds, &bits_axis, &inputs_axis, &entries_axis, 2024);

    let mut t = Table::new(
        "Fig 14 (left) — best one-shot accuracy at a given max size",
        &["Size ≤ KiB", "Best Acc.%"],
    );
    for (size, acc) in accuracy_size_frontier(&points) {
        t.row(vec![f2(size), pct(acc)]);
    }
    t.print();

    let mut tb = Table::new(
        "Fig 14 (middle) — best accuracy per thermometer bits",
        &["Bits/input", "Best Acc.%"],
    );
    for &b in &bits_axis {
        let best = points
            .iter()
            .filter(|p| p.therm_bits == b)
            .map(|p| p.test_accuracy)
            .fold(0.0f64, f64::max);
        tb.row(vec![format!("{b}"), pct(best)]);
    }
    tb.print();

    let mut te = Table::new(
        "Fig 14 (right) — best accuracy per entries/filter",
        &["Entries/filter", "Best Acc.%"],
    );
    for &e in &entries_axis {
        let best = points
            .iter()
            .filter(|p| p.entries_per_filter == e)
            .map(|p| p.test_accuracy)
            .fold(0.0f64, f64::max);
        te.row(vec![format!("{e}"), pct(best)]);
    }
    te.print();
    println!("(paper shape: diminishing returns in bits and entries; accuracy ~log(model size); one-shot plateaus well below multi-shot)");
    Ok(())
}
