//! Regenerates **Fig 11** — energy and inverse-throughput vs error Pareto
//! fronts for ULEEN and FINN on the FPGA target, at batch=1 and batch=∞.

use uleen::bench::paper;
use uleen::bench::table::{f2, f3, pct, Table};

fn main() -> anyhow::Result<()> {
    let zoo = paper::load_zoo()?;
    let mut rows = paper::uleen_fpga_rows(&zoo);
    rows.extend(paper::finn_fpga_rows(paper::bnn_accuracies().as_ref()));

    let mut t = Table::new(
        "Fig 11 — energy & inverse throughput vs error (FPGA)",
        &["Design", "Error %", "µJ/Inf b=1", "µJ/Inf b=∞", "1/Xput µs b=∞", "Latency µs (b=1)"],
    );
    for r in &rows {
        t.row(vec![
            r.name.clone(),
            pct(1.0 - r.accuracy),
            f3(r.uj_b1),
            f3(r.uj_binf),
            f3(1e3 / r.kips),
            f2(r.latency_us),
        ]);
    }
    t.print();

    // Pareto front check: which designs are dominated on (error, energy)?
    let mut pt = Table::new(
        "Fig 11 Pareto front (error vs steady-state energy)",
        &["Design", "On front?"],
    );
    for r in &rows {
        let dominated = rows.iter().any(|o| {
            !std::ptr::eq(o, r)
                && (1.0 - o.accuracy) <= (1.0 - r.accuracy)
                && o.uj_binf <= r.uj_binf
                && ((1.0 - o.accuracy) < (1.0 - r.accuracy) || o.uj_binf < r.uj_binf)
        });
        pt.row(vec![r.name.clone(), if dominated { "dominated".into() } else { "FRONT".into() }]);
    }
    pt.print();
    println!("(paper shape: every FINN design is dominated by a ULEEN design on energy at comparable error)");
    Ok(())
}
