//! Regenerates **Fig 10** — iterative impact of ULEEN's improvements on
//! SynthMNIST error and model size:
//!
//!   WiSARD (1981) → Bloom WiSARD (2019) → +bleach/Gaussian/H3 (one-shot
//!   ULEEN) → +multi-shot → +ensemble → +pruning (= ULN-L)
//!
//! The first three points are trained live here; the multi-shot points
//! load the artifacts exported by the Python compile path.

use uleen::bench::table::{f2, pct, Table};
use uleen::data::synth_mnist;
use uleen::encoding::thermometer::{ThermometerEncoder, ThermometerKind};
use uleen::model::bloom_wisard::BloomWisard;
use uleen::model::wisard::Wisard;
use uleen::train::oneshot::{train_oneshot, OneShotConfig};
use uleen::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let seed = 2024;
    let ds = synth_mnist(seed, 8000, 2000);
    let mut t = Table::new(
        "Fig 10 — iterative impact of ULEEN's improvements (SynthMNIST)",
        &["Model", "Error %", "Size KiB", "Notes"],
    );

    // 1. classic WiSARD: 1-bit encoding (threshold at mean ⇒ 1-bit linear
    // thermometer), direct 2^n RAM nodes.
    {
        let enc = ThermometerEncoder::fit(ThermometerKind::Linear, &ds.train_x, ds.num_features, 1);
        let mut rng = Rng::new(seed ^ 1);
        let mut w = Wisard::new(&mut rng, enc, 14, ds.num_classes);
        w.train(&ds.train_x, &ds.train_y, ds.num_features);
        let acc = w.evaluate(&ds.test_x, &ds.test_y, ds.num_features).accuracy();
        t.row(vec!["WiSARD (1981)".into(), pct(1.0 - acc), f2(w.size_kib()), "direct 2^14 RAM nodes".into()]);
    }

    // 2. Bloom WiSARD (2019): 8-bit linear thermometer, murmur double-hash
    // Bloom filters, no bleaching.
    {
        let enc = ThermometerEncoder::fit(ThermometerKind::Linear, &ds.train_x, ds.num_features, 8);
        let mut rng = Rng::new(seed ^ 2);
        let mut bw = BloomWisard::new(&mut rng, enc, 28, 2048, 2, ds.num_classes);
        bw.train(&ds.train_x, &ds.train_y, ds.num_features);
        let acc = bw.evaluate(&ds.test_x, &ds.test_y, ds.num_features).accuracy();
        t.row(vec![
            "Bloom WiSARD (2019)".into(),
            pct(1.0 - acc),
            f2(bw.size_kib()),
            format!("fill={:.2}, no bleaching", bw.mean_fill()),
        ]);
    }

    // 3. one-shot ULEEN: counting Bloom + bleaching + Gaussian thermometer
    // + H3 hashing — same geometry as the Bloom WiSARD point (n=28, 8-bit
    // thermometer) but HALF the table budget: the ULEEN one-shot
    // improvements buy equal error at half the size.
    {
        let cfg = OneShotConfig {
            inputs_per_filter: 28,
            entries_per_filter: 1024,
            therm_bits: 8,
            ..Default::default()
        };
        let (m, rep) = train_oneshot(&ds, &cfg);
        let acc = m.evaluate(&ds.test_x, &ds.test_y, ds.num_features).accuracy();
        t.row(vec![
            "+bleach+Gauss+H3 (one-shot)".into(),
            pct(1.0 - acc),
            f2(m.size_kib()),
            format!("b={}", rep.bleach),
        ]);
    }

    // 4-6. multi-shot artifacts.
    for (file, label, note) in [
        ("ms_single.uln", "+Multi-shot (single submodel)", "STE training"),
        ("uln_l_noprune.uln", "+Ensemble (ULN-L unpruned)", "6 submodels"),
        ("uln_l.uln", "+Pruning (= ULN-L)", "30% pruned + fine-tuned"),
    ] {
        let (m, _) = uleen::bench::load_model(file)?;
        let acc = m.evaluate(&ds.test_x, &ds.test_y, ds.num_features).accuracy();
        t.row(vec![label.into(), pct(1.0 - acc), f2(m.size_kib()), note.into()]);
    }
    t.print();
    println!("(paper shape: error falls monotonically WiSARD→ULN-L; pruning cuts size ~30% at ~no accuracy cost)");
    Ok(())
}
