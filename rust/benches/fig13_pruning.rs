//! Regenerates **Fig 13** — pruned size vs error for ULN-L across pruning
//! ratios 0–98%. Models come from the artifact sweep family (each pruned +
//! briefly fine-tuned at build time); error is re-measured here natively.

use uleen::bench::table::{f2, pct, Table};
use uleen::data::synth_mnist;

fn main() -> anyhow::Result<()> {
    let ds = synth_mnist(2024, 8000, 2000);
    let dir = uleen::bench::artifacts_dir().join("pruned");
    let mut files: Vec<_> = std::fs::read_dir(&dir)
        .map_err(|e| anyhow::anyhow!("{}: {e} — run `make artifacts`", dir.display()))?
        .filter_map(|e| e.ok())
        .map(|e| e.path())
        .filter(|p| p.extension().is_some_and(|x| x == "uln"))
        .collect();
    files.sort();
    let mut t = Table::new(
        "Fig 13 — pruned size vs error (ULN-L, SynthMNIST)",
        &["Prune %", "Size KiB", "Error %", "Acc.%"],
    );
    let mut prev_size = f64::INFINITY;
    for f in &files {
        let (model, meta) = uleen::model::uln_format::load(f)?;
        let ratio = meta.get("prune_ratio").and_then(|j| j.as_f64()).unwrap_or(0.0);
        let acc = model.evaluate(&ds.test_x, &ds.test_y, ds.num_features).accuracy();
        let size = model.size_kib();
        assert!(size <= prev_size + 1e-9 || ratio == 0.0, "size must shrink with pruning");
        prev_size = size;
        t.row(vec![
            format!("{:.0}", ratio * 100.0),
            f2(size),
            pct(1.0 - acc),
            pct(acc),
        ]);
    }
    t.print();
    println!("(paper shape: ~flat error to 30%, gradual to 80%, rapid decay past 90%)");
    Ok(())
}
