//! Regenerates **Table III** — ULEEN (45 nm ASIC, 192-bit IF, 500 MHz,
//! batch=16) vs Bit Fusion BF8/BF16/BF32 running ternary LeNet-5.

use uleen::bench::paper;
use uleen::bench::table::{f1, f2, i0, pct, Table};

fn main() -> anyhow::Result<()> {
    let zoo = paper::load_zoo()?;
    let uleen = paper::uleen_asic_rows(&zoo);
    let bf = paper::bitfusion_asic_rows();

    let mut t = Table::new(
        "Table III — ULEEN vs Bit Fusion on 45nm ASIC (batch=16)",
        &["Model", "Xput kIPS", "Power W", "nJ/Inf", "Area mm²", "Acc.%"],
    );
    for r in uleen.iter().chain(bf.iter()) {
        t.row(vec![
            r.name.clone(),
            i0(r.kips),
            f2(r.power_w),
            f1(r.nj_per_inf),
            f2(r.area_mm2),
            pct(r.accuracy),
        ]);
    }
    t.print();

    // headline ratios vs ULN-L (paper: 479-663x energy, 2014-19549x xput)
    let uln_l = uleen.last().unwrap();
    let mut rt = Table::new(
        "Table III ratios — ULN-L vs Bit Fusion configs",
        &["Pair", "Xput x", "Energy x"],
    );
    for b in &bf {
        rt.row(vec![
            format!("ULN-L vs {}", b.name),
            i0(uln_l.kips / b.kips),
            i0(b.nj_per_inf / uln_l.nj_per_inf),
        ]);
    }
    rt.print();
    Ok(())
}
