//! Regenerates **Table IV** — ULEEN vs Bloom WiSARD (the prior
//! state-of-the-art memory-efficient WNN) on the nine classification
//! datasets: test accuracy and model size.
//!
//! The Bloom WiSARD baseline is trained HERE, faithfully to the 2019
//! paper: binary Bloom filters, MurmurHash double hashing, one-shot
//! set-on-seen training, no bleaching. ULEEN rows load the multi-shot
//! artifacts and re-measure accuracy with the native engine.

use uleen::bench::table::{f2, pct, Table};
use uleen::data::{synth_mnist, synth_uci, uci_specs};
use uleen::encoding::thermometer::{ThermometerEncoder, ThermometerKind};
use uleen::model::bloom_wisard::BloomWisard;
use uleen::util::rng::Rng;

/// Bloom WiSARD baseline config per dataset: 28 inputs/filter like the
/// original paper's MNIST config, table sized to land near the original
/// paper's per-dataset model sizes.
fn baseline_entries(ds_name: &str) -> usize {
    match ds_name {
        "synth_mnist" => 2048,
        "synth_letter" => 4096,
        "synth_satimage" => 512,
        _ => 1024,
    }
}

fn main() -> anyhow::Result<()> {
    let seed = 2024;
    let mut t = Table::new(
        "Table IV — ULEEN (multi-shot) vs Bloom WiSARD baseline",
        &["Dataset", "BloomWSD Acc.%", "ULEEN Acc.%", "BloomWSD KiB", "ULEEN KiB"],
    );
    let mut wins_acc = 0usize;
    let mut wins_size = 0usize;
    let mut n = 0usize;

    let mut run = |ds: uleen::data::Dataset, uln_file: &str| -> anyhow::Result<()> {
        let (uln_model, _) = uleen::bench::load_model(uln_file)?;
        let uln_conf = uln_model.evaluate(&ds.test_x, &ds.test_y, ds.num_features);
        // Bloom WiSARD baseline: linear thermometer (pre-ULEEN practice)
        let enc = ThermometerEncoder::fit(ThermometerKind::Linear, &ds.train_x, ds.num_features, 8);
        let mut rng = Rng::new(seed ^ 0xB100);
        let mut bw = BloomWisard::new(&mut rng, enc, 28, baseline_entries(&ds.name), 2, ds.num_classes);
        bw.train(&ds.train_x, &ds.train_y, ds.num_features);
        let bw_conf = bw.evaluate(&ds.test_x, &ds.test_y, ds.num_features);
        if uln_conf.accuracy() >= bw_conf.accuracy() {
            wins_acc += 1;
        }
        if uln_model.size_kib() <= bw.size_kib() {
            wins_size += 1;
        }
        n += 1;
        t.row(vec![
            ds.name.clone(),
            pct(bw_conf.accuracy()),
            pct(uln_conf.accuracy()),
            f2(bw.size_kib()),
            f2(uln_model.size_kib()),
        ]);
        Ok(())
    };

    run(synth_mnist(seed, 8000, 2000), "uln_l.uln")?;
    for spec in uci_specs() {
        run(synth_uci(seed, spec), &format!("uci/uln_{}.uln", spec.name))?;
    }
    t.print();
    println!(
        "ULEEN more accurate on {wins_acc}/{n} datasets, smaller on {wins_size}/{n} \
         (paper: more accurate AND smaller on 9/9)"
    );
    Ok(())
}
