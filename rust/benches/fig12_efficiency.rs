//! Regenerates **Fig 12** — power efficiency (inferences per Joule) for the
//! ULEEN ASIC designs and the Bit Fusion configurations.

use uleen::bench::paper;
use uleen::bench::table::{i0, Table};

fn main() -> anyhow::Result<()> {
    let zoo = paper::load_zoo()?;
    let rows: Vec<_> = paper::uleen_asic_rows(&zoo)
        .into_iter()
        .chain(paper::bitfusion_asic_rows())
        .collect();

    let mut t = Table::new(
        "Fig 12 — power efficiency, inferences per Joule (45nm ASIC)",
        &["Design", "Inf/J", "bar"],
    );
    let max_ipj = rows
        .iter()
        .map(|r| 1e9 / r.nj_per_inf)
        .fold(0.0f64, f64::max);
    for r in &rows {
        let ipj = 1e9 / r.nj_per_inf;
        // log-scale bar like the paper's figure
        let bar_len = ((ipj.log10() - 2.0) / (max_ipj.log10() - 2.0) * 40.0).max(1.0) as usize;
        t.row(vec![r.name.clone(), i0(ipj), "#".repeat(bar_len)]);
    }
    t.print();
    let uln = rows.iter().find(|r| r.name == "ULN_L").unwrap();
    let bf = rows.iter().find(|r| r.name == "BF32").unwrap();
    println!(
        "ULN-L is {:.0}x more efficient than the best Bit Fusion config (paper: 479-663x vs BF set)",
        bf.nj_per_inf / uln.nj_per_inf
    );
    Ok(())
}
